//! Scenario-engine benches: expansion cost, policy-run cost serial vs
//! fanned, and the per-round evaluation fast path the runner sits on.

use epsl::config::NetworkConfig;
use epsl::optim::bcd::BcdOptions;
use epsl::profile::resnet18;
use epsl::scenario::{
    run_policy, ReoptPolicy, RunOptions, Scenario, ScenarioSpec,
};
use epsl::timeline::Mode;
use epsl::util::bench::Bencher;
use epsl::util::par;

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let mut b = if smoke { Bencher::smoke() } else { Bencher::new() };
    let net = NetworkConfig::default();
    let profile = resnet18::profile_static();

    // Expansion alone (no solves): the engine must stay negligible next
    // to BCD.
    let rounds = if smoke { 16 } else { 256 };
    let full_spec = ScenarioSpec {
        rounds,
        redraw_period: Some(2),
        los_flip: Some(epsl::scenario::LosFlipSpec { flip_prob: 0.2 }),
        compute_jitter: Some(epsl::scenario::ComputeJitterSpec {
            amplitude: 0.1,
        }),
        churn: None,
    };
    b.run(&format!("scenario expand {rounds} rounds (fading+los+jitter)"),
          || Scenario::generate(&net, &full_spec, 0xBE7).unwrap());

    // Policy runs over one pre-expanded scenario.
    let run_rounds = if smoke { 8 } else { 32 };
    let sc = Scenario::generate(
        &net,
        &ScenarioSpec::fading(run_rounds),
        0xBE7,
    )
    .unwrap();
    let opts = |policy, threads| RunOptions {
        policy,
        bcd: BcdOptions { max_iters: 6, tol: 1e-4 },
        batch: 64,
        phi: 0.5,
        threads,
        timeline_mode: Mode::Barrier,
    };
    b.run(&format!("run_policy never ({run_rounds} rounds, serial)"), || {
        run_policy(&sc, profile, &opts(ReoptPolicy::Never, 1))
    });
    if !smoke {
        b.run(&format!("run_policy oracle ({run_rounds} rounds, serial)"),
              || run_policy(&sc, profile, &opts(ReoptPolicy::EveryK(1), 1)));
        b.run(
            &format!(
                "run_policy oracle ({run_rounds} rounds, {} threads)",
                par::max_threads()
            ),
            || {
                run_policy(
                    &sc,
                    profile,
                    &opts(ReoptPolicy::EveryK(1), par::max_threads()),
                )
            },
        );
    }
    println!("\n{}", b.report());
    b.write_bench_json_if_requested();
}
