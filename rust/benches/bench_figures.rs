//! Figure-regeneration benches: one end-to-end timing per paper
//! table/figure family, matching DESIGN.md's per-experiment index.
//! (The latency figures run at quick settings; the training figures time a
//! short representative slice rather than a full convergence run.)

use epsl::config::Config;
use epsl::coordinator::{train, TrainerOptions};
use epsl::experiments::{self, Ctx};
use epsl::latency::frameworks::Framework;
use epsl::runtime::{select_backend, BackendChoice};
use epsl::util::bench::Bencher;

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let cfg = Config::new();
    let mut b = if smoke { Bencher::smoke() } else { Bencher::slow() };

    // Pure latency-model figures (no artifacts needed). fig12/fig13 share
    // fig11's machinery (scheme sweep / BCD loop) and take minutes per
    // iteration — fig11 is the representative timing. The sweep grids fan
    // across cores (EPSL_THREADS=1 to time the serial path); smoke mode
    // sticks to the cheap table generators.
    let figure_ids: &[&str] =
        if smoke { &["table1", "table4"] } else { &["table1", "table4", "fig11"] };
    for &id in figure_ids {
        b.run(&format!("figure {id} (quick)"), || {
            let mut ctx =
                Ctx::new(Config::new(), None, None, "/tmp/epsl_bench", true);
            experiments::run(id, &mut ctx).unwrap()
        });
    }

    // Training-figure slices (table5 / fig4 / fig7-10 share this path) —
    // on PJRT when artifacts exist, else on the native backend.
    let sel = select_backend("artifacts", BackendChoice::Auto)
        .expect("backend selection");
    let (rt, manifest) = (sel.backend.as_ref(), &sel.manifest);
    println!("training slices on the {} backend", sel.kind);
    for (name, fw) in [
        ("PSL", Framework::Psl),
        ("EPSL(0.5)", Framework::Epsl { phi: 0.5 }),
        ("SFL", Framework::Sfl),
        ("vanilla SL", Framework::VanillaSl),
    ] {
        b.run(&format!("train 5 rounds {name} C=5 (fig4/7/8 slice)"), || {
            let opts = TrainerOptions {
                framework: fw,
                n_clients: 5,
                rounds: 5,
                eval_every: 100,
                dataset_size: 500,
                test_size: 256,
                ..Default::default()
            };
            train(rt, manifest, &cfg, &opts).unwrap()
        });
    }
    println!("\n{}", b.report());
    b.write_bench_json_if_requested();
}
