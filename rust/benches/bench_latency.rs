//! Latency-model benches: stage-latency evaluation for every framework
//! (these run inside every optimizer objective evaluation — the tightest
//! L3 inner loop after the rate computations), plus the timeline event
//! engine in both modes (barrier must stay ~free next to the closed
//! form; pipelined pays an O(C²) FIFO-slot scan per round).
//!
//! `BENCH_JSON=BENCH_5.json cargo bench --bench bench_latency` records
//! the PR 5 perf row set.

use epsl::latency::frameworks::{round_latency, Framework};
use epsl::latency::{epsl_stage_latencies, LatencyInputs};
use epsl::profile::{resnet18, splitnet};
use epsl::timeline::{simulate, Mode};
use epsl::util::bench::Bencher;

fn main() {
    let p18 = resnet18::profile();
    let psn = splitnet::profile(splitnet::SplitNetConfig::mnist_like());
    let f = vec![1e9, 1.2e9, 1.3e9, 1.5e9, 1.6e9];
    let up = vec![1.5e8; 5];
    let dn = vec![1.5e8; 5];
    let mk = |profile, cut| LatencyInputs {
        profile,
        cut,
        batch: 64,
        phi: 0.5,
        f_server: 5e9,
        kappa_server: 1.0 / 32.0,
        kappa_client: 1.0 / 16.0,
        f_clients: &f,
        uplink: &up,
        downlink: &dn,
        broadcast: 2e8,
        uplink_comp: 1.0,
    };

    let smoke = std::env::args().any(|a| a == "--test");
    let mut b = if smoke { Bencher::smoke() } else { Bencher::new() };
    let inp18 = mk(&p18, 10);
    let inpsn = mk(&psn, 2);
    b.run("epsl_stages resnet18 (18 layers)", || {
        epsl_stage_latencies(&inp18)
    });
    b.run("epsl_stages splitnet (5 layers)", || {
        epsl_stage_latencies(&inpsn)
    });
    for fw in [
        Framework::VanillaSl,
        Framework::Sfl,
        Framework::Psl,
        Framework::Epsl { phi: 0.5 },
    ] {
        b.run(&format!("round_latency {}", fw.name()), || {
            round_latency(fw, &inp18).round_total()
        });
    }

    // Timeline engine: barrier parity smoke (the closed form plus event
    // emission) and the pipelined overlapped schedule, C=5 and C=32.
    for fw in [Framework::Epsl { phi: 0.5 }, Framework::Sfl] {
        b.run(&format!("timeline barrier {} C=5", fw.name()), || {
            simulate(fw, &inp18, Mode::Barrier).total
        });
        b.run(&format!("timeline pipelined {} C=5", fw.name()), || {
            simulate(fw, &inp18, Mode::Pipelined).total
        });
    }
    let f32c: Vec<f64> =
        (0..32).map(|i| 0.8e9 + 4e7 * i as f64).collect();
    let up32: Vec<f64> =
        (0..32).map(|i| 5e7 + 1e7 * i as f64).collect();
    let dn32: Vec<f64> =
        (0..32).map(|i| 5e7 + 9e6 * i as f64).collect();
    let inp32 = LatencyInputs {
        profile: &p18,
        cut: 10,
        batch: 64,
        phi: 0.5,
        f_server: 5e9,
        kappa_server: 1.0 / 32.0,
        kappa_client: 1.0 / 16.0,
        f_clients: &f32c,
        uplink: &up32,
        downlink: &dn32,
        broadcast: 2e8,
        uplink_comp: 1.0,
    };
    b.run("timeline barrier EPSL C=32", || {
        simulate(Framework::Epsl { phi: 0.5 }, &inp32, Mode::Barrier)
            .total
    });
    b.run("timeline pipelined EPSL C=32", || {
        simulate(Framework::Epsl { phi: 0.5 }, &inp32, Mode::Pipelined)
            .total
    });
    // Correctness gate before timing is trusted: parity + dominance on
    // the bench fixtures themselves.
    let bar =
        simulate(Framework::Epsl { phi: 0.5 }, &inp32, Mode::Barrier);
    let pipe =
        simulate(Framework::Epsl { phi: 0.5 }, &inp32, Mode::Pipelined);
    let closed =
        round_latency(Framework::Epsl { phi: 0.5 }, &inp32).round_total();
    assert_eq!(bar.total.to_bits(), closed.to_bits(), "barrier parity");
    assert!(pipe.total <= bar.total, "pipelined dominance");

    b.run("profile rho/varpi scan (all cuts)", || {
        let mut acc = 0.0;
        for &j in &p18.cut_candidates {
            acc += p18.client_fp_flops(j) + p18.server_bp_flops(j);
        }
        acc
    });
    println!("\n{}", b.report());
    b.write_bench_json_if_requested();
}
