//! Latency-model benches: stage-latency evaluation for every framework
//! (these run inside every optimizer objective evaluation — the tightest
//! L3 inner loop after the rate computations).

use epsl::latency::frameworks::{round_latency, Framework};
use epsl::latency::{epsl_stage_latencies, LatencyInputs};
use epsl::profile::{resnet18, splitnet};
use epsl::util::bench::Bencher;

fn main() {
    let p18 = resnet18::profile();
    let psn = splitnet::profile(splitnet::SplitNetConfig::mnist_like());
    let f = vec![1e9, 1.2e9, 1.3e9, 1.5e9, 1.6e9];
    let up = vec![1.5e8; 5];
    let dn = vec![1.5e8; 5];
    let mk = |profile, cut| LatencyInputs {
        profile,
        cut,
        batch: 64,
        phi: 0.5,
        f_server: 5e9,
        kappa_server: 1.0 / 32.0,
        kappa_client: 1.0 / 16.0,
        f_clients: &f,
        uplink: &up,
        downlink: &dn,
        broadcast: 2e8,
    };

    let smoke = std::env::args().any(|a| a == "--test");
    let mut b = if smoke { Bencher::smoke() } else { Bencher::new() };
    let inp18 = mk(&p18, 10);
    let inpsn = mk(&psn, 2);
    b.run("epsl_stages resnet18 (18 layers)", || {
        epsl_stage_latencies(&inp18)
    });
    b.run("epsl_stages splitnet (5 layers)", || {
        epsl_stage_latencies(&inpsn)
    });
    for fw in [
        Framework::VanillaSl,
        Framework::Sfl,
        Framework::Psl,
        Framework::Epsl { phi: 0.5 },
    ] {
        b.run(&format!("round_latency {}", fw.name()), || {
            round_latency(fw, &inp18).round_total()
        });
    }
    b.run("profile rho/varpi scan (all cuts)", || {
        let mut acc = 0.0;
        for &j in &p18.cut_candidates {
            acc += p18.client_fp_flops(j) + p18.server_bp_flops(j);
        }
        acc
    });
    println!("\n{}", b.report());
    b.write_bench_json_if_requested();
}
