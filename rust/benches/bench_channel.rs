//! Channel-simulator benches: deployment generation, gain realization,
//! rate evaluation (the inner loop of every optimizer iteration).

use epsl::channel::rate::{broadcast_rate, downlink_rates, uplink_rates,
                          Allocation};
use epsl::channel::{pathloss, ChannelRealization, Deployment};
use epsl::config::NetworkConfig;
use epsl::util::bench::Bencher;
use epsl::util::rng::Rng;

fn main() {
    let cfg = NetworkConfig::default();
    let mut rng = Rng::new(1);
    let dep = Deployment::generate(&cfg, &mut rng);
    let ch = ChannelRealization::average(&dep);
    let mut alloc = Allocation::empty(cfg.n_subchannels);
    for k in 0..cfg.n_subchannels {
        alloc.assign(k, k % cfg.n_clients);
    }
    let psd = vec![-62.0; cfg.n_subchannels];

    let smoke = std::env::args().any(|a| a == "--test");
    let mut b = if smoke { Bencher::smoke() } else { Bencher::new() };
    let mut rng2 = Rng::new(2);
    b.run("deployment_generate (C=5, M=20)", || {
        Deployment::generate(&cfg, &mut rng2)
    });
    b.run("channel_average (C=5, M=20)", || {
        ChannelRealization::average(&dep)
    });
    b.run("channel_sample (shadow fading)", || {
        ChannelRealization::sample(&dep, &mut rng)
    });
    b.run("uplink_rates (eq 14)", || {
        uplink_rates(&cfg, &ch, &alloc, &psd)
    });
    b.run("downlink_rates (eq 20)", || downlink_rates(&cfg, &ch, &alloc));
    b.run("broadcast_rate (eq 18)", || broadcast_rate(&cfg, &ch));
    b.run("pathloss_mean_gain", || {
        pathloss::mean_gain(28e9, 120.0, false)
    });
    println!("\n{}", b.report());
    b.write_bench_json_if_requested();
}
