//! Runtime benches: per-entry execution cost and the full EPSL round —
//! the measured counterpart of the §V latency model and the focus of the
//! §Perf pass.
//!
//! Runs on whatever backend `auto` selects: PJRT when `make artifacts`
//! has been run (the L1/L2 measurement), the pure-Rust native backend
//! otherwise — so the training hot path has perf coverage on every
//! checkout (PERF.md §4 records the native per-round wall numbers).

use epsl::config::Config;
use epsl::coordinator::{train, TrainerOptions};
use epsl::runtime::tensor::{literal_f32, literal_i32, literal_u32};
use epsl::runtime::{select_backend, Backend, BackendChoice};
use epsl::util::bench::Bencher;
use epsl::util::rng::Rng;

fn main() {
    let sel = select_backend("artifacts", BackendChoice::Auto)
        .expect("backend selection");
    let (rt, manifest) = (sel.backend.as_ref(), &sel.manifest);
    println!("bench_runtime backend: {}", sel.describe());
    let fam = manifest.family("mnist").expect("mnist family");
    let b = fam.batch;
    let cut = 2;
    let c = 5;
    let mut rng = Rng::new(3);

    // Inputs.
    let seed = literal_u32(&[2], &[0, 1]).unwrap();
    let params = rt.call(&fam.init, &[seed]).unwrap();
    let ncp = fam.client_param_count[&cut];
    let (client_p, server_p) = (params[..ncp].to_vec(), params[ncp..].to_vec());
    let img: Vec<f32> = (0..b * 16 * 16)
        .map(|_| rng.normal(0.0, 1.0) as f32)
        .collect();
    let x = literal_f32(&[b, 16, 16, 1], &img).unwrap();
    let smash = &fam.smashed_shape[&cut];
    let smash_len: usize = smash.iter().product();

    let mut bench = Bencher::slow();

    let cf = fam.client_fwd.get(&cut).unwrap();
    let mut inputs = client_p.clone();
    inputs.push(x.clone());
    bench.run("client_fwd cut2 (b=32)", || {
        rt.call(cf, &inputs).unwrap()
    });

    let smashed_out = rt.call(cf, &inputs).unwrap();
    let one = smashed_out[0].to_vec::<f32>().unwrap();
    let mut all = Vec::with_capacity(c * one.len());
    for _ in 0..c {
        all.extend_from_slice(&one);
    }
    let mut st_shape = vec![c, b];
    st_shape.extend(smash.iter());
    let labels: Vec<i32> =
        (0..c * b).map(|i| (i % 10) as i32).collect();
    let st = fam.server_train_entry(cut, c).unwrap();
    let mut st_inputs = server_p.clone();
    st_inputs.push(literal_f32(&st_shape, &all).unwrap());
    st_inputs.push(literal_i32(&[c, b], &labels).unwrap());
    st_inputs.push(literal_f32(&[c], &vec![0.2; c]).unwrap());
    st_inputs
        .push(literal_f32(&[b], &vec![1.0; b / 2]
            .into_iter()
            .chain(vec![0.0; b - b / 2])
            .collect::<Vec<f32>>()).unwrap());
    st_inputs.push(literal_f32(&[], &[0.1]).unwrap());
    bench.run("server_train cut2 C=5 (EPSL phi=0.5)", || {
        rt.call(st, &st_inputs).unwrap()
    });

    let cs = fam.client_step.get(&cut).unwrap();
    let g: Vec<f32> = vec![0.01; b * smash_len];
    let mut g_shape = vec![b];
    g_shape.extend(smash.iter());
    let mut cs_inputs = client_p.clone();
    cs_inputs.push(x.clone());
    cs_inputs.push(literal_f32(&g_shape, &g).unwrap());
    cs_inputs.push(literal_f32(&[], &[0.1]).unwrap());
    bench.run("client_step cut2 (b=32)", || {
        rt.call(cs, &cs_inputs).unwrap()
    });

    let pa = fam.phi_agg.get(&cut).unwrap();
    let zspec = &pa.inputs[0];
    let (zc, zb, zq) = (zspec.shape[0], zspec.shape[1], zspec.shape[2]);
    let z: Vec<f32> = vec![0.5; zc * zb * zq];
    let pa_inputs = vec![
        literal_f32(&[zc, zb, zq], &z).unwrap(),
        literal_f32(&[zc], &vec![0.2; zc]).unwrap(),
        literal_f32(&[zb], &vec![1.0; zb]).unwrap(),
    ];
    bench.run("phi_aggregate kernel (C=5)", || {
        rt.call(pa, &pa_inputs).unwrap()
    });

    // Full EPSL round through the coordinator (end-to-end: tables F4/F9).
    let cfg = Config::new();
    bench.run("full_epsl_round C=5 (coordinator e2e)", || {
        let opts = TrainerOptions {
            n_clients: 5,
            rounds: 1,
            eval_every: 100,
            dataset_size: 400,
            test_size: 256,
            ..Default::default()
        };
        train(rt, manifest, &cfg, &opts).unwrap()
    });

    println!("\n{}", bench.report());
    println!("{}", rt.stats_summary());
    // Optional perf-trajectory record (see PERF.md §5).
    bench.write_bench_json_if_requested();
}
