//! Runtime benches: per-entry execution cost, the full EPSL round,
//! the PR 4 **reference-vs-fast kernel pairs** for the native backend's
//! im2col + blocked-GEMM compute core, and — since PR 10 — the
//! **bitwise-vs-fast math-tier pairs** (scalar deterministic tier vs the
//! SIMD microkernel + threaded macro-loop tier).
//!
//! Runs on whatever backend `auto` selects for the entry-point section
//! (PJRT when `make artifacts` has been run, the pure-Rust native
//! backend otherwise); the kernel A/B sections always measure the native
//! model paths directly. Before timing, the bitwise outputs are verified
//! **bitwise** against the retained naive reference, and the fast tier's
//! outputs are verified finite, within tolerance of the bitwise tier,
//! and run-to-run deterministic at the fixed thread count — the bench
//! binary exits non-zero on any mismatch, which is what the CI smoke run
//! (`cargo bench --bench bench_runtime -- --test`) enforces. Both tiers
//! are verified before either is timed: a bench must never publish a
//! speedup for a configuration it has not checked in the same run.
//!
//! `BENCH_JSON=BENCH_4.json cargo bench --bench bench_runtime` records
//! the perf trajectory; the acceptance row for PR 4 is the
//! `server_train cut2 C=4` pair (target ≥5× reference/fast), and for
//! PR 10 (`BENCH_JSON=BENCH_10.json`) the `server_train cut2 C=4`
//! tier pair (target ≥2× bitwise/fast on ≥2 threads).

use epsl::config::Config;
use epsl::coordinator::{train, TrainerOptions};
use epsl::profile::splitnet::SplitNetConfig;
use epsl::runtime::native::kernels::ScratchPool;
use epsl::runtime::native::model;
use epsl::runtime::native::MathTier;
use epsl::runtime::tensor::{literal_f32, literal_i32, literal_u32};
use epsl::runtime::{select_backend, Backend, BackendChoice};
use epsl::util::bench::{format_ns, Bencher};
use epsl::util::par;
use epsl::util::rng::Rng;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_finite(name: &str, v: &[f32]) {
    assert!(
        v.iter().all(|x| x.is_finite()),
        "{name}: non-finite output from the fast kernels"
    );
}

/// Element-wise relative tolerance check for the fast-tier verification
/// (the fast tier reassociates, so bitwise equality does not apply —
/// see PERF.md §10 for the tolerance model).
fn assert_close(name: &str, reference: &[f32], fast: &[f32], tol: f32) {
    assert_eq!(reference.len(), fast.len(), "{name}: length mismatch");
    for (i, (r, f)) in reference.iter().zip(fast).enumerate() {
        let scale = r.abs().max(f.abs()).max(1.0);
        assert!(
            (r - f).abs() <= tol * scale,
            "{name}[{i}]: fast {f} vs bitwise {r} outside tol {tol}"
        );
    }
}

/// Reference-vs-GEMM pairs on the native model paths (the PR 4
/// acceptance measurement), preceded by a bitwise verification pass.
fn kernel_pairs(bench: &mut Bencher) {
    let cfg = SplitNetConfig::mnist_like();
    let pool = ScratchPool::new();
    let threads = par::max_threads();
    let (cut, c, b) = (2usize, 4usize, 32usize);
    let n_c = model::client_param_count(cut);
    let params = model::init_params(&cfg, 1);
    let in_len = cfg.img * cfg.img * cfg.channels;
    let (sh, sw, sc) = cfg.smashed_shape(cut);
    let smash_len = sh * sw * sc;
    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..b * in_len)
        .map(|_| rng.normal(0.0, 1.0) as f32)
        .collect();
    let smashed: Vec<f32> = (0..c * b * smash_len)
        .map(|_| rng.normal(0.0, 1.0) as f32)
        .collect();
    let labels: Vec<i32> =
        (0..c * b).map(|i| (i % 10) as i32).collect();
    let lam = vec![1.0 / c as f32; c];
    let mask: Vec<f32> = (0..b)
        .map(|j| if j < b / 2 { 1.0 } else { 0.0 })
        .collect();

    // --- verification: fast ≡ reference, bitwise, before timing ---
    let f_smash = model::client_fwd(&cfg, cut, &params[..n_c], &x, b,
                                    MathTier::Bitwise, &pool);
    let r_smash =
        model::client_fwd_reference(&cfg, cut, &params[..n_c], &x, b);
    assert_eq!(bits(&r_smash), bits(&f_smash),
               "client_fwd fast != reference");
    assert_finite("client_fwd", &f_smash);
    let f = model::server_train(&cfg, cut, c, b, threads,
                                MathTier::Bitwise, &params[n_c..],
                                &smashed, &labels, &lam, &mask, 0.05,
                                &pool)
        .expect("valid labels");
    let r = model::server_train_reference(&cfg, cut, c, b, threads,
                                          &params[n_c..], &smashed,
                                          &labels, &lam, &mask, 0.05);
    assert_eq!(f.loss.to_bits(), r.loss.to_bits(),
               "server_train loss fast != reference");
    assert_eq!(bits(&f.cut_agg), bits(&r.cut_agg),
               "server_train cut_agg fast != reference");
    assert_eq!(bits(&f.cut_unagg), bits(&r.cut_unagg),
               "server_train cut_unagg fast != reference");
    for (t, (fp, rp)) in f.new_params.iter().zip(&r.new_params).enumerate()
    {
        assert_eq!(bits(fp), bits(rp),
                   "server_train new_params[{t}] fast != reference");
        assert_finite("server_train new_params", fp);
    }
    assert_finite("server_train cut_agg", &f.cut_agg);
    assert_finite("server_train cut_unagg", &f.cut_unagg);
    println!("kernel verification: fast == reference (bitwise), finite\n");

    // --- timed pairs ---
    bench.run("client_fwd cut2 b=32 reference (naive)", || {
        model::client_fwd_reference(&cfg, cut, &params[..n_c], &x, b)
    });
    bench.run("client_fwd cut2 b=32 fast (im2col+GEMM)", || {
        model::client_fwd(&cfg, cut, &params[..n_c], &x, b,
                          MathTier::Bitwise, &pool)
    });
    bench.run("server_train cut2 C=4 reference (naive)", || {
        model::server_train_reference(&cfg, cut, c, b, threads,
                                      &params[n_c..], &smashed, &labels,
                                      &lam, &mask, 0.05)
    });
    bench.run("server_train cut2 C=4 fast (im2col+GEMM)", || {
        model::server_train(&cfg, cut, c, b, threads, MathTier::Bitwise,
                            &params[n_c..], &smashed, &labels, &lam,
                            &mask, 0.05, &pool)
            .unwrap()
    });
    let ex: Vec<f32> = (0..256 * in_len)
        .map(|_| rng.normal(0.0, 1.0) as f32)
        .collect();
    let ey: Vec<i32> = (0..256).map(|i| (i % 10) as i32).collect();
    bench.run("eval n=256 reference (naive)", || {
        model::eval_reference(&cfg, &params, &ex, &ey, threads)
    });
    bench.run("eval n=256 fast (im2col+GEMM)", || {
        model::eval(&cfg, &params, &ex, &ey, threads, MathTier::Bitwise,
                    &pool)
            .unwrap()
    });
}

/// Bitwise-vs-fast math-tier pairs on the native model paths (the PR 10
/// acceptance measurement), preceded by the fast tier's own
/// verification pass: the previous revision only verified the bitwise
/// tier before timing, so a broken fast tier could still publish
/// speedup rows — now every fast-tier output is checked for finiteness,
/// tolerance against the bitwise tier, and run-to-run determinism at
/// the fixed thread count before any tier row is timed.
fn tier_pairs(bench: &mut Bencher) {
    let cfg = SplitNetConfig::mnist_like();
    let pool = ScratchPool::new();
    let threads = par::max_threads();
    let (cut, c, b) = (2usize, 4usize, 32usize);
    let n_c = model::client_param_count(cut);
    let params = model::init_params(&cfg, 11);
    let in_len = cfg.img * cfg.img * cfg.channels;
    let (sh, sw, sc) = cfg.smashed_shape(cut);
    let smash_len = sh * sw * sc;
    let mut rng = Rng::new(29);
    let x: Vec<f32> = (0..b * in_len)
        .map(|_| rng.normal(0.0, 1.0) as f32)
        .collect();
    let smashed: Vec<f32> = (0..c * b * smash_len)
        .map(|_| rng.normal(0.0, 1.0) as f32)
        .collect();
    let labels: Vec<i32> =
        (0..c * b).map(|i| (i % 10) as i32).collect();
    let lam = vec![1.0 / c as f32; c];
    let mask: Vec<f32> = (0..b)
        .map(|j| if j < b / 2 { 1.0 } else { 0.0 })
        .collect();
    let tol = 1e-3f32;

    // --- verification: fast tier finite + within tolerance of bitwise
    //     + deterministic at this thread count, before timing ---
    let bw_smash = model::client_fwd(&cfg, cut, &params[..n_c], &x, b,
                                     MathTier::Bitwise, &pool);
    let ft_smash = model::client_fwd(&cfg, cut, &params[..n_c], &x, b,
                                     MathTier::Fast, &pool);
    assert_finite("client_fwd tier=fast", &ft_smash);
    assert_close("client_fwd tier=fast", &bw_smash, &ft_smash, tol);
    let bw = model::server_train(&cfg, cut, c, b, threads,
                                 MathTier::Bitwise, &params[n_c..],
                                 &smashed, &labels, &lam, &mask, 0.05,
                                 &pool)
        .expect("valid labels");
    let ft = model::server_train(&cfg, cut, c, b, threads,
                                 MathTier::Fast, &params[n_c..],
                                 &smashed, &labels, &lam, &mask, 0.05,
                                 &pool)
        .expect("valid labels");
    let ft2 = model::server_train(&cfg, cut, c, b, threads,
                                  MathTier::Fast, &params[n_c..],
                                  &smashed, &labels, &lam, &mask, 0.05,
                                  &pool)
        .expect("valid labels");
    assert_eq!(ft.loss.to_bits(), ft2.loss.to_bits(),
               "fast tier nondeterministic at fixed thread count");
    assert_eq!(bits(&ft.cut_agg), bits(&ft2.cut_agg),
               "fast tier cut_agg nondeterministic at fixed threads");
    assert!(ft.loss.is_finite(),
            "server_train tier=fast: non-finite loss");
    assert_close("server_train cut_agg tier=fast", &bw.cut_agg,
                 &ft.cut_agg, tol);
    assert_close("server_train cut_unagg tier=fast", &bw.cut_unagg,
                 &ft.cut_unagg, tol);
    assert_close("server_train loss tier=fast", &[bw.loss], &[ft.loss],
                 tol);
    for (t, (bp, fp)) in bw.new_params.iter().zip(&ft.new_params)
        .enumerate()
    {
        assert_finite("server_train new_params tier=fast", fp);
        assert_close(&format!("server_train new_params[{t}] tier=fast"),
                     bp, fp, tol);
    }
    let ex: Vec<f32> = (0..256 * in_len)
        .map(|_| rng.normal(0.0, 1.0) as f32)
        .collect();
    let ey: Vec<i32> = (0..256).map(|i| (i % 10) as i32).collect();
    let (bl, bc) = model::eval(&cfg, &params, &ex, &ey, threads,
                               MathTier::Bitwise, &pool)
        .expect("valid labels");
    let (fl, fc) = model::eval(&cfg, &params, &ex, &ey, threads,
                               MathTier::Fast, &pool)
        .expect("valid labels");
    assert_close("eval loss tier=fast", &[bl], &[fl], tol);
    // An argmax can legitimately flip on a near-tie under reassociated
    // sums; bound the drift instead of demanding equality.
    assert!((fc - bc).abs() <= 2.0,
            "eval ncorrect drifted: bitwise {bc} vs fast {fc}");
    println!(
        "tier verification: fast within tol={tol} of bitwise, finite, \
         deterministic at {threads} threads\n"
    );

    // --- timed pairs (adjacent rows feed the speedup table) ---
    bench.run("client_fwd cut2 b=32 tier=bitwise", || {
        model::client_fwd(&cfg, cut, &params[..n_c], &x, b,
                          MathTier::Bitwise, &pool)
    });
    bench.run("client_fwd cut2 b=32 tier=fast", || {
        model::client_fwd(&cfg, cut, &params[..n_c], &x, b,
                          MathTier::Fast, &pool)
    });
    bench.run("server_train cut2 C=4 tier=bitwise", || {
        model::server_train(&cfg, cut, c, b, threads, MathTier::Bitwise,
                            &params[n_c..], &smashed, &labels, &lam,
                            &mask, 0.05, &pool)
            .unwrap()
    });
    bench.run("server_train cut2 C=4 tier=fast", || {
        model::server_train(&cfg, cut, c, b, threads, MathTier::Fast,
                            &params[n_c..], &smashed, &labels, &lam,
                            &mask, 0.05, &pool)
            .unwrap()
    });
    bench.run("eval n=256 tier=bitwise", || {
        model::eval(&cfg, &params, &ex, &ey, threads, MathTier::Bitwise,
                    &pool)
            .unwrap()
    });
    bench.run("eval n=256 tier=fast", || {
        model::eval(&cfg, &params, &ex, &ey, threads, MathTier::Fast,
                    &pool)
            .unwrap()
    });
}

/// Print `slow / fast` ratios for every adjacent pair: the PR 4
/// reference-vs-GEMM pairs and the PR 10 bitwise-vs-fast tier pairs.
fn speedup_table(bench: &Bencher) {
    println!("\nspeedups (slow / fast):");
    let rs = bench.results();
    for pair in rs.windows(2) {
        let (a, b) = (&pair[0], &pair[1]);
        let stem = if b.name.ends_with(" fast (im2col+GEMM)") {
            a.name.strip_suffix(" reference (naive)")
        } else if b.name.ends_with(" tier=fast") {
            a.name.strip_suffix(" tier=bitwise")
        } else {
            None
        };
        if let Some(stem) = stem {
            println!(
                "  {:<32} {:>10} -> {:>10}  {:5.1}x",
                stem,
                format_ns(a.ns_per_iter()),
                format_ns(b.ns_per_iter()),
                a.ns_per_iter() / b.ns_per_iter()
            );
        }
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let sel = select_backend("artifacts", BackendChoice::Auto)
        .expect("backend selection");
    let (rt, manifest) = (sel.backend.as_ref(), &sel.manifest);
    println!("bench_runtime backend: {}", sel.describe());
    let fam = manifest.family("mnist").expect("mnist family");
    let b = fam.batch;
    let cut = 2;
    let c = 5;
    let mut rng = Rng::new(3);

    // Inputs.
    let seed = literal_u32(&[2], &[0, 1]).unwrap();
    let params = rt.call(&fam.init, &[seed]).unwrap();
    let ncp = fam.client_param_count[&cut];
    let (client_p, server_p) = (params[..ncp].to_vec(), params[ncp..].to_vec());
    let img: Vec<f32> = (0..b * 16 * 16)
        .map(|_| rng.normal(0.0, 1.0) as f32)
        .collect();
    let x = literal_f32(&[b, 16, 16, 1], &img).unwrap();
    let smash = &fam.smashed_shape[&cut];
    let smash_len: usize = smash.iter().product();

    let mut bench = if smoke { Bencher::smoke() } else { Bencher::slow() };

    // Reference-vs-fast kernel pairs (native model level) — also the
    // bitwise verification gate the CI smoke run relies on.
    kernel_pairs(&mut bench);

    // Bitwise-vs-fast math-tier pairs, with the fast tier's own
    // finiteness/tolerance/determinism gate ahead of the timing.
    tier_pairs(&mut bench);

    let cf = fam.client_fwd.get(&cut).unwrap();
    let mut inputs = client_p.clone();
    inputs.push(x.clone());
    bench.run("client_fwd cut2 (b=32)", || {
        rt.call(cf, &inputs).unwrap()
    });

    let smashed_out = rt.call(cf, &inputs).unwrap();
    let one = smashed_out[0].to_vec::<f32>().unwrap();
    let mut all = Vec::with_capacity(c * one.len());
    for _ in 0..c {
        all.extend_from_slice(&one);
    }
    let mut st_shape = vec![c, b];
    st_shape.extend(smash.iter());
    let labels: Vec<i32> =
        (0..c * b).map(|i| (i % 10) as i32).collect();
    let st = fam.server_train_entry(cut, c).unwrap();
    let mut st_inputs = server_p.clone();
    st_inputs.push(literal_f32(&st_shape, &all).unwrap());
    st_inputs.push(literal_i32(&[c, b], &labels).unwrap());
    st_inputs.push(literal_f32(&[c], &vec![0.2; c]).unwrap());
    st_inputs
        .push(literal_f32(&[b], &vec![1.0; b / 2]
            .into_iter()
            .chain(vec![0.0; b - b / 2])
            .collect::<Vec<f32>>()).unwrap());
    st_inputs.push(literal_f32(&[], &[0.1]).unwrap());
    bench.run("server_train cut2 C=5 (EPSL phi=0.5)", || {
        rt.call(st, &st_inputs).unwrap()
    });

    let cs = fam.client_step.get(&cut).unwrap();
    let g: Vec<f32> = vec![0.01; b * smash_len];
    let mut g_shape = vec![b];
    g_shape.extend(smash.iter());
    let mut cs_inputs = client_p.clone();
    cs_inputs.push(x.clone());
    cs_inputs.push(literal_f32(&g_shape, &g).unwrap());
    cs_inputs.push(literal_f32(&[], &[0.1]).unwrap());
    bench.run("client_step cut2 (b=32)", || {
        rt.call(cs, &cs_inputs).unwrap()
    });

    let pa = fam.phi_agg.get(&cut).unwrap();
    let zspec = &pa.inputs[0];
    let (zc, zb, zq) = (zspec.shape[0], zspec.shape[1], zspec.shape[2]);
    let z: Vec<f32> = vec![0.5; zc * zb * zq];
    let pa_inputs = vec![
        literal_f32(&[zc, zb, zq], &z).unwrap(),
        literal_f32(&[zc], &vec![0.2; zc]).unwrap(),
        literal_f32(&[zb], &vec![1.0; zb]).unwrap(),
    ];
    bench.run("phi_aggregate kernel (C=5)", || {
        rt.call(pa, &pa_inputs).unwrap()
    });

    // Full EPSL round through the coordinator (end-to-end: tables F4/F9).
    let cfg = Config::new();
    bench.run("full_epsl_round C=5 (coordinator e2e)", || {
        let opts = TrainerOptions {
            n_clients: 5,
            rounds: 1,
            eval_every: 100,
            dataset_size: 400,
            test_size: 256,
            ..Default::default()
        };
        train(rt, manifest, &cfg, &opts).unwrap()
    });

    println!("\n{}", bench.report());
    speedup_table(&bench);
    println!("{}", rt.stats_summary());
    // Optional perf-trajectory record (see PERF.md §7).
    bench.write_bench_json_if_requested();
}
