//! Optimizer benches: the resource-management hot path (L3).
//!
//! Paper-relevant targets: the BCD solver must be negligible next to a
//! training round (it runs once per deployment); per-block costs are
//! broken out so §Perf can attribute regressions.

use epsl::channel::{ChannelRealization, Deployment};
use epsl::config::NetworkConfig;
use epsl::optim::{baselines, bcd, cutlayer, greedy, power, Problem};
use epsl::profile::resnet18;
use epsl::util::bench::Bencher;
use epsl::util::rng::Rng;

fn main() {
    let cfg = NetworkConfig::default();
    let profile = resnet18::profile();
    let mut rng = Rng::new(42);
    let dep = Deployment::generate(&cfg, &mut rng);
    let ch = ChannelRealization::average(&dep);
    let prob = Problem {
        cfg: &cfg,
        profile: &profile,
        dep: &dep,
        ch: &ch,
        batch: 64,
        phi: 0.5,
    };
    let psd = vec![-65.0; cfg.n_subchannels];
    let alloc = greedy::allocate(&prob, &psd, 4);

    let mut b = Bencher::new();
    b.run("greedy_allocation (Alg 2)", || {
        greedy::allocate(&prob, &psd, 4)
    });
    b.run("power_control (P2 waterfill+bisect)", || {
        power::solve(&prob, &alloc, 4).unwrap()
    });
    b.run("cutlayer_milp (P3 B&B, 17 candidates)", || {
        cutlayer::solve(&prob, &alloc, &psd).unwrap()
    });
    b.run("cutlayer_exhaustive (reference)", || {
        cutlayer::exhaustive(&prob, &alloc, &psd)
    });
    b.run("bcd_full (Alg 3)", || {
        bcd::solve(&prob, bcd::BcdOptions::default()).unwrap()
    });
    let mut srng = Rng::new(7);
    b.run("baseline_a (RSS+uniform)", || {
        baselines::solve(&prob, baselines::Scheme::BaselineA, &mut srng)
            .unwrap()
    });
    b.run("objective_eval (eq 23)", || {
        let d = epsl::optim::Decision {
            alloc: alloc.clone(),
            psd_dbm_hz: psd.clone(),
            cut: 4,
        };
        prob.objective(&d)
    });
    println!("\n{}", b.report());
}
