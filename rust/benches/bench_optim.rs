//! Optimizer benches: the resource-management hot path (L3).
//!
//! Paper-relevant targets: the BCD solver must be negligible next to a
//! training round (it runs once per deployment); per-block costs are
//! broken out so §Perf can attribute regressions. Every stage that gained
//! an `optim::eval` fast path is benchmarked in reference/fast pairs, and
//! the speedup table at the end is the PR's acceptance artifact (target:
//! ≥5× on the BCD solve).
//!
//! `cargo bench --bench bench_optim -- --test` runs a smoke pass;
//! `BENCH_JSON=BENCH_1.json cargo bench --bench bench_optim` records the
//! results for the perf trajectory (see PERF.md).

use epsl::channel::{ChannelRealization, Deployment};
use epsl::config::NetworkConfig;
use epsl::optim::eval::Evaluator;
use epsl::optim::{baselines, bcd, cutlayer, greedy, power, Decision,
                  Problem};
use epsl::profile::resnet18;
use epsl::util::bench::Bencher;
use epsl::util::rng::Rng;

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let cfg = NetworkConfig::default();
    let profile = resnet18::profile();
    let mut rng = Rng::new(42);
    let dep = Deployment::generate(&cfg, &mut rng);
    let ch = ChannelRealization::average(&dep);
    let prob = Problem {
        cfg: &cfg,
        profile: &profile,
        dep: &dep,
        ch: &ch,
        batch: 64,
        phi: 0.5,
    };
    let psd = vec![-65.0; cfg.n_subchannels];
    let alloc = greedy::allocate(&prob, &psd, 4);
    let mut ev = Evaluator::new(&prob);
    let d = Decision { alloc: alloc.clone(), psd_dbm_hz: psd.clone(), cut: 4.into() };

    let mut b = if smoke { Bencher::smoke() } else { Bencher::new() };
    b.run("evaluator_build (C=5, M=20)", || Evaluator::new(&prob));
    b.run("objective_eval reference (eq 23)", || prob.objective(&d));
    b.run("objective_eval evaluator", || ev.objective(&d));
    b.run("greedy_allocation reference (Alg 2)", || {
        greedy::allocate_reference(&prob, &psd, 4)
    });
    b.run("greedy_allocation evaluator (Alg 2)", || {
        greedy::allocate_with(&prob, &ev, &psd, 4)
    });
    b.run("power_control (P2 waterfill+bisect)", || {
        power::solve_with(&prob, &ev, &alloc, 4).unwrap()
    });
    b.run("cutlayer_milp reference (P3 B&B)", || {
        cutlayer::solve(&prob, &alloc, &psd).unwrap()
    });
    b.run("cutlayer_milp evaluator (P3 B&B)", || {
        cutlayer::solve_with(&prob, &ev, &alloc, &psd).unwrap()
    });
    b.run("cutlayer_exhaustive reference", || {
        cutlayer::exhaustive(&prob, &alloc, &psd)
    });
    b.run("cutlayer_exhaustive evaluator", || {
        cutlayer::exhaustive_with(&prob, &ev, &alloc, &psd)
    });
    b.run("bcd_reference (pre-PR pipeline, Alg 3)", || {
        bcd::solve_reference(&prob, bcd::BcdOptions::default()).unwrap()
    });
    b.run("bcd_full evaluator (Alg 3)", || {
        bcd::solve(&prob, bcd::BcdOptions::default()).unwrap()
    });
    let mut srng = Rng::new(7);
    b.run("baseline_a (RSS+uniform)", || {
        baselines::solve(&prob, baselines::Scheme::BaselineA, &mut srng)
            .unwrap()
    });
    println!("\n{}", b.report());

    // Speedup attribution — reference vs evaluator pairs. The BCD row is
    // the PR acceptance number (target ≥ 5×).
    let pairs = [
        ("objective_eval reference (eq 23)", "objective_eval evaluator"),
        (
            "greedy_allocation reference (Alg 2)",
            "greedy_allocation evaluator (Alg 2)",
        ),
        (
            "cutlayer_exhaustive reference",
            "cutlayer_exhaustive evaluator",
        ),
        (
            "cutlayer_milp reference (P3 B&B)",
            "cutlayer_milp evaluator (P3 B&B)",
        ),
        (
            "bcd_reference (pre-PR pipeline, Alg 3)",
            "bcd_full evaluator (Alg 3)",
        ),
    ];
    let ns_of = |name: &str| {
        b.results()
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.ns_per_iter())
    };
    println!("speedups (reference / evaluator):");
    for (slow, fast) in pairs {
        if let (Some(s), Some(f)) = (ns_of(slow), ns_of(fast)) {
            println!("  {:<44} {:>7.2}x", fast, s / f.max(1e-9));
        }
    }

    // Optional perf-trajectory record (see PERF.md) through the shared
    // writer in util::bench (single home for the record format).
    b.write_bench_json_if_requested();
}
