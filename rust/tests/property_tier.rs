//! Math-tier parity properties (PR 10): the opt-in fast tier must track
//! the bitwise tier within the documented tolerance (PERF.md §10) on
//! every model entry point, across all cuts (1..=4), both dataset
//! families, and odd shapes; it must be run-to-run deterministic at a
//! fixed thread count; and the bitwise tier must remain bit-identical
//! to the retained naive reference oracles — the tier plumbing itself
//! must not have perturbed the default path.

use epsl::profile::splitnet::SplitNetConfig;
use epsl::runtime::native::kernels::ScratchPool;
use epsl::runtime::native::model;
use epsl::runtime::native::MathTier;
use epsl::util::rng::Rng;

/// Per-kernel relative tolerance (one GEMM seam deep).
const TOL: f32 = 1e-3;
/// Loss is a mean over one softmax/CE reduction past the GEMMs.
const LOSS_TOL: f32 = 5e-3;
/// Updated parameters sit at the end of the full forward+backward
/// sweep plus an SGD step, so rounding differences compound.
const PARAM_TOL: f32 = 1e-2;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()
}

fn assert_close(name: &str, reference: &[f32], fast: &[f32], tol: f32) {
    assert_eq!(reference.len(), fast.len(), "{name}: length mismatch");
    for (i, (r, f)) in reference.iter().zip(fast).enumerate() {
        assert!(f.is_finite(), "{name}[{i}]: fast tier non-finite ({f})");
        let scale = r.abs().max(f.abs()).max(1.0);
        assert!(
            (r - f).abs() <= tol * scale,
            "{name}[{i}]: fast {f} vs bitwise {r} outside tol {tol}"
        );
    }
}

/// Fast within tolerance of bitwise on every entry point, all cuts,
/// both families — the tolerance half of the tier contract.
#[test]
fn fast_tier_within_tolerance_all_cuts_both_families() {
    let pool = ScratchPool::new();
    let (b, c) = (4usize, 2usize);
    for family in ["mnist", "ham"] {
        let cfg = SplitNetConfig::for_family(family);
        let in_len = cfg.img * cfg.img * cfg.channels;
        for cut in 1..=4usize {
            let seed = (cut * 53) as u64
                + if family == "mnist" { 0 } else { 11 };
            let params = model::init_params(&cfg, seed);
            let n_c = model::client_param_count(cut);
            let mut rng = Rng::new(seed ^ 0x7157);
            let x = rand_vec(&mut rng, b * in_len);
            let tag = format!("{family} cut{cut}");

            let bw_smash = model::client_fwd(&cfg, cut, &params[..n_c],
                                             &x, b, MathTier::Bitwise,
                                             &pool);
            let ft_smash = model::client_fwd(&cfg, cut, &params[..n_c],
                                             &x, b, MathTier::Fast,
                                             &pool);
            assert_close(&format!("client_fwd {tag}"), &bw_smash,
                         &ft_smash, TOL);

            let (sh, sw, sc) = cfg.smashed_shape(cut);
            let smash_len = sh * sw * sc;
            let smashed = rand_vec(&mut rng, c * b * smash_len);
            let labels: Vec<i32> = (0..c * b)
                .map(|k| ((k * 5 + cut) % cfg.num_classes) as i32)
                .collect();
            let lam = vec![0.4f32, 0.6];
            let mask: Vec<f32> = (0..b)
                .map(|j| if j % 2 == 0 { 1.0 } else { 0.0 })
                .collect();
            let bw = model::server_train(&cfg, cut, c, b, 3,
                                         MathTier::Bitwise,
                                         &params[n_c..], &smashed,
                                         &labels, &lam, &mask, 0.05,
                                         &pool)
                .unwrap();
            let ft = model::server_train(&cfg, cut, c, b, 3,
                                         MathTier::Fast, &params[n_c..],
                                         &smashed, &labels, &lam, &mask,
                                         0.05, &pool)
                .unwrap();
            assert_close(&format!("loss {tag}"), &[bw.loss], &[ft.loss],
                         LOSS_TOL);
            assert!((bw.ncorrect - ft.ncorrect).abs() <= 1.0,
                    "training-batch ncorrect diverged on {tag}: \
                     bitwise {} vs fast {}", bw.ncorrect, ft.ncorrect);
            assert_close(&format!("cut_agg {tag}"), &bw.cut_agg,
                         &ft.cut_agg, TOL);
            assert_close(&format!("cut_unagg {tag}"), &bw.cut_unagg,
                         &ft.cut_unagg, TOL);
            for (t, (bp, fp)) in
                bw.new_params.iter().zip(&ft.new_params).enumerate()
            {
                assert_close(&format!("new_params[{t}] {tag}"), bp, fp,
                             PARAM_TOL);
            }

            let bw_step = model::client_step(
                &cfg, cut, &params[..n_c], &x,
                &bw.cut_agg[..b * smash_len], 0.05, b,
                MathTier::Bitwise, &pool);
            let ft_step = model::client_step(
                &cfg, cut, &params[..n_c], &x,
                &bw.cut_agg[..b * smash_len], 0.05, b, MathTier::Fast,
                &pool);
            for (t, (bp, fp)) in bw_step.iter().zip(&ft_step).enumerate()
            {
                assert_close(&format!("client_step[{t}] {tag}"), bp, fp,
                             PARAM_TOL);
            }
        }

        // eval: full model, odd-sized batch.
        let params = model::init_params(&cfg, 23);
        let n = 9usize;
        let mut rng = Rng::new(151);
        let ex = rand_vec(&mut rng, n * in_len);
        let ey: Vec<i32> =
            (0..n).map(|j| (j % cfg.num_classes) as i32).collect();
        let (bl, bc) = model::eval(&cfg, &params, &ex, &ey, 3,
                                   MathTier::Bitwise, &pool)
            .unwrap();
        let (fl, fc) = model::eval(&cfg, &params, &ex, &ey, 3,
                                   MathTier::Fast, &pool)
            .unwrap();
        assert_close(&format!("eval loss {family}"), &[bl], &[fl],
                     LOSS_TOL);
        // A near-tie argmax may flip under reassociated sums; bound the
        // drift rather than demanding equality on the 9-example batch.
        assert!((fc - bc).abs() <= 1.0,
                "eval ncorrect {family}: bitwise {bc} vs fast {fc}");
    }
}

/// The determinism half of the tier contract: at a *fixed* thread
/// count the fast tier is run-to-run bit-identical (reduction orders
/// are fixed given the panel partition; nothing reads the clock or an
/// unseeded RNG).
#[test]
fn fast_tier_deterministic_at_fixed_thread_count() {
    let cfg = SplitNetConfig::mnist_like();
    let pool = ScratchPool::new();
    let (cut, c, b) = (2usize, 3usize, 8usize);
    let n_c = model::client_param_count(cut);
    let params = model::init_params(&cfg, 41);
    let in_len = cfg.img * cfg.img * cfg.channels;
    let (sh, sw, sc) = cfg.smashed_shape(cut);
    let smash_len = sh * sw * sc;
    let mut rng = Rng::new(43);
    let x = rand_vec(&mut rng, b * in_len);
    let smashed = rand_vec(&mut rng, c * b * smash_len);
    let labels: Vec<i32> =
        (0..c * b).map(|k| (k % cfg.num_classes) as i32).collect();
    let lam = vec![1.0 / c as f32; c];
    let mask = vec![1.0f32; b];

    let s1 = model::client_fwd(&cfg, cut, &params[..n_c], &x, b,
                               MathTier::Fast, &pool);
    let s2 = model::client_fwd(&cfg, cut, &params[..n_c], &x, b,
                               MathTier::Fast, &pool);
    assert_eq!(bits(&s1), bits(&s2), "client_fwd fast nondeterministic");

    for threads in [1usize, 4] {
        let a = model::server_train(&cfg, cut, c, b, threads,
                                    MathTier::Fast, &params[n_c..],
                                    &smashed, &labels, &lam, &mask, 0.05,
                                    &pool)
            .unwrap();
        let z = model::server_train(&cfg, cut, c, b, threads,
                                    MathTier::Fast, &params[n_c..],
                                    &smashed, &labels, &lam, &mask, 0.05,
                                    &pool)
            .unwrap();
        assert_eq!(a.loss.to_bits(), z.loss.to_bits(),
                   "fast loss nondeterministic at {threads} threads");
        assert_eq!(bits(&a.cut_agg), bits(&z.cut_agg),
                   "fast cut_agg nondeterministic at {threads} threads");
        assert_eq!(bits(&a.cut_unagg), bits(&z.cut_unagg),
                   "fast cut_unagg nondeterministic at {threads} threads");
        for (t, (ap, zp)) in
            a.new_params.iter().zip(&z.new_params).enumerate()
        {
            assert_eq!(bits(ap), bits(zp),
                       "fast new_params[{t}] nondeterministic at \
                        {threads} threads");
        }
    }
}

/// Threading the tier argument through must not have changed the
/// default path: bitwise stays bit-identical to the naive reference
/// oracle (the exhaustive version lives in `property_kernels.rs`; this
/// is the focused regression pin for the PR 10 plumbing).
#[test]
fn bitwise_tier_still_bit_identical_to_reference() {
    let cfg = SplitNetConfig::mnist_like();
    let pool = ScratchPool::new();
    let (cut, c, b) = (3usize, 2usize, 4usize);
    let n_c = model::client_param_count(cut);
    let params = model::init_params(&cfg, 77);
    let (sh, sw, sc) = cfg.smashed_shape(cut);
    let smash_len = sh * sw * sc;
    let mut rng = Rng::new(79);
    let smashed = rand_vec(&mut rng, c * b * smash_len);
    let labels: Vec<i32> =
        (0..c * b).map(|k| (k % cfg.num_classes) as i32).collect();
    let lam = vec![0.5f32; c];
    let mask: Vec<f32> = (0..b)
        .map(|j| if j < b / 2 { 1.0 } else { 0.0 })
        .collect();
    let f = model::server_train(&cfg, cut, c, b, 2, MathTier::Bitwise,
                                &params[n_c..], &smashed, &labels, &lam,
                                &mask, 0.1, &pool)
        .unwrap();
    let r = model::server_train_reference(&cfg, cut, c, b, 1,
                                          &params[n_c..], &smashed,
                                          &labels, &lam, &mask, 0.1);
    assert_eq!(f.loss.to_bits(), r.loss.to_bits());
    assert_eq!(bits(&f.cut_agg), bits(&r.cut_agg));
    assert_eq!(bits(&f.cut_unagg), bits(&r.cut_unagg));
    for (fp, rp) in f.new_params.iter().zip(&r.new_params) {
        assert_eq!(bits(fp), bits(rp));
    }
}
