//! Cross-module integration for the scenario subsystem: expansion →
//! policy runner → figure pipeline, with the determinism contracts the
//! experiment harness depends on (same seed ⇒ same realizations; parallel
//! == serial bit-for-bit for any thread count).

use epsl::config::NetworkConfig;
use epsl::experiments::latency_figs::fig13_point;
use epsl::optim::bcd::BcdOptions;
use epsl::profile::resnet18;
use epsl::scenario::{
    run_policy, run_scenario_cells, ChurnSpec, ReoptPolicy, RunOptions,
    Scenario, ScenarioCell, ScenarioSpec,
};
use epsl::timeline::Mode;

fn small_net() -> NetworkConfig {
    NetworkConfig::default().with_clients(3)
}

fn opts(policy: ReoptPolicy, threads: usize) -> RunOptions {
    RunOptions {
        policy,
        bcd: BcdOptions { max_iters: 4, tol: 1e-4 },
        batch: 64,
        phi: 0.5,
        threads,
        timeline_mode: Mode::Barrier,
    }
}

#[test]
fn seed_determinism_end_to_end() {
    // Same seed through the whole pipeline (expansion + policy run) gives
    // bit-identical per-round latencies; a different seed does not.
    let net = small_net();
    let spec = ScenarioSpec::block_fading(10, 2);
    let profile = resnet18::profile_static();
    let run = |seed: u64| {
        let sc = Scenario::generate(&net, &spec, seed).unwrap();
        run_policy(&sc, profile, &opts(ReoptPolicy::EveryK(2), 1))
    };
    let a = run(0x5EED);
    let b = run(0x5EED);
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.latency.map(f64::to_bits), y.latency.map(f64::to_bits));
    }
    let c = run(0xD1FF);
    assert!(
        a.rounds
            .iter()
            .zip(&c.rounds)
            .any(|(x, y)| x.latency.map(f64::to_bits)
                != y.latency.map(f64::to_bits)),
        "different seeds produced identical runs"
    );
}

#[test]
fn parallel_equals_serial_across_the_stack() {
    // Policy runner blocks AND the cell-grid sweep must both be
    // bit-identical to their serial paths.
    let net = small_net();
    let profile = resnet18::profile_static();
    let sc =
        Scenario::generate(&net, &ScenarioSpec::fading(8), 0xF00D).unwrap();
    let serial = run_policy(&sc, profile, &opts(ReoptPolicy::EveryK(1), 1));
    let par8 = run_policy(&sc, profile, &opts(ReoptPolicy::EveryK(1), 8));
    for (a, b) in serial.rounds.iter().zip(&par8.rounds) {
        assert_eq!(a.latency.map(f64::to_bits), b.latency.map(f64::to_bits));
    }

    let cells: Vec<ScenarioCell> = (0..6)
        .map(|i| ScenarioCell {
            net: net.clone(),
            spec: ScenarioSpec::block_fading(6, 1 + (i % 3)),
            policy: if i % 2 == 0 {
                ReoptPolicy::Never
            } else {
                ReoptPolicy::EveryK(3)
            },
            bcd: BcdOptions { max_iters: 4, tol: 1e-4 },
            seed: 0xCE11 + i as u64,
            batch: 64,
            phi: 0.5,
            timeline_mode: Mode::Barrier,
        })
        .collect();
    let s = run_scenario_cells(profile, &cells, 1);
    let p = run_scenario_cells(profile, &cells, 4);
    for (a, b) in s.iter().zip(&p) {
        match (a, b) {
            (Some(x), Some(y)) => {
                assert_eq!(x.mean_latency.to_bits(), y.mean_latency.to_bits());
                assert_eq!(x.n_solves, y.n_solves);
            }
            (None, None) => {}
            _ => panic!("cell success/failure diverged across threads"),
        }
    }
}

#[test]
fn churn_forces_resolves_and_keeps_runs_valid() {
    let net = small_net();
    let profile = resnet18::profile_static();
    let spec = ScenarioSpec {
        rounds: 30,
        redraw_period: Some(1),
        los_flip: None,
        compute_jitter: None,
        churn: Some(ChurnSpec {
            drop_prob: 0.25,
            rejoin_prob: 0.4,
            min_active: 2,
        }),
    };
    let sc = Scenario::generate(&net, &spec, 0xC0FE).unwrap();
    let changes =
        sc.rounds.iter().filter(|r| r.membership_changed).count();
    assert!(changes > 0, "no membership change at 25% churn over 30 rounds");
    // Even under Never, every membership change re-solves.
    let out = run_policy(&sc, profile, &opts(ReoptPolicy::Never, 4));
    assert_eq!(out.n_solves, 1 + changes);
    assert_eq!(out.n_failed, 0);
    for r in &out.rounds {
        let t = r.latency.expect("round evaluated");
        assert!(t.is_finite() && t > 0.0);
    }
}

#[test]
fn fig13_pipeline_is_reproducible() {
    // Two invocations of the figure helper are bit-identical regardless
    // of thread count (the helper reseeds internally).
    let net = small_net();
    let a = fig13_point(&net, 64, 0.5, 3, 2).unwrap();
    let b = fig13_point(&net, 64, 0.5, 3, 4).unwrap();
    assert_eq!(a.0.to_bits(), b.0.to_bits());
    assert_eq!(
        a.1.iter().map(|v| v.map(f64::to_bits)).collect::<Vec<_>>(),
        b.1.iter().map(|v| v.map(f64::to_bits)).collect::<Vec<_>>()
    );
    assert_eq!(
        a.2.iter().map(|v| v.map(f64::to_bits)).collect::<Vec<_>>(),
        b.2.iter().map(|v| v.map(f64::to_bits)).collect::<Vec<_>>()
    );
    assert!(a.0 > 0.0);
    assert_eq!(a.1.len(), 3);
    assert_eq!(a.2.len(), 3);
}
