//! End-to-end training integration over the runtime backend seam.
//!
//! These tests run for real on every checkout: the native backend needs
//! no artifacts. When `make artifacts` has produced the PJRT build, the
//! same contract is additionally exercised through PJRT by the
//! artifact-gated tests at the bottom.

use epsl::config::Config;
use epsl::coordinator::{train, TrainerOptions};
use epsl::latency::frameworks::Framework;
use epsl::metrics::RunMetrics;
use epsl::runtime::artifact::Manifest;
use epsl::runtime::native::{self, NativeBackend};
use epsl::runtime::{Backend, Runtime};

fn setup() -> (NativeBackend, Manifest, Config) {
    (NativeBackend::new(), native::manifest(), Config::new())
}

fn short_opts(fw: Framework, rounds: usize) -> TrainerOptions {
    TrainerOptions {
        framework: fw,
        n_clients: 2,
        rounds,
        eval_every: rounds,
        dataset_size: 600,
        test_size: 256,
        eta_c: 0.1,
        eta_s: 0.1,
        seed: 99,
        ..Default::default()
    }
}

fn run(rt: &dyn Backend, m: &Manifest, cfg: &Config, opts: &TrainerOptions)
    -> RunMetrics {
    train(rt, m, cfg, opts).expect("training failed")
}

#[test]
fn epsl_loss_decreases_over_training() {
    let (rt, m, cfg) = setup();
    let r = run(&rt, &m, &cfg, &short_opts(Framework::Epsl { phi: 0.5 }, 40));
    let early = epsl::util::stats::mean(
        &r.rounds[..8].iter().map(|x| x.loss).collect::<Vec<_>>(),
    );
    let late = epsl::util::stats::mean(
        &r.rounds[32..].iter().map(|x| x.loss).collect::<Vec<_>>(),
    );
    assert!(late < early, "loss did not decrease: {early} -> {late}");
}

#[test]
fn epsl_phi0_bitwise_matches_psl_run() {
    // PSL is EPSL(φ=0) — with the same seed, the two drivers must produce
    // the exact same loss trajectory end-to-end through the backend. This
    // is the strongest cross-layer determinism + semantics check in the
    // system.
    let (rt, m, cfg) = setup();
    let a = run(&rt, &m, &cfg, &short_opts(Framework::Psl, 10));
    let b = run(&rt, &m, &cfg, &short_opts(Framework::Epsl { phi: 0.0 }, 10));
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.loss, rb.loss, "round {} diverged", ra.round);
        assert_eq!(ra.train_acc, rb.train_acc);
    }
}

#[test]
fn same_seed_same_run() {
    let (rt, m, cfg) = setup();
    let opts = short_opts(Framework::Epsl { phi: 0.5 }, 6);
    let a = run(&rt, &m, &cfg, &opts);
    let b = run(&rt, &m, &cfg, &opts);
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.loss, rb.loss);
    }
}

#[test]
fn thread_count_does_not_change_the_run() {
    // Acceptance criterion: results are EPSL_THREADS-independent — the
    // native backend's fan-out is order-preserving and all reductions are
    // serial, so a 1-thread and an 8-thread backend agree bit for bit.
    let (_, m, cfg) = setup();
    let opts = short_opts(Framework::Epsl { phi: 0.5 }, 5);
    let a = run(&NativeBackend::with_threads(1), &m, &cfg, &opts);
    let b = run(&NativeBackend::with_threads(8), &m, &cfg, &opts);
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
        assert_eq!(ra.train_acc.to_bits(), rb.train_acc.to_bits());
    }
}

#[test]
fn different_phi_different_dynamics() {
    let (rt, m, cfg) = setup();
    let a = run(&rt, &m, &cfg, &short_opts(Framework::Epsl { phi: 0.0 }, 6));
    let b = run(&rt, &m, &cfg, &short_opts(Framework::Epsl { phi: 1.0 }, 6));
    // φ changes the BP path, so trajectories must differ after round 0
    // (losses at round 0 agree: the FP path is identical).
    assert!((a.rounds[0].loss - b.rounds[0].loss).abs() < 1e-5);
    assert!(
        a.rounds[5].loss != b.rounds[5].loss,
        "phi had no effect on training"
    );
}

#[test]
fn non_iid_trains_and_is_harder() {
    let (rt, m, cfg) = setup();
    let mut iid_opts = short_opts(Framework::Epsl { phi: 0.5 }, 30);
    iid_opts.eval_every = 10;
    let mut niid_opts = iid_opts.clone();
    niid_opts.iid = false;
    let iid = run(&rt, &m, &cfg, &iid_opts);
    let niid = run(&rt, &m, &cfg, &niid_opts);
    assert!(iid.rounds.iter().all(|r| r.loss.is_finite()));
    assert!(niid.rounds.iter().all(|r| r.loss.is_finite()));
    // Paper Fig. 7b/8b: non-IID converges more slowly. With only 30 rounds
    // just require it not be dramatically better.
    let acc_iid = iid.converged_accuracy(2);
    let acc_niid = niid.converged_accuracy(2);
    assert!(
        acc_niid <= acc_iid + 0.15,
        "non-IID unexpectedly easier: {acc_niid} vs {acc_iid}"
    );
}

#[test]
fn epsl_pt_switches_phase() {
    let (rt, m, cfg) = setup();
    let mut opts = short_opts(Framework::EpslPt { early: true }, 8);
    opts.pt_switch = 4;
    let r = run(&rt, &m, &cfg, &opts);
    // φ=1 rounds broadcast everything: unicast time 0 → lower sim latency
    // in the early phase than the φ=0 phase.
    assert!(
        r.rounds[0].sim_latency < r.rounds[7].sim_latency,
        "PT early phase should be faster per round: {} vs {}",
        r.rounds[0].sim_latency,
        r.rounds[7].sim_latency
    );
}

#[test]
fn wall_clock_recorded() {
    let (rt, m, cfg) = setup();
    let r = run(&rt, &m, &cfg, &short_opts(Framework::Psl, 3));
    assert!(r.rounds.iter().all(|x| x.wall_ms > 0.0));
}

#[test]
fn pjrt_path_still_works_when_artifacts_exist() {
    // The PJRT half of the backend seam: artifact-gated (PJRT bindings
    // plus `make artifacts`), since offline checkouts cannot compile HLO.
    let Ok(m) = Manifest::load("artifacts") else {
        eprintln!("skipping PJRT half: artifacts not built");
        return;
    };
    let Ok(rt) = Runtime::new("artifacts") else {
        eprintln!("skipping PJRT half: PJRT unavailable");
        return;
    };
    let cfg = Config::new();
    let r = run(&rt, &m, &cfg, &short_opts(Framework::Epsl { phi: 0.5 }, 3));
    assert!(r.rounds.iter().all(|x| x.loss.is_finite()));
}
