//! End-to-end training integration over the real PJRT runtime.
//!
//! These tests need `make artifacts`; they skip (with a note) otherwise so
//! `cargo test` stays runnable on a fresh checkout.

use epsl::config::Config;
use epsl::coordinator::{train, TrainerOptions};
use epsl::latency::frameworks::Framework;
use epsl::metrics::RunMetrics;
use epsl::runtime::artifact::Manifest;
use epsl::runtime::Runtime;

fn setup() -> Option<(Runtime, Manifest, Config)> {
    let m = Manifest::load("artifacts").ok()?;
    let rt = Runtime::new("artifacts").ok()?;
    Some((rt, m, Config::new()))
}

fn short_opts(fw: Framework, rounds: usize) -> TrainerOptions {
    TrainerOptions {
        framework: fw,
        n_clients: 2,
        rounds,
        eval_every: rounds,
        dataset_size: 600,
        test_size: 256,
        eta_c: 0.1,
        eta_s: 0.1,
        seed: 99,
        ..Default::default()
    }
}

fn run(rt: &Runtime, m: &Manifest, cfg: &Config, opts: &TrainerOptions)
    -> RunMetrics {
    train(rt, m, cfg, opts).expect("training failed")
}

#[test]
fn epsl_loss_decreases_over_training() {
    let Some((rt, m, cfg)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let r = run(&rt, &m, &cfg, &short_opts(Framework::Epsl { phi: 0.5 }, 40));
    let early = epsl::util::stats::mean(
        &r.rounds[..8].iter().map(|x| x.loss).collect::<Vec<_>>(),
    );
    let late = epsl::util::stats::mean(
        &r.rounds[32..].iter().map(|x| x.loss).collect::<Vec<_>>(),
    );
    assert!(late < early, "loss did not decrease: {early} -> {late}");
}

#[test]
fn epsl_phi0_bitwise_matches_psl_run() {
    // PSL is EPSL(φ=0) — with the same seed, the two drivers must produce
    // the exact same loss trajectory end-to-end through PJRT. This is the
    // strongest cross-layer determinism + semantics check in the system.
    let Some((rt, m, cfg)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let a = run(&rt, &m, &cfg, &short_opts(Framework::Psl, 10));
    let b = run(&rt, &m, &cfg, &short_opts(Framework::Epsl { phi: 0.0 }, 10));
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.loss, rb.loss, "round {} diverged", ra.round);
        assert_eq!(ra.train_acc, rb.train_acc);
    }
}

#[test]
fn same_seed_same_run() {
    let Some((rt, m, cfg)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let opts = short_opts(Framework::Epsl { phi: 0.5 }, 6);
    let a = run(&rt, &m, &cfg, &opts);
    let b = run(&rt, &m, &cfg, &opts);
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.loss, rb.loss);
    }
}

#[test]
fn different_phi_different_dynamics() {
    let Some((rt, m, cfg)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let a = run(&rt, &m, &cfg, &short_opts(Framework::Epsl { phi: 0.0 }, 6));
    let b = run(&rt, &m, &cfg, &short_opts(Framework::Epsl { phi: 1.0 }, 6));
    // φ changes the BP path, so trajectories must differ after round 0
    // (losses at round 0 agree: the FP path is identical).
    assert!((a.rounds[0].loss - b.rounds[0].loss).abs() < 1e-5);
    assert!(
        a.rounds[5].loss != b.rounds[5].loss,
        "phi had no effect on training"
    );
}

#[test]
fn non_iid_trains_and_is_harder() {
    let Some((rt, m, cfg)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut iid_opts = short_opts(Framework::Epsl { phi: 0.5 }, 30);
    iid_opts.eval_every = 10;
    let mut niid_opts = iid_opts.clone();
    niid_opts.iid = false;
    let iid = run(&rt, &m, &cfg, &iid_opts);
    let niid = run(&rt, &m, &cfg, &niid_opts);
    assert!(iid.rounds.iter().all(|r| r.loss.is_finite()));
    assert!(niid.rounds.iter().all(|r| r.loss.is_finite()));
    // Paper Fig. 7b/8b: non-IID converges more slowly. With only 30 rounds
    // just require it not be dramatically better.
    let acc_iid = iid.converged_accuracy(2);
    let acc_niid = niid.converged_accuracy(2);
    assert!(
        acc_niid <= acc_iid + 0.15,
        "non-IID unexpectedly easier: {acc_niid} vs {acc_iid}"
    );
}

#[test]
fn epsl_pt_switches_phase() {
    let Some((rt, m, cfg)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut opts = short_opts(Framework::EpslPt { early: true }, 8);
    opts.pt_switch = 4;
    let r = run(&rt, &m, &cfg, &opts);
    // φ=1 rounds broadcast everything: unicast time 0 → lower sim latency
    // in the early phase than the φ=0 phase.
    assert!(
        r.rounds[0].sim_latency < r.rounds[7].sim_latency,
        "PT early phase should be faster per round: {} vs {}",
        r.rounds[0].sim_latency,
        r.rounds[7].sim_latency
    );
}

#[test]
fn wall_clock_recorded() {
    let Some((rt, m, cfg)) = setup() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let r = run(&rt, &m, &cfg, &short_opts(Framework::Psl, 3));
    assert!(r.rounds.iter().all(|x| x.wall_ms > 0.0));
}
