//! Cross-module integration: channel → rates → latency model, and the
//! paper-shape invariants of the framework latency ordering.

use epsl::channel::rate::{broadcast_rate, downlink_rates, uplink_rates,
                          Allocation};
use epsl::channel::{ChannelRealization, Deployment};
use epsl::config::NetworkConfig;
use epsl::latency::frameworks::{round_latency, Framework};
use epsl::latency::LatencyInputs;
use epsl::profile::resnet18;
use epsl::util::prop::check;
use epsl::util::rng::Rng;

fn round_robin(cfg: &NetworkConfig) -> Allocation {
    let mut alloc = Allocation::empty(cfg.n_subchannels);
    for k in 0..cfg.n_subchannels {
        alloc.assign(k, k % cfg.n_clients);
    }
    alloc
}

/// Build latency inputs straight from a simulated deployment.
fn latency_of(cfg: &NetworkConfig, fw: Framework, cut: usize, seed: u64)
    -> f64 {
    let profile = resnet18::profile();
    let mut rng = Rng::new(seed);
    let dep = Deployment::generate(cfg, &mut rng);
    let ch = ChannelRealization::average(&dep);
    let alloc = round_robin(cfg);
    let psd = vec![-62.0; cfg.n_subchannels];
    let up = uplink_rates(cfg, &ch, &alloc, &psd);
    let dn = downlink_rates(cfg, &ch, &alloc);
    let bc = broadcast_rate(cfg, &ch);
    let inp = LatencyInputs {
        profile: &profile,
        cut,
        batch: 64,
        phi: 0.5,
        f_server: cfg.f_server,
        kappa_server: cfg.kappa_server,
        kappa_client: cfg.kappa_client,
        f_clients: dep.f_clients(),
        uplink: &up,
        downlink: &dn,
        broadcast: bc,
    };
    round_latency(fw, &inp).round_total()
}

#[test]
fn paper_ordering_holds_across_deployments() {
    // Fig. 4b / Fig. 9 ordering: EPSL < PSL <= SFL < vanilla, across many
    // random deployments and cut layers.
    check("framework ordering", 25, |g| {
        let mut cfg = NetworkConfig::default();
        cfg.n_clients = g.usize_in(2, 8);
        cfg.n_subchannels = cfg.n_clients * g.usize_in(1, 4);
        let cut = g.usize_in(1, 17);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let epsl = latency_of(&cfg, Framework::Epsl { phi: 0.5 }, cut, seed);
        let psl = latency_of(&cfg, Framework::Psl, cut, seed);
        let sfl = latency_of(&cfg, Framework::Sfl, cut, seed);
        let vsl = latency_of(&cfg, Framework::VanillaSl, cut, seed);
        assert!(epsl < psl, "EPSL {epsl} !< PSL {psl} (cut {cut})");
        assert!(psl < sfl, "PSL {psl} !< SFL {sfl} (cut {cut})");
        assert!(psl < vsl, "PSL {psl} !< vanilla {vsl} (cut {cut})");
        // SFL < vanilla holds at practically-chosen cuts; at very deep cuts
        // the client model is nearly the whole network and SFL's model
        // exchange can exceed vanilla's relay (both are then far from the
        // optimum anyway — the optimizer never picks those cuts).
        if cut <= 12 {
            assert!(sfl < vsl, "SFL {sfl} !< vanilla {vsl} (cut {cut})");
        }
    });
}

#[test]
fn epsl_gap_grows_with_clients() {
    // The paper: EPSL's advantage over PSL widens as C grows (server BP
    // and unicast savings scale with C).
    let mut gaps = Vec::new();
    for c in [2usize, 5, 10, 15] {
        let mut cfg = NetworkConfig::default();
        cfg.n_clients = c;
        cfg.n_subchannels = c * 4;
        let epsl = latency_of(&cfg, Framework::Epsl { phi: 1.0 }, 4, 7);
        let psl = latency_of(&cfg, Framework::Psl, 4, 7);
        gaps.push(psl - epsl);
    }
    for w in gaps.windows(2) {
        assert!(w[1] > w[0] * 0.99, "gap shrank: {gaps:?}");
    }
}

#[test]
fn more_bandwidth_never_hurts() {
    check("bandwidth monotone", 15, |g| {
        let seed = g.usize_in(0, 1 << 30) as u64;
        let cut = g.usize_in(1, 17);
        let mut last = f64::INFINITY;
        for mhz in [100.0, 200.0, 300.0] {
            let cfg = NetworkConfig::default()
                .with_total_bandwidth(mhz * 1e6);
            let t = latency_of(&cfg, Framework::Epsl { phi: 0.5 }, cut, seed);
            assert!(
                t <= last * (1.0 + 1e-9),
                "latency rose with bandwidth at {mhz} MHz"
            );
            last = t;
        }
    });
}

#[test]
fn faster_server_never_hurts() {
    check("server compute monotone", 15, |g| {
        let seed = g.usize_in(0, 1 << 30) as u64;
        let cut = g.usize_in(1, 17);
        let mut last = f64::INFINITY;
        for ghz in [1.0, 3.0, 5.0, 9.0] {
            let mut cfg = NetworkConfig::default();
            cfg.f_server = ghz * 1e9;
            let t = latency_of(&cfg, Framework::Epsl { phi: 0.5 }, cut, seed);
            assert!(t <= last * (1.0 + 1e-9));
            last = t;
        }
    });
}

#[test]
fn deeper_cut_shifts_work_to_clients() {
    // Monotone structure check across all cut candidates.
    let profile = resnet18::profile();
    let mut prev_client = 0.0;
    let mut prev_server = f64::INFINITY;
    for &j in &profile.cut_candidates {
        let c = profile.client_fp_flops(j);
        let s = profile.server_fp_flops(j);
        assert!(c >= prev_client);
        assert!(s <= prev_server);
        prev_client = c;
        prev_server = s;
    }
}
