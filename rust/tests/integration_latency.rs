//! Cross-module integration: channel → rates → latency model, and the
//! paper-shape invariants of the framework latency ordering.

use epsl::channel::rate::{broadcast_rate, downlink_rates, uplink_rates,
                          Allocation};
use epsl::channel::{ChannelRealization, Deployment};
use epsl::config::NetworkConfig;
use epsl::latency::frameworks::{round_latency, Framework};
use epsl::latency::{epsl_stage_latencies, LatencyInputs};
use epsl::profile::resnet18;
use epsl::util::prop::{check, Gen};
use epsl::util::rng::Rng;

fn round_robin(cfg: &NetworkConfig) -> Allocation {
    let mut alloc = Allocation::empty(cfg.n_subchannels);
    for k in 0..cfg.n_subchannels {
        alloc.assign(k, k % cfg.n_clients);
    }
    alloc
}

/// Build latency inputs straight from a simulated deployment.
fn latency_of(cfg: &NetworkConfig, fw: Framework, cut: usize, seed: u64)
    -> f64 {
    let profile = resnet18::profile();
    let mut rng = Rng::new(seed);
    let dep = Deployment::generate(cfg, &mut rng);
    let ch = ChannelRealization::average(&dep);
    let alloc = round_robin(cfg);
    let psd = vec![-62.0; cfg.n_subchannels];
    let up = uplink_rates(cfg, &ch, &alloc, &psd);
    let dn = downlink_rates(cfg, &ch, &alloc);
    let bc = broadcast_rate(cfg, &ch);
    let inp = LatencyInputs {
        profile: &profile,
        cut,
        batch: 64,
        phi: 0.5,
        f_server: cfg.f_server,
        kappa_server: cfg.kappa_server,
        kappa_client: cfg.kappa_client,
        f_clients: dep.f_clients(),
        uplink: &up,
        downlink: &dn,
        broadcast: bc,
        uplink_comp: 1.0,
    };
    round_latency(fw, &inp).round_total()
}

#[test]
fn paper_ordering_holds_across_deployments() {
    // Fig. 4b / Fig. 9 ordering: EPSL < PSL <= SFL < vanilla, across many
    // random deployments and cut layers.
    check("framework ordering", 25, |g| {
        let mut cfg = NetworkConfig::default();
        cfg.n_clients = g.usize_in(2, 8);
        cfg.n_subchannels = cfg.n_clients * g.usize_in(1, 4);
        let cut = g.usize_in(1, 17);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let epsl = latency_of(&cfg, Framework::Epsl { phi: 0.5 }, cut, seed);
        let psl = latency_of(&cfg, Framework::Psl, cut, seed);
        let sfl = latency_of(&cfg, Framework::Sfl, cut, seed);
        let vsl = latency_of(&cfg, Framework::VanillaSl, cut, seed);
        assert!(epsl < psl, "EPSL {epsl} !< PSL {psl} (cut {cut})");
        assert!(psl < sfl, "PSL {psl} !< SFL {sfl} (cut {cut})");
        assert!(psl < vsl, "PSL {psl} !< vanilla {vsl} (cut {cut})");
        // SFL < vanilla holds at practically-chosen cuts; at very deep cuts
        // the client model is nearly the whole network and SFL's model
        // exchange can exceed vanilla's relay (both are then far from the
        // optimum anyway — the optimizer never picks those cuts).
        if cut <= 12 {
            assert!(sfl < vsl, "SFL {sfl} !< vanilla {vsl} (cut {cut})");
        }
    });
}

#[test]
fn epsl_gap_grows_with_clients() {
    // The paper: EPSL's advantage over PSL widens as C grows (server BP
    // and unicast savings scale with C).
    let mut gaps = Vec::new();
    for c in [2usize, 5, 10, 15] {
        let mut cfg = NetworkConfig::default();
        cfg.n_clients = c;
        cfg.n_subchannels = c * 4;
        let epsl = latency_of(&cfg, Framework::Epsl { phi: 1.0 }, 4, 7);
        let psl = latency_of(&cfg, Framework::Psl, 4, 7);
        gaps.push(psl - epsl);
    }
    for w in gaps.windows(2) {
        assert!(w[1] > w[0] * 0.99, "gap shrank: {gaps:?}");
    }
}

#[test]
fn more_bandwidth_never_hurts() {
    check("bandwidth monotone", 15, |g| {
        let seed = g.usize_in(0, 1 << 30) as u64;
        let cut = g.usize_in(1, 17);
        let mut last = f64::INFINITY;
        for mhz in [100.0, 200.0, 300.0] {
            let cfg = NetworkConfig::default()
                .with_total_bandwidth(mhz * 1e6);
            let t = latency_of(&cfg, Framework::Epsl { phi: 0.5 }, cut, seed);
            assert!(
                t <= last * (1.0 + 1e-9),
                "latency rose with bandwidth at {mhz} MHz"
            );
            last = t;
        }
    });
}

#[test]
fn faster_server_never_hurts() {
    check("server compute monotone", 15, |g| {
        let seed = g.usize_in(0, 1 << 30) as u64;
        let cut = g.usize_in(1, 17);
        let mut last = f64::INFINITY;
        for ghz in [1.0, 3.0, 5.0, 9.0] {
            let mut cfg = NetworkConfig::default();
            cfg.f_server = ghz * 1e9;
            let t = latency_of(&cfg, Framework::Epsl { phi: 0.5 }, cut, seed);
            assert!(t <= last * (1.0 + 1e-9));
            last = t;
        }
    });
}

/// Random heterogeneous per-client vectors for the stage-latency
/// property tests.
fn gen_rates(g: &mut Gen, c: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let f: Vec<f64> = (0..c).map(|_| g.f64_in(0.5e9, 3e9)).collect();
    let up: Vec<f64> = (0..c).map(|_| g.f64_log(1e7, 5e8)).collect();
    let dn: Vec<f64> = (0..c).map(|_| g.f64_log(1e7, 5e8)).collect();
    (f, up, dn)
}

#[test]
fn uplink_straggler_is_first_argmax_of_fp_plus_uplink() {
    let profile = resnet18::profile();
    check("uplink straggler argmax", 50, |g| {
        let c = g.usize_in(1, 12);
        let (f, up, dn) = gen_rates(g, c);
        let cut = *g.choose(&profile.cut_candidates);
        let inp = LatencyInputs {
            profile: &profile,
            cut,
            batch: 64,
            phi: g.f64_in(0.0, 1.0),
            f_server: 5e9,
            kappa_server: 1.0 / 32.0,
            kappa_client: 1.0 / 16.0,
            f_clients: &f,
            uplink: &up,
            downlink: &dn,
            broadcast: 2e8,
            uplink_comp: 1.0,
        };
        let s = epsl_stage_latencies(&inp);
        let idx = s.uplink_straggler();
        let sums: Vec<f64> = s
            .client_fp
            .iter()
            .zip(&s.uplink)
            .map(|(a, b)| a + b)
            .collect();
        // idx is the FIRST argmax of T_i^F + T_i^U.
        for (i, v) in sums.iter().enumerate() {
            if i < idx {
                assert!(*v < sums[idx], "earlier client {i} ties/beats");
            } else {
                assert!(*v <= sums[idx], "client {i} beats straggler");
            }
        }
        // And the straggler pins the uplink phase.
        assert_eq!(
            s.uplink_phase_max().to_bits(),
            sums[idx].to_bits()
        );
    });
}

#[test]
fn comm_compute_split_brackets_round_total() {
    // comm_seconds + compute_seconds uses per-stage maxima independently,
    // so it can only over-count relative to the paired maxima of eq. 23:
    // comm + compute ≥ round_total, with equality when one client is the
    // straggler of every stage (homogeneous clients, or C = 1).
    let profile = resnet18::profile();
    check("comm/compute split", 40, |g| {
        let c = g.usize_in(1, 10);
        let (f, up, dn) = gen_rates(g, c);
        let cut = *g.choose(&profile.cut_candidates);
        let inp = LatencyInputs {
            profile: &profile,
            cut,
            batch: 64,
            phi: g.f64_in(0.0, 1.0),
            f_server: 5e9,
            kappa_server: 1.0 / 32.0,
            kappa_client: 1.0 / 16.0,
            f_clients: &f,
            uplink: &up,
            downlink: &dn,
            broadcast: 2e8,
            uplink_comp: 1.0,
        };
        for fw in [
            Framework::VanillaSl,
            Framework::Sfl,
            Framework::Psl,
            Framework::Epsl { phi: 0.5 },
        ] {
            let s = round_latency(fw, &inp);
            let total = s.round_total();
            let split = s.comm_seconds() + s.compute_seconds();
            assert!(
                split >= total * (1.0 - 1e-12),
                "{}: comm+compute {split} < total {total}",
                fw.name()
            );
        }
    });
}

#[test]
fn comm_compute_split_exact_for_homogeneous_clients() {
    let profile = resnet18::profile();
    check("comm/compute homogeneous", 25, |g| {
        let c = g.usize_in(1, 8);
        let f = vec![g.f64_in(0.5e9, 3e9); c];
        let up = vec![g.f64_log(1e7, 5e8); c];
        let dn = vec![g.f64_log(1e7, 5e8); c];
        let cut = *g.choose(&profile.cut_candidates);
        let inp = LatencyInputs {
            profile: &profile,
            cut,
            batch: 64,
            phi: g.f64_in(0.0, 1.0),
            f_server: 5e9,
            kappa_server: 1.0 / 32.0,
            kappa_client: 1.0 / 16.0,
            f_clients: &f,
            uplink: &up,
            downlink: &dn,
            broadcast: 2e8,
            uplink_comp: 1.0,
        };
        for fw in [
            Framework::VanillaSl,
            Framework::Sfl,
            Framework::Psl,
            Framework::Epsl { phi: 0.5 },
        ] {
            let s = round_latency(fw, &inp);
            let total = s.round_total();
            let split = s.comm_seconds() + s.compute_seconds();
            assert!(
                (split - total).abs() <= 1e-9 * total.max(1e-9),
                "{}: split {split} vs total {total}",
                fw.name()
            );
        }
    });
}

#[test]
fn deeper_cut_shifts_work_to_clients() {
    // Monotone structure check across all cut candidates.
    let profile = resnet18::profile();
    let mut prev_client = 0.0;
    let mut prev_server = f64::INFINITY;
    for &j in &profile.cut_candidates {
        let c = profile.client_fp_flops(j);
        let s = profile.server_fp_flops(j);
        assert!(c >= prev_client);
        assert!(s <= prev_server);
        prev_client = c;
        prev_server = s;
    }
}
