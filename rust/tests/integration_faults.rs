//! Fault-tolerance integration: checkpoint/resume bit-exactness, the
//! deterministic fault-injection plan, and straggler-deadline graceful
//! degradation — all on the native backend (no artifacts needed).
//!
//! The contracts under test:
//!   * a killed run resumed from a checkpoint is *bitwise* identical to
//!     the uninterrupted run (golden + every-round property test);
//!   * scheduled fault specs consume no RNG, so the pre-fault prefix of
//!     a faulty run matches the fault-free run bit for bit;
//!   * a mid-round client crash commits the round with the surviving
//!     cohort (re-normalized λ weights) and reports it in the metrics;
//!   * cohort-below-quorum is a structured error naming the round.

use epsl::config::Config;
use epsl::coordinator::{
    resume, resume_with_state, run_fingerprint, train, train_with_state,
    Checkpoint, TrainerOptions,
};
use epsl::coordinator::params::host_params;
use epsl::error::Error;
use epsl::latency::frameworks::Framework;
use epsl::metrics::RunMetrics;
use epsl::runtime::artifact::Manifest;
use epsl::runtime::native::{self, NativeBackend};
use epsl::scenario::FaultSpec;

fn setup() -> (NativeBackend, Manifest, Config) {
    (NativeBackend::new(), native::manifest(), Config::new())
}

fn short_opts(rounds: usize) -> TrainerOptions {
    TrainerOptions {
        framework: Framework::Epsl { phi: 0.5 },
        n_clients: 2,
        rounds,
        eval_every: 2,
        dataset_size: 600,
        test_size: 256,
        eta_c: 0.1,
        eta_s: 0.1,
        seed: 99,
        ..Default::default()
    }
}

fn scheduled(events: &str) -> FaultSpec {
    FaultSpec {
        events: FaultSpec::parse_events(events).unwrap(),
        ..Default::default()
    }
}

fn tmp_path(tag: &str) -> String {
    let mut p = std::env::temp_dir();
    p.push(format!("epsl_faults_{tag}_{}.ckpt", std::process::id()));
    p.to_string_lossy().into_owned()
}

/// Learning dynamics of two runs, compared bit for bit from `from` on
/// (wall_ms is wall-clock and necessarily differs).
fn assert_rounds_bit_equal(a: &RunMetrics, b: &RunMetrics, from: usize) {
    assert_eq!(a.rounds.len(), b.rounds.len());
    for (ra, rb) in a.rounds[from..].iter().zip(&b.rounds[from..]) {
        assert_eq!(ra.round, rb.round);
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "round {}", ra.round);
        assert_eq!(
            ra.train_acc.to_bits(),
            rb.train_acc.to_bits(),
            "round {}",
            ra.round
        );
        assert_eq!(
            ra.test_acc.map(f64::to_bits),
            rb.test_acc.map(f64::to_bits),
            "round {}",
            ra.round
        );
        assert_eq!(
            ra.sim_latency.to_bits(),
            rb.sim_latency.to_bits(),
            "round {}",
            ra.round
        );
        assert_eq!(ra.faults, rb.faults, "round {}", ra.round);
    }
}

fn assert_params_bit_equal(
    a: &[Vec<xla::Literal>],
    b: &[Vec<xla::Literal>],
) {
    assert_eq!(a.len(), b.len());
    for (i, (pa, pb)) in a.iter().zip(b).enumerate() {
        let (ha, hb) = (host_params(pa).unwrap(), host_params(pb).unwrap());
        assert_eq!(ha, hb, "replica {i} diverged");
    }
}

// --- checkpoint / resume ----------------------------------------------

#[test]
fn golden_kill_and_resume_is_bit_exact() {
    // 10 rounds straight vs 5 + snapshot-to-disk + restore + 5.
    let (rt, m, cfg) = setup();
    let straight = short_opts(10);
    let (full, full_state) =
        train_with_state(&rt, &m, &cfg, &straight).unwrap();

    let path = tmp_path("golden");
    let ckpt_opts = TrainerOptions {
        checkpoint_every: 5,
        checkpoint_path: Some(path.clone()),
        ..straight.clone()
    };
    // Writing checkpoints must not perturb the run itself.
    let with_ckpt = train(&rt, &m, &cfg, &ckpt_opts).unwrap();
    assert_rounds_bit_equal(&full, &with_ckpt, 0);

    // "Kill" the run: all we have is the checkpoint file on disk.
    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!(ck.next_round, 5);
    let (resumed, resumed_state) =
        resume_with_state(&rt, &m, &cfg, &straight, &ck).unwrap();
    std::fs::remove_file(&path).ok();

    // The resumed run carries the first 5 records and continues with
    // rounds 5..10 bitwise identical to the uninterrupted run.
    assert_rounds_bit_equal(&full, &resumed, 0);
    assert_params_bit_equal(
        &full_state.client_params,
        &resumed_state.client_params,
    );
    let (hs_a, hs_b) = (
        host_params(&full_state.server_params).unwrap(),
        host_params(&resumed_state.server_params).unwrap(),
    );
    assert_eq!(hs_a, hs_b, "server params diverged after resume");
    assert_eq!(full_state.rng, resumed_state.rng);
}

#[test]
fn checkpoint_roundtrip_property_every_round_both_families() {
    // Satellite 3: snapshot at EVERY round k of a 6-round run, for both
    // model families and cuts {1, 4}; the resumed run's continuation and
    // final parameters must be bitwise equal to the uninterrupted run's.
    //
    // A k-round run's TrainState is exactly the round-k snapshot (setup
    // is a pure function of the seed and evaluation consumes no RNG), so
    // the checkpoint is built from it and the fingerprint is taken from
    // the full run's options.
    let (rt, m, cfg) = setup();
    for family in ["mnist", "ham"] {
        for cut in [1usize, 4] {
            let full_opts = TrainerOptions {
                family: family.into(),
                cut,
                eval_every: 3,
                ..short_opts(6)
            };
            let (full, full_state) =
                train_with_state(&rt, &m, &cfg, &full_opts).unwrap();
            for k in 1..6 {
                let (_, sk) = train_with_state(
                    &rt,
                    &m,
                    &cfg,
                    &TrainerOptions { rounds: k, ..full_opts.clone() },
                )
                .unwrap();
                let ck = Checkpoint {
                    fingerprint: run_fingerprint(&cfg, &full_opts),
                    next_round: k,
                    rng: sk.rng,
                    client_params: sk
                        .client_params
                        .iter()
                        .map(|cp| host_params(cp).unwrap())
                        .collect(),
                    server_params: host_params(&sk.server_params)
                        .unwrap(),
                    records: full.rounds[..k].to_vec(),
                };
                // Serialize through the wire format too: resume from the
                // decoded bytes, not the in-memory struct.
                let ck = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
                let (resumed, rs) =
                    resume_with_state(&rt, &m, &cfg, &full_opts, &ck)
                        .unwrap();
                assert_rounds_bit_equal(&full, &resumed, k);
                assert_params_bit_equal(
                    &full_state.client_params,
                    &rs.client_params,
                );
                assert_eq!(
                    host_params(&full_state.server_params).unwrap(),
                    host_params(&rs.server_params).unwrap(),
                    "{family}/cut{cut}/k={k}: server params diverged"
                );
            }
        }
    }
}

#[test]
fn resume_into_a_different_experiment_is_rejected() {
    let (rt, m, cfg) = setup();
    let opts = short_opts(6);
    let (_, s3) = train_with_state(
        &rt,
        &m,
        &cfg,
        &TrainerOptions { rounds: 3, ..opts.clone() },
    )
    .unwrap();
    let ck = Checkpoint {
        fingerprint: run_fingerprint(
            &cfg,
            &TrainerOptions { seed: 7, ..opts.clone() },
        ),
        next_round: 3,
        rng: s3.rng,
        client_params: s3
            .client_params
            .iter()
            .map(|cp| host_params(cp).unwrap())
            .collect(),
        server_params: host_params(&s3.server_params).unwrap(),
        records: vec![],
    };
    let e = resume(&rt, &m, &cfg, &opts, &ck).unwrap_err();
    assert!(e.to_string().contains("fingerprint"), "{e}");
}

// --- fault injection ---------------------------------------------------

#[test]
fn scheduled_crash_commits_round_with_surviving_cohort() {
    // crash@2:1 on a 3-client run: round 2 commits with 2 clients and
    // re-normalized λ; rounds before the fault are bit-identical to the
    // fault-free run (scheduled specs consume no RNG).
    let (rt, m, cfg) = setup();
    let clean_opts = TrainerOptions { n_clients: 3, ..short_opts(5) };
    let clean = train(&rt, &m, &cfg, &clean_opts).unwrap();
    let opts = TrainerOptions {
        faults: Some(scheduled("crash@2:1")),
        ..clean_opts
    };
    let run = train(&rt, &m, &cfg, &opts).unwrap();
    for r in &run.rounds {
        if r.round == 2 {
            assert_eq!(r.faults.injected, 1);
            assert_eq!(r.faults.dropped, 1);
            assert_eq!(r.faults.cohort, 2);
        } else {
            assert_eq!(r.faults.injected, 0, "round {}", r.round);
            assert_eq!(r.faults.cohort, 3, "round {}", r.round);
        }
        assert!(r.loss.is_finite());
    }
    // Pre-fault prefix is bit-identical.
    let pre: Vec<u64> =
        clean.rounds[..2].iter().map(|r| r.loss.to_bits()).collect();
    let got: Vec<u64> =
        run.rounds[..2].iter().map(|r| r.loss.to_bits()).collect();
    assert_eq!(pre, got);
}

#[test]
fn corrupt_payload_retries_without_changing_the_trajectory() {
    // A corrupted uplink with retry budget re-transmits: the cohort stays
    // full and learning dynamics are bit-identical to the fault-free run;
    // only the accounting (retries + recovery seconds) moves.
    let (rt, m, cfg) = setup();
    let clean = train(&rt, &m, &cfg, &short_opts(4)).unwrap();
    let opts = TrainerOptions {
        faults: Some(scheduled("corrupt@1:0")),
        ..short_opts(4)
    };
    let run = train(&rt, &m, &cfg, &opts).unwrap();
    for (rc, rf) in clean.rounds.iter().zip(&run.rounds) {
        assert_eq!(rc.loss.to_bits(), rf.loss.to_bits());
        assert_eq!(rc.train_acc.to_bits(), rf.train_acc.to_bits());
    }
    let r1 = &run.rounds[1];
    assert_eq!(r1.faults.injected, 1);
    assert_eq!(r1.faults.retries, 1);
    assert_eq!(r1.faults.dropped, 0);
    assert_eq!(r1.faults.cohort, 2);
    assert!(r1.faults.recovery_s > 0.0);
    assert!(r1.sim_latency > clean.rounds[1].sim_latency);
}

#[test]
fn corrupt_payload_without_retry_budget_drops_the_client() {
    let (rt, m, cfg) = setup();
    let opts = TrainerOptions {
        n_clients: 3,
        faults: Some(FaultSpec {
            max_retries: 0,
            ..scheduled("corrupt@1:2")
        }),
        ..short_opts(3)
    };
    let run = train(&rt, &m, &cfg, &opts).unwrap();
    let r1 = &run.rounds[1];
    assert_eq!(r1.faults.dropped, 1);
    assert_eq!(r1.faults.retries, 0);
    assert_eq!(r1.faults.cohort, 2);
}

#[test]
fn straggler_beyond_deadline_is_dropped_within_is_absorbed() {
    let (rt, m, cfg) = setup();
    // A 100 s uplink delay blows any deadline derived from the nominal
    // timeline: the straggler is evicted, the round commits degraded.
    let late = TrainerOptions {
        faults: Some(scheduled("delay@1:0:100")),
        ..short_opts(3)
    };
    let run = train(&rt, &m, &cfg, &late).unwrap();
    let r1 = &run.rounds[1];
    assert_eq!(r1.faults.injected, 1);
    assert_eq!(r1.faults.dropped, 1);
    assert_eq!(r1.faults.cohort, 1);

    // A 1 ms delay lands well inside the 1.5× deadline: full cohort, the
    // trajectory is bit-identical (delays never touch the computation).
    let clean = train(&rt, &m, &cfg, &short_opts(3)).unwrap();
    let slight = TrainerOptions {
        faults: Some(scheduled("delay@1:0:0.001")),
        ..short_opts(3)
    };
    let run = train(&rt, &m, &cfg, &slight).unwrap();
    for (rc, rf) in clean.rounds.iter().zip(&run.rounds) {
        assert_eq!(rc.loss.to_bits(), rf.loss.to_bits());
    }
    assert_eq!(run.rounds[1].faults.dropped, 0);
    assert_eq!(run.rounds[1].faults.cohort, 2);
}

#[test]
fn server_abort_recovers_by_recomputing() {
    let (rt, m, cfg) = setup();
    let clean = train(&rt, &m, &cfg, &short_opts(3)).unwrap();
    let opts = TrainerOptions {
        faults: Some(scheduled("abort@1")),
        ..short_opts(3)
    };
    let run = train(&rt, &m, &cfg, &opts).unwrap();
    for (rc, rf) in clean.rounds.iter().zip(&run.rounds) {
        assert_eq!(rc.loss.to_bits(), rf.loss.to_bits());
    }
    let r1 = &run.rounds[1];
    assert_eq!(r1.faults.retries, 1);
    assert!(r1.faults.recovery_s > 0.0, "abort recompute not accounted");

    // With no retry budget the abort is terminal.
    let opts = TrainerOptions {
        faults: Some(FaultSpec { max_retries: 0, ..scheduled("abort@1") }),
        ..short_opts(3)
    };
    let e = train(&rt, &m, &cfg, &opts).unwrap_err();
    assert!(
        matches!(e, Error::Fault(_)),
        "unexpected error kind: {e}"
    );
    assert!(e.to_string().contains("round 1"), "{e}");
}

#[test]
fn cohort_below_quorum_is_a_structured_error() {
    let (rt, m, cfg) = setup();
    let opts = TrainerOptions {
        faults: Some(scheduled("crash@1:0,crash@1:1")),
        ..short_opts(3)
    };
    let e = train(&rt, &m, &cfg, &opts).unwrap_err();
    match e {
        Error::Quorum { round, active, need } => {
            assert_eq!(round, 1);
            assert_eq!(active, 0);
            assert_eq!(need, 1);
        }
        other => panic!("expected Error::Quorum, got: {other}"),
    }
}

#[test]
fn random_fault_plans_are_seed_deterministic() {
    let (rt, m, cfg) = setup();
    let opts = TrainerOptions {
        n_clients: 3,
        faults: Some(FaultSpec {
            crash_prob: 0.2,
            delay_prob: 0.2,
            delay_s: 0.05,
            ..Default::default()
        }),
        ..short_opts(5)
    };
    // Whatever the expanded plan does (including a quorum abort), it does
    // the same thing on every run of the same seed.
    let a = train(&rt, &m, &cfg, &opts);
    let b = train(&rt, &m, &cfg, &opts);
    match (a, b) {
        (Ok(ra), Ok(rb)) => {
            assert_rounds_bit_equal(&ra, &rb, 0);
            assert!(
                ra.rounds.iter().any(|r| r.faults.injected > 0),
                "plan with p=0.2 over 5 rounds × 3 clients injected nothing"
            );
        }
        (Err(ea), Err(eb)) => assert_eq!(ea.to_string(), eb.to_string()),
        (a, b) => panic!(
            "runs diverged: {:?} vs {:?}",
            a.map(|r| r.rounds.len()),
            b.map(|r| r.rounds.len())
        ),
    }
}

#[test]
fn resume_works_across_a_faulty_run() {
    // Checkpoint/resume and scheduled fault injection compose: the
    // resumed half replays the same fault plan (re-expanded from the
    // seed) and stays bitwise identical.
    let (rt, m, cfg) = setup();
    let base = TrainerOptions {
        n_clients: 3,
        faults: Some(scheduled("crash@1:2,corrupt@4:0")),
        ..short_opts(6)
    };
    let full = train(&rt, &m, &cfg, &base).unwrap();

    let path = tmp_path("faulty_resume");
    let ckpt_opts = TrainerOptions {
        checkpoint_every: 3,
        checkpoint_path: Some(path.clone()),
        ..base.clone()
    };
    train(&rt, &m, &cfg, &ckpt_opts).unwrap();
    let ck = Checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(ck.next_round, 3);
    let resumed = resume(&rt, &m, &cfg, &base, &ck).unwrap();
    assert_rounds_bit_equal(&full, &resumed, 0);
    assert_eq!(resumed.rounds[4].faults.retries, 1, "corrupt@4 replayed");
}
