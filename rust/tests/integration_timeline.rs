//! The timeline acceptance suite (PR 5 contract):
//!
//! 1. **Barrier parity** — `timeline::simulate(.., Mode::Barrier)` round
//!    totals are *bit-identical* to the closed-form
//!    `round_latency(..).round_total()` for all five frameworks, across
//!    the SplitNet cuts 1..4 (ResNet-18 layers 1/4/10/16), C ∈
//!    {1, 4, 8, 32}, and heterogeneous per-client rates.
//! 2. **Pipelined dominance** — pipelined totals never exceed barrier
//!    totals anywhere on the same grid (the engine's fp-monotone
//!    composition + clamp make this exact), and are strictly smaller on
//!    heterogeneous fixtures where overlap has something to hide.
//! 3. Event-log sanity in both modes.
//!
//! The CI "timeline parity" smoke step runs exactly this file.

use epsl::latency::frameworks::{round_latency, Framework};
use epsl::latency::LatencyInputs;
use epsl::profile::{resnet18, NetworkProfile};
use epsl::timeline::{simulate, EventKind, Mode};
use epsl::util::rng::Rng;

/// SplitNet stage cuts 1..=4 mapped onto the paper's ResNet-18 Table-IV
/// layer indices (the same mapping the coordinator uses).
const CUTS: [usize; 4] = [1, 4, 10, 16];
const CLIENT_COUNTS: [usize; 4] = [1, 4, 8, 32];

fn frameworks() -> Vec<Framework> {
    vec![
        Framework::VanillaSl,
        Framework::Sfl,
        Framework::Psl,
        Framework::Epsl { phi: 0.0 },
        Framework::Epsl { phi: 0.5 },
        Framework::Epsl { phi: 1.0 },
        Framework::EpslPt { early: true },
        Framework::EpslPt { early: false },
    ]
}

/// Heterogeneous per-client compute and link rates from a deterministic
/// seed (distinct ranges so every stage sees real spread).
fn het_rates(c: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let f: Vec<f64> = (0..c).map(|_| rng.uniform(0.8e9, 2.0e9)).collect();
    let up: Vec<f64> = (0..c).map(|_| rng.uniform(3e7, 3e8)).collect();
    let dn: Vec<f64> = (0..c).map(|_| rng.uniform(3e7, 3e8)).collect();
    (f, up, dn)
}

fn inputs<'a>(p: &'a NetworkProfile, cut: usize, f: &'a [f64],
              up: &'a [f64], dn: &'a [f64]) -> LatencyInputs<'a> {
    LatencyInputs {
        profile: p,
        cut,
        batch: 64,
        phi: 0.5, // ignored: the framework defines its own φ
        f_server: 5e9,
        kappa_server: 1.0 / 32.0,
        kappa_client: 1.0 / 16.0,
        f_clients: f,
        uplink: up,
        downlink: dn,
        broadcast: 2e8,
        uplink_comp: 1.0,
    }
}

#[test]
fn barrier_parity_bitwise_across_frameworks_cuts_and_clients() {
    let p = resnet18::profile();
    for (ci, &cut) in CUTS.iter().enumerate() {
        for (ni, &c) in CLIENT_COUNTS.iter().enumerate() {
            let seed = 0x71AE + (ci * 16 + ni) as u64;
            let (f, up, dn) = het_rates(c, seed);
            let inp = inputs(&p, cut, &f, &up, &dn);
            for fw in frameworks() {
                let closed = round_latency(fw, &inp).round_total();
                let tl = simulate(fw, &inp, Mode::Barrier);
                assert_eq!(
                    tl.total.to_bits(),
                    closed.to_bits(),
                    "{} cut={cut} C={c}: barrier {} != closed {closed}",
                    fw.name(),
                    tl.total
                );
                // The barrier stage spans re-sum to the total bitwise
                // (same eq. 23 association).
                assert_eq!(
                    tl.spans.total().to_bits(),
                    tl.total.to_bits(),
                    "{} cut={cut} C={c}: spans drifted",
                    fw.name()
                );
            }
        }
    }
}

#[test]
fn pipelined_leq_barrier_everywhere() {
    let p = resnet18::profile();
    for (ci, &cut) in CUTS.iter().enumerate() {
        for (ni, &c) in CLIENT_COUNTS.iter().enumerate() {
            let seed = 0xB3A7 + (ci * 16 + ni) as u64;
            let (f, up, dn) = het_rates(c, seed);
            let inp = inputs(&p, cut, &f, &up, &dn);
            for fw in frameworks() {
                let bar = simulate(fw, &inp, Mode::Barrier).total;
                let pipe = simulate(fw, &inp, Mode::Pipelined).total;
                assert!(
                    pipe <= bar,
                    "{} cut={cut} C={c}: pipelined {pipe} > barrier {bar}",
                    fw.name()
                );
            }
        }
    }
}

#[test]
fn pipelined_leq_barrier_on_homogeneous_fixtures() {
    // Homogeneous clients are the rounding-sensitive corner (overlap
    // buys nothing on the client chains; equality must not tip over).
    let p = resnet18::profile();
    for &cut in &CUTS {
        for &c in &CLIENT_COUNTS {
            let f = vec![1.2e9; c];
            let up = vec![1.5e8; c];
            let dn = vec![1.5e8; c];
            let inp = inputs(&p, cut, &f, &up, &dn);
            for fw in frameworks() {
                let bar = simulate(fw, &inp, Mode::Barrier).total;
                let pipe = simulate(fw, &inp, Mode::Pipelined).total;
                assert!(
                    pipe <= bar,
                    "{} cut={cut} C={c} homogeneous: {pipe} > {bar}",
                    fw.name()
                );
            }
        }
    }
}

#[test]
fn pipelined_strictly_faster_on_heterogeneous_fixture() {
    // The acceptance fixture: strong compute + link heterogeneity at
    // C = 4. Every parallel framework must strictly gain from overlap.
    let p = resnet18::profile();
    let f = [0.8e9, 1.6e9, 1.2e9, 2.0e9];
    let up = [3e7, 3e8, 1e8, 2e8];
    let dn = [4e7, 2.5e8, 1.2e8, 1.8e8];
    for &cut in &CUTS {
        let inp = inputs(&p, cut, &f, &up, &dn);
        for fw in [
            Framework::Epsl { phi: 0.5 },
            Framework::Epsl { phi: 1.0 },
            Framework::Psl,
            Framework::Sfl,
        ] {
            let bar = simulate(fw, &inp, Mode::Barrier).total;
            let pipe = simulate(fw, &inp, Mode::Pipelined).total;
            assert!(
                pipe < bar,
                "{} cut={cut}: pipelined {pipe} !< barrier {bar}",
                fw.name()
            );
        }
    }
}

#[test]
fn vanilla_has_nothing_to_overlap() {
    let p = resnet18::profile();
    let (f, up, dn) = het_rates(5, 0x5E0);
    let inp = inputs(&p, 10, &f, &up, &dn);
    let bar = simulate(Framework::VanillaSl, &inp, Mode::Barrier).total;
    let pipe =
        simulate(Framework::VanillaSl, &inp, Mode::Pipelined).total;
    assert_eq!(pipe.to_bits(), bar.to_bits());
}

#[test]
fn event_logs_are_sane_in_both_modes() {
    let p = resnet18::profile();
    let (f, up, dn) = het_rates(4, 0xE7E7);
    let inp = inputs(&p, 10, &f, &up, &dn);
    for mode in [Mode::Barrier, Mode::Pipelined] {
        for fw in frameworks() {
            let tl = simulate(fw, &inp, mode);
            // Sorted, finite, nonnegative.
            assert!(tl.events.windows(2).all(|w| w[0].t <= w[1].t));
            assert!(tl
                .events
                .iter()
                .all(|e| e.t.is_finite() && e.t >= 0.0));
            // RoundDone is last and equals the total.
            let last = tl.events.last().unwrap();
            assert_eq!(last.kind, EventKind::RoundDone);
            assert_eq!(last.t.to_bits(), tl.total.to_bits());
            // One FP-done and one uplink-arrival event per chain.
            let n_chains = if matches!(fw, Framework::VanillaSl) {
                1
            } else {
                4
            };
            let fp_done = tl
                .events
                .iter()
                .filter(|e| {
                    matches!(e.kind, EventKind::ClientFpDone { .. })
                })
                .count();
            assert_eq!(fp_done, n_chains, "{} {mode:?}", fw.name());
            // SFL (and only SFL) logs model uploads.
            let uploads = tl
                .events
                .iter()
                .filter(|e| {
                    matches!(e.kind, EventKind::ModelUploadDone { .. })
                })
                .count();
            if matches!(fw, Framework::Sfl) {
                assert_eq!(uploads, n_chains);
            } else {
                assert_eq!(uploads, 0);
            }
        }
    }
}

#[test]
fn pipelined_epsl_beats_barrier_baselines() {
    // The paper's qualitative claim holds in both engines: pipelined
    // EPSL(φ=0.5) undercuts every baseline framework's barrier round
    // (baselines all run φ ≤ 0.5, so φ alone cannot explain it away).
    let p = resnet18::profile();
    let (f, up, dn) = het_rates(5, 0x0BD);
    let inp = inputs(&p, 10, &f, &up, &dn);
    let epsl_pipe =
        simulate(Framework::Epsl { phi: 0.5 }, &inp, Mode::Pipelined)
            .total;
    for fw in [
        Framework::Epsl { phi: 0.5 },
        Framework::Psl,
        Framework::Sfl,
        Framework::VanillaSl,
    ] {
        let bar = simulate(fw, &inp, Mode::Barrier).total;
        assert!(
            epsl_pipe <= bar,
            "pipelined EPSL {epsl_pipe} > barrier {} {bar}",
            fw.name()
        );
    }
}
