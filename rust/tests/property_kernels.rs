//! Bit-identity property tests for the native backend's im2col +
//! blocked-GEMM fast path (PR 4): every fast kernel and every fast
//! model entry point must be **bitwise** equal to the retained naive
//! reference implementations — across all cuts (1..=4), both families,
//! odd spatial sizes, and strides 1–2 — so the PR 3 determinism
//! guarantees (seed-reproducible, `EPSL_THREADS`-invariant) survive the
//! kernel rewrite unchanged.

use epsl::profile::splitnet::SplitNetConfig;
use epsl::runtime::native::kernels::{self, Buf, ScratchPool};
use epsl::runtime::native::model;
use epsl::runtime::native::MathTier;
use epsl::runtime::native::ops;
use epsl::util::prop::{check, Gen};
use epsl::util::rng::Rng;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()
}

#[test]
fn property_conv2d_fast_bit_identical_on_random_shapes() {
    // Random (odd and even) spatial sizes, strides 1–2, 1×1 and 3×3
    // kernels — the full shape envelope the model uses, plus odd sizes
    // it doesn't, to pin the padding arithmetic.
    check("conv2d fast == reference", 120, |g: &mut Gen| {
        let h = g.usize_in(1, 11);
        let w = g.usize_in(1, 11);
        let cin = g.usize_in(1, 9);
        let cout = g.usize_in(1, 20);
        let k = *g.choose(&[1usize, 3]);
        let stride = g.usize_in(1, 2);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let x = rand_vec(&mut rng, h * w * cin);
        let wt = rand_vec(&mut rng, k * k * cin * cout);
        let bias = rand_vec(&mut rng, cout);
        let reference =
            ops::conv2d(&x, (h, w, cin), &wt, k, cout, &bias, stride);
        let mut fast = vec![0.0f32; reference.len()];
        let mut patch = Buf::default();
        kernels::conv2d_fast(&x, (h, w, cin), &wt, k, cout, &bias,
                             stride, &mut patch, &mut fast);
        assert_eq!(
            bits(&reference),
            bits(&fast),
            "h={h} w={w} cin={cin} cout={cout} k={k} stride={stride}"
        );
    });
}

#[test]
fn property_conv2d_bwd_fast_bit_identical_on_random_shapes() {
    check("conv2d_bwd fast == reference", 80, |g: &mut Gen| {
        let h = g.usize_in(1, 9);
        let w = g.usize_in(1, 9);
        let cin = g.usize_in(1, 8);
        let cout = g.usize_in(1, 18);
        let k = *g.choose(&[1usize, 3]);
        let stride = g.usize_in(1, 2);
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let x = rand_vec(&mut rng, h * w * cin);
        let wt = rand_vec(&mut rng, k * k * cin * cout);
        let (oh, ow) = (ops::out_size(h, stride), ops::out_size(w, stride));
        let gy = rand_vec(&mut rng, oh * ow * cout);
        let (rgw, rgb, rgx) =
            ops::conv2d_bwd(&x, (h, w, cin), &wt, k, cout, stride, &gy);
        let (mut patch, mut dpatch) = (Buf::default(), Buf::default());
        let mut gw = vec![0.0f32; rgw.len()];
        let mut gb = vec![0.0f32; rgb.len()];
        let mut gx = vec![0.0f32; rgx.len()];
        kernels::conv2d_bwd_fast(&x, (h, w, cin), &wt, k, cout, stride,
                                 &gy, &mut patch, &mut dpatch, &mut gw,
                                 &mut gb, &mut gx);
        let tag = format!(
            "h={h} w={w} cin={cin} cout={cout} k={k} stride={stride}"
        );
        assert_eq!(bits(&rgw), bits(&gw), "gw {tag}");
        assert_eq!(bits(&rgb), bits(&gb), "gb {tag}");
        assert_eq!(bits(&rgx), bits(&gx), "gx {tag}");
    });
}

/// Fast vs reference across every model entry point, all cuts, both
/// families — the end-to-end half of the bit-identity contract.
#[test]
fn fast_model_paths_bit_identical_to_reference_all_cuts_both_families() {
    let pool = ScratchPool::new();
    let b = 4usize;
    let c = 2usize;
    for family in ["mnist", "ham"] {
        let cfg = SplitNetConfig::for_family(family);
        let in_len = cfg.img * cfg.img * cfg.channels;
        for cut in 1..=4usize {
            let seed = (cut * 31) as u64 + if family == "mnist" { 0 } else { 7 };
            let params = model::init_params(&cfg, seed);
            let n_c = model::client_param_count(cut);
            let mut rng = Rng::new(seed ^ 0xABCD);
            let x = rand_vec(&mut rng, b * in_len);

            // client_fwd
            let fast = model::client_fwd(&cfg, cut, &params[..n_c], &x, b,
                                         MathTier::Bitwise, &pool);
            let reference = model::client_fwd_reference(
                &cfg, cut, &params[..n_c], &x, b);
            assert_eq!(bits(&reference), bits(&fast),
                       "client_fwd {family} cut{cut}");

            // server_train (mixed mask: aggregated + unicast rows)
            let (sh, sw, sc) = cfg.smashed_shape(cut);
            let smash_len = sh * sw * sc;
            let smashed = rand_vec(&mut rng, c * b * smash_len);
            let labels: Vec<i32> = (0..c * b)
                .map(|k| ((k * 3 + cut) % cfg.num_classes) as i32)
                .collect();
            let lam = vec![0.3f32, 0.7];
            let mask: Vec<f32> = (0..b)
                .map(|j| if j % 2 == 0 { 1.0 } else { 0.0 })
                .collect();
            let f = model::server_train(&cfg, cut, c, b, 3,
                                        MathTier::Bitwise,
                                        &params[n_c..], &smashed,
                                        &labels, &lam, &mask, 0.05,
                                        &pool)
                .unwrap();
            let r = model::server_train_reference(&cfg, cut, c, b, 2,
                                                  &params[n_c..],
                                                  &smashed, &labels,
                                                  &lam, &mask, 0.05);
            assert_eq!(f.loss.to_bits(), r.loss.to_bits(),
                       "loss {family} cut{cut}");
            assert_eq!(f.ncorrect.to_bits(), r.ncorrect.to_bits(),
                       "ncorrect {family} cut{cut}");
            assert_eq!(bits(&f.cut_agg), bits(&r.cut_agg),
                       "cut_agg {family} cut{cut}");
            assert_eq!(bits(&f.cut_unagg), bits(&r.cut_unagg),
                       "cut_unagg {family} cut{cut}");
            for (t, (fp, rp)) in
                f.new_params.iter().zip(&r.new_params).enumerate()
            {
                assert_eq!(bits(fp), bits(rp),
                           "new_params[{t}] {family} cut{cut}");
            }

            // client_step driven by the broadcast gradient
            let new_fast = model::client_step(&cfg, cut, &params[..n_c],
                                              &x, &f.cut_agg[..b * smash_len],
                                              0.05, b, MathTier::Bitwise,
                                              &pool);
            let new_ref = model::client_step_reference(
                &cfg, cut, &params[..n_c], &x,
                &r.cut_agg[..b * smash_len], 0.05, b);
            for (t, (fp, rp)) in new_fast.iter().zip(&new_ref).enumerate()
            {
                assert_eq!(bits(fp), bits(rp),
                           "client_step[{t}] {family} cut{cut}");
            }
        }

        // eval (full model, odd-sized label batch)
        let params = model::init_params(&cfg, 5);
        let n = 9usize;
        let mut rng = Rng::new(99);
        let ex = rand_vec(&mut rng, n * in_len);
        let ey: Vec<i32> =
            (0..n).map(|j| (j % cfg.num_classes) as i32).collect();
        let (fl, fc) =
            model::eval(&cfg, &params, &ex, &ey, 4, MathTier::Bitwise,
                        &pool)
                .unwrap();
        let (rl, rc) = model::eval_reference(&cfg, &params, &ex, &ey, 1);
        assert_eq!(fl.to_bits(), rl.to_bits(), "eval loss {family}");
        assert_eq!(fc.to_bits(), rc.to_bits(), "eval ncorrect {family}");
    }
}

/// The φ=1.0 (all-aggregated) and φ=0 (all-unicast) mask corners also
/// match the reference exactly.
#[test]
fn fast_server_train_mask_corners_match_reference() {
    let cfg = SplitNetConfig::mnist_like();
    let pool = ScratchPool::new();
    let (cut, c, b) = (2usize, 3usize, 4usize);
    let n_c = model::client_param_count(cut);
    let params = model::init_params(&cfg, 13);
    let (sh, sw, sc) = cfg.smashed_shape(cut);
    let smash_len = sh * sw * sc;
    let mut rng = Rng::new(17);
    let smashed = rand_vec(&mut rng, c * b * smash_len);
    let labels: Vec<i32> =
        (0..c * b).map(|k| (k % cfg.num_classes) as i32).collect();
    let lam = vec![1.0 / c as f32; c];
    for mask in [vec![1.0f32; b], vec![0.0f32; b]] {
        let f = model::server_train(&cfg, cut, c, b, 2,
                                    MathTier::Bitwise, &params[n_c..],
                                    &smashed, &labels, &lam, &mask, 0.1,
                                    &pool)
            .unwrap();
        let r = model::server_train_reference(&cfg, cut, c, b, 1,
                                              &params[n_c..], &smashed,
                                              &labels, &lam, &mask, 0.1);
        assert_eq!(bits(&f.cut_agg), bits(&r.cut_agg));
        assert_eq!(bits(&f.cut_unagg), bits(&r.cut_unagg));
        assert_eq!(f.loss.to_bits(), r.loss.to_bits());
        for (fp, rp) in f.new_params.iter().zip(&r.new_params) {
            assert_eq!(bits(fp), bits(rp));
        }
    }
}
