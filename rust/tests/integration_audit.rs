//! Fixture tests for the `epsl-audit` static-analysis pass, the
//! live-tree clean self-check, and regression tests for the bugs the
//! first tree-wide sweep surfaced.
//!
//! Fixtures are audited as in-memory strings with a pretend repo path,
//! so each rule's firing and suppression behavior is pinned without
//! touching the real tree. All forbidden tokens below live inside
//! string literals, which the audit lexer blanks — this file audits
//! clean even though it spells out every violation.

use std::path::PathBuf;

use epsl::analysis::{audit_source, audit_tree, severity, RuleId, Severity};

/// Repo root: the crate manifest lives in `rust/`, the audited tree is
/// its parent.
fn repo_root() -> PathBuf {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().map(PathBuf::from).unwrap_or(manifest)
}

fn rules_fired(rel: &str, src: &str) -> Vec<RuleId> {
    let mut rules: Vec<RuleId> =
        audit_source(rel, src).findings.iter().map(|f| f.rule).collect();
    rules.dedup();
    rules
}

// ---- R1: no unwrap/expect/panic in non-test library code ---------------

#[test]
fn r1_fires_on_library_unwrap() {
    let src = "pub fn f(x: Option<u8>) -> u8 { x.unwrap() }\n";
    assert_eq!(rules_fired("rust/src/latency/fake.rs", src), vec![RuleId::R1]);
}

#[test]
fn r1_negative_test_code_and_non_src() {
    let test_src = "#[cfg(test)]\nmod tests {\n fn f() { o.unwrap(); }\n}\n";
    assert!(rules_fired("rust/src/latency/fake.rs", test_src).is_empty());
    // Integration tests, benches, and examples may panic freely.
    let src = "fn f() { o.unwrap(); p.expect(\"m\"); panic!(\"x\"); }\n";
    assert!(rules_fired("rust/tests/fake.rs", src).is_empty());
    assert!(rules_fired("rust/benches/fake.rs", src).is_empty());
    assert!(rules_fired("examples/fake.rs", src).is_empty());
    // Non-panicking cousins don't fire.
    let ok = "pub fn f(x: Option<u8>) -> u8 { x.unwrap_or(0) }\n";
    assert!(rules_fired("rust/src/latency/fake.rs", ok).is_empty());
}

// ---- R2: no hash-ordered maps in deterministic modules -----------------

#[test]
fn r2_fires_in_deterministic_modules() {
    let src = "use std::collections::HashMap;\n";
    for rel in [
        "rust/src/optim/fake.rs",
        "rust/src/timeline/fake.rs",
        "rust/src/coordinator/fake.rs",
        "rust/src/scenario/fake.rs",
        "rust/src/runtime/native/fake.rs",
    ] {
        assert_eq!(rules_fired(rel, src), vec![RuleId::R2], "{rel}");
    }
}

#[test]
fn r2_negative_outside_det_modules_and_for_btreemap() {
    let src = "use std::collections::HashMap;\n";
    assert!(rules_fired("rust/src/util/fake.rs", src).is_empty());
    let ok = "use std::collections::BTreeMap;\n";
    assert!(rules_fired("rust/src/optim/fake.rs", ok).is_empty());
}

// ---- R3: no host clock outside bench + driver wall-stats ---------------

#[test]
fn r3_fires_on_instant_in_src() {
    let src = "use std::time::Instant;\n";
    assert_eq!(rules_fired("rust/src/runtime/fake.rs", src), vec![RuleId::R3]);
    let sys = "let t = SystemTime::now();\n";
    assert_eq!(rules_fired("rust/src/channel/fake.rs", sys), vec![RuleId::R3]);
}

#[test]
fn r3_negative_in_exempt_files() {
    let src = "use std::time::Instant;\n";
    assert!(rules_fired("rust/src/util/bench.rs", src).is_empty());
    assert!(rules_fired("rust/src/coordinator/driver.rs", src)
        .iter()
        .all(|r| *r != RuleId::R3));
    // Benches measure wall time by design.
    assert!(rules_fired("rust/benches/fake.rs", src).is_empty());
}

// ---- R4: no ambient entropy -------------------------------------------

#[test]
fn r4_fires_everywhere() {
    for tok in ["thread_rng()", "from_entropy()", "RandomState::new()"] {
        let src = format!("let r = {tok};\n");
        for rel in ["rust/src/util/fake.rs", "rust/tests/fake.rs",
                    "examples/fake.rs"] {
            assert_eq!(
                rules_fired(rel, &src),
                vec![RuleId::R4],
                "{tok} in {rel}"
            );
        }
    }
}

#[test]
fn r4_negative_named_streams() {
    // The sanctioned pattern: forking a named stream from the run seed.
    let src = "let mut rng = Rng::new(seed).fork(0xFA17);\n";
    assert!(rules_fired("rust/src/scenario/fake.rs", src).is_empty());
}

// ---- R5: no fast-math / ad-hoc threading ------------------------------

#[test]
fn r5_fires_on_mul_add_and_threading() {
    let fma = "let y = a.mul_add(b, c);\n";
    assert_eq!(
        rules_fired("rust/src/runtime/native/kernels.rs", fma),
        vec![RuleId::R5]
    );
    let spawn = "std::thread::spawn(move || work());\n";
    assert_eq!(rules_fired("rust/src/experiments/fake.rs", spawn),
               vec![RuleId::R5]);
    let par = "let s: f32 = v.par_iter().sum();\n";
    assert_eq!(rules_fired("rust/src/optim/fake.rs", par)
                   .iter()
                   .filter(|r| **r == RuleId::R5)
                   .count(),
               1);
}

#[test]
fn r5_negative_in_util_par_and_plain_code() {
    let spawn = "std::thread::scope(|scope| { scope.spawn(|| f()); });\n";
    assert!(rules_fired("rust/src/util/par.rs", spawn).is_empty());
    // A plain multiply-add spelled out does not fire.
    let ok = "let y = a * b + c; let z = v.iter().sum::<f32>();\n";
    assert!(rules_fired("rust/src/runtime/native/kernels.rs", ok).is_empty());
}

// ---- R6: narrowing casts in parsing layers ----------------------------

#[test]
fn r6_fires_in_config_and_checkpoint() {
    let src = "let n = x as u32;\n";
    assert_eq!(rules_fired("rust/src/config/fake.rs", src), vec![RuleId::R6]);
    assert_eq!(
        rules_fired("rust/src/coordinator/checkpoint.rs", src),
        vec![RuleId::R6]
    );
}

#[test]
fn r6_negative_widening_and_out_of_scope() {
    // Widening casts are fine even in scope.
    let ok = "let n = x as u64; let f = y as f64;\n";
    assert!(rules_fired("rust/src/config/fake.rs", ok).is_empty());
    // Narrowing casts outside the parsing layers are other rules' turf.
    let src = "let n = x as u32;\n";
    assert!(rules_fired("rust/src/latency/fake.rs", src).is_empty());
}

#[test]
fn r6_is_advisory_unless_deny_all() {
    assert_eq!(severity(RuleId::R6, false), Severity::Warn);
    assert_eq!(severity(RuleId::R6, true), Severity::Deny);
    for rule in [RuleId::R1, RuleId::R2, RuleId::R3, RuleId::R4, RuleId::R5] {
        assert_eq!(severity(rule, false), Severity::Deny, "{rule}");
    }
}

// ---- suppression directives -------------------------------------------

#[test]
fn suppression_same_line_and_preceding_comment() {
    let trailing = "let v = o.unwrap(); \
                    // audit:allow(R1, \"established invariant\")\n";
    let fa = audit_source("rust/src/latency/fake.rs", trailing);
    assert!(fa.findings.is_empty());
    assert_eq!(fa.suppressed, 1);

    let preceding = "// audit:allow(R2, \"never iterated, keyed get/insert only\")\n\
                     use std::collections::HashMap;\n";
    let fa = audit_source("rust/src/optim/fake.rs", preceding);
    assert!(fa.findings.is_empty());
    assert_eq!(fa.suppressed, 1);
}

#[test]
fn suppression_requires_matching_rule_and_reason() {
    // Wrong rule id: the finding survives.
    let wrong = "let v = o.unwrap(); // audit:allow(R3, \"wrong rule\")\n";
    assert_eq!(rules_fired("rust/src/latency/fake.rs", wrong),
               vec![RuleId::R1]);
    // Missing reason: malformed directive, finding survives.
    let bare = "let v = o.unwrap(); // audit:allow(R1)\n";
    assert_eq!(rules_fired("rust/src/latency/fake.rs", bare),
               vec![RuleId::R1]);
    // Directive does not leak past an intervening code line.
    let stale = "// audit:allow(R1, \"one line only\")\nlet a = 1;\n\
                 let v = o.unwrap();\n";
    let fa = audit_source("rust/src/latency/fake.rs", stale);
    assert_eq!(fa.findings.len(), 1);
    assert_eq!(fa.findings[0].line, 3);
}

// ---- the live tree audits clean (epsl-audit --deny-all contract) ------

#[test]
fn live_tree_audits_clean_under_deny_all() {
    let report = audit_tree(&repo_root()).expect("audit walk failed");
    assert!(
        report.files_scanned > 50,
        "suspiciously few files scanned ({}) — wrong root?",
        report.files_scanned
    );
    let listing: Vec<String> = report
        .findings
        .iter()
        .map(|f| format!("{}:{}: {} [{}] {}",
                         f.path, f.line, f.rule, f.token, f.snippet))
        .collect();
    // Zero findings of ANY severity: `epsl-audit --deny-all` must exit 0.
    assert!(
        report.findings.is_empty(),
        "live tree has audit findings:\n{}",
        listing.join("\n")
    );
}

// ---- serial-vs-threaded parity over the swept coordinator paths -------

#[test]
fn training_parity_serial_vs_threaded_after_sweep() {
    // The HashMap→BTreeMap swap (session mask_cache, driver) and the
    // error-handling sweep must leave training bit-identical across
    // thread counts: EPSL φ=0.5 exercises the mask cache, evaluation,
    // and the λ-aggregation path end to end.
    use epsl::config::Config;
    use epsl::coordinator::{train, TrainerOptions};
    use epsl::latency::frameworks::Framework;
    use epsl::runtime::native::{self, NativeBackend};

    let cfg = Config::new();
    let m = native::manifest();
    let opts = TrainerOptions {
        framework: Framework::Epsl { phi: 0.5 },
        n_clients: 3,
        rounds: 6,
        eval_every: 3,
        dataset_size: 480,
        test_size: 256,
        eta_c: 0.1,
        eta_s: 0.1,
        seed: 2024,
        ..Default::default()
    };
    let a = train(&NativeBackend::with_threads(1), &m, &cfg, &opts)
        .expect("serial run failed");
    let b = train(&NativeBackend::with_threads(8), &m, &cfg, &opts)
        .expect("threaded run failed");
    assert_eq!(a.rounds.len(), b.rounds.len());
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(
            ra.loss.to_bits(),
            rb.loss.to_bits(),
            "round {} loss diverged across thread counts",
            ra.round
        );
        assert_eq!(ra.test_acc.map(f64::to_bits),
                   rb.test_acc.map(f64::to_bits));
        assert_eq!(ra.sim_latency.to_bits(), rb.sim_latency.to_bits());
    }
}

// ---- regression: bugs surfaced by the first sweep ---------------------

#[test]
fn regression_toml_as_usize_rejects_non_integers() {
    use epsl::config::toml::Value;
    assert_eq!(Value::Num(2.0).as_usize(), Some(2));
    assert_eq!(Value::Num(0.0).as_usize(), Some(0));
    // Fractional counts used to silently truncate (rounds = 2.7 → 2).
    assert_eq!(Value::Num(2.7).as_usize(), None);
    assert_eq!(Value::Num(-1.0).as_usize(), None);
    // Past 2^53 the f64 has already lost integer precision.
    assert_eq!(Value::Num(1e16).as_usize(), None);
    assert_eq!(Value::Str("3".into()).as_usize(), None);
}

#[test]
fn regression_json_as_usize_rejects_non_integers() {
    use epsl::util::json::Json;
    assert_eq!(Json::Num(64.0).as_usize(), Some(64));
    assert_eq!(Json::Num(64.5).as_usize(), None);
    assert_eq!(Json::Num(-2.0).as_usize(), None);
}

#[test]
fn regression_init_seed_uses_all_64_bits() {
    // The init literal used to pass [0, seed as u32]: seeds differing
    // only in the high 32 bits collapsed to identical model inits.
    use epsl::config::Config;
    use epsl::coordinator::{train, TrainerOptions};
    use epsl::latency::frameworks::Framework;
    use epsl::runtime::native::{self, NativeBackend};

    let cfg = Config::new();
    let m = native::manifest();
    let rt = NativeBackend::with_threads(1);
    let mk = |seed: u64| TrainerOptions {
        framework: Framework::Psl,
        n_clients: 2,
        rounds: 1,
        eval_every: 1,
        dataset_size: 320,
        test_size: 256,
        eta_c: 0.1,
        eta_s: 0.1,
        seed,
        ..Default::default()
    };
    let lo = train(&rt, &m, &cfg, &mk(7)).expect("seed=7 run failed");
    let hi = train(&rt, &m, &cfg, &mk(7 + (1u64 << 32)))
        .expect("seed=7+2^32 run failed");
    assert_ne!(
        lo.rounds[0].loss.to_bits(),
        hi.rounds[0].loss.to_bits(),
        "seeds differing only in the high 32 bits must not collide"
    );
}
