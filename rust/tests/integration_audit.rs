//! Fixture tests for the `epsl-audit` static-analysis pass, the
//! live-tree clean self-check, and regression tests for the bugs the
//! first tree-wide sweep surfaced.
//!
//! Fixtures are audited as in-memory strings with a pretend repo path,
//! so each rule's firing and suppression behavior is pinned without
//! touching the real tree. All forbidden tokens below live inside
//! string literals, which the audit lexer blanks — this file audits
//! clean even though it spells out every violation.

use std::path::PathBuf;

use epsl::analysis::{
    audit_source, audit_source_with, audit_tree, severity, Baseline, RuleId,
    Severity, StreamRegistry,
};

/// Repo root: the crate manifest lives in `rust/`, the audited tree is
/// its parent.
fn repo_root() -> PathBuf {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().map(PathBuf::from).unwrap_or(manifest)
}

fn rules_fired(rel: &str, src: &str) -> Vec<RuleId> {
    let mut rules: Vec<RuleId> =
        audit_source(rel, src).findings.iter().map(|f| f.rule).collect();
    rules.dedup();
    rules
}

/// A small stand-in `util::rng::streams` registry for R8 fixtures, so
/// named-tag resolution is pinned without depending on the live tree's
/// tag set.
fn fixture_registry() -> StreamRegistry {
    StreamRegistry::parse(
        "pub mod streams {\n\
         pub const FIG_SEED: u64 = 0x1A2B;\n\
         pub const CELL_DRAW: u64 = 0x3C4D;\n\
         pub const ALL: [u64; 2] = [FIG_SEED, CELL_DRAW];\n\
         }\n",
    )
}

// ---- R1: no unwrap/expect/panic in non-test library code ---------------

#[test]
fn r1_fires_on_library_unwrap() {
    let src = "pub fn f(x: Option<u8>) -> u8 { x.unwrap() }\n";
    assert_eq!(rules_fired("rust/src/latency/fake.rs", src), vec![RuleId::R1]);
}

#[test]
fn r1_negative_test_code_and_non_src() {
    let test_src = "#[cfg(test)]\nmod tests {\n fn f() { o.unwrap(); }\n}\n";
    assert!(rules_fired("rust/src/latency/fake.rs", test_src).is_empty());
    // Integration tests, benches, and examples may panic freely.
    let src = "fn f() { o.unwrap(); p.expect(\"m\"); panic!(\"x\"); }\n";
    assert!(rules_fired("rust/tests/fake.rs", src).is_empty());
    assert!(rules_fired("rust/benches/fake.rs", src).is_empty());
    assert!(rules_fired("examples/fake.rs", src).is_empty());
    // Non-panicking cousins don't fire.
    let ok = "pub fn f(x: Option<u8>) -> u8 { x.unwrap_or(0) }\n";
    assert!(rules_fired("rust/src/latency/fake.rs", ok).is_empty());
}

// ---- R2: no hash-ordered maps in deterministic modules -----------------

#[test]
fn r2_fires_in_deterministic_modules() {
    let src = "use std::collections::HashMap;\n";
    for rel in [
        "rust/src/optim/fake.rs",
        "rust/src/timeline/fake.rs",
        "rust/src/coordinator/fake.rs",
        "rust/src/scenario/fake.rs",
        "rust/src/runtime/native/fake.rs",
    ] {
        assert_eq!(rules_fired(rel, src), vec![RuleId::R2], "{rel}");
    }
}

#[test]
fn r2_negative_outside_det_modules_and_for_btreemap() {
    let src = "use std::collections::HashMap;\n";
    assert!(rules_fired("rust/src/util/fake.rs", src).is_empty());
    let ok = "use std::collections::BTreeMap;\n";
    assert!(rules_fired("rust/src/optim/fake.rs", ok).is_empty());
}

// ---- R3: no host clock outside bench + driver wall-stats ---------------

#[test]
fn r3_fires_on_instant_in_src() {
    let src = "use std::time::Instant;\n";
    assert_eq!(rules_fired("rust/src/runtime/fake.rs", src), vec![RuleId::R3]);
    let sys = "let t = SystemTime::now();\n";
    assert_eq!(rules_fired("rust/src/channel/fake.rs", sys), vec![RuleId::R3]);
}

#[test]
fn r3_negative_in_exempt_files() {
    let src = "use std::time::Instant;\n";
    assert!(rules_fired("rust/src/util/bench.rs", src).is_empty());
    assert!(rules_fired("rust/src/coordinator/driver.rs", src)
        .iter()
        .all(|r| *r != RuleId::R3));
    // Benches measure wall time by design.
    assert!(rules_fired("rust/benches/fake.rs", src).is_empty());
}

// ---- R4: no ambient entropy -------------------------------------------

#[test]
fn r4_fires_everywhere() {
    for tok in ["thread_rng()", "from_entropy()", "RandomState::new()"] {
        let src = format!("let r = {tok};\n");
        for rel in ["rust/src/util/fake.rs", "rust/tests/fake.rs",
                    "examples/fake.rs"] {
            assert_eq!(
                rules_fired(rel, &src),
                vec![RuleId::R4],
                "{tok} in {rel}"
            );
        }
    }
}

#[test]
fn r4_negative_named_streams() {
    // The sanctioned pattern: forking a *registered named* stream from
    // the run seed. (A raw-literal tag here would be R8's turf now.)
    let reg = fixture_registry();
    let src = "let mut rng = Rng::new(seed).fork(streams::FIG_SEED);\n";
    let fa = audit_source_with("rust/src/scenario/fake.rs", src, Some(&reg));
    assert!(fa.findings.is_empty(), "{:?}", fa.findings);
}

// ---- R5: no fast-math / ad-hoc threading ------------------------------

#[test]
fn r5_fires_on_mul_add_and_threading() {
    let fma = "let y = a.mul_add(b, c);\n";
    assert_eq!(
        rules_fired("rust/src/runtime/native/kernels.rs", fma),
        vec![RuleId::R5]
    );
    let spawn = "std::thread::spawn(move || work());\n";
    assert_eq!(rules_fired("rust/src/experiments/fake.rs", spawn),
               vec![RuleId::R5]);
    let par = "let s: f32 = v.par_iter().sum();\n";
    assert_eq!(rules_fired("rust/src/optim/fake.rs", par)
                   .iter()
                   .filter(|r| **r == RuleId::R5)
                   .count(),
               1);
}

#[test]
fn r5_negative_in_util_par_and_plain_code() {
    let spawn = "std::thread::scope(|scope| { scope.spawn(|| f()); });\n";
    assert!(rules_fired("rust/src/util/par.rs", spawn).is_empty());
    // A plain multiply-add spelled out does not fire.
    let ok = "let y = a * b + c; let z = v.iter().sum::<f32>();\n";
    assert!(rules_fired("rust/src/runtime/native/kernels.rs", ok).is_empty());
}

#[test]
fn r5_exempts_the_fast_tier_but_not_its_neighbors() {
    // PR 10: the opt-in fast math tier is the second sanctioned R5 home
    // — fused arithmetic and the threaded macro-loop are its purpose,
    // under a tolerance (not bit-identity) contract.
    let fma = "let y = a.mul_add(b, c);\n";
    let spawn = "std::thread::spawn(move || work());\n";
    for src in [fma, spawn] {
        assert!(
            rules_fired("rust/src/runtime/native/kernels_fast.rs", src)
                .is_empty(),
            "R5 must not fire in the sanctioned fast tier: {src}"
        );
    }
    // The exemption is path-exact: the bitwise kernels and the model
    // layer next door stay under the ban.
    for rel in [
        "rust/src/runtime/native/kernels.rs",
        "rust/src/runtime/native/model.rs",
        "rust/src/runtime/native/kernels_fast/helper.rs",
    ] {
        assert_eq!(rules_fired(rel, fma), vec![RuleId::R5],
                   "R5 must still fire in {rel}");
    }
}

// ---- R6: narrowing casts in parsing layers ----------------------------

#[test]
fn r6_fires_in_config_and_checkpoint() {
    let src = "let n = x as u32;\n";
    assert_eq!(rules_fired("rust/src/config/fake.rs", src), vec![RuleId::R6]);
    assert_eq!(
        rules_fired("rust/src/coordinator/checkpoint.rs", src),
        vec![RuleId::R6]
    );
}

#[test]
fn r6_negative_widening_and_out_of_scope() {
    // Widening casts are fine even in scope.
    let ok = "let n = x as u64; let f = y as f64;\n";
    assert!(rules_fired("rust/src/config/fake.rs", ok).is_empty());
    // Narrowing casts outside the parsing layers are other rules' turf.
    let src = "let n = x as u32;\n";
    assert!(rules_fired("rust/src/latency/fake.rs", src).is_empty());
}

#[test]
fn r6_is_advisory_unless_deny_all() {
    assert_eq!(severity(RuleId::R6, false), Severity::Warn);
    assert_eq!(severity(RuleId::R6, true), Severity::Deny);
    for rule in [RuleId::R1, RuleId::R2, RuleId::R3, RuleId::R4, RuleId::R5] {
        assert_eq!(severity(rule, false), Severity::Deny, "{rule}");
    }
}

// ---- suppression directives -------------------------------------------

#[test]
fn suppression_same_line_and_preceding_comment() {
    let trailing = "let v = o.unwrap(); \
                    // audit:allow(R1, \"established invariant\")\n";
    let fa = audit_source("rust/src/latency/fake.rs", trailing);
    assert!(fa.findings.is_empty());
    assert_eq!(fa.suppressed, 1);

    let preceding = "// audit:allow(R2, \"never iterated, keyed get/insert only\")\n\
                     use std::collections::HashMap;\n";
    let fa = audit_source("rust/src/optim/fake.rs", preceding);
    assert!(fa.findings.is_empty());
    assert_eq!(fa.suppressed, 1);
}

#[test]
fn suppression_requires_matching_rule_and_reason() {
    // Wrong rule id: the finding survives.
    let wrong = "let v = o.unwrap(); // audit:allow(R3, \"wrong rule\")\n";
    assert_eq!(rules_fired("rust/src/latency/fake.rs", wrong),
               vec![RuleId::R1]);
    // Missing reason: malformed directive, finding survives.
    let bare = "let v = o.unwrap(); // audit:allow(R1)\n";
    assert_eq!(rules_fired("rust/src/latency/fake.rs", bare),
               vec![RuleId::R1]);
    // Directive does not leak past an intervening code line.
    let stale = "// audit:allow(R1, \"one line only\")\nlet a = 1;\n\
                 let v = o.unwrap();\n";
    let fa = audit_source("rust/src/latency/fake.rs", stale);
    assert_eq!(fa.findings.len(), 1);
    assert_eq!(fa.findings[0].line, 3);
}

// ---- R7: module references follow the layering DAG --------------------

#[test]
fn r7_fires_on_back_edge_use() {
    // optim sits below coordinator: the upward `use` is a back-edge.
    let src = "use crate::coordinator::train;\n";
    assert_eq!(rules_fired("rust/src/optim/fake.rs", src), vec![RuleId::R7]);
    // Inline qualified paths are the same edge as a `use`.
    let inline = "fn f() { crate::experiments::sweep::run(); }\n";
    assert_eq!(
        rules_fired("rust/src/scenario/fake.rs", inline),
        vec![RuleId::R7]
    );
    // Grouped imports surface each offending head.
    let group = "use crate::{util, runtime};\n";
    let fa = audit_source("rust/src/timeline/fake.rs", group);
    assert_eq!(fa.findings.len(), 1, "{:?}", fa.findings);
    assert_eq!(fa.findings[0].token, "crate::runtime");
}

#[test]
fn r7_negative_downward_self_and_out_of_scope() {
    // Downward references are the DAG's normal direction.
    let down = "use crate::util::rng::Rng;\nuse crate::channel::Deployment;\n\
                use crate::Error;\n";
    assert!(rules_fired("rust/src/coordinator/fake.rs", down).is_empty());
    // Self-module references are always fine.
    let own = "use crate::optim::bcd;\n";
    assert!(rules_fired("rust/src/optim/fake.rs", own).is_empty());
    // lib.rs (module root) and non-src trees are out of scope.
    let up = "use crate::experiments::sweep;\n";
    assert!(rules_fired("rust/src/lib.rs", up).is_empty());
    assert!(rules_fired("rust/tests/fake.rs", up).is_empty());
}

#[test]
fn r7_applies_inside_test_modules() {
    // A test-only back-edge still couples the layers at build time —
    // the exact shape that used to live in scenario::run's tests.
    let src = "#[cfg(test)]\nmod tests {\n use crate::experiments::sweep;\n}\n";
    assert_eq!(
        rules_fired("rust/src/scenario/fake.rs", src),
        vec![RuleId::R7]
    );
}

// ---- R8: fork tags are unique registered named streams ----------------

#[test]
fn r8_fires_on_raw_literal_fork_tag() {
    let src = "let base = rng.fork(0xFEA7);\n";
    assert_eq!(rules_fired("rust/src/scenario/fake.rs", src), vec![RuleId::R8]);
}

#[test]
fn r8_fires_on_unregistered_named_tag() {
    let reg = fixture_registry();
    let src = "let base = rng.fork(streams::NOT_A_STREAM);\n";
    let fa = audit_source_with("rust/src/scenario/fake.rs", src, Some(&reg));
    assert_eq!(fa.findings.len(), 1);
    assert_eq!(fa.findings[0].rule, RuleId::R8);
}

#[test]
fn r8_fires_on_registered_value_as_raw_literal() {
    // The PR 8 bug class: a registered tag value smuggled back in as a
    // raw literal (`sub(0xC42B)`-style) collides with the named stream.
    let reg = fixture_registry();
    let src = "let x = sub(0x1A2B);\n";
    let fa = audit_source_with("rust/src/optim/fake.rs", src, Some(&reg));
    assert_eq!(fa.findings.len(), 1, "{:?}", fa.findings);
    assert_eq!(fa.findings[0].rule, RuleId::R8);
    assert!(fa.findings[0].token.contains("FIG_SEED"));
}

#[test]
fn r8_fires_on_duplicate_registry_values() {
    // Auditing the registry file itself re-parses it from the text:
    // two constants sharing a value is the duplicate-tag collision R8
    // exists to deny.
    let dup = "pub mod streams {\n\
               pub const A_TAG: u64 = 0x9999;\n\
               pub const B_TAG: u64 = 0x9999;\n\
               pub const ALL: [u64; 2] = [A_TAG, B_TAG];\n\
               }\n";
    let fa = audit_source("rust/src/util/rng.rs", dup);
    assert!(
        fa.findings
            .iter()
            .any(|f| f.rule == RuleId::R8 && f.token.contains("duplicates")),
        "{:?}",
        fa.findings
    );
}

#[test]
fn r8_negative_threaded_tag_and_test_code() {
    let reg = fixture_registry();
    // A lowercase binding threads a tag chosen (and checked) upstream.
    let threaded = "let sub = |tag: u64| base.fork(tag);\n";
    let fa =
        audit_source_with("rust/src/scenario/fake.rs", threaded, Some(&reg));
    assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    // Test code may fork ad-hoc literals (fixtures need local streams).
    let test_src =
        "#[cfg(test)]\nmod tests {\n fn f() { rng.fork(0x9ABC); }\n}\n";
    let fa =
        audit_source_with("rust/src/scenario/fake.rs", test_src, Some(&reg));
    assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    // Unregistered large literals outside fork positions are fine.
    let plain = "let batch = 4096;\n";
    let fa = audit_source_with("rust/src/optim/fake.rs", plain, Some(&reg));
    assert!(fa.findings.is_empty(), "{:?}", fa.findings);
}

// ---- R9: stale suppressions are findings ------------------------------

#[test]
fn r9_fires_on_stale_allow() {
    // The unwrap was fixed but the directive stayed behind.
    let src = "let v = o.unwrap_or(0); // audit:allow(R1, \"obsolete\")\n";
    let fa = audit_source("rust/src/latency/fake.rs", src);
    assert_eq!(fa.findings.len(), 1);
    assert_eq!(fa.findings[0].rule, RuleId::R9);
    assert_eq!(fa.findings[0].line, 1);
    assert!(fa.findings[0].token.contains("R1"));
}

#[test]
fn r9_negative_live_allow() {
    let src = "let v = o.unwrap(); // audit:allow(R1, \"bounded above\")\n";
    let fa = audit_source("rust/src/latency/fake.rs", src);
    assert!(fa.findings.is_empty());
    assert_eq!(fa.suppressed, 1);
}

#[test]
fn r9_directive_leaking_past_code_line_goes_stale() {
    // Same fixture as the suppression-scope test, seen from R9's side:
    // the directive that no longer reaches its target is itself flagged.
    let src = "// audit:allow(R1, \"one line only\")\nlet a = 1;\n\
               let v = o.unwrap();\n";
    let fa = audit_source("rust/src/latency/fake.rs", src);
    let r9: Vec<usize> = fa
        .findings
        .iter()
        .filter(|f| f.rule == RuleId::R9)
        .map(|f| f.line)
        .collect();
    assert_eq!(r9, vec![1]);
}

// ---- baseline ratchet semantics ---------------------------------------

#[test]
fn baseline_passes_frozen_findings_and_denies_fresh() {
    let rel = "rust/src/latency/fake.rs";
    let old = audit_source(rel, "pub fn f(x: Option<u8>) -> u8 { x.unwrap() }\n");
    let base = Baseline::from_findings(&old.findings);

    // Unchanged tree: everything baselined, nothing fresh.
    let (baselined, fresh) = base.partition(&old.findings);
    assert_eq!(baselined.len(), 1);
    assert!(fresh.is_empty());

    // Line drift does not un-baseline a finding (key omits the line).
    let drifted = audit_source(
        rel,
        "// a new doc line\npub fn f(x: Option<u8>) -> u8 { x.unwrap() }\n",
    );
    let (baselined, fresh) = base.partition(&drifted.findings);
    assert_eq!(baselined.len(), 1);
    assert!(fresh.is_empty());

    // A second violation of the same rule exceeds the frozen count and
    // is fresh; so is any new rule.
    let grown = audit_source(
        rel,
        "pub fn f(x: Option<u8>) -> u8 { x.unwrap() }\n\
         pub fn g(x: Option<u8>) -> u8 { x.unwrap() }\n\
         use crate::coordinator::train;\n",
    );
    let (baselined, fresh) = base.partition(&grown.findings);
    assert_eq!(baselined.len(), 1);
    assert_eq!(fresh.len(), 2, "{:?}", fresh);
    assert!(fresh.iter().any(|f| f.rule == RuleId::R1));
    assert!(fresh.iter().any(|f| f.rule == RuleId::R7));
}

#[test]
fn baseline_serialization_roundtrip() {
    let rel = "rust/src/optim/fake.rs";
    let fa = audit_source(
        rel,
        "use std::collections::HashMap;\nuse crate::runtime::pjrt;\n",
    );
    assert_eq!(fa.findings.len(), 2);
    let base = Baseline::from_findings(&fa.findings);
    let text = base.to_json().to_string_pretty();
    let back = Baseline::parse(&text).expect("baseline reparse failed");
    assert_eq!(back, base);
    let (baselined, fresh) = back.partition(&fa.findings);
    assert_eq!(baselined.len(), 2);
    assert!(fresh.is_empty());
}

// ---- the live tree audits clean (epsl-audit --deny-all contract) ------

#[test]
fn live_tree_audits_clean_under_deny_all() {
    let report = audit_tree(&repo_root()).expect("audit walk failed");
    assert!(
        report.files_scanned > 50,
        "suspiciously few files scanned ({}) — wrong root?",
        report.files_scanned
    );
    let listing: Vec<String> = report
        .findings
        .iter()
        .map(|f| format!("{}:{}: {} [{}] {}",
                         f.path, f.line, f.rule, f.token, f.snippet))
        .collect();
    // Zero findings of ANY severity: `epsl-audit --deny-all` must exit 0.
    // This now includes the semantic rules — no layering back-edges
    // (R7), every fork tag a registered named stream (R8), and zero
    // stale suppressions (R9).
    assert!(
        report.findings.is_empty(),
        "live tree has audit findings:\n{}",
        listing.join("\n")
    );
    assert_eq!(report.stale_suppressions(), 0);
}

#[test]
fn live_stream_registry_parses_and_matches_constants() {
    // The analyzer's view of `util::rng::streams` must agree with the
    // compiled constants — if the parser misreads the registry, R8's
    // checks silently hollow out.
    use epsl::util::rng::streams;
    let path = repo_root().join("rust/src/util/rng.rs");
    let text = std::fs::read_to_string(&path).expect("read rng.rs");
    let reg = StreamRegistry::parse(&text);
    assert_eq!(reg.defs.len(), streams::ALL.len());
    assert_eq!(reg.all_names.len(), streams::ALL.len());
    assert!(reg.duplicate_values().is_empty());
    assert!(reg.low_values().is_empty());
    assert!(reg.mirror_mismatch().is_empty(), "{:?}", reg.mirror_mismatch());
    for (def, value) in reg.defs.iter().zip(streams::ALL) {
        assert_eq!(def.value, value, "parsed {} drifted", def.name);
    }
    assert!(reg.contains("SCENARIO_DYNAMICS"));
    assert!(reg.contains("FAULT_PLAN"));
}

// ---- serial-vs-threaded parity over the swept coordinator paths -------

#[test]
fn training_parity_serial_vs_threaded_after_sweep() {
    // The HashMap→BTreeMap swap (session mask_cache, driver) and the
    // error-handling sweep must leave training bit-identical across
    // thread counts: EPSL φ=0.5 exercises the mask cache, evaluation,
    // and the λ-aggregation path end to end.
    use epsl::config::Config;
    use epsl::coordinator::{train, TrainerOptions};
    use epsl::latency::frameworks::Framework;
    use epsl::runtime::native::{self, NativeBackend};

    let cfg = Config::new();
    let m = native::manifest();
    let opts = TrainerOptions {
        framework: Framework::Epsl { phi: 0.5 },
        n_clients: 3,
        rounds: 6,
        eval_every: 3,
        dataset_size: 480,
        test_size: 256,
        eta_c: 0.1,
        eta_s: 0.1,
        seed: 2024,
        ..Default::default()
    };
    let a = train(&NativeBackend::with_threads(1), &m, &cfg, &opts)
        .expect("serial run failed");
    let b = train(&NativeBackend::with_threads(8), &m, &cfg, &opts)
        .expect("threaded run failed");
    assert_eq!(a.rounds.len(), b.rounds.len());
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(
            ra.loss.to_bits(),
            rb.loss.to_bits(),
            "round {} loss diverged across thread counts",
            ra.round
        );
        assert_eq!(ra.test_acc.map(f64::to_bits),
                   rb.test_acc.map(f64::to_bits));
        assert_eq!(ra.sim_latency.to_bits(), rb.sim_latency.to_bits());
    }
}

// ---- regression: bugs surfaced by the first sweep ---------------------

#[test]
fn regression_toml_as_usize_rejects_non_integers() {
    use epsl::config::toml::Value;
    assert_eq!(Value::Num(2.0).as_usize(), Some(2));
    assert_eq!(Value::Num(0.0).as_usize(), Some(0));
    // Fractional counts used to silently truncate (rounds = 2.7 → 2).
    assert_eq!(Value::Num(2.7).as_usize(), None);
    assert_eq!(Value::Num(-1.0).as_usize(), None);
    // Past 2^53 the f64 has already lost integer precision.
    assert_eq!(Value::Num(1e16).as_usize(), None);
    assert_eq!(Value::Str("3".into()).as_usize(), None);
}

#[test]
fn regression_json_as_usize_rejects_non_integers() {
    use epsl::util::json::Json;
    assert_eq!(Json::Num(64.0).as_usize(), Some(64));
    assert_eq!(Json::Num(64.5).as_usize(), None);
    assert_eq!(Json::Num(-2.0).as_usize(), None);
}

#[test]
fn regression_init_seed_uses_all_64_bits() {
    // The init literal used to pass [0, seed as u32]: seeds differing
    // only in the high 32 bits collapsed to identical model inits.
    use epsl::config::Config;
    use epsl::coordinator::{train, TrainerOptions};
    use epsl::latency::frameworks::Framework;
    use epsl::runtime::native::{self, NativeBackend};

    let cfg = Config::new();
    let m = native::manifest();
    let rt = NativeBackend::with_threads(1);
    let mk = |seed: u64| TrainerOptions {
        framework: Framework::Psl,
        n_clients: 2,
        rounds: 1,
        eval_every: 1,
        dataset_size: 320,
        test_size: 256,
        eta_c: 0.1,
        eta_s: 0.1,
        seed,
        ..Default::default()
    };
    let lo = train(&rt, &m, &cfg, &mk(7)).expect("seed=7 run failed");
    let hi = train(&rt, &m, &cfg, &mk(7 + (1u64 << 32)))
        .expect("seed=7+2^32 run failed");
    assert_ne!(
        lo.rounds[0].loss.to_bits(),
        hi.rounds[0].loss.to_bits(),
        "seeds differing only in the high 32 bits must not collide"
    );
}
