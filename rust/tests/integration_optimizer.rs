//! Cross-module integration for the resource-management stack:
//! BCD vs baselines, paper-shape claims of Figs. 11–12, feasibility under
//! stress, and solver cross-validation.

use epsl::channel::rate::Allocation;
use epsl::channel::{ChannelRealization, Deployment};
use epsl::config::{dbm_to_w, NetworkConfig};
use epsl::optim::baselines::{self, Scheme};
use epsl::optim::eval::Evaluator;
use epsl::optim::{bcd, cutlayer, greedy, power, Decision, Problem};
use epsl::profile::resnet18;
use epsl::util::prop::check;
use epsl::util::rng::Rng;
use epsl::util::stats::mean;

fn avg_scheme_latency(cfg: &NetworkConfig, scheme: Scheme, seeds: u64)
    -> f64 {
    let profile = resnet18::profile();
    let mut vals = Vec::new();
    for s in 0..seeds {
        let mut rng = Rng::new(100 + s);
        let dep = Deployment::generate(cfg, &mut rng);
        let ch = ChannelRealization::average(&dep);
        let prob = Problem {
            cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let mut srng = Rng::new(1000 + s);
        if let Ok(d) = baselines::solve(&prob, scheme, &mut srng) {
            vals.push(prob.objective(&d));
        }
    }
    mean(&vals)
}

#[test]
fn fig11_shape_proposed_best_baseline_a_worst() {
    let cfg = NetworkConfig::default();
    let a = avg_scheme_latency(&cfg, Scheme::BaselineA, 6);
    let b = avg_scheme_latency(&cfg, Scheme::BaselineB, 6);
    let c = avg_scheme_latency(&cfg, Scheme::BaselineC, 6);
    let d = avg_scheme_latency(&cfg, Scheme::BaselineD, 6);
    let p = avg_scheme_latency(&cfg, Scheme::Proposed, 6);
    assert!(p <= d * 1.01, "proposed {p} !<= d {d}");
    assert!(d < b, "cut-opt d {d} !< random-cut b {b}");
    assert!(c < a, "cut-opt c {c} !< random-cut a {a}");
    assert!(p < a * 0.8, "proposed {p} not well below baseline a {a}");
}

#[test]
fn fig12_gap_vs_server_compute() {
    // Paper Fig. 12: "with a more powerful server, the performance
    // improvements brought by power control and subchannel allocation
    // grow" — when the server stops being the bottleneck, the round is
    // comm-dominated and the power-control margin (baseline d = uniform
    // power vs proposed) widens.
    let mut ratios = Vec::new();
    for ghz in [1.0, 9.0] {
        let mut cfg = NetworkConfig::default();
        cfg.f_server = ghz * 1e9;
        let d = avg_scheme_latency(&cfg, Scheme::BaselineD, 6);
        let p = avg_scheme_latency(&cfg, Scheme::Proposed, 6);
        ratios.push(d / p);
    }
    assert!(
        ratios[1] >= ratios[0] * 0.999,
        "power-control gain should grow with server compute: {ratios:?}"
    );
}

#[test]
fn bcd_follows_bandwidth_trend() {
    let mut last = f64::INFINITY;
    for mhz in [100.0, 200.0, 300.0] {
        let cfg =
            NetworkConfig::default().with_total_bandwidth(mhz * 1e6);
        let t = avg_scheme_latency(&cfg, Scheme::Proposed, 4);
        assert!(t <= last * 1.02, "latency rose with bandwidth: {t} @ {mhz}");
        last = t;
    }
}

#[test]
fn stress_feasibility_tight_power_budget() {
    // Slash the power budgets; every scheme must stay feasible.
    let mut cfg = NetworkConfig::default();
    cfg.p_max_dbm = 15.0; // ~32 mW per device
    cfg.p_th_dbm = 18.0;
    let profile = resnet18::profile();
    let mut rng = Rng::new(3);
    let dep = Deployment::generate(&cfg, &mut rng);
    let ch = ChannelRealization::average(&dep);
    let prob = Problem {
        cfg: &cfg,
        profile: &profile,
        dep: &dep,
        ch: &ch,
        batch: 64,
        phi: 0.5,
    };
    for scheme in Scheme::all() {
        let mut srng = Rng::new(5);
        let d = baselines::solve(&prob, scheme, &mut srng)
            .unwrap_or_else(|e| panic!("{}: {e}", scheme.name()));
        prob.check_feasible(&d)
            .unwrap_or_else(|e| panic!("{}: {e}", scheme.name()));
        // C6 must really bind below the threshold.
        assert!(prob.total_power_w(&d) <= dbm_to_w(cfg.p_th_dbm) * 1.01);
    }
}

#[test]
fn power_then_cut_consistency() {
    // After BCD converges, neither P2 nor P3 alone can improve by > tol:
    // a genuine block-coordinate fixed point.
    let cfg = NetworkConfig::default();
    let profile = resnet18::profile();
    let mut rng = Rng::new(17);
    let dep = Deployment::generate(&cfg, &mut rng);
    let ch = ChannelRealization::average(&dep);
    let prob = Problem {
        cfg: &cfg,
        profile: &profile,
        dep: &dep,
        ch: &ch,
        batch: 64,
        phi: 0.5,
    };
    let res = bcd::solve(&prob, bcd::BcdOptions::default()).unwrap();
    let d = res.decision;
    // P3 can't improve:
    let (best_cut, _) = cutlayer::solve(&prob, &d.alloc, &d.psd_dbm_hz).unwrap();
    let mut d_cut = d.clone();
    d_cut.cut = best_cut.into();
    assert!(prob.objective(&d_cut) >= res.objective - 1e-6);
    // P2 can't improve:
    if let Ok(sol) = power::solve(&prob, &d.alloc, d.uniform_cut().unwrap())
    {
        let mut d_pow = d.clone();
        d_pow.psd_dbm_hz = sol.psd_dbm_hz;
        assert!(prob.objective(&d_pow) >= res.objective - 1e-6);
    }
}

#[test]
fn property_evaluator_matches_reference_objective_cross_module() {
    // Cross-module statement of the fast-path contract: the evaluator's
    // objective tracks `Problem::objective` to ≤ 1e-9 relative error for
    // random deployments, allocations, PSDs, cuts and φ ∈ {0, ½, 1}.
    check("evaluator == reference (integration)", 25, |g| {
        let mut cfg = NetworkConfig::default();
        cfg.n_clients = g.usize_in(1, 7);
        cfg.n_subchannels = cfg.n_clients + g.usize_in(0, 12);
        cfg.f_server = g.f64_in(1e9, 9e9);
        let profile = resnet18::profile();
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let dep = Deployment::generate(&cfg, &mut rng);
        let ch = ChannelRealization::average(&dep);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: g.usize_in(1, 128),
            phi: *g.choose(&[0.0, 0.5, 1.0]),
        };
        let mut ev = Evaluator::new(&prob);
        let mut alloc = Allocation::empty(cfg.n_subchannels);
        for k in 0..cfg.n_subchannels {
            alloc.assign(k, g.usize_in(0, cfg.n_clients - 1));
        }
        let psd: Vec<f64> = (0..cfg.n_subchannels)
            .map(|_| g.f64_in(-78.0, -55.0))
            .collect();
        let cut = *g.choose(&profile.cut_candidates);
        let d = Decision { alloc, psd_dbm_hz: psd, cut: cut.into() };
        let reference = prob.objective(&d);
        let fast = ev.objective(&d);
        assert!(
            (fast - reference).abs() <= 1e-9 * reference.abs().max(1e-12),
            "fast {fast} vs reference {reference}"
        );
    });
}

#[test]
fn property_fast_bcd_equals_reference_bcd() {
    // The optimizer rewiring must not change any decision: the fast BCD
    // and the pre-fast-path pipeline agree bit-for-bit on the objective
    // and land on the same (r, p, μ).
    check("fast BCD == reference BCD", 6, |g| {
        let mut cfg = NetworkConfig::default();
        cfg.n_clients = g.usize_in(2, 5);
        cfg.n_subchannels = cfg.n_clients + g.usize_in(1, 10);
        cfg.f_server = g.f64_in(1e9, 9e9);
        let profile = resnet18::profile();
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let dep = Deployment::generate(&cfg, &mut rng);
        let ch = ChannelRealization::average(&dep);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: *g.choose(&[0.0, 0.5, 1.0]),
        };
        let opts = bcd::BcdOptions { max_iters: 6, tol: 1e-6 };
        let fast = bcd::solve(&prob, opts).unwrap();
        let reference = bcd::solve_reference(&prob, opts).unwrap();
        assert_eq!(fast.decision, reference.decision);
        assert_eq!(fast.objective.to_bits(), reference.objective.to_bits());
    });
}

#[test]
fn property_greedy_power_pipeline_feasible() {
    check("greedy→power pipeline", 12, |g| {
        let mut cfg = NetworkConfig::default();
        cfg.n_clients = g.usize_in(2, 8);
        cfg.n_subchannels = cfg.n_clients + g.usize_in(0, 14);
        cfg.f_server = g.f64_in(1e9, 9e9);
        let profile = resnet18::profile();
        let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
        let dep = Deployment::generate(&cfg, &mut rng);
        let ch = ChannelRealization::average(&dep);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: g.f64_in(0.0, 1.0),
        };
        let cut = g.usize_in(1, 17);
        let alloc = greedy::allocate(&prob, &vec![-65.0; cfg.n_subchannels], cut);
        let sol = power::solve(&prob, &alloc, cut).unwrap();
        let d = epsl::optim::Decision {
            alloc,
            psd_dbm_hz: sol.psd_dbm_hz,
            cut: cut.into(),
        };
        prob.check_feasible(&d).unwrap();
        assert!(prob.objective(&d).is_finite());
    });
}
