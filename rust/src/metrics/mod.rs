//! Training metrics: per-round records, curves, CSV emission.

use std::fmt::Write as _;

use crate::timeline::StageSpans;
use crate::util::stats;

/// Recovery accounting for one round: what was injected and what the
/// coordinator paid to absorb it. All-zero (the [`Default`]) for a
/// fault-free round.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultStats {
    /// Fault events injected this round (crashes + delays + corruptions
    /// + server aborts).
    pub injected: usize,
    /// Retries performed for transient faults (corrupt payload, server
    /// abort).
    pub retries: usize,
    /// Clients dropped from the round (crashes + straggler-deadline
    /// evictions + retry-exhausted corruptions).
    pub dropped: usize,
    /// Clients the round actually committed with.
    pub cohort: usize,
    /// Recovery latency (seconds) added on top of the nominal timeline:
    /// retry backoff, repeated server work, in-deadline straggler
    /// overshoot.
    pub recovery_s: f64,
}

/// One training round's record.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    /// Global weighted training loss (eq. 1).
    pub loss: f64,
    /// Training mini-batch accuracy over C·b samples.
    pub train_acc: f64,
    /// Test accuracy (`None` when the round was not evaluated — emitted
    /// as an empty CSV cell, never a NaN sentinel).
    pub test_acc: Option<f64>,
    /// Simulated per-round latency from the timeline engine (seconds).
    pub sim_latency: f64,
    /// Per-stage breakdown of `sim_latency` (uplink phase, server FP/BP,
    /// broadcast, downlink phase, model exchange).
    pub stages: StageSpans,
    /// Injected-fault / recovery accounting (all zero when quiet).
    pub faults: FaultStats,
    /// Wall-clock milliseconds actually spent executing the round.
    pub wall_ms: f64,
    /// Cut-layer label for the round: a single SplitNet cut (`"2"`) or a
    /// `'-'`-joined per-client vector (`"1-2-2-3"`) under mixed-cut
    /// training. CSV-safe (no commas).
    pub cut: String,
}

/// A full training run's record.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub name: String,
    pub rounds: Vec<RoundRecord>,
}

impl RunMetrics {
    pub fn new(name: &str) -> Self {
        RunMetrics { name: name.to_string(), rounds: Vec::new() }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    /// Cumulative simulated latency up to and including round `idx`.
    pub fn cumulative_latency(&self, idx: usize) -> f64 {
        self.rounds[..=idx].iter().map(|r| r.sim_latency).sum()
    }

    pub fn total_latency(&self) -> f64 {
        self.rounds.iter().map(|r| r.sim_latency).sum()
    }

    /// Test-accuracy curve as (round, acc) over evaluated rounds.
    pub fn accuracy_curve(&self) -> Vec<(f64, f64)> {
        self.rounds
            .iter()
            .filter_map(|r| r.test_acc.map(|a| (r.round as f64, a)))
            .collect()
    }

    /// Loss curve as (round, loss).
    pub fn loss_curve(&self) -> Vec<(f64, f64)> {
        self.rounds.iter().map(|r| (r.round as f64, r.loss)).collect()
    }

    /// Evaluated (record index, accuracy) pairs in round order.
    fn evaluated(&self) -> Vec<(usize, f64)> {
        self.rounds
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.test_acc.map(|a| (i, a)))
            .collect()
    }

    /// Final test accuracy: mean of the last `k` evaluated points
    /// (the paper's "converged test accuracy"). NaN when the run was
    /// never evaluated.
    pub fn converged_accuracy(&self, k: usize) -> f64 {
        let pts: Vec<f64> =
            self.evaluated().into_iter().map(|(_, a)| a).collect();
        if pts.is_empty() {
            return f64::NAN;
        }
        let tail = &pts[pts.len().saturating_sub(k)..];
        stats::mean(tail)
    }

    /// Simulated latency (seconds) until the EMA-smoothed test accuracy
    /// first reaches `target`; `None` if never reached.
    pub fn latency_to_accuracy(&self, target: f64) -> Option<f64> {
        let evaluated = self.evaluated();
        let series: Vec<f64> = evaluated.iter().map(|(_, a)| *a).collect();
        let hit = stats::rounds_to_target(&series, target, 0.5)?;
        let round_idx = evaluated[hit].0;
        Some(self.cumulative_latency(round_idx))
    }

    /// Rounds until the smoothed test accuracy reaches `target`.
    pub fn rounds_to_accuracy(&self, target: f64) -> Option<usize> {
        let evaluated = self.evaluated();
        let series: Vec<f64> = evaluated.iter().map(|(_, a)| *a).collect();
        let hit = stats::rounds_to_target(&series, target, 0.5)?;
        Some(self.rounds[evaluated[hit].0].round)
    }

    /// CSV dump (one row per round; unevaluated `test_acc` is an empty
    /// cell; the six timeline stage spans follow the total; the five
    /// fault-accounting columns precede wall clock; the cut label is the
    /// last column so earlier column indices stay stable).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,loss,train_acc,test_acc,sim_latency_s,t_uplink_s,\
             t_server_fp_s,t_server_bp_s,t_broadcast_s,t_downlink_s,\
             t_exchange_s,faults_injected,fault_retries,fault_dropped,\
             fault_cohort,recovery_s,wall_ms,cut\n",
        );
        for r in &self.rounds {
            let acc = match r.test_acc {
                Some(a) => format!("{a:.4}"),
                None => String::new(),
            };
            let s = &r.stages;
            let fs = &r.faults;
            let _ = writeln!(
                out,
                "{},{:.6},{:.4},{},{:.6},{:.6},{:.6},{:.6},{:.6},{:.6},\
                 {:.6},{},{},{},{},{:.6},{:.3},{}",
                r.round,
                r.loss,
                r.train_acc,
                acc,
                r.sim_latency,
                s.uplink_phase,
                s.server_fp,
                s.server_bp,
                s.broadcast,
                s.downlink_phase,
                s.model_exchange,
                fs.injected,
                fs.retries,
                fs.dropped,
                fs.cohort,
                fs.recovery_s,
                r.wall_ms,
                r.cut
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(i: usize, acc: Option<f64>) -> RoundRecord {
        RoundRecord {
            round: i,
            loss: 1.0 / (i + 1) as f64,
            train_acc: acc.unwrap_or(0.0),
            test_acc: acc,
            sim_latency: 2.0,
            stages: StageSpans {
                uplink_phase: 0.5,
                server_fp: 0.5,
                server_bp: 0.5,
                broadcast: 0.25,
                downlink_phase: 0.25,
                model_exchange: 0.0,
            },
            faults: FaultStats::default(),
            wall_ms: 1.0,
            cut: "2".into(),
        }
    }

    fn run_with(accs: &[f64]) -> RunMetrics {
        let mut m = RunMetrics::new("test");
        for (i, &a) in accs.iter().enumerate() {
            m.push(record(i, Some(a)));
        }
        m
    }

    #[test]
    fn cumulative_latency_sums() {
        let m = run_with(&[0.1, 0.2, 0.3]);
        assert_eq!(m.cumulative_latency(1), 4.0);
        assert_eq!(m.total_latency(), 6.0);
    }

    #[test]
    fn latency_to_accuracy_crossing() {
        let accs: Vec<f64> = (0..10).map(|i| i as f64 / 10.0).collect();
        let m = run_with(&accs);
        // EMA(0.5) crosses 0.5 a bit after raw would.
        let lat = m.latency_to_accuracy(0.5).unwrap();
        assert!(lat >= 2.0 * 6.0 && lat <= 2.0 * 10.0, "{lat}");
        assert!(m.latency_to_accuracy(0.99).is_none());
    }

    #[test]
    fn converged_accuracy_tail_mean() {
        let m = run_with(&[0.0, 0.0, 0.8, 0.9]);
        assert!((m.converged_accuracy(2) - 0.85).abs() < 1e-12);
        assert!(RunMetrics::new("empty").converged_accuracy(3).is_nan());
    }

    #[test]
    fn unevaluated_rounds_skipped_in_curves() {
        let mut m = run_with(&[0.1]);
        m.push(record(1, None));
        assert_eq!(m.accuracy_curve().len(), 1);
        assert_eq!(m.loss_curve().len(), 2);
        // Unevaluated rounds do not shift the latency-to-accuracy map.
        m.push(record(2, Some(0.9)));
        assert_eq!(m.rounds_to_accuracy(0.05), Some(0));
    }

    #[test]
    fn csv_shape_and_empty_cells() {
        let mut m = run_with(&[0.1, 0.2]);
        m.push(record(2, None));
        let csv = m.to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("round,"));
        let header_cols = csv.lines().next().unwrap().split(',').count();
        assert_eq!(header_cols, 18);
        for line in csv.lines().skip(1) {
            assert_eq!(line.split(',').count(), header_cols, "{line}");
        }
        // The unevaluated round has an empty test_acc cell, not NaN.
        let last = csv.lines().nth(3).unwrap();
        assert!(last.starts_with("2,"));
        assert!(!last.to_lowercase().contains("nan"), "{last}");
        assert_eq!(last.split(',').nth(3), Some(""));
    }

    #[test]
    fn stage_columns_sum_to_total() {
        let m = run_with(&[0.1]);
        let r = &m.rounds[0];
        assert_eq!(r.stages.total(), r.sim_latency);
    }

    #[test]
    fn fault_columns_in_csv() {
        let mut m = run_with(&[0.1]);
        let mut r = record(1, Some(0.2));
        r.faults = FaultStats {
            injected: 2,
            retries: 1,
            dropped: 1,
            cohort: 3,
            recovery_s: 0.25,
        };
        m.push(r);
        let csv = m.to_csv();
        let header: Vec<&str> =
            csv.lines().next().unwrap().split(',').collect();
        assert_eq!(&header[11..16], &[
            "faults_injected",
            "fault_retries",
            "fault_dropped",
            "fault_cohort",
            "recovery_s"
        ]);
        let row: Vec<&str> = csv.lines().nth(2).unwrap().split(',').collect();
        assert_eq!(&row[11..16], &["2", "1", "1", "3", "0.250000"]);
        // Quiet rounds stay all-zero.
        let quiet: Vec<&str> =
            csv.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(&quiet[11..15], &["0", "0", "0", "0"]);
    }

    #[test]
    fn cut_label_is_the_last_csv_column() {
        let mut m = run_with(&[0.1]);
        let mut r = record(1, Some(0.2));
        r.cut = "1-2-2-3".into();
        m.push(r);
        let csv = m.to_csv();
        let header: Vec<&str> =
            csv.lines().next().unwrap().split(',').collect();
        assert_eq!(header.last(), Some(&"cut"));
        assert_eq!(header[16], "wall_ms");
        let uniform: Vec<&str> =
            csv.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(uniform.last(), Some(&"2"));
        let mixed: Vec<&str> =
            csv.lines().nth(2).unwrap().split(',').collect();
        assert_eq!(mixed.last(), Some(&"1-2-2-3"));
        assert_eq!(mixed.len(), header.len());
    }
}
