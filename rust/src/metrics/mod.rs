//! Training metrics: per-round records, curves, CSV emission.

use std::fmt::Write as _;

use crate::util::stats;

/// One training round's record.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    /// Global weighted training loss (eq. 1).
    pub loss: f64,
    /// Training mini-batch accuracy over C·b samples.
    pub train_acc: f64,
    /// Test accuracy (NaN when not evaluated this round).
    pub test_acc: f64,
    /// Simulated per-round latency from the §V model (seconds).
    pub sim_latency: f64,
    /// Wall-clock milliseconds actually spent executing the round.
    pub wall_ms: f64,
}

/// A full training run's record.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub name: String,
    pub rounds: Vec<RoundRecord>,
}

impl RunMetrics {
    pub fn new(name: &str) -> Self {
        RunMetrics { name: name.to_string(), rounds: Vec::new() }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    /// Cumulative simulated latency up to and including round `idx`.
    pub fn cumulative_latency(&self, idx: usize) -> f64 {
        self.rounds[..=idx].iter().map(|r| r.sim_latency).sum()
    }

    pub fn total_latency(&self) -> f64 {
        self.rounds.iter().map(|r| r.sim_latency).sum()
    }

    /// Test-accuracy curve as (round, acc) over evaluated rounds.
    pub fn accuracy_curve(&self) -> Vec<(f64, f64)> {
        self.rounds
            .iter()
            .filter(|r| !r.test_acc.is_nan())
            .map(|r| (r.round as f64, r.test_acc))
            .collect()
    }

    /// Loss curve as (round, loss).
    pub fn loss_curve(&self) -> Vec<(f64, f64)> {
        self.rounds.iter().map(|r| (r.round as f64, r.loss)).collect()
    }

    /// Final test accuracy: mean of the last `k` evaluated points
    /// (the paper's "converged test accuracy").
    pub fn converged_accuracy(&self, k: usize) -> f64 {
        let pts: Vec<f64> = self
            .rounds
            .iter()
            .filter(|r| !r.test_acc.is_nan())
            .map(|r| r.test_acc)
            .collect();
        if pts.is_empty() {
            return f64::NAN;
        }
        let tail = &pts[pts.len().saturating_sub(k)..];
        stats::mean(tail)
    }

    /// Simulated latency (seconds) until the EMA-smoothed test accuracy
    /// first reaches `target`; `None` if never reached.
    pub fn latency_to_accuracy(&self, target: f64) -> Option<f64> {
        let evaluated: Vec<(usize, f64)> = self
            .rounds
            .iter()
            .enumerate()
            .filter(|(_, r)| !r.test_acc.is_nan())
            .map(|(i, r)| (i, r.test_acc))
            .collect();
        let series: Vec<f64> = evaluated.iter().map(|(_, a)| *a).collect();
        let hit = stats::rounds_to_target(&series, target, 0.5)?;
        let round_idx = evaluated[hit].0;
        Some(self.cumulative_latency(round_idx))
    }

    /// Rounds until the smoothed test accuracy reaches `target`.
    pub fn rounds_to_accuracy(&self, target: f64) -> Option<usize> {
        let evaluated: Vec<(usize, f64)> = self
            .rounds
            .iter()
            .enumerate()
            .filter(|(_, r)| !r.test_acc.is_nan())
            .map(|(i, r)| (i, r.test_acc))
            .collect();
        let series: Vec<f64> = evaluated.iter().map(|(_, a)| *a).collect();
        let hit = stats::rounds_to_target(&series, target, 0.5)?;
        Some(self.rounds[evaluated[hit].0].round)
    }

    /// CSV dump (one row per round).
    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("round,loss,train_acc,test_acc,sim_latency_s,wall_ms\n");
        for r in &self.rounds {
            let _ = writeln!(
                out,
                "{},{:.6},{:.4},{:.4},{:.6},{:.3}",
                r.round, r.loss, r.train_acc, r.test_acc, r.sim_latency,
                r.wall_ms
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_with(accs: &[f64]) -> RunMetrics {
        let mut m = RunMetrics::new("test");
        for (i, &a) in accs.iter().enumerate() {
            m.push(RoundRecord {
                round: i,
                loss: 1.0 / (i + 1) as f64,
                train_acc: a,
                test_acc: a,
                sim_latency: 2.0,
                wall_ms: 1.0,
            });
        }
        m
    }

    #[test]
    fn cumulative_latency_sums() {
        let m = run_with(&[0.1, 0.2, 0.3]);
        assert_eq!(m.cumulative_latency(1), 4.0);
        assert_eq!(m.total_latency(), 6.0);
    }

    #[test]
    fn latency_to_accuracy_crossing() {
        let accs: Vec<f64> = (0..10).map(|i| i as f64 / 10.0).collect();
        let m = run_with(&accs);
        // EMA(0.5) crosses 0.5 a bit after raw would.
        let lat = m.latency_to_accuracy(0.5).unwrap();
        assert!(lat >= 2.0 * 6.0 && lat <= 2.0 * 10.0, "{lat}");
        assert!(m.latency_to_accuracy(0.99).is_none());
    }

    #[test]
    fn converged_accuracy_tail_mean() {
        let m = run_with(&[0.0, 0.0, 0.8, 0.9]);
        assert!((m.converged_accuracy(2) - 0.85).abs() < 1e-12);
    }

    #[test]
    fn nan_test_acc_skipped_in_curves() {
        let mut m = run_with(&[0.1]);
        m.push(RoundRecord {
            round: 1,
            loss: 0.5,
            train_acc: 0.5,
            test_acc: f64::NAN,
            sim_latency: 1.0,
            wall_ms: 1.0,
        });
        assert_eq!(m.accuracy_curve().len(), 1);
        assert_eq!(m.loss_curve().len(), 2);
    }

    #[test]
    fn csv_shape() {
        let m = run_with(&[0.1, 0.2]);
        let csv = m.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("round,"));
    }
}
