//! The paper's exact ResNet-18 profile — Table IV, reproduced row-for-row.
//!
//! Input images are resized to 64×64 (paper §VII-A). The table lists layer
//! parameter size (MB), forward FLOPs (MFLOP) and smashed-data size (MB)
//! per sample. The forward order follows Fig. 6: CONV1 → MAXPOOL →
//! stage-1 (CONV2,3) → stage-2 (CONV4,5,6 twice — the table repeats those
//! rows for the two residual blocks) → stage-3 (CONV7,8,9) → stage-4
//! (CONV10,11,12) → AVGPOOL → FC.
//!
//! These numbers feed every latency/optimizer experiment; they are the
//! paper's own, not re-derived (Table IV has quirks — e.g. identical rows
//! for the two stage-2 blocks — which we reproduce rather than "fix" so the
//! latency results match the paper's model). [`flops`] cross-checks the
//! orders of magnitude.

use std::sync::OnceLock;

use super::{Layer, LayerKind, NetworkProfile};

/// Rows exactly as printed in Table IV, in forward order.
/// (name, kind, params MB, FP MFLOPs, smashed MB)
const ROWS: &[(&str, LayerKind, f64, f64, f64)] = &[
    ("CONV1", LayerKind::Conv, 0.0364, 9.8304, 0.25),
    ("MAXPOOL", LayerKind::Pool, 0.0, 0.0655, 0.0625),
    ("CONV2", LayerKind::Conv, 0.1411, 9.5027, 0.0625),
    ("CONV3", LayerKind::Conv, 0.1414, 9.4863, 0.0625),
    ("CONV4", LayerKind::Conv, 0.2827, 4.7432, 0.0313),
    ("CONV5", LayerKind::Conv, 0.564, 9.4618, 0.0313),
    ("CONV6", LayerKind::Conv, 0.0327, 0.5489, 0.0313),
    ("CONV4b", LayerKind::Conv, 0.2827, 4.7432, 0.0313),
    ("CONV5b", LayerKind::Conv, 0.564, 9.4618, 0.0313),
    ("CONV6b", LayerKind::Conv, 0.0327, 0.5489, 0.0313),
    ("CONV7", LayerKind::Conv, 1.1279, 4.7309, 0.0156),
    ("CONV8", LayerKind::Conv, 2.2529, 9.4495, 0.0156),
    ("CONV9", LayerKind::Conv, 0.1279, 0.5366, 0.0156),
    ("CONV10", LayerKind::Conv, 4.5059, 4.7247, 0.0078),
    ("CONV11", LayerKind::Conv, 9.0059, 9.4433, 0.0078),
    ("CONV12", LayerKind::Conv, 0.5059, 0.5304, 0.0078),
    ("AVGPOOL", LayerKind::Pool, 0.0, 0.001, 0.0020),
    ("FC", LayerKind::Fc, 0.0137, 0.0036, 2.67e-5),
];

static PROFILE: OnceLock<NetworkProfile> = OnceLock::new();

fn build() -> NetworkProfile {
    let layers: Vec<Layer> = ROWS
        .iter()
        .map(|&(name, kind, params_mib, fp_mflops, smashed_mib)| Layer {
            name,
            kind,
            params_mib,
            fp_mflops,
            smashed_mib,
        })
        .collect();
    // Fig. 6: a cut may be placed after any layer except the final FC
    // (the server keeps at least the output layer; labels go to the server).
    let cut_candidates = (1..layers.len()).collect();
    NetworkProfile { name: "resnet18-64", layers, cut_candidates }
}

/// The cached ResNet-18 profile from Table IV — the zero-copy accessor for
/// hot paths (the §V latency model evaluates it on every simulated round).
pub fn profile_static() -> &'static NetworkProfile {
    PROFILE.get_or_init(build)
}

/// Owned copy of the ResNet-18 profile (cached build, cloned per call).
pub fn profile() -> NetworkProfile {
    profile_static().clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::flops;

    #[test]
    fn row_count_matches_table() {
        let p = profile();
        assert_eq!(p.n_layers(), 18);
        assert_eq!(p.cut_candidates.len(), 17);
    }

    #[test]
    fn static_profile_is_cached_and_identical() {
        let a = profile_static();
        let b = profile_static();
        assert!(std::ptr::eq(a, b), "OnceLock must hand out one instance");
        assert_eq!(a.n_layers(), profile().n_layers());
        assert_eq!(a.rho_total(), profile().rho_total());
    }

    #[test]
    fn totals_are_plausible_resnet18_at_64() {
        let p = profile();
        // ~88 MFLOPs FP/sample at 64x64 (ResNet-18 at 224x224 is ~1.8G;
        // (64/224)^2 scaling ≈ 0.082 -> ~150M; the paper's table sums lower
        // because stage repeats are collapsed). Sanity: order of magnitude.
        let total_mflops = p.rho_total() / 1e6;
        assert!(
            (50.0..200.0).contains(&total_mflops),
            "total FP = {total_mflops} MFLOPs"
        );
        // Model size ~ 19.6 MB per the table rows (paper quotes ResNet-18 at
        // ~44MB full; Table IV lists one conv per repeated pair).
        let mb = p.model_bits() / (8.0 * 1024.0 * 1024.0);
        assert!((15.0..25.0).contains(&mb), "model = {mb} MiB");
    }

    #[test]
    fn conv1_flops_cross_check() {
        // CONV1: 7x7, 3->64, stride 2 on 64x64 input -> 32x32 output.
        // MACs = 49*3*64*32*32 ≈ 9.6M, paper lists 9.8304 MFLOP.
        let macs = flops::conv2d_macs(64, 64, 3, 64, 7, 2);
        let paper = 9.8304e6;
        let ratio = macs / paper;
        assert!(
            (0.8..1.25).contains(&ratio),
            "conv1 macs={macs:.3e} vs paper {paper:.3e}"
        );
    }

    #[test]
    fn smashed_monotone_nonincreasing_after_stage1() {
        let p = profile();
        // Downsampling stages shrink activations: cut deeper => smaller
        // uplink payload (the paper's core cut-layer trade-off).
        assert!(p.psi_bits(1) > p.psi_bits(5));
        assert!(p.psi_bits(5) > p.psi_bits(11));
        assert!(p.psi_bits(11) > p.psi_bits(14));
        assert!(p.psi_bits(17) > p.psi_bits(p.n_layers() - 1) * 0.9);
    }

    #[test]
    fn conv1_smashed_is_quarter_mib() {
        let p = profile();
        // 32*32*64 f32 = 256 KiB = 0.25 MiB.
        assert!((p.psi_bits(1) - 0.25 * 8.0 * 1024.0 * 1024.0).abs() < 1.0);
    }

    #[test]
    fn client_model_grows_with_cut() {
        let p = profile();
        let mut prev = 0.0;
        for j in 1..p.n_layers() {
            let u = p.client_model_bits(j);
            assert!(u >= prev);
            prev = u;
        }
    }
}
