//! Neural-network layer profiles: the quantities the paper's latency model
//! consumes.
//!
//! For a network of L layers and a cut at layer j (client owns layers 1..j):
//!
//! - `ρ_j`  — FP FLOPs of propagating the first j layers, one sample
//! - `ϖ_j`  — BP FLOPs of the first j layers, one sample
//! - `ψ_j`  — smashed-data bits at cut layer j (uplink payload, eq. 15)
//! - `χ_j`  — activations'-gradient bits at cut layer j (downlink, eq. 19/21)
//! - `u_j`  — client-side model bits (SFL model exchange / vanilla-SL relay)
//!
//! Two profiles ship: the paper's exact **ResNet-18 Table IV**
//! ([`resnet18`]) driving every latency/optimizer experiment, and the
//! trainable **SplitNet** ([`splitnet`]) whose numbers are derived from
//! first principles by [`flops`] and which matches the AOT artifacts the
//! coordinator actually executes.

pub mod flops;
pub mod resnet18;
pub mod splitnet;

/// Layer category (affects BP cost accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Pool,
    Fc,
}

/// One layer's profile entries (paper Table IV row).
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: &'static str,
    pub kind: LayerKind,
    /// Parameter size in MiB (Table IV "Layer size (MB)").
    pub params_mib: f64,
    /// Forward FLOPs for one sample, in MFLOPs (Table IV "FP FLOPs").
    pub fp_mflops: f64,
    /// Output (smashed-data) size in MiB for one sample.
    pub smashed_mib: f64,
}

const MIB_BITS: f64 = 8.0 * 1024.0 * 1024.0;
const MFLOP: f64 = 1e6;

/// BP cost multiplier relative to FP (standard 2x approximation: gradient
/// wrt inputs + gradient wrt weights each cost about one forward pass).
pub const BP_FP_RATIO: f64 = 2.0;

/// A complete network profile.
#[derive(Debug, Clone)]
pub struct NetworkProfile {
    pub name: &'static str,
    pub layers: Vec<Layer>,
    /// Cut-layer candidates (1-based layer indices after which the split may
    /// be placed). The last layer is never a candidate — the server must own
    /// at least the output layer for loss computation / label privacy.
    pub cut_candidates: Vec<usize>,
}

impl NetworkProfile {
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn check_cut(&self, j: usize) {
        debug_assert!(
            j >= 1 && j < self.n_layers(),
            "cut {} out of range 1..{} for {}",
            j,
            self.n_layers(),
            self.name
        );
    }

    /// ρ_j: cumulative FP FLOPs of layers 1..=j (one sample).
    pub fn rho(&self, j: usize) -> f64 {
        self.layers[..j].iter().map(|l| l.fp_mflops * MFLOP).sum()
    }

    /// Total FP FLOPs ρ_L.
    pub fn rho_total(&self) -> f64 {
        self.rho(self.n_layers())
    }

    /// ϖ_j: cumulative BP FLOPs of layers 1..=j (one sample).
    pub fn varpi(&self, j: usize) -> f64 {
        self.layers[..j]
            .iter()
            .map(|l| l.fp_mflops * MFLOP * BP_FP_RATIO)
            .sum()
    }

    /// Total BP FLOPs ϖ_L.
    pub fn varpi_total(&self) -> f64 {
        self.varpi(self.n_layers())
    }

    /// ψ_j: smashed-data bits at cut j (one sample).
    pub fn psi_bits(&self, j: usize) -> f64 {
        self.check_cut(j);
        self.layers[j - 1].smashed_mib * MIB_BITS
    }

    /// χ_j: activations'-gradient bits at cut j (one sample). Gradients have
    /// the same dimensionality as activations.
    pub fn chi_bits(&self, j: usize) -> f64 {
        self.psi_bits(j)
    }

    /// u_j: client-side model bits with the cut at j (layers 1..=j).
    pub fn client_model_bits(&self, j: usize) -> f64 {
        self.check_cut(j);
        self.layers[..j].iter().map(|l| l.params_mib * MIB_BITS).sum()
    }

    /// Full-model bits.
    pub fn model_bits(&self) -> f64 {
        self.layers.iter().map(|l| l.params_mib * MIB_BITS).sum()
    }

    /// Client-side FP workload Φ_c^F(j) = ρ_j (FLOPs).
    pub fn client_fp_flops(&self, j: usize) -> f64 {
        self.check_cut(j);
        self.rho(j)
    }

    /// Server-side FP workload Φ_s^F(j) = ρ_L − ρ_j.
    pub fn server_fp_flops(&self, j: usize) -> f64 {
        self.check_cut(j);
        self.rho_total() - self.rho(j)
    }

    /// Server-side BP workload excluding the last layer:
    /// Φ_s^B(j) = ϖ_{L−1} − ϖ_j.
    pub fn server_bp_flops(&self, j: usize) -> f64 {
        self.check_cut(j);
        (self.varpi(self.n_layers() - 1) - self.varpi(j)).max(0.0)
    }

    /// Last-layer BP workload Φ_s^L = ϖ_L − ϖ_{L−1}.
    pub fn last_layer_bp_flops(&self) -> f64 {
        self.varpi_total() - self.varpi(self.n_layers() - 1)
    }

    /// Client-side BP workload Φ_c^B(j) = ϖ_j.
    pub fn client_bp_flops(&self, j: usize) -> f64 {
        self.check_cut(j);
        self.varpi(j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> NetworkProfile {
        NetworkProfile {
            name: "toy",
            layers: vec![
                Layer {
                    name: "l1",
                    kind: LayerKind::Conv,
                    params_mib: 0.5,
                    fp_mflops: 10.0,
                    smashed_mib: 0.25,
                },
                Layer {
                    name: "l2",
                    kind: LayerKind::Conv,
                    params_mib: 1.0,
                    fp_mflops: 20.0,
                    smashed_mib: 0.125,
                },
                Layer {
                    name: "l3",
                    kind: LayerKind::Fc,
                    params_mib: 0.25,
                    fp_mflops: 5.0,
                    smashed_mib: 0.01,
                },
            ],
            cut_candidates: vec![1, 2],
        }
    }

    #[test]
    fn cumulative_rho_varpi() {
        let p = toy();
        assert_eq!(p.rho(1), 10e6);
        assert_eq!(p.rho(2), 30e6);
        assert_eq!(p.rho_total(), 35e6);
        assert_eq!(p.varpi(2), 60e6);
        assert_eq!(p.varpi_total(), 70e6);
    }

    #[test]
    fn split_workloads_sum_to_totals() {
        let p = toy();
        for j in [1usize, 2] {
            assert!(
                (p.client_fp_flops(j) + p.server_fp_flops(j) - p.rho_total())
                    .abs()
                    < 1e-6
            );
            let bp_sum = p.client_bp_flops(j)
                + p.server_bp_flops(j)
                + p.last_layer_bp_flops();
            assert!((bp_sum - p.varpi_total()).abs() < 1e-6);
        }
    }

    #[test]
    fn payload_bits() {
        let p = toy();
        assert_eq!(p.psi_bits(1), 0.25 * 8.0 * 1024.0 * 1024.0);
        assert_eq!(p.chi_bits(2), p.psi_bits(2));
        assert_eq!(p.client_model_bits(2), 1.5 * 8.0 * 1024.0 * 1024.0);
        assert!((p.model_bits() - 1.75 * 8.0 * 1024.0 * 1024.0).abs() < 1.0);
    }

    #[test]
    fn deeper_cut_more_client_work() {
        let p = toy();
        assert!(p.client_fp_flops(2) > p.client_fp_flops(1));
        assert!(p.server_fp_flops(2) < p.server_fp_flops(1));
    }
}
