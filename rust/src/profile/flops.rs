//! First-principles FLOP / payload calculators for CNN layers.
//!
//! Used to derive the SplitNet profile (matching the AOT-exported model
//! exactly) and to cross-check the paper's Table IV orders of magnitude.

/// MACs of a same-padded 2-D convolution.
/// `h, w`: input spatial dims; `cin -> cout`; square kernel `k`, stride `s`.
pub fn conv2d_macs(h: usize, w: usize, cin: usize, cout: usize, k: usize,
                   s: usize) -> f64 {
    let oh = h.div_ceil(s);
    let ow = w.div_ceil(s);
    (k * k * cin * cout * oh * ow) as f64
}

/// FLOPs of the same conv (2 FLOPs per MAC: multiply + add).
pub fn conv2d_flops(h: usize, w: usize, cin: usize, cout: usize, k: usize,
                    s: usize) -> f64 {
    2.0 * conv2d_macs(h, w, cin, cout, k, s)
}

/// FLOPs of a pooling layer over `h×w×c` input with window `k`, stride `s`
/// (one compare/accumulate per element in each window).
pub fn pool_flops(h: usize, w: usize, c: usize, k: usize, s: usize) -> f64 {
    let oh = h.div_ceil(s);
    let ow = w.div_ceil(s);
    (k * k * c * oh * ow) as f64
}

/// FLOPs of a dense layer `cin -> cout` (2 per MAC).
pub fn fc_flops(cin: usize, cout: usize) -> f64 {
    2.0 * (cin * cout) as f64
}

/// Parameter count of a conv layer (+bias).
pub fn conv2d_params(cin: usize, cout: usize, k: usize) -> usize {
    k * k * cin * cout + cout
}

/// Parameter count of a dense layer (+bias).
pub fn fc_params(cin: usize, cout: usize) -> usize {
    cin * cout + cout
}

/// Activation tensor bits for `h×w×c` float32.
pub fn activation_bits(h: usize, w: usize, c: usize) -> f64 {
    (h * w * c) as f64 * 32.0
}

/// Parameter bits for `n` float32 parameters.
pub fn param_bits(n: usize) -> f64 {
    n as f64 * 32.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_macs_formula() {
        // 3x3, 8->8, 16x16, stride 1: 9*8*8*256 = 147456 MACs.
        assert_eq!(conv2d_macs(16, 16, 8, 8, 3, 1), 147_456.0);
        // stride 2 halves each spatial dim.
        assert_eq!(conv2d_macs(16, 16, 8, 16, 3, 2), 9.0 * 8.0 * 16.0 * 64.0);
    }

    #[test]
    fn fc_flops_formula() {
        assert_eq!(fc_flops(32, 10), 640.0);
        assert_eq!(fc_params(32, 10), 330);
    }

    #[test]
    fn conv_params_formula() {
        assert_eq!(conv2d_params(3, 64, 7), 7 * 7 * 3 * 64 + 64);
    }

    #[test]
    fn activation_bits_f32() {
        // 16x16x8 f32 = 2048 floats = 65536 bits.
        assert_eq!(activation_bits(16, 16, 8), 65_536.0);
    }

    #[test]
    fn odd_sizes_ceil_division() {
        // 15x15 stride 2 -> 8x8 output.
        assert_eq!(conv2d_macs(15, 15, 1, 1, 1, 2), 64.0);
    }
}
