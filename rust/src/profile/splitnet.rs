//! SplitNet profile — the trainable reproduction-scale residual CNN.
//!
//! This profile is derived from first principles ([`flops`]) and must match
//! the AOT-exported model in `python/compile/model.py` *exactly* in shapes:
//! the coordinator uses it to account latency for the rounds it actually
//! executes through PJRT. The four stages mirror ResNet-18's block topology;
//! stage boundaries are the four cut candidates exported as artifacts.
//!
//! Layer granularity note: the python model treats one *stage* as one layer
//! for cut purposes, so this profile has 5 "layers" (4 stages + head) and
//! cut candidates {1,2,3,4}.

use super::flops::*;
use super::{Layer, LayerKind, NetworkProfile};

/// Shape configuration mirroring `python/compile/model.py::ModelConfig`.
#[derive(Debug, Clone, Copy)]
pub struct SplitNetConfig {
    pub channels: usize,
    pub num_classes: usize,
    pub img: usize,
    pub width: usize,
}

impl SplitNetConfig {
    pub fn mnist_like() -> Self {
        SplitNetConfig { channels: 1, num_classes: 10, img: 16, width: 8 }
    }

    pub fn ham_like() -> Self {
        SplitNetConfig { channels: 3, num_classes: 7, img: 16, width: 8 }
    }

    pub fn for_family(family: &str) -> Self {
        match family {
            "mnist" => Self::mnist_like(),
            _ => Self::ham_like(),
        }
    }

    fn stage_widths(&self) -> [usize; 4] {
        [self.width, self.width, 2 * self.width, 4 * self.width]
    }

    /// (h, w, c) of the smashed activations after stage `cut` (1..=4).
    pub fn smashed_shape(&self, cut: usize) -> (usize, usize, usize) {
        let ws = self.stage_widths();
        match cut {
            1 => (self.img, self.img, ws[0]),
            2 => (self.img, self.img, ws[1]),
            3 => (self.img / 2, self.img / 2, ws[2]),
            4 => (self.img / 4, self.img / 4, ws[3]),
            // audit:allow(R1, "internal contract: every caller passes a cut validated against cut_candidates (1..=4) at parse time")
            _ => panic!("cut {cut} out of 1..=4"),
        }
    }

    /// Total parameter count (must equal the python model's).
    pub fn param_count(&self) -> usize {
        let [w1, w2, w3, w4] = self.stage_widths();
        let mut n = conv2d_params(self.channels, w1, 3); // s1
        n += conv2d_params(w1, w2, 3) + conv2d_params(w2, w2, 3); // s2
        n += conv2d_params(w2, w3, 3)
            + conv2d_params(w3, w3, 3)
            + conv2d_params(w2, w3, 1); // s3 (+proj)
        n += conv2d_params(w3, w4, 3)
            + conv2d_params(w4, w4, 3)
            + conv2d_params(w3, w4, 1); // s4 (+proj)
        n += fc_params(w4, self.num_classes); // head
        n
    }
}

const MIB: f64 = 1024.0 * 1024.0;

/// Build the 5-layer (4 stages + head) profile for a family config.
pub fn profile(cfg: SplitNetConfig) -> NetworkProfile {
    let [w1, w2, w3, w4] = cfg.stage_widths();
    let img = cfg.img;

    // stage 1: conv3x3 ch->w1 @ img
    let s1_flops = conv2d_flops(img, img, cfg.channels, w1, 3, 1);
    let s1_params = conv2d_params(cfg.channels, w1, 3);

    // stage 2: two 3x3 convs w1->w2->w2 @ img (+skip add, negligible)
    let s2_flops =
        conv2d_flops(img, img, w1, w2, 3, 1) + conv2d_flops(img, img, w2, w2, 3, 1);
    let s2_params = conv2d_params(w1, w2, 3) + conv2d_params(w2, w2, 3);

    // stage 3: conv stride2 w2->w3, conv w3->w3 @ img/2, 1x1 proj stride2
    let s3_flops = conv2d_flops(img, img, w2, w3, 3, 2)
        + conv2d_flops(img / 2, img / 2, w3, w3, 3, 1)
        + conv2d_flops(img, img, w2, w3, 1, 2);
    let s3_params = conv2d_params(w2, w3, 3)
        + conv2d_params(w3, w3, 3)
        + conv2d_params(w2, w3, 1);

    // stage 4: same shape at img/2 -> img/4
    let s4_flops = conv2d_flops(img / 2, img / 2, w3, w4, 3, 2)
        + conv2d_flops(img / 4, img / 4, w4, w4, 3, 1)
        + conv2d_flops(img / 2, img / 2, w3, w4, 1, 2);
    let s4_params = conv2d_params(w3, w4, 3)
        + conv2d_params(w4, w4, 3)
        + conv2d_params(w3, w4, 1);

    // head: GAP + FC
    let head_flops = pool_flops(img / 4, img / 4, w4, img / 4, img / 4)
        + fc_flops(w4, cfg.num_classes);
    let head_params = fc_params(w4, cfg.num_classes);

    let smashed = |cut: usize| {
        let (h, w, c) = cfg.smashed_shape(cut);
        activation_bits(h, w, c) / 8.0 / MIB
    };

    let layers = vec![
        Layer {
            name: "stage1",
            kind: LayerKind::Conv,
            params_mib: param_bits(s1_params) / 8.0 / MIB,
            fp_mflops: s1_flops / 1e6,
            smashed_mib: smashed(1),
        },
        Layer {
            name: "stage2",
            kind: LayerKind::Conv,
            params_mib: param_bits(s2_params) / 8.0 / MIB,
            fp_mflops: s2_flops / 1e6,
            smashed_mib: smashed(2),
        },
        Layer {
            name: "stage3",
            kind: LayerKind::Conv,
            params_mib: param_bits(s3_params) / 8.0 / MIB,
            fp_mflops: s3_flops / 1e6,
            smashed_mib: smashed(3),
        },
        Layer {
            name: "stage4",
            kind: LayerKind::Conv,
            params_mib: param_bits(s4_params) / 8.0 / MIB,
            fp_mflops: s4_flops / 1e6,
            smashed_mib: smashed(4),
        },
        Layer {
            name: "head",
            kind: LayerKind::Fc,
            params_mib: param_bits(head_params) / 8.0 / MIB,
            fp_mflops: head_flops / 1e6,
            smashed_mib: cfg.num_classes as f64 * 4.0 / MIB,
        },
    ];
    NetworkProfile {
        name: "splitnet",
        layers,
        cut_candidates: vec![1, 2, 3, 4],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_matches_python_model() {
        // python smoke run reported 19642 params for mnist-like (w=8, ch=1,
        // nc=10). This constant is the cross-language contract.
        assert_eq!(SplitNetConfig::mnist_like().param_count(), 19_642);
    }

    #[test]
    fn smashed_shapes_match_python() {
        let c = SplitNetConfig::mnist_like();
        assert_eq!(c.smashed_shape(1), (16, 16, 8));
        assert_eq!(c.smashed_shape(2), (16, 16, 8));
        assert_eq!(c.smashed_shape(3), (8, 8, 16));
        assert_eq!(c.smashed_shape(4), (4, 4, 32));
    }

    #[test]
    fn profile_has_four_cuts() {
        let p = profile(SplitNetConfig::mnist_like());
        assert_eq!(p.n_layers(), 5);
        assert_eq!(p.cut_candidates, vec![1, 2, 3, 4]);
    }

    #[test]
    fn deeper_cut_smaller_payload() {
        let p = profile(SplitNetConfig::mnist_like());
        assert!(p.psi_bits(2) > p.psi_bits(3));
        assert!(p.psi_bits(3) > p.psi_bits(4));
    }

    #[test]
    fn ham_family_differs_only_in_io() {
        let m = profile(SplitNetConfig::mnist_like());
        let h = profile(SplitNetConfig::ham_like());
        // stage-2..4 smashed payloads identical; stage-1 FLOPs differ (3ch).
        assert_eq!(m.psi_bits(2), h.psi_bits(2));
        assert!(h.layers[0].fp_mflops > m.layers[0].fp_mflops);
    }

    #[test]
    fn totals_small_enough_to_train_on_cpu() {
        let p = profile(SplitNetConfig::mnist_like());
        // < 10 MFLOPs/sample forward: hundreds of rounds on CPU PJRT is fine
        assert!(p.rho_total() < 10e6, "rho_total = {}", p.rho_total());
    }
}
