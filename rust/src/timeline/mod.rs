//! Event-timeline round engine — the paper's §V accounting seen as a
//! *schedule*, not just a stage sum.
//!
//! [`latency`](crate::latency) gives the seven closed-form stage
//! latencies and the eq. 23 barrier total. EPSL's core claim, however, is
//! *overlap*: client-side FP, per-client uplink, server compute, and the
//! gradient return pipeline across heterogeneous clients. This module is
//! a deterministic discrete-event simulator over typed events
//! ([`EventKind`]) that makes both views executable:
//!
//! - [`Mode::Barrier`] synchronizes at every phase boundary and
//!   reproduces the closed-form `round_latency(..).round_total()`
//!   **bit-identically** for every framework (proven by the parity suite
//!   in `tests/integration_timeline.rs` and by the CI smoke step) — the
//!   engine folds each phase's chain offsets and accumulates phase spans
//!   in exactly the eq. 23 association;
//! - [`Mode::Pipelined`] overlaps phases per client / per link: the
//!   server starts its forward pass on the first smashed-data arrival
//!   (FIFO slots, one per client sub-batch), broadcast and unicast
//!   payloads travel concurrently on their own links, and SFL's model
//!   uploads begin as each client finishes its backward pass. The
//!   composition is floating-point-monotone against the barrier fold and
//!   finally clamped by it, so `pipelined ≤ barrier` holds *exactly* —
//!   never "up to rounding" (PERF.md §5 documents the discipline).
//!
//! Stage durations come from the closed forms
//! ([`plan::shape_for`] consumes [`crate::latency::frameworks::round_latency`]),
//! so there is a single source of per-stage truth; the engine only
//! decides how those durations compose in time.

pub mod engine;
pub mod event;
pub mod plan;

pub use engine::{simulate, simulate_cuts, simulate_shape, RoundTimeline};
pub use event::{Event, EventKind};
pub use plan::{shape_for, shape_for_cuts, Exchange, RoundShape};

use crate::error::{Error, Result};

/// How the engine composes stage dependencies in time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Mode {
    /// Synchronize at every phase boundary — the paper's eq. 23
    /// semantics. Bit-identical to the closed-form `round_latency`.
    #[default]
    Barrier,
    /// Overlap phases per client / subchannel — the tighter latency a
    /// pipelining coordinator actually achieves. Never exceeds barrier.
    Pipelined,
}

impl Mode {
    /// Parse a config/CLI string (`barrier` | `pipelined`).
    pub fn parse(s: &str) -> Result<Mode> {
        match s {
            "barrier" => Ok(Mode::Barrier),
            "pipelined" => Ok(Mode::Pipelined),
            other => Err(Error::Config(format!(
                "timeline mode '{other}' unknown (barrier|pipelined)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Mode::Barrier => "barrier",
            Mode::Pipelined => "pipelined",
        }
    }
}

/// Per-stage wall-clock spans of one simulated round (seconds), in the
/// order the stages gate the round. In barrier mode each field is the
/// exact eq. 23 phase span and the left-to-right sum is bit-identical to
/// the round total; in pipelined mode the fields are deltas between the
/// engine's milestone events (last arrival, server FP/BP done, broadcast
/// done, last client BP, model sync), so re-summing them may differ from
/// the authoritative [`RoundTimeline::total`] by float rounding.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StageSpans {
    /// Round start → last smashed-data arrival at the server.
    pub uplink_phase: f64,
    /// → server-side forward pass complete.
    pub server_fp: f64,
    /// → server-side backward pass (incl. φ-aggregation) complete.
    pub server_bp: f64,
    /// → aggregated-gradient broadcast complete.
    pub broadcast: f64,
    /// → last client finished unicast reception + client-side BP.
    pub downlink_phase: f64,
    /// → model exchange complete (SFL FedAvg / vanilla relay; 0 for
    /// EPSL and PSL).
    pub model_exchange: f64,
}

impl StageSpans {
    /// Left-to-right sum of the spans — in barrier mode bit-identical to
    /// [`RoundTimeline::total`] (same association as eq. 23).
    pub fn total(&self) -> f64 {
        self.uplink_phase
            + self.server_fp
            + self.server_bp
            + self.broadcast
            + self.downlink_phase
            + self.model_exchange
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parses_both_names() {
        assert_eq!(Mode::parse("barrier").unwrap(), Mode::Barrier);
        assert_eq!(Mode::parse("pipelined").unwrap(), Mode::Pipelined);
        assert_eq!(Mode::Barrier.name(), "barrier");
        assert_eq!(Mode::Pipelined.name(), "pipelined");
        let e = Mode::parse("overlapped").unwrap_err();
        assert!(e.to_string().contains("barrier|pipelined"), "{e}");
    }

    #[test]
    fn default_mode_is_barrier() {
        assert_eq!(Mode::default(), Mode::Barrier);
    }

    #[test]
    fn timeline_mode_matches_mode_parse() {
        // `Config::validate` re-spells this accept set inline (config
        // sits below timeline in the layering DAG and must not call
        // up into it); this pins the two together.
        for name in ["barrier", "pipelined"] {
            assert!(Mode::parse(name).is_ok());
            let mut c = crate::config::Config::new();
            c.timeline_mode = name.to_string();
            assert!(c.validate().is_ok(), "config rejects '{name}'");
        }
        let mut c = crate::config::Config::new();
        c.timeline_mode = "overlap".to_string();
        let e = c.validate().unwrap_err();
        assert!(e.to_string().contains("barrier|pipelined"), "{e}");
    }

    #[test]
    fn spans_total_sums_in_order() {
        let s = StageSpans {
            uplink_phase: 1.0,
            server_fp: 2.0,
            server_bp: 3.0,
            broadcast: 0.5,
            downlink_phase: 1.5,
            model_exchange: 0.25,
        };
        assert_eq!(s.total(), 8.25);
        assert_eq!(StageSpans::default().total(), 0.0);
    }
}
