//! The two execution modes over a [`RoundShape`].
//!
//! **Barrier** folds each phase's chain offsets (client FP→uplink,
//! unicast→client BP) and accumulates phase spans left-to-right — the
//! exact floating-point association of eq. 23 — so the total is
//! bit-identical to `round_latency(fw, inp).round_total()`.
//!
//! **Pipelined** computes absolute milestone times with overlap:
//!
//! - the server forward pass serves smashed-data sub-batches FIFO in
//!   arrival order (equal shares Φ_s^F/C); slot finish times use the
//!   idle-free remaining-work form `max_{j≤k}(a_(j) + Φ·(k−j+1)/C)`,
//!   whose every term is a monotone fp image of the barrier milestone
//!   `max_i a_i + Φ`, so the pipelined server FP never finishes later;
//! - broadcast and unicast travel concurrently on their own links; a
//!   client's BP start is gated by whichever payload lands last, and the
//!   gating branch also picks the fp association (`(t_bc) + b` vs
//!   `t_sbp + (d + b)`) that stays dominated by the barrier fold;
//! - SFL model uploads start as each client finishes BP; the FedAvg
//!   broadcast follows the last upload.
//!
//! The final totals are additionally clamped by the barrier totals: the
//! barrier schedule is always admissible, so rounding in the overlapped
//! composition must never report a slower round. `pipelined ≤ barrier`
//! therefore holds exactly, not "up to an ulp".

use super::event::{sort_events, Event, EventKind};
use super::plan::{shape_for, Exchange, RoundShape};
use super::{Mode, StageSpans};
use crate::latency::frameworks::Framework;
use crate::latency::LatencyInputs;

/// One simulated round: the typed event log, per-stage spans, and the
/// round-completion time.
#[derive(Debug, Clone)]
pub struct RoundTimeline {
    pub mode: Mode,
    /// Events sorted by time (stable — construction order breaks ties).
    pub events: Vec<Event>,
    /// Per-stage breakdown (see [`StageSpans`] for the two modes'
    /// semantics).
    pub spans: StageSpans,
    /// Round-completion time in seconds. Barrier: bit-identical to the
    /// closed-form eq. 23 total. Pipelined: ≤ the barrier total, exactly.
    pub total: f64,
}

/// Simulate one round of `fw` under `inp` in the given mode.
pub fn simulate(fw: Framework, inp: &LatencyInputs, mode: Mode)
    -> RoundTimeline {
    simulate_shape(&shape_for(fw, inp), mode)
}

/// Execute an already-built [`RoundShape`] in the given mode — the single
/// dispatch both [`simulate`] and the mixed-cut entry point share.
pub fn simulate_shape(shape: &RoundShape, mode: Mode) -> RoundTimeline {
    match mode {
        Mode::Barrier => run_barrier(shape, Mode::Barrier),
        // Vanilla SL is strictly sequential — nothing overlaps, so the
        // pipelined schedule degenerates to the barrier one.
        Mode::Pipelined if shape.sequential => {
            run_barrier(shape, Mode::Pipelined)
        }
        Mode::Pipelined => run_pipelined(shape),
    }
}

/// Simulate one mixed-cut round (client i splits at `cuts[i]`). Only the
/// parallel frameworks are supported; an all-equal vector is
/// bit-identical to [`simulate`] at that cut.
pub fn simulate_cuts(fw: Framework, inp: &LatencyInputs, cuts: &[usize],
                     mode: Mode) -> crate::error::Result<RoundTimeline> {
    Ok(simulate_shape(&super::plan::shape_for_cuts(fw, inp, cuts)?, mode))
}

/// Barrier-mode totals (pre-exchange, final) in the eq. 23 association —
/// shared by the barrier executor and the pipelined clamp.
fn barrier_totals(shape: &RoundShape) -> (f64, f64) {
    let mut total = 0.0f64;
    let mut span = 0.0f64;
    for (f, u) in shape.client_fp.iter().zip(&shape.uplink) {
        span = span.max(f + u);
    }
    total += span;
    total += shape.server_fp;
    total += shape.server_bp;
    total += shape.broadcast;
    let mut span = 0.0f64;
    for (d, b) in shape.downlink.iter().zip(&shape.client_bp) {
        span = span.max(d + b);
    }
    total += span;
    let pre_exchange = total;
    let total = match &shape.exchange {
        Exchange::None => pre_exchange,
        Exchange::FedAvg { uploads, down } => {
            let up_max = uploads.iter().cloned().fold(0.0, f64::max);
            pre_exchange + (up_max + down)
        }
        Exchange::Relay(r) => pre_exchange + r,
    };
    (pre_exchange, total)
}

fn run_barrier(shape: &RoundShape, mode: Mode) -> RoundTimeline {
    let n = shape.n_chains();
    let mut ev = Vec::with_capacity(4 * n + 8);
    let mut total = 0.0f64;

    // Phase 1: client FP chained into smashed-data uplink, synchronized
    // at the server-ingest barrier (phase starts at t = 0).
    let mut span = 0.0f64;
    let arrivals = shape.uplink_arrivals();
    for (i, &arr) in arrivals.iter().enumerate() {
        ev.push(Event::new(
            shape.client_fp[i],
            EventKind::ClientFpDone { client: i },
        ));
        ev.push(Event::new(arr, EventKind::UplinkDone { client: i }));
        span = span.max(arr);
    }
    let uplink_phase = span;
    total += span;

    // Phases 2–4: serial server FP, server BP (+ aggregation), broadcast.
    total += shape.server_fp;
    ev.push(Event::new(total, EventKind::ServerFpDone));
    total += shape.server_bp;
    ev.push(Event::new(total, EventKind::GradAggregated));
    ev.push(Event::new(total, EventKind::ServerBpDone));
    total += shape.broadcast;
    ev.push(Event::new(total, EventKind::BroadcastDone));

    // Phase 5: unicast chained into client BP, synchronized at round end.
    let dl_base = total;
    let mut span = 0.0f64;
    for i in 0..n {
        let d = shape.downlink[i];
        let done = d + shape.client_bp[i];
        ev.push(Event::new(
            dl_base + d,
            EventKind::DownlinkDone { client: i },
        ));
        ev.push(Event::new(
            dl_base + done,
            EventKind::ClientBpDone { client: i },
        ));
        span = span.max(done);
    }
    let downlink_phase = span;
    total += span;

    // Phase 6: model exchange. The span composes internally exactly as
    // the closed form's single `model_exchange` term.
    let model_exchange = match &shape.exchange {
        Exchange::None => 0.0,
        Exchange::FedAvg { uploads, down } => {
            let base = total;
            let mut up_max = 0.0f64;
            for (i, u) in uploads.iter().enumerate() {
                ev.push(Event::new(
                    base + u,
                    EventKind::ModelUploadDone { client: i },
                ));
                up_max = up_max.max(*u);
            }
            up_max + down
        }
        Exchange::Relay(r) => *r,
    };
    if !matches!(shape.exchange, Exchange::None) {
        total += model_exchange;
        ev.push(Event::new(total, EventKind::ModelSyncDone));
    }
    ev.push(Event::new(total, EventKind::RoundDone));
    sort_events(&mut ev);

    // The executor's fold and `barrier_totals` (the pipelined clamp's
    // source) must stay the same association; the parity suite pins the
    // executor to the closed form, and this ties the clamp to it.
    debug_assert_eq!(
        total.to_bits(),
        barrier_totals(shape).1.to_bits(),
        "barrier executor drifted from the shared eq. 23 fold"
    );

    RoundTimeline {
        mode,
        events: ev,
        spans: StageSpans {
            uplink_phase,
            server_fp: shape.server_fp,
            server_bp: shape.server_bp,
            broadcast: shape.broadcast,
            downlink_phase,
            model_exchange,
        },
        total,
    }
}

fn run_pipelined(shape: &RoundShape) -> RoundTimeline {
    let n = shape.n_chains();
    let nf = n as f64;
    let mut ev = Vec::with_capacity(5 * n + 8);

    // Client FP → uplink chains (the per-client association is identical
    // to barrier mode: each client's data lands at a_i = T_i^F + T_i^U).
    let arrivals = shape.uplink_arrivals();
    for (i, &arr) in arrivals.iter().enumerate() {
        ev.push(Event::new(
            shape.client_fp[i],
            EventKind::ClientFpDone { client: i },
        ));
        ev.push(Event::new(arr, EventKind::UplinkDone { client: i }));
    }
    let t_arr = arrivals.iter().cloned().fold(0.0, f64::max);

    // Server FP: FIFO slots in arrival order, equal shares Φ_s^F/C. The
    // remaining-work form is idle-gap free and every term is bounded by
    // max_i a_i + Φ_s^F under monotone fp add/mul (fractions ≤ 1).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&x, &y| {
        arrivals[x].total_cmp(&arrivals[y]).then(x.cmp(&y))
    });
    let mut t_sfp = 0.0f64;
    for k in 0..n {
        let mut slot = 0.0f64;
        for (j, &ci) in order.iter().enumerate().take(k + 1) {
            let frac = (k - j + 1) as f64 / nf;
            slot = slot.max(arrivals[ci] + shape.server_fp * frac);
        }
        ev.push(Event::new(
            slot,
            EventKind::ServerFpSlotDone { client: order[k] },
        ));
        t_sfp = slot;
    }
    ev.push(Event::new(t_sfp, EventKind::ServerFpDone));

    // Server BP needs every sub-batch's loss gradient (the φ-aggregation
    // spans the whole effective batch): one serial slot.
    let t_sbp = t_sfp + shape.server_bp;
    ev.push(Event::new(t_sbp, EventKind::GradAggregated));
    ev.push(Event::new(t_sbp, EventKind::ServerBpDone));
    let t_bc = t_sbp + shape.broadcast;
    ev.push(Event::new(t_bc, EventKind::BroadcastDone));

    // Gradient return: broadcast and per-client unicast depart together
    // after server BP on their own links; client i's BP starts once both
    // payloads are in. The gating branch picks the association that stays
    // dominated by the barrier fold (broadcast-gated: (t_sbp+T^B)+T_i^B;
    // unicast-gated: t_sbp+(T_i^D+T_i^B)).
    let mut completions = Vec::with_capacity(n);
    let mut completion = 0.0f64;
    for i in 0..n {
        let d = shape.downlink[i];
        let b = shape.client_bp[i];
        ev.push(Event::new(
            t_sbp + d,
            EventKind::DownlinkDone { client: i },
        ));
        let done = if shape.broadcast >= d {
            t_bc + b
        } else {
            t_sbp + (d + b)
        };
        ev.push(Event::new(done, EventKind::ClientBpDone { client: i }));
        completions.push(done);
        completion = completion.max(done);
    }

    // The barrier schedule is always admissible; clamp so fp rounding in
    // the overlapped composition can never report a slower round.
    let (barrier_pre_exchange, barrier_total) = barrier_totals(shape);
    let completion = completion.min(barrier_pre_exchange);

    let total = match &shape.exchange {
        Exchange::None => completion,
        Exchange::FedAvg { uploads, down } => {
            // Fast clients upload their client-side model while the
            // straggler is still in BP; the FedAvg broadcast follows the
            // last upload.
            let mut up_done = 0.0f64;
            for (i, u) in uploads.iter().enumerate() {
                let t = completions[i] + u;
                ev.push(Event::new(
                    t,
                    EventKind::ModelUploadDone { client: i },
                ));
                up_done = up_done.max(t);
            }
            let t = (up_done + down).min(barrier_total);
            ev.push(Event::new(t, EventKind::ModelSyncDone));
            t
        }
        Exchange::Relay(r) => {
            // Unreachable through `simulate` (sequential shapes run the
            // barrier executor) — kept total for direct engine users.
            let t = (completion + r).min(barrier_total);
            ev.push(Event::new(t, EventKind::ModelSyncDone));
            t
        }
    };
    // Everything in-round finishes by round end: when the admissibility
    // clamp tightened the totals, pull any event rounded past them back
    // onto the boundary so the log stays consistent with `total`.
    for e in &mut ev {
        if e.t > total {
            e.t = total;
        }
    }
    ev.push(Event::new(total, EventKind::RoundDone));
    sort_events(&mut ev);

    RoundTimeline {
        mode: Mode::Pipelined,
        events: ev,
        spans: StageSpans {
            uplink_phase: t_arr,
            server_fp: t_sfp - t_arr,
            server_bp: t_sbp - t_sfp,
            broadcast: t_bc - t_sbp,
            downlink_phase: completion - t_bc,
            model_exchange: total - completion,
        },
        total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latency::frameworks::round_latency;
    use crate::profile::resnet18;
    use crate::profile::NetworkProfile;

    fn inputs<'a>(p: &'a NetworkProfile, f: &'a [f64], up: &'a [f64],
                  dn: &'a [f64], phi: f64) -> LatencyInputs<'a> {
        LatencyInputs {
            profile: p,
            cut: 4,
            batch: 64,
            phi,
            f_server: 5e9,
            kappa_server: 1.0 / 32.0,
            kappa_client: 1.0 / 16.0,
            f_clients: f,
            uplink: up,
            downlink: dn,
            broadcast: 2e8,
            uplink_comp: 1.0,
        }
    }

    fn all_frameworks() -> Vec<Framework> {
        vec![
            Framework::VanillaSl,
            Framework::Sfl,
            Framework::Psl,
            Framework::Epsl { phi: 0.5 },
            Framework::EpslPt { early: true },
        ]
    }

    #[test]
    fn barrier_matches_closed_form_bitwise() {
        let p = resnet18::profile();
        let f = [1e9, 1.3e9, 1.6e9];
        let up = [5e7, 1.5e8, 2.5e8];
        let dn = [6e7, 1.2e8, 2.2e8];
        let inp = inputs(&p, &f, &up, &dn, 0.5);
        for fw in all_frameworks() {
            let closed = round_latency(fw, &inp).round_total();
            let tl = simulate(fw, &inp, Mode::Barrier);
            assert_eq!(
                tl.total.to_bits(),
                closed.to_bits(),
                "{}: barrier {} vs closed form {closed}",
                fw.name(),
                tl.total
            );
            // The barrier spans re-sum to the total bit-for-bit.
            assert_eq!(tl.spans.total().to_bits(), tl.total.to_bits());
        }
    }

    #[test]
    fn pipelined_never_exceeds_barrier() {
        let p = resnet18::profile();
        let f = [1e9, 1.3e9, 1.6e9, 1.1e9];
        let up = [5e7, 1.5e8, 2.5e8, 9e7];
        let dn = [6e7, 1.2e8, 2.2e8, 8e7];
        for phi in [0.0, 0.5, 1.0] {
            let inp = inputs(&p, &f, &up, &dn, phi);
            for fw in all_frameworks() {
                let bar = simulate(fw, &inp, Mode::Barrier).total;
                let pipe = simulate(fw, &inp, Mode::Pipelined).total;
                assert!(
                    pipe <= bar,
                    "{} φ={phi}: pipelined {pipe} > barrier {bar}",
                    fw.name()
                );
            }
        }
    }

    #[test]
    fn pipelined_strictly_faster_under_heterogeneity() {
        let p = resnet18::profile();
        // Strongly heterogeneous compute and links: the straggler's
        // arrival leaves plenty of server-FP work to overlap.
        let f = [0.8e9, 1.6e9, 1.2e9, 2.0e9];
        let up = [3e7, 3e8, 1e8, 2e8];
        let dn = [4e7, 2.5e8, 1.2e8, 1.8e8];
        let inp = inputs(&p, &f, &up, &dn, 0.5);
        for fw in [
            Framework::Epsl { phi: 0.5 },
            Framework::Psl,
            Framework::Sfl,
        ] {
            let bar = simulate(fw, &inp, Mode::Barrier).total;
            let pipe = simulate(fw, &inp, Mode::Pipelined).total;
            assert!(
                pipe < bar,
                "{}: pipelined {pipe} !< barrier {bar}",
                fw.name()
            );
        }
    }

    #[test]
    fn vanilla_pipelined_degenerates_to_barrier() {
        let p = resnet18::profile();
        let f = [1e9, 1.4e9];
        let up = [1e8, 2e8];
        let dn = [1e8, 2e8];
        let inp = inputs(&p, &f, &up, &dn, 0.5);
        let bar = simulate(Framework::VanillaSl, &inp, Mode::Barrier);
        let pipe = simulate(Framework::VanillaSl, &inp, Mode::Pipelined);
        assert_eq!(pipe.total.to_bits(), bar.total.to_bits());
        assert_eq!(pipe.mode, Mode::Pipelined);
        assert_eq!(bar.mode, Mode::Barrier);
    }

    #[test]
    fn events_sorted_round_done_last_and_consistent() {
        let p = resnet18::profile();
        let f = [1e9, 1.3e9, 1.6e9];
        let up = [5e7, 1.5e8, 2.5e8];
        let dn = [6e7, 1.2e8, 2.2e8];
        let inp = inputs(&p, &f, &up, &dn, 0.5);
        for mode in [Mode::Barrier, Mode::Pipelined] {
            for fw in all_frameworks() {
                let tl = simulate(fw, &inp, mode);
                assert!(tl
                    .events
                    .windows(2)
                    .all(|w| w[0].t <= w[1].t));
                let last = tl.events.last().unwrap();
                assert_eq!(last.kind, EventKind::RoundDone);
                assert_eq!(last.t.to_bits(), tl.total.to_bits());
                assert!(tl.events.iter().all(|e| e.t.is_finite()
                    && e.t >= 0.0));
            }
        }
    }

    #[test]
    fn pipelined_spans_nonnegative_and_milestones_ordered() {
        let p = resnet18::profile();
        let f = [0.8e9, 1.6e9, 1.2e9];
        let up = [3e7, 3e8, 1e8];
        let dn = [4e7, 2.5e8, 1.2e8];
        for phi in [0.0, 0.5, 1.0] {
            let inp = inputs(&p, &f, &up, &dn, phi);
            for fw in all_frameworks() {
                let tl = simulate(fw, &inp, Mode::Pipelined);
                let s = tl.spans;
                for (name, v) in [
                    ("uplink_phase", s.uplink_phase),
                    ("server_fp", s.server_fp),
                    ("server_bp", s.server_bp),
                    ("broadcast", s.broadcast),
                    ("downlink_phase", s.downlink_phase),
                    ("model_exchange", s.model_exchange),
                ] {
                    assert!(
                        v >= 0.0 && v.is_finite(),
                        "{} φ={phi}: span {name} = {v}",
                        fw.name()
                    );
                }
            }
        }
    }

    #[test]
    fn single_client_psl_pipelined_equals_barrier() {
        // C = 1, φ = 0: no broadcast to overlap and a single FP slot, so
        // the two schedules coincide bit for bit.
        let p = resnet18::profile();
        let f = [1.2e9];
        let up = [1e8];
        let dn = [1e8];
        let inp = inputs(&p, &f, &up, &dn, 0.0);
        let bar = simulate(Framework::Psl, &inp, Mode::Barrier).total;
        let pipe = simulate(Framework::Psl, &inp, Mode::Pipelined).total;
        assert_eq!(pipe.to_bits(), bar.to_bits());
    }

    #[test]
    fn server_fp_slots_serve_in_arrival_order() {
        let p = resnet18::profile();
        // Client 1 arrives first (fast compute + fat uplink).
        let f = [0.8e9, 2.0e9];
        let up = [3e7, 3e8];
        let dn = [1e8, 1e8];
        let inp = inputs(&p, &f, &up, &dn, 0.5);
        let tl = simulate(Framework::Epsl { phi: 0.5 }, &inp,
                          Mode::Pipelined);
        let slots: Vec<usize> = tl
            .events
            .iter()
            .filter_map(|e| match e.kind {
                EventKind::ServerFpSlotDone { client } => Some(client),
                _ => None,
            })
            .collect();
        assert_eq!(slots, vec![1, 0], "fast arrival served first");
    }
}
