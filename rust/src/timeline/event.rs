//! Typed timeline events.
//!
//! Every milestone of a simulated round is a timestamped [`Event`]. The
//! engine emits them in a deterministic construction order and sorts by
//! time with a stable sort, so the event log is reproducible for a given
//! input in either mode.

/// What happened at a timeline instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Client `client` finished its client-side forward pass (eq. 13).
    /// For vanilla SL the per-turn chains are pre-summed, so the single
    /// chain's events cover the whole sequential sweep.
    ClientFpDone { client: usize },
    /// Client `client`'s smashed data fully crossed its uplink
    /// subchannels and is resident at the server (eq. 15).
    UplinkDone { client: usize },
    /// The server finished the FP slot for `client`'s sub-batch
    /// (pipelined mode: FIFO service in arrival order).
    ServerFpSlotDone { client: usize },
    /// Server-side forward pass complete over all C·b samples (eq. 16).
    ServerFpDone,
    /// Last-layer gradient aggregation (the EPSL φ-kernel) complete.
    GradAggregated,
    /// Server-side backward pass complete (eq. 17).
    ServerBpDone,
    /// Aggregated-gradient broadcast complete (eq. 19).
    BroadcastDone,
    /// Unaggregated-gradient unicast to `client` complete (eq. 21).
    DownlinkDone { client: usize },
    /// Client `client` finished its client-side backward pass (eq. 22).
    ClientBpDone { client: usize },
    /// SFL: `client` uploaded its client-side model for FedAvg.
    ModelUploadDone { client: usize },
    /// Model synchronization complete (SFL aggregated-model broadcast /
    /// vanilla SL relay chain).
    ModelSyncDone,
    /// The round is over; the timestamp equals the round total.
    RoundDone,
}

/// One timestamped event (seconds from round start).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub t: f64,
    pub kind: EventKind,
}

impl Event {
    pub fn new(t: f64, kind: EventKind) -> Event {
        Event { t, kind }
    }
}

/// Stable in-place sort by timestamp (construction order breaks ties, so
/// logs are deterministic).
pub(crate) fn sort_events(events: &mut [Event]) {
    events.sort_by(|a, b| a.t.total_cmp(&b.t));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sort_is_stable_on_ties() {
        let mut ev = vec![
            Event::new(2.0, EventKind::ServerFpDone),
            Event::new(1.0, EventKind::GradAggregated),
            Event::new(1.0, EventKind::ServerBpDone),
            Event::new(0.5, EventKind::ClientFpDone { client: 0 }),
        ];
        sort_events(&mut ev);
        assert_eq!(ev[0].kind, EventKind::ClientFpDone { client: 0 });
        // Ties keep construction order.
        assert_eq!(ev[1].kind, EventKind::GradAggregated);
        assert_eq!(ev[2].kind, EventKind::ServerBpDone);
        assert_eq!(ev[3].kind, EventKind::ServerFpDone);
    }
}
