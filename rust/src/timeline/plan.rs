//! Declarative round shapes: per-stage durations plus the dependency
//! structure each framework's round actually has, consumed by the engine
//! in either execution mode.
//!
//! Durations come from the §V closed forms
//! ([`round_latency`]) — the single source of per-stage truth — so the
//! barrier engine's totals are bit-identical to
//! `round_latency(fw, inp).round_total()` by construction.

use crate::error::Result;
use crate::latency::frameworks::{
    round_latency, round_latency_hetero, sfl_exchange_parts, Framework,
};
use crate::latency::LatencyInputs;

/// End-of-round client-side model synchronization.
#[derive(Debug, Clone, PartialEq)]
pub enum Exchange {
    /// PSL / EPSL / EPSL-PT: client models never synchronize in-round.
    None,
    /// SFL: every client uploads its client-side model over its own
    /// subchannels, the server FedAvg-aggregates, then broadcasts the
    /// result (Thapa et al.).
    FedAvg {
        /// Per-client model upload seconds.
        uploads: Vec<f64>,
        /// Aggregated-model broadcast seconds.
        down: f64,
    },
    /// Vanilla SL: the summed inter-turn relay time — strictly serial,
    /// nothing to overlap.
    Relay(f64),
}

/// One framework round as stage durations (seconds) plus structure.
///
/// The per-client vectors are parallel chains (client i's FP feeds its
/// own uplink; its unicast feeds its own BP). For vanilla SL the chains
/// are the pre-summed sequential sweep (a single chain), mirroring the
/// closed form's summed [`crate::latency::StageLatencies`].
#[derive(Debug, Clone, PartialEq)]
pub struct RoundShape {
    /// Framework the shape was derived from (event labeling, reporting).
    pub framework: Framework,
    /// True for vanilla SL: the chains are pre-summed sequential turns,
    /// so pipelined execution degenerates to barrier execution.
    pub sequential: bool,
    /// T_i^F per chain (eq. 13).
    pub client_fp: Vec<f64>,
    /// T_i^U per chain (eq. 15).
    pub uplink: Vec<f64>,
    /// T_s^F (eq. 16).
    pub server_fp: f64,
    /// T_s^B including the last-layer aggregation term (eq. 17).
    pub server_bp: f64,
    /// T^B (eq. 19).
    pub broadcast: f64,
    /// T_i^D per chain (eq. 21).
    pub downlink: Vec<f64>,
    /// T_i^B per chain (eq. 22).
    pub client_bp: Vec<f64>,
    pub exchange: Exchange,
}

impl RoundShape {
    /// Number of parallel chains (C for the parallel frameworks, 1 for
    /// vanilla SL's pre-summed sweep).
    pub fn n_chains(&self) -> usize {
        self.client_fp.len()
    }

    /// Per-chain smashed-data arrival times at the server ingest,
    /// `a_i = T_i^F + T_i^U` — the single fp association both engine
    /// modes fold over, and the nominal baseline the coordinator's
    /// straggler deadline is derived from.
    pub fn uplink_arrivals(&self) -> Vec<f64> {
        self.client_fp
            .iter()
            .zip(&self.uplink)
            .map(|(f, u)| f + u)
            .collect()
    }
}

/// Build the declarative shape for `fw` under `inp` (the framework
/// defines its own effective φ, exactly as [`round_latency`] does).
pub fn shape_for(fw: Framework, inp: &LatencyInputs) -> RoundShape {
    let s = round_latency(fw, inp);
    let exchange = match fw {
        Framework::Sfl => {
            let (uploads, down) = sfl_exchange_parts(inp);
            Exchange::FedAvg { uploads, down }
        }
        Framework::VanillaSl => Exchange::Relay(s.model_exchange),
        _ => Exchange::None,
    };
    RoundShape {
        framework: fw,
        sequential: matches!(fw, Framework::VanillaSl),
        client_fp: s.client_fp,
        uplink: s.uplink,
        server_fp: s.server_fp,
        server_bp: s.server_bp,
        broadcast: s.broadcast,
        downlink: s.downlink,
        client_bp: s.client_bp,
        exchange,
    }
}

/// Build a mixed-cut shape: client i splits at `cuts[i]`. Only the
/// parallel frameworks are supported (see
/// [`round_latency_hetero`]); an all-equal vector produces a shape
/// bit-identical to [`shape_for`] at that cut.
pub fn shape_for_cuts(fw: Framework, inp: &LatencyInputs, cuts: &[usize])
    -> Result<RoundShape> {
    let s = round_latency_hetero(fw, inp, cuts)?;
    Ok(RoundShape {
        framework: fw,
        sequential: false,
        client_fp: s.client_fp,
        uplink: s.uplink,
        server_fp: s.server_fp,
        server_bp: s.server_bp,
        broadcast: s.broadcast,
        downlink: s.downlink,
        client_bp: s.client_bp,
        exchange: Exchange::None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::resnet18;
    use crate::profile::NetworkProfile;

    fn inputs<'a>(p: &'a NetworkProfile, f: &'a [f64], up: &'a [f64],
                  dn: &'a [f64]) -> LatencyInputs<'a> {
        LatencyInputs {
            profile: p,
            cut: 4,
            batch: 64,
            phi: 0.5,
            f_server: 5e9,
            kappa_server: 1.0 / 32.0,
            kappa_client: 1.0 / 16.0,
            f_clients: f,
            uplink: up,
            downlink: dn,
            broadcast: 2e8,
            uplink_comp: 1.0,
        }
    }

    #[test]
    fn epsl_shape_has_c_chains_no_exchange() {
        let p = resnet18::profile();
        let f = [1e9, 2e9, 1.5e9];
        let up = [1e8; 3];
        let dn = [1e8; 3];
        let inp = inputs(&p, &f, &up, &dn);
        let sh = shape_for(Framework::Epsl { phi: 0.5 }, &inp);
        assert_eq!(sh.n_chains(), 3);
        assert!(!sh.sequential);
        assert_eq!(sh.exchange, Exchange::None);
        let s = round_latency(Framework::Epsl { phi: 0.5 }, &inp);
        assert_eq!(sh.client_fp, s.client_fp);
        assert_eq!(sh.server_bp, s.server_bp);
    }

    #[test]
    fn sfl_shape_carries_exchange_parts() {
        let p = resnet18::profile();
        let f = [1e9; 2];
        let up = [1e8, 2e8];
        let dn = [1e8; 2];
        let inp = inputs(&p, &f, &up, &dn);
        let sh = shape_for(Framework::Sfl, &inp);
        match &sh.exchange {
            Exchange::FedAvg { uploads, down } => {
                assert_eq!(uploads.len(), 2);
                // Slower uplink ⇒ longer model upload.
                assert!(uploads[0] > uploads[1]);
                assert!(*down > 0.0);
                // Parts recompose to the closed form's exchange term.
                let up_max =
                    uploads.iter().cloned().fold(0.0, f64::max);
                let s = round_latency(Framework::Sfl, &inp);
                assert_eq!(
                    (up_max + down).to_bits(),
                    s.model_exchange.to_bits()
                );
            }
            other => panic!("SFL exchange missing: {other:?}"),
        }
    }

    #[test]
    fn shape_for_cuts_all_equal_matches_shape_for() {
        let p = resnet18::profile();
        let f = [1e9, 2e9, 1.5e9];
        let up = [1e8; 3];
        let dn = [1e8; 3];
        let inp = inputs(&p, &f, &up, &dn);
        let uni = shape_for(Framework::Epsl { phi: 0.5 }, &inp);
        let het =
            shape_for_cuts(Framework::Epsl { phi: 0.5 }, &inp, &[4, 4, 4])
                .unwrap();
        assert_eq!(uni, het);
        // A mixed vector still builds a C-chain parallel shape.
        let mix =
            shape_for_cuts(Framework::Epsl { phi: 0.5 }, &inp, &[1, 4, 10])
                .unwrap();
        assert_eq!(mix.n_chains(), 3);
        assert!(!mix.sequential);
        assert_eq!(mix.exchange, Exchange::None);
    }

    #[test]
    fn shape_for_cuts_rejects_exchange_frameworks() {
        let p = resnet18::profile();
        let f = [1e9; 2];
        let up = [1e8; 2];
        let dn = [1e8; 2];
        let inp = inputs(&p, &f, &up, &dn);
        assert!(shape_for_cuts(Framework::Sfl, &inp, &[1, 4]).is_err());
        assert!(
            shape_for_cuts(Framework::VanillaSl, &inp, &[1, 4]).is_err()
        );
    }

    #[test]
    fn vanilla_shape_is_single_presummed_chain() {
        let p = resnet18::profile();
        let f = [1e9, 2e9, 1.5e9];
        let up = [1e8; 3];
        let dn = [1e8; 3];
        let inp = inputs(&p, &f, &up, &dn);
        let sh = shape_for(Framework::VanillaSl, &inp);
        assert!(sh.sequential);
        assert_eq!(sh.n_chains(), 1);
        match &sh.exchange {
            Exchange::Relay(r) => assert!(*r > 0.0),
            other => panic!("vanilla relay missing: {other:?}"),
        }
    }
}
