//! `repro` — the EPSL reproduction CLI.
//!
//! Subcommands:
//!   train     run one training experiment (framework/φ/C/cut configurable)
//!   optimize  run the resource-management optimizer on a deployment
//!   figures   regenerate paper tables/figures into `results/`
//!   profile   print network profiles (ResNet-18 Table IV / SplitNet)
//!   info      artifact + platform information

use epsl::channel::{ChannelRealization, Deployment};
use epsl::config::cli::{render_help, Args, FlagSpec};
use epsl::config::Config;
use epsl::coordinator::{resume, train, Checkpoint, CutMode,
                        TrainerOptions};
use epsl::experiments::{self, Ctx};
use epsl::latency::frameworks::Framework;
use epsl::optim::baselines::Scheme;
use epsl::optim::{baselines, bcd, Problem};
use epsl::profile::{resnet18, splitnet};
use epsl::runtime::artifact::Manifest;
use epsl::runtime::{select_backend_with, BackendChoice, MathTier,
                    SelectedBackend};
use epsl::scenario::{DynamicChannel, FaultSpec};
use epsl::util::rng::Rng;
use epsl::util::table::Table;

fn flag_specs() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "config", takes_value: true, help: "TOML config file" },
        FlagSpec { name: "id", takes_value: true, help: "figure/table id" },
        FlagSpec { name: "all", takes_value: false, help: "all figures" },
        FlagSpec { name: "full", takes_value: false, help: "full-budget experiments (default: quick)" },
        FlagSpec { name: "out", takes_value: true, help: "results directory" },
        FlagSpec { name: "framework", takes_value: true, help: "epsl|psl|sfl|vanilla|epsl-pt" },
        FlagSpec { name: "phi", takes_value: true, help: "aggregation ratio" },
        FlagSpec { name: "clients", takes_value: true, help: "client count C" },
        FlagSpec { name: "cut", takes_value: true, help: "cut spec: splitnet layer 1..4 | hetero | per-client vector a-b-c" },
        FlagSpec { name: "rounds", takes_value: true, help: "training rounds" },
        FlagSpec { name: "family", takes_value: true, help: "mnist|ham" },
        FlagSpec { name: "non-iid", takes_value: false, help: "2-class non-IID sharding" },
        FlagSpec { name: "seed", takes_value: true, help: "RNG seed" },
        FlagSpec { name: "lr", takes_value: true, help: "learning rate (both sides)" },
        FlagSpec { name: "dataset", takes_value: true, help: "dataset size D" },
        FlagSpec { name: "optimize", takes_value: false, help: "use BCD for latency accounting" },
        FlagSpec { name: "dynamic-channel", takes_value: false, help: "per-round channel dynamics for latency accounting" },
        FlagSpec { name: "redraw", takes_value: true, help: "fading redraw period in rounds (0=static; implies --dynamic-channel)" },
        FlagSpec { name: "reopt", takes_value: true, help: "re-opt policy: never|every:<k>|regress:<x>|oracle (implies --dynamic-channel)" },
        FlagSpec { name: "scheme", takes_value: true, help: "a|b|c|d|proposed (optimize)" },
        FlagSpec { name: "backend", takes_value: true, help: "auto|native|pjrt (training backend)" },
        FlagSpec { name: "math-tier", takes_value: true, help: "native compute tier: bitwise|fast" },
        FlagSpec { name: "uplink-compression", takes_value: true, help: "uplink activation payload factor in (0,1] (1=f32, 0.5=f16, 0.25=int8)" },
        FlagSpec { name: "timeline", takes_value: true, help: "latency timeline mode: barrier|pipelined" },
        FlagSpec { name: "faults", takes_value: true, help: "scheduled fault events: crash@r:c,delay@r:c:s,corrupt@r:c,abort@r (implies [faults] enabled)" },
        FlagSpec { name: "checkpoint-every", takes_value: true, help: "write a checkpoint every k rounds (0=never)" },
        FlagSpec { name: "checkpoint", takes_value: true, help: "checkpoint file path (for --checkpoint-every / --resume)" },
        FlagSpec { name: "resume", takes_value: true, help: "resume bit-exactly from a checkpoint file" },
        FlagSpec { name: "artifacts", takes_value: true, help: "artifacts dir" },
        FlagSpec { name: "help", takes_value: false, help: "print help" },
    ]
}

const SUBCOMMANDS: &[(&str, &str)] = &[
    ("train", "run one training experiment end-to-end"),
    ("optimize", "run resource management on a simulated deployment"),
    ("figures", "regenerate paper tables/figures (--id X | --all)"),
    ("profile", "print ResNet-18 / SplitNet profiles"),
    ("info", "artifact + platform info"),
];

fn parse_framework(s: &str, phi: f64) -> Result<Framework, String> {
    Ok(match s {
        "epsl" => Framework::Epsl { phi },
        "psl" => Framework::Psl,
        "sfl" => Framework::Sfl,
        "vanilla" => Framework::VanillaSl,
        "epsl-pt" => Framework::EpslPt { early: true },
        other => return Err(format!("unknown framework '{other}'")),
    })
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let specs = flag_specs();
    let args = match Args::parse(&argv, &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n");
            eprintln!("{}", render_help("repro", SUBCOMMANDS, &specs));
            std::process::exit(2);
        }
    };
    if args.has("help") || args.subcommand.is_empty() {
        println!("{}", render_help("repro", SUBCOMMANDS, &specs));
        return;
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn load_config(args: &Args) -> anyhow::Result<Config> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(path)?,
        None => Config::new(),
    };
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = b.to_string();
        cfg.validate()?;
    }
    if let Some(t) = args.get("math-tier") {
        cfg.math_tier = t.to_string();
        cfg.validate()?;
    }
    if let Some(c) = args.f64("uplink-compression")? {
        cfg.net.uplink_compression = c;
        cfg.validate()?;
    }
    Ok(cfg)
}

/// Resolve the configured backend choice (`[backend]` TOML / `--backend`)
/// and native math tier (`--math-tier`).
fn pick_backend(cfg: &Config) -> anyhow::Result<SelectedBackend> {
    let choice = BackendChoice::parse(&cfg.backend)?;
    let tier = MathTier::parse(&cfg.math_tier)?;
    let sel = select_backend_with(&cfg.artifacts_dir, choice, tier)?;
    println!("backend: {}", sel.describe());
    Ok(sel)
}

fn dispatch(args: &Args) -> anyhow::Result<()> {
    match args.subcommand.as_str() {
        "train" => cmd_train(args),
        "optimize" => cmd_optimize(args),
        "figures" => cmd_figures(args),
        "profile" => cmd_profile(args),
        "info" => cmd_info(args),
        other => {
            anyhow::bail!("unknown subcommand '{other}' (try --help)")
        }
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut cfg = load_config(args)?;
    if let Some(t) = args.get("timeline") {
        cfg.timeline_mode = t.to_string();
        cfg.validate()?;
    }
    let timeline_mode = epsl::timeline::Mode::parse(&cfg.timeline_mode)?;
    let phi = args.f64("phi")?.unwrap_or(0.5);
    let fw = parse_framework(args.get("framework").unwrap_or("epsl"), phi)
        .map_err(|e| anyhow::anyhow!(e))?;
    let lr = args.f64("lr")?.unwrap_or(0.1) as f32;
    let rounds = args.usize("rounds")?.unwrap_or(200);
    // Dynamic-channel mode: the `[scenario]` config section, overridable
    // (and implicitly enabled) by the --dynamic-channel/--redraw/--reopt
    // flags.
    let mut scn = cfg.scenario.clone();
    if args.has("dynamic-channel") {
        scn.enabled = true;
    }
    if let Some(k) = args.usize("redraw")? {
        scn.redraw_period = k;
        scn.enabled = true;
    }
    if let Some(p) = args.get("reopt") {
        scn.reopt = p.to_string();
        scn.enabled = true;
    }
    let dynamic_channel = if scn.enabled {
        Some(DynamicChannel::from_settings(&scn, rounds)?)
    } else {
        None
    };
    // Fault injection: the `[faults]` config section, overridable (and
    // implicitly enabled) by --faults with scheduled events.
    let mut fts = cfg.faults.clone();
    if let Some(events) = args.get("faults") {
        fts.events = events.to_string();
        fts.enabled = true;
    }
    let faults = if fts.enabled {
        Some(FaultSpec::from_settings(&fts)?)
    } else {
        None
    };
    // Cut assignment: --cut takes a uniform layer, "hetero", or an
    // explicit per-client vector; the `[optim] cut` TOML knob is the
    // flagless default.
    let cut_spec = args
        .get("cut")
        .map(str::to_string)
        .unwrap_or_else(|| cfg.optim.cut.clone());
    let (cut_mode, uniform_cut) = CutMode::parse(&cut_spec)?;
    let opts = TrainerOptions {
        family: args.get("family").unwrap_or("mnist").to_string(),
        framework: fw,
        n_clients: args.usize("clients")?.unwrap_or(5),
        cut: uniform_cut.unwrap_or(2),
        cut_mode,
        iid: !args.has("non-iid"),
        dataset_size: args.usize("dataset")?.unwrap_or(2000),
        rounds,
        eta_c: lr,
        eta_s: lr,
        seed: args.usize("seed")?.unwrap_or(2023) as u64,
        optimize_resources: args.has("optimize"),
        dynamic_channel,
        timeline_mode,
        faults,
        checkpoint_every: args.usize("checkpoint-every")?.unwrap_or(0),
        checkpoint_path: args.get("checkpoint").map(str::to_string),
        ..Default::default()
    };
    let sel = pick_backend(&cfg)?;
    let cut_desc = match &opts.cut_mode {
        CutMode::Uniform => opts.cut.to_string(),
        _ => cut_spec.clone(),
    };
    println!(
        "training {} C={} cut={} rounds={} family={} timeline={}",
        opts.framework.name(),
        opts.n_clients,
        cut_desc,
        opts.rounds,
        opts.family,
        opts.timeline_mode.name()
    );
    let run = match args.get("resume") {
        Some(path) => {
            let ck = Checkpoint::load(path)?;
            println!("resuming from {} at round {}", path, ck.next_round);
            resume(sel.backend.as_ref(), &sel.manifest, &cfg, &opts, &ck)?
        }
        None => train(sel.backend.as_ref(), &sel.manifest, &cfg, &opts)?,
    };
    for r in &run.rounds {
        if let Some(acc) = r.test_acc {
            println!(
                "round {:>4}: loss {:.4}  train {:.3}  test {:.3}  sim {:.2}s",
                r.round, r.loss, r.train_acc, acc, r.sim_latency
            );
        }
    }
    if opts.faults.is_some() {
        let (inj, ret, drop): (usize, usize, usize) = run.rounds.iter().fold(
            (0, 0, 0),
            |(i, r, d), rec| {
                (i + rec.faults.injected,
                 r + rec.faults.retries,
                 d + rec.faults.dropped)
            },
        );
        let recov: f64 =
            run.rounds.iter().map(|r| r.faults.recovery_s).sum();
        println!(
            "faults: injected {inj}, retries {ret}, dropped {drop}, \
             recovery {recov:.3}s"
        );
    }
    println!(
        "converged accuracy {:.3}; total simulated latency {:.1}s",
        run.converged_accuracy(3),
        run.total_latency()
    );
    Ok(())
}

fn cmd_optimize(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let mut net = cfg.net.clone();
    if let Some(c) = args.usize("clients")? {
        net.n_clients = c;
    }
    let profile = resnet18::profile();
    let mut rng = Rng::new(args.usize("seed")?.unwrap_or(11) as u64);
    let dep = Deployment::generate(&net, &mut rng);
    let ch = ChannelRealization::average(&dep);
    let prob = Problem {
        cfg: &net,
        profile: &profile,
        dep: &dep,
        ch: &ch,
        batch: cfg.train.batch,
        phi: args.f64("phi")?.unwrap_or(cfg.train.phi),
    };
    let scheme = match args.get("scheme").unwrap_or("proposed") {
        "a" => Scheme::BaselineA,
        "b" => Scheme::BaselineB,
        "c" => Scheme::BaselineC,
        "d" => Scheme::BaselineD,
        _ => Scheme::Proposed,
    };
    let d = if scheme == Scheme::Proposed {
        let res = bcd::solve(&prob, bcd::BcdOptions::default())?;
        println!(
            "BCD converged in {} iterations; trajectory: {:?}",
            res.iterations,
            res.trajectory
                .iter()
                .map(|t| format!("{t:.3}"))
                .collect::<Vec<_>>()
        );
        res.decision
    } else {
        let mut srng = Rng::new(7);
        baselines::solve(&prob, scheme, &mut srng)?
    };
    let s = prob.stage_latencies(&d);
    let cut = d.uniform_cut()?;
    println!("scheme: {}", scheme.name());
    println!("cut layer: {} ({})", cut, profile.layers[cut - 1].name);
    let mut t = Table::new("per-client allocation").header(&[
        "client", "f (GHz)", "d (m)", "channels", "power (W)", "T_F+T_U (s)",
        "T_D+T_B (s)",
    ]);
    for i in 0..prob.n_clients() {
        t.row(&[
            i.to_string(),
            format!("{:.2}", dep.clients[i].f_client / 1e9),
            format!("{:.0}", dep.clients[i].distance_m),
            d.alloc.count_of(i).to_string(),
            format!("{:.3}", prob.client_power_w(&d, i)),
            format!("{:.3}", s.client_fp[i] + s.uplink[i]),
            format!("{:.3}", s.downlink[i] + s.client_bp[i]),
        ]);
    }
    println!("{}", t.render());
    println!(
        "round latency: {:.3}s  (uplink phase {:.3} + server fp {:.3} + \
         server bp {:.3} + broadcast {:.3} + downlink phase {:.3})",
        s.round_total(),
        s.uplink_phase_max(),
        s.server_fp,
        s.server_bp,
        s.broadcast,
        s.downlink_phase_max()
    );
    Ok(())
}

fn cmd_figures(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    let out = args.get("out").unwrap_or("results").to_string();
    let quick = !args.has("full");
    // Backend selection (auto prefers PJRT artifacts, falls back to the
    // native backend). Latency-only figures need no backend at all, so a
    // failed explicit choice (e.g. --backend pjrt without artifacts)
    // degrades to a no-backend context instead of blocking them;
    // training-backed ids then fail with the usual Ctx::runtime error.
    let sel = match pick_backend(&cfg) {
        Ok(sel) => Some(sel),
        Err(e) => {
            eprintln!(
                "backend unavailable ({e}); latency-only figures still run"
            );
            None
        }
    };
    let mut ctx = Ctx::new(
        cfg,
        sel.as_ref().map(|s| s.backend.as_ref()),
        sel.as_ref().map(|s| &s.manifest),
        &out,
        quick,
    );
    if args.has("all") {
        // One failed figure must not abort the sweep: failures are
        // collected, reported at the end, and propagate a non-zero exit.
        experiments::run_all(&mut ctx)?;
    } else if let Some(id) = args.get("id") {
        experiments::run(id, &mut ctx)?;
    } else {
        anyhow::bail!("figures: pass --id <id> or --all");
    }
    Ok(())
}

fn cmd_profile(_args: &Args) -> anyhow::Result<()> {
    for p in [
        resnet18::profile(),
        splitnet::profile(splitnet::SplitNetConfig::mnist_like()),
    ] {
        let mut t = Table::new(p.name).header(&[
            "layer", "params (MiB)", "FP (MFLOP)", "smashed (MiB)",
        ]);
        for l in &p.layers {
            t.row(&[
                l.name.to_string(),
                format!("{:.4}", l.params_mib),
                format!("{:.4}", l.fp_mflops),
                format!("{:.4}", l.smashed_mib),
            ]);
        }
        println!("{}", t.render());
        println!(
            "total: {:.2} MFLOP fwd, {:.2} MiB params, cuts {:?}\n",
            p.rho_total() / 1e6,
            p.model_bits() / 8.0 / 1024.0 / 1024.0,
            p.cut_candidates
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let cfg = load_config(args)?;
    match Manifest::load(&cfg.artifacts_dir) {
        Ok(m) => {
            println!("artifacts: {}", cfg.artifacts_dir);
            println!("client counts: {:?}", m.client_counts);
            println!("cuts: {:?}", m.cuts);
            for (name, fam) in &m.families {
                println!(
                    "family {name}: {} params ({} tensors), batch {}, \
                     {} classes",
                    fam.param_elements(),
                    fam.params.len(),
                    fam.batch,
                    fam.num_classes
                );
            }
        }
        Err(e) => println!("no artifacts: {e}"),
    }
    // Report what the configured backend choice resolves to (this also
    // covers PJRT availability — `describe()` names the platform). info
    // is a diagnostic command: selection failure is a status line, not
    // an error.
    match BackendChoice::parse(&cfg.backend)
        .and_then(|c| {
            let tier = MathTier::parse(&cfg.math_tier)?;
            select_backend_with(&cfg.artifacts_dir, c, tier)
        })
    {
        Ok(sel) => println!(
            "backend ({}): {} — {} famil{} available",
            cfg.backend,
            sel.describe(),
            sel.manifest.families.len(),
            if sel.manifest.families.len() == 1 { "y" } else { "ies" }
        ),
        Err(e) => println!("backend ({}): unavailable — {e}", cfg.backend),
    }
    Ok(())
}
