//! Experiment configuration: the paper's Table III defaults, overridable
//! from a TOML-subset file and CLI flags.

pub mod cli;
pub mod toml;

use crate::error::{Error, Result};

/// Convert dBm to linear milliwatts-equivalent (mW).
pub fn dbm_to_mw(dbm: f64) -> f64 {
    10f64.powf(dbm / 10.0)
}

/// Convert dBm to watts.
pub fn dbm_to_w(dbm: f64) -> f64 {
    dbm_to_mw(dbm) * 1e-3
}

/// Convert dB to a linear power ratio.
pub fn db_to_lin(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// Convert a linear power ratio to dB.
pub fn lin_to_db(lin: f64) -> f64 {
    10.0 * lin.log10()
}

/// Wireless + compute deployment parameters (paper Table III).
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkConfig {
    /// Number of participating client devices C.
    pub n_clients: usize,
    /// Number of subchannels M.
    pub n_subchannels: usize,
    /// Per-subchannel bandwidth B_k (Hz). Total bandwidth = M * B.
    pub subchannel_bw_hz: f64,
    /// Lowest subchannel center frequency (Hz) — mmWave band.
    pub base_freq_hz: f64,
    /// Server computing capability f_s (cycles/s).
    pub f_server: f64,
    /// Client computing capability range [lo, hi] (cycles/s); clients draw
    /// uniformly (Table III: [1, 1.6]x10^9).
    pub f_client_range: (f64, f64),
    /// Server computing intensity κ_s (cycles/FLOP).
    pub kappa_server: f64,
    /// Client computing intensity κ (cycles/FLOP).
    pub kappa_client: f64,
    /// Server transmit PSD p^DL (dBm/Hz).
    pub p_dl_dbm_hz: f64,
    /// Noise PSD σ² (dBm/Hz).
    pub noise_dbm_hz: f64,
    /// Combined antenna gain G_c * G_s (linear).
    pub antenna_gain: f64,
    /// Coverage radius d_max (m).
    pub d_max_m: f64,
    /// Per-device max transmit power p^max (dBm).
    pub p_max_dbm: f64,
    /// Total uplink power threshold p_th (dBm).
    pub p_th_dbm: f64,
    /// Uplink activation-payload compression factor in (0, 1]: the
    /// smashed-activation bits per sample are multiplied by this before
    /// entering the rate equation (eq. 15). 1.0 = raw f32 payloads
    /// (bit-identical to the uncompressed model); 0.5 models f16, 0.25
    /// models int8 quantization. Modeled latency only — training
    /// numerics are untouched.
    pub uplink_compression: f64,
}

impl Default for NetworkConfig {
    fn default() -> Self {
        NetworkConfig {
            n_clients: 5,
            n_subchannels: 20,
            subchannel_bw_hz: 10e6,
            base_freq_hz: 28e9,
            f_server: 5e9,
            f_client_range: (1e9, 1.6e9),
            kappa_server: 1.0 / 32.0,
            kappa_client: 1.0 / 16.0,
            p_dl_dbm_hz: -50.0,
            noise_dbm_hz: -174.0,
            antenna_gain: 10.0,
            d_max_m: 200.0,
            p_max_dbm: 31.76,
            p_th_dbm: 36.99,
            uplink_compression: 1.0,
        }
    }
}

impl NetworkConfig {
    /// Total system bandwidth (Hz).
    pub fn total_bandwidth_hz(&self) -> f64 {
        self.n_subchannels as f64 * self.subchannel_bw_hz
    }

    /// Rescale to a different total bandwidth keeping M fixed (Fig. 11).
    pub fn with_total_bandwidth(mut self, hz: f64) -> Self {
        self.subchannel_bw_hz = hz / self.n_subchannels as f64;
        self
    }

    /// Set the client count, growing M so every client can own at least
    /// one subchannel (the paper's implicit serving assumption — latency
    /// is unbounded for an unserved client). The single home for the
    /// clamp the driver / figure sweeps / scenario engine all need.
    pub fn with_clients(mut self, n: usize) -> Self {
        self.n_clients = n;
        if self.n_subchannels < n {
            self.n_subchannels = n;
        }
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_clients == 0 {
            return Err(Error::Config("n_clients must be > 0".into()));
        }
        if self.n_subchannels < self.n_clients {
            return Err(Error::Config(format!(
                "need at least one subchannel per client: M={} < C={}",
                self.n_subchannels, self.n_clients
            )));
        }
        if self.subchannel_bw_hz <= 0.0 || self.f_server <= 0.0 {
            return Err(Error::Config("bandwidth/compute must be > 0".into()));
        }
        let (lo, hi) = self.f_client_range;
        if lo <= 0.0 || hi < lo {
            return Err(Error::Config("bad client compute range".into()));
        }
        let c = self.uplink_compression;
        if !c.is_finite() || c <= 0.0 || c > 1.0 {
            return Err(Error::Config(format!(
                "net.uplink_compression={c} out of (0,1]"
            )));
        }
        Ok(())
    }
}

/// Training-procedure parameters (paper Table III + §VII-A).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Mini-batch size b used by the *latency model* (paper: 64).
    pub batch: usize,
    /// Aggregation ratio φ ∈ [0, 1].
    pub phi: f64,
    /// Client-side learning rate η_c.
    pub eta_c: f64,
    /// Server-side learning rate η_s.
    pub eta_s: f64,
    /// Total dataset size D (samples across all clients).
    pub dataset_size: usize,
    /// Number of training rounds to run.
    pub rounds: usize,
    /// Dataset family: "mnist" or "ham" (synthetic analogues).
    pub family: String,
    /// IID vs non-IID (2 classes per client) sharding.
    pub iid: bool,
    /// RNG seed for the whole experiment.
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            batch: 64,
            phi: 0.5,
            eta_c: 1.5e-4,
            eta_s: 1e-4,
            dataset_size: 8000,
            rounds: 300,
            family: "ham".into(),
            iid: true,
            seed: 2023,
        }
    }
}

impl TrainConfig {
    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.phi) {
            return Err(Error::Config(format!("phi={} out of [0,1]", self.phi)));
        }
        if self.batch == 0 || self.rounds == 0 {
            return Err(Error::Config("batch/rounds must be > 0".into()));
        }
        if self.family != "mnist" && self.family != "ham" {
            return Err(Error::Config(format!(
                "unknown family '{}' (mnist|ham)",
                self.family
            )));
        }
        Ok(())
    }

    /// ⌈φb⌉ — number of aggregated sample slots.
    pub fn aggregated_count(&self) -> usize {
        // audit:allow(R6, "exact for the validated domain: phi in [0,1] and batch >= 1 bound the product to [0, batch]")
        (self.phi * self.batch as f64).ceil() as usize
    }
}

/// Opt-in per-round network dynamics for the training driver's latency
/// accounting (`scenario` module; knobs documented in EXPERIMENTS.md).
/// Plain data here — the `scenario` module turns it into a typed
/// `ScenarioSpec` + `ReoptPolicy` so config stays dependency-free.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSettings {
    /// Master switch for the dynamic-channel training mode.
    pub enabled: bool,
    /// Block-fading redraw period in rounds (0 = channel stays at the
    /// deterministic average gains).
    pub redraw_period: usize,
    /// Per-round LoS↔NLoS Markov flip probability scale (0 disables).
    pub los_flip_prob: f64,
    /// Client compute jitter amplitude as a fraction of f_i (0 disables).
    pub compute_jitter: f64,
    /// Per-round client dropout probability (0 disables churn).
    pub drop_prob: f64,
    /// Per-round re-arrival probability for dropped clients.
    pub rejoin_prob: f64,
    /// Churn never drops the active set below this many clients.
    pub min_active: usize,
    /// Re-optimization policy: "never" | "every:<k>" | "regress:<x>" |
    /// "oracle" (parsed by `scenario::ReoptPolicy::parse`).
    pub reopt: String,
}

impl Default for ScenarioSettings {
    fn default() -> Self {
        ScenarioSettings {
            enabled: false,
            redraw_period: 1,
            los_flip_prob: 0.0,
            compute_jitter: 0.0,
            drop_prob: 0.0,
            rejoin_prob: 0.0,
            min_active: 1,
            reopt: "never".into(),
        }
    }
}

impl ScenarioSettings {
    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("los_flip_prob", self.los_flip_prob),
            ("drop_prob", self.drop_prob),
            ("rejoin_prob", self.rejoin_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(Error::Config(format!(
                    "scenario.{name}={p} out of [0,1]"
                )));
            }
        }
        if !(0.0..1.0).contains(&self.compute_jitter) {
            return Err(Error::Config(format!(
                "scenario.compute_jitter={} out of [0,1)",
                self.compute_jitter
            )));
        }
        if self.min_active == 0 {
            return Err(Error::Config(
                "scenario.min_active must be > 0".into(),
            ));
        }
        Ok(())
    }
}

/// Cut-assignment knobs for the training driver (`[optim]` TOML table).
/// Plain data here — the CLI/driver boundary parses `cut` into a typed
/// `coordinator::CutMode` so config stays dependency-free, mirroring
/// [`ScenarioSettings::reopt`].
#[derive(Debug, Clone, PartialEq)]
pub struct OptimSettings {
    /// Cut spec: a single SplitNet cut (`"2"`), `"hetero"` for the
    /// per-client refinement pass, or an explicit per-client vector
    /// (`"1-2-2-3"`). Parsed by `coordinator::CutMode::parse`.
    pub cut: String,
}

impl Default for OptimSettings {
    fn default() -> Self {
        OptimSettings { cut: "2".into() }
    }
}

/// Opt-in fault injection + resilience policy for the training driver
/// (`scenario::faults`; knobs documented in EXPERIMENTS.md). Plain data
/// here — `scenario::FaultSpec::from_settings` turns it into the typed
/// spec so config stays dependency-free, mirroring [`ScenarioSettings`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSettings {
    /// Master switch for fault injection.
    pub enabled: bool,
    /// Scheduled events, comma-separated: `crash@r:c`, `delay@r:c:s`,
    /// `corrupt@r:c`, `abort@r` (parsed by `FaultSpec::parse_events`).
    pub events: String,
    /// Per-client per-round crash probability.
    pub crash_prob: f64,
    /// Per-client per-round delayed-uplink probability.
    pub delay_prob: f64,
    /// Delay seconds applied by probabilistic delay events.
    pub delay_s: f64,
    /// Per-client per-round corrupted-payload probability.
    pub corrupt_prob: f64,
    /// Per-round server-abort probability.
    pub abort_prob: f64,
    /// Minimum surviving cohort a round may commit with.
    pub quorum: usize,
    /// Bounded retries for transient faults (0 = drop instead).
    pub max_retries: usize,
    /// Base backoff seconds charged per retry.
    pub retry_backoff_s: f64,
    /// Straggler deadline as a multiple of the round's nominal slowest
    /// uplink arrival (>= 1).
    pub deadline_factor: f64,
}

impl Default for FaultSettings {
    fn default() -> Self {
        FaultSettings {
            enabled: false,
            events: String::new(),
            crash_prob: 0.0,
            delay_prob: 0.0,
            delay_s: 0.5,
            corrupt_prob: 0.0,
            abort_prob: 0.0,
            quorum: 1,
            max_retries: 2,
            retry_backoff_s: 0.05,
            deadline_factor: 1.5,
        }
    }
}

impl FaultSettings {
    /// Range checks on the plain knobs. Event-string syntax and
    /// round/client bounds are checked by `FaultSpec` at expansion time,
    /// when the run shape is known.
    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("crash_prob", self.crash_prob),
            ("delay_prob", self.delay_prob),
            ("corrupt_prob", self.corrupt_prob),
            ("abort_prob", self.abort_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(Error::Config(format!(
                    "faults.{name}={p} out of [0,1]"
                )));
            }
        }
        for (name, v) in [
            ("delay_s", self.delay_s),
            ("retry_backoff_s", self.retry_backoff_s),
        ] {
            if !v.is_finite() || v < 0.0 {
                return Err(Error::Config(format!(
                    "faults.{name}={v} must be finite and >= 0"
                )));
            }
        }
        if !self.deadline_factor.is_finite() || self.deadline_factor < 1.0 {
            return Err(Error::Config(format!(
                "faults.deadline_factor={} must be >= 1",
                self.deadline_factor
            )));
        }
        if self.quorum == 0 {
            return Err(Error::Config("faults.quorum must be > 0".into()));
        }
        Ok(())
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub net: NetworkConfig,
    pub train: TrainConfig,
    pub scenario: ScenarioSettings,
    pub faults: FaultSettings,
    pub optim: OptimSettings,
    /// Execution backend: "auto" (PJRT artifacts when present, else the
    /// pure-Rust native backend), "native", or "pjrt". TOML:
    /// `[backend] mode = "native"` (or a top-level `backend = "native"`);
    /// CLI: `--backend`.
    pub backend: String,
    /// Native-backend compute tier: "bitwise" (default — bit-identical
    /// to the reference oracles, EPSL_THREADS-invariant) or "fast" (SIMD
    /// + threaded GEMM, tolerance contract; PERF.md §10). Plain string
    /// here — `runtime::MathTier::parse` constructs the typed tier at
    /// the CLI/driver boundary so config stays dependency-free. TOML:
    /// `[backend] math_tier = "fast"` (or top-level `math_tier`); CLI:
    /// `--math-tier`.
    pub math_tier: String,
    /// Latency timeline mode: "barrier" (eq. 23 phase synchronization,
    /// bit-identical to the closed forms) or "pipelined" (per-client /
    /// per-link overlap). TOML: `[timeline] mode = "pipelined"` (or a
    /// top-level `timeline = "pipelined"`); CLI: `--timeline`.
    pub timeline_mode: String,
    /// Artifact directory (default "artifacts").
    pub artifacts_dir: String,
    /// Results directory (default "results").
    pub results_dir: String,
}

impl Config {
    pub fn new() -> Self {
        Config {
            net: NetworkConfig::default(),
            train: TrainConfig::default(),
            scenario: ScenarioSettings::default(),
            faults: FaultSettings::default(),
            optim: OptimSettings::default(),
            backend: "auto".into(),
            math_tier: "bitwise".into(),
            timeline_mode: "barrier".into(),
            artifacts_dir: "artifacts".into(),
            results_dir: "results".into(),
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !matches!(self.backend.as_str(), "auto" | "native" | "pjrt") {
            return Err(Error::Config(format!(
                "backend '{}' unknown (auto|native|pjrt)",
                self.backend
            )));
        }
        // Mirrors `runtime::MathTier::parse` (config sits below runtime
        // in the layering DAG, so it validates the spelling without
        // constructing the tier; `tier_parse_roundtrip_and_default` in
        // the kernels_fast tests pins the two accept sets together).
        if !matches!(self.math_tier.as_str(), "bitwise" | "fast") {
            return Err(Error::Config(format!(
                "math tier '{}' unknown (bitwise|fast)",
                self.math_tier
            )));
        }
        // Mirrors `timeline::Mode::parse` (config sits below timeline in
        // the layering DAG, so it validates the spelling without
        // constructing the mode; `timeline_mode_matches_mode_parse` in
        // the timeline tests pins the two accept sets together).
        if !matches!(self.timeline_mode.as_str(), "barrier" | "pipelined") {
            return Err(Error::Config(format!(
                "timeline mode '{}' unknown (barrier|pipelined)",
                self.timeline_mode
            )));
        }
        self.net.validate()?;
        self.train.validate()?;
        self.scenario.validate()?;
        self.faults.validate()
    }

    /// Apply overrides from a parsed TOML doc (keys mirror field paths,
    /// e.g. `net.n_clients`, `train.phi`, `artifacts_dir`).
    pub fn apply_toml(&mut self, doc: &toml::Doc) -> Result<()> {
        let d = doc;
        if let Some(v) = d.usize("net.n_clients") {
            self.net.n_clients = v;
        }
        if let Some(v) = d.usize("net.n_subchannels") {
            self.net.n_subchannels = v;
        }
        if let Some(v) = d.f64("net.subchannel_bw_hz") {
            self.net.subchannel_bw_hz = v;
        }
        if let Some(v) = d.f64("net.base_freq_hz") {
            self.net.base_freq_hz = v;
        }
        if let Some(v) = d.f64("net.f_server") {
            self.net.f_server = v;
        }
        if let Some(v) = d.f64("net.f_client_lo") {
            self.net.f_client_range.0 = v;
        }
        if let Some(v) = d.f64("net.f_client_hi") {
            self.net.f_client_range.1 = v;
        }
        if let Some(v) = d.f64("net.kappa_server") {
            self.net.kappa_server = v;
        }
        if let Some(v) = d.f64("net.kappa_client") {
            self.net.kappa_client = v;
        }
        if let Some(v) = d.f64("net.p_dl_dbm_hz") {
            self.net.p_dl_dbm_hz = v;
        }
        if let Some(v) = d.f64("net.noise_dbm_hz") {
            self.net.noise_dbm_hz = v;
        }
        if let Some(v) = d.f64("net.antenna_gain") {
            self.net.antenna_gain = v;
        }
        if let Some(v) = d.f64("net.d_max_m") {
            self.net.d_max_m = v;
        }
        if let Some(v) = d.f64("net.p_max_dbm") {
            self.net.p_max_dbm = v;
        }
        if let Some(v) = d.f64("net.p_th_dbm") {
            self.net.p_th_dbm = v;
        }
        if let Some(v) = d.f64("net.uplink_compression") {
            self.net.uplink_compression = v;
        }
        if let Some(v) = d.usize("train.batch") {
            self.train.batch = v;
        }
        if let Some(v) = d.f64("train.phi") {
            self.train.phi = v;
        }
        if let Some(v) = d.f64("train.eta_c") {
            self.train.eta_c = v;
        }
        if let Some(v) = d.f64("train.eta_s") {
            self.train.eta_s = v;
        }
        if let Some(v) = d.usize("train.dataset_size") {
            self.train.dataset_size = v;
        }
        if let Some(v) = d.usize("train.rounds") {
            self.train.rounds = v;
        }
        if let Some(v) = d.str("train.family") {
            self.train.family = v.to_string();
        }
        if let Some(v) = d.bool("train.iid") {
            self.train.iid = v;
        }
        if let Some(v) = d.usize("train.seed") {
            self.train.seed = v as u64;
        }
        if let Some(v) = d.bool("scenario.enabled") {
            self.scenario.enabled = v;
        }
        if let Some(v) = d.usize("scenario.redraw_period") {
            self.scenario.redraw_period = v;
        }
        if let Some(v) = d.f64("scenario.los_flip_prob") {
            self.scenario.los_flip_prob = v;
        }
        if let Some(v) = d.f64("scenario.compute_jitter") {
            self.scenario.compute_jitter = v;
        }
        if let Some(v) = d.f64("scenario.drop_prob") {
            self.scenario.drop_prob = v;
        }
        if let Some(v) = d.f64("scenario.rejoin_prob") {
            self.scenario.rejoin_prob = v;
        }
        if let Some(v) = d.usize("scenario.min_active") {
            self.scenario.min_active = v;
        }
        if let Some(v) = d.str("scenario.reopt") {
            self.scenario.reopt = v.to_string();
        }
        if let Some(v) = d.bool("faults.enabled") {
            self.faults.enabled = v;
        }
        if let Some(v) = d.str("faults.events") {
            self.faults.events = v.to_string();
        }
        if let Some(v) = d.f64("faults.crash_prob") {
            self.faults.crash_prob = v;
        }
        if let Some(v) = d.f64("faults.delay_prob") {
            self.faults.delay_prob = v;
        }
        if let Some(v) = d.f64("faults.delay_s") {
            self.faults.delay_s = v;
        }
        if let Some(v) = d.f64("faults.corrupt_prob") {
            self.faults.corrupt_prob = v;
        }
        if let Some(v) = d.f64("faults.abort_prob") {
            self.faults.abort_prob = v;
        }
        if let Some(v) = d.usize("faults.quorum") {
            self.faults.quorum = v;
        }
        if let Some(v) = d.usize("faults.max_retries") {
            self.faults.max_retries = v;
        }
        if let Some(v) = d.f64("faults.retry_backoff_s") {
            self.faults.retry_backoff_s = v;
        }
        if let Some(v) = d.f64("faults.deadline_factor") {
            self.faults.deadline_factor = v;
        }
        if let Some(v) = d.str("optim.cut") {
            self.optim.cut = v.to_string();
        }
        if let Some(v) = d.str("backend").or_else(|| d.str("backend.mode")) {
            self.backend = v.to_string();
        }
        if let Some(v) =
            d.str("math_tier").or_else(|| d.str("backend.math_tier"))
        {
            self.math_tier = v.to_string();
        }
        if let Some(v) =
            d.str("timeline").or_else(|| d.str("timeline.mode"))
        {
            self.timeline_mode = v.to_string();
        }
        if let Some(v) = d.str("artifacts_dir") {
            self.artifacts_dir = v.to_string();
        }
        if let Some(v) = d.str("results_dir") {
            self.results_dir = v.to_string();
        }
        self.validate()
    }

    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("{path}: {e}")))?;
        let doc = toml::parse(&text)?;
        let mut cfg = Config::new();
        cfg.apply_toml(&doc)?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table_iii() {
        let c = Config::new();
        assert_eq!(c.net.n_clients, 5);
        assert_eq!(c.net.n_subchannels, 20);
        assert_eq!(c.net.subchannel_bw_hz, 10e6);
        assert_eq!(c.net.total_bandwidth_hz(), 200e6);
        assert_eq!(c.net.f_server, 5e9);
        assert_eq!(c.net.kappa_server, 1.0 / 32.0);
        assert_eq!(c.net.kappa_client, 1.0 / 16.0);
        assert_eq!(c.net.p_max_dbm, 31.76);
        assert_eq!(c.net.p_th_dbm, 36.99);
        assert_eq!(c.train.batch, 64);
        assert_eq!(c.train.eta_c, 1.5e-4);
        assert_eq!(c.train.eta_s, 1e-4);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn db_conversions() {
        assert!((dbm_to_mw(0.0) - 1.0).abs() < 1e-12);
        assert!((dbm_to_mw(30.0) - 1000.0).abs() < 1e-9);
        assert!((dbm_to_w(30.0) - 1.0).abs() < 1e-12);
        assert!((db_to_lin(10.0) - 10.0).abs() < 1e-12);
        assert!((lin_to_db(100.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn p_max_matches_1_5_watt() {
        // 31.76 dBm ≈ 1.5 W (sanity on the paper's constant)
        assert!((dbm_to_w(31.76) - 1.5).abs() < 0.01);
        // 36.99 dBm ≈ 5 W total threshold
        assert!((dbm_to_w(36.99) - 5.0).abs() < 0.02);
    }

    #[test]
    fn aggregated_count_ceil() {
        let mut t = TrainConfig::default();
        t.batch = 64;
        t.phi = 0.5;
        assert_eq!(t.aggregated_count(), 32);
        t.phi = 0.01;
        assert_eq!(t.aggregated_count(), 1);
        t.phi = 0.0;
        assert_eq!(t.aggregated_count(), 0);
        t.phi = 1.0;
        assert_eq!(t.aggregated_count(), 64);
    }

    #[test]
    fn validation_rejects_bad() {
        let mut c = Config::new();
        c.train.phi = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::new();
        c.net.n_subchannels = 2; // < n_clients
        assert!(c.validate().is_err());
        let mut c = Config::new();
        c.train.family = "cifar".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn toml_overrides() {
        let doc = toml::parse(
            "[net]\nn_clients = 10\nf_server = 7e9\n[train]\nphi = 0.25\nfamily = \"mnist\"\n",
        )
        .unwrap();
        let mut c = Config::new();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.net.n_clients, 10);
        assert_eq!(c.net.f_server, 7e9);
        assert_eq!(c.train.phi, 0.25);
        assert_eq!(c.train.family, "mnist");
    }

    #[test]
    fn with_total_bandwidth_rescales() {
        let n = NetworkConfig::default().with_total_bandwidth(100e6);
        assert!((n.subchannel_bw_hz - 5e6).abs() < 1.0);
        assert!((n.total_bandwidth_hz() - 100e6).abs() < 1.0);
    }

    #[test]
    fn with_clients_clamps_subchannels() {
        // The one shared home for the M >= C clamp (previously hand-rolled
        // in driver.rs and latency_figs.rs).
        let n = NetworkConfig::default().with_clients(30);
        assert_eq!(n.n_clients, 30);
        assert_eq!(n.n_subchannels, 30);
        assert!(n.validate().is_ok());
        // Below the default M the subchannel plan is untouched.
        let n = NetworkConfig::default().with_clients(3);
        assert_eq!(n.n_clients, 3);
        assert_eq!(n.n_subchannels, 20);
    }

    #[test]
    fn backend_from_toml_and_validated() {
        let mut c = Config::new();
        assert_eq!(c.backend, "auto");
        c.apply_toml(&toml::parse("[backend]\nmode = \"native\"\n").unwrap())
            .unwrap();
        assert_eq!(c.backend, "native");
        c.apply_toml(&toml::parse("backend = \"pjrt\"\n").unwrap())
            .unwrap();
        assert_eq!(c.backend, "pjrt");
        let e = c
            .apply_toml(&toml::parse("backend = \"tpu\"\n").unwrap())
            .unwrap_err();
        assert!(e.to_string().contains("auto|native|pjrt"), "{e}");
    }

    #[test]
    fn math_tier_from_toml_and_validated() {
        let mut c = Config::new();
        assert_eq!(c.math_tier, "bitwise");
        c.apply_toml(
            &toml::parse("[backend]\nmath_tier = \"fast\"\n").unwrap(),
        )
        .unwrap();
        assert_eq!(c.math_tier, "fast");
        c.apply_toml(&toml::parse("math_tier = \"bitwise\"\n").unwrap())
            .unwrap();
        assert_eq!(c.math_tier, "bitwise");
        let e = c
            .apply_toml(&toml::parse("math_tier = \"turbo\"\n").unwrap())
            .unwrap_err();
        assert!(e.to_string().contains("bitwise|fast"), "{e}");
    }

    #[test]
    fn uplink_compression_from_toml_and_validated() {
        let mut c = Config::new();
        assert_eq!(c.net.uplink_compression, 1.0);
        c.apply_toml(
            &toml::parse("[net]\nuplink_compression = 0.5\n").unwrap(),
        )
        .unwrap();
        assert_eq!(c.net.uplink_compression, 0.5);
        for bad in [0.0, -0.5, 1.5, f64::NAN] {
            let mut c = Config::new();
            c.net.uplink_compression = bad;
            assert!(c.validate().is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn scenario_settings_from_toml() {
        let doc = toml::parse(
            "[scenario]\nenabled = true\nredraw_period = 4\n\
             los_flip_prob = 0.1\ncompute_jitter = 0.05\n\
             drop_prob = 0.02\nrejoin_prob = 0.5\nmin_active = 2\n\
             reopt = \"every:8\"\n",
        )
        .unwrap();
        let mut c = Config::new();
        c.apply_toml(&doc).unwrap();
        assert!(c.scenario.enabled);
        assert_eq!(c.scenario.redraw_period, 4);
        assert_eq!(c.scenario.los_flip_prob, 0.1);
        assert_eq!(c.scenario.compute_jitter, 0.05);
        assert_eq!(c.scenario.drop_prob, 0.02);
        assert_eq!(c.scenario.rejoin_prob, 0.5);
        assert_eq!(c.scenario.min_active, 2);
        assert_eq!(c.scenario.reopt, "every:8");
    }

    #[test]
    fn timeline_mode_from_toml_and_validated() {
        let mut c = Config::new();
        assert_eq!(c.timeline_mode, "barrier");
        c.apply_toml(
            &toml::parse("[timeline]\nmode = \"pipelined\"\n").unwrap(),
        )
        .unwrap();
        assert_eq!(c.timeline_mode, "pipelined");
        c.apply_toml(&toml::parse("timeline = \"barrier\"\n").unwrap())
            .unwrap();
        assert_eq!(c.timeline_mode, "barrier");
        let e = c
            .apply_toml(&toml::parse("timeline = \"overlap\"\n").unwrap())
            .unwrap_err();
        assert!(e.to_string().contains("barrier|pipelined"), "{e}");
    }

    #[test]
    fn optim_cut_from_toml() {
        let mut c = Config::new();
        assert_eq!(c.optim.cut, "2");
        c.apply_toml(&toml::parse("[optim]\ncut = \"hetero\"\n").unwrap())
            .unwrap();
        assert_eq!(c.optim.cut, "hetero");
        c.apply_toml(&toml::parse("[optim]\ncut = \"1-2-2-3\"\n").unwrap())
            .unwrap();
        assert_eq!(c.optim.cut, "1-2-2-3");
    }

    #[test]
    fn fault_settings_from_toml() {
        let doc = toml::parse(
            "[faults]\nenabled = true\nevents = \"crash@3:1,abort@5\"\n\
             crash_prob = 0.05\ndelay_prob = 0.1\ndelay_s = 1.25\n\
             corrupt_prob = 0.02\nabort_prob = 0.01\nquorum = 2\n\
             max_retries = 3\nretry_backoff_s = 0.1\n\
             deadline_factor = 2.0\n",
        )
        .unwrap();
        let mut c = Config::new();
        c.apply_toml(&doc).unwrap();
        assert!(c.faults.enabled);
        assert_eq!(c.faults.events, "crash@3:1,abort@5");
        assert_eq!(c.faults.crash_prob, 0.05);
        assert_eq!(c.faults.delay_prob, 0.1);
        assert_eq!(c.faults.delay_s, 1.25);
        assert_eq!(c.faults.corrupt_prob, 0.02);
        assert_eq!(c.faults.abort_prob, 0.01);
        assert_eq!(c.faults.quorum, 2);
        assert_eq!(c.faults.max_retries, 3);
        assert_eq!(c.faults.retry_backoff_s, 0.1);
        assert_eq!(c.faults.deadline_factor, 2.0);
    }

    #[test]
    fn fault_settings_validated() {
        let mut c = Config::new();
        c.faults.crash_prob = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::new();
        c.faults.quorum = 0;
        assert!(c.validate().is_err());
        let mut c = Config::new();
        c.faults.deadline_factor = 0.9;
        assert!(c.validate().is_err());
        let mut c = Config::new();
        c.faults.delay_s = -1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn scenario_settings_validated() {
        let mut c = Config::new();
        c.scenario.drop_prob = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::new();
        c.scenario.compute_jitter = 1.0;
        assert!(c.validate().is_err());
        let mut c = Config::new();
        c.scenario.min_active = 0;
        assert!(c.validate().is_err());
    }
}
