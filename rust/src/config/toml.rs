//! TOML-subset parser (no `toml`/`serde` crates offline).
//!
//! Supports the subset the project's config files use:
//! `[section]` headers, `key = value` with string / bool / integer / float /
//! homogeneous-array values, `#` comments, and dotted keys inside sections.
//! Produces a flat map `section.key -> Value`.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Num(f64),
    Arr(Vec<Value>),
}

/// Largest f64 at or below which every integer is exactly
/// representable (2^53). Above this, a "count" read from config has
/// already lost precision in the float, so we refuse it.
const MAX_EXACT_F64: f64 = 9_007_199_254_740_992.0;

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        // Integral values only: `rounds = 2.7` used to silently
        // truncate to 2, and values past 2^53 have already lost
        // integer precision in the f64 — both now read as "wrong type"
        // (None), the same handling every other type mismatch gets.
        self.as_f64()
            .filter(|x| *x >= 0.0 && x.fract() == 0.0 && *x <= MAX_EXACT_F64)
            // audit:allow(R6, "cast is exact: value is a non-negative integer below 2^53, checked on the line above")
            .map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        match self {
            Value::Arr(v) => v.iter().map(Value::as_f64).collect(),
            _ => None,
        }
    }
}

/// Parsed document: flat `section.key` map (root keys have no prefix).
#[derive(Debug, Default, Clone)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_f64)
    }

    pub fn usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(Value::as_usize)
    }

    pub fn bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| {
                Error::Config(format!("line {}: bad section header", ln + 1))
            })?;
            section = name.trim().to_string();
            continue;
        }
        let eq = line.find('=').ok_or_else(|| {
            Error::Config(format!("line {}: expected key = value", ln + 1))
        })?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(Error::Config(format!("line {}: empty key", ln + 1)));
        }
        let value = parse_value(line[eq + 1..].trim()).map_err(|e| {
            Error::Config(format!("line {}: {e}", ln + 1))
        })?;
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.entries.insert(full, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' outside of quotes starts a comment.
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items = inner
            .split(',')
            .map(|it| parse_value(it.trim()))
            .collect::<std::result::Result<Vec<_>, _>>()?;
        return Ok(Value::Arr(items));
    }
    // numbers: allow underscores and scientific notation
    let cleaned = s.replace('_', "");
    cleaned
        .parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
seed = 42
name = "fig11"

[network]
n_clients = 5
bandwidth_mhz = 10.0
subchannels = 20
p_max_dbm = 31.76
freqs = [28.0, 28.01, 28.02]
enabled = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = parse(SAMPLE).unwrap();
        assert_eq!(d.usize("seed"), Some(42));
        assert_eq!(d.str("name"), Some("fig11"));
        assert_eq!(d.usize("network.n_clients"), Some(5));
        assert_eq!(d.f64("network.p_max_dbm"), Some(31.76));
        assert_eq!(d.bool("network.enabled"), Some(true));
        assert_eq!(
            d.get("network.freqs").unwrap().as_f64_vec().unwrap(),
            vec![28.0, 28.01, 28.02]
        );
    }

    #[test]
    fn comments_stripped_outside_strings() {
        let d = parse("a = 1 # comment\nb = \"x # y\"\n").unwrap();
        assert_eq!(d.f64("a"), Some(1.0));
        assert_eq!(d.str("b"), Some("x # y"));
    }

    #[test]
    fn underscores_and_scientific() {
        let d = parse("f = 5_000_000_000\ng = 1.5e-4\n").unwrap();
        assert_eq!(d.f64("f"), Some(5e9));
        assert_eq!(d.f64("g"), Some(1.5e-4));
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = parse("x\n").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        let err = parse("a = 1\n[broken\n").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn empty_array() {
        let d = parse("xs = []\n").unwrap();
        assert_eq!(d.get("xs").unwrap().as_f64_vec().unwrap(), Vec::<f64>::new());
    }
}
