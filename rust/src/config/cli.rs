//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `repro <subcommand> [--flag [value]] [positional…]`.
//! Flags with values: `--key value` or `--key=value`. Boolean flags have no
//! value. Unknown flags are an error (catches typos in experiment scripts).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

/// Flag specification for validation.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

impl Args {
    /// Parse argv (without the binary name) against a flag spec.
    pub fn parse(argv: &[String], specs: &[FlagSpec]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = (*first).clone();
                it.next();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = specs.iter().find(|s| s.name == name).ok_or_else(
                    || Error::Config(format!("unknown flag --{name}")),
                )?;
                let value = if spec.takes_value {
                    match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| {
                                Error::Config(format!(
                                    "flag --{name} requires a value"
                                ))
                            })?
                            .clone(),
                    }
                } else {
                    if inline_val.is_some() {
                        return Err(Error::Config(format!(
                            "flag --{name} takes no value"
                        )));
                    }
                    "true".to_string()
                };
                out.flags.insert(name, value);
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn f64(&self, name: &str) -> Result<Option<f64>> {
        self.get(name)
            .map(|v| {
                v.parse::<f64>().map_err(|_| {
                    Error::Config(format!("--{name}: expected number, got '{v}'"))
                })
            })
            .transpose()
    }

    pub fn usize(&self, name: &str) -> Result<Option<usize>> {
        self.get(name)
            .map(|v| {
                v.parse::<usize>().map_err(|_| {
                    Error::Config(format!(
                        "--{name}: expected integer, got '{v}'"
                    ))
                })
            })
            .transpose()
    }
}

/// Render a help string from specs.
pub fn render_help(prog: &str, subcommands: &[(&str, &str)],
                   specs: &[FlagSpec]) -> String {
    let mut s = format!("usage: {prog} <subcommand> [flags]\n\nsubcommands:\n");
    for (name, help) in subcommands {
        s.push_str(&format!("  {name:<18} {help}\n"));
    }
    s.push_str("\nflags:\n");
    for f in specs {
        let v = if f.takes_value { " <v>" } else { "" };
        s.push_str(&format!("  --{}{v:<8} {}\n", f.name, f.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<FlagSpec> {
        vec![
            FlagSpec { name: "id", takes_value: true, help: "figure id" },
            FlagSpec { name: "all", takes_value: false, help: "run all" },
            FlagSpec { name: "phi", takes_value: true, help: "ratio" },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_positional() {
        let a = Args::parse(
            &sv(&["figures", "--id", "fig11", "--all", "extra"]),
            &specs(),
        )
        .unwrap();
        assert_eq!(a.subcommand, "figures");
        assert_eq!(a.get("id"), Some("fig11"));
        assert!(a.has("all"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(&sv(&["train", "--phi=0.5"]), &specs()).unwrap();
        assert_eq!(a.f64("phi").unwrap(), Some(0.5));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(Args::parse(&sv(&["x", "--nope"]), &specs()).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::parse(&sv(&["x", "--id"]), &specs()).is_err());
    }

    #[test]
    fn bool_flag_with_value_rejected() {
        assert!(Args::parse(&sv(&["x", "--all=yes"]), &specs()).is_err());
    }

    #[test]
    fn numeric_parse_errors() {
        let a = Args::parse(&sv(&["x", "--phi", "abc"]), &specs()).unwrap();
        assert!(a.f64("phi").is_err());
    }

    #[test]
    fn help_renders() {
        let h = render_help("repro", &[("train", "run training")], &specs());
        assert!(h.contains("repro"));
        assert!(h.contains("--id"));
    }
}
