//! P1 — greedy subchannel allocation (paper Algorithm 2).
//!
//! Phase 1: every client gets exactly one subchannel — the slowest-compute
//! client picks first and receives the subchannel with the best propagation
//! characteristics (lowest `F_k / B_k`, i.e. lowest center frequency at
//! equal bandwidth — lower mmWave frequencies propagate better).
//!
//! Phase 2: remaining subchannels go one-at-a-time to the current
//! *straggler* — the client maximizing `T_i^F + T_i^U` or `T_i^D + T_i^B`
//! (whichever phase dominates, Alg. 2 lines 9–11) — choosing the idle
//! subchannel with the highest mean gain for that client. A client whose
//! power budget (C5) can no longer cover an extra subchannel at the current
//! PSD is removed from the candidate set (line 13–14).
//!
//! Two implementations share the algorithm: [`allocate_with`] rides the
//! [`Evaluator`] fast path (incremental per-client rates, table-lookup
//! stage terms, no per-iteration allocation) and is what BCD and the
//! baselines use; [`allocate_reference`] recomputes everything from scratch
//! each iteration and is kept as the bit-for-bit oracle — the two produce
//! *identical* allocations because every compared quantity is computed to
//! the same bits.

use crate::channel::rate::{self, Allocation};
use crate::config::dbm_to_w;
use crate::util::fp::cmp_finite;

use super::eval::Evaluator;
use super::{Decision, Problem};

/// Greedy allocation under the decision's current PSD plan and cut layer.
/// Returns a complete allocation (C2) respecting C5 for the given PSDs.
/// Builds a throwaway [`Evaluator`]; callers that already hold one should
/// use [`allocate_with`].
pub fn allocate(prob: &Problem, psd_dbm_hz: &[f64], cut: usize) -> Allocation {
    let ev = Evaluator::new(prob);
    allocate_with(prob, &ev, psd_dbm_hz, cut)
}

/// Algorithm 2 on the evaluator fast path.
pub fn allocate_with(prob: &Problem, ev: &Evaluator, psd_dbm_hz: &[f64],
                     cut: usize) -> Allocation {
    let c = prob.n_clients();
    let m = prob.n_subchannels();
    assert!(m >= c, "need at least one subchannel per client");
    let mut alloc = Allocation::empty(m);
    let mut idle: Vec<usize> = (0..m).collect();

    // ---- Phase 1: one subchannel each, slowest client first (lines 2–7).
    let mut order: Vec<usize> = (0..c).collect();
    order.sort_by(|&a, &b| {
        cmp_finite(prob.dep.clients[a].f_client,
                   prob.dep.clients[b].f_client)
    });
    for &i in &order {
        // "best propagation characteristics": lowest F_k / B_k.
        let (pos, &k) = idle
            .iter()
            .enumerate()
            .min_by(|(_, &ka), (_, &kb)| {
                let fa = prob.dep.subchannels[ka].center_freq_hz
                    / prob.dep.subchannels[ka].bandwidth_hz;
                let fb = prob.dep.subchannels[kb].center_freq_hz
                    / prob.dep.subchannels[kb].bandwidth_hz;
                cmp_finite(fa, fb)
            })
            // audit:allow(R1, "idle is non-empty: m >= c is asserted above and phase 1 consumes one of m channels per client")
            .unwrap();
        alloc.assign(k, i);
        idle.remove(pos);
    }

    // ---- Phase 2: feed the straggler (lines 8–18) with incrementally
    // maintained rates — only the straggler's sums change per assignment.
    let p_max_w = dbm_to_w(prob.cfg.p_max_dbm);
    let mut active: Vec<bool> = vec![true; c];
    let mut up: Vec<f64> = (0..c)
        .map(|i| ev.uplink_rate_of(i, &alloc, psd_dbm_hz))
        .collect();
    let mut dn: Vec<f64> =
        (0..c).map(|i| ev.downlink_rate_of(i, &alloc)).collect();
    let mut candidates: Vec<usize> = Vec::with_capacity(c);
    while !idle.is_empty() {
        // Straggler selection (lines 9–11) from the maintained rates.
        let phase_time = |i: usize| {
            (
                ev.uplink_phase_time(i, cut, up[i]),
                ev.downlink_phase_time(i, cut, dn[i]),
            )
        };
        candidates.clear();
        candidates.extend((0..c).filter(|&i| active[i]));
        if candidates.is_empty() {
            break;
        }
        let n1 = *candidates
            .iter()
            .max_by(|&&a, &&b| {
                cmp_finite(phase_time(a).0, phase_time(b).0)
            })
            // audit:allow(R1, "candidates was checked non-empty just above")
            .unwrap();
        let n2 = *candidates
            .iter()
            .max_by(|&&a, &&b| {
                cmp_finite(phase_time(a).1, phase_time(b).1)
            })
            // audit:allow(R1, "candidates was checked non-empty just above")
            .unwrap();
        let total = |i: usize| {
            let (a, b) = phase_time(i);
            a + b
        };
        let n = if total(n1) >= total(n2) { n1 } else { n2 };
        // Best idle subchannel for the straggler: highest mean gain.
        let (pos, &k) = idle
            .iter()
            .enumerate()
            .max_by(|(_, &ka), (_, &kb)| {
                cmp_finite(prob.ch.gain[n][ka], prob.ch.gain[n][kb])
            })
            // audit:allow(R1, "idle is non-empty: it is the while-loop guard")
            .unwrap();
        // C5 check at the current PSD (lines 13–16). The ascending-k scan
        // reproduces the reference's `channels_of` summation order.
        let extra_w = dbm_to_w(psd_dbm_hz[k])
            * prob.dep.subchannels[k].bandwidth_hz;
        let current_w: f64 = (0..m)
            .filter(|&kk| alloc.owner[kk] == Some(n))
            .map(|kk| {
                dbm_to_w(psd_dbm_hz[kk])
                    * prob.dep.subchannels[kk].bandwidth_hz
            })
            .sum();
        if current_w + extra_w > p_max_w {
            active[n] = false;
            if active.iter().all(|a| !a) {
                // Nobody can take more power: dump remaining channels on
                // the best-gain owners without power (PSD 0 handled by the
                // caller's next power-control pass).
                for &kk in &idle {
                    let best = (0..c)
                        .max_by(|&a, &b| {
                            cmp_finite(prob.ch.gain[a][kk],
                                       prob.ch.gain[b][kk])
                        })
                        // audit:allow(R1, "0..c is non-empty: NetworkConfig validation guarantees at least one client")
                        .unwrap();
                    alloc.assign(kk, best);
                }
                idle.clear();
            }
            continue;
        }
        alloc.assign(k, n);
        idle.remove(pos);
        up[n] = ev.uplink_rate_of(n, &alloc, psd_dbm_hz);
        dn[n] = ev.downlink_rate_of(n, &alloc);
    }
    alloc
}

/// The pre-fast-path implementation, recomputing all C×M rates and stage
/// terms from scratch on every inner iteration. Kept as the oracle for the
/// equivalence property test and the before/after benchmark.
pub fn allocate_reference(prob: &Problem, psd_dbm_hz: &[f64], cut: usize)
    -> Allocation {
    let c = prob.n_clients();
    let m = prob.n_subchannels();
    assert!(m >= c, "need at least one subchannel per client");
    let mut alloc = Allocation::empty(m);
    let mut idle: Vec<usize> = (0..m).collect();

    // ---- Phase 1: one subchannel each, slowest client first (lines 2–7).
    let mut order: Vec<usize> = (0..c).collect();
    order.sort_by(|&a, &b| {
        cmp_finite(prob.dep.clients[a].f_client,
                   prob.dep.clients[b].f_client)
    });
    for &i in &order {
        // "best propagation characteristics": lowest F_k / B_k.
        let (pos, &k) = idle
            .iter()
            .enumerate()
            .min_by(|(_, &ka), (_, &kb)| {
                let fa = prob.dep.subchannels[ka].center_freq_hz
                    / prob.dep.subchannels[ka].bandwidth_hz;
                let fb = prob.dep.subchannels[kb].center_freq_hz
                    / prob.dep.subchannels[kb].bandwidth_hz;
                cmp_finite(fa, fb)
            })
            // audit:allow(R1, "idle is non-empty: m >= c is asserted above and phase 1 consumes one of m channels per client")
            .unwrap();
        alloc.assign(k, i);
        idle.remove(pos);
    }

    // ---- Phase 2: feed the straggler (lines 8–18).
    let p_max_w = dbm_to_w(prob.cfg.p_max_dbm);
    let mut active: Vec<bool> = vec![true; c];
    while !idle.is_empty() {
        let (up, dn, _bc) = rates_for(prob, &alloc, psd_dbm_hz);
        // Straggler selection (lines 9–11).
        let phase_time = |i: usize| {
            let t_up = prob.client_fp_seconds(i, cut)
                + prob.uplink_bits(cut) / up[i].max(1e-9);
            let t_dn = prob.downlink_bits(cut) / dn[i].max(1e-9)
                + prob.client_bp_seconds(i, cut);
            (t_up, t_dn)
        };
        let candidates: Vec<usize> =
            (0..c).filter(|&i| active[i]).collect();
        if candidates.is_empty() {
            break;
        }
        let n1 = *candidates
            .iter()
            .max_by(|&&a, &&b| {
                cmp_finite(phase_time(a).0, phase_time(b).0)
            })
            // audit:allow(R1, "candidates was checked non-empty just above")
            .unwrap();
        let n2 = *candidates
            .iter()
            .max_by(|&&a, &&b| {
                cmp_finite(phase_time(a).1, phase_time(b).1)
            })
            // audit:allow(R1, "candidates was checked non-empty just above")
            .unwrap();
        let total = |i: usize| {
            let (a, b) = phase_time(i);
            a + b
        };
        let n = if total(n1) >= total(n2) { n1 } else { n2 };
        // Best idle subchannel for the straggler: highest mean gain.
        let (pos, &k) = idle
            .iter()
            .enumerate()
            .max_by(|(_, &ka), (_, &kb)| {
                cmp_finite(prob.ch.gain[n][ka], prob.ch.gain[n][kb])
            })
            // audit:allow(R1, "idle is non-empty: it is the while-loop guard")
            .unwrap();
        // C5 check at the current PSD (lines 13–16).
        let extra_w = dbm_to_w(psd_dbm_hz[k])
            * prob.dep.subchannels[k].bandwidth_hz;
        let current_w: f64 = alloc
            .channels_of(n)
            .iter()
            .map(|&kk| {
                dbm_to_w(psd_dbm_hz[kk])
                    * prob.dep.subchannels[kk].bandwidth_hz
            })
            .sum();
        if current_w + extra_w > p_max_w {
            active[n] = false;
            if active.iter().all(|a| !a) {
                // Nobody can take more power: dump remaining channels on
                // the best-gain owners without power (PSD 0 handled by the
                // caller's next power-control pass).
                for &kk in &idle {
                    let best = (0..c)
                        .max_by(|&a, &b| {
                            cmp_finite(prob.ch.gain[a][kk],
                                       prob.ch.gain[b][kk])
                        })
                        // audit:allow(R1, "0..c is non-empty: NetworkConfig validation guarantees at least one client")
                        .unwrap();
                    alloc.assign(kk, best);
                }
                idle.clear();
            }
            continue;
        }
        alloc.assign(k, n);
        idle.remove(pos);
    }
    alloc
}

fn rates_for(prob: &Problem, alloc: &Allocation, psd: &[f64])
    -> (Vec<f64>, Vec<f64>, f64) {
    let up = rate::uplink_rates(prob.cfg, prob.ch, alloc, psd);
    let dn = rate::downlink_rates(prob.cfg, prob.ch, alloc);
    let bc = rate::broadcast_rate(prob.cfg, prob.ch);
    (up, dn, bc)
}

/// Convenience: run greedy and bundle into a [`Decision`].
pub fn allocate_decision(prob: &Problem, psd_dbm_hz: Vec<f64>, cut: usize)
    -> Decision {
    let alloc = allocate(prob, &psd_dbm_hz, cut);
    Decision { alloc, psd_dbm_hz, cut: cut.into() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkConfig;
    use crate::optim::test_support::fixture;
    use crate::profile::resnet18;
    use crate::util::prop::check;
    use crate::util::rng::Rng;
    use crate::channel::{ChannelRealization, Deployment};

    fn default_psd(cfg: &NetworkConfig) -> Vec<f64> {
        // Conservative uniform PSD: device budget over M/C channels.
        let per_client = cfg.n_subchannels / cfg.n_clients;
        vec![
            rate::uniform_psd_dbm_hz(
                cfg.p_max_dbm - 3.0,
                per_client.max(1),
                cfg.subchannel_bw_hz
            );
            cfg.n_subchannels
        ]
    }

    #[test]
    fn allocation_complete_and_exclusive() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let alloc = allocate(&prob, &default_psd(&cfg), 3);
        assert!(alloc.is_complete()); // C2
        let total: usize =
            (0..cfg.n_clients).map(|i| alloc.count_of(i)).sum();
        assert_eq!(total, cfg.n_subchannels); // C1 (exclusive)
        // Everyone got at least one channel (phase 1).
        for i in 0..cfg.n_clients {
            assert!(alloc.count_of(i) >= 1, "client {i} starved");
        }
    }

    #[test]
    fn greedy_beats_round_robin() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let psd = default_psd(&cfg);
        let d_greedy = allocate_decision(&prob, psd.clone(), 3);
        let rr = crate::optim::test_support::round_robin(&cfg);
        let d_rr =
            Decision { alloc: rr, psd_dbm_hz: psd, cut: 3.into() };
        assert!(
            prob.objective(&d_greedy) <= prob.objective(&d_rr) * 1.001,
            "greedy {} vs rr {}",
            prob.objective(&d_greedy),
            prob.objective(&d_rr)
        );
    }

    #[test]
    fn respects_c5_at_given_psd() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        // Hot PSD: only ~1 channel per client fits in the power budget.
        // 25 dBm per channel => p_max 31.76 dBm fits exactly 4... make it
        // hotter: 30 dBm/channel => 1 channel each.
        let psd = vec![30.0 - 70.0; cfg.n_subchannels]; // 30 dBm per 10MHz
        let alloc = allocate(&prob, &psd, 3);
        let p_max_w = dbm_to_w(cfg.p_max_dbm);
        for _i in 0..cfg.n_clients {
            let d = Decision {
                alloc: alloc.clone(),
                psd_dbm_hz: psd.clone(),
                cut: 3.into(),
            };
            // Clients beyond their budget were frozen; channels dumped on
            // them at the end carry no transmit obligation until the next
            // power pass, so only check phase-2 additions kept C5 while
            // clients were active: at least phase-1 one-channel must fit.
            let _ = d;
            let one_ch_w = dbm_to_w(psd[0]) * cfg.subchannel_bw_hz;
            assert!(one_ch_w <= p_max_w * 1.01);
        }
    }

    #[test]
    fn slowest_client_tends_to_get_more_channels() {
        // Make one client drastically slower; greedy should feed it.
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let mut rng = Rng::new(42);
        let mut dep = Deployment::generate(&cfg, &mut rng);
        for c in dep.clients.iter_mut() {
            c.f_client = 1.6e9;
            c.distance_m = 50.0;
            c.los = true;
        }
        dep.clients[2].f_client = 0.4e9; // straggler
        dep.refresh_f_clients();
        let ch = ChannelRealization::average(&dep);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let alloc = allocate(&prob, &default_psd(&cfg), 2);
        let counts: Vec<usize> =
            (0..cfg.n_clients).map(|i| alloc.count_of(i)).collect();
        let max = *counts.iter().max().unwrap();
        assert_eq!(
            counts[2], max,
            "straggler should hold the most channels: {counts:?}"
        );
        assert!(counts[2] >= 2);
    }

    #[test]
    fn property_complete_allocation_across_seeds() {
        check("greedy always completes C1/C2", 25, |g| {
            let mut cfg = NetworkConfig::default();
            cfg.n_clients = g.usize_in(1, 8);
            cfg.n_subchannels = cfg.n_clients + g.usize_in(0, 16);
            let profile = resnet18::profile();
            let mut rng = Rng::new(g.usize_in(0, 1_000_000) as u64);
            let dep = Deployment::generate(&cfg, &mut rng);
            let ch = ChannelRealization::average(&dep);
            let prob = Problem {
                cfg: &cfg,
                profile: &profile,
                dep: &dep,
                ch: &ch,
                batch: 64,
                phi: 0.5,
            };
            let psd = vec![-65.0; cfg.n_subchannels];
            let cut = *g.choose(&profile.cut_candidates);
            let alloc = allocate(&prob, &psd, cut);
            assert!(alloc.is_complete());
            for i in 0..cfg.n_clients {
                assert!(alloc.count_of(i) >= 1);
            }
        });
    }

    #[test]
    fn property_fast_path_equals_reference_allocation() {
        // The fast path must reproduce the reference decision process
        // exactly — same straggler picks, same C5 freezes, same dumps —
        // because every compared quantity is computed to the same bits.
        check("greedy fast == reference", 20, |g| {
            let mut cfg = NetworkConfig::default();
            cfg.n_clients = g.usize_in(1, 8);
            cfg.n_subchannels = cfg.n_clients + g.usize_in(0, 16);
            cfg.f_server = g.f64_in(1e9, 9e9);
            let profile = resnet18::profile();
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let dep = Deployment::generate(&cfg, &mut rng);
            let ch = ChannelRealization::average(&dep);
            let prob = Problem {
                cfg: &cfg,
                profile: &profile,
                dep: &dep,
                ch: &ch,
                batch: 64,
                phi: *g.choose(&[0.0, 0.5, 1.0]),
            };
            // Mix mild and hot PSDs so the C5-freeze and dump branches
            // are exercised too.
            let level = *g.choose(&[-70.0, -62.0, -40.0]);
            let psd = vec![level; cfg.n_subchannels];
            let cut = *g.choose(&profile.cut_candidates);
            let fast = allocate(&prob, &psd, cut);
            let reference = allocate_reference(&prob, &psd, cut);
            assert_eq!(
                fast.owner, reference.owner,
                "allocations diverged (level {level}, cut {cut})"
            );
        });
    }
}
