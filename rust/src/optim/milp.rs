//! Small dense LP / MILP substrate: two-phase tableau simplex +
//! branch-and-bound.
//!
//! The paper solves P3 (cut-layer selection) "with the branch-and-bound
//! algorithm"; CVX/MATLAB are unavailable here, so this module is the
//! from-scratch replacement. It is a general 0/1 MILP solver:
//!
//! - [`solve_lp`]: minimize `c·x` s.t. `A x ≤ b`, `x ≥ 0`, via two-phase
//!   primal simplex with Bland's anti-cycling rule.
//! - [`solve_milp`]: depth-first branch-and-bound over declared binary
//!   variables, LP relaxation for bounds, incumbent pruning.
//!
//! Sizes in this system are tiny (≤ ~20 binaries, ≤ ~60 rows), so a dense
//! tableau is the right tool — exactness and debuggability over sparsity.

/// `minimize c·x  s.t.  rows[i].0 · x ≤ rows[i].1,  x ≥ 0`.
#[derive(Debug, Clone)]
pub struct Lp {
    /// Number of structural variables.
    pub n: usize,
    /// Objective coefficients (length n).
    pub c: Vec<f64>,
    /// Constraints as (coefficients, rhs).
    pub rows: Vec<(Vec<f64>, f64)>,
}

impl Lp {
    pub fn new(n: usize, c: Vec<f64>) -> Self {
        assert_eq!(c.len(), n);
        Lp { n, c, rows: Vec::new() }
    }

    /// Add `a · x ≤ b`.
    pub fn leq(&mut self, a: Vec<f64>, b: f64) -> &mut Self {
        assert_eq!(a.len(), self.n);
        self.rows.push((a, b));
        self
    }

    /// Add `a · x ≥ b` (stored as `−a · x ≤ −b`).
    pub fn geq(&mut self, a: Vec<f64>, b: f64) -> &mut Self {
        self.leq(a.iter().map(|v| -v).collect(), -b)
    }

    /// Add `a · x = b` (two inequalities).
    pub fn eq(&mut self, a: Vec<f64>, b: f64) -> &mut Self {
        self.leq(a.clone(), b);
        self.geq(a, b)
    }
}

/// LP outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum LpResult {
    Optimal { x: Vec<f64>, obj: f64 },
    Infeasible,
    Unbounded,
}

const EPS: f64 = 1e-9;

/// Two-phase primal simplex on the dense tableau.
pub fn solve_lp(lp: &Lp) -> LpResult {
    let m = lp.rows.len();
    let n = lp.n;
    // Columns: [x_0..x_{n-1} | slack_0..slack_{m-1} | artificial...] + rhs.
    // Normalize rows to b >= 0 (flip sign; slack coefficient then -1 and an
    // artificial variable is required for a starting basis).
    let mut need_art: Vec<bool> = Vec::with_capacity(m);
    let mut a_rows: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut rhs: Vec<f64> = Vec::with_capacity(m);
    let mut slack_sign: Vec<f64> = Vec::with_capacity(m);
    for (coefs, b) in &lp.rows {
        if *b >= 0.0 {
            a_rows.push(coefs.clone());
            rhs.push(*b);
            slack_sign.push(1.0);
            need_art.push(false);
        } else {
            a_rows.push(coefs.iter().map(|v| -v).collect());
            rhs.push(-b);
            slack_sign.push(-1.0);
            need_art.push(true);
        }
    }
    let n_art: usize = need_art.iter().filter(|x| **x).count();
    let total = n + m + n_art;
    // tableau[row][col], plus rhs column at index `total`.
    let mut t = vec![vec![0.0; total + 1]; m];
    let mut basis = vec![0usize; m];
    let mut art_idx = n + m;
    for i in 0..m {
        for j in 0..n {
            t[i][j] = a_rows[i][j];
        }
        t[i][n + i] = slack_sign[i];
        t[i][total] = rhs[i];
        if need_art[i] {
            t[i][art_idx] = 1.0;
            basis[i] = art_idx;
            art_idx += 1;
        } else {
            basis[i] = n + i;
        }
    }

    // Phase 1: minimize sum of artificials (if any).
    if n_art > 0 {
        let mut cost = vec![0.0; total];
        for j in (n + m)..total {
            cost[j] = 1.0;
        }
        match simplex_core(&mut t, &mut basis, &cost, total) {
            SimplexOutcome::Optimal(obj) => {
                if obj > 1e-7 {
                    return LpResult::Infeasible;
                }
            }
            SimplexOutcome::Unbounded => return LpResult::Infeasible,
        }
        // Pivot any artificial still in the basis out (degenerate rows).
        for i in 0..m {
            if basis[i] >= n + m {
                if let Some(j) = (0..n + m)
                    .find(|&j| t[i][j].abs() > EPS)
                {
                    pivot(&mut t, &mut basis, i, j, total);
                } // else: zero row, harmless
            }
        }
    }

    // Phase 2: original objective (artificial columns frozen at zero).
    let mut cost = vec![0.0; total];
    cost[..n].copy_from_slice(&lp.c);
    // Forbid re-entering artificials by making them very expensive.
    for cj in cost.iter_mut().take(total).skip(n + m) {
        *cj = 1e30;
    }
    match simplex_core(&mut t, &mut basis, &cost, total) {
        SimplexOutcome::Unbounded => LpResult::Unbounded,
        SimplexOutcome::Optimal(_) => {
            let mut x = vec![0.0; n];
            for i in 0..m {
                if basis[i] < n {
                    x[basis[i]] = t[i][total];
                }
            }
            let obj =
                x.iter().zip(&lp.c).map(|(xi, ci)| xi * ci).sum::<f64>();
            LpResult::Optimal { x, obj }
        }
    }
}

enum SimplexOutcome {
    Optimal(f64),
    Unbounded,
}

/// Primal simplex iterations on an existing feasible tableau; returns the
/// achieved objective value for `cost`.
fn simplex_core(t: &mut [Vec<f64>], basis: &mut [usize], cost: &[f64],
                total: usize) -> SimplexOutcome {
    let m = t.len();
    let max_iters = 200 * (total + m + 8);
    for _ in 0..max_iters {
        // Reduced costs: r_j = c_j − c_B · B^{-1} A_j (computed from the
        // tableau since rows are already B^{-1}A).
        let mut entering = None;
        for j in 0..total {
            let mut rj = cost[j];
            for i in 0..m {
                rj -= cost[basis[i]] * t[i][j];
            }
            if rj < -1e-9 {
                // Bland: smallest index.
                entering = Some(j);
                break;
            }
        }
        let Some(e) = entering else {
            let obj = (0..m).map(|i| cost[basis[i]] * t[i][total]).sum();
            return SimplexOutcome::Optimal(obj);
        };
        // Ratio test (Bland tie-break on basis index).
        let mut leave: Option<usize> = None;
        let mut best = f64::INFINITY;
        for i in 0..m {
            if t[i][e] > EPS {
                let ratio = t[i][total] / t[i][e];
                if ratio < best - EPS
                    || (ratio < best + EPS
                        && leave.map(|l| basis[i] < basis[l]).unwrap_or(true))
                {
                    best = ratio;
                    leave = Some(i);
                }
            }
        }
        let Some(l) = leave else {
            return SimplexOutcome::Unbounded;
        };
        pivot(t, basis, l, e, total);
    }
    // Iteration cap hit: return current (still feasible) point as optimal —
    // with Bland's rule this should be unreachable; the cap is a backstop.
    let obj = (0..m).map(|i| cost[basis[i]] * t[i][total]).sum();
    SimplexOutcome::Optimal(obj)
}

fn pivot(t: &mut [Vec<f64>], basis: &mut [usize], row: usize, col: usize,
         total: usize) {
    let m = t.len();
    let pv = t[row][col];
    debug_assert!(pv.abs() > EPS);
    for j in 0..=total {
        t[row][j] /= pv;
    }
    for i in 0..m {
        if i != row && t[i][col].abs() > EPS {
            let f = t[i][col];
            for j in 0..=total {
                t[i][j] -= f * t[row][j];
            }
        }
    }
    basis[row] = col;
}

/// 0/1 MILP: the LP plus a set of variable indices constrained to {0, 1}.
#[derive(Debug, Clone)]
pub struct Milp {
    pub lp: Lp,
    pub binary: Vec<usize>,
}

/// Branch-and-bound search statistics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MilpStats {
    pub nodes: usize,
    pub lp_solves: usize,
    pub pruned: usize,
}

/// Solve via depth-first B&B. Returns `(Some((x, obj)), stats)` or
/// `(None, stats)` if infeasible.
pub fn solve_milp(milp: &Milp) -> (Option<(Vec<f64>, f64)>, MilpStats) {
    let mut base = milp.lp.clone();
    // x_j ≤ 1 for binaries.
    for &j in &milp.binary {
        let mut a = vec![0.0; base.n];
        a[j] = 1.0;
        base.leq(a, 1.0);
    }
    let mut stats = MilpStats::default();
    let mut incumbent: Option<(Vec<f64>, f64)> = None;
    // Stack of (fixed assignments) frames: Vec<(var, value)>.
    let mut stack: Vec<Vec<(usize, f64)>> = vec![vec![]];
    while let Some(fixes) = stack.pop() {
        stats.nodes += 1;
        let mut lp = base.clone();
        for &(j, v) in &fixes {
            let mut a = vec![0.0; lp.n];
            a[j] = 1.0;
            lp.eq(a, v);
        }
        stats.lp_solves += 1;
        let sol = match solve_lp(&lp) {
            LpResult::Optimal { x, obj } => (x, obj),
            LpResult::Infeasible => continue,
            LpResult::Unbounded => {
                // Relaxation unbounded with binaries bounded means the
                // continuous part is unbounded: give up on this node.
                continue;
            }
        };
        if let Some((_, best)) = &incumbent {
            if sol.1 >= *best - 1e-12 {
                stats.pruned += 1;
                continue;
            }
        }
        // Most-fractional branching.
        let frac = milp
            .binary
            .iter()
            .map(|&j| (j, (sol.0[j] - sol.0[j].round()).abs()))
            .filter(|(_, f)| *f > 1e-6)
            .max_by(|a, b| crate::util::fp::cmp_finite(a.1, b.1));
        match frac {
            None => {
                // Integral: candidate incumbent.
                if incumbent
                    .as_ref()
                    .map(|(_, best)| sol.1 < *best - 1e-12)
                    .unwrap_or(true)
                {
                    incumbent = Some(sol);
                }
            }
            Some((j, _)) => {
                let mut f0 = fixes.clone();
                f0.push((j, 0.0));
                let mut f1 = fixes;
                f1.push((j, 1.0));
                // Explore x_j = 1 first (one-hot problems resolve fast).
                stack.push(f0);
                stack.push(f1);
            }
        }
    }
    (incumbent, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }

    #[test]
    fn lp_textbook_max_problem() {
        // max 3x + 5y s.t. x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18 → (2, 6), 36.
        // As minimize −3x − 5y.
        let mut lp = Lp::new(2, vec![-3.0, -5.0]);
        lp.leq(vec![1.0, 0.0], 4.0);
        lp.leq(vec![0.0, 2.0], 12.0);
        lp.leq(vec![3.0, 2.0], 18.0);
        match solve_lp(&lp) {
            LpResult::Optimal { x, obj } => {
                assert_close(x[0], 2.0);
                assert_close(x[1], 6.0);
                assert_close(obj, -36.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lp_with_geq_needs_phase1() {
        // min x + y s.t. x + y ≥ 2, x ≤ 5, y ≤ 5 → obj 2.
        let mut lp = Lp::new(2, vec![1.0, 1.0]);
        lp.geq(vec![1.0, 1.0], 2.0);
        lp.leq(vec![1.0, 0.0], 5.0);
        lp.leq(vec![0.0, 1.0], 5.0);
        match solve_lp(&lp) {
            LpResult::Optimal { obj, .. } => assert_close(obj, 2.0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lp_infeasible_detected() {
        // x ≤ 1 and x ≥ 3.
        let mut lp = Lp::new(1, vec![1.0]);
        lp.leq(vec![1.0], 1.0);
        lp.geq(vec![1.0], 3.0);
        assert_eq!(solve_lp(&lp), LpResult::Infeasible);
    }

    #[test]
    fn lp_unbounded_detected() {
        // min −x, x ≥ 0 unbounded below.
        let lp = Lp::new(1, vec![-1.0]);
        assert_eq!(solve_lp(&lp), LpResult::Unbounded);
    }

    #[test]
    fn lp_equality_constraint() {
        // min 2x + 3y s.t. x + y = 4, x ≤ 3 → y ≥ 1; optimum x=3,y=1 → 9.
        let mut lp = Lp::new(2, vec![2.0, 3.0]);
        lp.eq(vec![1.0, 1.0], 4.0);
        lp.leq(vec![1.0, 0.0], 3.0);
        match solve_lp(&lp) {
            LpResult::Optimal { x, obj } => {
                assert_close(x[0], 3.0);
                assert_close(x[1], 1.0);
                assert_close(obj, 9.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn milp_knapsack() {
        // max 10a + 13b + 7c, weight 3a + 4b + 2c ≤ 6, binary.
        // Best: a + c? 17 w5; b + c = 20 w6 ✓; a+b w7 infeasible → 20.
        let mut lp = Lp::new(3, vec![-10.0, -13.0, -7.0]);
        lp.leq(vec![3.0, 4.0, 2.0], 6.0);
        let milp = Milp { lp, binary: vec![0, 1, 2] };
        let (sol, stats) = solve_milp(&milp);
        let (x, obj) = sol.unwrap();
        assert_close(obj, -20.0);
        assert_close(x[0], 0.0);
        assert_close(x[1], 1.0);
        assert_close(x[2], 1.0);
        assert!(stats.nodes >= 1);
    }

    #[test]
    fn milp_one_hot_selection() {
        // Exactly one of 4 options, each with cost; mixed continuous slack
        // var T ≥ cost_j of the chosen option: min T.
        // Variables: mu_0..3, T.
        let costs = [7.0, 3.0, 5.0, 9.0];
        let mut lp = Lp::new(5, vec![0.0, 0.0, 0.0, 0.0, 1.0]);
        lp.eq(vec![1.0, 1.0, 1.0, 1.0, 0.0], 1.0);
        // T ≥ Σ mu_j cost_j  →  Σ mu_j cost_j − T ≤ 0.
        lp.leq(vec![costs[0], costs[1], costs[2], costs[3], -1.0], 0.0);
        let milp = Milp { lp, binary: vec![0, 1, 2, 3] };
        let (sol, _) = solve_milp(&milp);
        let (x, obj) = sol.unwrap();
        assert_close(obj, 3.0);
        assert_close(x[1], 1.0);
    }

    #[test]
    fn milp_matches_exhaustive_on_random_instances() {
        use crate::util::prop::{check, Gen};
        check("milp == brute force", 40, |g: &mut Gen| {
            let nb = g.usize_in(2, 6);
            let c: Vec<f64> =
                (0..nb).map(|_| g.f64_in(-10.0, 10.0)).collect();
            // One ≤ row with positive weights keeps it bounded + feasible
            // (x = 0 is always feasible).
            let w: Vec<f64> = (0..nb).map(|_| g.f64_in(0.5, 4.0)).collect();
            let cap = g.f64_in(1.0, 8.0);
            let mut lp = Lp::new(nb, c.clone());
            lp.leq(w.clone(), cap);
            let milp = Milp { lp, binary: (0..nb).collect() };
            let (sol, _) = solve_milp(&milp);
            let (_, obj) = sol.expect("x=0 feasible");
            // Brute force.
            let mut best = f64::INFINITY;
            for mask in 0..(1u32 << nb) {
                let mut wsum = 0.0;
                let mut csum = 0.0;
                for j in 0..nb {
                    if mask & (1 << j) != 0 {
                        wsum += w[j];
                        csum += c[j];
                    }
                }
                if wsum <= cap + 1e-9 {
                    best = best.min(csum);
                }
            }
            assert!(
                (obj - best).abs() < 1e-5,
                "milp {obj} vs brute {best} (c={c:?}, w={w:?}, cap={cap})"
            );
        });
    }

    #[test]
    fn milp_infeasible() {
        // a + b ≥ 3 with binaries can reach at most 2.
        let mut lp = Lp::new(2, vec![1.0, 1.0]);
        lp.geq(vec![1.0, 1.0], 3.0);
        let milp = Milp { lp, binary: vec![0, 1] };
        let (sol, _) = solve_milp(&milp);
        assert!(sol.is_none());
    }
}
