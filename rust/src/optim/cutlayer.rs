//! P3 — cut-layer selection as a MILP solved by branch-and-bound
//! (paper problem (31)).
//!
//! With allocation and powers fixed, the remaining decision is the one-hot
//! cut vector μ plus the auxiliary straggler bounds T₁, T₂:
//!
//!   minimize   T₁ + Σ_j μ_j·(T_s^F(j) + T_s^B(j) + T^B(j)) + T₂
//!   s.t.       Σ_j μ_j = 1                                   (C4)
//!              Σ_j μ_j·(T_i^F(j) + bψ_j/R_i^U) ≤ T₁   ∀i     (C8)
//!              Σ_j μ_j·((b−⌈φb⌉)χ_j/R_i^D + T_i^B(j)) ≤ T₂ ∀i (C9)
//!              μ_j ∈ {0,1}
//!
//! Everything is linear in (μ, T₁, T₂), so this is exactly the MILP the
//! paper hands to B&B; we hand it to [`super::milp`]. An exhaustive
//! reference solver cross-checks optimality in tests (the candidate set is
//! small — the paper makes the same observation about AlexNet/GoogLeNet).

use crate::channel::rate::Allocation;
use crate::error::{Error, Result};
use crate::util::fp::cmp_finite;

use super::eval::Evaluator;
use super::milp::{solve_milp, Lp, Milp, MilpStats};
use super::{Decision, Problem};

/// Per-candidate server-side cost `T_s^F + T_s^B + T^B` (the μ-weighted
/// part of the objective).
fn server_cost(prob: &Problem, cut: usize, broadcast_rate: f64) -> f64 {
    let p = prob.profile;
    let b = prob.batch as f64;
    let c = prob.n_clients() as f64;
    let m = (prob.phi * b).ceil();
    let t_sf =
        c * b * prob.cfg.kappa_server * p.server_fp_flops(cut) / prob.cfg.f_server;
    let eff = m + c * (b - m);
    let t_sb = (eff * prob.cfg.kappa_server * p.server_bp_flops(cut)
        + c * b * prob.cfg.kappa_server * p.last_layer_bp_flops())
        / prob.cfg.f_server;
    let t_b = m * p.chi_bits(cut) / broadcast_rate.max(1e-9);
    t_sf + t_sb + t_b
}

/// Solve P3 by B&B, deriving rates and stage terms from the [`Problem`]
/// (reference setup). Returns the optimal cut and the solver statistics.
pub fn solve(prob: &Problem, alloc: &Allocation, psd_dbm_hz: &[f64])
    -> Result<(usize, MilpStats)> {
    let cands = &prob.profile.cut_candidates;
    if cands.is_empty() {
        return Err(Error::Optim("no cut candidates".into()));
    }
    let d0 = Decision {
        alloc: alloc.clone(),
        psd_dbm_hz: psd_dbm_hz.to_vec(),
        cut: cands[0].into(),
    };
    let (up, dn, bc) = prob.rates(&d0);
    let nj = cands.len();
    let c = prob.n_clients();
    let costs: Vec<f64> =
        cands.iter().map(|&cut| server_cost(prob, cut, bc)).collect();
    let mut c8m = vec![0.0; c * nj];
    let mut c9m = vec![0.0; c * nj];
    for i in 0..c {
        for (jj, &cut) in cands.iter().enumerate() {
            c8m[i * nj + jj] = prob.client_fp_seconds(i, cut)
                + prob.uplink_bits(cut) / up[i].max(1e-9);
            c9m[i * nj + jj] = prob.downlink_bits(cut) / dn[i].max(1e-9)
                + prob.client_bp_seconds(i, cut);
        }
    }
    solve_milp_core(cands, c, &costs, &c8m, &c9m)
}

/// Solve P3 with rates and stage terms served from a prebuilt
/// [`Evaluator`] — bit-identical coefficients, no per-call rate rebuild.
pub fn solve_with(prob: &Problem, ev: &Evaluator, alloc: &Allocation,
                  psd_dbm_hz: &[f64]) -> Result<(usize, MilpStats)> {
    let cands = &prob.profile.cut_candidates;
    if cands.is_empty() {
        return Err(Error::Optim("no cut candidates".into()));
    }
    let c = prob.n_clients();
    let mut up = Vec::new();
    let mut dn = Vec::new();
    ev.fill_rates(alloc, psd_dbm_hz, &mut up, &mut dn);
    let nj = cands.len();
    let costs: Vec<f64> =
        cands.iter().map(|&cut| ev.server_cost(cut)).collect();
    let mut c8m = vec![0.0; c * nj];
    let mut c9m = vec![0.0; c * nj];
    for i in 0..c {
        for (jj, &cut) in cands.iter().enumerate() {
            c8m[i * nj + jj] = ev.uplink_phase_time(i, cut, up[i]);
            c9m[i * nj + jj] = ev.downlink_phase_time(i, cut, dn[i]);
        }
    }
    solve_milp_core(cands, c, &costs, &c8m, &c9m)
}

/// Shared MILP assembly + B&B over variables μ_0..μ_{nj−1}, T₁, T₂.
fn solve_milp_core(cands: &[usize], n_clients: usize, costs: &[f64],
                   c8m: &[f64], c9m: &[f64]) -> Result<(usize, MilpStats)> {
    let nj = cands.len();
    let n = nj + 2;
    let mut obj = vec![0.0; n];
    obj[..nj].copy_from_slice(costs);
    obj[nj] = 1.0; // T1
    obj[nj + 1] = 1.0; // T2
    let mut lp = Lp::new(n, obj);
    // C4: Σ μ = 1.
    let mut ones = vec![0.0; n];
    ones[..nj].fill(1.0);
    lp.eq(ones, 1.0);
    // C8 / C9 per client.
    for i in 0..n_clients {
        let mut c8 = vec![0.0; n];
        let mut c9 = vec![0.0; n];
        c8[..nj].copy_from_slice(&c8m[i * nj..(i + 1) * nj]);
        c9[..nj].copy_from_slice(&c9m[i * nj..(i + 1) * nj]);
        c8[nj] = -1.0;
        lp.leq(c8, 0.0);
        c9[nj + 1] = -1.0;
        lp.leq(c9, 0.0);
    }
    let milp = Milp { lp, binary: (0..nj).collect() };
    let (sol, stats) = solve_milp(&milp);
    let (x, _) = sol.ok_or_else(|| {
        Error::Optim("P3 MILP infeasible (should never happen)".into())
    })?;
    let jj = (0..nj)
        .max_by(|&a, &b| cmp_finite(x[a], x[b]))
        // audit:allow(R1, "nj >= 1: every NetworkProfile constructor ships non-empty cut_candidates, and exhaustive() below already indexes [0]")
        .unwrap();
    Ok((cands[jj], stats))
}

/// Exhaustive reference: evaluate the true round objective at every cut
/// (rates recomputed from scratch per candidate).
pub fn exhaustive(prob: &Problem, alloc: &Allocation, psd_dbm_hz: &[f64])
    -> (usize, f64) {
    let mut best = (prob.profile.cut_candidates[0], f64::INFINITY);
    for &cut in &prob.profile.cut_candidates {
        let d = Decision {
            alloc: alloc.clone(),
            psd_dbm_hz: psd_dbm_hz.to_vec(),
            cut: cut.into(),
        };
        let t = prob.objective(&d);
        if t < best.1 {
            best = (cut, t);
        }
    }
    best
}

/// Exhaustive cut sweep on the fast path: rates computed once, then each
/// candidate is an O(C) table evaluation. Bit-identical result to
/// [`exhaustive`].
pub fn exhaustive_with(prob: &Problem, ev: &Evaluator, alloc: &Allocation,
                       psd_dbm_hz: &[f64]) -> (usize, f64) {
    let mut up = Vec::new();
    let mut dn = Vec::new();
    ev.fill_rates(alloc, psd_dbm_hz, &mut up, &mut dn);
    let mut best = (prob.profile.cut_candidates[0], f64::INFINITY);
    for &cut in &prob.profile.cut_candidates {
        let t = ev.objective_with_rates(cut, &up, &dn);
        if t < best.1 {
            best = (cut, t);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkConfig;
    use crate::optim::test_support::{fixture, round_robin};
    use crate::profile::{resnet18, splitnet};
    use crate::util::prop::check;
    use crate::util::rng::Rng;
    use crate::channel::{ChannelRealization, Deployment};

    #[test]
    fn milp_matches_exhaustive_resnet() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let alloc = round_robin(&cfg);
        let psd = vec![-65.0; 20];
        let (cut_milp, stats) = solve(&prob, &alloc, &psd).unwrap();
        let (cut_ex, _) = exhaustive(&prob, &alloc, &psd);
        assert_eq!(cut_milp, cut_ex);
        assert!(stats.lp_solves >= 1);
    }

    #[test]
    fn milp_matches_exhaustive_splitnet() {
        let cfg = NetworkConfig::default();
        let profile = splitnet::profile(splitnet::SplitNetConfig::mnist_like());
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 32,
            phi: 0.5,
        };
        let alloc = round_robin(&cfg);
        let psd = vec![-65.0; 20];
        let (cut_milp, _) = solve(&prob, &alloc, &psd).unwrap();
        let (cut_ex, _) = exhaustive(&prob, &alloc, &psd);
        assert_eq!(cut_milp, cut_ex);
    }

    #[test]
    fn property_milp_equals_exhaustive() {
        check("P3 B&B == exhaustive", 15, |g| {
            let mut cfg = NetworkConfig::default();
            cfg.n_clients = g.usize_in(2, 6);
            cfg.n_subchannels = cfg.n_clients * g.usize_in(1, 3);
            cfg.f_server = g.f64_in(1e9, 9e9);
            let profile = resnet18::profile();
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let dep = Deployment::generate(&cfg, &mut rng);
            let ch = ChannelRealization::average(&dep);
            let phi = *g.choose(&[0.0, 0.5, 1.0]);
            let prob = Problem {
                cfg: &cfg,
                profile: &profile,
                dep: &dep,
                ch: &ch,
                batch: 64,
                phi,
            };
            let mut alloc = Allocation::empty(cfg.n_subchannels);
            for k in 0..cfg.n_subchannels {
                alloc.assign(k, k % cfg.n_clients);
            }
            let psd = vec![g.f64_in(-75.0, -58.0); cfg.n_subchannels];
            let (cut_milp, _) = solve(&prob, &alloc, &psd).unwrap();
            let (cut_ex, t_ex) = exhaustive(&prob, &alloc, &psd);
            // Objectives must agree even if ties pick different cuts.
            let d = Decision {
                alloc: alloc.clone(),
                psd_dbm_hz: psd.clone(),
                cut: cut_milp.into(),
            };
            let t_milp = prob.objective(&d);
            assert!(
                (t_milp - t_ex).abs() / t_ex < 1e-6,
                "milp cut {cut_milp} ({t_milp}) vs exhaustive {cut_ex} ({t_ex})"
            );
        });
    }

    #[test]
    fn fast_paths_match_reference_solvers() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let ev = Evaluator::new(&prob);
        let alloc = round_robin(&cfg);
        let psd = vec![-63.0; 20];
        let (cut_ref, _) = solve(&prob, &alloc, &psd).unwrap();
        let (cut_fast, stats) = solve_with(&prob, &ev, &alloc, &psd).unwrap();
        assert_eq!(cut_ref, cut_fast);
        assert!(stats.lp_solves >= 1);
        let (ex_cut, ex_t) = exhaustive(&prob, &alloc, &psd);
        let (fx_cut, fx_t) = exhaustive_with(&prob, &ev, &alloc, &psd);
        assert_eq!(ex_cut, fx_cut);
        assert_eq!(ex_t.to_bits(), fx_t.to_bits());
    }

    #[test]
    fn weak_uplink_pushes_cut_deeper() {
        // With a starved uplink, the optimizer should prefer deeper cuts
        // (smaller smashed payload), despite more client compute.
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, _) = fixture(&cfg);
        // Artificially weak channel: scale gains down hard.
        let weak = ChannelRealization {
            gain: (0..cfg.n_clients)
                .map(|i| {
                    (0..cfg.n_subchannels)
                        .map(|k| {
                            ChannelRealization::average(&dep).gain[i][k] * 1e-4
                        })
                        .collect()
                })
                .collect(),
        };
        let strong = ChannelRealization::average(&dep);
        let alloc = round_robin(&cfg);
        let psd = vec![-65.0; 20];
        let cut_weak = {
            let prob = Problem {
                cfg: &cfg,
                profile: &profile,
                dep: &dep,
                ch: &weak,
                batch: 64,
                phi: 0.5,
            };
            solve(&prob, &alloc, &psd).unwrap().0
        };
        let cut_strong = {
            let prob = Problem {
                cfg: &cfg,
                profile: &profile,
                dep: &dep,
                ch: &strong,
                batch: 64,
                phi: 0.5,
            };
            solve(&prob, &alloc, &psd).unwrap().0
        };
        assert!(
            cut_weak >= cut_strong,
            "weak channel cut {cut_weak} < strong channel cut {cut_strong}"
        );
    }
}
