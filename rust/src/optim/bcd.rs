//! Algorithm 3 — block-coordinate descent over (r, p, μ, T₁, T₂).
//!
//! Each iteration updates the four blocks in the paper's order:
//! 1. `r`  ← greedy subchannel allocation (Algorithm 2)
//! 2. `θ/p` ← exact power control (P2)
//! 3. `μ`  ← cut-layer MILP via B&B (P3)
//! 4. `(T₁, T₂)` ← closed form (P4, eqs. 33–34)
//!
//! Hardening over the paper's pseudocode: every block update is accepted
//! only if it does not increase the true objective (eq. 23), which makes
//! the trajectory provably non-increasing — BCD on a non-convex problem
//! can otherwise oscillate between blocks.
//!
//! [`solve`] runs on the [`Evaluator`] fast path: one evaluator is built
//! per problem, all objective checks are table-driven and allocation-free,
//! and one scratch candidate [`Decision`] is reused across blocks and
//! iterations (the pre-PR code cloned the incumbent three times per
//! iteration). [`solve_reference`] preserves the from-scratch evaluation
//! pipeline; the two return identical results because every fast-path
//! quantity is computed to the same bits as its reference counterpart.

use crate::channel::rate;
use crate::error::Result;

use super::eval::Evaluator;
use super::{cutlayer, greedy, power, Decision, Problem};

/// BCD options.
#[derive(Debug, Clone, Copy)]
pub struct BcdOptions {
    pub max_iters: usize,
    /// Convergence tolerance ε on |ΔT̃| (seconds).
    pub tol: f64,
}

impl Default for BcdOptions {
    fn default() -> Self {
        BcdOptions { max_iters: 20, tol: 1e-6 }
    }
}

/// BCD outcome.
#[derive(Debug, Clone)]
pub struct BcdResult {
    pub decision: Decision,
    pub objective: f64,
    /// Objective after each iteration (non-increasing).
    pub trajectory: Vec<f64>,
    pub iterations: usize,
}

/// Initial uniform PSD plan shared by both pipelines.
fn initial_psd(prob: &Problem) -> Vec<f64> {
    let per_client = (prob.n_subchannels() / prob.n_clients()).max(1);
    vec![
        rate::uniform_psd_dbm_hz(
            prob.cfg.p_max_dbm - 3.0,
            per_client,
            prob.cfg.subchannel_bw_hz
        );
        prob.n_subchannels()
    ]
}

/// Initial decision: middle cut candidate, round-robin-ish greedy at a
/// conservative uniform PSD (fast-path greedy).
fn initial_with(prob: &Problem, ev: &Evaluator) -> Decision {
    let cands = &prob.profile.cut_candidates;
    let cut = cands[cands.len() / 2];
    let psd = initial_psd(prob);
    let alloc = greedy::allocate_with(prob, ev, &psd, cut);
    Decision { alloc, psd_dbm_hz: psd, cut: cut.into() }
}

/// Copy `src` into `dst` reusing `dst`'s buffers (no allocation once the
/// shapes match, which they always do within one solve).
fn copy_decision(dst: &mut Decision, src: &Decision) {
    dst.alloc.owner.clone_from(&src.alloc.owner);
    dst.psd_dbm_hz.clone_from(&src.psd_dbm_hz);
    dst.cut.clone_from(&src.cut);
}

/// Run Algorithm 3 on the evaluator fast path.
pub fn solve(prob: &Problem, opts: BcdOptions) -> Result<BcdResult> {
    let mut ev = Evaluator::new(prob);
    solve_with(prob, &mut ev, opts)
}

/// Run Algorithm 3 reusing a caller-owned [`Evaluator`] (e.g. across the
/// schemes of one sweep cell).
pub fn solve_with(prob: &Problem, ev: &mut Evaluator, opts: BcdOptions)
    -> Result<BcdResult> {
    let mut d = initial_with(prob, ev);
    let mut best = ev.objective(&d);
    let mut trajectory = vec![best];
    let mut iterations = 0;
    // One scratch candidate, cloned once and mutated block-by-block.
    let mut cand = d.clone();

    for _ in 0..opts.max_iters {
        iterations += 1;
        let before = best;
        // BCD is the paper's uniform-cut Alg. 3 — the incumbent's
        // assignment is always uniform here.
        let dj = d.uniform_cut()?;

        // Block 1: subchannel allocation (Algorithm 2).
        cand.alloc = greedy::allocate_with(prob, ev, &d.psd_dbm_hz, dj);
        cand.psd_dbm_hz.clone_from(&d.psd_dbm_hz);
        cand.cut.clone_from(&d.cut);
        if prob.check_feasible(&cand).is_ok() {
            let t = ev.objective(&cand);
            if t <= best {
                copy_decision(&mut d, &cand);
                best = t;
            }
        }

        // Block 2: power control (P2).
        if let Ok(sol) = power::solve_with(prob, ev, &d.alloc, dj) {
            cand.alloc.owner.clone_from(&d.alloc.owner);
            cand.psd_dbm_hz = sol.psd_dbm_hz;
            cand.cut.clone_from(&d.cut);
            if prob.check_feasible(&cand).is_ok() {
                let t = ev.objective(&cand);
                if t <= best {
                    copy_decision(&mut d, &cand);
                    best = t;
                }
            }
        }

        // Block 3: cut layer (P3 via B&B). Re-run power for the new cut so
        // the comparison is fair (the cut changes the uplink payload).
        if let Ok((cut, _stats)) =
            cutlayer::solve_with(prob, ev, &d.alloc, &d.psd_dbm_hz)
        {
            if d.cut != cut {
                cand.alloc.owner.clone_from(&d.alloc.owner);
                cand.psd_dbm_hz.clone_from(&d.psd_dbm_hz);
                cand.cut = cut.into();
                if let Ok(sol) = power::solve_with(prob, ev, &cand.alloc, cut)
                {
                    cand.psd_dbm_hz = sol.psd_dbm_hz;
                }
                if prob.check_feasible(&cand).is_ok() {
                    let t = ev.objective(&cand);
                    if t <= best {
                        copy_decision(&mut d, &cand);
                        best = t;
                    }
                }
            }
        }

        // Block 4: (T1, T2) are implicit in `objective` (P4 closed form).
        trajectory.push(best);
        if (before - best).abs() < opts.tol {
            break;
        }
    }
    Ok(BcdResult { decision: d, objective: best, trajectory, iterations })
}

/// The pre-fast-path Algorithm 3: every block evaluated through
/// [`Problem::objective`] with per-candidate decision clones. Kept as the
/// oracle for the equivalence test and the before/after benchmark.
pub fn solve_reference(prob: &Problem, opts: BcdOptions) -> Result<BcdResult> {
    let cands = &prob.profile.cut_candidates;
    let cut = cands[cands.len() / 2];
    let psd = initial_psd(prob);
    let alloc = greedy::allocate_reference(prob, &psd, cut);
    let mut d = Decision { alloc, psd_dbm_hz: psd, cut: cut.into() };
    let mut best = prob.objective(&d);
    let mut trajectory = vec![best];
    let mut iterations = 0;

    for _ in 0..opts.max_iters {
        iterations += 1;
        let before = best;
        let dj = d.uniform_cut()?;

        // Block 1: subchannel allocation (Algorithm 2).
        let alloc = greedy::allocate_reference(prob, &d.psd_dbm_hz, dj);
        let cand = Decision { alloc, ..d.clone() };
        if prob.check_feasible(&cand).is_ok() {
            let t = prob.objective(&cand);
            if t <= best {
                d = cand;
                best = t;
            }
        }

        // Block 2: power control (P2).
        if let Ok(sol) = power::solve(prob, &d.alloc, dj) {
            let cand = Decision { psd_dbm_hz: sol.psd_dbm_hz, ..d.clone() };
            if prob.check_feasible(&cand).is_ok() {
                let t = prob.objective(&cand);
                if t <= best {
                    d = cand;
                    best = t;
                }
            }
        }

        // Block 3: cut layer (P3 via B&B). Re-run power for the new cut so
        // the comparison is fair (the cut changes the uplink payload).
        if let Ok((cut, _stats)) =
            cutlayer::solve(prob, &d.alloc, &d.psd_dbm_hz)
        {
            if d.cut != cut {
                let mut cand = Decision { cut: cut.into(), ..d.clone() };
                if let Ok(sol) = power::solve(prob, &cand.alloc, cut) {
                    cand.psd_dbm_hz = sol.psd_dbm_hz;
                }
                if prob.check_feasible(&cand).is_ok() {
                    let t = prob.objective(&cand);
                    if t <= best {
                        d = cand;
                        best = t;
                    }
                }
            }
        }

        // Block 4: (T1, T2) are implicit in `objective` (P4 closed form).
        trajectory.push(best);
        if (before - best).abs() < opts.tol {
            break;
        }
    }
    Ok(BcdResult { decision: d, objective: best, trajectory, iterations })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkConfig;
    use crate::optim::test_support::{fixture, round_robin};
    use crate::profile::resnet18;
    use crate::util::prop::check;
    use crate::util::rng::Rng;
    use crate::channel::{ChannelRealization, Deployment};

    fn prob_fixture<'a>(
        cfg: &'a NetworkConfig,
        profile: &'a crate::profile::NetworkProfile,
        dep: &'a Deployment,
        ch: &'a ChannelRealization,
    ) -> Problem<'a> {
        Problem { cfg, profile, dep, ch, batch: 64, phi: 0.5 }
    }

    #[test]
    fn trajectory_non_increasing_and_feasible() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = prob_fixture(&cfg, &profile, &dep, &ch);
        let res = solve(&prob, BcdOptions::default()).unwrap();
        for w in res.trajectory.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "trajectory increased: {w:?}");
        }
        prob.check_feasible(&res.decision).unwrap();
        assert!(res.objective > 0.0);
        assert!(
            (prob.objective(&res.decision) - res.objective).abs() < 1e-12
        );
    }

    #[test]
    fn beats_naive_baseline_decision() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = prob_fixture(&cfg, &profile, &dep, &ch);
        let res = solve(&prob, BcdOptions::default()).unwrap();
        // Naive: round-robin channels, uniform mild PSD, shallow cut.
        let naive = Decision {
            alloc: round_robin(&cfg),
            psd_dbm_hz: vec![-65.0; 20],
            cut: 1.into(),
        };
        assert!(
            res.objective < prob.objective(&naive),
            "BCD {} !< naive {}",
            res.objective,
            prob.objective(&naive)
        );
    }

    #[test]
    fn converges_within_max_iters() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = prob_fixture(&cfg, &profile, &dep, &ch);
        let res = solve(&prob, BcdOptions { max_iters: 30, tol: 1e-9 })
            .unwrap();
        assert!(res.iterations <= 30);
        // Last two iterations should be ~converged.
        let n = res.trajectory.len();
        if n >= 2 {
            assert!(res.trajectory[n - 2] - res.trajectory[n - 1] < 1e-3);
        }
    }

    #[test]
    fn fast_path_matches_reference_pipeline() {
        // Same deployment, same options: the fast solve and the pre-PR
        // reference pipeline must take the same trajectory (every compared
        // quantity is bit-identical) and land on the same decision.
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = prob_fixture(&cfg, &profile, &dep, &ch);
        let fast = solve(&prob, BcdOptions::default()).unwrap();
        let reference = solve_reference(&prob, BcdOptions::default()).unwrap();
        assert_eq!(fast.decision, reference.decision);
        assert_eq!(
            fast.objective.to_bits(),
            reference.objective.to_bits(),
            "fast {} vs reference {}",
            fast.objective,
            reference.objective
        );
        assert_eq!(fast.trajectory.len(), reference.trajectory.len());
    }

    #[test]
    fn property_bcd_feasible_and_monotone_across_deployments() {
        check("BCD invariants", 10, |g| {
            let mut cfg = NetworkConfig::default();
            cfg.n_clients = g.usize_in(2, 6);
            cfg.n_subchannels = cfg.n_clients + g.usize_in(1, 12);
            cfg.f_server = g.f64_in(1e9, 9e9);
            let profile = resnet18::profile();
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let dep = Deployment::generate(&cfg, &mut rng);
            let ch = ChannelRealization::average(&dep);
            let prob = Problem {
                cfg: &cfg,
                profile: &profile,
                dep: &dep,
                ch: &ch,
                batch: 64,
                phi: *g.choose(&[0.0, 0.5, 1.0]),
            };
            let res =
                solve(&prob, BcdOptions { max_iters: 8, tol: 1e-6 }).unwrap();
            prob.check_feasible(&res.decision).unwrap();
            for w in res.trajectory.windows(2) {
                assert!(w[1] <= w[0] + 1e-12);
            }
        });
    }
}
