//! Algorithm 3 — block-coordinate descent over (r, p, μ, T₁, T₂).
//!
//! Each iteration updates the four blocks in the paper's order:
//! 1. `r`  ← greedy subchannel allocation (Algorithm 2)
//! 2. `θ/p` ← exact power control (P2)
//! 3. `μ`  ← cut-layer MILP via B&B (P3)
//! 4. `(T₁, T₂)` ← closed form (P4, eqs. 33–34)
//!
//! Hardening over the paper's pseudocode: every block update is accepted
//! only if it does not increase the true objective (eq. 23), which makes
//! the trajectory provably non-increasing — BCD on a non-convex problem
//! can otherwise oscillate between blocks.

use crate::channel::rate;
use crate::error::Result;

use super::{cutlayer, greedy, power, Decision, Problem};

/// BCD options.
#[derive(Debug, Clone, Copy)]
pub struct BcdOptions {
    pub max_iters: usize,
    /// Convergence tolerance ε on |ΔT̃| (seconds).
    pub tol: f64,
}

impl Default for BcdOptions {
    fn default() -> Self {
        BcdOptions { max_iters: 20, tol: 1e-6 }
    }
}

/// BCD outcome.
#[derive(Debug, Clone)]
pub struct BcdResult {
    pub decision: Decision,
    pub objective: f64,
    /// Objective after each iteration (non-increasing).
    pub trajectory: Vec<f64>,
    pub iterations: usize,
}

/// Initial decision: middle cut candidate, round-robin-ish greedy at a
/// conservative uniform PSD.
fn initial(prob: &Problem) -> Decision {
    let cands = &prob.profile.cut_candidates;
    let cut = cands[cands.len() / 2];
    let per_client =
        (prob.n_subchannels() / prob.n_clients()).max(1);
    let psd = vec![
        rate::uniform_psd_dbm_hz(
            prob.cfg.p_max_dbm - 3.0,
            per_client,
            prob.cfg.subchannel_bw_hz
        );
        prob.n_subchannels()
    ];
    greedy::allocate_decision(prob, psd, cut)
}

/// Run Algorithm 3.
pub fn solve(prob: &Problem, opts: BcdOptions) -> Result<BcdResult> {
    let mut d = initial(prob);
    let mut best = prob.objective(&d);
    let mut trajectory = vec![best];
    let mut iterations = 0;

    for _ in 0..opts.max_iters {
        iterations += 1;
        let before = best;

        // Block 1: subchannel allocation (Algorithm 2).
        let alloc = greedy::allocate(prob, &d.psd_dbm_hz, d.cut);
        let cand = Decision { alloc, ..d.clone() };
        if prob.check_feasible(&cand).is_ok() {
            let t = prob.objective(&cand);
            if t <= best {
                d = cand;
                best = t;
            }
        }

        // Block 2: power control (P2).
        if let Ok(sol) = power::solve(prob, &d.alloc, d.cut) {
            let cand = Decision { psd_dbm_hz: sol.psd_dbm_hz, ..d.clone() };
            if prob.check_feasible(&cand).is_ok() {
                let t = prob.objective(&cand);
                if t <= best {
                    d = cand;
                    best = t;
                }
            }
        }

        // Block 3: cut layer (P3 via B&B). Re-run power for the new cut so
        // the comparison is fair (the cut changes the uplink payload).
        if let Ok((cut, _stats)) =
            cutlayer::solve(prob, &d.alloc, &d.psd_dbm_hz)
        {
            if cut != d.cut {
                let mut cand = Decision { cut, ..d.clone() };
                if let Ok(sol) = power::solve(prob, &cand.alloc, cut) {
                    cand.psd_dbm_hz = sol.psd_dbm_hz;
                }
                if prob.check_feasible(&cand).is_ok() {
                    let t = prob.objective(&cand);
                    if t <= best {
                        d = cand;
                        best = t;
                    }
                }
            }
        }

        // Block 4: (T1, T2) are implicit in `objective` (P4 closed form).
        trajectory.push(best);
        if (before - best).abs() < opts.tol {
            break;
        }
    }
    Ok(BcdResult { decision: d, objective: best, trajectory, iterations })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkConfig;
    use crate::optim::test_support::{fixture, round_robin};
    use crate::profile::resnet18;
    use crate::util::prop::check;
    use crate::util::rng::Rng;
    use crate::channel::{ChannelRealization, Deployment};

    fn prob_fixture<'a>(
        cfg: &'a NetworkConfig,
        profile: &'a crate::profile::NetworkProfile,
        dep: &'a Deployment,
        ch: &'a ChannelRealization,
    ) -> Problem<'a> {
        Problem { cfg, profile, dep, ch, batch: 64, phi: 0.5 }
    }

    #[test]
    fn trajectory_non_increasing_and_feasible() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = prob_fixture(&cfg, &profile, &dep, &ch);
        let res = solve(&prob, BcdOptions::default()).unwrap();
        for w in res.trajectory.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "trajectory increased: {w:?}");
        }
        prob.check_feasible(&res.decision).unwrap();
        assert!(res.objective > 0.0);
        assert!(
            (prob.objective(&res.decision) - res.objective).abs() < 1e-12
        );
    }

    #[test]
    fn beats_naive_baseline_decision() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = prob_fixture(&cfg, &profile, &dep, &ch);
        let res = solve(&prob, BcdOptions::default()).unwrap();
        // Naive: round-robin channels, uniform mild PSD, shallow cut.
        let naive = Decision {
            alloc: round_robin(&cfg),
            psd_dbm_hz: vec![-65.0; 20],
            cut: 1,
        };
        assert!(
            res.objective < prob.objective(&naive),
            "BCD {} !< naive {}",
            res.objective,
            prob.objective(&naive)
        );
    }

    #[test]
    fn converges_within_max_iters() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = prob_fixture(&cfg, &profile, &dep, &ch);
        let res = solve(&prob, BcdOptions { max_iters: 30, tol: 1e-9 })
            .unwrap();
        assert!(res.iterations <= 30);
        // Last two iterations should be ~converged.
        let n = res.trajectory.len();
        if n >= 2 {
            assert!(res.trajectory[n - 2] - res.trajectory[n - 1] < 1e-3);
        }
    }

    #[test]
    fn property_bcd_feasible_and_monotone_across_deployments() {
        check("BCD invariants", 10, |g| {
            let mut cfg = NetworkConfig::default();
            cfg.n_clients = g.usize_in(2, 6);
            cfg.n_subchannels = cfg.n_clients + g.usize_in(1, 12);
            cfg.f_server = g.f64_in(1e9, 9e9);
            let profile = resnet18::profile();
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let dep = Deployment::generate(&cfg, &mut rng);
            let ch = ChannelRealization::average(&dep);
            let prob = Problem {
                cfg: &cfg,
                profile: &profile,
                dep: &dep,
                ch: &ch,
                batch: 64,
                phi: *g.choose(&[0.0, 0.5, 1.0]),
            };
            let res =
                solve(&prob, BcdOptions { max_iters: 8, tol: 1e-6 }).unwrap();
            prob.check_feasible(&res.decision).unwrap();
            for w in res.trajectory.windows(2) {
                assert!(w[1] <= w[0] + 1e-12);
            }
        });
    }
}
