//! Resource management for EPSL — paper §V–§VI.
//!
//! The joint problem (24) minimizes per-round latency over subchannel
//! allocation `r`, transmit PSDs `p`, and the cut layer `μ`, subject to:
//!
//! - C1/C2: each subchannel exclusively owned by one client
//! - C3/C4: exactly one cut layer
//! - C5: per-device power `Σ_k r_i^k p_k B_k ≤ p_i^max`
//! - C6: total uplink power `Σ_i Σ_k r_i^k p_k B_k ≤ p_th`
//! - C7: non-negative PSDs
//!
//! NP-hard MINLP → block-coordinate descent (Algorithm 3) over four
//! subproblems: [`greedy`] (P1, Algorithm 2), [`power`] (P2, exact KKT
//! water-filling), [`cutlayer`] (P3, MILP via the [`milp`] branch-and-bound
//! substrate with a two-phase simplex LP relaxation), and [`lp`] (P4,
//! closed form eqs. 33–34). [`baselines`] implements comparison schemes
//! a–d of §VII-C. [`eval`] is the decision-evaluation fast path: per-problem
//! precomputed SNR/FLOP/payload tables serving allocation-free objective
//! evaluation to all of the above (with [`Problem::objective`] kept as the
//! from-scratch reference).

pub mod baselines;
pub mod bcd;
pub mod cutlayer;
pub mod eval;
pub mod greedy;
pub mod lp;
pub mod milp;
pub mod power;

pub mod hetero;

use crate::channel::rate::{self, Allocation};
use crate::channel::{ChannelRealization, Deployment};
use crate::config::{dbm_to_w, NetworkConfig};
use crate::error::{Error, Result};
use crate::latency::{
    epsl_stage_latencies, epsl_stage_latencies_hetero, LatencyInputs,
    StageLatencies,
};
use crate::profile::NetworkProfile;

/// Per-client cut-layer assignment μ.
///
/// `Uniform(j)` is the paper's Alg. 3 decision (one cut for the whole
/// cohort) and the fast path everywhere: any all-equal assignment
/// normalizes to it through [`CutAssignment::as_uniform`], which every
/// consumer uses to dispatch to the literal single-cut code path — so a
/// `PerClient` vector whose entries agree is *bit-identical* to the
/// scalar it replaces, not merely numerically close.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CutAssignment {
    /// Every client splits at layer j.
    Uniform(usize),
    /// Client i splits at layer `v[i]`; `v.len()` must equal the client
    /// count of the problem the decision belongs to.
    PerClient(Vec<usize>),
}

impl CutAssignment {
    /// `Some(j)` iff every client splits at the same layer j (covers
    /// `Uniform(j)` and all-equal `PerClient` vectors). This is *the*
    /// dispatch point that keeps uniform assignments on the pre-existing
    /// single-cut code paths.
    pub fn as_uniform(&self) -> Option<usize> {
        match self {
            CutAssignment::Uniform(j) => Some(*j),
            CutAssignment::PerClient(v) => match v.split_first() {
                Some((first, rest)) if rest.iter().all(|c| c == first) => {
                    Some(*first)
                }
                _ => None,
            },
        }
    }

    /// Cut layer of client `i`. For `PerClient` the index must be in
    /// range (assignments are validated against the client count before
    /// they reach any consumer).
    pub fn cut_of(&self, i: usize) -> usize {
        match self {
            CutAssignment::Uniform(j) => *j,
            CutAssignment::PerClient(v) => v[i],
        }
    }

    /// Materialize the per-client vector for `c` clients.
    pub fn cuts_for(&self, c: usize) -> Vec<usize> {
        match self {
            CutAssignment::Uniform(j) => vec![*j; c],
            CutAssignment::PerClient(v) => v.clone(),
        }
    }

    /// Shallowest cut in the assignment.
    pub fn min_cut(&self) -> usize {
        match self {
            CutAssignment::Uniform(j) => *j,
            CutAssignment::PerClient(v) => {
                v.iter().copied().min().unwrap_or(1)
            }
        }
    }

    /// Deepest cut in the assignment.
    pub fn max_cut(&self) -> usize {
        match self {
            CutAssignment::Uniform(j) => *j,
            CutAssignment::PerClient(v) => {
                v.iter().copied().max().unwrap_or(1)
            }
        }
    }

    /// Client indices grouped by cut, ascending in cut layer. Group
    /// member lists preserve client order.
    pub fn groups(&self, c: usize) -> Vec<(usize, Vec<usize>)> {
        let cuts = self.cuts_for(c);
        let mut distinct: Vec<usize> = cuts.clone();
        distinct.sort_unstable();
        distinct.dedup();
        distinct
            .into_iter()
            .map(|j| {
                let members: Vec<usize> = (0..cuts.len())
                    .filter(|&i| cuts[i] == j)
                    .collect();
                (j, members)
            })
            .collect()
    }

    /// Validate shape (len == C for `PerClient`) and membership of every
    /// cut in `candidates`. Typed `Error::Config` so config/manifest
    /// layers can reject bad assignments at parse time.
    pub fn validate(&self, n_clients: usize, candidates: &[usize])
        -> Result<()> {
        match self {
            CutAssignment::Uniform(j) => {
                if !candidates.contains(j) {
                    return Err(Error::Config(format!(
                        "cut {j} not a candidate (candidates: \
                         {candidates:?})"
                    )));
                }
            }
            CutAssignment::PerClient(v) => {
                if v.len() != n_clients {
                    return Err(Error::Config(format!(
                        "cut vector has {} entries but the deployment \
                         has {n_clients} client(s)",
                        v.len()
                    )));
                }
                for (i, j) in v.iter().enumerate() {
                    if !candidates.contains(j) {
                        return Err(Error::Config(format!(
                            "client {i}: cut {j} not a candidate \
                             (candidates: {candidates:?})"
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Canonicalize a per-client vector: all-equal collapses to
    /// `Uniform(j)` so downstream `as_uniform` dispatch — and equality
    /// with a uniform-solver decision — is exact.
    pub fn normalized(cuts: Vec<usize>) -> CutAssignment {
        match CutAssignment::PerClient(cuts.clone()).as_uniform() {
            Some(j) => CutAssignment::Uniform(j),
            None => CutAssignment::PerClient(cuts),
        }
    }

    /// Compact label: `"2"` for uniform, `"1-2-2-3"` per client
    /// ('-'-separated so it stays CSV-safe).
    pub fn label(&self) -> String {
        match self.as_uniform() {
            Some(j) => j.to_string(),
            None => match self {
                CutAssignment::PerClient(v) => v
                    .iter()
                    .map(|j| j.to_string())
                    .collect::<Vec<_>>()
                    .join("-"),
                CutAssignment::Uniform(j) => j.to_string(),
            },
        }
    }

    /// Parse a CLI/TOML cut spec: `"2"` (uniform) or `"1-2-2-3"`
    /// (per-client).
    pub fn parse(s: &str) -> Result<CutAssignment> {
        let parts: Vec<&str> = s.split('-').collect();
        let mut cuts = Vec::with_capacity(parts.len());
        for p in &parts {
            cuts.push(p.trim().parse::<usize>().map_err(|_| {
                Error::Config(format!(
                    "bad cut spec '{s}' (expected e.g. \"2\" or \
                     \"1-2-2-3\")"
                ))
            })?);
        }
        match cuts.as_slice() {
            [] => Err(Error::Config(format!("empty cut spec '{s}'"))),
            [j] => Ok(CutAssignment::Uniform(*j)),
            _ => Ok(CutAssignment::PerClient(cuts)),
        }
    }
}

impl From<usize> for CutAssignment {
    fn from(j: usize) -> Self {
        CutAssignment::Uniform(j)
    }
}

impl From<Vec<usize>> for CutAssignment {
    fn from(v: Vec<usize>) -> Self {
        CutAssignment::PerClient(v)
    }
}

impl std::fmt::Display for CutAssignment {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

impl PartialEq<usize> for CutAssignment {
    fn eq(&self, other: &usize) -> bool {
        self.as_uniform() == Some(*other)
    }
}

/// One resource-management problem instance (fixed deployment + channel).
#[derive(Debug, Clone)]
pub struct Problem<'a> {
    pub cfg: &'a NetworkConfig,
    pub profile: &'a NetworkProfile,
    pub dep: &'a Deployment,
    /// The gains the optimizer sees (the paper's average γ(F_k, d_i)).
    pub ch: &'a ChannelRealization,
    pub batch: usize,
    pub phi: f64,
}

/// A complete decision: (r, p, μ).
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    pub alloc: Allocation,
    /// Per-subchannel transmit PSD (dBm/Hz).
    pub psd_dbm_hz: Vec<f64>,
    /// Cut-layer assignment μ (uniform or per-client).
    pub cut: CutAssignment,
}

impl Decision {
    /// The single cut layer when the assignment is uniform (the paper's
    /// original decision space); `Error::Optim` otherwise.
    pub fn uniform_cut(&self) -> Result<usize> {
        self.cut.as_uniform().ok_or_else(|| {
            Error::Optim(format!(
                "decision has per-client cuts ({}) where a uniform cut \
                 is required",
                self.cut
            ))
        })
    }
}

impl<'a> Problem<'a> {
    pub fn n_clients(&self) -> usize {
        self.dep.n_clients()
    }

    pub fn n_subchannels(&self) -> usize {
        self.dep.n_subchannels()
    }

    /// Uplink power of client `i` in watts: `Σ_{k∈M_i} p_k B_k` (C5 LHS).
    pub fn client_power_w(&self, d: &Decision, i: usize) -> f64 {
        d.alloc
            .channels_of(i)
            .iter()
            .map(|&k| {
                dbm_to_w(d.psd_dbm_hz[k])
                    * self.dep.subchannels[k].bandwidth_hz
            })
            .sum()
    }

    /// Total uplink power in watts (C6 LHS).
    pub fn total_power_w(&self, d: &Decision) -> f64 {
        (0..self.n_clients()).map(|i| self.client_power_w(d, i)).sum()
    }

    /// Check C1–C7 feasibility.
    pub fn check_feasible(&self, d: &Decision) -> Result<()> {
        if d.alloc.owner.len() != self.n_subchannels() {
            return Err(Error::Optim("allocation size mismatch".into()));
        }
        if !d.alloc.is_complete() {
            return Err(Error::Optim("C2: unassigned subchannel".into()));
        }
        match &d.cut {
            CutAssignment::Uniform(j) => {
                if !self.profile.cut_candidates.contains(j) {
                    return Err(Error::Optim(format!(
                        "C3/C4: cut {j} not a candidate"
                    )));
                }
            }
            CutAssignment::PerClient(v) => {
                if v.len() != self.n_clients() {
                    return Err(Error::Optim(format!(
                        "C3/C4: cut vector has {} entries for {} \
                         client(s)",
                        v.len(),
                        self.n_clients()
                    )));
                }
                for (i, j) in v.iter().enumerate() {
                    if !self.profile.cut_candidates.contains(j) {
                        return Err(Error::Optim(format!(
                            "C3/C4: client {i} cut {j} not a candidate"
                        )));
                    }
                }
            }
        }
        let p_max = dbm_to_w(self.cfg.p_max_dbm);
        for i in 0..self.n_clients() {
            let pw = self.client_power_w(d, i);
            if pw > p_max * (1.0 + 1e-6) {
                return Err(Error::Optim(format!(
                    "C5: client {i} power {pw:.3} W > {p_max:.3} W"
                )));
            }
        }
        let pth = dbm_to_w(self.cfg.p_th_dbm);
        let total = self.total_power_w(d);
        if total > pth * (1.0 + 1e-6) {
            return Err(Error::Optim(format!(
                "C6: total power {total:.3} W > {pth:.3} W"
            )));
        }
        Ok(())
    }

    /// Link rates implied by a decision: (uplink R_i^U, downlink R_i^D,
    /// broadcast R^B).
    pub fn rates(&self, d: &Decision) -> (Vec<f64>, Vec<f64>, f64) {
        let up = rate::uplink_rates(self.cfg, self.ch, &d.alloc, &d.psd_dbm_hz);
        let dn = rate::downlink_rates(self.cfg, self.ch, &d.alloc);
        let bc = rate::broadcast_rate(self.cfg, self.ch);
        (up, dn, bc)
    }

    /// Full EPSL stage latencies for a decision (objective eq. 23).
    ///
    /// Uniform (and all-equal per-client) assignments take the literal
    /// single-cut closed form; mixed assignments take the grouped-by-cut
    /// extension.
    pub fn stage_latencies(&self, d: &Decision) -> StageLatencies {
        let (up, dn, bc) = self.rates(d);
        let inp = LatencyInputs {
            profile: self.profile,
            cut: d.cut.min_cut(),
            batch: self.batch,
            phi: self.phi,
            f_server: self.cfg.f_server,
            kappa_server: self.cfg.kappa_server,
            kappa_client: self.cfg.kappa_client,
            f_clients: self.dep.f_clients(),
            uplink: &up,
            downlink: &dn,
            broadcast: bc,
            uplink_comp: self.cfg.uplink_compression,
        };
        match d.cut.as_uniform() {
            Some(j) => {
                let inp = LatencyInputs { cut: j, ..inp };
                epsl_stage_latencies(&inp)
            }
            None => epsl_stage_latencies_hetero(
                &inp,
                &d.cut.cuts_for(self.n_clients()),
            ),
        }
    }

    /// Objective value T(r, μ, p).
    pub fn objective(&self, d: &Decision) -> f64 {
        self.stage_latencies(d).round_total()
    }

    /// Per-Hz SNR coefficient for client i on subchannel k:
    /// rate_k = B log2(1 + p_k · coeff) with p_k the linear PSD (W/Hz).
    /// coeff = G_c G_s γ_ik / σ²  (σ² converted from dBm/Hz to W/Hz).
    pub fn snr_coeff(&self, i: usize, k: usize) -> f64 {
        let noise_w_hz = dbm_to_w(self.cfg.noise_dbm_hz);
        self.cfg.antenna_gain * self.ch.gain[i][k] / noise_w_hz
    }

    /// T_i^F (seconds) — cut-dependent client forward time.
    pub fn client_fp_seconds(&self, i: usize, cut: usize) -> f64 {
        self.batch as f64
            * self.cfg.kappa_client
            * self.profile.client_fp_flops(cut)
            / self.dep.clients[i].f_client
    }

    /// T_i^B (seconds) — cut-dependent client backward time.
    pub fn client_bp_seconds(&self, i: usize, cut: usize) -> f64 {
        self.batch as f64
            * self.cfg.kappa_client
            * self.profile.client_bp_flops(cut)
            / self.dep.clients[i].f_client
    }

    /// Uplink payload bits for one round: b·ψ_j·γ (γ = the configured
    /// activation-compression factor; γ = 1 is the raw f32 payload).
    pub fn uplink_bits(&self, cut: usize) -> f64 {
        self.batch as f64
            * self.profile.psi_bits(cut)
            * self.cfg.uplink_compression
    }

    /// Unicast downlink payload bits: (b − ⌈φb⌉)·χ_j.
    pub fn downlink_bits(&self, cut: usize) -> f64 {
        let m = (self.phi * self.batch as f64).ceil();
        (self.batch as f64 - m) * self.profile.chi_bits(cut)
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::util::rng::Rng;

    /// Shared fixture: default deployment + average channel.
    pub fn fixture(cfg: &NetworkConfig) -> (Deployment, ChannelRealization) {
        let mut rng = Rng::new(11);
        let dep = Deployment::generate(cfg, &mut rng);
        let ch = ChannelRealization::average(&dep);
        (dep, ch)
    }

    /// Round-robin complete allocation.
    pub fn round_robin(cfg: &NetworkConfig) -> Allocation {
        let mut alloc = Allocation::empty(cfg.n_subchannels);
        for k in 0..cfg.n_subchannels {
            alloc.assign(k, k % cfg.n_clients);
        }
        alloc
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::*;
    use super::*;
    use crate::profile::resnet18;

    #[test]
    fn feasibility_checks_fire() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        // incomplete allocation
        let d = Decision {
            alloc: Allocation::empty(cfg.n_subchannels),
            psd_dbm_hz: vec![-60.0; cfg.n_subchannels],
            cut: 3.into(),
        };
        assert!(prob.check_feasible(&d).is_err());
        // complete, sane powers
        let d = Decision {
            alloc: round_robin(&cfg),
            psd_dbm_hz: vec![-60.0; cfg.n_subchannels],
            cut: 3.into(),
        };
        prob.check_feasible(&d).unwrap();
        // hot PSD violates C5: -35 dBm/Hz * 10 MHz = 35 dBm per channel.
        let d_hot = Decision { psd_dbm_hz: vec![-35.0; 20], ..d.clone() };
        assert!(prob.check_feasible(&d_hot).is_err());
        // bad cut (last layer)
        let d_cut = Decision { cut: 18.into(), ..d };
        assert!(prob.check_feasible(&d_cut).is_err());
    }

    #[test]
    fn objective_positive_and_cut_sensitive() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let mk = |cut: usize| Decision {
            alloc: round_robin(&cfg),
            psd_dbm_hz: vec![-60.0; 20],
            cut: cut.into(),
        };
        let t1 = prob.objective(&mk(1));
        let t9 = prob.objective(&mk(9));
        assert!(t1 > 0.0 && t9 > 0.0);
        assert_ne!(t1, t9);
    }

    #[test]
    fn power_accounting_watts() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let mut alloc = Allocation::empty(20);
        alloc.assign(0, 0);
        let d =
            Decision { alloc, psd_dbm_hz: vec![-60.0; 20], cut: 3.into() };
        // -60 dBm/Hz over 10 MHz = -60 + 70 = 10 dBm = 10 mW.
        let pw = prob.client_power_w(&d, 0);
        assert!((pw - 0.01).abs() < 1e-6, "{pw}");
        assert_eq!(prob.client_power_w(&d, 1), 0.0);
        assert!((prob.total_power_w(&d) - pw).abs() < 1e-12);
    }

    #[test]
    fn snr_coeff_matches_rate_module() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        // B log2(1 + p_lin * coeff) must equal rate::subchannel_rate at the
        // same dBm/Hz PSD.
        let psd = -60.0;
        let p_lin = dbm_to_w(psd);
        let coeff = prob.snr_coeff(2, 3);
        let direct = rate::subchannel_rate(
            cfg.subchannel_bw_hz,
            rate::snr_linear(
                psd,
                cfg.antenna_gain,
                ch.gain[2][3],
                cfg.noise_dbm_hz,
            ),
        );
        let via_coeff = cfg.subchannel_bw_hz * (1.0 + p_lin * coeff).log2();
        assert!((direct - via_coeff).abs() / direct < 1e-9);
    }

    #[test]
    fn cut_assignment_uniform_dispatch() {
        assert_eq!(CutAssignment::Uniform(3).as_uniform(), Some(3));
        assert_eq!(
            CutAssignment::PerClient(vec![2, 2, 2]).as_uniform(),
            Some(2)
        );
        assert_eq!(
            CutAssignment::PerClient(vec![1, 2, 2]).as_uniform(),
            None
        );
        assert_eq!(CutAssignment::PerClient(vec![]).as_uniform(), None);
        // PartialEq<usize> keeps scalar assertions working.
        assert_eq!(CutAssignment::Uniform(4), 4);
        assert_eq!(CutAssignment::PerClient(vec![4, 4]), 4);
        assert!(CutAssignment::PerClient(vec![1, 4]) != 4);
    }

    #[test]
    fn cut_assignment_groups_and_extremes() {
        let a = CutAssignment::PerClient(vec![3, 1, 3, 2]);
        assert_eq!(a.min_cut(), 1);
        assert_eq!(a.max_cut(), 3);
        assert_eq!(a.cut_of(2), 3);
        assert_eq!(a.groups(4), vec![
            (1, vec![1]),
            (2, vec![3]),
            (3, vec![0, 2]),
        ]);
        let u = CutAssignment::Uniform(2);
        assert_eq!(u.groups(3), vec![(2, vec![0, 1, 2])]);
        assert_eq!(u.cuts_for(3), vec![2, 2, 2]);
    }

    #[test]
    fn cut_assignment_labels_and_parse() {
        assert_eq!(CutAssignment::Uniform(2).label(), "2");
        // All-equal per-client vectors label as the uniform scalar.
        assert_eq!(CutAssignment::PerClient(vec![2, 2]).label(), "2");
        assert_eq!(
            CutAssignment::PerClient(vec![1, 2, 2, 3]).label(),
            "1-2-2-3"
        );
        assert_eq!(
            CutAssignment::parse("2").unwrap(),
            CutAssignment::Uniform(2)
        );
        assert_eq!(
            CutAssignment::parse("1-2-2-3").unwrap(),
            CutAssignment::PerClient(vec![1, 2, 2, 3])
        );
        assert!(CutAssignment::parse("hi").is_err());
        assert!(CutAssignment::parse("1-x").is_err());
    }

    #[test]
    fn cut_assignment_validate_typed_errors() {
        let cands = [1, 2, 3, 4];
        CutAssignment::Uniform(2).validate(4, &cands).unwrap();
        CutAssignment::PerClient(vec![1, 4, 2, 3])
            .validate(4, &cands)
            .unwrap();
        let short = CutAssignment::PerClient(vec![1, 2]).validate(4, &cands);
        assert!(matches!(short, Err(Error::Config(_))), "{short:?}");
        let bad = CutAssignment::PerClient(vec![1, 2, 9, 3])
            .validate(4, &cands);
        assert!(matches!(bad, Err(Error::Config(_))), "{bad:?}");
        let off = CutAssignment::Uniform(7).validate(4, &cands);
        assert!(matches!(off, Err(Error::Config(_))), "{off:?}");
    }

    #[test]
    fn mixed_cut_feasibility_and_latency() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let c = prob.n_clients();
        // All-equal per-client vector is bit-identical to the scalar.
        let d_uni = Decision {
            alloc: round_robin(&cfg),
            psd_dbm_hz: vec![-60.0; 20],
            cut: 4.into(),
        };
        let d_vec = Decision { cut: vec![4; c].into(), ..d_uni.clone() };
        prob.check_feasible(&d_vec).unwrap();
        assert_eq!(
            prob.objective(&d_uni).to_bits(),
            prob.objective(&d_vec).to_bits()
        );
        // A genuinely mixed assignment is feasible and positive.
        let mut cuts = vec![4; c];
        cuts[0] = 1;
        cuts[1 % c] = 10;
        let d_mix = Decision { cut: cuts.into(), ..d_uni.clone() };
        prob.check_feasible(&d_mix).unwrap();
        assert!(prob.objective(&d_mix) > 0.0);
        // Wrong-length vectors are infeasible.
        let d_short = Decision { cut: vec![4; c - 1].into(), ..d_uni };
        assert!(prob.check_feasible(&d_short).is_err());
    }
}
