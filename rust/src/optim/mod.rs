//! Resource management for EPSL — paper §V–§VI.
//!
//! The joint problem (24) minimizes per-round latency over subchannel
//! allocation `r`, transmit PSDs `p`, and the cut layer `μ`, subject to:
//!
//! - C1/C2: each subchannel exclusively owned by one client
//! - C3/C4: exactly one cut layer
//! - C5: per-device power `Σ_k r_i^k p_k B_k ≤ p_i^max`
//! - C6: total uplink power `Σ_i Σ_k r_i^k p_k B_k ≤ p_th`
//! - C7: non-negative PSDs
//!
//! NP-hard MINLP → block-coordinate descent (Algorithm 3) over four
//! subproblems: [`greedy`] (P1, Algorithm 2), [`power`] (P2, exact KKT
//! water-filling), [`cutlayer`] (P3, MILP via the [`milp`] branch-and-bound
//! substrate with a two-phase simplex LP relaxation), and [`lp`] (P4,
//! closed form eqs. 33–34). [`baselines`] implements comparison schemes
//! a–d of §VII-C. [`eval`] is the decision-evaluation fast path: per-problem
//! precomputed SNR/FLOP/payload tables serving allocation-free objective
//! evaluation to all of the above (with [`Problem::objective`] kept as the
//! from-scratch reference).

pub mod baselines;
pub mod bcd;
pub mod cutlayer;
pub mod eval;
pub mod greedy;
pub mod lp;
pub mod milp;
pub mod power;

use crate::channel::rate::{self, Allocation};
use crate::channel::{ChannelRealization, Deployment};
use crate::config::{dbm_to_w, NetworkConfig};
use crate::error::{Error, Result};
use crate::latency::{epsl_stage_latencies, LatencyInputs, StageLatencies};
use crate::profile::NetworkProfile;

/// One resource-management problem instance (fixed deployment + channel).
#[derive(Debug, Clone)]
pub struct Problem<'a> {
    pub cfg: &'a NetworkConfig,
    pub profile: &'a NetworkProfile,
    pub dep: &'a Deployment,
    /// The gains the optimizer sees (the paper's average γ(F_k, d_i)).
    pub ch: &'a ChannelRealization,
    pub batch: usize,
    pub phi: f64,
}

/// A complete decision: (r, p, μ).
#[derive(Debug, Clone, PartialEq)]
pub struct Decision {
    pub alloc: Allocation,
    /// Per-subchannel transmit PSD (dBm/Hz).
    pub psd_dbm_hz: Vec<f64>,
    /// Cut layer j.
    pub cut: usize,
}

impl<'a> Problem<'a> {
    pub fn n_clients(&self) -> usize {
        self.dep.n_clients()
    }

    pub fn n_subchannels(&self) -> usize {
        self.dep.n_subchannels()
    }

    /// Uplink power of client `i` in watts: `Σ_{k∈M_i} p_k B_k` (C5 LHS).
    pub fn client_power_w(&self, d: &Decision, i: usize) -> f64 {
        d.alloc
            .channels_of(i)
            .iter()
            .map(|&k| {
                dbm_to_w(d.psd_dbm_hz[k])
                    * self.dep.subchannels[k].bandwidth_hz
            })
            .sum()
    }

    /// Total uplink power in watts (C6 LHS).
    pub fn total_power_w(&self, d: &Decision) -> f64 {
        (0..self.n_clients()).map(|i| self.client_power_w(d, i)).sum()
    }

    /// Check C1–C7 feasibility.
    pub fn check_feasible(&self, d: &Decision) -> Result<()> {
        if d.alloc.owner.len() != self.n_subchannels() {
            return Err(Error::Optim("allocation size mismatch".into()));
        }
        if !d.alloc.is_complete() {
            return Err(Error::Optim("C2: unassigned subchannel".into()));
        }
        if !self.profile.cut_candidates.contains(&d.cut) {
            return Err(Error::Optim(format!(
                "C3/C4: cut {} not a candidate",
                d.cut
            )));
        }
        let p_max = dbm_to_w(self.cfg.p_max_dbm);
        for i in 0..self.n_clients() {
            let pw = self.client_power_w(d, i);
            if pw > p_max * (1.0 + 1e-6) {
                return Err(Error::Optim(format!(
                    "C5: client {i} power {pw:.3} W > {p_max:.3} W"
                )));
            }
        }
        let pth = dbm_to_w(self.cfg.p_th_dbm);
        let total = self.total_power_w(d);
        if total > pth * (1.0 + 1e-6) {
            return Err(Error::Optim(format!(
                "C6: total power {total:.3} W > {pth:.3} W"
            )));
        }
        Ok(())
    }

    /// Link rates implied by a decision: (uplink R_i^U, downlink R_i^D,
    /// broadcast R^B).
    pub fn rates(&self, d: &Decision) -> (Vec<f64>, Vec<f64>, f64) {
        let up = rate::uplink_rates(self.cfg, self.ch, &d.alloc, &d.psd_dbm_hz);
        let dn = rate::downlink_rates(self.cfg, self.ch, &d.alloc);
        let bc = rate::broadcast_rate(self.cfg, self.ch);
        (up, dn, bc)
    }

    /// Full EPSL stage latencies for a decision (objective eq. 23).
    pub fn stage_latencies(&self, d: &Decision) -> StageLatencies {
        let (up, dn, bc) = self.rates(d);
        let inp = LatencyInputs {
            profile: self.profile,
            cut: d.cut,
            batch: self.batch,
            phi: self.phi,
            f_server: self.cfg.f_server,
            kappa_server: self.cfg.kappa_server,
            kappa_client: self.cfg.kappa_client,
            f_clients: self.dep.f_clients(),
            uplink: &up,
            downlink: &dn,
            broadcast: bc,
        };
        epsl_stage_latencies(&inp)
    }

    /// Objective value T(r, μ, p).
    pub fn objective(&self, d: &Decision) -> f64 {
        self.stage_latencies(d).round_total()
    }

    /// Per-Hz SNR coefficient for client i on subchannel k:
    /// rate_k = B log2(1 + p_k · coeff) with p_k the linear PSD (W/Hz).
    /// coeff = G_c G_s γ_ik / σ²  (σ² converted from dBm/Hz to W/Hz).
    pub fn snr_coeff(&self, i: usize, k: usize) -> f64 {
        let noise_w_hz = dbm_to_w(self.cfg.noise_dbm_hz);
        self.cfg.antenna_gain * self.ch.gain[i][k] / noise_w_hz
    }

    /// T_i^F (seconds) — cut-dependent client forward time.
    pub fn client_fp_seconds(&self, i: usize, cut: usize) -> f64 {
        self.batch as f64
            * self.cfg.kappa_client
            * self.profile.client_fp_flops(cut)
            / self.dep.clients[i].f_client
    }

    /// T_i^B (seconds) — cut-dependent client backward time.
    pub fn client_bp_seconds(&self, i: usize, cut: usize) -> f64 {
        self.batch as f64
            * self.cfg.kappa_client
            * self.profile.client_bp_flops(cut)
            / self.dep.clients[i].f_client
    }

    /// Uplink payload bits for one round: b·ψ_j.
    pub fn uplink_bits(&self, cut: usize) -> f64 {
        self.batch as f64 * self.profile.psi_bits(cut)
    }

    /// Unicast downlink payload bits: (b − ⌈φb⌉)·χ_j.
    pub fn downlink_bits(&self, cut: usize) -> f64 {
        let m = (self.phi * self.batch as f64).ceil();
        (self.batch as f64 - m) * self.profile.chi_bits(cut)
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::util::rng::Rng;

    /// Shared fixture: default deployment + average channel.
    pub fn fixture(cfg: &NetworkConfig) -> (Deployment, ChannelRealization) {
        let mut rng = Rng::new(11);
        let dep = Deployment::generate(cfg, &mut rng);
        let ch = ChannelRealization::average(&dep);
        (dep, ch)
    }

    /// Round-robin complete allocation.
    pub fn round_robin(cfg: &NetworkConfig) -> Allocation {
        let mut alloc = Allocation::empty(cfg.n_subchannels);
        for k in 0..cfg.n_subchannels {
            alloc.assign(k, k % cfg.n_clients);
        }
        alloc
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::*;
    use super::*;
    use crate::profile::resnet18;

    #[test]
    fn feasibility_checks_fire() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        // incomplete allocation
        let d = Decision {
            alloc: Allocation::empty(cfg.n_subchannels),
            psd_dbm_hz: vec![-60.0; cfg.n_subchannels],
            cut: 3,
        };
        assert!(prob.check_feasible(&d).is_err());
        // complete, sane powers
        let d = Decision {
            alloc: round_robin(&cfg),
            psd_dbm_hz: vec![-60.0; cfg.n_subchannels],
            cut: 3,
        };
        prob.check_feasible(&d).unwrap();
        // hot PSD violates C5: -35 dBm/Hz * 10 MHz = 35 dBm per channel.
        let d_hot = Decision { psd_dbm_hz: vec![-35.0; 20], ..d.clone() };
        assert!(prob.check_feasible(&d_hot).is_err());
        // bad cut (last layer)
        let d_cut = Decision { cut: 18, ..d };
        assert!(prob.check_feasible(&d_cut).is_err());
    }

    #[test]
    fn objective_positive_and_cut_sensitive() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let mk = |cut| Decision {
            alloc: round_robin(&cfg),
            psd_dbm_hz: vec![-60.0; 20],
            cut,
        };
        let t1 = prob.objective(&mk(1));
        let t9 = prob.objective(&mk(9));
        assert!(t1 > 0.0 && t9 > 0.0);
        assert_ne!(t1, t9);
    }

    #[test]
    fn power_accounting_watts() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let mut alloc = Allocation::empty(20);
        alloc.assign(0, 0);
        let d = Decision { alloc, psd_dbm_hz: vec![-60.0; 20], cut: 3 };
        // -60 dBm/Hz over 10 MHz = -60 + 70 = 10 dBm = 10 mW.
        let pw = prob.client_power_w(&d, 0);
        assert!((pw - 0.01).abs() < 1e-6, "{pw}");
        assert_eq!(prob.client_power_w(&d, 1), 0.0);
        assert!((prob.total_power_w(&d) - pw).abs() < 1e-12);
    }

    #[test]
    fn snr_coeff_matches_rate_module() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        // B log2(1 + p_lin * coeff) must equal rate::subchannel_rate at the
        // same dBm/Hz PSD.
        let psd = -60.0;
        let p_lin = dbm_to_w(psd);
        let coeff = prob.snr_coeff(2, 3);
        let direct = rate::subchannel_rate(
            cfg.subchannel_bw_hz,
            rate::snr_linear(
                psd,
                cfg.antenna_gain,
                ch.gain[2][3],
                cfg.noise_dbm_hz,
            ),
        );
        let via_coeff = cfg.subchannel_bw_hz * (1.0 + p_lin * coeff).log2();
        assert!((direct - via_coeff).abs() / direct < 1e-9);
    }
}
