//! Comparison schemes a)–d) from the paper's §VII-C (Figs. 11–12), plus the
//! proposed full BCD solution.
//!
//! - **a)** RSS-based subchannel allocation, uniform PSD, random cut.
//! - **b)** greedy allocation (Alg. 2) + optimized power (P2), random cut.
//! - **c)** RSS-based allocation + optimized cut (P3) + optimized power.
//! - **d)** greedy allocation + optimized cut, uniform PSD.
//! - **proposed**: the full BCD of Algorithm 3.
//!
//! RSS-based allocation assigns each subchannel to the client with the
//! highest received signal strength on it (∝ mean gain at equal PSD). To
//! keep every client served (an implicit assumption in the paper — latency
//! would otherwise be unbounded), each client is first granted its best
//! subchannel, then the rest go by RSS.

use crate::channel::rate::{uniform_psd_dbm_hz, Allocation};
use crate::config::dbm_to_w;
use crate::error::Result;
use crate::util::fp::cmp_finite;
use crate::util::rng::Rng;

use super::bcd::{self, BcdOptions};
use super::eval::Evaluator;
use super::power::PSD_OFF_DBM_HZ;
use super::{cutlayer, greedy, power, Decision, Problem};

/// The five schemes of Figs. 11–12.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    BaselineA,
    BaselineB,
    BaselineC,
    BaselineD,
    Proposed,
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::BaselineA => "baseline a (RSS+uniform+rand cut)",
            Scheme::BaselineB => "baseline b (greedy+power+rand cut)",
            Scheme::BaselineC => "baseline c (RSS+cut+power)",
            Scheme::BaselineD => "baseline d (greedy+cut+uniform)",
            Scheme::Proposed => "proposed (BCD)",
        }
    }

    pub fn all() -> [Scheme; 5] {
        [
            Scheme::BaselineA,
            Scheme::BaselineB,
            Scheme::BaselineC,
            Scheme::BaselineD,
            Scheme::Proposed,
        ]
    }
}

/// RSS-based subchannel allocation.
pub fn rss_allocation(prob: &Problem) -> Allocation {
    let c = prob.n_clients();
    let m = prob.n_subchannels();
    let mut alloc = Allocation::empty(m);
    let mut taken = vec![false; m];
    // Serve every client its best channel first.
    let mut order: Vec<usize> = (0..c).collect();
    // Weakest average link first so it gets a genuine pick.
    order.sort_by(|&a, &b| {
        let ga: f64 = prob.ch.gain[a].iter().sum();
        let gb: f64 = prob.ch.gain[b].iter().sum();
        cmp_finite(ga, gb)
    });
    for &i in &order {
        let k = (0..m)
            .filter(|&k| !taken[k])
            .max_by(|&a, &b| {
                cmp_finite(prob.ch.gain[i][a], prob.ch.gain[i][b])
            })
            // audit:allow(R1, "M >= C is a Problem invariant, so an untaken channel always remains during the first pass")
            .expect("M >= C");
        alloc.assign(k, i);
        taken[k] = true;
    }
    // Remaining channels: highest RSS owner.
    for k in 0..m {
        if !taken[k] {
            let i = (0..c)
                .max_by(|&a, &b| {
                    cmp_finite(prob.ch.gain[a][k], prob.ch.gain[b][k])
                })
                // audit:allow(R1, "0..c is non-empty: NetworkConfig validation guarantees at least one client")
                .unwrap();
            alloc.assign(k, i);
        }
    }
    alloc
}

/// Uniform PSD plan: every client spreads its power budget evenly over its
/// subchannels; globally scaled down if C6 would be violated.
pub fn uniform_power(prob: &Problem, alloc: &Allocation) -> Vec<f64> {
    let m = prob.n_subchannels();
    let c = prob.n_clients();
    let p_max_w = dbm_to_w(prob.cfg.p_max_dbm);
    let p_th_w = dbm_to_w(prob.cfg.p_th_dbm);
    let scale = (p_th_w / (c as f64 * p_max_w)).min(1.0);
    let mut psd = vec![PSD_OFF_DBM_HZ; m];
    for i in 0..c {
        let chs = alloc.channels_of(i);
        if chs.is_empty() {
            continue;
        }
        let dbm_budget =
            prob.cfg.p_max_dbm + 10.0 * scale.log10();
        let v = uniform_psd_dbm_hz(
            dbm_budget,
            chs.len(),
            prob.cfg.subchannel_bw_hz,
        );
        for k in chs {
            psd[k] = v;
        }
    }
    psd
}

/// The no-optimizer decision at a fixed cut: RSS allocation + the uniform
/// PSD computed for it. The allocation is computed **once** and shared by
/// the PSD plan and the decision — the training driver previously ran
/// `rss_allocation` twice, pairing the shipped PSD with a second (equal,
/// but separately computed) allocation.
pub fn uniform_decision(prob: &Problem, cut: usize) -> Decision {
    let alloc = rss_allocation(prob);
    let psd = uniform_power(prob, &alloc);
    Decision { alloc, psd_dbm_hz: psd, cut: cut.into() }
}

/// Random cut among the candidates (baselines a/b).
pub fn random_cut(prob: &Problem, rng: &mut Rng) -> usize {
    let cands = &prob.profile.cut_candidates;
    cands[rng.below(cands.len())]
}

/// Scheme a): RSS allocation + uniform PSD + random cut — no solver, no
/// evaluator. Shared by [`solve`] and [`solve_with`].
fn baseline_a(prob: &Problem, rng: &mut Rng) -> Decision {
    let cut = random_cut(prob, rng);
    let alloc = rss_allocation(prob);
    let psd = uniform_power(prob, &alloc);
    Decision { alloc, psd_dbm_hz: psd, cut: cut.into() }
}

/// Solve one scheme. `rng` drives the random cut draws of a)/b). Builds a
/// throwaway [`Evaluator`] for the schemes that optimize anything; callers
/// evaluating several schemes on one deployment should use [`solve_with`].
pub fn solve(prob: &Problem, scheme: Scheme, rng: &mut Rng)
    -> Result<Decision> {
    if scheme == Scheme::BaselineA {
        // Touches no solver — skip the evaluator build entirely.
        return Ok(baseline_a(prob, rng));
    }
    let mut ev = Evaluator::new(prob);
    solve_with(prob, &mut ev, scheme, rng)
}

/// Solve one scheme on the shared evaluator fast path.
pub fn solve_with(prob: &Problem, ev: &mut Evaluator, scheme: Scheme,
                  rng: &mut Rng) -> Result<Decision> {
    match scheme {
        Scheme::BaselineA => Ok(baseline_a(prob, rng)),
        Scheme::BaselineB => {
            let cut = random_cut(prob, rng);
            let seed_psd = uniform_power(prob, &rss_allocation(prob));
            let alloc = greedy::allocate_with(prob, ev, &seed_psd, cut);
            let sol = power::solve_with(prob, ev, &alloc, cut)?;
            Ok(Decision { alloc, psd_dbm_hz: sol.psd_dbm_hz, cut: cut.into() })
        }
        Scheme::BaselineC => {
            let alloc = rss_allocation(prob);
            // Iterate cut ↔ power to a joint fixed point (2 passes suffice).
            let mut psd = uniform_power(prob, &alloc);
            let mut cut = prob.profile.cut_candidates
                [prob.profile.cut_candidates.len() / 2];
            for _ in 0..3 {
                let (new_cut, _) = cutlayer::solve_with(prob, ev, &alloc, &psd)?;
                cut = new_cut;
                let sol = power::solve_with(prob, ev, &alloc, cut)?;
                psd = sol.psd_dbm_hz;
            }
            Ok(Decision { alloc, psd_dbm_hz: psd, cut: cut.into() })
        }
        Scheme::BaselineD => {
            let mut cut = prob.profile.cut_candidates
                [prob.profile.cut_candidates.len() / 2];
            let mut alloc = rss_allocation(prob);
            let mut psd = uniform_power(prob, &alloc);
            for _ in 0..3 {
                alloc = greedy::allocate_with(prob, ev, &psd, cut);
                psd = uniform_power(prob, &alloc);
                let (new_cut, _) = cutlayer::solve_with(prob, ev, &alloc, &psd)?;
                cut = new_cut;
            }
            Ok(Decision { alloc, psd_dbm_hz: psd, cut: cut.into() })
        }
        Scheme::Proposed => {
            Ok(bcd::solve_with(prob, ev, BcdOptions::default())?.decision)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkConfig;
    use crate::optim::test_support::fixture;
    use crate::profile::resnet18;

    fn prob<'a>(
        cfg: &'a NetworkConfig,
        profile: &'a crate::profile::NetworkProfile,
        dep: &'a crate::channel::Deployment,
        ch: &'a crate::channel::ChannelRealization,
    ) -> Problem<'a> {
        Problem { cfg, profile, dep, ch, batch: 64, phi: 0.5 }
    }

    #[test]
    fn all_schemes_feasible() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let p = prob(&cfg, &profile, &dep, &ch);
        let mut rng = Rng::new(77);
        for scheme in Scheme::all() {
            let d = solve(&p, scheme, &mut rng).unwrap();
            p.check_feasible(&d)
                .unwrap_or_else(|e| panic!("{}: {e}", scheme.name()));
        }
    }

    #[test]
    fn rss_allocation_serves_everyone() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let p = prob(&cfg, &profile, &dep, &ch);
        let alloc = rss_allocation(&p);
        assert!(alloc.is_complete());
        for i in 0..cfg.n_clients {
            assert!(alloc.count_of(i) >= 1);
        }
    }

    #[test]
    fn proposed_no_worse_than_every_baseline() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let p = prob(&cfg, &profile, &dep, &ch);
        let mut rng = Rng::new(123);
        let t_prop =
            p.objective(&solve(&p, Scheme::Proposed, &mut rng).unwrap());
        // Average the random-cut baselines over a few draws.
        for scheme in [
            Scheme::BaselineA,
            Scheme::BaselineB,
            Scheme::BaselineC,
            Scheme::BaselineD,
        ] {
            let mut ts = Vec::new();
            for s in 0..5 {
                let mut r = Rng::new(1000 + s);
                ts.push(p.objective(&solve(&p, scheme, &mut r).unwrap()));
            }
            let avg = crate::util::stats::mean(&ts);
            assert!(
                t_prop <= avg * 1.02,
                "{}: proposed {t_prop} vs baseline avg {avg}",
                scheme.name()
            );
        }
    }

    #[test]
    fn uniform_decision_single_allocation_bit_identical() {
        // Regression guard for the driver's build_sim_latency fix: the one
        // shared allocation must ship a decision bit-identical to the old
        // compute-it-twice construction, and the PSD must be the one
        // derived from the decision's own allocation.
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let p = prob(&cfg, &profile, &dep, &ch);
        let d = uniform_decision(&p, 4);
        // Pre-fix construction: two independent rss_allocation calls.
        let legacy_psd = uniform_power(&p, &rss_allocation(&p));
        let legacy_alloc = rss_allocation(&p);
        assert_eq!(d.alloc, legacy_alloc);
        assert_eq!(d.cut, 4);
        assert_eq!(d.psd_dbm_hz.len(), legacy_psd.len());
        for (a, b) in d.psd_dbm_hz.iter().zip(&legacy_psd) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Internal consistency: the shipped PSD is the uniform plan for
        // the shipped allocation.
        let re_psd = uniform_power(&p, &d.alloc);
        for (a, b) in d.psd_dbm_hz.iter().zip(&re_psd) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        p.check_feasible(&d).unwrap();
    }

    #[test]
    fn uniform_power_respects_c5_c6() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let p = prob(&cfg, &profile, &dep, &ch);
        let alloc = rss_allocation(&p);
        let psd = uniform_power(&p, &alloc);
        let d =
            Decision { alloc, psd_dbm_hz: psd, cut: 3.into() };
        p.check_feasible(&d).unwrap();
    }

    #[test]
    fn cut_optimized_schemes_beat_random_cut_schemes() {
        // The paper's key observation (Figs. 11–12): cut-layer optimization
        // dominates power/subchannel optimization.
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let p = prob(&cfg, &profile, &dep, &ch);
        let avg_over = |scheme: Scheme| {
            let mut ts = Vec::new();
            for s in 0..8 {
                let mut r = Rng::new(500 + s);
                ts.push(p.objective(&solve(&p, scheme, &mut r).unwrap()));
            }
            crate::util::stats::mean(&ts)
        };
        let a = avg_over(Scheme::BaselineA);
        let c = avg_over(Scheme::BaselineC);
        assert!(c < a, "cut-optimized c ({c}) !< random-cut a ({a})");
    }
}
