//! P4 — the auxiliary linear program, closed form (paper eqs. 33–34).
//!
//! With (r, p, μ) fixed, the optimal straggler bounds are simply the
//! realized maxima:
//!
//!   T₁* = max_i { T_i^F + T_i^U }      (eq. 33)
//!   T₂* = max_i { T_i^D + T_i^B }      (eq. 34)

use super::{Decision, Problem};

/// Compute (T₁*, T₂*) for a complete decision.
pub fn optimal_t1_t2(prob: &Problem, d: &Decision) -> (f64, f64) {
    let s = prob.stage_latencies(d);
    (s.uplink_phase_max(), s.downlink_phase_max())
}

/// The linearized objective T̃ = T₁ + T_s^F + T_s^B + T^B + T₂ evaluated at
/// the optimal (T₁*, T₂*) — must equal the true eq. 23 round latency (the
/// paper's equivalence argument for problem (27)).
pub fn objective_tilde(prob: &Problem, d: &Decision) -> f64 {
    let s = prob.stage_latencies(d);
    let (t1, t2) = (s.uplink_phase_max(), s.downlink_phase_max());
    t1 + s.server_fp + s.server_bp + s.broadcast + t2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkConfig;
    use crate::optim::test_support::{fixture, round_robin};
    use crate::profile::resnet18;

    #[test]
    fn tilde_equals_eq23() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let d = Decision {
            alloc: round_robin(&cfg),
            psd_dbm_hz: vec![-62.0; 20],
            cut: 5.into(),
        };
        let direct = prob.objective(&d);
        let tilde = objective_tilde(&prob, &d);
        assert!((direct - tilde).abs() < 1e-12);
    }

    #[test]
    fn t1_t2_are_maxima() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let d = Decision {
            alloc: round_robin(&cfg),
            psd_dbm_hz: vec![-62.0; 20],
            cut: 5.into(),
        };
        let (t1, t2) = optimal_t1_t2(&prob, &d);
        let s = prob.stage_latencies(&d);
        for i in 0..prob.n_clients() {
            assert!(s.client_fp[i] + s.uplink[i] <= t1 + 1e-12);
            assert!(s.downlink[i] + s.client_bp[i] <= t2 + 1e-12);
        }
    }
}
