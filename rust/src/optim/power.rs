//! P2 — power control (paper problem (30)).
//!
//! After the θ-substitution the paper's P2 is convex; instead of handing it
//! to CVX we solve its exact KKT structure from scratch:
//!
//! The real objective of the power block is to minimize the uplink-phase
//! straggler `T₁ = max_i (T_i^F + T_i^U)` subject to C5/C6/C7 (downlink
//! powers are the server's fixed PSD, so T₂ is unaffected by `p`). For a
//! fixed target `T₁` the minimal power for client i is a classic
//! *water-filling* problem over its subchannels:
//!
//!   minimize  Σ_k p_k B       s.t.  Σ_k B log2(1 + p_k g_k) ≥ R_i(T₁)
//!
//! with `p_k = (w − 1/g_k)⁺` at water level `w`, and the feasibility of
//! `T₁` is monotone — so an outer bisection on `T₁` plus inner bisections
//! on the water levels yields the global optimum of the min-max problem,
//! with KKT residuals checkable to machine precision (see tests).

use crate::channel::rate::Allocation;
use crate::config::{dbm_to_w, lin_to_db};
use crate::error::{Error, Result};

use super::eval::Evaluator;
use super::Problem;

/// Numeric floor for "zero" PSD in dBm/Hz (≈ 1e-40 W/Hz).
pub const PSD_OFF_DBM_HZ: f64 = -400.0;

/// Result of the power-control block.
#[derive(Debug, Clone)]
pub struct PowerSolution {
    /// Per-subchannel PSD (dBm/Hz); unowned/unpowered channels at
    /// [`PSD_OFF_DBM_HZ`].
    pub psd_dbm_hz: Vec<f64>,
    /// Achieved uplink-phase straggler time T₁* (seconds).
    pub t1: f64,
}

/// Water-filling: minimum total power (W) achieving `target_rate` (bits/s)
/// over channels with per-Hz SNR coefficients `g` and bandwidth `bw`.
/// Returns per-channel linear PSDs (W/Hz) and the total power.
pub fn min_power_for_rate(g: &[f64], bw: f64, target_rate: f64)
    -> (Vec<f64>, f64) {
    assert!(!g.is_empty());
    if target_rate <= 0.0 {
        return (vec![0.0; g.len()], 0.0);
    }
    let rate_at = |w: f64| -> f64 {
        g.iter()
            .map(|&gk| {
                let p = (w - 1.0 / gk).max(0.0);
                bw * (1.0 + p * gk).log2()
            })
            .sum()
    };
    // Bracket the water level.
    let mut lo = g.iter().map(|gk| 1.0 / gk).fold(f64::INFINITY, f64::min);
    let mut hi = lo.max(1e-30);
    while rate_at(hi) < target_rate {
        hi *= 2.0;
        if hi > 1e30 {
            break;
        }
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if rate_at(mid) >= target_rate {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    let w = hi;
    let psd: Vec<f64> = g.iter().map(|&gk| (w - 1.0 / gk).max(0.0)).collect();
    let total: f64 = psd.iter().map(|p| p * bw).sum();
    (psd, total)
}

/// Water-filling dual: maximum rate achievable with total power budget
/// `power_w` over the channels. Returns (per-channel PSD W/Hz, rate bits/s).
pub fn max_rate_at_power(g: &[f64], bw: f64, power_w: f64)
    -> (Vec<f64>, f64) {
    assert!(!g.is_empty());
    if power_w <= 0.0 {
        return (vec![0.0; g.len()], 0.0);
    }
    let power_at = |w: f64| -> f64 {
        g.iter().map(|&gk| (w - 1.0 / gk).max(0.0) * bw).sum()
    };
    let mut lo = 0.0;
    let mut hi = g.iter().map(|gk| 1.0 / gk).fold(0.0, f64::max)
        + power_w / (bw * g.len() as f64)
        + 1.0;
    while power_at(hi) < power_w {
        hi *= 2.0;
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if power_at(mid) >= power_w {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    let w = hi;
    let psd: Vec<f64> = g.iter().map(|&gk| (w - 1.0 / gk).max(0.0)).collect();
    let rate: f64 = psd
        .iter()
        .zip(g)
        .map(|(&p, &gk)| bw * (1.0 + p * gk).log2())
        .sum();
    (psd, rate)
}

/// Solve the power block for a fixed allocation and cut layer, deriving
/// the per-client coefficients from the [`Problem`] (reference setup).
pub fn solve(prob: &Problem, alloc: &Allocation, cut: usize)
    -> Result<PowerSolution> {
    let c = prob.n_clients();
    // Per-client channel sets and SNR coefficients.
    let channels: Vec<Vec<usize>> =
        (0..c).map(|i| alloc.channels_of(i)).collect();
    for (i, chs) in channels.iter().enumerate() {
        if chs.is_empty() {
            return Err(Error::Optim(format!(
                "client {i} owns no subchannel — allocation must precede \
                 power control"
            )));
        }
    }
    let coeffs: Vec<Vec<f64>> = (0..c)
        .map(|i| channels[i].iter().map(|&k| prob.snr_coeff(i, k)).collect())
        .collect();
    let a: Vec<f64> =
        (0..c).map(|i| prob.client_fp_seconds(i, cut)).collect();
    let bits = prob.uplink_bits(cut);
    solve_core(prob, channels, coeffs, a, bits)
}

/// Solve the power block with the coefficients served from a prebuilt
/// [`Evaluator`] — bit-identical to [`solve`] (the evaluator's tables are
/// computed with the same expressions), but without re-deriving the SNR
/// coefficients and client FP times on every BCD iteration.
pub fn solve_with(prob: &Problem, ev: &Evaluator, alloc: &Allocation,
                  cut: usize) -> Result<PowerSolution> {
    let c = prob.n_clients();
    let channels: Vec<Vec<usize>> =
        (0..c).map(|i| alloc.channels_of(i)).collect();
    for (i, chs) in channels.iter().enumerate() {
        if chs.is_empty() {
            return Err(Error::Optim(format!(
                "client {i} owns no subchannel — allocation must precede \
                 power control"
            )));
        }
    }
    let coeffs: Vec<Vec<f64>> = (0..c)
        .map(|i| channels[i].iter().map(|&k| ev.snr_coeff(i, k)).collect())
        .collect();
    let a: Vec<f64> =
        (0..c).map(|i| ev.client_fp_seconds(i, cut)).collect();
    let bits = ev.uplink_bits(cut);
    solve_core(prob, channels, coeffs, a, bits)
}

/// Shared KKT solver: outer bisection on T₁, inner water-filling per
/// client.
fn solve_core(prob: &Problem, channels: Vec<Vec<usize>>,
              coeffs: Vec<Vec<f64>>, a: Vec<f64>, bits: f64)
    -> Result<PowerSolution> {
    let c = prob.n_clients();
    let bw = prob.cfg.subchannel_bw_hz;
    let p_max_w = dbm_to_w(prob.cfg.p_max_dbm);
    let p_th_w = dbm_to_w(prob.cfg.p_th_dbm);

    // Feasibility of a target T1: per-client minimal powers must satisfy
    // C5 individually and C6 in aggregate.
    let min_powers = |t1: f64| -> Option<Vec<(Vec<f64>, f64)>> {
        let mut out = Vec::with_capacity(c);
        for i in 0..c {
            if t1 <= a[i] {
                return None;
            }
            let need = bits / (t1 - a[i]);
            let (psd, total) = min_power_for_rate(&coeffs[i], bw, need);
            if total > p_max_w * (1.0 + 1e-9) {
                return None;
            }
            out.push((psd, total));
        }
        let total: f64 = out.iter().map(|(_, t)| t).sum();
        if total > p_th_w * (1.0 + 1e-9) {
            return None;
        }
        Some(out)
    };

    // Upper bound: T1 at per-client max power (then grow until C6 holds).
    let mut hi = (0..c)
        .map(|i| {
            let (_, r) = max_rate_at_power(&coeffs[i], bw, p_max_w);
            a[i] + bits / r.max(1e-9)
        })
        .fold(0.0, f64::max)
        * (1.0 + 1e-6);
    let mut guard = 0;
    while min_powers(hi).is_none() {
        hi *= 2.0;
        guard += 1;
        if guard > 60 {
            return Err(Error::Optim(
                "power control: no feasible T1 found".into(),
            ));
        }
    }
    let mut lo = a.iter().cloned().fold(0.0, f64::max);
    for _ in 0..100 {
        let mid = 0.5 * (lo + hi);
        if min_powers(mid).is_some() {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    let t1 = hi;
    // audit:allow(R1, "t1 == hi and the bisection only ever shrinks hi to values where min_powers succeeded")
    let sols = min_powers(t1).expect("hi is feasible by construction");

    let mut psd_dbm = vec![PSD_OFF_DBM_HZ; prob.n_subchannels()];
    for i in 0..c {
        for (slot, &k) in channels[i].iter().enumerate() {
            let p_w_hz = sols[i].0[slot];
            psd_dbm[k] = if p_w_hz > 0.0 {
                lin_to_db(p_w_hz * 1e3) // W/Hz → dBm/Hz
            } else {
                PSD_OFF_DBM_HZ
            };
        }
    }
    Ok(PowerSolution { psd_dbm_hz: psd_dbm, t1 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NetworkConfig;
    use crate::optim::greedy;
    use crate::optim::test_support::fixture;
    use crate::optim::Decision;
    use crate::profile::resnet18;
    use crate::util::prop::check;

    #[test]
    fn waterfill_hits_target_rate() {
        let g = vec![1e10, 3e9, 5e8];
        let bw = 10e6;
        let target = 2e8;
        let (psd, _total) = min_power_for_rate(&g, bw, target);
        let rate: f64 = psd
            .iter()
            .zip(&g)
            .map(|(&p, &gk)| bw * (1.0 + p * gk).log2())
            .sum();
        assert!((rate - target).abs() / target < 1e-6, "rate {rate}");
    }

    #[test]
    fn waterfill_kkt_equal_water_level() {
        let g = vec![8e9, 2e9, 9e8, 1e8];
        let (psd, _) = min_power_for_rate(&g, 10e6, 3e8);
        // Active channels share the water level w = p_k + 1/g_k.
        let levels: Vec<f64> = psd
            .iter()
            .zip(&g)
            .filter(|(&p, _)| p > 0.0)
            .map(|(&p, &gk)| p + 1.0 / gk)
            .collect();
        assert!(levels.len() >= 2);
        let w0 = levels[0];
        for w in &levels {
            assert!((w - w0).abs() / w0 < 1e-6);
        }
        // Inactive channels must have 1/g above the water level.
        for (p, gk) in psd.iter().zip(&g) {
            if *p == 0.0 {
                assert!(1.0 / gk >= w0 * (1.0 - 1e-6));
            }
        }
    }

    #[test]
    fn waterfill_beats_uniform_on_asymmetric_channels() {
        let g = vec![1e10, 1e7];
        let bw = 10e6;
        let target = 3e8;
        let (_, wf_total) = min_power_for_rate(&g, bw, target);
        // Uniform split of the same total must deliver <= target rate.
        let per = wf_total / 2.0 / bw;
        let uni_rate: f64 =
            g.iter().map(|&gk| bw * (1.0 + per * gk).log2()).sum();
        assert!(uni_rate <= target * (1.0 + 1e-9));
    }

    #[test]
    fn max_rate_exhausts_budget() {
        let g = vec![5e9, 5e8];
        let (psd, rate) = max_rate_at_power(&g, 10e6, 0.5);
        let spent: f64 = psd.iter().map(|p| p * 10e6).sum();
        assert!((spent - 0.5).abs() < 1e-6);
        assert!(rate > 0.0);
    }

    #[test]
    fn duality_roundtrip() {
        // min_power(rate = max_rate(P)) == P.
        let g = vec![4e9, 7e8, 6e9];
        let (_, rate) = max_rate_at_power(&g, 10e6, 1.0);
        let (_, back) = min_power_for_rate(&g, 10e6, rate);
        assert!((back - 1.0).abs() < 1e-4, "{back}");
    }

    #[test]
    fn solve_satisfies_constraints_and_t1() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let cut = 3;
        let alloc = greedy::allocate(&prob, &vec![-65.0; 20], cut);
        let sol = solve(&prob, &alloc, cut).unwrap();
        let d = Decision {
            alloc,
            psd_dbm_hz: sol.psd_dbm_hz.clone(),
            cut: cut.into(),
        };
        prob.check_feasible(&d).unwrap();
        // T1 reported must match the realized uplink-phase straggler time.
        let s = prob.stage_latencies(&d);
        assert!(
            (s.uplink_phase_max() - sol.t1).abs() / sol.t1 < 1e-3,
            "reported {} vs realized {}",
            sol.t1,
            s.uplink_phase_max()
        );
    }

    #[test]
    fn optimized_power_beats_uniform() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let cut = 3;
        let alloc = greedy::allocate(&prob, &vec![-65.0; 20], cut);
        let sol = solve(&prob, &alloc, cut).unwrap();
        // Uniform: each client spreads p_max over its channels, scaled for
        // C6 if needed.
        let mut psd_uni = vec![PSD_OFF_DBM_HZ; 20];
        let scale =
            (dbm_to_w(cfg.p_th_dbm) / (5.0 * dbm_to_w(cfg.p_max_dbm))).min(1.0);
        for i in 0..cfg.n_clients {
            let chs = alloc.channels_of(i);
            let per_w_hz = dbm_to_w(cfg.p_max_dbm) * scale
                / (chs.len() as f64 * cfg.subchannel_bw_hz);
            for k in chs {
                psd_uni[k] = lin_to_db(per_w_hz * 1e3);
            }
        }
        let d_uni = Decision {
            alloc: alloc.clone(),
            psd_dbm_hz: psd_uni,
            cut: cut.into(),
        };
        prob.check_feasible(&d_uni).unwrap();
        let t1_uni = prob.stage_latencies(&d_uni).uplink_phase_max();
        assert!(
            sol.t1 <= t1_uni * (1.0 + 1e-6),
            "optimized {} vs uniform {}",
            sol.t1,
            t1_uni
        );
    }

    #[test]
    fn t1_monotone_in_power_budget() {
        let mut cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let cut = 3;
        let mut t1s = Vec::new();
        for pmax in [20.0, 26.0, 31.76] {
            cfg.p_max_dbm = pmax;
            let prob = Problem {
                cfg: &cfg,
                profile: &profile,
                dep: &dep,
                ch: &ch,
                batch: 64,
                phi: 0.5,
            };
            let alloc = greedy::allocate(&prob, &vec![-70.0; 20], cut);
            t1s.push(solve(&prob, &alloc, cut).unwrap().t1);
        }
        assert!(t1s[0] >= t1s[1] && t1s[1] >= t1s[2], "{t1s:?}");
    }

    #[test]
    fn solve_with_evaluator_matches_reference_setup() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let ev = crate::optim::eval::Evaluator::new(&prob);
        for cut in [2usize, 7, 13] {
            let alloc = greedy::allocate(&prob, &vec![-65.0; 20], cut);
            let a = solve(&prob, &alloc, cut).unwrap();
            let b = solve_with(&prob, &ev, &alloc, cut).unwrap();
            assert_eq!(a.t1.to_bits(), b.t1.to_bits(), "cut {cut}");
            assert_eq!(a.psd_dbm_hz, b.psd_dbm_hz, "cut {cut}");
        }
    }

    #[test]
    fn property_waterfill_never_negative_and_meets_rate() {
        check("waterfilling valid", 60, |g| {
            let n = g.usize_in(1, 8);
            let coeffs: Vec<f64> =
                (0..n).map(|_| g.f64_log(1e6, 1e12)).collect();
            let target = g.f64_log(1e6, 5e8);
            let (psd, total) = min_power_for_rate(&coeffs, 10e6, target);
            assert!(psd.iter().all(|&p| p >= 0.0));
            assert!(total >= 0.0);
            let rate: f64 = psd
                .iter()
                .zip(&coeffs)
                .map(|(&p, &gk)| 10e6 * (1.0 + p * gk).log2())
                .sum();
            assert!(rate >= target * (1.0 - 1e-5));
        });
    }
}
