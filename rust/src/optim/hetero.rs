//! Heterogeneous (per-client) cut-layer optimization.
//!
//! The paper's Algorithm 3 picks one cut layer for the whole cohort;
//! related work (Sun et al. arXiv:2411.13907, Zhang et al.
//! arXiv:2403.15815) optimizes *per-client* split points under device
//! heterogeneity. This module adds that pass on top of the uniform
//! solver rather than replacing it:
//!
//! 1. [`solve`] first runs the uniform BCD ([`bcd::solve`]) — the
//!    retained reference oracle — to obtain the uniform optimum
//!    `(r*, p*, j*)`.
//! 2. [`refine_with`] then coordinate-descends over the per-client cut
//!    vector at *fixed* `(r*, p*)` (link rates do not depend on the cut,
//!    so the precomputed rates stay valid), initialized at
//!    `[j*; C]` and accepting a move only if it *strictly* lowers the
//!    evaluator objective.
//!
//! Because the initial vector is all-equal, its evaluation dispatches
//! bitwise to the uniform objective, and every accepted move strictly
//! decreases it — so the hetero objective is **provably ≤ the uniform
//! optimum**, with exact equality when no mixed assignment helps.

use crate::error::Result;

use super::bcd::{self, BcdOptions};
use super::eval::Evaluator;
use super::{CutAssignment, Decision, Problem};

/// Options for the heterogeneous-cut pass.
#[derive(Debug, Clone)]
pub struct HeteroOptions {
    /// Options for the uniform BCD that seeds the refinement.
    pub bcd: BcdOptions,
    /// Max full client sweeps of the coordinate descent (each sweep
    /// tries every candidate cut for every client).
    pub max_sweeps: usize,
    /// Restrict the per-client search to these cut candidates (`None`
    /// searches the full profile candidate set). The training driver
    /// passes the four SplitNet-mappable layers here so a refined vector
    /// is always executable by the runtime, not just analytically better.
    pub candidates: Option<Vec<usize>>,
}

impl Default for HeteroOptions {
    fn default() -> Self {
        HeteroOptions {
            bcd: BcdOptions::default(),
            max_sweeps: 4,
            candidates: None,
        }
    }
}

/// Outcome of the heterogeneous-cut solve.
#[derive(Debug, Clone)]
pub struct HeteroResult {
    /// Refined decision; `cut` is `Uniform(j*)` when no mixed assignment
    /// beat the uniform optimum.
    pub decision: Decision,
    /// Objective of `decision` (eq. 23, mixed-cut extension).
    pub objective: f64,
    /// The uniform optimum the refinement started from.
    pub uniform_objective: f64,
    /// The uniform optimum's cut layer j*.
    pub uniform_cut: usize,
    /// Whether any per-client move was accepted (`objective <
    /// uniform_objective` exactly when true).
    pub improved: bool,
    /// Coordinate-descent sweeps performed.
    pub sweeps: usize,
}

/// Uniform BCD followed by the per-client refinement.
pub fn solve(prob: &Problem, opts: HeteroOptions) -> Result<HeteroResult> {
    let mut ev = Evaluator::new(prob);
    let uniform = bcd::solve_with(prob, &mut ev, opts.bcd)?;
    refine_with(prob, &ev, &uniform.decision, opts)
}

/// Coordinate descent over per-client cuts at fixed allocation + power.
///
/// `seed` must carry a uniform cut assignment (it is the uniform-solver
/// incumbent). The returned objective is ≤ the seed's objective *by
/// construction*: the initial all-equal vector evaluates bitwise equal
/// to the uniform objective, and only strictly-improving moves are
/// accepted.
pub fn refine_with(prob: &Problem, ev: &Evaluator, seed: &Decision,
                   opts: HeteroOptions) -> Result<HeteroResult> {
    let c = prob.n_clients();
    let uniform_cut = seed.uniform_cut()?;
    let mut up = Vec::new();
    let mut dn = Vec::new();
    ev.fill_rates(&seed.alloc, &seed.psd_dbm_hz, &mut up, &mut dn);

    let mut cuts = vec![uniform_cut; c];
    // Bitwise equal to the uniform objective (all-equal dispatch).
    let uniform_objective = ev.objective_with_rates_cuts(&cuts, &up, &dn);
    let mut best = uniform_objective;
    let cands: Vec<usize> = opts
        .candidates
        .clone()
        .unwrap_or_else(|| ev.cut_candidates().to_vec());

    let mut sweeps = 0;
    let mut improved = false;
    for _ in 0..opts.max_sweeps {
        sweeps += 1;
        let mut changed = false;
        for i in 0..c {
            let keep = cuts[i];
            let mut best_j = keep;
            for &j in &cands {
                if j == keep {
                    continue;
                }
                cuts[i] = j;
                let t = ev.objective_with_rates_cuts(&cuts, &up, &dn);
                if t < best {
                    best = t;
                    best_j = j;
                }
            }
            cuts[i] = best_j;
            if best_j != keep {
                changed = true;
                improved = true;
            }
        }
        if !changed {
            break;
        }
    }

    // Normalize all-equal vectors back to Uniform so the decision is
    // indistinguishable from the uniform solver's when nothing improved.
    let assignment = CutAssignment::normalized(cuts);
    let decision = Decision {
        alloc: seed.alloc.clone(),
        psd_dbm_hz: seed.psd_dbm_hz.clone(),
        cut: assignment,
    };
    prob.check_feasible(&decision)?;
    Ok(HeteroResult {
        decision,
        objective: best,
        uniform_objective,
        uniform_cut,
        improved,
        sweeps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{ChannelRealization, Deployment};
    use crate::config::NetworkConfig;
    use crate::optim::test_support::fixture;
    use crate::profile::resnet18;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    #[test]
    fn refinement_never_worse_than_uniform() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let res = solve(&prob, HeteroOptions::default()).unwrap();
        assert!(
            res.objective <= res.uniform_objective,
            "hetero {} > uniform {}",
            res.objective,
            res.uniform_objective
        );
        // `improved` is exact: false means bitwise-equal objectives and a
        // uniform decision.
        if !res.improved {
            assert_eq!(
                res.objective.to_bits(),
                res.uniform_objective.to_bits()
            );
            assert_eq!(res.decision.cut, res.uniform_cut);
        } else {
            assert!(res.objective < res.uniform_objective);
        }
        prob.check_feasible(&res.decision).unwrap();
    }

    #[test]
    fn refined_objective_matches_reference_evaluation() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let res = solve(&prob, HeteroOptions::default()).unwrap();
        let reference = prob.objective(&res.decision);
        assert_eq!(
            res.objective.to_bits(),
            reference.to_bits(),
            "refined {} vs reference {}",
            res.objective,
            reference
        );
    }

    #[test]
    fn strict_gain_under_strong_compute_heterogeneity() {
        // One order-of-magnitude compute spread: the slow clients want a
        // shallow cut, the fast ones a deep one — a mixed assignment must
        // strictly beat any single cut at fixed allocation/power.
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let mut rng = Rng::new(17);
        let mut dep = Deployment::generate(&cfg, &mut rng);
        for (i, cl) in dep.clients.iter_mut().enumerate() {
            cl.f_client = if i % 2 == 0 { 2e8 } else { 4e9 };
        }
        dep.refresh_f_clients();
        let ch = ChannelRealization::average(&dep);
        let prob = Problem {
            cfg: &cfg,
            profile: &profile,
            dep: &dep,
            ch: &ch,
            batch: 64,
            phi: 0.5,
        };
        let res = solve(&prob, HeteroOptions::default()).unwrap();
        assert!(
            res.improved,
            "expected a strict hetero gain; uniform {} hetero {}",
            res.uniform_objective,
            res.objective
        );
        assert!(res.objective < res.uniform_objective);
        assert!(res.decision.cut.as_uniform().is_none());
    }

    #[test]
    fn property_hetero_dominates_uniform() {
        check("hetero objective <= uniform optimum", 15, |g| {
            let mut cfg = NetworkConfig::default();
            cfg.n_clients = g.usize_in(2, 6);
            cfg.n_subchannels = cfg.n_clients + g.usize_in(1, 12);
            cfg.f_server = g.f64_in(1e9, 9e9);
            let profile = resnet18::profile();
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let dep = Deployment::generate(&cfg, &mut rng);
            let ch = ChannelRealization::average(&dep);
            let prob = Problem {
                cfg: &cfg,
                profile: &profile,
                dep: &dep,
                ch: &ch,
                batch: g.usize_in(8, 96),
                phi: *g.choose(&[0.0, 0.5, 1.0]),
            };
            let opts = HeteroOptions {
                bcd: BcdOptions { max_iters: 8, tol: 1e-6 },
                max_sweeps: 3,
                candidates: None,
            };
            let res = solve(&prob, opts).unwrap();
            assert!(
                res.objective <= res.uniform_objective,
                "hetero {} > uniform {} (C={})",
                res.objective,
                res.uniform_objective,
                cfg.n_clients
            );
            prob.check_feasible(&res.decision).unwrap();
        });
    }
}
