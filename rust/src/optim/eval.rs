//! Decision-evaluation fast path for the §V resource optimizer.
//!
//! [`Problem::objective`] is the *reference* implementation of the eq. 23
//! round latency: it recomputes every uplink/downlink rate, rebuilds the
//! per-stage latency vectors, and allocates fresh buffers on every call —
//! fine for one evaluation, ruinous inside BCD's inner loops where the same
//! deployment is evaluated thousands of times with only one block changed.
//!
//! [`Evaluator`] precomputes, per [`Problem`]:
//!
//! - the per-(client, subchannel) channel-gain terms of eq. 14, so one
//!   subchannel's uplink rate at a given PSD is two transcendentals;
//! - the decision-independent downlink rates (eq. 20; the server PSD is
//!   fixed) and the constant broadcast rate (eq. 18);
//! - the per-cut FLOP/payload tables (ρ_j, ϖ_j, ψ_j, χ_j aggregates) and
//!   per-(client, cut) FP/BP seconds, so the cut-dependent stage terms of
//!   eqs. 13, 15–17, 19, 21–22 are table lookups;
//! - the per-(client, subchannel) linear SNR coefficients the P2 power
//!   solver consumes.
//!
//! Every arithmetic expression mirrors the reference implementation
//! operation-for-operation, so a full evaluation through the fast path is
//! **bit-identical** to `Problem::objective` — the optimizer's trajectory,
//! the accepted decisions, and the generated figures do not change, they
//! only arrive faster. Scratch buffers live inside the evaluator; steady
//! state evaluation performs no heap allocation.

use crate::channel::rate::{self, Allocation};
use crate::config::dbm_to_w;

use super::{Decision, Problem};

/// Precomputed evaluation tables for one [`Problem`] instance. Owns its
/// data — no borrows of the originating problem — so it can be built once
/// and moved freely (e.g. into a sweep worker).
#[derive(Debug, Clone)]
pub struct Evaluator {
    n_clients: usize,
    n_subchannels: usize,
    /// Per-subchannel bandwidth B (Hz) — uniform, from the config.
    bw: f64,
    noise_dbm_hz: f64,
    /// `10·log10(G·γ_ik)` — the dB gain term of eq. 14, `[i·M + k]`.
    gdb: Vec<f64>,
    /// Decision-independent downlink rate of subchannel k for client i
    /// (eq. 20 at the fixed server PSD), `[i·M + k]`.
    dlr: Vec<f64>,
    /// Linear SNR coefficient `G·γ_ik / σ²` for P2, `[i·M + k]`.
    coeff: Vec<f64>,
    /// Constant broadcast rate R^B (eq. 18).
    bc_rate: f64,
    /// Cut-layer candidates of the profile (copied).
    cut_candidates: Vec<usize>,
    // ---- per-cut tables, 1-based cut index j (slot 0 unused) ----
    /// Uplink payload bits b·ψ_j·γ (γ = uplink compression factor).
    ub: Vec<f64>,
    /// Unicast downlink payload bits (b − ⌈φb⌉)·χ_j.
    db: Vec<f64>,
    /// T_s^F(j) — eq. 16.
    sfp: Vec<f64>,
    /// T_s^B(j) — eq. 17.
    sbp: Vec<f64>,
    /// T^B(j) — eq. 19 (constant broadcast rate folded in).
    tbc: Vec<f64>,
    /// T_i^F(j) — eq. 13, `[j·C + i]`.
    cfp: Vec<f64>,
    /// T_i^B(j) — eq. 22, `[j·C + i]`.
    cbp: Vec<f64>,
    // ---- per-cut *unit* tables for mixed-cut groups (the canonical
    // association of `epsl_stage_latencies_hetero`) ----
    /// One client's server-FP seconds at cut j: b·κ_s·Φ_s^F(j)/f_s.
    sfp1: Vec<f64>,
    /// Per-effective-sample server-BP seconds at cut j: κ_s·Φ_s^B(j)/f_s.
    sbp_unit: Vec<f64>,
    /// One client's last-layer BP seconds: b·κ_s·Φ_s^L/f_s.
    sll_unit: f64,
    /// b and ⌈φb⌉ as f64 (for per-group effective-sample counts).
    batch_f: f64,
    magg_f: f64,
    // ---- reusable scratch (steady-state evaluation is allocation-free) --
    up: Vec<f64>,
    dn: Vec<f64>,
}

impl Evaluator {
    /// Precompute all tables for `prob`. O(C·M) transcendentals plus
    /// O(L·C) table fills — amortized over every objective evaluation that
    /// follows.
    pub fn new(prob: &Problem) -> Evaluator {
        let c = prob.n_clients();
        let m = prob.n_subchannels();
        let cfg = prob.cfg;
        let p = prob.profile;
        let nl = p.n_layers();
        let b = prob.batch as f64;
        let cc = c as f64;
        // ⌈φb⌉ exactly as the latency model computes it.
        let magg = (prob.phi * b).ceil() as usize as f64;

        let noise_w_hz = dbm_to_w(cfg.noise_dbm_hz);
        let mut gdb = vec![0.0; c * m];
        let mut dlr = vec![0.0; c * m];
        let mut coeff = vec![0.0; c * m];
        for i in 0..c {
            for k in 0..m {
                let g = prob.ch.gain[i][k];
                gdb[i * m + k] = 10.0 * (cfg.antenna_gain * g).log10();
                let snr = rate::snr_linear(
                    cfg.p_dl_dbm_hz,
                    cfg.antenna_gain,
                    g,
                    cfg.noise_dbm_hz,
                );
                dlr[i * m + k] =
                    rate::subchannel_rate(cfg.subchannel_bw_hz, snr);
                coeff[i * m + k] = cfg.antenna_gain * g / noise_w_hz;
            }
        }
        let bc_rate = rate::broadcast_rate(cfg, prob.ch);

        let f = prob.dep.f_clients();
        let mut ub = vec![0.0; nl];
        let mut db = vec![0.0; nl];
        let mut sfp = vec![0.0; nl];
        let mut sbp = vec![0.0; nl];
        let mut tbc = vec![0.0; nl];
        let mut cfp = vec![0.0; nl * c];
        let mut cbp = vec![0.0; nl * c];
        let mut sfp1 = vec![0.0; nl];
        let mut sbp_unit = vec![0.0; nl];
        for j in 1..nl {
            let psi = p.psi_bits(j);
            let chi = p.chi_bits(j);
            // b·ψ_j·γ — same association as `Problem::uplink_bits` and
            // the eq. 15 term in `epsl_stage_latencies` (γ = 1 leaves it
            // bit-identical to the uncompressed payload).
            ub[j] = b * psi * cfg.uplink_compression;
            db[j] = (b - magg) * chi;
            sfp[j] = cc * b * cfg.kappa_server * p.server_fp_flops(j)
                / cfg.f_server;
            let eff_samples = magg + cc * (b - magg);
            sbp[j] = (eff_samples * cfg.kappa_server * p.server_bp_flops(j)
                + cc * b * cfg.kappa_server * p.last_layer_bp_flops())
                / cfg.f_server;
            tbc[j] = magg * chi / bc_rate.max(1e-9);
            sfp1[j] = b * cfg.kappa_server * p.server_fp_flops(j)
                / cfg.f_server;
            sbp_unit[j] =
                cfg.kappa_server * p.server_bp_flops(j) / cfg.f_server;
            let phi_cf = p.client_fp_flops(j);
            let phi_cb = p.client_bp_flops(j);
            for i in 0..c {
                cfp[j * c + i] = b * cfg.kappa_client * phi_cf / f[i];
                cbp[j * c + i] = b * cfg.kappa_client * phi_cb / f[i];
            }
        }
        let sll_unit = b * cfg.kappa_server * p.last_layer_bp_flops()
            / cfg.f_server;

        Evaluator {
            n_clients: c,
            n_subchannels: m,
            bw: cfg.subchannel_bw_hz,
            noise_dbm_hz: cfg.noise_dbm_hz,
            gdb,
            dlr,
            coeff,
            bc_rate,
            cut_candidates: p.cut_candidates.clone(),
            ub,
            db,
            sfp,
            sbp,
            tbc,
            cfp,
            cbp,
            sfp1,
            sbp_unit,
            sll_unit,
            batch_f: b,
            magg_f: magg,
            up: vec![0.0; c],
            dn: vec![0.0; c],
        }
    }

    pub fn n_clients(&self) -> usize {
        self.n_clients
    }

    pub fn n_subchannels(&self) -> usize {
        self.n_subchannels
    }

    /// Constant broadcast rate R^B (eq. 18).
    pub fn broadcast_rate(&self) -> f64 {
        self.bc_rate
    }

    pub fn cut_candidates(&self) -> &[usize] {
        &self.cut_candidates
    }

    /// Uplink rate of subchannel k for client i at PSD `psd_dbm_hz`
    /// (one eq. 14 summand) — bit-identical to
    /// `subchannel_rate(B, snr_linear(p, G, γ, σ²))`.
    #[inline]
    pub fn chan_uplink_rate(&self, i: usize, k: usize, psd_dbm_hz: f64)
        -> f64 {
        let num_db = psd_dbm_hz + self.gdb[i * self.n_subchannels + k];
        let snr = 10f64.powf((num_db - self.noise_dbm_hz) / 10.0);
        self.bw * (1.0 + snr).log2()
    }

    /// Downlink rate of subchannel k for client i (decision-independent).
    #[inline]
    pub fn chan_downlink_rate(&self, i: usize, k: usize) -> f64 {
        self.dlr[i * self.n_subchannels + k]
    }

    /// Linear SNR coefficient `G·γ_ik / σ²` — the P2 water-filling input;
    /// bit-identical to [`Problem::snr_coeff`].
    #[inline]
    pub fn snr_coeff(&self, i: usize, k: usize) -> f64 {
        self.coeff[i * self.n_subchannels + k]
    }

    /// Client i's total uplink rate under `alloc`/`psd` — accumulated in
    /// ascending-k order, matching `rate::uplink_rates` bit-for-bit.
    pub fn uplink_rate_of(&self, i: usize, alloc: &Allocation, psd: &[f64])
        -> f64 {
        let mut r = 0.0;
        for k in 0..self.n_subchannels {
            if alloc.owner[k] == Some(i) {
                r += self.chan_uplink_rate(i, k, psd[k]);
            }
        }
        r
    }

    /// Client i's total downlink rate under `alloc`.
    pub fn downlink_rate_of(&self, i: usize, alloc: &Allocation) -> f64 {
        let mut r = 0.0;
        for k in 0..self.n_subchannels {
            if alloc.owner[k] == Some(i) {
                r += self.chan_downlink_rate(i, k);
            }
        }
        r
    }

    /// Fill per-client uplink/downlink rates into caller buffers (resized
    /// here) — one pass over the subchannels, no allocation in steady
    /// state.
    pub fn fill_rates(&self, alloc: &Allocation, psd: &[f64],
                      up: &mut Vec<f64>, dn: &mut Vec<f64>) {
        up.clear();
        up.resize(self.n_clients, 0.0);
        dn.clear();
        dn.resize(self.n_clients, 0.0);
        for k in 0..self.n_subchannels {
            if let Some(i) = alloc.owner[k] {
                up[i] += self.chan_uplink_rate(i, k, psd[k]);
                dn[i] += self.chan_downlink_rate(i, k);
            }
        }
    }

    /// T_i^F(j) (seconds) — table lookup.
    #[inline]
    pub fn client_fp_seconds(&self, i: usize, cut: usize) -> f64 {
        self.cfp[cut * self.n_clients + i]
    }

    /// T_i^B(j) (seconds) — table lookup.
    #[inline]
    pub fn client_bp_seconds(&self, i: usize, cut: usize) -> f64 {
        self.cbp[cut * self.n_clients + i]
    }

    /// Uplink payload bits b·ψ_j·γ (γ = uplink compression factor).
    #[inline]
    pub fn uplink_bits(&self, cut: usize) -> f64 {
        self.ub[cut]
    }

    /// Unicast downlink payload bits (b − ⌈φb⌉)·χ_j.
    #[inline]
    pub fn downlink_bits(&self, cut: usize) -> f64 {
        self.db[cut]
    }

    /// Client i's uplink-phase time T_i^F + T_i^U at uplink rate `up_i`.
    #[inline]
    pub fn uplink_phase_time(&self, i: usize, cut: usize, up_i: f64) -> f64 {
        self.client_fp_seconds(i, cut) + self.ub[cut] / up_i.max(1e-9)
    }

    /// Client i's downlink-phase time T_i^D + T_i^B at downlink rate
    /// `dn_i`.
    #[inline]
    pub fn downlink_phase_time(&self, i: usize, cut: usize, dn_i: f64)
        -> f64 {
        self.db[cut] / dn_i.max(1e-9) + self.client_bp_seconds(i, cut)
    }

    /// μ-weighted server-side cost `T_s^F(j) + T_s^B(j) + T^B(j)` — the P3
    /// objective coefficient for candidate `cut`.
    #[inline]
    pub fn server_cost(&self, cut: usize) -> f64 {
        self.sfp[cut] + self.sbp[cut] + self.tbc[cut]
    }

    /// Eq. 23 round total given per-client rates — O(C), no allocation.
    pub fn objective_with_rates(&self, cut: usize, up: &[f64], dn: &[f64])
        -> f64 {
        let c = self.n_clients;
        let mut upmax = 0.0f64;
        for i in 0..c {
            upmax = upmax.max(self.uplink_phase_time(i, cut, up[i]));
        }
        let mut dnmax = 0.0f64;
        for i in 0..c {
            dnmax = dnmax.max(self.downlink_phase_time(i, cut, dn[i]));
        }
        upmax + self.sfp[cut] + self.sbp[cut] + self.tbc[cut] + dnmax
    }

    /// Mixed-cut round total given per-client rates and per-client cuts —
    /// operation-for-operation the association of
    /// [`crate::latency::epsl_stage_latencies_hetero`], so it is
    /// bit-identical to the reference [`Problem::objective`] on the same
    /// assignment. All-equal `cuts` dispatch to the uniform fast path
    /// (which delegates bitwise to the uniform closed form). Allocates
    /// one small distinct-cut scratch vector (hetero-only path).
    pub fn objective_with_rates_cuts(&self, cuts: &[usize], up: &[f64],
                                     dn: &[f64]) -> f64 {
        if let Some((first, rest)) = cuts.split_first() {
            if rest.iter().all(|c| c == first) {
                return self.objective_with_rates(*first, up, dn);
            }
        }
        let c = self.n_clients;
        let mut upmax = 0.0f64;
        for i in 0..c {
            upmax = upmax.max(self.uplink_phase_time(i, cuts[i], up[i]));
        }
        let mut dnmax = 0.0f64;
        for i in 0..c {
            dnmax = dnmax.max(self.downlink_phase_time(i, cuts[i], dn[i]));
        }
        let mut distinct: Vec<usize> = cuts.to_vec();
        distinct.sort_unstable();
        distinct.dedup();
        let mut server_fp = 0.0;
        let mut server_bp = 0.0;
        let mut broadcast = 0.0;
        for &j in &distinct {
            let c_g = cuts.iter().filter(|&&x| x == j).count() as f64;
            let eff_g = self.magg_f + c_g * (self.batch_f - self.magg_f);
            server_fp += c_g * self.sfp1[j];
            server_bp += eff_g * self.sbp_unit[j] + c_g * self.sll_unit;
            broadcast += self.tbc[j];
        }
        upmax + server_fp + server_bp + broadcast + dnmax
    }

    /// Full objective of a decision — bit-identical to
    /// [`Problem::objective`], allocation-free in steady state for
    /// uniform (and all-equal) cut assignments.
    pub fn objective(&mut self, d: &Decision) -> f64 {
        let mut up = std::mem::take(&mut self.up);
        let mut dn = std::mem::take(&mut self.dn);
        self.fill_rates(&d.alloc, &d.psd_dbm_hz, &mut up, &mut dn);
        let t = match d.cut.as_uniform() {
            Some(j) => self.objective_with_rates(j, &up, &dn),
            None => self.objective_with_rates_cuts(
                &d.cut.cuts_for(self.n_clients),
                &up,
                &dn,
            ),
        };
        self.up = up;
        self.dn = dn;
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{ChannelRealization, Deployment};
    use crate::config::NetworkConfig;
    use crate::optim::test_support::{fixture, round_robin};
    use crate::profile::resnet18;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn default_prob<'a>(
        cfg: &'a NetworkConfig,
        profile: &'a crate::profile::NetworkProfile,
        dep: &'a Deployment,
        ch: &'a ChannelRealization,
    ) -> Problem<'a> {
        Problem { cfg, profile, dep, ch, batch: 64, phi: 0.5 }
    }

    #[test]
    fn matches_reference_on_fixture() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = default_prob(&cfg, &profile, &dep, &ch);
        let mut ev = Evaluator::new(&prob);
        for cut in [1usize, 4, 9, 14, 17] {
            let d = Decision {
                alloc: round_robin(&cfg),
                psd_dbm_hz: vec![-62.0; cfg.n_subchannels],
                cut: cut.into(),
            };
            let reference = prob.objective(&d);
            let fast = ev.objective(&d);
            assert!(
                (fast - reference).abs() <= 1e-13 * reference,
                "cut {cut}: fast {fast} vs reference {reference}"
            );
        }
    }

    #[test]
    fn rates_match_rate_module() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = default_prob(&cfg, &profile, &dep, &ch);
        let ev = Evaluator::new(&prob);
        let alloc = round_robin(&cfg);
        let psd: Vec<f64> =
            (0..cfg.n_subchannels).map(|k| -70.0 + k as f64 * 0.5).collect();
        let up_ref = rate::uplink_rates(&cfg, &ch, &alloc, &psd);
        let dn_ref = rate::downlink_rates(&cfg, &ch, &alloc);
        let mut up = Vec::new();
        let mut dn = Vec::new();
        ev.fill_rates(&alloc, &psd, &mut up, &mut dn);
        assert_eq!(up, up_ref, "uplink rates must be bit-identical");
        assert_eq!(dn, dn_ref, "downlink rates must be bit-identical");
        assert_eq!(ev.broadcast_rate(), rate::broadcast_rate(&cfg, &ch));
        for i in 0..cfg.n_clients {
            let r = ev.uplink_rate_of(i, &alloc, &psd);
            assert_eq!(r, up_ref[i]);
            assert_eq!(ev.downlink_rate_of(i, &alloc), dn_ref[i]);
        }
    }

    #[test]
    fn tables_match_problem_accessors() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = default_prob(&cfg, &profile, &dep, &ch);
        let ev = Evaluator::new(&prob);
        for &cut in &profile.cut_candidates {
            assert_eq!(ev.uplink_bits(cut), prob.uplink_bits(cut));
            assert_eq!(ev.downlink_bits(cut), prob.downlink_bits(cut));
            for i in 0..cfg.n_clients {
                assert_eq!(
                    ev.client_fp_seconds(i, cut),
                    prob.client_fp_seconds(i, cut)
                );
                assert_eq!(
                    ev.client_bp_seconds(i, cut),
                    prob.client_bp_seconds(i, cut)
                );
                for k in 0..cfg.n_subchannels {
                    assert_eq!(ev.snr_coeff(i, k), prob.snr_coeff(i, k));
                }
            }
        }
    }

    #[test]
    fn property_evaluator_matches_reference_objective() {
        // The satellite acceptance check: ≤ 1e-9 relative error across
        // random deployments, allocations, PSDs, cuts and φ ∈ {0, ½, 1}.
        check("evaluator == reference objective", 40, |g| {
            let mut cfg = NetworkConfig::default();
            cfg.n_clients = g.usize_in(1, 6);
            cfg.n_subchannels = cfg.n_clients + g.usize_in(0, 10);
            cfg.f_server = g.f64_in(1e9, 9e9);
            let profile = resnet18::profile();
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let dep = Deployment::generate(&cfg, &mut rng);
            let ch = ChannelRealization::average(&dep);
            let phi = *g.choose(&[0.0, 0.5, 1.0]);
            let batch = g.usize_in(1, 128);
            let prob = Problem {
                cfg: &cfg,
                profile: &profile,
                dep: &dep,
                ch: &ch,
                batch,
                phi,
            };
            let mut ev = Evaluator::new(&prob);
            // Random (possibly starving) complete-ownership allocation.
            let mut alloc = Allocation::empty(cfg.n_subchannels);
            for k in 0..cfg.n_subchannels {
                alloc.assign(k, g.usize_in(0, cfg.n_clients - 1));
            }
            let psd: Vec<f64> = (0..cfg.n_subchannels)
                .map(|_| g.f64_in(-78.0, -55.0))
                .collect();
            let cut = *g.choose(&profile.cut_candidates);
            let d = Decision { alloc, psd_dbm_hz: psd, cut: cut.into() };
            let reference = prob.objective(&d);
            let fast = ev.objective(&d);
            assert!(
                (fast - reference).abs()
                    <= 1e-9 * reference.abs().max(1e-12),
                "fast {fast} vs reference {reference} \
                 (C={} M={} cut={cut} phi={phi})",
                cfg.n_clients,
                cfg.n_subchannels
            );
        });
    }

    #[test]
    fn uplink_compression_tracks_reference_and_lowers_objective() {
        let mut cfg = NetworkConfig::default();
        cfg.uplink_compression = 0.5;
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = default_prob(&cfg, &profile, &dep, &ch);
        let mut ev = Evaluator::new(&prob);
        let d = Decision {
            alloc: round_robin(&cfg),
            psd_dbm_hz: vec![-62.0; cfg.n_subchannels],
            cut: 4.into(),
        };
        // The fast path stays bit-identical to the reference under a
        // compressed payload table...
        assert_eq!(ev.uplink_bits(4), prob.uplink_bits(4));
        let reference = prob.objective(&d);
        let fast = ev.objective(&d);
        assert!(
            (fast - reference).abs() <= 1e-13 * reference,
            "fast {fast} vs reference {reference}"
        );
        // ...and halving the uplink payload strictly lowers eq. 23 on a
        // deployment whose uplink stage is non-degenerate.
        let mut raw_cfg = cfg.clone();
        raw_cfg.uplink_compression = 1.0;
        let raw_prob = default_prob(&raw_cfg, &profile, &dep, &ch);
        assert!(prob.objective(&d) < raw_prob.objective(&d));
    }

    #[test]
    fn objective_with_rates_sweeps_cuts_consistently() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = default_prob(&cfg, &profile, &dep, &ch);
        let mut ev = Evaluator::new(&prob);
        let alloc = round_robin(&cfg);
        let psd = vec![-62.0; cfg.n_subchannels];
        let mut up = Vec::new();
        let mut dn = Vec::new();
        ev.fill_rates(&alloc, &psd, &mut up, &mut dn);
        for &cut in &profile.cut_candidates {
            let d = Decision {
                alloc: alloc.clone(),
                psd_dbm_hz: psd.clone(),
                cut: cut.into(),
            };
            let full = ev.objective(&d);
            let via_rates = ev.objective_with_rates(cut, &up, &dn);
            assert_eq!(full.to_bits(), via_rates.to_bits());
        }
    }

    #[test]
    fn server_cost_matches_stage_terms() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = default_prob(&cfg, &profile, &dep, &ch);
        let ev = Evaluator::new(&prob);
        for &cut in &profile.cut_candidates {
            let d = Decision {
                alloc: round_robin(&cfg),
                psd_dbm_hz: vec![-62.0; cfg.n_subchannels],
                cut: cut.into(),
            };
            let s = prob.stage_latencies(&d);
            let expect = s.server_fp + s.server_bp + s.broadcast;
            let got = ev.server_cost(cut);
            assert!(
                (got - expect).abs() <= 1e-12 * expect.max(1e-12),
                "cut {cut}: {got} vs {expect}"
            );
        }
    }

    #[test]
    fn hetero_objective_bitwise_matches_reference() {
        let cfg = NetworkConfig::default();
        let profile = resnet18::profile();
        let (dep, ch) = fixture(&cfg);
        let prob = default_prob(&cfg, &profile, &dep, &ch);
        let mut ev = Evaluator::new(&prob);
        let c = cfg.n_clients;
        let alloc = round_robin(&cfg);
        let psd = vec![-62.0; cfg.n_subchannels];
        // Mixed assignment spanning the candidate set.
        let cands = &profile.cut_candidates;
        let cuts: Vec<usize> =
            (0..c).map(|i| cands[i % cands.len()]).collect();
        let d = Decision {
            alloc: alloc.clone(),
            psd_dbm_hz: psd.clone(),
            cut: cuts.clone().into(),
        };
        let reference = prob.objective(&d);
        let fast = ev.objective(&d);
        assert_eq!(
            fast.to_bits(),
            reference.to_bits(),
            "hetero fast {fast} vs reference {reference}"
        );
        // All-equal per-client vector is bitwise the scalar objective.
        for &j in cands {
            let d_vec = Decision {
                alloc: alloc.clone(),
                psd_dbm_hz: psd.clone(),
                cut: vec![j; c].into(),
            };
            let d_uni = Decision {
                alloc: alloc.clone(),
                psd_dbm_hz: psd.clone(),
                cut: j.into(),
            };
            assert_eq!(
                ev.objective(&d_vec).to_bits(),
                ev.objective(&d_uni).to_bits(),
                "cut {j}"
            );
            assert_eq!(
                ev.objective(&d_vec).to_bits(),
                prob.objective(&d_vec).to_bits(),
                "cut {j} vs reference"
            );
        }
    }

    #[test]
    fn property_hetero_evaluator_matches_reference() {
        check("hetero evaluator == reference objective", 30, |g| {
            let mut cfg = NetworkConfig::default();
            cfg.n_clients = g.usize_in(1, 6);
            cfg.n_subchannels = cfg.n_clients + g.usize_in(0, 10);
            cfg.f_server = g.f64_in(1e9, 9e9);
            let profile = resnet18::profile();
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let dep = Deployment::generate(&cfg, &mut rng);
            let ch = ChannelRealization::average(&dep);
            let phi = *g.choose(&[0.0, 0.5, 1.0]);
            let batch = g.usize_in(1, 128);
            let prob = Problem {
                cfg: &cfg,
                profile: &profile,
                dep: &dep,
                ch: &ch,
                batch,
                phi,
            };
            let mut ev = Evaluator::new(&prob);
            let mut alloc = Allocation::empty(cfg.n_subchannels);
            for k in 0..cfg.n_subchannels {
                alloc.assign(k, g.usize_in(0, cfg.n_clients - 1));
            }
            let psd: Vec<f64> = (0..cfg.n_subchannels)
                .map(|_| g.f64_in(-78.0, -55.0))
                .collect();
            let cuts: Vec<usize> = (0..cfg.n_clients)
                .map(|_| *g.choose(&profile.cut_candidates))
                .collect();
            let d = Decision {
                alloc,
                psd_dbm_hz: psd,
                cut: cuts.clone().into(),
            };
            let reference = prob.objective(&d);
            let fast = ev.objective(&d);
            assert_eq!(
                fast.to_bits(),
                reference.to_bits(),
                "fast {fast} vs reference {reference} (C={} cuts={cuts:?} \
                 phi={phi})",
                cfg.n_clients
            );
        });
    }
}
