//! Session checkpoints: everything a killed run needs to resume
//! bit-exactly.
//!
//! The driver's setup phase is a pure function of `(Config,
//! TrainerOptions)` — data synthesis, sharding, the simulated deployment,
//! and the fault plan are all re-derived from the seed on resume. What a
//! checkpoint must carry is only the *mutable* session state: model
//! parameters (host `f32` mirrors, bit-preserved), the session RNG
//! stream position (including a pending cached Gaussian deviate), the
//! next round index, and the metric records already emitted. A
//! fingerprint over the run-defining configuration guards against
//! resuming into a different experiment.
//!
//! The format is a versioned little-endian binary layout written by this
//! module alone (no serde offline); floats travel as raw IEEE-754 bits so
//! the resumed run is bitwise identical, never "close".

use std::fs;
use std::path::Path;

use crate::config::Config;
use crate::error::{Error, Result};
use crate::metrics::{FaultStats, RoundRecord};
use crate::timeline::StageSpans;
use crate::util::rng::RngState;

use super::driver::TrainerOptions;

const MAGIC: &[u8; 8] = b"EPSLCKP1";
/// Version 2 added the per-round cut label to each record (mixed-cut
/// training). Version-1 checkpoints predate the field and are rejected
/// with a typed error rather than silently misparsed.
const VERSION: u32 = 2;

/// A resumable snapshot of one training session.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// [`run_fingerprint`] of the configuration that produced it.
    pub fingerprint: u64,
    /// First round the resumed run executes.
    pub next_round: usize,
    /// Session RNG state at the snapshot point.
    pub rng: RngState,
    /// Per-replica client-side parameters (host mirrors, canonical
    /// tensor order).
    pub client_params: Vec<Vec<Vec<f32>>>,
    /// Server-side parameters.
    pub server_params: Vec<Vec<f32>>,
    /// Metric records of the rounds already run.
    pub records: Vec<RoundRecord>,
}

/// FNV-1a hash of the run-defining configuration. Checkpoint knobs are
/// excluded: checkpointing more or less often, or to a different path,
/// must not invalidate a snapshot of the same experiment.
pub fn run_fingerprint(cfg: &Config, opts: &TrainerOptions) -> u64 {
    let mut canon = opts.clone();
    canon.checkpoint_every = 0;
    canon.checkpoint_path = None;
    // Debug derives render every field deterministically; config and
    // options are plain data, so this is a stable canonical encoding.
    let repr = format!("{:?}|{:?}|{:?}", cfg.net, cfg.train, canon);
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in repr.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// --- binary writer helpers -------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_usize(out: &mut Vec<u8>, v: usize) {
    put_u64(out, v as u64);
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_f32_slice(out: &mut Vec<u8>, xs: &[f32]) {
    put_usize(out, xs.len());
    for x in xs {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_usize(out, s.len());
    out.extend_from_slice(s.as_bytes());
}

// --- binary reader ----------------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|e| *e <= self.buf.len());
        let end = end.ok_or_else(|| {
            Error::Fault(format!(
                "checkpoint truncated at byte {} (wanted {n} more)",
                self.pos
            ))
        })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Fixed-width slice as an array. `take(N)` returns exactly `N`
    /// bytes, so the conversion cannot fail in practice; a typed error
    /// (not a panic) keeps corrupted-input handling uniform anyway.
    fn array<const N: usize>(&mut self) -> Result<[u8; N]> {
        self.take(N)?.try_into().map_err(|_| {
            Error::Fault(format!("checkpoint field: expected {N} bytes"))
        })
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.array()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.array()?))
    }

    fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| {
            Error::Fault(format!("checkpoint length {v} overflows usize"))
        })
    }

    /// Bounded count: each element occupies at least `min_elem_bytes`
    /// more of the buffer, so a corrupted length cannot trigger a huge
    /// allocation before the truncation check would catch it.
    fn count(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.usize()?;
        let remaining = self.buf.len() - self.pos;
        if n.saturating_mul(min_elem_bytes.max(1)) > remaining {
            return Err(Error::Fault(format!(
                "checkpoint count {n} exceeds the remaining {remaining} \
                 byte(s)"
            )));
        }
        Ok(n)
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn string(&mut self) -> Result<String> {
        let n = self.count(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| {
            Error::Fault("checkpoint string is not UTF-8".into())
        })
    }

    fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(f32::from_bits(u32::from_le_bytes(self.array()?)));
        }
        Ok(v)
    }
}

fn put_record(out: &mut Vec<u8>, r: &RoundRecord) {
    put_usize(out, r.round);
    put_f64(out, r.loss);
    put_f64(out, r.train_acc);
    match r.test_acc {
        Some(a) => {
            out.push(1);
            put_f64(out, a);
        }
        None => out.push(0),
    }
    put_f64(out, r.sim_latency);
    let s = &r.stages;
    for v in [
        s.uplink_phase,
        s.server_fp,
        s.server_bp,
        s.broadcast,
        s.downlink_phase,
        s.model_exchange,
    ] {
        put_f64(out, v);
    }
    put_usize(out, r.faults.injected);
    put_usize(out, r.faults.retries);
    put_usize(out, r.faults.dropped);
    put_usize(out, r.faults.cohort);
    put_f64(out, r.faults.recovery_s);
    put_f64(out, r.wall_ms);
    put_str(out, &r.cut);
}

fn read_record(rd: &mut Reader<'_>) -> Result<RoundRecord> {
    let round = rd.usize()?;
    let loss = rd.f64()?;
    let train_acc = rd.f64()?;
    let test_acc = match rd.u8()? {
        0 => None,
        1 => Some(rd.f64()?),
        other => {
            return Err(Error::Fault(format!(
                "checkpoint record flag {other} is not 0/1"
            )))
        }
    };
    let sim_latency = rd.f64()?;
    let stages = StageSpans {
        uplink_phase: rd.f64()?,
        server_fp: rd.f64()?,
        server_bp: rd.f64()?,
        broadcast: rd.f64()?,
        downlink_phase: rd.f64()?,
        model_exchange: rd.f64()?,
    };
    let faults = FaultStats {
        injected: rd.usize()?,
        retries: rd.usize()?,
        dropped: rd.usize()?,
        cohort: rd.usize()?,
        recovery_s: rd.f64()?,
    };
    let wall_ms = rd.f64()?;
    let cut = rd.string()?;
    Ok(RoundRecord {
        round,
        loss,
        train_acc,
        test_acc,
        sim_latency,
        stages,
        faults,
        wall_ms,
        cut,
    })
}

impl Checkpoint {
    /// Serialize to the versioned binary layout.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        put_u32(&mut out, VERSION);
        put_u64(&mut out, self.fingerprint);
        put_usize(&mut out, self.next_round);
        for lane in self.rng.s {
            put_u64(&mut out, lane);
        }
        match self.rng.gauss_spare {
            Some(v) => {
                out.push(1);
                put_f64(&mut out, v);
            }
            None => out.push(0),
        }
        put_usize(&mut out, self.client_params.len());
        for replica in &self.client_params {
            put_usize(&mut out, replica.len());
            for t in replica {
                put_f32_slice(&mut out, t);
            }
        }
        put_usize(&mut out, self.server_params.len());
        for t in &self.server_params {
            put_f32_slice(&mut out, t);
        }
        put_usize(&mut out, self.records.len());
        for r in &self.records {
            put_record(&mut out, r);
        }
        out
    }

    /// Parse the binary layout; every malformation is a typed
    /// [`Error::Fault`], never a panic.
    pub fn from_bytes(buf: &[u8]) -> Result<Checkpoint> {
        let mut rd = Reader { buf, pos: 0 };
        if rd.take(MAGIC.len())? != MAGIC {
            return Err(Error::Fault(
                "not an EPSL checkpoint (bad magic)".into(),
            ));
        }
        let version = rd.u32()?;
        if version != VERSION {
            return Err(Error::Fault(format!(
                "checkpoint version {version} unsupported (expected \
                 {VERSION})"
            )));
        }
        let fingerprint = rd.u64()?;
        let next_round = rd.usize()?;
        let s = [rd.u64()?, rd.u64()?, rd.u64()?, rd.u64()?];
        let gauss_spare = match rd.u8()? {
            0 => None,
            1 => Some(rd.f64()?),
            other => {
                return Err(Error::Fault(format!(
                    "checkpoint rng flag {other} is not 0/1"
                )))
            }
        };
        let n_replicas = rd.count(8)?;
        let mut client_params = Vec::with_capacity(n_replicas);
        for _ in 0..n_replicas {
            let n_tensors = rd.count(8)?;
            let mut replica = Vec::with_capacity(n_tensors);
            for _ in 0..n_tensors {
                replica.push(rd.f32_vec()?);
            }
            client_params.push(replica);
        }
        let n_server = rd.count(8)?;
        let mut server_params = Vec::with_capacity(n_server);
        for _ in 0..n_server {
            server_params.push(rd.f32_vec()?);
        }
        let n_records = rd.count(8)?;
        let mut records = Vec::with_capacity(n_records);
        for _ in 0..n_records {
            records.push(read_record(&mut rd)?);
        }
        if rd.pos != buf.len() {
            return Err(Error::Fault(format!(
                "checkpoint has {} trailing byte(s)",
                buf.len() - rd.pos
            )));
        }
        Ok(Checkpoint {
            fingerprint,
            next_round,
            rng: RngState { s, gauss_spare },
            client_params,
            server_params,
            records,
        })
    }

    /// Write to disk (atomic-ish: temp file + rename, so a crash during
    /// the write never leaves a half-checkpoint under the final name).
    pub fn save(&self, path: &str) -> Result<()> {
        let tmp = format!("{path}.tmp");
        fs::write(&tmp, self.to_bytes())
            .map_err(|e| Error::Io(format!("{tmp}: {e}")))?;
        fs::rename(&tmp, path)
            .map_err(|e| Error::Io(format!("{tmp} -> {path}: {e}")))
    }

    /// Read + parse from disk.
    pub fn load(path: &str) -> Result<Checkpoint> {
        if !Path::new(path).exists() {
            return Err(Error::Fault(format!(
                "checkpoint '{path}' does not exist"
            )));
        }
        let buf = fs::read(path)
            .map_err(|e| Error::Io(format!("{path}: {e}")))?;
        Self::from_bytes(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> Checkpoint {
        Checkpoint {
            fingerprint: 0xDEAD_BEEF_1234_5678,
            next_round: 5,
            rng: RngState {
                s: [1, u64::MAX, 3, 0x8000_0000_0000_0001],
                gauss_spare: Some(-0.123456789),
            },
            client_params: vec![
                vec![vec![1.0, -2.5, f32::MIN_POSITIVE], vec![0.0]],
                vec![vec![3.5, 4.25, -0.0], vec![9.0]],
            ],
            server_params: vec![vec![0.5; 7], vec![]],
            records: vec![
                RoundRecord {
                    round: 0,
                    loss: 2.302585,
                    train_acc: 0.125,
                    test_acc: None,
                    sim_latency: 1.5,
                    stages: StageSpans {
                        uplink_phase: 0.5,
                        server_fp: 0.25,
                        server_bp: 0.25,
                        broadcast: 0.25,
                        downlink_phase: 0.25,
                        model_exchange: 0.0,
                    },
                    faults: FaultStats::default(),
                    wall_ms: 12.5,
                    cut: "2".into(),
                },
                RoundRecord {
                    round: 1,
                    loss: 2.1,
                    train_acc: 0.25,
                    test_acc: Some(0.3),
                    sim_latency: 1.75,
                    stages: StageSpans {
                        uplink_phase: 0.75,
                        server_fp: 0.25,
                        server_bp: 0.25,
                        broadcast: 0.25,
                        downlink_phase: 0.25,
                        model_exchange: 0.0,
                    },
                    faults: FaultStats {
                        injected: 1,
                        retries: 2,
                        dropped: 1,
                        cohort: 4,
                        recovery_s: 0.375,
                    },
                    wall_ms: 13.25,
                    cut: "1-2-2-3".into(),
                },
            ],
        }
    }

    #[test]
    fn byte_roundtrip_is_exact() {
        let ck = fixture();
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(ck, back);
        // Bit-level check on the float payloads (PartialEq would accept
        // -0.0 == 0.0; the resume contract is bitwise).
        assert_eq!(
            ck.client_params[1][0][2].to_bits(),
            back.client_params[1][0][2].to_bits(),
            "-0.0 not preserved"
        );
    }

    #[test]
    fn no_spare_roundtrip() {
        let mut ck = fixture();
        ck.rng.gauss_spare = None;
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.rng.gauss_spare, None);
    }

    #[test]
    fn truncation_is_a_typed_error() {
        let bytes = ck_bytes();
        for cut in [0, 4, 12, 21, bytes.len() / 2, bytes.len() - 1] {
            let e = Checkpoint::from_bytes(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(e, Error::Fault(_)),
                "cut at {cut}: unexpected kind {e}"
            );
        }
    }

    fn ck_bytes() -> Vec<u8> {
        fixture().to_bytes()
    }

    #[test]
    fn bad_magic_and_version_rejected() {
        let mut bytes = ck_bytes();
        bytes[0] = b'X';
        let e = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(e.to_string().contains("magic"), "{e}");
        let mut bytes = ck_bytes();
        bytes[8] = 99; // version LE low byte
        let e = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(e.to_string().contains("version"), "{e}");
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut bytes = ck_bytes();
        bytes.push(0);
        let e = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(e.to_string().contains("trailing"), "{e}");
    }

    #[test]
    fn corrupt_count_rejected_without_huge_alloc() {
        let mut bytes = ck_bytes();
        // The replica-count field sits right after the rng block:
        // 8 magic + 4 version + 8 fp + 8 round + 32 rng + 1 flag + 8 spare.
        let off = 8 + 4 + 8 + 8 + 32 + 1 + 8;
        bytes[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let e = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(matches!(e, Error::Fault(_)), "{e}");
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir
            .join(format!("epsl-ckpt-test-{}.bin", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let ck = fixture();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(ck, back);
        let e = Checkpoint::load("/nonexistent/epsl.ckpt").unwrap_err();
        assert!(e.to_string().contains("does not exist"), "{e}");
    }

    #[test]
    fn fingerprint_covers_cut_assignment() {
        // Satellite: a checkpoint taken under one cut assignment must not
        // resume into a run with another — uniform → hetero, hetero →
        // uniform, and two different explicit vectors all re-fingerprint.
        use super::super::driver::CutMode;
        let cfg = Config::new();
        let uniform = TrainerOptions::default();
        let hetero = TrainerOptions {
            cut_mode: CutMode::Hetero,
            ..TrainerOptions::default()
        };
        let explicit = TrainerOptions {
            cut_mode: CutMode::Explicit(vec![1, 2, 2, 3, 3]),
            ..TrainerOptions::default()
        };
        let explicit2 = TrainerOptions {
            cut_mode: CutMode::Explicit(vec![2, 2, 2, 3, 3]),
            ..TrainerOptions::default()
        };
        let fp_u = run_fingerprint(&cfg, &uniform);
        let fp_h = run_fingerprint(&cfg, &hetero);
        let fp_e = run_fingerprint(&cfg, &explicit);
        assert_ne!(fp_u, fp_h, "uniform vs hetero");
        assert_ne!(fp_u, fp_e, "uniform vs explicit");
        assert_ne!(fp_h, fp_e, "hetero vs explicit");
        assert_ne!(fp_e, run_fingerprint(&cfg, &explicit2));
    }

    #[test]
    fn fingerprint_ignores_checkpoint_knobs_only() {
        let cfg = Config::new();
        let a = TrainerOptions::default();
        let mut b = a.clone();
        b.checkpoint_every = 3;
        b.checkpoint_path = Some("x.ckpt".into());
        assert_eq!(run_fingerprint(&cfg, &a), run_fingerprint(&cfg, &b));
        let mut c = a.clone();
        c.seed = 7;
        assert_ne!(run_fingerprint(&cfg, &a), run_fingerprint(&cfg, &c));
        let mut d = a.clone();
        d.n_clients += 1;
        assert_ne!(run_fingerprint(&cfg, &a), run_fingerprint(&cfg, &d));
        let mut cfg2 = Config::new();
        cfg2.train.batch = 32;
        assert_ne!(run_fingerprint(&cfg, &a), run_fingerprint(&cfg2, &a));
    }
}
