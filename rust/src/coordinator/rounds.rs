//! Declarative round plans and the one engine that executes them.
//!
//! Every framework's training round used to be one of two divergent
//! hand-rolled functions (`parallel_round` / `vanilla_round`). Now a
//! round is data — a [`RoundPlan`] of turn scheduling, effective φ, and
//! end-of-round model synchronization — and [`execute_round`] is the
//! single engine that runs any plan through the shared stage sequence:
//! client FP fan-out → smashed-data concat → fused server step (with the
//! φ-aggregation inside the graph) → gradient routing (broadcast vs
//! unicast by the φ-mask) → client BP fan-out → model sync.
//!
//! The engine is bit-identical to both legacy round functions: batches
//! are sampled in the same RNG-stream order, parallel plans run one
//! C-client turn (one fused server call, `call_many` fan-out), and
//! sequential plans run C single-client turns against one shared relayed
//! client model with φ = 0 (all-unicast routing).

use xla::Literal;

use crate::error::{Error, Result};
use crate::latency::frameworks::Framework;
use crate::metrics::FaultStats;
use crate::runtime::tensor::{literal_f32, literal_i32, scalar_f32,
                             to_f32_vec};

use crate::optim::CutAssignment;

use super::params::{client_tensor_count, fedavg};
use super::phi_at_round;
use super::session::Session;

/// How a round's client work is scheduled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TurnStyle {
    /// All C clients advance together: one fused server step over the
    /// concatenated C·b smashed batch.
    Parallel,
    /// One client at a time against the server (vanilla SL), sharing a
    /// single relayed client-side model.
    Sequential,
}

/// End-of-round client-side model synchronization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncStyle {
    /// Client models never synchronize during training (PSL / EPSL;
    /// vanilla SL needs none because the model is shared by relay).
    None,
    /// λ-weighted FedAvg of the client-side models (SFL).
    FedAvg,
}

/// A declarative description of one training round — every framework in
/// the paper's evaluation is one of these, executed by [`execute_round`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundPlan {
    pub framework: Framework,
    /// Effective aggregation ratio φ this round (EPSL-PT flips by round).
    pub phi: f64,
    pub turns: TurnStyle,
    pub sync: SyncStyle,
}

impl RoundPlan {
    /// The plan `fw` runs at training round `round`.
    pub fn for_round(fw: Framework, round: usize, pt_switch: usize)
        -> RoundPlan {
        RoundPlan {
            framework: fw,
            phi: phi_at_round(fw, round, pt_switch),
            turns: if matches!(fw, Framework::VanillaSl) {
                TurnStyle::Sequential
            } else {
                TurnStyle::Parallel
            },
            sync: if matches!(fw, Framework::Sfl) {
                SyncStyle::FedAvg
            } else {
                SyncStyle::None
            },
        }
    }

    /// Client-side parameter replicas this plan trains: one shared model
    /// for sequential relay, C independent models otherwise.
    pub fn param_replicas(&self, n_clients: usize) -> usize {
        match self.turns {
            TurnStyle::Parallel => n_clients,
            TurnStyle::Sequential => 1,
        }
    }

    /// Clients per fused server step (C for parallel, 1 for sequential).
    pub fn server_clients(&self, n_clients: usize) -> usize {
        match self.turns {
            TurnStyle::Parallel => n_clients,
            TurnStyle::Sequential => 1,
        }
    }

    /// Which parameter replica `client` trains under this plan.
    fn param_index(&self, client: usize) -> usize {
        match self.turns {
            TurnStyle::Parallel => client,
            TurnStyle::Sequential => 0,
        }
    }
}

/// What one executed round hands back to the driver.
pub(crate) struct RoundOutput {
    /// Weighted loss over the committed cohort.
    pub(crate) loss: f64,
    /// Train accuracy over the committed cohort's samples.
    pub(crate) train_acc: f64,
    /// Injected-fault / recovery accounting for the round.
    pub(crate) faults: FaultStats,
}

/// λ weights re-normalized over the present cohort: `λ_i / Σ_{j present}
/// λ_j`, so the fused server step's weighted reduction stays a proper
/// convex combination when clients drop mid-round.
pub(crate) fn renormalized_lambda(lam: &[f32], present: &[usize])
    -> Vec<f32> {
    let total: f32 = present.iter().map(|&i| lam[i]).sum();
    present.iter().map(|&i| lam[i] / total).collect()
}

/// Execute round `round` of `plan`. A fault-free session takes the exact
/// pre-fault path (same RNG stream, same literals, bit-identical); with
/// a [`super::session::FaultRuntime`] installed the round absorbs the
/// injected faults — crashes and deadline-expired stragglers shrink the
/// committed cohort (⌈φb⌉ mask unchanged, λ re-normalized over the
/// survivors), transient corruptions and server aborts retry with
/// backoff, and everything is accounted in the returned
/// [`FaultStats`].
pub(crate) fn execute_round(
    sess: &mut Session, plan: &RoundPlan, round: usize,
    client_params: &mut [Vec<Literal>], server_params: &mut Vec<Literal>,
) -> Result<RoundOutput> {
    if sess.cuts.windows(2).any(|w| w[0] != w[1]) {
        return execute_round_hetero(sess, plan, client_params,
                                    server_params);
    }
    let c = sess.opts.n_clients;
    let b = sess.fam.batch;
    let cut = sess.cuts.first().copied().unwrap_or(sess.opts.cut);
    let fam = sess.fam;
    let smash = fam.smashed_shape.get(&cut).ok_or_else(|| {
        Error::Artifact(format!("no smashed_shape for cut {cut}"))
    })?;
    let smash_len: usize = smash.iter().product();

    let cf_entry = fam.client_fwd.get(&cut).ok_or_else(|| {
        Error::Artifact(format!("no client_fwd for cut {cut}"))
    })?;
    let cs_entry = fam.client_step.get(&cut).ok_or_else(|| {
        Error::Artifact(format!("no client_step for cut {cut}"))
    })?;

    // Resolve this round's faults + resilience policy (quiet defaults).
    let rf = sess
        .faults
        .as_ref()
        .map(|f| f.round(round))
        .unwrap_or_default();
    let (quorum, max_retries, backoff_s, deadline_factor) = sess
        .faults
        .as_ref()
        .map_or((1, 0, 0.0, 1.5), |f| {
            (f.quorum, f.max_retries, f.retry_backoff_s, f.deadline_factor)
        });
    let mut stats = FaultStats {
        injected: rf.n_injected(),
        ..FaultStats::default()
    };

    // Cohort assembly: crashes drop clients outright; corrupted payloads
    // retry (detected on ingest, the deterministic resend succeeds) or
    // drop when the retry budget is 0; injected uplink delays past the
    // straggler deadline evict, within it they cost recovery seconds.
    let mut present: Vec<usize> =
        (0..c).filter(|i| !rf.crashed.contains(i)).collect();
    stats.dropped += rf.crashed.len();
    for &ci in &rf.corrupt {
        if !present.contains(&ci) {
            continue;
        }
        if max_retries == 0 {
            present.retain(|&x| x != ci);
            stats.dropped += 1;
        } else {
            stats.retries += 1;
            stats.recovery_s += backoff_s;
        }
    }
    if !rf.delays.is_empty() {
        let arrivals =
            sess.sim_latency.uplink_arrivals(round, plan.framework,
                                             plan.phi);
        // The deadline only has per-client meaning when the timeline has
        // one chain per client (vanilla SL's pre-summed sweep does not).
        if arrivals.len() == c {
            let nominal_max =
                arrivals.iter().cloned().fold(0.0, f64::max);
            let deadline = deadline_factor * nominal_max;
            let mut overshoot = 0.0f64;
            for &(ci, d) in &rf.delays {
                if !present.contains(&ci) {
                    continue;
                }
                let arr = arrivals[ci] + d;
                if arr > deadline {
                    present.retain(|&x| x != ci);
                    stats.dropped += 1;
                } else {
                    overshoot = overshoot.max((arr - nominal_max).max(0.0));
                }
            }
            stats.recovery_s += overshoot;
        }
    }
    if present.len() < quorum.max(1) {
        return Err(Error::Quorum {
            round,
            active: present.len(),
            need: quorum.max(1),
        });
    }
    stats.cohort = present.len();
    let full_cohort = present.len() == c;

    let turns: Vec<Vec<usize>> = match plan.turns {
        TurnStyle::Parallel => vec![present.clone()],
        TurnStyle::Sequential => present.iter().map(|&i| vec![i]).collect(),
    };
    let tc = match plan.turns {
        TurnStyle::Parallel => present.len(),
        TurnStyle::Sequential => 1,
    };
    let st_entry = fam.server_train_entry(cut, tc)?;
    let (mask, mask_lit) = sess.mask_for(plan.phi)?;
    let agg_used = mask.iter().any(|m| *m > 0.5);
    let lam_lit = match plan.turns {
        // The hoisted literal on the full cohort keeps the fault-free
        // path bit-identical; a shrunk cohort re-normalizes λ over the
        // survivors.
        TurnStyle::Parallel if full_cohort => sess.lam_lit.clone(),
        TurnStyle::Parallel => literal_f32(
            &[present.len()],
            &renormalized_lambda(&sess.lam, &present),
        )?,
        TurnStyle::Sequential => literal_f32(&[1], &[1.0])?,
    };
    let mut abort_pending = rf.server_abort;
    if abort_pending && max_retries == 0 {
        return Err(Error::Fault(format!(
            "server abort at round {round} with no retry budget \
             (faults.max_retries = 0): the round cannot commit"
        )));
    }

    let n_turns = turns.len();
    let mut loss_sum = 0.0f64;
    let mut ncorr_sum = 0.0f64;
    for turn in &turns {
        // Stages 1-2: client FP + smashed-data uplink. Batches are
        // sampled serially (the session RNG stream stays deterministic),
        // then the independent forward passes fan across cores via
        // call_many (order-preserving, bit-identical to a serial loop).
        let mut smashed_host = Vec::with_capacity(tc * b * smash_len);
        let mut labels_host: Vec<i32> = Vec::with_capacity(tc * b);
        let mut xs = Vec::with_capacity(tc);
        let mut fwd_batches: Vec<Vec<Literal>> = Vec::with_capacity(tc);
        for &ci in turn {
            let (x, _imgs, labels) = sess.batch_literals(ci)?;
            let mut inputs: Vec<Literal> =
                client_params[plan.param_index(ci)].to_vec();
            inputs.push(x.clone());
            fwd_batches.push(inputs);
            labels_host.extend(labels);
            xs.push(x);
        }
        for out in sess.rt.call_many(cf_entry, &fwd_batches)? {
            smashed_host.extend(to_f32_vec(&out[0])?);
        }

        // Stages 3-4: fused server FP + BP (+ φ-aggregation kernel).
        let mut smash_shape = vec![tc, b];
        smash_shape.extend(smash.iter());
        let mut inputs: Vec<Literal> = server_params.to_vec();
        inputs.push(literal_f32(&smash_shape, &smashed_host)?);
        inputs.push(literal_i32(&[tc, b], &labels_host)?);
        inputs.push(lam_lit.clone());
        inputs.push(mask_lit.clone());
        inputs.push(sess.lr_s_lit.clone());
        if abort_pending {
            // Server abort mid-round: the first fused step's work is
            // lost before it commits (server_params are only assigned
            // below, so discarding the result really discards the
            // update); the retry recomputes it. Recovery pays the
            // backoff plus the repeated server compute.
            abort_pending = false;
            let _ = sess.rt.call(st_entry, &inputs)?;
            stats.retries += 1;
            let spans = sess
                .sim_latency
                .round_timeline(round, plan.framework, plan.phi)
                .spans;
            stats.recovery_s += backoff_s + spans.server_fp
                + spans.server_bp;
        }
        let mut out = sess.rt.call(st_entry, &inputs)?;
        let n_sp = server_params.len();
        ncorr_sum += scalar_f32(&out[n_sp + 3])? as f64;
        loss_sum += scalar_f32(&out[n_sp + 2])? as f64;
        let cut_unagg = to_f32_vec(&out[n_sp + 1])?;
        // The aggregated payload is only materialized when some mask slot
        // routes through the broadcast (φ > 0).
        let cut_agg = if agg_used {
            to_f32_vec(&out[n_sp])?
        } else {
            Vec::new()
        };
        out.truncate(n_sp);
        *server_params = out;

        // Stages 5-7: gradient routing (broadcast payload for aggregated
        // slots, unicast otherwise) + client BP fan-out.
        let mut g_cut = vec![0.0f32; b * smash_len];
        let mut g_shape = vec![b];
        g_shape.extend(smash.iter());
        let mut step_batches: Vec<Vec<Literal>> = Vec::with_capacity(tc);
        for (ti, x) in xs.into_iter().enumerate() {
            for j in 0..b {
                let dst = &mut g_cut[j * smash_len..(j + 1) * smash_len];
                if mask[j] > 0.5 {
                    // broadcast payload (identical for every client)
                    dst.copy_from_slice(
                        &cut_agg[j * smash_len..(j + 1) * smash_len],
                    );
                } else {
                    // unicast payload
                    let base = (ti * b + j) * smash_len;
                    dst.copy_from_slice(
                        &cut_unagg[base..base + smash_len],
                    );
                }
            }
            let mut inputs: Vec<Literal> =
                client_params[plan.param_index(turn[ti])].to_vec();
            inputs.push(x);
            inputs.push(literal_f32(&g_shape, &g_cut)?);
            inputs.push(sess.lr_c_lit.clone());
            step_batches.push(inputs);
        }
        for (ti, out) in
            sess.rt.call_many(cs_entry, &step_batches)?.into_iter().enumerate()
        {
            client_params[plan.param_index(turn[ti])] = out;
        }
    }

    // Model sync: SFL's per-round client-side FedAvg. With a shrunk
    // cohort only the survivors contribute (λ re-normalized), but every
    // replica — including a crashed client's — receives the synced
    // model, exactly as a rejoining SFL client downloads the current
    // global model.
    if matches!(plan.sync, SyncStyle::FedAvg) {
        let avg = if full_cohort {
            fedavg(client_params, &sess.lam, fam, cut)?
        } else {
            let subset: Vec<Vec<Literal>> = present
                .iter()
                .map(|&i| client_params[plan.param_index(i)].clone())
                .collect();
            let w = renormalized_lambda(&sess.lam, &present);
            fedavg(&subset, &w, fam, cut)?
        };
        for cp in client_params.iter_mut() {
            *cp = avg.clone();
        }
    }
    Ok(RoundOutput {
        loss: loss_sum / n_turns as f64,
        train_acc: ncorr_sum / (present.len() * b) as f64,
        faults: stats,
    })
}

/// One mixed-cut parallel round: clients are batched by cut group, each
/// group runs its own fused server step over the server *sub-suffix* at
/// its cut (the server owns the suffix at the shallowest assigned cut;
/// a deeper group's extra layers live client-side), and φ-aggregation
/// routes gradients within each group.
///
/// The driver gates this path to the parallel, fault-free, static-
/// channel frameworks, so there is no cohort assembly here — the round
/// always commits with the full cohort. Batches are drawn for *every*
/// client in ascending client order before any group runs, so the RNG
/// stream order is a function of the client count alone, not of how the
/// assignment happens to group.
///
/// Loss accounting: the group's fused step returns the λ-renormalized
/// group loss; weighting it by the group's λ mass (`w_g = Σ_g λ / Σ λ`)
/// and summing recovers exactly the global λ-weighted loss of eq. 1.
fn execute_round_hetero(
    sess: &mut Session, plan: &RoundPlan,
    client_params: &mut [Vec<Literal>], server_params: &mut Vec<Literal>,
) -> Result<RoundOutput> {
    debug_assert_eq!(plan.turns, TurnStyle::Parallel);
    let c = sess.opts.n_clients;
    let b = sess.fam.batch;
    let fam = sess.fam;
    let cuts = sess.cuts.clone();
    let j_min = *cuts.iter().min().ok_or_else(|| {
        Error::Config("round has zero clients".into())
    })?;
    let n_min = client_tensor_count(fam, j_min)?;

    let (mask, mask_lit) = sess.mask_for(plan.phi)?;
    let agg_used = mask.iter().any(|m| *m > 0.5);

    let mut batches: Vec<(Literal, Vec<i32>)> = Vec::with_capacity(c);
    for ci in 0..c {
        let (x, _imgs, labels) = sess.batch_literals(ci)?;
        batches.push((x, labels));
    }

    let lam_total: f64 = sess.lam.iter().map(|&w| w as f64).sum();
    let mut loss_sum = 0.0f64;
    let mut ncorr_sum = 0.0f64;
    // Groups execute ascending in cut layer (deterministic order).
    for (cut, members) in
        CutAssignment::PerClient(cuts.clone()).groups(c)
    {
        let tc = members.len();
        let smash = fam.smashed_shape.get(&cut).ok_or_else(|| {
            Error::Artifact(format!("no smashed_shape for cut {cut}"))
        })?;
        let smash_len: usize = smash.iter().product();
        let cf_entry = fam.client_fwd.get(&cut).ok_or_else(|| {
            Error::Artifact(format!("no client_fwd for cut {cut}"))
        })?;
        let cs_entry = fam.client_step.get(&cut).ok_or_else(|| {
            Error::Artifact(format!("no client_step for cut {cut}"))
        })?;
        let st_entry = fam.server_train_entry(cut, tc)?;
        let off = client_tensor_count(fam, cut)? - n_min;

        // Stages 1-2: the group's client FP fan-out.
        let mut smashed_host = Vec::with_capacity(tc * b * smash_len);
        let mut labels_host: Vec<i32> = Vec::with_capacity(tc * b);
        let mut xs = Vec::with_capacity(tc);
        let mut fwd_batches: Vec<Vec<Literal>> = Vec::with_capacity(tc);
        for &ci in &members {
            let (x, labels) = &batches[ci];
            let mut inputs: Vec<Literal> = client_params[ci].to_vec();
            inputs.push(x.clone());
            fwd_batches.push(inputs);
            labels_host.extend_from_slice(labels);
            xs.push(x.clone());
        }
        for out in sess.rt.call_many(cf_entry, &fwd_batches)? {
            smashed_host.extend(to_f32_vec(&out[0])?);
        }

        // Stages 3-4: the group's fused server step on its sub-suffix.
        let mut smash_shape = vec![tc, b];
        smash_shape.extend(smash.iter());
        let lam_g = renormalized_lambda(&sess.lam, &members);
        let mut inputs: Vec<Literal> = server_params[off..].to_vec();
        inputs.push(literal_f32(&smash_shape, &smashed_host)?);
        inputs.push(literal_i32(&[tc, b], &labels_host)?);
        inputs.push(literal_f32(&[tc], &lam_g)?);
        inputs.push(mask_lit.clone());
        inputs.push(sess.lr_s_lit.clone());
        let mut out = sess.rt.call(st_entry, &inputs)?;
        let n_sp = server_params.len() - off;
        let w_g: f64 = members
            .iter()
            .map(|&i| sess.lam[i] as f64)
            .sum::<f64>()
            / lam_total;
        ncorr_sum += scalar_f32(&out[n_sp + 3])? as f64;
        loss_sum += w_g * scalar_f32(&out[n_sp + 2])? as f64;
        let cut_unagg = to_f32_vec(&out[n_sp + 1])?;
        let cut_agg = if agg_used {
            to_f32_vec(&out[n_sp])?
        } else {
            Vec::new()
        };
        out.truncate(n_sp);
        for (k, lit) in out.into_iter().enumerate() {
            server_params[off + k] = lit;
        }

        // Stages 5-7: gradient routing + client BP for the group.
        let mut g_cut = vec![0.0f32; b * smash_len];
        let mut g_shape = vec![b];
        g_shape.extend(smash.iter());
        let mut step_batches: Vec<Vec<Literal>> = Vec::with_capacity(tc);
        for (ti, x) in xs.into_iter().enumerate() {
            for j in 0..b {
                let dst = &mut g_cut[j * smash_len..(j + 1) * smash_len];
                if mask[j] > 0.5 {
                    dst.copy_from_slice(
                        &cut_agg[j * smash_len..(j + 1) * smash_len],
                    );
                } else {
                    let base = (ti * b + j) * smash_len;
                    dst.copy_from_slice(
                        &cut_unagg[base..base + smash_len],
                    );
                }
            }
            let mut inputs: Vec<Literal> =
                client_params[members[ti]].to_vec();
            inputs.push(x);
            inputs.push(literal_f32(&g_shape, &g_cut)?);
            inputs.push(sess.lr_c_lit.clone());
            step_batches.push(inputs);
        }
        for (ti, out) in
            sess.rt.call_many(cs_entry, &step_batches)?.into_iter().enumerate()
        {
            client_params[members[ti]] = out;
        }
    }
    Ok(RoundOutput {
        loss: loss_sum,
        train_acc: ncorr_sum / (c * b) as f64,
        faults: FaultStats { cohort: c, ..FaultStats::default() },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::coordinator::driver::{train, train_with_state, CutMode,
                                     TrainerOptions};
    use crate::runtime::artifact::Manifest;
    use crate::runtime::native::{self, NativeBackend};
    use crate::scenario::{DynamicChannel, ReoptPolicy, ScenarioSpec};

    /// The smoke tests run for real on the native backend (no skipping):
    /// the training path is exercised on every `cargo test`.
    fn setup() -> (NativeBackend, Manifest, Config) {
        (NativeBackend::new(), native::manifest(), Config::new())
    }

    fn smoke_opts() -> TrainerOptions {
        TrainerOptions {
            n_clients: 2,
            rounds: 4,
            eval_every: 2,
            dataset_size: 400,
            test_size: 256,
            ..Default::default()
        }
    }

    #[test]
    fn plans_match_framework_semantics() {
        let p = RoundPlan::for_round(Framework::Epsl { phi: 0.5 }, 0, 10);
        assert_eq!(p.turns, TurnStyle::Parallel);
        assert_eq!(p.sync, SyncStyle::None);
        assert_eq!(p.phi, 0.5);
        assert_eq!(p.param_replicas(5), 5);
        assert_eq!(p.server_clients(5), 5);

        let p = RoundPlan::for_round(Framework::Sfl, 0, 10);
        assert_eq!(p.sync, SyncStyle::FedAvg);
        assert_eq!(p.phi, 0.0);

        let p = RoundPlan::for_round(Framework::VanillaSl, 0, 10);
        assert_eq!(p.turns, TurnStyle::Sequential);
        assert_eq!(p.param_replicas(5), 1);
        assert_eq!(p.server_clients(5), 1);

        // EPSL-PT flips φ at the switch round.
        let fw = Framework::EpslPt { early: true };
        assert_eq!(RoundPlan::for_round(fw, 9, 10).phi, 1.0);
        assert_eq!(RoundPlan::for_round(fw, 10, 10).phi, 0.0);
    }

    #[test]
    fn sfl_keeps_clients_synchronized() {
        let (rt, m, cfg) = setup();
        let opts = TrainerOptions {
            framework: Framework::Sfl,
            rounds: 2,
            eval_every: 10,
            ..smoke_opts()
        };
        // The per-round FedAvg must leave every client with bit-identical
        // client-side parameters (previously only finiteness was checked).
        let (run, state) = train_with_state(&rt, &m, &cfg, &opts).unwrap();
        assert!(run.rounds.iter().all(|r| r.loss.is_finite()));
        assert_eq!(state.client_params.len(), 2);
        let reference: Vec<Vec<f32>> = state.client_params[0]
            .iter()
            .map(|l| to_f32_vec(l).unwrap())
            .collect();
        for (ci, cp) in state.client_params.iter().enumerate().skip(1) {
            for (t, lit) in cp.iter().enumerate() {
                assert_eq!(
                    to_f32_vec(lit).unwrap(),
                    reference[t],
                    "client {ci} tensor {t} diverged after SFL FedAvg"
                );
            }
        }
    }

    #[test]
    fn psl_clients_do_diverge() {
        // Control for the SFL assertion: without the model exchange the
        // client models must NOT be synchronized (distinct shards).
        let (rt, m, cfg) = setup();
        let opts = TrainerOptions {
            framework: Framework::Psl,
            rounds: 2,
            eval_every: 10,
            ..smoke_opts()
        };
        let (_, state) = train_with_state(&rt, &m, &cfg, &opts).unwrap();
        let a = to_f32_vec(&state.client_params[0][0]).unwrap();
        let b = to_f32_vec(&state.client_params[1][0]).unwrap();
        assert_ne!(a, b, "PSL clients unexpectedly synchronized");
    }

    #[test]
    fn missing_cut_is_an_error_not_a_panic() {
        // Both plan shapes must fail with Error::Artifact when the
        // manifest has no entries for the requested cut. Each entry kind
        // is removed separately so both lookup sites stay covered —
        // client_fwd is checked first, so a combined removal would never
        // reach the client_step path.
        let (rt, _, cfg) = setup();
        for missing in ["client_fwd", "client_step"] {
            let mut m = native::manifest();
            let fam = m.families.get_mut("mnist").unwrap();
            match missing {
                "client_fwd" => fam.client_fwd.remove(&2),
                _ => fam.client_step.remove(&2),
            };
            for fw in [Framework::VanillaSl, Framework::Epsl { phi: 0.5 }] {
                let opts = TrainerOptions {
                    framework: fw,
                    rounds: 1,
                    ..smoke_opts()
                };
                let e = train(&rt, &m, &cfg, &opts).unwrap_err();
                assert!(
                    matches!(e, Error::Artifact(_)),
                    "{fw:?}/{missing}: unexpected error kind: {e}"
                );
                assert!(
                    e.to_string()
                        .contains(&format!("no {missing} for cut 2")),
                    "{fw:?}/{missing}: {e}"
                );
            }
        }
    }

    #[test]
    fn native_run_is_seed_deterministic_and_thread_invariant() {
        // Acceptance criterion: same seed ⇒ bit-identical run, for any
        // thread budget.
        let (_, m, cfg) = setup();
        let opts = smoke_opts();
        let serial = NativeBackend::with_threads(1);
        let fanned = NativeBackend::with_threads(7);
        let a = train(&serial, &m, &cfg, &opts).unwrap();
        let b = train(&fanned, &m, &cfg, &opts).unwrap();
        let c = train(&fanned, &m, &cfg, &opts).unwrap();
        for ((ra, rb), rc) in
            a.rounds.iter().zip(&b.rounds).zip(&c.rounds)
        {
            assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
            assert_eq!(ra.train_acc.to_bits(), rb.train_acc.to_bits());
            assert_eq!(rb.loss.to_bits(), rc.loss.to_bits());
            assert_eq!(
                ra.test_acc.map(f64::to_bits),
                rb.test_acc.map(f64::to_bits)
            );
        }
    }

    #[test]
    fn sfl_dynamic_channel_smoke() {
        // The scenario-tracked latency accounting used to be exercised
        // only on EPSL paths; SFL adds the model-exchange term on top of
        // the per-round realized rates.
        let (rt, m, cfg) = setup();
        let opts = TrainerOptions {
            framework: Framework::Sfl,
            rounds: 4,
            eval_every: 10,
            dynamic_channel: Some(DynamicChannel {
                spec: ScenarioSpec::fading(4),
                policy: ReoptPolicy::Never,
            }),
            ..smoke_opts()
        };
        let run = train(&rt, &m, &cfg, &opts).unwrap();
        assert_eq!(run.rounds.len(), 4);
        assert!(run
            .rounds
            .iter()
            .all(|r| r.sim_latency > 0.0 && r.sim_latency.is_finite()));
        // Per-round fading must move the simulated latency.
        let t0 = run.rounds[0].sim_latency;
        assert!(
            run.rounds.iter().any(|r| r.sim_latency != t0),
            "fading never moved the SFL simulated latency"
        );
        // SFL's stage breakdown carries the model exchange.
        assert!(run.rounds.iter().all(|r| r.stages.model_exchange > 0.0));
    }

    #[test]
    fn explicit_all_equal_cuts_bit_identical_to_uniform() {
        // Tentpole acceptance: an Explicit all-equal assignment must
        // collapse onto the literal uniform path — per-round records AND
        // final parameters agree bit-for-bit, for both families and
        // several cohort sizes.
        let (rt, m, cfg) = setup();
        for family in ["mnist", "ham"] {
            for c in [1usize, 4, 8] {
                let base = TrainerOptions {
                    family: family.into(),
                    n_clients: c,
                    rounds: 2,
                    eval_every: 2,
                    dataset_size: 400,
                    test_size: 256,
                    ..Default::default()
                };
                let explicit = TrainerOptions {
                    cut_mode: CutMode::Explicit(vec![base.cut; c]),
                    ..base.clone()
                };
                let (ra, sa) =
                    train_with_state(&rt, &m, &cfg, &base).unwrap();
                let (rb, sb) =
                    train_with_state(&rt, &m, &cfg, &explicit).unwrap();
                for (x, y) in ra.rounds.iter().zip(&rb.rounds) {
                    assert_eq!(
                        x.loss.to_bits(),
                        y.loss.to_bits(),
                        "{family}/C={c} round {} loss diverged",
                        x.round
                    );
                    assert_eq!(x.train_acc.to_bits(),
                               y.train_acc.to_bits());
                    assert_eq!(
                        x.test_acc.map(f64::to_bits),
                        y.test_acc.map(f64::to_bits)
                    );
                    assert_eq!(x.sim_latency.to_bits(),
                               y.sim_latency.to_bits());
                    assert_eq!(x.cut, y.cut, "{family}/C={c}");
                }
                for (ca, cb) in
                    sa.client_params.iter().zip(&sb.client_params)
                {
                    for (la, lb) in ca.iter().zip(cb) {
                        assert_eq!(to_f32_vec(la).unwrap(),
                                   to_f32_vec(lb).unwrap());
                    }
                }
                for (la, lb) in
                    sa.server_params.iter().zip(&sb.server_params)
                {
                    assert_eq!(to_f32_vec(la).unwrap(),
                               to_f32_vec(lb).unwrap());
                }
            }
        }
    }

    #[test]
    fn mixed_cut_round_trains_end_to_end() {
        // A genuinely mixed assignment runs the grouped server-batching
        // path: finite loss, recorded per-client cut label, per-client
        // tensor counts at each client's own cut, and the server holding
        // exactly the suffix at the shallowest assigned cut.
        let (rt, m, cfg) = setup();
        let opts = TrainerOptions {
            n_clients: 4,
            rounds: 3,
            eval_every: 3,
            dataset_size: 400,
            test_size: 256,
            cut_mode: CutMode::Explicit(vec![1, 2, 2, 3]),
            ..Default::default()
        };
        let (run, state) =
            train_with_state(&rt, &m, &cfg, &opts).unwrap();
        assert_eq!(run.rounds.len(), 3);
        assert!(run
            .rounds
            .iter()
            .all(|r| r.loss.is_finite() && r.loss > 0.0));
        assert!(
            run.rounds.iter().all(|r| r.cut == "1-2-2-3"),
            "cut labels: {:?}",
            run.rounds.iter().map(|r| r.cut.clone()).collect::<Vec<_>>()
        );
        let last = run.rounds.last().unwrap();
        assert!(last.test_acc.is_some(), "mixed-cut eval never ran");
        assert!(last.test_acc.unwrap().is_finite());

        let fam = m.family("mnist").unwrap();
        for (ci, &cut) in [1usize, 2, 2, 3].iter().enumerate() {
            assert_eq!(
                state.client_params[ci].len(),
                fam.client_param_count[&cut],
                "client {ci} tensor count at cut {cut}"
            );
        }
        // Client 0 sits at the shallowest cut (j_min = 1): its prefix
        // plus the server suffix must tile the full parameter list.
        assert_eq!(
            state.client_params[0].len() + state.server_params.len(),
            fam.params.len()
        );
    }

    #[test]
    fn mixed_cut_run_is_thread_invariant() {
        // The grouped fan-out must stay bit-identical across thread
        // budgets, exactly like the uniform engine.
        let (_, m, cfg) = setup();
        let opts = TrainerOptions {
            n_clients: 4,
            rounds: 2,
            eval_every: 2,
            dataset_size: 400,
            test_size: 256,
            cut_mode: CutMode::Explicit(vec![1, 2, 3, 4]),
            ..Default::default()
        };
        let serial = NativeBackend::with_threads(1);
        let fanned = NativeBackend::with_threads(7);
        let a = train(&serial, &m, &cfg, &opts).unwrap();
        let b = train(&fanned, &m, &cfg, &opts).unwrap();
        for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
            assert_eq!(ra.train_acc.to_bits(), rb.train_acc.to_bits());
            assert_eq!(
                ra.test_acc.map(f64::to_bits),
                rb.test_acc.map(f64::to_bits)
            );
            assert_eq!(ra.cut, rb.cut);
        }
    }

    #[test]
    fn renormalized_lambda_hand_computed() {
        // λ = [0.2, 0.3, 0.5], clients {0, 2} survive:
        // weights = [0.2/0.7, 0.5/0.7], exactly as computed by hand.
        let lam = [0.2_f32, 0.3, 0.5];
        let w = renormalized_lambda(&lam, &[0, 2]);
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].to_bits(), (0.2_f32 / 0.7).to_bits());
        assert_eq!(w[1].to_bits(), (0.5_f32 / 0.7).to_bits());
        assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        // Full cohort renormalizes to the original weights (already
        // normalized), single survivor gets weight 1.
        let full = renormalized_lambda(&lam, &[0, 1, 2]);
        assert!((full.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert_eq!(renormalized_lambda(&lam, &[1]), vec![1.0]);
    }
}
