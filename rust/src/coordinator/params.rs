//! Model parameter state: literal-resident parameters with host mirrors
//! only where aggregation requires them (SFL FedAvg, evaluation average).

use xla::Literal;

use crate::error::Result;
use crate::runtime::artifact::FamilyManifest;
use crate::runtime::tensor::{literal_f32, to_f32_vec, weighted_average};

/// A full model's parameters in canonical order, as XLA literals.
pub struct ParamSet {
    pub literals: Vec<Literal>,
}

impl ParamSet {
    pub fn new(literals: Vec<Literal>) -> Self {
        ParamSet { literals }
    }

    pub fn len(&self) -> usize {
        self.literals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.literals.is_empty()
    }

    /// Split into (client prefix, server suffix) clones for the given cut.
    pub fn split(&self, fam: &FamilyManifest, cut: usize)
        -> (Vec<Literal>, Vec<Literal>) {
        let n = fam.client_param_count[&cut];
        (
            self.literals[..n].to_vec(),
            self.literals[n..].to_vec(),
        )
    }

    /// Recombine client + server parts into a full canonical list.
    pub fn join(client: &[Literal], server: &[Literal]) -> Vec<Literal> {
        let mut v = Vec::with_capacity(client.len() + server.len());
        v.extend(client.iter().cloned());
        v.extend(server.iter().cloned());
        v
    }
}

/// λ-weighted FedAvg over per-client parameter lists (same shapes).
/// Used by SFL every round and by the evaluation-model average for
/// PSL/EPSL (whose client models never synchronize during training).
pub fn fedavg(clients: &[Vec<Literal>], weights: &[f32],
              fam: &FamilyManifest, cut: usize) -> Result<Vec<Literal>> {
    assert_eq!(clients.len(), weights.len());
    let n_tensors = fam.client_param_count[&cut];
    let mut out = Vec::with_capacity(n_tensors);
    for t in 0..n_tensors {
        let bufs: Vec<Vec<f32>> = clients
            .iter()
            .map(|c| to_f32_vec(&c[t]))
            .collect::<Result<_>>()?;
        let avg = weighted_average(&bufs, weights);
        let shape = &fam.params[t].1;
        out.push(literal_f32(shape, &avg)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The native manifest shares the exact shape contract with the AOT
    /// export, so these tests no longer skip on artifact-less checkouts.
    fn fam() -> FamilyManifest {
        crate::runtime::native::manifest()
            .family("mnist")
            .unwrap()
            .clone()
    }

    #[test]
    fn split_join_roundtrip() {
        let fam = fam();
        let lits: Vec<Literal> = fam
            .params
            .iter()
            .map(|(_, s)| {
                let n: usize = s.iter().product();
                literal_f32(s, &vec![1.0; n]).unwrap()
            })
            .collect();
        let ps = ParamSet::new(lits);
        let (c, s) = ps.split(&fam, 2);
        assert_eq!(c.len(), 6);
        assert_eq!(c.len() + s.len(), fam.params.len());
        let joined = ParamSet::join(&c, &s);
        assert_eq!(joined.len(), fam.params.len());
    }

    #[test]
    fn fedavg_weighted() {
        let fam = fam();
        let cut = 2;
        let n = fam.client_param_count[&cut];
        let mk = |v: f32| -> Vec<Literal> {
            fam.params[..n]
                .iter()
                .map(|(_, s)| {
                    let len: usize = s.iter().product();
                    literal_f32(s, &vec![v; len]).unwrap()
                })
                .collect()
        };
        let avg =
            fedavg(&[mk(1.0), mk(3.0)], &[0.25, 0.75], &fam, cut).unwrap();
        let v = to_f32_vec(&avg[0]).unwrap();
        assert!(v.iter().all(|&x| (x - 2.5).abs() < 1e-6));
    }
}
