//! Model parameter state: literal-resident parameters with host mirrors
//! only where aggregation requires them (SFL FedAvg, evaluation average,
//! checkpoint serialization).

use xla::Literal;

use crate::error::{Error, Result};
use crate::runtime::artifact::FamilyManifest;
use crate::runtime::tensor::{literal_f32, to_f32_vec, weighted_average};

/// Number of client-side parameter tensors for a cut, as a typed error
/// instead of a `BTreeMap` index panic on an unsupported cut.
pub fn client_tensor_count(fam: &FamilyManifest, cut: usize)
    -> Result<usize> {
    fam.client_param_count.get(&cut).copied().ok_or_else(|| {
        Error::Artifact(format!(
            "family '{}' has no client parameter split for cut {cut}",
            fam.name
        ))
    })
}

/// A full model's parameters in canonical order, as XLA literals.
pub struct ParamSet {
    pub literals: Vec<Literal>,
}

impl ParamSet {
    pub fn new(literals: Vec<Literal>) -> Self {
        ParamSet { literals }
    }

    pub fn len(&self) -> usize {
        self.literals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.literals.is_empty()
    }

    /// Split into (client prefix, server suffix) clones for the given cut.
    pub fn split(&self, fam: &FamilyManifest, cut: usize)
        -> Result<(Vec<Literal>, Vec<Literal>)> {
        let n = client_tensor_count(fam, cut)?;
        if n > self.literals.len() {
            return Err(Error::Artifact(format!(
                "cut {cut} wants {n} client tensors but the model has {}",
                self.literals.len()
            )));
        }
        Ok((
            self.literals[..n].to_vec(),
            self.literals[n..].to_vec(),
        ))
    }

    /// Recombine client + server parts into a full canonical list.
    pub fn join(client: &[Literal], server: &[Literal]) -> Vec<Literal> {
        let mut v = Vec::with_capacity(client.len() + server.len());
        v.extend(client.iter().cloned());
        v.extend(server.iter().cloned());
        v
    }
}

/// λ-weighted FedAvg over per-client parameter lists (same shapes).
/// Used by SFL every round and by the evaluation-model average for
/// PSL/EPSL (whose client models never synchronize during training).
pub fn fedavg(clients: &[Vec<Literal>], weights: &[f32],
              fam: &FamilyManifest, cut: usize) -> Result<Vec<Literal>> {
    if clients.len() != weights.len() {
        return Err(Error::Data(format!(
            "fedavg over {} client(s) with {} weight(s)",
            clients.len(),
            weights.len()
        )));
    }
    let n_tensors = client_tensor_count(fam, cut)?;
    let mut out = Vec::with_capacity(n_tensors);
    for t in 0..n_tensors {
        let bufs: Vec<Vec<f32>> = clients
            .iter()
            .map(|c| {
                c.get(t).ok_or_else(|| {
                    Error::Data(format!(
                        "fedavg: client model missing tensor {t} \
                         (have {})",
                        c.len()
                    ))
                })
                .and_then(to_f32_vec)
            })
            .collect::<Result<_>>()?;
        let avg = weighted_average(&bufs, weights);
        let shape = &fam.params[t].1;
        out.push(literal_f32(shape, &avg)?);
    }
    Ok(out)
}

/// Copy a literal parameter list to host `f32` buffers (checkpointing).
pub fn host_params(lits: &[Literal]) -> Result<Vec<Vec<f32>>> {
    lits.iter().map(to_f32_vec).collect()
}

/// Rebuild a literal parameter list from host buffers against the
/// manifest's `(name, shape)` slice — the exact inverse of
/// [`host_params`], validated element count by element count so a stale
/// or cross-family checkpoint surfaces as a typed error.
pub fn literal_params(host: &[Vec<f32>], shapes: &[(String, Vec<usize>)])
    -> Result<Vec<Literal>> {
    if host.len() != shapes.len() {
        return Err(Error::Fault(format!(
            "checkpoint carries {} tensor(s) but the model expects {}",
            host.len(),
            shapes.len()
        )));
    }
    host.iter()
        .zip(shapes)
        .map(|(buf, (name, shape))| {
            let want: usize = shape.iter().product();
            if buf.len() != want {
                return Err(Error::Fault(format!(
                    "checkpoint tensor '{name}' has {} element(s), \
                     expected {want}",
                    buf.len()
                )));
            }
            literal_f32(shape, buf)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The native manifest shares the exact shape contract with the AOT
    /// export, so these tests no longer skip on artifact-less checkouts.
    fn fam() -> FamilyManifest {
        crate::runtime::native::manifest()
            .family("mnist")
            .unwrap()
            .clone()
    }

    fn full_params(fam: &FamilyManifest) -> Vec<Literal> {
        fam.params
            .iter()
            .map(|(_, s)| {
                let n: usize = s.iter().product();
                literal_f32(s, &vec![1.0; n]).unwrap()
            })
            .collect()
    }

    #[test]
    fn split_join_roundtrip() {
        let fam = fam();
        let ps = ParamSet::new(full_params(&fam));
        let (c, s) = ps.split(&fam, 2).unwrap();
        assert_eq!(c.len(), 6);
        assert_eq!(c.len() + s.len(), fam.params.len());
        let joined = ParamSet::join(&c, &s);
        assert_eq!(joined.len(), fam.params.len());
    }

    #[test]
    fn split_unknown_cut_is_an_error() {
        let fam = fam();
        let ps = ParamSet::new(full_params(&fam));
        let e = ps.split(&fam, 99).unwrap_err();
        assert!(e.to_string().contains("cut 99"), "{e}");
    }

    #[test]
    fn fedavg_weighted() {
        let fam = fam();
        let cut = 2;
        let n = fam.client_param_count[&cut];
        let mk = |v: f32| -> Vec<Literal> {
            fam.params[..n]
                .iter()
                .map(|(_, s)| {
                    let len: usize = s.iter().product();
                    literal_f32(s, &vec![v; len]).unwrap()
                })
                .collect()
        };
        let avg =
            fedavg(&[mk(1.0), mk(3.0)], &[0.25, 0.75], &fam, cut).unwrap();
        let v = to_f32_vec(&avg[0]).unwrap();
        assert!(v.iter().all(|&x| (x - 2.5).abs() < 1e-6));
        // Mismatched weight vector is a typed error, not a panic.
        assert!(fedavg(&[mk(1.0)], &[0.5, 0.5], &fam, cut).is_err());
    }

    #[test]
    fn host_literal_roundtrip_is_bit_exact() {
        let fam = fam();
        let lits = full_params(&fam);
        let host = host_params(&lits).unwrap();
        let back = literal_params(&host, &fam.params).unwrap();
        let host2 = host_params(&back).unwrap();
        assert_eq!(host.len(), host2.len());
        for (a, b) in host.iter().zip(&host2) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn literal_params_validates_shape_contract() {
        let fam = fam();
        let lits = full_params(&fam);
        let mut host = host_params(&lits).unwrap();
        // Wrong tensor count.
        let e = literal_params(&host[..2], &fam.params).unwrap_err();
        assert!(e.to_string().contains("tensor"), "{e}");
        // Wrong element count in one tensor.
        host[0].push(0.0);
        let e = literal_params(&host, &fam.params).unwrap_err();
        assert!(e.to_string().contains("element"), "{e}");
    }
}
