//! The EPSL training coordinator (L3): Algorithm 1 end-to-end.
//!
//! This is the system that actually *runs* split learning: per round it
//! drives client-side forward passes, smashed-data concatenation, the
//! EPSL server step (with the φ-aggregation Pallas kernel inside the AOT
//! graph), gradient routing (broadcast vs unicast), and client-side
//! updates — all through the `runtime::Backend` seam: PJRT-compiled
//! artifacts when they exist, the pure-Rust native backend otherwise.
//! Python never runs at training time either way.
//!
//! Latency semantics: this testbed's CPU is not five heterogeneous edge
//! devices behind a 28 GHz FDMA uplink, so per-round *latency* is accounted
//! by the paper's §V model over the simulated deployment (exactly as the
//! paper's own evaluation does), while *learning dynamics* (loss/accuracy)
//! come from the real computation. Wall-clock per round is recorded
//! separately for the §Perf benchmarks.
//!
//! Frameworks ([`frameworks`]): EPSL (any φ), PSL (φ=0), SFL (PSL +
//! client-model FedAvg each round), vanilla SL (sequential with model
//! relay), EPSL-PT (φ=1 → φ=0 switch). Every framework's round is a
//! declarative [`RoundPlan`] (turn scheduling × φ × model sync) executed
//! by the single engine in [`rounds`]; round-invariant state and the §V
//! latency accounting (timeline barrier/pipelined modes) live in
//! [`session`]; [`driver`] is the thin entry point.

pub mod checkpoint;
pub mod driver;
pub mod params;
pub mod rounds;
pub mod session;

pub use checkpoint::{run_fingerprint, Checkpoint};
pub use driver::{
    resume, resume_with_state, train, train_with_state, CutMode,
    TrainState, TrainerOptions,
};
pub use rounds::{RoundPlan, SyncStyle, TurnStyle};

use crate::error::{Error, Result};
use crate::latency::frameworks::Framework;

/// Cut-layer mapping: SplitNet stage boundaries → the paper's ResNet-18
/// Table-IV layer indices, so the latency model runs on the paper's own
/// profile while training runs the reproduction-scale network.
///
/// stage 1 ↔ CONV1 (layer 1), stage 2 ↔ end of stage-1 convs (layer 4),
/// stage 3 ↔ end of stage-2 blocks (layer 10), stage 4 ↔ CONV12 (layer 16).
pub fn resnet18_cut_for_splitnet(cut: usize) -> usize {
    try_resnet18_cut_for_splitnet(cut)
        // audit:allow(R1, "documented panicking convenience wrapper; hot paths use the try_ form below")
        .unwrap_or_else(|e| panic!("{e}"))
}

/// Fallible form of [`resnet18_cut_for_splitnet`] for hot paths that
/// report a typed error instead of panicking on a bad cut.
pub fn try_resnet18_cut_for_splitnet(cut: usize) -> Result<usize> {
    match cut {
        1 => Ok(1),
        2 => Ok(4),
        3 => Ok(10),
        4 => Ok(16),
        other => Err(Error::Config(format!(
            "splitnet cut {other} out of 1..=4"
        ))),
    }
}

/// Inverse of [`try_resnet18_cut_for_splitnet`]: map a paper Table-IV
/// layer index back to the SplitNet stage boundary it corresponds to.
/// Only the four mapped indices are valid.
pub fn try_splitnet_cut_for_resnet18(cut: usize) -> Result<usize> {
    match cut {
        1 => Ok(1),
        4 => Ok(2),
        10 => Ok(3),
        16 => Ok(4),
        other => Err(Error::Config(format!(
            "resnet18 cut {other} has no splitnet stage (expected one of \
             1/4/10/16)"
        ))),
    }
}

/// φ for a framework at a given round (EPSL-PT switches at `pt_switch`).
pub fn phi_at_round(fw: Framework, round: usize, pt_switch: usize) -> f64 {
    match fw {
        Framework::EpslPt { .. } => {
            if round < pt_switch {
                1.0
            } else {
                0.0
            }
        }
        other => other.phi(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cut_mapping_monotone() {
        let cuts: Vec<usize> =
            (1..=4).map(resnet18_cut_for_splitnet).collect();
        assert_eq!(cuts, vec![1, 4, 10, 16]);
        assert!(cuts.windows(2).all(|w| w[0] < w[1]));
        let e = try_resnet18_cut_for_splitnet(5).unwrap_err();
        assert!(e.to_string().contains("out of 1..=4"), "{e}");
    }

    #[test]
    fn cut_mapping_roundtrips() {
        for s in 1..=4 {
            let r = resnet18_cut_for_splitnet(s);
            assert_eq!(try_splitnet_cut_for_resnet18(r).unwrap(), s);
        }
        let e = try_splitnet_cut_for_resnet18(7).unwrap_err();
        assert!(e.to_string().contains("no splitnet stage"), "{e}");
    }

    #[test]
    fn pt_phi_switches() {
        let fw = Framework::EpslPt { early: true };
        assert_eq!(phi_at_round(fw, 0, 10), 1.0);
        assert_eq!(phi_at_round(fw, 9, 10), 1.0);
        assert_eq!(phi_at_round(fw, 10, 10), 0.0);
        assert_eq!(phi_at_round(Framework::Epsl { phi: 0.5 }, 3, 10), 0.5);
        assert_eq!(phi_at_round(Framework::Psl, 0, 10), 0.0);
    }
}
