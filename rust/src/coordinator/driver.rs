//! Framework training drivers: EPSL / PSL / SFL / vanilla SL / EPSL-PT.
//!
//! One entry point, [`train`], runs Algorithm 1 for the chosen framework
//! over the AOT artifacts and returns per-round [`RunMetrics`] (loss,
//! train/test accuracy, the §V simulated latency, and wall-clock).

use std::time::Instant;

use xla::Literal;

use crate::channel::{ChannelRealization, Deployment};
use crate::config::{Config, NetworkConfig};
use crate::data::partition::{iid, lambda_weights, non_iid_two_class};
use crate::data::synth::{train_test, SynthSpec};
use crate::data::{Dataset, Shard};
use crate::error::{Error, Result};
use crate::latency::frameworks::{round_latency, Framework};
use crate::latency::LatencyInputs;
use crate::metrics::{RoundRecord, RunMetrics};
use crate::optim::{bcd, Decision, Problem};
use crate::profile::resnet18;
use crate::runtime::artifact::{FamilyManifest, Manifest};
use crate::runtime::tensor::{literal_f32, literal_i32, literal_u32,
                             scalar_f32, to_f32_vec};
use crate::runtime::Backend;
use crate::scenario::{self, DynamicChannel, Scenario};
use crate::util::par;
use crate::util::rng::Rng;

use super::params::{fedavg, ParamSet};
use super::{phi_at_round, resnet18_cut_for_splitnet};

/// Options for one training run.
#[derive(Debug, Clone)]
pub struct TrainerOptions {
    pub family: String,
    pub framework: Framework,
    pub n_clients: usize,
    /// SplitNet cut (1..=4).
    pub cut: usize,
    pub iid: bool,
    pub dataset_size: usize,
    pub test_size: usize,
    pub rounds: usize,
    pub eval_every: usize,
    pub eta_c: f32,
    pub eta_s: f32,
    pub seed: u64,
    /// EPSL-PT: round at which φ switches 1 → 0.
    pub pt_switch: usize,
    /// Run the BCD resource optimizer for the latency accounting
    /// (otherwise a greedy + uniform-power decision is used).
    pub optimize_resources: bool,
    /// Opt-in dynamic-channel mode: the §V latency accounting tracks a
    /// per-round [`Scenario`] (block fading, LoS flips, compute jitter,
    /// churn) under the given re-optimization policy, instead of one
    /// frozen averaged draw. The scenario spans `rounds` rounds.
    pub dynamic_channel: Option<DynamicChannel>,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            family: "mnist".into(),
            framework: Framework::Epsl { phi: 0.5 },
            n_clients: 5,
            cut: 2,
            iid: true,
            dataset_size: 2000,
            test_size: 512,
            rounds: 100,
            eval_every: 5,
            eta_c: 0.08,
            eta_s: 0.08,
            seed: 2023,
            pt_switch: 50,
            optimize_resources: false,
            dynamic_channel: None,
        }
    }
}

/// Everything fixed across rounds.
struct Session<'a> {
    rt: &'a dyn Backend,
    fam: &'a FamilyManifest,
    opts: &'a TrainerOptions,
    train_set: Dataset,
    test_set: Dataset,
    shards: Vec<Shard>,
    lam: Vec<f32>,
    /// Per-round simulated latency per φ value (resnet18 profile).
    sim_latency: SimLatency,
    rng: Rng,
    /// Round-invariant literals, hoisted out of the hot loop (§Perf).
    lam_lit: Literal,
    lr_s_lit: Literal,
    lr_c_lit: Literal,
    /// (φ bits) → (mask host vector, mask literal).
    mask_cache: std::collections::HashMap<u64, (Vec<f32>, Literal)>,
}

/// One round's link state for the §V model.
struct SimRound {
    f_clients: Vec<f64>,
    uplink: Vec<f64>,
    downlink: Vec<f64>,
    broadcast: f64,
}

/// Pre-computed stage-latency inputs for the §V model: one [`SimRound`]
/// per training round under a dynamic-channel scenario, a single frozen
/// entry otherwise.
struct SimLatency {
    rounds: Vec<SimRound>,
    cut: usize,
    batch: usize,
    f_server: f64,
    kappa_server: f64,
    kappa_client: f64,
}

impl SimLatency {
    fn round_seconds(&self, round: usize, fw: Framework, phi: f64) -> f64 {
        // Cached profile: this runs once per training round, and the old
        // per-call Table IV rebuild dominated the simulated-latency cost.
        let profile = resnet18::profile_static();
        let r = &self.rounds[round.min(self.rounds.len() - 1)];
        let inp = LatencyInputs {
            profile,
            cut: self.cut,
            batch: self.batch,
            phi,
            f_server: self.f_server,
            kappa_server: self.kappa_server,
            kappa_client: self.kappa_client,
            f_clients: &r.f_clients,
            uplink: &r.uplink,
            downlink: &r.downlink,
            broadcast: r.broadcast,
        };
        // For EPSL-PT the effective framework at this round is EPSL{phi}.
        let fw_eff = match fw {
            Framework::EpslPt { .. } => Framework::Epsl { phi },
            other => other,
        };
        round_latency(fw_eff, &inp).round_total()
    }
}

fn build_sim_latency(cfg: &Config, opts: &TrainerOptions, rng: &mut Rng)
    -> Result<SimLatency> {
    let net = cfg.net.clone().with_clients(opts.n_clients);
    let profile = resnet18::profile_static();
    let cut = resnet18_cut_for_splitnet(opts.cut);
    if let Some(dc) = &opts.dynamic_channel {
        return build_dynamic_sim_latency(cfg, opts, &net, cut, dc, rng);
    }
    let dep = Deployment::generate(&net, rng);
    let ch = ChannelRealization::average(&dep);
    let prob = Problem {
        cfg: &net,
        profile,
        dep: &dep,
        ch: &ch,
        batch: cfg.train.batch,
        phi: opts.framework.phi(),
    };
    let decision: Decision = if opts.optimize_resources {
        bcd::solve(&prob, bcd::BcdOptions::default())?.decision
    } else {
        // One shared allocation for both the PSD plan and the decision
        // (the pre-fix code ran rss_allocation twice).
        crate::optim::baselines::uniform_decision(&prob, cut)
    };
    let (up, dn, bc) = prob.rates(&decision);
    Ok(SimLatency {
        rounds: vec![SimRound {
            f_clients: dep.f_clients().to_vec(),
            uplink: up,
            downlink: dn,
            broadcast: bc,
        }],
        cut,
        batch: cfg.train.batch,
        f_server: net.f_server,
        kappa_server: net.kappa_server,
        kappa_client: net.kappa_client,
    })
}

/// Dynamic-channel mode: expand the scenario from the session RNG stream
/// and track per-round realized rates. With `optimize_resources` the
/// re-optimization policy drives BCD re-solves (blocks fan across cores);
/// without it a fixed uniform-power decision at the training cut rides
/// the varying channel (churn then has no valid meaning — rejected).
fn build_dynamic_sim_latency(cfg: &Config, opts: &TrainerOptions,
                             net: &NetworkConfig, cut: usize,
                             dc: &DynamicChannel, rng: &mut Rng)
    -> Result<SimLatency> {
    let profile = resnet18::profile_static();
    let mut spec = dc.spec.clone();
    spec.rounds = opts.rounds; // the scenario spans the training run
    let roster = Deployment::generate(net, rng);
    let sc = Scenario::from_deployment(net.clone(), roster, spec, rng)?;
    let rounds: Vec<SimRound> = if opts.optimize_resources {
        let (outcome, rates) = scenario::run_policy_with_rates(
            &sc,
            profile,
            &scenario::RunOptions {
                policy: dc.policy,
                bcd: bcd::BcdOptions::default(),
                batch: cfg.train.batch,
                phi: opts.framework.phi(),
                threads: par::max_threads(),
            },
        );
        println!(
            "dynamic channel: {} optimizer solve(s) over {} rounds \
             (policy {})",
            outcome.n_solves,
            sc.n_rounds(),
            dc.policy.name()
        );
        // Latency accounting always prices the *training* cut (same
        // semantics as the static --optimize path); when a re-solve picked
        // a different cut its rates were tuned for that cut's payloads —
        // surface the mismatch instead of silently mixing.
        let cut_mismatch = rates
            .iter()
            .flatten()
            .filter(|rr| rr.cut != cut)
            .count();
        if cut_mismatch > 0 {
            println!(
                "dynamic channel: optimizer preferred a different cut \
                 layer in {cut_mismatch} round(s); accounting keeps the \
                 training cut {cut}"
            );
        }
        rates
            .into_iter()
            .enumerate()
            .map(|(r, rr)| {
                rr.ok_or_else(|| {
                    Error::Optim(format!(
                        "dynamic channel: resource solve failed at round {r}"
                    ))
                })
            })
            .collect::<Result<Vec<scenario::RoundRates>>>()?
            .into_iter()
            .map(|rr| SimRound {
                f_clients: rr.f_clients,
                uplink: rr.uplink,
                downlink: rr.downlink,
                broadcast: rr.broadcast,
            })
            .collect()
    } else {
        if !matches!(dc.policy, scenario::ReoptPolicy::Never) {
            return Err(Error::Config(format!(
                "dynamic channel: re-optimization policy '{}' requires \
                 optimize_resources (without it a fixed uniform-power \
                 decision rides the channel; pass --optimize, or use \
                 --reopt never)",
                dc.policy.name()
            )));
        }
        if sc.rounds.iter().any(|r| r.membership_changed) {
            return Err(Error::Config(
                "dynamic channel with churn requires optimize_resources: a \
                 fixed uniform decision cannot follow membership changes"
                    .into(),
            ));
        }
        let avg = ChannelRealization::average(&sc.roster);
        let base = Problem {
            cfg: net,
            profile,
            dep: &sc.roster,
            ch: &avg,
            batch: cfg.train.batch,
            phi: opts.framework.phi(),
        };
        let d = crate::optim::baselines::uniform_decision(&base, cut);
        sc.rounds
            .iter()
            .map(|round| {
                let prob = Problem {
                    cfg: net,
                    profile,
                    dep: &round.dep,
                    ch: &round.ch,
                    batch: cfg.train.batch,
                    phi: opts.framework.phi(),
                };
                let (up, dn, bc) = prob.rates(&d);
                SimRound {
                    f_clients: round.dep.f_clients().to_vec(),
                    uplink: up,
                    downlink: dn,
                    broadcast: bc,
                }
            })
            .collect()
    };
    Ok(SimLatency {
        rounds,
        cut,
        batch: cfg.train.batch,
        f_server: net.f_server,
        kappa_server: net.kappa_server,
        kappa_client: net.kappa_client,
    })
}

/// Fail fast when the fixed-shape eval artifact can never see one full
/// chunk: every chunk would hit the ragged-tail `break` in
/// [`Session::evaluate`] and the accuracy column would be silently
/// all-NaN.
fn check_eval_batch(test_size: usize, eval_batch: usize) -> Result<()> {
    if test_size < eval_batch {
        return Err(Error::Config(format!(
            "test_size {test_size} < eval_batch {eval_batch}: evaluation \
             would drop every chunk and report NaN accuracy — raise \
             test_size to at least the artifact eval batch"
        )));
    }
    Ok(())
}

/// Build the aggregation mask for ⌈φb⌉ slots.
fn mask_vec(phi: f64, b: usize) -> Vec<f32> {
    let m = (phi * b as f64).ceil() as usize;
    (0..b).map(|j| if j < m { 1.0 } else { 0.0 }).collect()
}

impl<'a> Session<'a> {
    /// Cached aggregation mask for this φ (host copy + literal).
    fn mask_for(&mut self, phi: f64) -> Result<(Vec<f32>, Literal)> {
        let key = phi.to_bits();
        if let Some((v, l)) = self.mask_cache.get(&key) {
            return Ok((v.clone(), l.clone()));
        }
        let v = mask_vec(phi, self.fam.batch);
        let l = literal_f32(&[self.fam.batch], &v)?;
        self.mask_cache.insert(key, (v.clone(), l.clone()));
        Ok((v, l))
    }

    fn batch_literals(&mut self, client: usize)
        -> Result<(Literal, Vec<f32>, Vec<i32>)> {
        let b = self.fam.batch;
        let idx = self.shards[client].sample_batch(b, &mut self.rng);
        let (imgs, labels) = self.train_set.gather(&idx);
        let x = literal_f32(
            &[b, self.fam.img, self.fam.img, self.fam.channels],
            &imgs,
        )?;
        Ok((x, imgs, labels))
    }

    /// One parallel round (EPSL / PSL / SFL): returns (loss, train_acc).
    #[allow(clippy::too_many_arguments)]
    fn parallel_round(&mut self, client_params: &mut [Vec<Literal>],
                      server_params: &mut Vec<Literal>, phi: f64)
        -> Result<(f64, f64)> {
        let c = self.opts.n_clients;
        let b = self.fam.batch;
        let cut = self.opts.cut;
        let fam = self.fam;
        let smash = &fam.smashed_shape[&cut];
        let smash_len: usize = smash.iter().product();

        // Stage 1-2: client FP + uplink. Batches are sampled serially
        // (the session RNG stream stays deterministic), then the C
        // independent forward passes fan across cores via call_many
        // (order-preserving, so bit-identical to the old serial loop).
        let cf_entry = fam.client_fwd.get(&cut).ok_or_else(|| {
            Error::Artifact(format!("no client_fwd for cut {cut}"))
        })?;
        let mut smashed_host = Vec::with_capacity(c * b * smash_len);
        let mut labels_host: Vec<i32> = Vec::with_capacity(c * b);
        let mut xs = Vec::with_capacity(c);
        let mut fwd_batches: Vec<Vec<Literal>> = Vec::with_capacity(c);
        for i in 0..c {
            let (x, _imgs, labels) = self.batch_literals(i)?;
            let mut inputs: Vec<Literal> = client_params[i].to_vec();
            inputs.push(x.clone());
            fwd_batches.push(inputs);
            labels_host.extend(labels);
            xs.push(x);
        }
        for out in self.rt.call_many(cf_entry, &fwd_batches)? {
            smashed_host.extend(to_f32_vec(&out[0])?);
        }

        // Stage 3-4: server FP + EPSL BP.
        let st_entry = fam.server_train_entry(cut, c)?;
        let mut smash_shape = vec![c, b];
        smash_shape.extend(smash.iter());
        let (mask, mask_lit) = self.mask_for(phi)?;
        let mut inputs: Vec<Literal> = server_params.to_vec();
        inputs.push(literal_f32(&smash_shape, &smashed_host)?);
        inputs.push(literal_i32(&[c, b], &labels_host)?);
        inputs.push(self.lam_lit.clone());
        inputs.push(mask_lit);
        inputs.push(self.lr_s_lit.clone());
        let mut out = self.rt.call(st_entry, &inputs)?;
        let n_sp = server_params.len();
        let ncorr = scalar_f32(&out[n_sp + 3])? as f64;
        let loss = scalar_f32(&out[n_sp + 2])? as f64;
        let cut_unagg = to_f32_vec(&out[n_sp + 1])?;
        let cut_agg = to_f32_vec(&out[n_sp])?;
        out.truncate(n_sp);
        *server_params = out;

        // Stage 5-7: gradient routing + client BP (fanned across cores —
        // each client's step is independent).
        let cs_entry = fam.client_step.get(&cut).ok_or_else(|| {
            Error::Artifact(format!("no client_step for cut {cut}"))
        })?;
        let mut g_cut = vec![0.0f32; b * smash_len];
        let mut g_shape = vec![b];
        g_shape.extend(smash.iter());
        let mut step_batches: Vec<Vec<Literal>> = Vec::with_capacity(c);
        for (i, x) in xs.into_iter().enumerate() {
            for j in 0..b {
                let dst = &mut g_cut[j * smash_len..(j + 1) * smash_len];
                if mask[j] > 0.5 {
                    // broadcast payload (identical for every client)
                    dst.copy_from_slice(
                        &cut_agg[j * smash_len..(j + 1) * smash_len],
                    );
                } else {
                    // unicast payload
                    let base = (i * b + j) * smash_len;
                    dst.copy_from_slice(
                        &cut_unagg[base..base + smash_len],
                    );
                }
            }
            let mut inputs: Vec<Literal> = client_params[i].to_vec();
            inputs.push(x);
            inputs.push(literal_f32(&g_shape, &g_cut)?);
            inputs.push(self.lr_c_lit.clone());
            step_batches.push(inputs);
        }
        for (i, out) in
            self.rt.call_many(cs_entry, &step_batches)?.into_iter().enumerate()
        {
            client_params[i] = out;
        }

        // SFL: client-side model FedAvg (the model exchange).
        if matches!(self.opts.framework, Framework::Sfl) {
            let avg = fedavg(client_params, &self.lam, fam, cut)?;
            for cp in client_params.iter_mut() {
                *cp = avg.clone();
            }
        }
        Ok((loss, ncorr / (c * b) as f64))
    }

    /// One vanilla-SL "round": a sequential pass over all clients with a
    /// single relayed client-side model.
    fn vanilla_round(&mut self, shared_client: &mut Vec<Literal>,
                     server_params: &mut Vec<Literal>)
        -> Result<(f64, f64)> {
        let c = self.opts.n_clients;
        let b = self.fam.batch;
        let cut = self.opts.cut;
        let fam = self.fam;
        let smash = &fam.smashed_shape[&cut];
        let smash_len: usize = smash.iter().product();
        // Same descriptive error path as parallel_round (these were
        // unwraps that panicked on a manifest missing the cut).
        let cf_entry = fam.client_fwd.get(&cut).ok_or_else(|| {
            Error::Artifact(format!("no client_fwd for cut {cut}"))
        })?;
        let st_entry = fam.server_train_entry(cut, 1)?;
        let cs_entry = fam.client_step.get(&cut).ok_or_else(|| {
            Error::Artifact(format!("no client_step for cut {cut}"))
        })?;
        let (_mask, mask_lit) = self.mask_for(0.0)?;
        let lam1 = literal_f32(&[1], &[1.0])?;
        let mut loss_sum = 0.0;
        let mut ncorr_sum = 0.0;
        for i in 0..c {
            let (x, _imgs, labels) = self.batch_literals(i)?;
            let mut inputs: Vec<Literal> = shared_client.to_vec();
            inputs.push(x.clone());
            let smashed = self.rt.call(cf_entry, &inputs)?;
            let mut smash_shape = vec![1, b];
            smash_shape.extend(smash.iter());
            let smashed_host = to_f32_vec(&smashed[0])?;
            let mut inputs: Vec<Literal> = server_params.to_vec();
            inputs.push(literal_f32(&smash_shape, &smashed_host)?);
            inputs.push(literal_i32(&[1, b], &labels)?);
            inputs.push(lam1.clone());
            inputs.push(mask_lit.clone());
            inputs.push(self.lr_s_lit.clone());
            let mut out = self.rt.call(st_entry, &inputs)?;
            let n_sp = server_params.len();
            ncorr_sum += scalar_f32(&out[n_sp + 3])? as f64;
            loss_sum += scalar_f32(&out[n_sp + 2])? as f64;
            let cut_unagg = to_f32_vec(&out[n_sp + 1])?;
            out.truncate(n_sp);
            *server_params = out;
            // all-unicast gradients for this client
            let mut g_shape = vec![b];
            g_shape.extend(smash.iter());
            let g = &cut_unagg[..b * smash_len];
            let mut inputs: Vec<Literal> = shared_client.to_vec();
            inputs.push(x);
            inputs.push(literal_f32(&g_shape, g)?);
            inputs.push(self.lr_c_lit.clone());
            *shared_client = self.rt.call(cs_entry, &inputs)?;
        }
        Ok((loss_sum / c as f64, ncorr_sum / (c * b) as f64))
    }

    /// Test accuracy of the λ-averaged model (full test set, chunked).
    fn evaluate(&mut self, client_params: &[Vec<Literal>],
                server_params: &[Literal]) -> Result<f64> {
        let fam = self.fam;
        let cut = self.opts.cut;
        let avg_client = if client_params.len() == 1 {
            client_params[0].clone()
        } else {
            fedavg(client_params, &self.lam, fam, cut)?
        };
        let full = ParamSet::join(&avg_client, server_params);
        let eb = fam.eval_batch;
        let mut correct = 0.0;
        let mut total = 0.0;
        let img_len = self.test_set.image_len();
        let n_chunks = self.test_set.n / eb;
        for chunk in 0..n_chunks.max(1) {
            let lo = chunk * eb;
            let hi = ((chunk + 1) * eb).min(self.test_set.n);
            if hi - lo < eb {
                break; // artifacts are fixed-shape; drop the ragged tail
            }
            let idx: Vec<usize> = (lo..hi).collect();
            let (imgs, labels) = self.test_set.gather(&idx);
            debug_assert_eq!(imgs.len(), eb * img_len);
            let mut inputs: Vec<Literal> = full.clone();
            inputs.push(literal_f32(
                &[eb, fam.img, fam.img, fam.channels],
                &imgs,
            )?);
            inputs.push(literal_i32(&[eb], &labels)?);
            let out = self.rt.call(&fam.eval, &inputs)?;
            correct += scalar_f32(&out[1])? as f64;
            total += eb as f64;
        }
        if total == 0.0 {
            // train() rejects this up front (check_eval_batch); kept as a
            // defensive guard against silently reporting NaN accuracy.
            return Err(Error::Data(format!(
                "evaluate: test set of {} samples yields no full \
                 eval chunk (eval_batch {eb})",
                self.test_set.n
            )));
        }
        Ok(correct / total)
    }
}

/// Final model state of a run (exposed for tests and checkpointing-style
/// consumers; the driver itself only needs it internally).
pub struct TrainState {
    /// Per-client client-side parameters (single entry for vanilla SL).
    pub client_params: Vec<Vec<Literal>>,
    pub server_params: Vec<Literal>,
}

/// Run one full training experiment.
pub fn train(rt: &dyn Backend, manifest: &Manifest, cfg: &Config,
             opts: &TrainerOptions) -> Result<RunMetrics> {
    train_with_state(rt, manifest, cfg, opts).map(|(m, _)| m)
}

/// [`train`], also returning the final parameter state.
pub fn train_with_state(rt: &dyn Backend, manifest: &Manifest, cfg: &Config,
                        opts: &TrainerOptions)
    -> Result<(RunMetrics, TrainState)> {
    let fam = manifest.family(&opts.family)?;
    let st_c = if matches!(opts.framework, Framework::VanillaSl) {
        1
    } else {
        opts.n_clients
    };
    // Fail fast if the needed artifact is missing, or if evaluation could
    // never see a full chunk (all-NaN accuracy otherwise).
    fam.server_train_entry(opts.cut, st_c)?;
    check_eval_batch(opts.test_size, fam.eval_batch)?;

    let mut rng = Rng::new(opts.seed);
    // Data.
    let spec = SynthSpec::for_family(&opts.family, opts.dataset_size);
    let (train_set, test_set) =
        train_test(&spec, opts.test_size, opts.seed ^ 0xDA7A);
    let shards = if opts.iid {
        iid(&train_set, opts.n_clients, &mut rng)?
    } else {
        non_iid_two_class(&train_set, opts.n_clients, &mut rng)?
    };
    let lam = lambda_weights(&shards);

    // Latency model over a simulated deployment.
    let sim_latency = build_sim_latency(cfg, opts, &mut rng)?;

    // Model init.
    let seed_lit = literal_u32(&[2], &[0, opts.seed as u32])?;
    let full = ParamSet::new(rt.call(&fam.init, &[seed_lit])?);
    let (client0, mut server_params) = full.split(fam, opts.cut);
    let mut client_params: Vec<Vec<Literal>> = if matches!(
        opts.framework,
        Framework::VanillaSl
    ) {
        vec![client0]
    } else {
        (0..opts.n_clients).map(|_| client0.clone()).collect()
    };

    let lam_lit = literal_f32(&[lam.len()], &lam)?;
    let lr_s_lit = literal_f32(&[], &[opts.eta_s])?;
    let lr_c_lit = literal_f32(&[], &[opts.eta_c])?;
    let mut session = Session {
        rt,
        fam,
        opts,
        train_set,
        test_set,
        shards,
        lam,
        sim_latency,
        rng,
        lam_lit,
        lr_s_lit,
        lr_c_lit,
        mask_cache: std::collections::HashMap::new(),
    };

    let mut metrics = RunMetrics::new(opts.framework.name());
    for round in 0..opts.rounds {
        let t0 = Instant::now();
        let phi = phi_at_round(opts.framework, round, opts.pt_switch);
        let (loss, train_acc) = match opts.framework {
            Framework::VanillaSl => session
                .vanilla_round(&mut client_params[0], &mut server_params)?,
            _ => session.parallel_round(
                &mut client_params,
                &mut server_params,
                phi,
            )?,
        };
        let test_acc = if round % opts.eval_every == opts.eval_every - 1
            || round + 1 == opts.rounds
        {
            session.evaluate(&client_params, &server_params)?
        } else {
            f64::NAN
        };
        let sim =
            session.sim_latency.round_seconds(round, opts.framework, phi);
        metrics.push(RoundRecord {
            round,
            loss,
            train_acc,
            test_acc,
            sim_latency: sim,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
    }
    Ok((metrics, TrainState { client_params, server_params }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::{self, NativeBackend};

    /// The smoke tests run for real on the native backend (no skipping):
    /// the training path is exercised on every `cargo test`.
    fn setup() -> (NativeBackend, Manifest, Config) {
        (NativeBackend::new(), native::manifest(), Config::new())
    }

    fn smoke_opts() -> TrainerOptions {
        TrainerOptions {
            n_clients: 2,
            rounds: 4,
            eval_every: 2,
            dataset_size: 400,
            test_size: 256,
            ..Default::default()
        }
    }

    #[test]
    fn epsl_smoke_two_clients() {
        let (rt, m, cfg) = setup();
        let run = train(&rt, &m, &cfg, &smoke_opts()).unwrap();
        assert_eq!(run.rounds.len(), 4);
        assert!(run.rounds.iter().all(|r| r.loss.is_finite()));
        assert!(run.rounds.iter().all(|r| r.sim_latency > 0.0));
        // at least one evaluation happened
        assert!(run.rounds.iter().any(|r| !r.test_acc.is_nan()));
    }

    #[test]
    fn vanilla_smoke() {
        let (rt, m, cfg) = setup();
        let opts = TrainerOptions {
            framework: Framework::VanillaSl,
            rounds: 2,
            ..smoke_opts()
        };
        let run = train(&rt, &m, &cfg, &opts).unwrap();
        assert_eq!(run.rounds.len(), 2);
        assert!(run.rounds[0].loss.is_finite());
    }

    #[test]
    fn sfl_keeps_clients_synchronized() {
        let (rt, m, cfg) = setup();
        let opts = TrainerOptions {
            framework: Framework::Sfl,
            rounds: 2,
            eval_every: 10,
            ..smoke_opts()
        };
        // The per-round FedAvg must leave every client with bit-identical
        // client-side parameters (previously only finiteness was checked).
        let (run, state) = train_with_state(&rt, &m, &cfg, &opts).unwrap();
        assert!(run.rounds.iter().all(|r| r.loss.is_finite()));
        assert_eq!(state.client_params.len(), 2);
        let reference: Vec<Vec<f32>> = state.client_params[0]
            .iter()
            .map(|l| to_f32_vec(l).unwrap())
            .collect();
        for (ci, cp) in state.client_params.iter().enumerate().skip(1) {
            for (t, lit) in cp.iter().enumerate() {
                assert_eq!(
                    to_f32_vec(lit).unwrap(),
                    reference[t],
                    "client {ci} tensor {t} diverged after SFL FedAvg"
                );
            }
        }
    }

    #[test]
    fn psl_clients_do_diverge() {
        // Control for the SFL assertion: without the model exchange the
        // client models must NOT be synchronized (distinct shards).
        let (rt, m, cfg) = setup();
        let opts = TrainerOptions {
            framework: Framework::Psl,
            rounds: 2,
            eval_every: 10,
            ..smoke_opts()
        };
        let (_, state) = train_with_state(&rt, &m, &cfg, &opts).unwrap();
        let a = to_f32_vec(&state.client_params[0][0]).unwrap();
        let b = to_f32_vec(&state.client_params[1][0]).unwrap();
        assert_ne!(a, b, "PSL clients unexpectedly synchronized");
    }

    #[test]
    fn missing_cut_is_an_error_not_a_panic() {
        // Both round shapes must fail with Error::Artifact when the
        // manifest has no entries for the requested cut (vanilla_round
        // used to unwrap and panic here). Each entry kind is removed
        // separately so both lookup sites stay covered — client_fwd is
        // checked first, so a combined removal would never reach the
        // client_step path.
        let (rt, _, cfg) = setup();
        for missing in ["client_fwd", "client_step"] {
            let mut m = native::manifest();
            let fam = m.families.get_mut("mnist").unwrap();
            match missing {
                "client_fwd" => fam.client_fwd.remove(&2),
                _ => fam.client_step.remove(&2),
            };
            for fw in [Framework::VanillaSl, Framework::Epsl { phi: 0.5 }] {
                let opts = TrainerOptions {
                    framework: fw,
                    rounds: 1,
                    ..smoke_opts()
                };
                let e = train(&rt, &m, &cfg, &opts).unwrap_err();
                assert!(
                    matches!(e, Error::Artifact(_)),
                    "{fw:?}/{missing}: unexpected error kind: {e}"
                );
                assert!(
                    e.to_string()
                        .contains(&format!("no {missing} for cut 2")),
                    "{fw:?}/{missing}: {e}"
                );
            }
        }
    }

    #[test]
    fn native_run_is_seed_deterministic_and_thread_invariant() {
        // Acceptance criterion: same seed ⇒ bit-identical run, for any
        // thread budget.
        let (_, m, cfg) = setup();
        let opts = smoke_opts();
        let serial = NativeBackend::with_threads(1);
        let fanned = NativeBackend::with_threads(7);
        let a = train(&serial, &m, &cfg, &opts).unwrap();
        let b = train(&fanned, &m, &cfg, &opts).unwrap();
        let c = train(&fanned, &m, &cfg, &opts).unwrap();
        for ((ra, rb), rc) in
            a.rounds.iter().zip(&b.rounds).zip(&c.rounds)
        {
            assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
            assert_eq!(ra.train_acc.to_bits(), rb.train_acc.to_bits());
            assert_eq!(rb.loss.to_bits(), rc.loss.to_bits());
            if !ra.test_acc.is_nan() || !rb.test_acc.is_nan() {
                assert_eq!(ra.test_acc.to_bits(), rb.test_acc.to_bits());
            }
        }
    }

    #[test]
    fn mask_vec_counts() {
        assert_eq!(mask_vec(0.5, 32).iter().sum::<f32>(), 16.0);
        assert_eq!(mask_vec(0.0, 32).iter().sum::<f32>(), 0.0);
        assert_eq!(mask_vec(1.0, 32).iter().sum::<f32>(), 32.0);
        assert_eq!(mask_vec(0.01, 32).iter().sum::<f32>(), 1.0);
    }

    #[test]
    fn small_test_set_fails_fast() {
        // Pre-fix, test_size < eval_batch made every eval chunk hit the
        // ragged-tail break and the run reported an all-NaN accuracy
        // column; now it is rejected up front with a descriptive error.
        assert!(check_eval_batch(100, 256).is_err());
        assert!(check_eval_batch(256, 256).is_ok());
        assert!(check_eval_batch(300, 256).is_ok());
        let e = check_eval_batch(10, 64).unwrap_err();
        assert!(e.to_string().contains("NaN"), "{e}");
        assert!(e.to_string().contains("eval_batch 64"), "{e}");
    }

    #[test]
    fn sim_latency_static_is_single_frozen_entry() {
        let cfg = Config::new();
        let opts = TrainerOptions::default();
        let mut rng = Rng::new(1);
        let s = build_sim_latency(&cfg, &opts, &mut rng).unwrap();
        assert_eq!(s.rounds.len(), 1);
        let t = s.round_seconds(0, opts.framework, 0.5);
        assert!(t > 0.0);
        // Any round index maps onto the frozen entry.
        assert_eq!(
            t.to_bits(),
            s.round_seconds(99, opts.framework, 0.5).to_bits()
        );
    }

    #[test]
    fn sim_latency_static_decision_bit_identical_to_prefix_construction() {
        // Regression guard for the single-allocation fix: the frozen-draw
        // rates must match the pre-fix double-rss_allocation construction
        // bit for bit (same RNG stream, same decision).
        let cfg = Config::new();
        let opts = TrainerOptions::default();
        let mut rng = Rng::new(3);
        let s = build_sim_latency(&cfg, &opts, &mut rng).unwrap();
        let mut rng = Rng::new(3);
        let net = cfg.net.clone().with_clients(opts.n_clients);
        let dep = Deployment::generate(&net, &mut rng);
        let ch = ChannelRealization::average(&dep);
        let profile = resnet18::profile_static();
        let prob = Problem {
            cfg: &net,
            profile,
            dep: &dep,
            ch: &ch,
            batch: cfg.train.batch,
            phi: opts.framework.phi(),
        };
        // The pre-fix construction: two independent rss_allocation calls.
        let psd = crate::optim::baselines::uniform_power(
            &prob,
            &crate::optim::baselines::rss_allocation(&prob),
        );
        let alloc = crate::optim::baselines::rss_allocation(&prob);
        let legacy = Decision {
            alloc,
            psd_dbm_hz: psd,
            cut: resnet18_cut_for_splitnet(opts.cut),
        };
        let (up, dn, bc) = prob.rates(&legacy);
        assert_eq!(s.rounds[0].uplink, up);
        assert_eq!(s.rounds[0].downlink, dn);
        assert_eq!(s.rounds[0].broadcast.to_bits(), bc.to_bits());
    }

    #[test]
    fn sim_latency_dynamic_tracks_the_scenario() {
        use crate::scenario::{ReoptPolicy, ScenarioSpec};
        let cfg = Config::new();
        let opts = TrainerOptions {
            rounds: 6,
            dynamic_channel: Some(DynamicChannel {
                spec: ScenarioSpec::fading(6),
                policy: ReoptPolicy::Never,
            }),
            ..Default::default()
        };
        let mut rng = Rng::new(2);
        let s = build_sim_latency(&cfg, &opts, &mut rng).unwrap();
        assert_eq!(s.rounds.len(), 6, "one entry per training round");
        let t0 = s.round_seconds(0, opts.framework, 0.5);
        assert!(t0 > 0.0);
        assert!(
            (1..6).any(|r| s.round_seconds(r, opts.framework, 0.5) != t0),
            "per-round fading never moved the simulated latency"
        );
    }

    #[test]
    fn dynamic_policy_without_optimizer_rejected() {
        use crate::scenario::{ReoptPolicy, ScenarioSpec};
        let cfg = Config::new();
        let opts = TrainerOptions {
            rounds: 3,
            dynamic_channel: Some(DynamicChannel {
                spec: ScenarioSpec::fading(3),
                policy: ReoptPolicy::EveryK(1),
            }),
            ..Default::default()
        };
        let mut rng = Rng::new(5);
        let e = build_sim_latency(&cfg, &opts, &mut rng).unwrap_err();
        assert!(e.to_string().contains("optimize_resources"), "{e}");
    }

    #[test]
    fn sim_latency_dynamic_with_optimizer_and_policy() {
        use crate::scenario::{ReoptPolicy, ScenarioSpec};
        let cfg = Config::new();
        let opts = TrainerOptions {
            n_clients: 3,
            rounds: 4,
            optimize_resources: true,
            dynamic_channel: Some(DynamicChannel {
                spec: ScenarioSpec::fading(4),
                policy: ReoptPolicy::EveryK(2),
            }),
            ..Default::default()
        };
        let mut rng = Rng::new(4);
        let s = build_sim_latency(&cfg, &opts, &mut rng).unwrap();
        assert_eq!(s.rounds.len(), 4);
        for r in 0..4 {
            assert!(s.round_seconds(r, opts.framework, 0.5) > 0.0);
        }
    }
}
