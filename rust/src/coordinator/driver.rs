//! The training entry point: Algorithm 1 for the chosen framework.
//!
//! [`train`] builds the round-invariant [`Session`] state (data shards,
//! hoisted literals, the §V simulated-latency model), then drives one
//! [`RoundPlan`](super::rounds::RoundPlan) per round through the plan
//! engine ([`super::rounds::execute_round`]) and records per-round
//! [`RunMetrics`] — loss, train/test accuracy, the simulated latency
//! with its timeline stage breakdown plus fault-recovery seconds, and
//! wall-clock. [`resume`] restarts a killed run from a
//! [`Checkpoint`] bit-exactly: the deterministic setup phase is re-run
//! from the seed (data, shards, deployment, fault plan are pure
//! functions of it), then the checkpointed parameters, RNG stream
//! position, and metric records are installed and the loop continues at
//! the saved round. The heavy lifting lives in [`super::rounds`] (round
//! execution + graceful degradation) and [`super::session`] (session
//! state, fault runtime, latency accounting).

use std::collections::BTreeMap;
use std::time::Instant;

use xla::Literal;

use crate::config::Config;
use crate::data::partition::{iid, lambda_weights, non_iid_two_class};
use crate::data::synth::{train_test, SynthSpec};
use crate::error::{Error, Result};
use crate::latency::frameworks::Framework;
use crate::metrics::{RoundRecord, RunMetrics};
use crate::runtime::artifact::Manifest;
use crate::runtime::tensor::{literal_f32, literal_u32};
use crate::runtime::Backend;
use crate::scenario::{DynamicChannel, FaultSpec};
use crate::timeline::Mode;
use crate::util::rng::Rng;

use super::checkpoint::{run_fingerprint, Checkpoint};
use super::params::{client_tensor_count, host_params, literal_params,
                    ParamSet};
use super::rounds::{execute_round, RoundPlan};
use super::session::{build_sim_latency, check_eval_batch, FaultRuntime,
                     Session};
use super::try_splitnet_cut_for_resnet18;

/// How the per-client cut assignment is chosen for a run.
///
/// `Uniform` is the paper's Alg. 3 semantics (one cut for the whole
/// cohort at `TrainerOptions::cut`) and keeps every pre-existing path
/// bit-identical. The other modes run *mixed-cut* rounds: clients split
/// at different layers, the server batches them per cut group, and the
/// §V latency accounting prices the per-client assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CutMode {
    /// Every client splits at `TrainerOptions::cut`.
    Uniform,
    /// Per-client cuts from the heterogeneous refinement pass
    /// ([`crate::optim::hetero`]) over the simulated deployment —
    /// provably never worse than the uniform optimum.
    Hetero,
    /// A user-supplied per-client SplitNet cut vector (one entry per
    /// client, each in 1..=4).
    Explicit(Vec<usize>),
}

impl Default for CutMode {
    fn default() -> Self {
        CutMode::Uniform
    }
}

impl CutMode {
    /// Parse a CLI/TOML cut spec. `"hetero"` selects the refinement
    /// pass; a single integer is a uniform cut (returned as the second
    /// element so the caller can install it in `TrainerOptions::cut`);
    /// `"1-2-2-3"` is an explicit per-client vector. Entries are
    /// range-checked here (1..=4) so a typo fails at parse time.
    pub fn parse(s: &str) -> Result<(CutMode, Option<usize>)> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("hetero") {
            return Ok((CutMode::Hetero, None));
        }
        let assignment = crate::optim::CutAssignment::parse(s)?;
        let cuts = match &assignment {
            crate::optim::CutAssignment::Uniform(j) => vec![*j],
            crate::optim::CutAssignment::PerClient(v) => v.clone(),
        };
        for &j in &cuts {
            if !(1..=4).contains(&j) {
                return Err(Error::Config(format!(
                    "cut spec '{s}': cut {j} out of 1..=4"
                )));
            }
        }
        match assignment {
            crate::optim::CutAssignment::Uniform(j) => {
                Ok((CutMode::Uniform, Some(j)))
            }
            crate::optim::CutAssignment::PerClient(v) => {
                Ok((CutMode::Explicit(v), None))
            }
        }
    }
}

/// Options for one training run.
#[derive(Debug, Clone)]
pub struct TrainerOptions {
    pub family: String,
    pub framework: Framework,
    pub n_clients: usize,
    /// SplitNet cut (1..=4).
    pub cut: usize,
    /// Per-client cut-assignment mode; `Uniform` trains every client at
    /// `cut` (the bit-identical legacy path).
    pub cut_mode: CutMode,
    pub iid: bool,
    pub dataset_size: usize,
    pub test_size: usize,
    pub rounds: usize,
    pub eval_every: usize,
    pub eta_c: f32,
    pub eta_s: f32,
    pub seed: u64,
    /// EPSL-PT: round at which φ switches 1 → 0.
    pub pt_switch: usize,
    /// Run the BCD resource optimizer for the latency accounting
    /// (otherwise a greedy + uniform-power decision is used).
    pub optimize_resources: bool,
    /// Opt-in dynamic-channel mode: the §V latency accounting tracks a
    /// per-round [`crate::scenario::Scenario`] (block fading, LoS flips,
    /// compute jitter, churn) under the given re-optimization policy,
    /// instead of one frozen averaged draw. The scenario spans `rounds`
    /// rounds.
    pub dynamic_channel: Option<DynamicChannel>,
    /// Timeline execution mode for the latency accounting: `Barrier`
    /// reproduces the closed-form eq. 23 numbers bit-identically,
    /// `Pipelined` overlaps phases per client/link.
    pub timeline_mode: Mode,
    /// Opt-in fault injection + resilience policy, expanded from the run
    /// seed into a deterministic per-round plan.
    pub faults: Option<FaultSpec>,
    /// Write a [`Checkpoint`] to `checkpoint_path` every k rounds
    /// (0 = never).
    pub checkpoint_every: usize,
    /// Where periodic checkpoints are written (required when
    /// `checkpoint_every > 0`).
    pub checkpoint_path: Option<String>,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            family: "mnist".into(),
            framework: Framework::Epsl { phi: 0.5 },
            n_clients: 5,
            cut: 2,
            cut_mode: CutMode::Uniform,
            iid: true,
            dataset_size: 2000,
            test_size: 512,
            rounds: 100,
            eval_every: 5,
            eta_c: 0.08,
            eta_s: 0.08,
            seed: 2023,
            pt_switch: 50,
            optimize_resources: false,
            dynamic_channel: None,
            timeline_mode: Mode::Barrier,
            faults: None,
            checkpoint_every: 0,
            checkpoint_path: None,
        }
    }
}

/// Final model state of a run (exposed for tests and checkpointing
/// consumers; the driver itself only needs it internally).
pub struct TrainState {
    /// Per-client client-side parameters (single entry for vanilla SL).
    pub client_params: Vec<Vec<Literal>>,
    pub server_params: Vec<Literal>,
    /// Session RNG stream position after the last round — together with
    /// the parameters this is exactly the mutable state a [`Checkpoint`]
    /// carries, so a k-round run's state doubles as a round-k snapshot.
    pub rng: crate::util::rng::RngState,
}

/// Run one full training experiment.
pub fn train(rt: &dyn Backend, manifest: &Manifest, cfg: &Config,
             opts: &TrainerOptions) -> Result<RunMetrics> {
    train_with_state(rt, manifest, cfg, opts).map(|(m, _)| m)
}

/// [`train`], also returning the final parameter state.
pub fn train_with_state(rt: &dyn Backend, manifest: &Manifest, cfg: &Config,
                        opts: &TrainerOptions)
    -> Result<(RunMetrics, TrainState)> {
    run_training(rt, manifest, cfg, opts, None)
}

/// Resume a run from a checkpoint; the completed run (prior records +
/// continued rounds) is bit-identical to the uninterrupted one.
pub fn resume(rt: &dyn Backend, manifest: &Manifest, cfg: &Config,
              opts: &TrainerOptions, ckpt: &Checkpoint)
    -> Result<RunMetrics> {
    resume_with_state(rt, manifest, cfg, opts, ckpt).map(|(m, _)| m)
}

/// [`resume`], also returning the final parameter state.
pub fn resume_with_state(rt: &dyn Backend, manifest: &Manifest,
                         cfg: &Config, opts: &TrainerOptions,
                         ckpt: &Checkpoint)
    -> Result<(RunMetrics, TrainState)> {
    run_training(rt, manifest, cfg, opts, Some(ckpt))
}

/// Snapshot the mutable session state (everything the deterministic
/// setup phase cannot re-derive from the seed).
fn snapshot(fingerprint: u64, next_round: usize, rng: &Rng,
            client_params: &[Vec<Literal>], server_params: &[Literal],
            metrics: &RunMetrics) -> Result<Checkpoint> {
    Ok(Checkpoint {
        fingerprint,
        next_round,
        rng: rng.state(),
        client_params: client_params
            .iter()
            .map(|cp| host_params(cp))
            .collect::<Result<_>>()?,
        server_params: host_params(server_params)?,
        records: metrics.rounds.clone(),
    })
}

fn run_training(rt: &dyn Backend, manifest: &Manifest, cfg: &Config,
                opts: &TrainerOptions, ckpt: Option<&Checkpoint>)
    -> Result<(RunMetrics, TrainState)> {
    let fam = manifest.family(&opts.family)?;
    let plan0 = RoundPlan::for_round(opts.framework, 0, opts.pt_switch);
    // Fail fast if the needed artifact is missing, or if evaluation could
    // never see a full chunk (no accuracy column otherwise).
    fam.server_train_entry(opts.cut, plan0.server_clients(opts.n_clients))?;
    check_eval_batch(opts.test_size, fam.eval_batch)?;
    if opts.cut_mode != CutMode::Uniform {
        // Mixed-cut rounds are defined for the parallel, fault-free,
        // static-channel frameworks: sequential relay shares one client
        // model (a single cut by construction), SFL's FedAvg needs
        // same-shape client models, and the fault/dynamic machinery
        // reasons about one uplink payload size per round.
        if matches!(opts.framework,
                    Framework::Sfl | Framework::VanillaSl) {
            return Err(Error::Config(format!(
                "cut mode {:?} requires a parallel multi-model framework \
                 (EPSL/PSL/EPSL-PT); {} shares or synchronizes the \
                 client model across clients",
                opts.cut_mode,
                opts.framework.name()
            )));
        }
        if opts.faults.is_some() {
            return Err(Error::Config(
                "mixed-cut training is incompatible with fault \
                 injection: drop --faults or use --cut <uniform>"
                    .into(),
            ));
        }
    }
    if opts.checkpoint_every > 0 && opts.checkpoint_path.is_none() {
        return Err(Error::Config(
            "checkpoint_every > 0 requires a checkpoint path \
             (--checkpoint <path>)"
                .into(),
        ));
    }

    // Deterministic setup: everything below this line is a pure function
    // of (cfg, opts) — a resumed run re-derives it identically from the
    // seed, so the checkpoint only carries the mutable state.
    let mut rng = Rng::new(opts.seed);
    let spec = SynthSpec::for_family(&opts.family, opts.dataset_size);
    let (train_set, test_set) =
        train_test(&spec, opts.test_size, opts.seed ^ 0xDA7A);
    let shards = if opts.iid {
        iid(&train_set, opts.n_clients, &mut rng)?
    } else {
        non_iid_two_class(&train_set, opts.n_clients, &mut rng)?
    };
    let lam = lambda_weights(&shards);

    // Latency model over a simulated deployment. Under a non-uniform cut
    // mode this also resolves the per-client assignment (refined against
    // the deployment for `Hetero`, validated for `Explicit`).
    let sim_latency = build_sim_latency(cfg, opts, &mut rng)?;

    // The training-side cut vector, in SplitNet stage indices (the
    // latency model lives in the paper's ResNet-18 layer domain).
    let train_cuts: Vec<usize> = sim_latency
        .cut
        .cuts_for(opts.n_clients)
        .iter()
        .map(|&j| try_splitnet_cut_for_resnet18(j))
        .collect::<Result<_>>()?;
    fam.validate_cut_vector(&train_cuts, opts.n_clients)?;
    let assignment =
        crate::optim::CutAssignment::normalized(train_cuts.clone());
    let cut_label = assignment.label();
    let mixed = assignment.as_uniform().is_none();
    let j_min = *train_cuts.iter().min().ok_or_else(|| {
        Error::Config("run has zero clients".into())
    })?;
    if mixed {
        // Fail fast per cut group: every group runs its own fused server
        // step sized to the group's membership.
        for (j, members) in assignment.groups(opts.n_clients) {
            fam.server_train_entry(j, members.len())?;
        }
    }

    // Fault plan, expanded from the same seed stream (scheduled-only
    // specs consume nothing — see scenario::faults).
    let faults = match &opts.faults {
        Some(spec) => Some(FaultRuntime::from_spec(
            spec,
            opts.rounds,
            opts.n_clients,
            &mut rng,
        )?),
        None => None,
    };

    // Model init. The server owns the suffix at the *shallowest* cut in
    // the assignment; a deeper-cut group uses a sub-suffix of it (the
    // layers between two cuts live client-side for that group). Uniform
    // assignments split at the single cut exactly as before.
    // Both 32-bit words of the run seed: the Init entry point rebuilds
    // `(hi << 32) | lo`, so passing `[0, seed as u32]` silently dropped
    // the high word for seeds >= 2^32 (same init for distinct seeds).
    // For the common sub-2^32 seeds the words are unchanged, so
    // existing golden runs are bit-identical.
    let seed_lit = literal_u32(
        &[2],
        &[(opts.seed >> 32) as u32, opts.seed as u32],
    )?;
    let full = ParamSet::new(rt.call(&fam.init, &[seed_lit])?);
    let (client0, mut server_params) = full.split(fam, j_min)?;
    let n_replicas = plan0.param_replicas(opts.n_clients);
    let mut client_params: Vec<Vec<Literal>> = if mixed {
        train_cuts
            .iter()
            .map(|&jc| full.split(fam, jc).map(|(cp, _)| cp))
            .collect::<Result<_>>()?
    } else if n_replicas == 1 {
        vec![client0]
    } else {
        (0..n_replicas).map(|_| client0.clone()).collect()
    };

    let lam_lit = literal_f32(&[lam.len()], &lam)?;
    let lr_s_lit = literal_f32(&[], &[opts.eta_s])?;
    let lr_c_lit = literal_f32(&[], &[opts.eta_c])?;
    let mut session = Session {
        rt,
        fam,
        opts,
        train_set,
        test_set,
        shards,
        lam,
        sim_latency,
        cuts: train_cuts.clone(),
        rng,
        lam_lit,
        lr_s_lit,
        lr_c_lit,
        mask_cache: BTreeMap::new(),
        faults,
    };

    let fingerprint = run_fingerprint(cfg, opts);
    let mut metrics = RunMetrics::new(opts.framework.name());
    let mut start_round = 0;
    if let Some(ck) = ckpt {
        // Install the checkpointed mutable state over the re-derived
        // setup. The fingerprint gate rejects resuming into a different
        // experiment before any tensor is touched.
        if ck.fingerprint != fingerprint {
            return Err(Error::Fault(format!(
                "checkpoint fingerprint {:016x} does not match this \
                 run's {:016x}: it was taken under a different \
                 configuration",
                ck.fingerprint, fingerprint
            )));
        }
        if ck.next_round > opts.rounds {
            return Err(Error::Fault(format!(
                "checkpoint resumes at round {} but the run has only \
                 {} round(s)",
                ck.next_round, opts.rounds
            )));
        }
        if ck.client_params.len() != client_params.len() {
            return Err(Error::Fault(format!(
                "checkpoint carries {} client replica(s), expected {}",
                ck.client_params.len(),
                client_params.len()
            )));
        }
        for (i, replica) in ck.client_params.iter().enumerate() {
            // Replica i trains client i's cut under a mixed assignment;
            // all replicas share the single cut otherwise (for uniform
            // runs this is exactly the pre-refactor prefix length).
            let rc = if mixed { train_cuts[i] } else { train_cuts[0] };
            let n_client = client_tensor_count(fam, rc)?;
            client_params[i] =
                literal_params(replica, &fam.params[..n_client])?;
        }
        let n_min = client_tensor_count(fam, j_min)?;
        server_params =
            literal_params(&ck.server_params, &fam.params[n_min..])?;
        session.rng = Rng::from_state(ck.rng);
        metrics.rounds = ck.records.clone();
        start_round = ck.next_round;
    }

    for round in start_round..opts.rounds {
        let t0 = Instant::now();
        let plan = RoundPlan::for_round(opts.framework, round,
                                        opts.pt_switch);
        let out = execute_round(
            &mut session,
            &plan,
            round,
            &mut client_params,
            &mut server_params,
        )?;
        let test_acc = if round % opts.eval_every == opts.eval_every - 1
            || round + 1 == opts.rounds
        {
            Some(session.evaluate(&client_params, &server_params)?)
        } else {
            None
        };
        let tl = session
            .sim_latency
            .round_timeline(round, opts.framework, plan.phi);
        metrics.push(RoundRecord {
            round,
            loss: out.loss,
            train_acc: out.train_acc,
            test_acc,
            // Recovery seconds ride on top of the nominal timeline
            // (+0.0 for a quiet round keeps the total bit-identical).
            sim_latency: tl.total + out.faults.recovery_s,
            stages: tl.spans,
            faults: out.faults,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            cut: cut_label.clone(),
        });
        if opts.checkpoint_every > 0
            && (round + 1) % opts.checkpoint_every == 0
            && round + 1 < opts.rounds
        {
            if let Some(path) = &opts.checkpoint_path {
                snapshot(
                    fingerprint,
                    round + 1,
                    &session.rng,
                    &client_params,
                    &server_params,
                    &metrics,
                )?
                .save(path)?;
            }
        }
    }
    let rng_state = session.rng.state();
    Ok((
        metrics,
        TrainState { client_params, server_params, rng: rng_state },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::{self, NativeBackend};

    fn setup() -> (NativeBackend, Manifest, Config) {
        (NativeBackend::new(), native::manifest(), Config::new())
    }

    fn smoke_opts() -> TrainerOptions {
        TrainerOptions {
            n_clients: 2,
            rounds: 4,
            eval_every: 2,
            dataset_size: 400,
            test_size: 256,
            ..Default::default()
        }
    }

    #[test]
    fn epsl_smoke_two_clients() {
        let (rt, m, cfg) = setup();
        let run = train(&rt, &m, &cfg, &smoke_opts()).unwrap();
        assert_eq!(run.rounds.len(), 4);
        assert!(run.rounds.iter().all(|r| r.loss.is_finite()));
        assert!(run.rounds.iter().all(|r| r.sim_latency > 0.0));
        // at least one evaluation happened
        assert!(run.rounds.iter().any(|r| r.test_acc.is_some()));
        // the timeline stage breakdown is populated and consistent
        assert!(run
            .rounds
            .iter()
            .all(|r| r.stages.total().to_bits() == r.sim_latency.to_bits()));
        // quiet run: no fault accounting
        assert!(run.rounds.iter().all(|r| {
            r.faults.injected == 0
                && r.faults.dropped == 0
                && r.faults.cohort == 2
                && r.faults.recovery_s == 0.0
        }));
    }

    #[test]
    fn vanilla_smoke() {
        let (rt, m, cfg) = setup();
        let opts = TrainerOptions {
            framework: Framework::VanillaSl,
            rounds: 2,
            ..smoke_opts()
        };
        let run = train(&rt, &m, &cfg, &opts).unwrap();
        assert_eq!(run.rounds.len(), 2);
        assert!(run.rounds[0].loss.is_finite());
    }

    #[test]
    fn cut_mode_parse_specs() {
        assert_eq!(
            CutMode::parse("hetero").unwrap(),
            (CutMode::Hetero, None)
        );
        assert_eq!(
            CutMode::parse("HETERO").unwrap(),
            (CutMode::Hetero, None)
        );
        assert_eq!(CutMode::parse("3").unwrap(), (CutMode::Uniform, Some(3)));
        assert_eq!(
            CutMode::parse("1-2-2-3").unwrap(),
            (CutMode::Explicit(vec![1, 2, 2, 3]), None)
        );
        assert!(CutMode::parse("0").is_err());
        assert!(CutMode::parse("5").is_err());
        assert!(CutMode::parse("1-5").is_err());
        assert!(CutMode::parse("x").is_err());
        assert!(CutMode::parse("").is_err());
    }

    #[test]
    fn mixed_cut_incompatible_frameworks_rejected() {
        let (rt, m, cfg) = setup();
        for fw in [Framework::Sfl, Framework::VanillaSl] {
            let opts = TrainerOptions {
                framework: fw,
                cut_mode: CutMode::Explicit(vec![1, 2]),
                ..smoke_opts()
            };
            let e = train(&rt, &m, &cfg, &opts).unwrap_err();
            assert!(
                e.to_string().contains("parallel multi-model"),
                "{fw:?}: {e}"
            );
        }
    }

    #[test]
    fn mixed_cut_with_faults_rejected() {
        let (rt, m, cfg) = setup();
        let opts = TrainerOptions {
            cut_mode: CutMode::Hetero,
            faults: Some(crate::scenario::FaultSpec::default()),
            ..smoke_opts()
        };
        let e = train(&rt, &m, &cfg, &opts).unwrap_err();
        assert!(e.to_string().contains("fault"), "{e}");
    }

    #[test]
    fn explicit_cut_vector_length_must_match_clients() {
        let (rt, m, cfg) = setup();
        let opts = TrainerOptions {
            cut_mode: CutMode::Explicit(vec![1, 2, 3]),
            ..smoke_opts() // 2 clients
        };
        let e = train(&rt, &m, &cfg, &opts).unwrap_err();
        assert!(e.to_string().contains("client"), "{e}");
        let opts = TrainerOptions {
            cut_mode: CutMode::Explicit(vec![1, 9]),
            ..smoke_opts()
        };
        assert!(train(&rt, &m, &cfg, &opts).is_err());
    }

    #[test]
    fn checkpoint_every_requires_a_path() {
        let (rt, m, cfg) = setup();
        let opts = TrainerOptions {
            checkpoint_every: 2,
            ..smoke_opts()
        };
        let e = train(&rt, &m, &cfg, &opts).unwrap_err();
        assert!(e.to_string().contains("checkpoint"), "{e}");
    }

    #[test]
    fn resume_rejects_foreign_fingerprint() {
        let (rt, m, cfg) = setup();
        let opts = smoke_opts();
        let ck = Checkpoint {
            fingerprint: 0x1234,
            next_round: 2,
            rng: Rng::new(1).state(),
            client_params: vec![],
            server_params: vec![],
            records: vec![],
        };
        let e = resume(&rt, &m, &cfg, &opts, &ck).unwrap_err();
        assert!(e.to_string().contains("fingerprint"), "{e}");
    }

    #[test]
    fn pipelined_mode_trains_identically_with_leq_latency() {
        // The timeline mode only changes the latency *accounting*:
        // learning dynamics are bit-identical, and the pipelined round
        // never reports more seconds than the barrier round.
        let (rt, m, cfg) = setup();
        let barrier = train(&rt, &m, &cfg, &smoke_opts()).unwrap();
        let opts = TrainerOptions {
            timeline_mode: Mode::Pipelined,
            ..smoke_opts()
        };
        let pipelined = train(&rt, &m, &cfg, &opts).unwrap();
        for (a, b) in barrier.rounds.iter().zip(&pipelined.rounds) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(
                a.test_acc.map(f64::to_bits),
                b.test_acc.map(f64::to_bits)
            );
            assert!(
                b.sim_latency <= a.sim_latency,
                "round {}: pipelined {} > barrier {}",
                a.round,
                b.sim_latency,
                a.sim_latency
            );
        }
        // The simulated deployment is heterogeneous: pipelining gains.
        assert!(
            pipelined.total_latency() < barrier.total_latency(),
            "pipelined {} !< barrier {}",
            pipelined.total_latency(),
            barrier.total_latency()
        );
    }
}
