//! The training entry point: Algorithm 1 for the chosen framework.
//!
//! [`train`] builds the round-invariant [`Session`] state (data shards,
//! hoisted literals, the §V simulated-latency model), then drives one
//! [`RoundPlan`](super::rounds::RoundPlan) per round through the plan
//! engine ([`super::rounds::execute_round`]) and records per-round
//! [`RunMetrics`] — loss, train/test accuracy, the simulated latency
//! with its timeline stage breakdown, and wall-clock. The heavy lifting
//! lives in [`super::rounds`] (round execution) and [`super::session`]
//! (session state + latency accounting).

use std::collections::HashMap;
use std::time::Instant;

use xla::Literal;

use crate::config::Config;
use crate::data::partition::{iid, lambda_weights, non_iid_two_class};
use crate::data::synth::{train_test, SynthSpec};
use crate::error::Result;
use crate::latency::frameworks::Framework;
use crate::metrics::{RoundRecord, RunMetrics};
use crate::runtime::artifact::Manifest;
use crate::runtime::tensor::{literal_f32, literal_u32};
use crate::runtime::Backend;
use crate::scenario::DynamicChannel;
use crate::timeline::Mode;
use crate::util::rng::Rng;

use super::params::ParamSet;
use super::rounds::{execute_round, RoundPlan};
use super::session::{build_sim_latency, check_eval_batch, Session};

/// Options for one training run.
#[derive(Debug, Clone)]
pub struct TrainerOptions {
    pub family: String,
    pub framework: Framework,
    pub n_clients: usize,
    /// SplitNet cut (1..=4).
    pub cut: usize,
    pub iid: bool,
    pub dataset_size: usize,
    pub test_size: usize,
    pub rounds: usize,
    pub eval_every: usize,
    pub eta_c: f32,
    pub eta_s: f32,
    pub seed: u64,
    /// EPSL-PT: round at which φ switches 1 → 0.
    pub pt_switch: usize,
    /// Run the BCD resource optimizer for the latency accounting
    /// (otherwise a greedy + uniform-power decision is used).
    pub optimize_resources: bool,
    /// Opt-in dynamic-channel mode: the §V latency accounting tracks a
    /// per-round [`crate::scenario::Scenario`] (block fading, LoS flips,
    /// compute jitter, churn) under the given re-optimization policy,
    /// instead of one frozen averaged draw. The scenario spans `rounds`
    /// rounds.
    pub dynamic_channel: Option<DynamicChannel>,
    /// Timeline execution mode for the latency accounting: `Barrier`
    /// reproduces the closed-form eq. 23 numbers bit-identically,
    /// `Pipelined` overlaps phases per client/link.
    pub timeline_mode: Mode,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            family: "mnist".into(),
            framework: Framework::Epsl { phi: 0.5 },
            n_clients: 5,
            cut: 2,
            iid: true,
            dataset_size: 2000,
            test_size: 512,
            rounds: 100,
            eval_every: 5,
            eta_c: 0.08,
            eta_s: 0.08,
            seed: 2023,
            pt_switch: 50,
            optimize_resources: false,
            dynamic_channel: None,
            timeline_mode: Mode::Barrier,
        }
    }
}

/// Final model state of a run (exposed for tests and checkpointing-style
/// consumers; the driver itself only needs it internally).
pub struct TrainState {
    /// Per-client client-side parameters (single entry for vanilla SL).
    pub client_params: Vec<Vec<Literal>>,
    pub server_params: Vec<Literal>,
}

/// Run one full training experiment.
pub fn train(rt: &dyn Backend, manifest: &Manifest, cfg: &Config,
             opts: &TrainerOptions) -> Result<RunMetrics> {
    train_with_state(rt, manifest, cfg, opts).map(|(m, _)| m)
}

/// [`train`], also returning the final parameter state.
pub fn train_with_state(rt: &dyn Backend, manifest: &Manifest, cfg: &Config,
                        opts: &TrainerOptions)
    -> Result<(RunMetrics, TrainState)> {
    let fam = manifest.family(&opts.family)?;
    let plan0 = RoundPlan::for_round(opts.framework, 0, opts.pt_switch);
    // Fail fast if the needed artifact is missing, or if evaluation could
    // never see a full chunk (no accuracy column otherwise).
    fam.server_train_entry(opts.cut, plan0.server_clients(opts.n_clients))?;
    check_eval_batch(opts.test_size, fam.eval_batch)?;

    let mut rng = Rng::new(opts.seed);
    // Data.
    let spec = SynthSpec::for_family(&opts.family, opts.dataset_size);
    let (train_set, test_set) =
        train_test(&spec, opts.test_size, opts.seed ^ 0xDA7A);
    let shards = if opts.iid {
        iid(&train_set, opts.n_clients, &mut rng)?
    } else {
        non_iid_two_class(&train_set, opts.n_clients, &mut rng)?
    };
    let lam = lambda_weights(&shards);

    // Latency model over a simulated deployment.
    let sim_latency = build_sim_latency(cfg, opts, &mut rng)?;

    // Model init.
    let seed_lit = literal_u32(&[2], &[0, opts.seed as u32])?;
    let full = ParamSet::new(rt.call(&fam.init, &[seed_lit])?);
    let (client0, mut server_params) = full.split(fam, opts.cut);
    let n_replicas = plan0.param_replicas(opts.n_clients);
    let mut client_params: Vec<Vec<Literal>> = if n_replicas == 1 {
        vec![client0]
    } else {
        (0..n_replicas).map(|_| client0.clone()).collect()
    };

    let lam_lit = literal_f32(&[lam.len()], &lam)?;
    let lr_s_lit = literal_f32(&[], &[opts.eta_s])?;
    let lr_c_lit = literal_f32(&[], &[opts.eta_c])?;
    let mut session = Session {
        rt,
        fam,
        opts,
        train_set,
        test_set,
        shards,
        lam,
        sim_latency,
        rng,
        lam_lit,
        lr_s_lit,
        lr_c_lit,
        mask_cache: HashMap::new(),
    };

    let mut metrics = RunMetrics::new(opts.framework.name());
    for round in 0..opts.rounds {
        let t0 = Instant::now();
        let plan = RoundPlan::for_round(opts.framework, round,
                                        opts.pt_switch);
        let (loss, train_acc) = execute_round(
            &mut session,
            &plan,
            &mut client_params,
            &mut server_params,
        )?;
        let test_acc = if round % opts.eval_every == opts.eval_every - 1
            || round + 1 == opts.rounds
        {
            Some(session.evaluate(&client_params, &server_params)?)
        } else {
            None
        };
        let tl = session
            .sim_latency
            .round_timeline(round, opts.framework, plan.phi);
        metrics.push(RoundRecord {
            round,
            loss,
            train_acc,
            test_acc,
            sim_latency: tl.total,
            stages: tl.spans,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        });
    }
    Ok((metrics, TrainState { client_params, server_params }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::{self, NativeBackend};

    fn setup() -> (NativeBackend, Manifest, Config) {
        (NativeBackend::new(), native::manifest(), Config::new())
    }

    fn smoke_opts() -> TrainerOptions {
        TrainerOptions {
            n_clients: 2,
            rounds: 4,
            eval_every: 2,
            dataset_size: 400,
            test_size: 256,
            ..Default::default()
        }
    }

    #[test]
    fn epsl_smoke_two_clients() {
        let (rt, m, cfg) = setup();
        let run = train(&rt, &m, &cfg, &smoke_opts()).unwrap();
        assert_eq!(run.rounds.len(), 4);
        assert!(run.rounds.iter().all(|r| r.loss.is_finite()));
        assert!(run.rounds.iter().all(|r| r.sim_latency > 0.0));
        // at least one evaluation happened
        assert!(run.rounds.iter().any(|r| r.test_acc.is_some()));
        // the timeline stage breakdown is populated and consistent
        assert!(run
            .rounds
            .iter()
            .all(|r| r.stages.total().to_bits() == r.sim_latency.to_bits()));
    }

    #[test]
    fn vanilla_smoke() {
        let (rt, m, cfg) = setup();
        let opts = TrainerOptions {
            framework: Framework::VanillaSl,
            rounds: 2,
            ..smoke_opts()
        };
        let run = train(&rt, &m, &cfg, &opts).unwrap();
        assert_eq!(run.rounds.len(), 2);
        assert!(run.rounds[0].loss.is_finite());
    }

    #[test]
    fn pipelined_mode_trains_identically_with_leq_latency() {
        // The timeline mode only changes the latency *accounting*:
        // learning dynamics are bit-identical, and the pipelined round
        // never reports more seconds than the barrier round.
        let (rt, m, cfg) = setup();
        let barrier = train(&rt, &m, &cfg, &smoke_opts()).unwrap();
        let opts = TrainerOptions {
            timeline_mode: Mode::Pipelined,
            ..smoke_opts()
        };
        let pipelined = train(&rt, &m, &cfg, &opts).unwrap();
        for (a, b) in barrier.rounds.iter().zip(&pipelined.rounds) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(
                a.test_acc.map(f64::to_bits),
                b.test_acc.map(f64::to_bits)
            );
            assert!(
                b.sim_latency <= a.sim_latency,
                "round {}: pipelined {} > barrier {}",
                a.round,
                b.sim_latency,
                a.sim_latency
            );
        }
        // The simulated deployment is heterogeneous: pipelining gains.
        assert!(
            pipelined.total_latency() < barrier.total_latency(),
            "pipelined {} !< barrier {}",
            pipelined.total_latency(),
            barrier.total_latency()
        );
    }
}
