//! Round-invariant training-session state: data shards, hoisted
//! literals, the evaluation path, and the §V simulated-latency model
//! (static frozen draw or per-round dynamic-channel tracking), now
//! executed through the [`crate::timeline`] event engine in either
//! `barrier` or `pipelined` mode.

use std::collections::BTreeMap;

use xla::Literal;

use crate::channel::{ChannelRealization, Deployment};
use crate::config::{Config, NetworkConfig};
use crate::data::{Dataset, Shard};
use crate::error::{Error, Result};
use crate::latency::frameworks::Framework;
use crate::latency::LatencyInputs;
use crate::optim::eval::Evaluator;
use crate::optim::{bcd, hetero, CutAssignment, Decision, Problem};
use crate::profile::resnet18;
use crate::runtime::artifact::FamilyManifest;
use crate::runtime::tensor::{literal_f32, literal_i32, scalar_f32};
use crate::runtime::Backend;
use crate::scenario::{self, DynamicChannel, FaultPlan, FaultSpec,
                      RoundFaults, Scenario};
use crate::timeline::{self, Mode, RoundTimeline};
use crate::util::par;
use crate::util::rng::Rng;

use super::driver::{CutMode, TrainerOptions};
use super::params::{client_tensor_count, fedavg, ParamSet};
use super::rounds::renormalized_lambda;
use super::{try_resnet18_cut_for_splitnet,
            try_splitnet_cut_for_resnet18};

/// Everything fixed across rounds.
pub(crate) struct Session<'a> {
    pub(crate) rt: &'a dyn Backend,
    pub(crate) fam: &'a FamilyManifest,
    pub(crate) opts: &'a TrainerOptions,
    pub(crate) train_set: Dataset,
    pub(crate) test_set: Dataset,
    pub(crate) shards: Vec<Shard>,
    pub(crate) lam: Vec<f32>,
    /// Per-round simulated latency per φ value (resnet18 profile).
    pub(crate) sim_latency: SimLatency,
    /// Per-client SplitNet cuts (all-equal for a uniform run). The round
    /// engine dispatches on this: uniform vectors take the literal
    /// single-cut path, mixed vectors run per-cut-group server batches.
    pub(crate) cuts: Vec<usize>,
    pub(crate) rng: Rng,
    /// Round-invariant literals, hoisted out of the hot loop (§Perf).
    pub(crate) lam_lit: Literal,
    pub(crate) lr_s_lit: Literal,
    pub(crate) lr_c_lit: Literal,
    /// (φ bits) → (mask host vector, mask literal). BTreeMap, not
    /// HashMap: keyed session state must be hash-order-free by
    /// construction (audit rule R2).
    pub(crate) mask_cache: BTreeMap<u64, (Vec<f32>, Literal)>,
    /// Expanded fault plan + resilience policy (`None` = fault-free run;
    /// the round engine takes the quiet path with zero overhead).
    pub(crate) faults: Option<FaultRuntime>,
}

/// The session-resident fault machinery: the seed-expanded per-round
/// plan plus the resilience knobs the round engine applies.
pub(crate) struct FaultRuntime {
    pub(crate) plan: FaultPlan,
    pub(crate) quorum: usize,
    pub(crate) max_retries: usize,
    pub(crate) retry_backoff_s: f64,
    pub(crate) deadline_factor: f64,
}

impl FaultRuntime {
    /// Expand `spec` against the run shape. Consumes the session RNG
    /// stream only when the spec has probabilistic knobs (scheduled-only
    /// specs leave the batch-sampling stream untouched).
    pub(crate) fn from_spec(spec: &FaultSpec, rounds: usize,
                            n_clients: usize, rng: &mut Rng)
        -> Result<FaultRuntime> {
        Ok(FaultRuntime {
            plan: spec.expand(rounds, n_clients, rng)?,
            quorum: spec.quorum,
            max_retries: spec.max_retries,
            retry_backoff_s: spec.retry_backoff_s,
            deadline_factor: spec.deadline_factor,
        })
    }

    /// This round's injected faults (quiet past the planned horizon).
    pub(crate) fn round(&self, r: usize) -> RoundFaults {
        self.plan.round(r).cloned().unwrap_or_default()
    }
}

/// One round's link state for the §V model.
pub(crate) struct SimRound {
    pub(crate) f_clients: Vec<f64>,
    pub(crate) uplink: Vec<f64>,
    pub(crate) downlink: Vec<f64>,
    pub(crate) broadcast: f64,
}

/// Pre-computed stage-latency inputs for the §V model: one [`SimRound`]
/// per training round under a dynamic-channel scenario, a single frozen
/// entry otherwise. `mode` picks the timeline execution semantics
/// (barrier reproduces the closed-form eq. 23 numbers bit-identically).
pub(crate) struct SimLatency {
    pub(crate) rounds: Vec<SimRound>,
    /// Cut assignment in the paper's ResNet-18 layer domain; mixed
    /// assignments route through the hetero timeline builder.
    pub(crate) cut: CutAssignment,
    pub(crate) batch: usize,
    pub(crate) f_server: f64,
    pub(crate) kappa_server: f64,
    pub(crate) kappa_client: f64,
    /// Uplink activation-payload compression factor (eq. 15 scale).
    pub(crate) uplink_comp: f64,
    pub(crate) mode: Mode,
}

impl SimLatency {
    /// Closed-form latency inputs for this round (any round index past
    /// the horizon maps onto the last entry — the static frozen draw).
    fn inputs_at(&self, round: usize, phi: f64, cut: usize)
        -> LatencyInputs<'_> {
        // Cached profile: this runs once per training round, and the old
        // per-call Table IV rebuild dominated the simulated-latency cost.
        let profile = resnet18::profile_static();
        let r = &self.rounds[round.min(self.rounds.len() - 1)];
        LatencyInputs {
            profile,
            cut,
            batch: self.batch,
            phi,
            f_server: self.f_server,
            kappa_server: self.kappa_server,
            kappa_client: self.kappa_client,
            f_clients: &r.f_clients,
            uplink: &r.uplink,
            downlink: &r.downlink,
            broadcast: r.broadcast,
            uplink_comp: self.uplink_comp,
        }
    }

    /// For EPSL-PT the effective framework at a round is EPSL{phi}.
    fn effective_fw(fw: Framework, phi: f64) -> Framework {
        match fw {
            Framework::EpslPt { .. } => Framework::Epsl { phi },
            other => other,
        }
    }

    /// Simulate this round's timeline (per-stage events + total).
    pub(crate) fn round_timeline(&self, round: usize, fw: Framework,
                                 phi: f64) -> RoundTimeline {
        let fw = Self::effective_fw(fw, phi);
        match self.cut.as_uniform() {
            Some(j) => {
                let inp = self.inputs_at(round, phi, j);
                timeline::simulate(fw, &inp, self.mode)
            }
            None => {
                let inp = self.inputs_at(round, phi, self.cut.min_cut());
                let cuts = self.cut.cuts_for(inp.f_clients.len());
                // Mixed assignments are gated to EPSL/PSL at build time,
                // so the hetero shape builder accepts the framework.
                timeline::simulate_cuts(fw, &inp, &cuts, self.mode)
                    // audit:allow(R1, "mixed assignments are rejected for non-EPSL/PSL frameworks when the session is built, so the shape builder cannot refuse here")
                    .expect("mixed-cut timeline on a gated framework")
            }
        }
    }

    /// Nominal per-client smashed-data arrival times at the server
    /// (`a_i = T_i^F + T_i^U`) — the baseline the straggler deadline is
    /// derived from. One entry per timeline chain: C for the parallel
    /// frameworks, a single pre-summed chain for vanilla SL.
    pub(crate) fn uplink_arrivals(&self, round: usize, fw: Framework,
                                  phi: f64) -> Vec<f64> {
        let fw = Self::effective_fw(fw, phi);
        match self.cut.as_uniform() {
            Some(j) => {
                let inp = self.inputs_at(round, phi, j);
                timeline::shape_for(fw, &inp).uplink_arrivals()
            }
            None => {
                let inp = self.inputs_at(round, phi, self.cut.min_cut());
                let cuts = self.cut.cuts_for(inp.f_clients.len());
                timeline::shape_for_cuts(fw, &inp, &cuts)
                    // audit:allow(R1, "mixed assignments are rejected for non-EPSL/PSL frameworks when the session is built, so the shape builder cannot refuse here")
                    .expect("mixed-cut timeline on a gated framework")
                    .uplink_arrivals()
            }
        }
    }

    /// This round's simulated latency in seconds.
    pub(crate) fn round_seconds(&self, round: usize, fw: Framework,
                                phi: f64) -> f64 {
        self.round_timeline(round, fw, phi).total
    }
}

pub(crate) fn build_sim_latency(cfg: &Config, opts: &TrainerOptions,
                                rng: &mut Rng) -> Result<SimLatency> {
    let net = cfg.net.clone().with_clients(opts.n_clients);
    let profile = resnet18::profile_static();
    let cut = try_resnet18_cut_for_splitnet(opts.cut)?;
    if let Some(dc) = &opts.dynamic_channel {
        if opts.cut_mode != CutMode::Uniform {
            return Err(Error::Config(
                "mixed-cut training requires a static channel: the \
                 dynamic-channel tracker reasons about one uplink \
                 payload size per round (drop --dynamic or use a \
                 uniform --cut)"
                    .into(),
            ));
        }
        return build_dynamic_sim_latency(cfg, opts, &net, cut, dc, rng);
    }
    let dep = Deployment::generate(&net, rng);
    let ch = ChannelRealization::average(&dep);
    let prob = Problem {
        cfg: &net,
        profile,
        dep: &dep,
        ch: &ch,
        batch: cfg.train.batch,
        phi: opts.framework.phi(),
    };
    let decision: Decision = if opts.optimize_resources {
        bcd::solve(&prob, bcd::BcdOptions::default())?.decision
    } else {
        // One shared allocation for both the PSD plan and the decision
        // (the pre-fix code ran rss_allocation twice).
        crate::optim::baselines::uniform_decision(&prob, cut)
    };
    let (up, dn, bc) = prob.rates(&decision);
    let assignment = resolve_cut_assignment(&prob, opts, cut, &decision)?;
    Ok(SimLatency {
        rounds: vec![SimRound {
            f_clients: dep.f_clients().to_vec(),
            uplink: up,
            downlink: dn,
            broadcast: bc,
        }],
        cut: assignment,
        batch: cfg.train.batch,
        f_server: net.f_server,
        kappa_server: net.kappa_server,
        kappa_client: net.kappa_client,
        uplink_comp: net.uplink_compression,
        mode: opts.timeline_mode,
    })
}

/// Resolve the run's cut assignment (ResNet-18 layer domain) from the
/// configured [`CutMode`] against the frozen deployment draw.
///
/// - `Uniform` → `Uniform(cut)`: the literal pre-refactor behavior.
/// - `Explicit` → the user's SplitNet vector, length-checked and mapped
///   into the layer domain (all-equal vectors collapse to `Uniform`).
/// - `Hetero` → per-client coordinate descent
///   ([`hetero::refine_with`]) at the solved allocation/power, seeded
///   from the uniform training cut and restricted to the four
///   SplitNet-mappable layers so the result is always executable by the
///   runtime — never worse than uniform by construction.
fn resolve_cut_assignment(prob: &Problem, opts: &TrainerOptions,
                          cut: usize, decision: &Decision)
    -> Result<CutAssignment> {
    match &opts.cut_mode {
        CutMode::Uniform => Ok(CutAssignment::Uniform(cut)),
        CutMode::Explicit(v) => {
            if v.len() != opts.n_clients {
                return Err(Error::Config(format!(
                    "explicit cut vector has {} entr{} but the run has \
                     {} client(s)",
                    v.len(),
                    if v.len() == 1 { "y" } else { "ies" },
                    opts.n_clients
                )));
            }
            let mapped: Vec<usize> = v
                .iter()
                .map(|&s| try_resnet18_cut_for_splitnet(s))
                .collect::<Result<_>>()?;
            Ok(CutAssignment::normalized(mapped))
        }
        CutMode::Hetero => {
            let ev = Evaluator::new(prob);
            let mappable: Vec<usize> = ev
                .cut_candidates()
                .iter()
                .copied()
                .filter(|&j| try_splitnet_cut_for_resnet18(j).is_ok())
                .collect();
            let seed = Decision {
                alloc: decision.alloc.clone(),
                psd_dbm_hz: decision.psd_dbm_hz.clone(),
                cut: cut.into(),
            };
            let res = hetero::refine_with(
                prob,
                &ev,
                &seed,
                hetero::HeteroOptions {
                    candidates: Some(mappable),
                    ..Default::default()
                },
            )?;
            println!(
                "hetero cut: {} (objective {:.4} s vs uniform {:.4} s at \
                 cut {})",
                if res.improved {
                    format!("per-client assignment {}",
                            res.decision.cut.label())
                } else {
                    "uniform assignment kept".to_string()
                },
                res.objective,
                res.uniform_objective,
                cut
            );
            Ok(res.decision.cut)
        }
    }
}

/// Dynamic-channel mode: expand the scenario from the session RNG stream
/// and track per-round realized rates. With `optimize_resources` the
/// re-optimization policy drives BCD re-solves (blocks fan across cores);
/// without it a fixed uniform-power decision at the training cut rides
/// the varying channel (churn then has no valid meaning — rejected).
fn build_dynamic_sim_latency(cfg: &Config, opts: &TrainerOptions,
                             net: &NetworkConfig, cut: usize,
                             dc: &DynamicChannel, rng: &mut Rng)
    -> Result<SimLatency> {
    let profile = resnet18::profile_static();
    let mut spec = dc.spec.clone();
    spec.rounds = opts.rounds; // the scenario spans the training run
    let roster = Deployment::generate(net, rng);
    let sc = Scenario::from_deployment(net.clone(), roster, spec, rng)?;
    // Churn/quorum interaction: surface a structured error naming the
    // first round whose surviving cohort falls below the floor, instead
    // of a downstream optimizer solve failure. The floor is the fault
    // quorum when fault injection is on, else the optimizer's own
    // feasibility minimum of one active client.
    let quorum_floor = opts.faults.as_ref().map_or(1, |f| f.quorum);
    for round in &sc.rounds {
        if round.active.len() < quorum_floor {
            return Err(Error::Quorum {
                round: round.round,
                active: round.active.len(),
                need: quorum_floor,
            });
        }
    }
    let rounds: Vec<SimRound> = if opts.optimize_resources {
        let (outcome, rates) = scenario::run_policy_with_rates(
            &sc,
            profile,
            &scenario::RunOptions {
                policy: dc.policy,
                bcd: bcd::BcdOptions::default(),
                batch: cfg.train.batch,
                phi: opts.framework.phi(),
                threads: par::max_threads(),
                // The policy must react to the latency the run actually
                // accounts (OnRegression triggers off eval_round's value).
                timeline_mode: opts.timeline_mode,
            },
        );
        println!(
            "dynamic channel: {} optimizer solve(s) over {} rounds \
             (policy {})",
            outcome.n_solves,
            sc.n_rounds(),
            dc.policy.name()
        );
        // Latency accounting always prices the *training* cut (same
        // semantics as the static --optimize path); when a re-solve picked
        // a different cut its rates were tuned for that cut's payloads —
        // surface the mismatch instead of silently mixing.
        let cut_mismatch = rates
            .iter()
            .flatten()
            .filter(|rr| rr.cut != cut)
            .count();
        if cut_mismatch > 0 {
            println!(
                "dynamic channel: optimizer preferred a different cut \
                 layer in {cut_mismatch} round(s); accounting keeps the \
                 training cut {cut}"
            );
        }
        rates
            .into_iter()
            .enumerate()
            .map(|(r, rr)| {
                rr.ok_or_else(|| {
                    Error::Optim(format!(
                        "dynamic channel: resource solve failed at round {r}"
                    ))
                })
            })
            .collect::<Result<Vec<scenario::RoundRates>>>()?
            .into_iter()
            .map(|rr| SimRound {
                f_clients: rr.f_clients,
                uplink: rr.uplink,
                downlink: rr.downlink,
                broadcast: rr.broadcast,
            })
            .collect()
    } else {
        if !matches!(dc.policy, scenario::ReoptPolicy::Never) {
            return Err(Error::Config(format!(
                "dynamic channel: re-optimization policy '{}' requires \
                 optimize_resources (without it a fixed uniform-power \
                 decision rides the channel; pass --optimize, or use \
                 --reopt never)",
                dc.policy.name()
            )));
        }
        if sc.rounds.iter().any(|r| r.membership_changed) {
            return Err(Error::Config(
                "dynamic channel with churn requires optimize_resources: a \
                 fixed uniform decision cannot follow membership changes"
                    .into(),
            ));
        }
        let avg = ChannelRealization::average(&sc.roster);
        let base = Problem {
            cfg: net,
            profile,
            dep: &sc.roster,
            ch: &avg,
            batch: cfg.train.batch,
            phi: opts.framework.phi(),
        };
        let d = crate::optim::baselines::uniform_decision(&base, cut);
        sc.rounds
            .iter()
            .map(|round| {
                let prob = Problem {
                    cfg: net,
                    profile,
                    dep: &round.dep,
                    ch: &round.ch,
                    batch: cfg.train.batch,
                    phi: opts.framework.phi(),
                };
                let (up, dn, bc) = prob.rates(&d);
                SimRound {
                    f_clients: round.dep.f_clients().to_vec(),
                    uplink: up,
                    downlink: dn,
                    broadcast: bc,
                }
            })
            .collect()
    };
    Ok(SimLatency {
        rounds,
        cut: cut.into(),
        batch: cfg.train.batch,
        f_server: net.f_server,
        kappa_server: net.kappa_server,
        kappa_client: net.kappa_client,
        uplink_comp: net.uplink_compression,
        mode: opts.timeline_mode,
    })
}

/// Fail fast when the fixed-shape eval artifact can never see one full
/// chunk: every chunk would hit the ragged-tail `break` in
/// [`Session::evaluate`] and the accuracy column would be silently
/// missing for the whole run.
pub(crate) fn check_eval_batch(test_size: usize, eval_batch: usize)
    -> Result<()> {
    if test_size < eval_batch {
        return Err(Error::Config(format!(
            "test_size {test_size} < eval_batch {eval_batch}: evaluation \
             would drop every chunk and report NaN accuracy — raise \
             test_size to at least the artifact eval batch"
        )));
    }
    Ok(())
}

/// Build the aggregation mask for ⌈φb⌉ slots.
pub(crate) fn mask_vec(phi: f64, b: usize) -> Vec<f32> {
    let m = (phi * b as f64).ceil() as usize;
    (0..b).map(|j| if j < m { 1.0 } else { 0.0 }).collect()
}

impl<'a> Session<'a> {
    /// Cached aggregation mask for this φ (host copy + literal).
    pub(crate) fn mask_for(&mut self, phi: f64)
        -> Result<(Vec<f32>, Literal)> {
        let key = phi.to_bits();
        if let Some((v, l)) = self.mask_cache.get(&key) {
            return Ok((v.clone(), l.clone()));
        }
        let v = mask_vec(phi, self.fam.batch);
        let l = literal_f32(&[self.fam.batch], &v)?;
        self.mask_cache.insert(key, (v.clone(), l.clone()));
        Ok((v, l))
    }

    pub(crate) fn batch_literals(&mut self, client: usize)
        -> Result<(Literal, Vec<f32>, Vec<i32>)> {
        let b = self.fam.batch;
        let idx = self.shards[client].sample_batch(b, &mut self.rng);
        let (imgs, labels) = self.train_set.gather(&idx);
        let x = literal_f32(
            &[b, self.fam.img, self.fam.img, self.fam.channels],
            &imgs,
        )?;
        Ok((x, imgs, labels))
    }

    /// Test accuracy of the λ-averaged model (full test set, chunked).
    ///
    /// Under a mixed cut assignment the client models have different
    /// shapes, so one global FedAvg is undefined: each cut group
    /// λ-averages its own members, joins them with the server sub-suffix
    /// at its cut, and the reported accuracy is the λ-mass-weighted mean
    /// of the group accuracies (for an all-equal assignment this is the
    /// literal single-model path, bit-identical).
    pub(crate) fn evaluate(&mut self, client_params: &[Vec<Literal>],
                           server_params: &[Literal]) -> Result<f64> {
        let fam = self.fam;
        let mixed = self.cuts.windows(2).any(|w| w[0] != w[1]);
        if !mixed {
            let cut =
                self.cuts.first().copied().unwrap_or(self.opts.cut);
            let avg_client = if client_params.len() == 1 {
                client_params[0].clone()
            } else {
                fedavg(client_params, &self.lam, fam, cut)?
            };
            let full = ParamSet::join(&avg_client, server_params);
            let (correct, total) = self.eval_model(&full)?;
            return Ok(correct / total);
        }
        let j_min = *self.cuts.iter().min().ok_or_else(|| {
            Error::Runtime("evaluate: session has no client cuts".into())
        })?;
        let n_min = client_tensor_count(fam, j_min)?;
        let lam_total: f64 =
            self.lam.iter().map(|&w| w as f64).sum();
        let groups = CutAssignment::PerClient(self.cuts.clone())
            .groups(self.cuts.len());
        let mut acc = 0.0f64;
        for (cut, members) in groups {
            let n_cut = client_tensor_count(fam, cut)?;
            let off = n_cut - n_min;
            let avg_client = if members.len() == 1 {
                client_params[members[0]].clone()
            } else {
                let subset: Vec<Vec<Literal>> = members
                    .iter()
                    .map(|&i| client_params[i].clone())
                    .collect();
                let w = renormalized_lambda(&self.lam, &members);
                fedavg(&subset, &w, fam, cut)?
            };
            let full =
                ParamSet::join(&avg_client, &server_params[off..]);
            let (correct, total) = self.eval_model(&full)?;
            let w_g: f64 = members
                .iter()
                .map(|&i| self.lam[i] as f64)
                .sum::<f64>()
                / lam_total;
            acc += w_g * (correct / total);
        }
        Ok(acc)
    }

    /// Chunked full-test-set pass of one assembled model: returns
    /// `(correct, total)` over every full eval chunk.
    fn eval_model(&self, full: &[Literal]) -> Result<(f64, f64)> {
        let fam = self.fam;
        let eb = fam.eval_batch;
        let mut correct = 0.0;
        let mut total = 0.0;
        let img_len = self.test_set.image_len();
        let n_chunks = self.test_set.n / eb;
        for chunk in 0..n_chunks.max(1) {
            let lo = chunk * eb;
            let hi = ((chunk + 1) * eb).min(self.test_set.n);
            if hi - lo < eb {
                break; // artifacts are fixed-shape; drop the ragged tail
            }
            let idx: Vec<usize> = (lo..hi).collect();
            let (imgs, labels) = self.test_set.gather(&idx);
            debug_assert_eq!(imgs.len(), eb * img_len);
            let mut inputs: Vec<Literal> = full.to_vec();
            inputs.push(literal_f32(
                &[eb, fam.img, fam.img, fam.channels],
                &imgs,
            )?);
            inputs.push(literal_i32(&[eb], &labels)?);
            let out = self.rt.call(&fam.eval, &inputs)?;
            correct += scalar_f32(&out[1])? as f64;
            total += eb as f64;
        }
        if total == 0.0 {
            // train() rejects this up front (check_eval_batch); kept as a
            // defensive guard against silently reporting NaN accuracy.
            return Err(Error::Data(format!(
                "evaluate: test set of {} samples yields no full \
                 eval chunk (eval_batch {eb})",
                self.test_set.n
            )));
        }
        Ok((correct, total))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_vec_counts() {
        assert_eq!(mask_vec(0.5, 32).iter().sum::<f32>(), 16.0);
        assert_eq!(mask_vec(0.0, 32).iter().sum::<f32>(), 0.0);
        assert_eq!(mask_vec(1.0, 32).iter().sum::<f32>(), 32.0);
        assert_eq!(mask_vec(0.01, 32).iter().sum::<f32>(), 1.0);
    }

    #[test]
    fn small_test_set_fails_fast() {
        // Pre-fix, test_size < eval_batch made every eval chunk hit the
        // ragged-tail break and the run reported no accuracy at all; now
        // it is rejected up front with a descriptive error.
        assert!(check_eval_batch(100, 256).is_err());
        assert!(check_eval_batch(256, 256).is_ok());
        assert!(check_eval_batch(300, 256).is_ok());
        let e = check_eval_batch(10, 64).unwrap_err();
        assert!(e.to_string().contains("NaN"), "{e}");
        assert!(e.to_string().contains("eval_batch 64"), "{e}");
    }

    #[test]
    fn sim_latency_static_is_single_frozen_entry() {
        let cfg = Config::new();
        let opts = TrainerOptions::default();
        let mut rng = Rng::new(1);
        let s = build_sim_latency(&cfg, &opts, &mut rng).unwrap();
        assert_eq!(s.rounds.len(), 1);
        let t = s.round_seconds(0, opts.framework, 0.5);
        assert!(t > 0.0);
        // Any round index maps onto the frozen entry.
        assert_eq!(
            t.to_bits(),
            s.round_seconds(99, opts.framework, 0.5).to_bits()
        );
    }

    #[test]
    fn sim_latency_static_decision_bit_identical_to_prefix_construction() {
        // Regression guard for the single-allocation fix: the frozen-draw
        // rates must match the pre-fix double-rss_allocation construction
        // bit for bit (same RNG stream, same decision).
        let cfg = Config::new();
        let opts = TrainerOptions::default();
        let mut rng = Rng::new(3);
        let s = build_sim_latency(&cfg, &opts, &mut rng).unwrap();
        let mut rng = Rng::new(3);
        let net = cfg.net.clone().with_clients(opts.n_clients);
        let dep = Deployment::generate(&net, &mut rng);
        let ch = ChannelRealization::average(&dep);
        let profile = resnet18::profile_static();
        let prob = Problem {
            cfg: &net,
            profile,
            dep: &dep,
            ch: &ch,
            batch: cfg.train.batch,
            phi: opts.framework.phi(),
        };
        // The pre-fix construction: two independent rss_allocation calls.
        let psd = crate::optim::baselines::uniform_power(
            &prob,
            &crate::optim::baselines::rss_allocation(&prob),
        );
        let alloc = crate::optim::baselines::rss_allocation(&prob);
        let legacy = Decision {
            alloc,
            psd_dbm_hz: psd,
            cut: crate::coordinator::resnet18_cut_for_splitnet(opts.cut)
                .into(),
        };
        let (up, dn, bc) = prob.rates(&legacy);
        assert_eq!(s.rounds[0].uplink, up);
        assert_eq!(s.rounds[0].downlink, dn);
        assert_eq!(s.rounds[0].broadcast.to_bits(), bc.to_bits());
    }

    #[test]
    fn barrier_sim_matches_closed_form_and_pipelined_is_leq() {
        // The timeline refactor contract at the SimLatency layer: barrier
        // mode reproduces round_latency bit for bit; pipelined mode (same
        // RNG stream, same rates) never reports a slower round.
        use crate::latency::frameworks::round_latency;
        let cfg = Config::new();
        let barrier_opts = TrainerOptions::default();
        let pipe_opts = TrainerOptions {
            timeline_mode: Mode::Pipelined,
            ..TrainerOptions::default()
        };
        let mut rng = Rng::new(7);
        let sb = build_sim_latency(&cfg, &barrier_opts, &mut rng).unwrap();
        let mut rng = Rng::new(7);
        let sp = build_sim_latency(&cfg, &pipe_opts, &mut rng).unwrap();
        for fw in [
            Framework::Epsl { phi: 0.5 },
            Framework::Psl,
            Framework::Sfl,
            Framework::VanillaSl,
        ] {
            let tb = sb.round_seconds(0, fw, fw.phi());
            let tp = sp.round_seconds(0, fw, fw.phi());
            let r = &sb.rounds[0];
            let inp = LatencyInputs {
                profile: resnet18::profile_static(),
                cut: sb.cut.as_uniform().unwrap(),
                batch: sb.batch,
                phi: fw.phi(),
                f_server: sb.f_server,
                kappa_server: sb.kappa_server,
                kappa_client: sb.kappa_client,
                f_clients: &r.f_clients,
                uplink: &r.uplink,
                downlink: &r.downlink,
                broadcast: r.broadcast,
                uplink_comp: sb.uplink_comp,
            };
            let closed = round_latency(fw, &inp).round_total();
            assert_eq!(tb.to_bits(), closed.to_bits(), "{}", fw.name());
            assert!(tp <= tb, "{}: {tp} > {tb}", fw.name());
        }
        // The Table-III deployment is heterogeneous (compute draws +
        // distance-dependent gains): EPSL must strictly gain.
        let tb = sb.round_seconds(0, Framework::Epsl { phi: 0.5 }, 0.5);
        let tp = sp.round_seconds(0, Framework::Epsl { phi: 0.5 }, 0.5);
        assert!(tp < tb, "no pipelining gain on heterogeneous fixture");
    }

    #[test]
    fn sim_latency_dynamic_tracks_the_scenario() {
        use crate::scenario::{ReoptPolicy, ScenarioSpec};
        let cfg = Config::new();
        let opts = TrainerOptions {
            rounds: 6,
            dynamic_channel: Some(DynamicChannel {
                spec: ScenarioSpec::fading(6),
                policy: ReoptPolicy::Never,
            }),
            ..Default::default()
        };
        let mut rng = Rng::new(2);
        let s = build_sim_latency(&cfg, &opts, &mut rng).unwrap();
        assert_eq!(s.rounds.len(), 6, "one entry per training round");
        let t0 = s.round_seconds(0, opts.framework, 0.5);
        assert!(t0 > 0.0);
        assert!(
            (1..6).any(|r| s.round_seconds(r, opts.framework, 0.5) != t0),
            "per-round fading never moved the simulated latency"
        );
    }

    #[test]
    fn dynamic_policy_without_optimizer_rejected() {
        use crate::scenario::{ReoptPolicy, ScenarioSpec};
        let cfg = Config::new();
        let opts = TrainerOptions {
            rounds: 3,
            dynamic_channel: Some(DynamicChannel {
                spec: ScenarioSpec::fading(3),
                policy: ReoptPolicy::EveryK(1),
            }),
            ..Default::default()
        };
        let mut rng = Rng::new(5);
        let e = build_sim_latency(&cfg, &opts, &mut rng).unwrap_err();
        assert!(e.to_string().contains("optimize_resources"), "{e}");
    }

    #[test]
    fn sim_latency_dynamic_with_optimizer_and_policy() {
        use crate::scenario::{ReoptPolicy, ScenarioSpec};
        let cfg = Config::new();
        let opts = TrainerOptions {
            n_clients: 3,
            rounds: 4,
            optimize_resources: true,
            dynamic_channel: Some(DynamicChannel {
                spec: ScenarioSpec::fading(4),
                policy: ReoptPolicy::EveryK(2),
            }),
            ..Default::default()
        };
        let mut rng = Rng::new(4);
        let s = build_sim_latency(&cfg, &opts, &mut rng).unwrap();
        assert_eq!(s.rounds.len(), 4);
        for r in 0..4 {
            assert!(s.round_seconds(r, opts.framework, 0.5) > 0.0);
        }
    }

    #[test]
    fn churn_below_quorum_is_a_structured_error() {
        // Satellite: a scenario round whose churned cohort falls below
        // the fault policy's quorum floor must fail up front with a
        // structured error naming the offending round — not deep inside
        // the optimizer with a shape panic.
        use crate::scenario::{ChurnSpec, FaultSpec, ReoptPolicy,
                              ScenarioSpec};
        let cfg = Config::new();
        let spec = ScenarioSpec {
            churn: Some(ChurnSpec {
                drop_prob: 1.0,
                rejoin_prob: 0.0,
                min_active: 1,
            }),
            ..ScenarioSpec::fading(6)
        };
        let opts = TrainerOptions {
            rounds: 6,
            optimize_resources: true,
            dynamic_channel: Some(DynamicChannel {
                spec,
                policy: ReoptPolicy::EveryK(1),
            }),
            faults: Some(FaultSpec { quorum: 5, ..Default::default() }),
            seed: 2,
            ..Default::default()
        };
        let mut rng = Rng::new(opts.seed);
        let e = build_sim_latency(&cfg, &opts, &mut rng).unwrap_err();
        match e {
            Error::Quorum { round, active, need } => {
                assert!(round < 6, "round {round} out of range");
                assert!(active < 5, "active {active} not below quorum");
                assert_eq!(need, 5);
            }
            other => panic!("expected Error::Quorum, got: {other}"),
        }
        assert!(e_string_names_round(&opts, &cfg));
    }

    /// The quorum error's Display must name the round (checked through a
    /// fresh run so the matched-out error above stays structural).
    fn e_string_names_round(opts: &TrainerOptions, cfg: &Config) -> bool {
        let mut rng = Rng::new(opts.seed);
        let e = build_sim_latency(cfg, opts, &mut rng).unwrap_err();
        let s = e.to_string();
        s.contains("round") && s.contains("quorum")
    }

    #[test]
    fn explicit_all_equal_cut_resolves_to_uniform() {
        // An all-equal explicit vector must be indistinguishable from the
        // scalar uniform path — same assignment, bit-identical latency.
        let cfg = Config::new();
        let uni = TrainerOptions::default();
        let expl = TrainerOptions {
            cut_mode: CutMode::Explicit(vec![2; 5]),
            ..Default::default()
        };
        let mut rng = Rng::new(11);
        let a = build_sim_latency(&cfg, &uni, &mut rng).unwrap();
        let mut rng = Rng::new(11);
        let b = build_sim_latency(&cfg, &expl, &mut rng).unwrap();
        assert_eq!(a.cut, b.cut);
        assert_eq!(b.cut.as_uniform(), Some(4)); // stage 2 ↔ layer 4
        assert_eq!(
            a.round_seconds(0, uni.framework, 0.5).to_bits(),
            b.round_seconds(0, uni.framework, 0.5).to_bits()
        );
    }

    #[test]
    fn explicit_mixed_cut_prices_per_client_payloads() {
        let cfg = Config::new();
        let uni = TrainerOptions::default();
        let mixd = TrainerOptions {
            cut_mode: CutMode::Explicit(vec![1, 2, 2, 3, 4]),
            ..Default::default()
        };
        let mut rng = Rng::new(12);
        let a = build_sim_latency(&cfg, &uni, &mut rng).unwrap();
        let mut rng = Rng::new(12);
        let b = build_sim_latency(&cfg, &mixd, &mut rng).unwrap();
        assert!(b.cut.as_uniform().is_none());
        let ta = a.round_seconds(0, uni.framework, 0.5);
        let tb = b.round_seconds(0, mixd.framework, 0.5);
        assert!(tb > 0.0 && tb.is_finite());
        assert_ne!(ta.to_bits(), tb.to_bits());
        // One uplink arrival per client: the straggler-deadline machinery
        // keeps per-client meaning under mixed cuts.
        assert_eq!(b.uplink_arrivals(0, mixd.framework, 0.5).len(), 5);
    }

    #[test]
    fn hetero_cut_mode_never_slower_than_uniform() {
        let cfg = Config::new();
        let uni = TrainerOptions {
            optimize_resources: true,
            ..Default::default()
        };
        let het = TrainerOptions {
            optimize_resources: true,
            cut_mode: CutMode::Hetero,
            ..Default::default()
        };
        let mut rng = Rng::new(13);
        let a = build_sim_latency(&cfg, &uni, &mut rng).unwrap();
        let mut rng = Rng::new(13);
        let b = build_sim_latency(&cfg, &het, &mut rng).unwrap();
        let ta = a.round_seconds(0, uni.framework, 0.5);
        let tb = b.round_seconds(0, het.framework, 0.5);
        assert!(tb <= ta, "hetero {tb} > uniform {ta}");
        // Executable contract: every refined cut maps to a SplitNet stage.
        for j in b.cut.cuts_for(5) {
            assert!(try_splitnet_cut_for_resnet18(j).is_ok(), "{j}");
        }
    }

    #[test]
    fn mixed_cut_with_dynamic_channel_rejected() {
        use crate::scenario::{ReoptPolicy, ScenarioSpec};
        let cfg = Config::new();
        let opts = TrainerOptions {
            rounds: 3,
            cut_mode: CutMode::Explicit(vec![1, 2, 2, 3, 4]),
            dynamic_channel: Some(DynamicChannel {
                spec: ScenarioSpec::fading(3),
                policy: ReoptPolicy::Never,
            }),
            ..Default::default()
        };
        let mut rng = Rng::new(6);
        let e = build_sim_latency(&cfg, &opts, &mut rng).unwrap_err();
        assert!(e.to_string().contains("static channel"), "{e}");
    }

    #[test]
    fn explicit_cut_vector_shape_and_range_validated() {
        let cfg = Config::new();
        let mut rng = Rng::new(8);
        let bad_len = TrainerOptions {
            cut_mode: CutMode::Explicit(vec![1, 2]), // run has 5 clients
            ..Default::default()
        };
        let e = build_sim_latency(&cfg, &bad_len, &mut rng).unwrap_err();
        assert!(e.to_string().contains("5 client"), "{e}");
        let mut rng = Rng::new(8);
        let bad_range = TrainerOptions {
            cut_mode: CutMode::Explicit(vec![1, 2, 3, 4, 7]),
            ..Default::default()
        };
        let e = build_sim_latency(&cfg, &bad_range, &mut rng).unwrap_err();
        assert!(e.to_string().contains("out of 1..=4"), "{e}");
    }
}
