//! Crate-wide error type.

use std::fmt;

/// Unified error for the EPSL library.
#[derive(Debug)]
pub enum Error {
    /// Configuration parse / validation failure.
    Config(String),
    /// Artifact manifest or HLO loading failure.
    Artifact(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// Optimizer infeasibility or numerical failure.
    Optim(String),
    /// Dataset construction / partitioning failure.
    Data(String),
    /// I/O error with context.
    Io(String),
    /// Fault-injection / recovery failure (bad fault spec, unusable
    /// checkpoint, unrecoverable injected fault).
    Fault(String),
    /// A round's surviving cohort fell below the configured quorum —
    /// structured so callers can name the failing round directly.
    Quorum {
        round: usize,
        /// Clients still present when the round tried to commit.
        active: usize,
        /// Configured quorum floor.
        need: usize,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Optim(m) => write!(f, "optimizer error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::Fault(m) => write!(f, "fault error: {m}"),
            Error::Quorum { round, active, need } => write!(
                f,
                "quorum error: round {round} committed with {active} \
                 active client(s), below the quorum of {need}"
            ),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        assert!(Error::Config("x".into()).to_string().contains("config"));
        assert!(Error::Runtime("y".into()).to_string().contains("runtime"));
        assert!(Error::Optim("z".into()).to_string().contains("optimizer"));
        assert!(Error::Fault("w".into()).to_string().contains("fault"));
    }

    #[test]
    fn quorum_names_the_round() {
        let e = Error::Quorum { round: 7, active: 1, need: 3 };
        let s = e.to_string();
        assert!(s.contains("round 7"), "{s}");
        assert!(s.contains("1 active"), "{s}");
        assert!(s.contains("quorum of 3"), "{s}");
    }

    #[test]
    fn from_io() {
        let e: Error =
            std::io::Error::new(std::io::ErrorKind::NotFound, "nope").into();
        assert!(matches!(e, Error::Io(_)));
    }
}
