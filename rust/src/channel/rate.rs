//! Link-rate expressions — paper eqs. (14), (18), (20).
//!
//! All powers are spectral densities: the transmit PSD `p` (dBm/Hz), the
//! noise PSD σ² (dBm/Hz) and the antenna gain / channel gain are linear
//! factors, so the per-subchannel SNR is dimensionless:
//! `SNR = p·G_c·G_s·γ / σ²`.

use crate::config::NetworkConfig;

use super::ChannelRealization;

/// A subchannel→client assignment: `owner[k] = Some(i)` means subchannel k
/// is allocated to client i (constraints C1/C2: at most one owner each).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allocation {
    pub owner: Vec<Option<usize>>,
}

impl Allocation {
    pub fn empty(n_subchannels: usize) -> Self {
        Allocation { owner: vec![None; n_subchannels] }
    }

    /// Subchannels owned by client `i`.
    pub fn channels_of(&self, i: usize) -> Vec<usize> {
        self.owner
            .iter()
            .enumerate()
            .filter_map(|(k, o)| (*o == Some(i)).then_some(k))
            .collect()
    }

    /// Number of subchannels owned by client `i` (M_i).
    pub fn count_of(&self, i: usize) -> usize {
        self.owner.iter().filter(|o| **o == Some(i)).count()
    }

    pub fn assign(&mut self, subch: usize, client: usize) {
        self.owner[subch] = Some(client);
    }

    pub fn is_complete(&self) -> bool {
        self.owner.iter().all(Option::is_some)
    }
}

/// Linear SNR from PSDs in dBm/Hz and linear gains.
#[inline]
pub fn snr_linear(p_dbm_hz: f64, antenna_gain: f64, channel_gain: f64,
                  noise_dbm_hz: f64) -> f64 {
    let num_db = p_dbm_hz + 10.0 * (antenna_gain * channel_gain).log10();
    10f64.powf((num_db - noise_dbm_hz) / 10.0)
}

/// Shannon rate of one subchannel (bits/s).
#[inline]
pub fn subchannel_rate(bandwidth_hz: f64, snr: f64) -> f64 {
    bandwidth_hz * (1.0 + snr).log2()
}

/// Eq. (14): uplink rate of every client under allocation `alloc` with
/// per-subchannel transmit PSDs `p_dbm_hz[k]`. (The optimizer's
/// allocation-free fast path lives in `optim::eval::Evaluator::fill_rates`,
/// which mirrors this summation bit-for-bit.)
pub fn uplink_rates(cfg: &NetworkConfig, ch: &ChannelRealization,
                    alloc: &Allocation, p_dbm_hz: &[f64]) -> Vec<f64> {
    let n_clients = ch.gain.len();
    let mut rates = vec![0.0; n_clients];
    for (k, owner) in alloc.owner.iter().enumerate() {
        if let Some(i) = owner {
            let snr = snr_linear(
                p_dbm_hz[k],
                cfg.antenna_gain,
                ch.gain[*i][k],
                cfg.noise_dbm_hz,
            );
            rates[*i] += subchannel_rate(cfg.subchannel_bw_hz, snr);
        }
    }
    rates
}

/// Eq. (20): downlink (server→client i) rate over client i's subchannels at
/// the server PSD p^DL.
pub fn downlink_rates(cfg: &NetworkConfig, ch: &ChannelRealization,
                      alloc: &Allocation) -> Vec<f64> {
    let n_clients = ch.gain.len();
    let mut rates = vec![0.0; n_clients];
    for (k, owner) in alloc.owner.iter().enumerate() {
        if let Some(i) = owner {
            let snr = snr_linear(
                cfg.p_dl_dbm_hz,
                cfg.antenna_gain,
                ch.gain[*i][k],
                cfg.noise_dbm_hz,
            );
            rates[*i] += subchannel_rate(cfg.subchannel_bw_hz, snr);
        }
    }
    rates
}

/// Eq. (18): broadcast rate over *all* M subchannels, limited by the
/// weakest gain γ_w across clients and subchannels.
pub fn broadcast_rate(cfg: &NetworkConfig, ch: &ChannelRealization) -> f64 {
    let gw = ch.worst_gain();
    let snr = snr_linear(
        cfg.p_dl_dbm_hz,
        cfg.antenna_gain,
        gw,
        cfg.noise_dbm_hz,
    );
    cfg.n_subchannels as f64
        * subchannel_rate(cfg.subchannel_bw_hz, snr)
}

/// Uniform-power helper: spread a device power budget `p_total_dbm` (dBm)
/// uniformly over `n` subchannels of bandwidth `bw`, returning the PSD in
/// dBm/Hz. (Baselines a/d set power uniformly.)
pub fn uniform_psd_dbm_hz(p_total_dbm: f64, n: usize, bw_hz: f64) -> f64 {
    p_total_dbm - 10.0 * ((n.max(1) as f64) * bw_hz).log10()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Deployment;
    use crate::util::rng::Rng;

    fn setup() -> (NetworkConfig, ChannelRealization, Deployment) {
        let cfg = NetworkConfig::default();
        let mut rng = Rng::new(7);
        let dep = Deployment::generate(&cfg, &mut rng);
        let ch = ChannelRealization::average(&dep);
        (cfg, ch, dep)
    }

    #[test]
    fn allocation_bookkeeping() {
        let mut a = Allocation::empty(4);
        assert!(!a.is_complete());
        a.assign(0, 1);
        a.assign(2, 1);
        a.assign(1, 0);
        a.assign(3, 2);
        assert!(a.is_complete());
        assert_eq!(a.channels_of(1), vec![0, 2]);
        assert_eq!(a.count_of(1), 2);
        assert_eq!(a.count_of(3), 0);
    }

    #[test]
    fn snr_db_arithmetic() {
        // p = -60 dBm/Hz, G*γ = 1 (0 dB), σ² = -174 dBm/Hz → SNR = 114 dB.
        let snr = snr_linear(-60.0, 1.0, 1.0, -174.0);
        assert!((10.0 * snr.log10() - 114.0).abs() < 1e-9);
    }

    #[test]
    fn shannon_rate_monotone_in_snr() {
        assert!(subchannel_rate(10e6, 100.0) > subchannel_rate(10e6, 10.0));
        assert_eq!(subchannel_rate(10e6, 0.0), 0.0);
    }

    #[test]
    fn uplink_sums_over_owned_channels() {
        let (cfg, ch, _dep) = setup();
        let mut alloc = Allocation::empty(cfg.n_subchannels);
        for k in 0..cfg.n_subchannels {
            alloc.assign(k, k % cfg.n_clients);
        }
        let p = vec![-60.0; cfg.n_subchannels];
        let rates = uplink_rates(&cfg, &ch, &alloc, &p);
        assert_eq!(rates.len(), 5);
        assert!(rates.iter().all(|&r| r > 0.0));
        // Removing a channel strictly reduces its owner's rate.
        let mut alloc2 = alloc.clone();
        alloc2.owner[0] = None;
        let owner = alloc.owner[0].unwrap();
        let rates2 = uplink_rates(&cfg, &ch, &alloc2, &p);
        assert!(rates2[owner] < rates[owner]);
    }

    #[test]
    fn broadcast_rate_uses_worst_gain() {
        let (cfg, ch, _dep) = setup();
        let r = broadcast_rate(&cfg, &ch);
        assert!(r > 0.0);
        // Weakening the worst link lowers the broadcast rate.
        let mut ch2 = ch.clone();
        ch2.gain[0][0] = ch2.worst_gain() / 100.0;
        assert!(broadcast_rate(&cfg, &ch2) < r);
    }

    #[test]
    fn more_power_more_rate() {
        let (cfg, ch, _dep) = setup();
        let mut alloc = Allocation::empty(cfg.n_subchannels);
        alloc.assign(0, 0);
        let lo = uplink_rates(&cfg, &ch, &alloc, &vec![-70.0; 20])[0];
        let hi = uplink_rates(&cfg, &ch, &alloc, &vec![-50.0; 20])[0];
        assert!(hi > lo);
    }

    #[test]
    fn uniform_psd_conserves_budget() {
        // 31.76 dBm over 4 channels of 10 MHz = PSD such that
        // psd + 10log10(4*10e6) = 31.76.
        let psd = uniform_psd_dbm_hz(31.76, 4, 10e6);
        assert!((psd + 10.0 * (4.0 * 10e6f64).log10() - 31.76).abs() < 1e-9);
    }
}
