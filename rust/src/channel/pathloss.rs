//! mmWave path-loss model (Samimi–Rappaport, per paper §VII-A).
//!
//! Close-in free-space-reference model:
//! `PL(f, d)[dB] = FSPL(f, 1 m) + 10·n·log10(d)` with path-loss exponents
//! n = 2.1 (LoS) / 3.4 (NLoS) and lognormal shadow fading with standard
//! deviation 3.6 dB (LoS) / 9.7 dB (NLoS) — exactly the paper's constants
//! from [42]. LoS probability follows the 3GPP UMi street-canyon model
//! (the paper does not specify one; documented substitution in DESIGN.md).

use crate::util::rng::Rng;

/// Paper constants from [42] (Samimi et al.).
pub const LOS_PLE: f64 = 2.1;
pub const NLOS_PLE: f64 = 3.4;
pub const LOS_SHADOW_DB: f64 = 3.6;
pub const NLOS_SHADOW_DB: f64 = 9.7;

const SPEED_OF_LIGHT: f64 = 2.997_924_58e8;

/// Free-space path loss at 1 m reference distance, in dB.
pub fn fspl_1m_db(freq_hz: f64) -> f64 {
    20.0 * (4.0 * std::f64::consts::PI * freq_hz / SPEED_OF_LIGHT).log10()
}

/// 3GPP UMi street-canyon LoS probability at distance `d` (m).
pub fn los_probability(d_m: f64) -> f64 {
    if d_m <= 18.0 {
        return 1.0;
    }
    (18.0 / d_m) * (1.0 - (-d_m / 36.0).exp()) + (-d_m / 36.0).exp()
}

/// Mean path loss in dB (no shadowing) at frequency `f`, distance `d`.
pub fn path_loss_db(freq_hz: f64, d_m: f64, los: bool) -> f64 {
    let d = d_m.max(1.0); // CI model reference distance
    let n = if los { LOS_PLE } else { NLOS_PLE };
    fspl_1m_db(freq_hz) + 10.0 * n * d.log10()
}

/// Mean (average) linear channel gain γ(F_k, d_i) — the paper's
/// deterministic gain used for resource management.
pub fn mean_gain(freq_hz: f64, d_m: f64, los: bool) -> f64 {
    10f64.powf(-path_loss_db(freq_hz, d_m, los) / 10.0)
}

/// One shadow-fading realization: mean gain perturbed by lognormal
/// shadowing with the LoS/NLoS standard deviation.
pub fn sample_gain(freq_hz: f64, d_m: f64, los: bool, rng: &mut Rng) -> f64 {
    let sigma = if los { LOS_SHADOW_DB } else { NLOS_SHADOW_DB };
    let shadow_db = rng.normal(0.0, sigma);
    10f64.powf(-(path_loss_db(freq_hz, d_m, los) + shadow_db) / 10.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fspl_28ghz_near_61db() {
        // FSPL(1 m, 28 GHz) ≈ 61.4 dB (textbook value).
        let v = fspl_1m_db(28e9);
        assert!((v - 61.38).abs() < 0.1, "{v}");
    }

    #[test]
    fn path_loss_increases_with_distance_and_nlos() {
        let f = 28e9;
        assert!(path_loss_db(f, 100.0, true) > path_loss_db(f, 10.0, true));
        assert!(path_loss_db(f, 100.0, false) > path_loss_db(f, 100.0, true));
        // LoS slope: 21 dB/decade.
        let slope =
            path_loss_db(f, 100.0, true) - path_loss_db(f, 10.0, true);
        assert!((slope - 21.0).abs() < 1e-9);
        let slope_n =
            path_loss_db(f, 100.0, false) - path_loss_db(f, 10.0, false);
        assert!((slope_n - 34.0).abs() < 1e-9);
    }

    #[test]
    fn gain_is_inverse_of_loss() {
        let g = mean_gain(28e9, 50.0, true);
        let pl = path_loss_db(28e9, 50.0, true);
        assert!((10.0 * g.log10() + pl).abs() < 1e-9);
        assert!(g > 0.0 && g < 1.0);
    }

    #[test]
    fn los_probability_monotone() {
        assert_eq!(los_probability(5.0), 1.0);
        assert!(los_probability(50.0) > los_probability(100.0));
        assert!(los_probability(200.0) > 0.0);
        assert!(los_probability(200.0) < 0.2);
    }

    #[test]
    fn shadowing_statistics() {
        let mut rng = Rng::new(1);
        let n = 20_000;
        let mean_db = path_loss_db(28e9, 80.0, false);
        let mut db_samples = Vec::with_capacity(n);
        for _ in 0..n {
            let g = sample_gain(28e9, 80.0, false, &mut rng);
            db_samples.push(-10.0 * g.log10() - mean_db);
        }
        let m = crate::util::stats::mean(&db_samples);
        let s = crate::util::stats::std_dev(&db_samples);
        assert!(m.abs() < 0.3, "shadow mean {m}");
        assert!((s - NLOS_SHADOW_DB).abs() < 0.3, "shadow std {s}");
    }

    #[test]
    fn higher_frequency_more_loss() {
        assert!(path_loss_db(38e9, 50.0, true) > path_loss_db(28e9, 50.0, true));
    }
}
