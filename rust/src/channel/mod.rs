//! Wireless-edge deployment and channel simulator.
//!
//! Implements the paper's system model (§III, §V): C client devices placed
//! uniformly in a disc of radius `d_max` around the edge server, M FDMA
//! subchannels of bandwidth `B` at mmWave center frequencies, per-link mean
//! gains γ(F_k, d_i) from [`pathloss`], and the three link-rate expressions
//! (eqs. 14, 18, 20) in [`rate`].

pub mod pathloss;
pub mod rate;

use crate::config::NetworkConfig;
use crate::util::rng::Rng;

/// One FDMA subchannel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Subchannel {
    pub index: usize,
    /// Center frequency F_k (Hz).
    pub center_freq_hz: f64,
    /// Bandwidth B_k (Hz).
    pub bandwidth_hz: f64,
}

/// One client device's link + compute state.
#[derive(Debug, Clone, Copy)]
pub struct ClientLink {
    /// Distance d_i to the server (m).
    pub distance_m: f64,
    /// Computing capability f_i (cycles/s).
    pub f_client: f64,
    /// LoS / NLoS state (drawn once per deployment).
    pub los: bool,
}

/// A generated deployment: client placement + subchannel plan.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub clients: Vec<ClientLink>,
    pub subchannels: Vec<Subchannel>,
    /// Cached per-client compute capabilities (kept in sync with `clients`;
    /// call [`Deployment::refresh_f_clients`] after mutating them in place).
    f_clients: Vec<f64>,
}

impl Deployment {
    /// Assemble a deployment, building the `f_clients` cache.
    pub fn new(clients: Vec<ClientLink>, subchannels: Vec<Subchannel>)
        -> Deployment {
        let f_clients = clients.iter().map(|c| c.f_client).collect();
        Deployment { clients, subchannels, f_clients }
    }

    /// Generate per the paper's simulation setup (§VII-A): clients uniform
    /// in the coverage disc, f_i uniform in the configured range, LoS drawn
    /// from the distance-dependent probability, contiguous subchannels from
    /// the base frequency.
    pub fn generate(cfg: &NetworkConfig, rng: &mut Rng) -> Deployment {
        let clients = (0..cfg.n_clients)
            .map(|_| {
                let (x, y) = rng.in_disc(cfg.d_max_m);
                let d = (x * x + y * y).sqrt().max(1.0);
                let f =
                    rng.uniform(cfg.f_client_range.0, cfg.f_client_range.1);
                let los = rng.chance(pathloss::los_probability(d));
                ClientLink { distance_m: d, f_client: f, los }
            })
            .collect();
        let subchannels = (0..cfg.n_subchannels)
            .map(|k| Subchannel {
                index: k,
                center_freq_hz: cfg.base_freq_hz
                    + (k as f64 + 0.5) * cfg.subchannel_bw_hz,
                bandwidth_hz: cfg.subchannel_bw_hz,
            })
            .collect();
        Deployment::new(clients, subchannels)
    }

    pub fn n_clients(&self) -> usize {
        self.clients.len()
    }

    pub fn n_subchannels(&self) -> usize {
        self.subchannels.len()
    }

    /// Mean linear gain γ(F_k, d_i) (deterministic; the optimizer's view).
    pub fn mean_gain(&self, client: usize, subch: usize) -> f64 {
        let c = &self.clients[client];
        let s = &self.subchannels[subch];
        pathloss::mean_gain(s.center_freq_hz, c.distance_m, c.los)
    }

    /// Client compute capabilities as a slice (no per-call allocation —
    /// this sits on the optimizer's objective hot path).
    pub fn f_clients(&self) -> &[f64] {
        debug_assert!(
            self.f_clients.len() == self.clients.len()
                && self
                    .f_clients
                    .iter()
                    .zip(&self.clients)
                    .all(|(f, c)| *f == c.f_client),
            "f_clients cache desynced — call refresh_f_clients() after \
             mutating clients"
        );
        &self.f_clients
    }

    /// Re-sync the cached `f_clients` after mutating `clients` in place.
    pub fn refresh_f_clients(&mut self) {
        self.f_clients.clear();
        self.f_clients.extend(self.clients.iter().map(|c| c.f_client));
    }
}

/// A channel *realization*: per-(client, subchannel) linear gains.
///
/// `average` is the paper's deterministic γ(F_k, d_i) (used by the
/// optimizer and the "ideal static channel" benchmark of Fig. 13);
/// `sample` adds lognormal shadow fading (the per-round redraw of Fig. 13).
#[derive(Debug, Clone)]
pub struct ChannelRealization {
    /// gain[client][subchannel], linear.
    pub gain: Vec<Vec<f64>>,
}

impl ChannelRealization {
    pub fn average(dep: &Deployment) -> ChannelRealization {
        let gain = (0..dep.n_clients())
            .map(|i| {
                (0..dep.n_subchannels())
                    .map(|k| dep.mean_gain(i, k))
                    .collect()
            })
            .collect();
        ChannelRealization { gain }
    }

    pub fn sample(dep: &Deployment, rng: &mut Rng) -> ChannelRealization {
        let gain = dep
            .clients
            .iter()
            .map(|c| {
                dep.subchannels
                    .iter()
                    .map(|s| {
                        pathloss::sample_gain(
                            s.center_freq_hz,
                            c.distance_m,
                            c.los,
                            rng,
                        )
                    })
                    .collect()
            })
            .collect();
        ChannelRealization { gain }
    }

    /// γ_w — the weakest gain across all clients and subchannels (eq. 18's
    /// broadcast bottleneck).
    pub fn worst_gain(&self) -> f64 {
        self.gain
            .iter()
            .flat_map(|row| row.iter().copied())
            .fold(f64::INFINITY, f64::min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> NetworkConfig {
        NetworkConfig::default()
    }

    #[test]
    fn generate_respects_config() {
        let mut rng = Rng::new(3);
        let dep = Deployment::generate(&cfg(), &mut rng);
        assert_eq!(dep.n_clients(), 5);
        assert_eq!(dep.n_subchannels(), 20);
        for c in &dep.clients {
            assert!(c.distance_m <= 200.0 + 1e-9);
            assert!((1e9..=1.6e9).contains(&c.f_client));
        }
        // Subchannels tile the band contiguously.
        for w in dep.subchannels.windows(2) {
            let gap = w[1].center_freq_hz - w[0].center_freq_hz;
            assert!((gap - 10e6).abs() < 1.0);
        }
    }

    #[test]
    fn deployment_is_seed_deterministic() {
        let a = Deployment::generate(&cfg(), &mut Rng::new(9));
        let b = Deployment::generate(&cfg(), &mut Rng::new(9));
        for (x, y) in a.clients.iter().zip(&b.clients) {
            assert_eq!(x.distance_m, y.distance_m);
            assert_eq!(x.f_client, y.f_client);
        }
    }

    #[test]
    fn average_realization_matches_mean_gain() {
        let mut rng = Rng::new(4);
        let dep = Deployment::generate(&cfg(), &mut rng);
        let re = ChannelRealization::average(&dep);
        assert!((re.gain[2][7] - dep.mean_gain(2, 7)).abs() < 1e-18);
    }

    #[test]
    fn sampled_realization_varies() {
        let mut rng = Rng::new(5);
        let dep = Deployment::generate(&cfg(), &mut rng);
        let a = ChannelRealization::sample(&dep, &mut rng);
        let b = ChannelRealization::sample(&dep, &mut rng);
        assert_ne!(a.gain[0][0], b.gain[0][0]);
    }

    #[test]
    fn worst_gain_is_minimum() {
        let re = ChannelRealization {
            gain: vec![vec![1e-9, 5e-9], vec![3e-9, 2e-10]],
        };
        assert_eq!(re.worst_gain(), 2e-10);
    }

    #[test]
    fn nearer_clients_have_higher_gain_on_average() {
        // construct two clients at fixed distances with LoS
        let dep = Deployment::new(
            vec![
                ClientLink { distance_m: 20.0, f_client: 1e9, los: true },
                ClientLink { distance_m: 180.0, f_client: 1e9, los: true },
            ],
            vec![Subchannel {
                index: 0,
                center_freq_hz: 28e9,
                bandwidth_hz: 10e6,
            }],
        );
        assert!(dep.mean_gain(0, 0) > dep.mean_gain(1, 0));
    }

    #[test]
    fn f_clients_cache_tracks_clients() {
        let mut rng = Rng::new(8);
        let mut dep = Deployment::generate(&cfg(), &mut rng);
        let expect: Vec<f64> =
            dep.clients.iter().map(|c| c.f_client).collect();
        assert_eq!(dep.f_clients(), expect.as_slice());
        dep.clients[1].f_client = 0.7e9;
        dep.refresh_f_clients();
        assert_eq!(dep.f_clients()[1], 0.7e9);
        assert_eq!(dep.f_clients().len(), dep.n_clients());
    }
}
