//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime.
//!
//! `artifacts/manifest.json` describes every exported HLO graph — file
//! name, input/output tensor specs (name, dtype, shape) — plus the model
//! family metadata (parameter list, per-cut client/server split, smashed
//! shapes). The coordinator never guesses a shape: everything flows from
//! here.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Tensor element type.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
    Bf16,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            "u32" => Ok(DType::U32),
            "bf16" => Ok(DType::Bf16),
            other => Err(Error::Artifact(format!("unknown dtype '{other}'"))),
        }
    }
}

/// One tensor's spec.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j
                .req("name")?
                .as_str()
                .ok_or_else(|| Error::Artifact("spec name".into()))?
                .to_string(),
            dtype: DType::parse(
                j.req("dtype")?
                    .as_str()
                    .ok_or_else(|| Error::Artifact("spec dtype".into()))?,
            )?,
            shape: j.req("shape")?.usize_vec()?,
        })
    }
}

/// One exported graph.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactEntry {
    fn parse(j: &Json) -> Result<ArtifactEntry> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.req(key)?
                .as_arr()
                .ok_or_else(|| Error::Artifact(format!("{key} not array")))?
                .iter()
                .map(TensorSpec::parse)
                .collect()
        };
        Ok(ArtifactEntry {
            file: j
                .req("file")?
                .as_str()
                .ok_or_else(|| Error::Artifact("file".into()))?
                .to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }
}

/// One model family's manifest subtree.
#[derive(Debug, Clone)]
pub struct FamilyManifest {
    pub name: String,
    pub channels: usize,
    pub num_classes: usize,
    pub img: usize,
    pub batch: usize,
    pub eval_batch: usize,
    /// Canonical parameter order: (name, shape).
    pub params: Vec<(String, Vec<usize>)>,
    /// cut → number of client-side parameter tensors (canonical prefix).
    pub client_param_count: BTreeMap<usize, usize>,
    /// cut → smashed (h, w, c).
    pub smashed_shape: BTreeMap<usize, Vec<usize>>,
    pub init: ArtifactEntry,
    pub eval: ArtifactEntry,
    /// cut → entry.
    pub client_fwd: BTreeMap<usize, ArtifactEntry>,
    pub client_step: BTreeMap<usize, ArtifactEntry>,
    pub phi_agg: BTreeMap<usize, ArtifactEntry>,
    /// cut → (C → entry).
    pub server_train: BTreeMap<usize, BTreeMap<usize, ArtifactEntry>>,
}

impl FamilyManifest {
    pub fn cuts(&self) -> Vec<usize> {
        self.client_fwd.keys().copied().collect()
    }

    pub fn client_counts(&self, cut: usize) -> Vec<usize> {
        self.server_train
            .get(&cut)
            .map(|m| m.keys().copied().collect())
            .unwrap_or_default()
    }

    /// Server_train entry for (cut, C) with a clear error.
    pub fn server_train_entry(&self, cut: usize, c: usize)
        -> Result<&ArtifactEntry> {
        self.server_train
            .get(&cut)
            .and_then(|m| m.get(&c))
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no server_train artifact for cut={cut}, C={c} \
                     (exported counts: {:?})",
                    self.client_counts(cut)
                ))
            })
    }

    /// Total parameter element count.
    pub fn param_elements(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Validate a per-client cut vector against this family's exported
    /// artifacts before any worker touches it: the vector must have one
    /// entry per client, and every cut must carry a full artifact set
    /// (client_fwd/client_step entries, parameter split, smashed shape).
    /// A user-supplied vector that fails is a configuration error, not a
    /// corrupt manifest — hence [`Error::Config`] and not a worker panic
    /// deep inside the round.
    pub fn validate_cut_vector(&self, cuts: &[usize], n_clients: usize)
        -> Result<()> {
        if cuts.len() != n_clients {
            return Err(Error::Config(format!(
                "cut vector has {} entr{} but the run has {n_clients} \
                 client(s)",
                cuts.len(),
                if cuts.len() == 1 { "y" } else { "ies" }
            )));
        }
        for &cut in cuts {
            let complete = self.client_fwd.contains_key(&cut)
                && self.client_step.contains_key(&cut)
                && self.client_param_count.contains_key(&cut)
                && self.smashed_shape.contains_key(&cut);
            if !complete {
                return Err(Error::Config(format!(
                    "family '{}' exports no artifacts for cut {cut} \
                     (available cuts: {:?})",
                    self.name,
                    self.cuts()
                )));
            }
        }
        Ok(())
    }

    /// Reject degenerate shapes before they reach the kernels. The
    /// splitnet stages halve the spatial dims twice, so `img < 4`
    /// produces zero-sized feature maps whose SAME-padding arithmetic
    /// (`(out − 1) · stride`) would underflow; zero channels / classes /
    /// batch are equally meaningless. A corrupt or hand-edited
    /// manifest.json surfaces here as `Error::Artifact` instead of a
    /// debug-overflow panic (or garbage in release) mid-round.
    fn validate(&self) -> Result<()> {
        let bad = |what: &str, got: usize, min: usize| {
            Err(Error::Artifact(format!(
                "family '{}': {what} = {got} is below the minimum {min} \
                 — degenerate shapes would underflow the SAME-padding \
                 arithmetic in the conv kernels",
                self.name
            )))
        };
        if self.img < 4 {
            return bad("img", self.img, 4);
        }
        if self.channels == 0 {
            return bad("channels", 0, 1);
        }
        if self.num_classes == 0 {
            return bad("num_classes", 0, 1);
        }
        if self.batch == 0 {
            return bad("batch", 0, 1);
        }
        if self.eval_batch == 0 {
            return bad("eval_batch", 0, 1);
        }
        for (cut, shape) in &self.smashed_shape {
            if shape.iter().any(|&d| d == 0) {
                return Err(Error::Artifact(format!(
                    "family '{}': smashed_shape[{cut}] = {shape:?} has a \
                     zero dim",
                    self.name
                )));
            }
        }
        Ok(())
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub client_counts: Vec<usize>,
    pub cuts: Vec<usize>,
    pub families: BTreeMap<String, FamilyManifest>,
}

fn parse_cut_map(j: &Json) -> Result<BTreeMap<usize, ArtifactEntry>> {
    let obj = j
        .as_obj()
        .ok_or_else(|| Error::Artifact("expected object".into()))?;
    let mut out = BTreeMap::new();
    for (k, v) in obj {
        let cut: usize = k
            .parse()
            .map_err(|_| Error::Artifact(format!("bad cut key '{k}'")))?;
        out.insert(cut, ArtifactEntry::parse(v)?);
    }
    Ok(out)
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = Path::new(dir).join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "{}: {e} — run `make artifacts` first",
                path.display()
            ))
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j.req("version")?.as_usize().unwrap_or(0);
        if version != 1 {
            return Err(Error::Artifact(format!(
                "manifest version {version} != 1"
            )));
        }
        let client_counts = j.req("client_counts")?.usize_vec()?;
        let cuts = j.req("cuts")?.usize_vec()?;
        let mut families = BTreeMap::new();
        let fams = j
            .req("families")?
            .as_obj()
            .ok_or_else(|| Error::Artifact("families".into()))?;
        for (name, fj) in fams {
            let arts = fj.req("artifacts")?;
            let params = fj
                .req("params")?
                .as_arr()
                .ok_or_else(|| Error::Artifact("params".into()))?
                .iter()
                .map(|p| {
                    Ok((
                        p.req("name")?
                            .as_str()
                            .ok_or_else(|| {
                                Error::Artifact("param name".into())
                            })?
                            .to_string(),
                        p.req("shape")?.usize_vec()?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            let cpc = fj
                .req("client_param_count")?
                .as_obj()
                .ok_or_else(|| Error::Artifact("client_param_count".into()))?
                .iter()
                .map(|(k, v)| {
                    Ok((
                        k.parse::<usize>().map_err(|_| {
                            Error::Artifact(format!("cut key {k}"))
                        })?,
                        v.as_usize().ok_or_else(|| {
                            Error::Artifact("param count".into())
                        })?,
                    ))
                })
                .collect::<Result<BTreeMap<_, _>>>()?;
            let smashed = fj
                .req("smashed_shape")?
                .as_obj()
                .ok_or_else(|| Error::Artifact("smashed_shape".into()))?
                .iter()
                .map(|(k, v)| {
                    Ok((
                        k.parse::<usize>().map_err(|_| {
                            Error::Artifact(format!("cut key {k}"))
                        })?,
                        v.usize_vec()?,
                    ))
                })
                .collect::<Result<BTreeMap<_, _>>>()?;
            let mut server_train = BTreeMap::new();
            let st = arts
                .req("server_train")?
                .as_obj()
                .ok_or_else(|| Error::Artifact("server_train".into()))?;
            for (cut_key, by_c) in st {
                let cut: usize = cut_key.parse().map_err(|_| {
                    Error::Artifact(format!("cut key {cut_key}"))
                })?;
                let mut inner = BTreeMap::new();
                for (c_key, entry) in by_c
                    .as_obj()
                    .ok_or_else(|| Error::Artifact("server_train map".into()))?
                {
                    let c: usize = c_key.parse().map_err(|_| {
                        Error::Artifact(format!("C key {c_key}"))
                    })?;
                    inner.insert(c, ArtifactEntry::parse(entry)?);
                }
                server_train.insert(cut, inner);
            }
            let fam = FamilyManifest {
                name: name.clone(),
                channels: fj.req("channels")?.as_usize().unwrap_or(1),
                num_classes: fj
                    .req("num_classes")?
                    .as_usize()
                    .unwrap_or(10),
                img: fj.req("img")?.as_usize().unwrap_or(16),
                batch: fj.req("batch")?.as_usize().unwrap_or(32),
                eval_batch: fj
                    .req("eval_batch")?
                    .as_usize()
                    .unwrap_or(256),
                params,
                client_param_count: cpc,
                smashed_shape: smashed,
                init: ArtifactEntry::parse(arts.req("init")?)?,
                eval: ArtifactEntry::parse(arts.req("eval")?)?,
                client_fwd: parse_cut_map(arts.req("client_fwd")?)?,
                client_step: parse_cut_map(arts.req("client_step")?)?,
                phi_agg: parse_cut_map(arts.req("phi_agg")?)?,
                server_train,
            };
            fam.validate()?;
            families.insert(name.clone(), fam);
        }
        Ok(Manifest { client_counts, cuts, families })
    }

    pub fn family(&self, name: &str) -> Result<&FamilyManifest> {
        self.families.get(name).ok_or_else(|| {
            Error::Artifact(format!(
                "family '{name}' not in manifest (have: {:?})",
                self.families.keys().collect::<Vec<_>>()
            ))
        })
    }

    /// Closest exported client count ≥ requested (exact match preferred).
    pub fn nearest_client_count(&self, c: usize) -> usize {
        if self.client_counts.contains(&c) {
            return c;
        }
        self.client_counts
            .iter()
            .copied()
            .filter(|&x| x >= c)
            .min()
            .or_else(|| self.client_counts.iter().copied().max())
            .unwrap_or(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "client_counts": [1, 2, 5],
      "cuts": [2],
      "families": {
        "mnist": {
          "channels": 1, "num_classes": 10, "img": 16, "width": 8,
          "batch": 32, "eval_batch": 256,
          "params": [{"name": "s1.w", "shape": [3,3,1,8]},
                     {"name": "s1.b", "shape": [8]}],
          "client_param_count": {"2": 1},
          "smashed_shape": {"2": [16,16,8]},
          "artifacts": {
            "init": {"file": "i.hlo.txt",
                     "inputs": [{"name":"seed","dtype":"u32","shape":[2]}],
                     "outputs": [{"name":"s1.w","dtype":"f32","shape":[3,3,1,8]}]},
            "eval": {"file": "e.hlo.txt", "inputs": [], "outputs": []},
            "client_fwd": {"2": {"file": "cf.hlo.txt", "inputs": [],
                                  "outputs": []}},
            "client_step": {"2": {"file": "cs.hlo.txt", "inputs": [],
                                   "outputs": []}},
            "phi_agg": {"2": {"file": "pa.hlo.txt", "inputs": [],
                               "outputs": []}},
            "server_train": {"2": {"5": {"file": "st.hlo.txt",
                                          "inputs": [], "outputs": []}}}
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.client_counts, vec![1, 2, 5]);
        let fam = m.family("mnist").unwrap();
        assert_eq!(fam.batch, 32);
        assert_eq!(fam.params.len(), 2);
        assert_eq!(fam.client_param_count[&2], 1);
        assert_eq!(fam.smashed_shape[&2], vec![16, 16, 8]);
        assert_eq!(fam.init.inputs[0].dtype, DType::U32);
        assert!(fam.server_train_entry(2, 5).is_ok());
        assert!(fam.server_train_entry(2, 3).is_err());
        assert!(m.family("nope").is_err());
    }

    #[test]
    fn degenerate_shapes_rejected_at_parse_time() {
        // img below the two spatial halvings → Error::Artifact, not a
        // later conv-kernel underflow.
        let bad_img = SAMPLE.replace(r#""img": 16"#, r#""img": 3"#);
        let e = Manifest::parse(&bad_img).unwrap_err();
        assert!(e.to_string().contains("img"), "{e}");
        let bad_ch =
            SAMPLE.replace(r#""channels": 1"#, r#""channels": 0"#);
        let e = Manifest::parse(&bad_ch).unwrap_err();
        assert!(e.to_string().contains("channels"), "{e}");
        let bad_smash = SAMPLE
            .replace(r#""smashed_shape": {"2": [16,16,8]}"#,
                     r#""smashed_shape": {"2": [16,0,8]}"#);
        let e = Manifest::parse(&bad_smash).unwrap_err();
        assert!(e.to_string().contains("smashed_shape"), "{e}");
    }

    #[test]
    fn cut_vectors_validated_against_exports() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let fam = m.family("mnist").unwrap();
        // Every entry exported → ok.
        assert!(fam.validate_cut_vector(&[2, 2, 2], 3).is_ok());
        // Length must match the cohort.
        let e = fam.validate_cut_vector(&[2, 2], 3).unwrap_err();
        assert!(e.to_string().contains("2 entries"), "{e}");
        assert!(e.to_string().contains("3 client"), "{e}");
        let e = fam.validate_cut_vector(&[2], 3).unwrap_err();
        assert!(e.to_string().contains("1 entry"), "{e}");
        // A cut with no exported artifacts is rejected by name.
        let e = fam.validate_cut_vector(&[2, 3, 2], 3).unwrap_err();
        assert!(
            e.to_string().contains("no artifacts for cut 3"),
            "{e}"
        );
        assert!(e.to_string().contains("available cuts"), "{e}");
        // The real native manifest accepts any vector over 1..=4.
        let native = crate::runtime::native::manifest();
        let fam = native.family("mnist").unwrap();
        assert!(fam.validate_cut_vector(&[1, 2, 3, 4], 4).is_ok());
        assert!(fam.validate_cut_vector(&[1, 5], 2).is_err());
    }

    #[test]
    fn nearest_client_count_rounds_up() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.nearest_client_count(2), 2);
        assert_eq!(m.nearest_client_count(3), 5);
        assert_eq!(m.nearest_client_count(7), 5); // above max → max
    }

    #[test]
    fn loads_real_manifest_when_present() {
        if let Ok(m) = Manifest::load("artifacts") {
            let fam = m.family("mnist").unwrap();
            assert_eq!(fam.params.len(), 20);
            assert_eq!(fam.cuts(), vec![1, 2, 3, 4]);
            // cross-check the split contract with the profile module
            assert_eq!(fam.client_param_count[&2], 6);
            let spec = &fam.server_train_entry(2, 5).unwrap().inputs;
            let names: Vec<&str> =
                spec.iter().map(|s| s.name.as_str()).collect();
            assert!(names.ends_with(&["smashed", "y", "lam", "mask", "lr"]));
        }
    }
}
