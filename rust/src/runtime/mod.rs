//! Execution runtimes for the AOT entry-point contract.
//!
//! Two implementations sit behind the [`backend::Backend`] seam:
//!
//! - **[`Runtime`] (PJRT)**: load AOT HLO-text artifacts, compile once,
//!   execute from the training hot path. Mirrors
//!   `/opt/xla-example/load_hlo`: `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//!   `client.compile` → `execute`. Compiled executables are cached by
//!   file name; every graph was lowered with `return_tuple=True`, so
//!   execution returns one tuple literal that we decompose and validate
//!   against the manifest's output specs. Preferred when artifacts exist.
//! - **[`native`]**: the pure-Rust host-f32 backend — the same splitnet
//!   graphs implemented directly, selected automatically when artifacts
//!   are absent so the training stack always runs.

pub mod artifact;
pub mod backend;
pub mod native;
pub mod tensor;

pub use backend::{select_backend, select_backend_with, Backend,
                  BackendChoice, SelectedBackend};
pub use native::MathTier;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::rc::Rc;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable,
          XlaComputation};

use crate::error::{Error, Result};
use crate::util::bench::WallTimer;

use artifact::ArtifactEntry;

/// Cumulative runtime counters (perf visibility).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub executions: usize,
    pub compile_seconds: f64,
    pub execute_seconds: f64,
}

/// The PJRT runtime handle. Not `Send` (PJRT client is thread-affine in the
/// `xla` crate); the coordinator is an event-driven single-thread loop.
pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    // BTreeMap rather than HashMap: the executable cache is keyed state
    // inside a deterministic module (audit rule R2) — even though nothing
    // iterates it today, hash order must never be one refactor away from
    // leaking into round behavior.
    cache: RefCell<BTreeMap<String, Rc<PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at the artifact directory.
    pub fn new(artifacts_dir: &str) -> Result<Runtime> {
        let client = PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: PathBuf::from(artifacts_dir),
            cache: RefCell::new(BTreeMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact file (cached).
    pub fn load(&self, file: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(file);
        let t0 = WallTimer::start();
        let proto = HloModuleProto::from_text_file(&path).map_err(|e| {
            Error::Artifact(format!("{}: {e}", path.display()))
        })?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        let mut stats = self.stats.borrow_mut();
        stats.compiles += 1;
        stats.compile_seconds += t0.elapsed_seconds();
        drop(stats);
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (hides compile latency from the
    /// per-round timings).
    pub fn warmup<'a, I: IntoIterator<Item = &'a ArtifactEntry>>(
        &self, entries: I,
    ) -> Result<()> {
        for e in entries {
            self.load(&e.file)?;
        }
        Ok(())
    }

    /// Execute an artifact with the given input literals; returns the
    /// decomposed output tuple, validated against the manifest specs.
    pub fn call(&self, entry: &ArtifactEntry, inputs: &[Literal])
        -> Result<Vec<Literal>> {
        validate_inputs(entry, inputs)?;
        let exe = self.load(&entry.file)?;
        let t0 = WallTimer::start();
        let result = exe.execute::<Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let mut stats = self.stats.borrow_mut();
        stats.executions += 1;
        stats.execute_seconds += t0.elapsed_seconds();
        drop(stats);
        let outs = tuple.to_tuple()?;
        if outs.len() != entry.outputs.len() {
            return Err(Error::Runtime(format!(
                "{}: expected {} outputs, got {}",
                entry.file,
                entry.outputs.len(),
                outs.len()
            )));
        }
        Ok(outs)
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Shared input validation (arity + element counts vs the manifest
/// specs), used by both the PJRT and native backends.
pub(crate) fn validate_inputs(entry: &ArtifactEntry, inputs: &[Literal])
    -> Result<()> {
    if inputs.len() != entry.inputs.len() {
        return Err(Error::Runtime(format!(
            "{}: expected {} inputs, got {}",
            entry.file,
            entry.inputs.len(),
            inputs.len()
        )));
    }
    for (lit, spec) in inputs.iter().zip(&entry.inputs) {
        let n = lit.element_count();
        if n != spec.numel() {
            return Err(Error::Runtime(format!(
                "{}: input '{}' has {} elements, spec wants {} {:?}",
                entry.file, spec.name, n, spec.numel(), spec.shape
            )));
        }
    }
    Ok(())
}

impl Backend for Runtime {
    fn platform(&self) -> String {
        Runtime::platform(self)
    }

    fn call(&self, entry: &ArtifactEntry, inputs: &[Literal])
        -> Result<Vec<Literal>> {
        Runtime::call(self, entry, inputs)
    }

    // call_many keeps the serial default: the PJRT client is
    // thread-affine (the coordinator is an event-driven single-thread
    // loop around it).

    fn stats_summary(&self) -> String {
        let s = self.stats();
        format!(
            "pjrt backend: {} compiles ({:.2}s), {} executions ({:.2}s)",
            s.compiles, s.compile_seconds, s.executions, s.execute_seconds
        )
    }
}

#[cfg(test)]
mod tests {
    use super::artifact::Manifest;
    use super::tensor::*;
    use super::*;

    /// These tests require `make artifacts` to have run; they are the
    /// rust-side half of the AOT contract.
    fn runtime_and_manifest() -> Option<(Runtime, Manifest)> {
        let m = Manifest::load("artifacts").ok()?;
        let rt = Runtime::new("artifacts").ok()?;
        Some((rt, m))
    }

    #[test]
    fn init_executes_and_shapes_match() {
        let Some((rt, m)) = runtime_and_manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let fam = m.family("mnist").unwrap();
        let seed = literal_u32(&[2], &[0, 42]).unwrap();
        let params = rt.call(&fam.init, &[seed]).unwrap();
        assert_eq!(params.len(), fam.params.len());
        for (lit, (name, shape)) in params.iter().zip(&fam.params) {
            assert_eq!(
                lit.element_count(),
                shape.iter().product::<usize>(),
                "param {name}"
            );
        }
        // determinism
        let seed2 = literal_u32(&[2], &[0, 42]).unwrap();
        let params2 = rt.call(&fam.init, &[seed2]).unwrap();
        assert_eq!(
            to_f32_vec(&params[0]).unwrap(),
            to_f32_vec(&params2[0]).unwrap()
        );
    }

    #[test]
    fn phi_agg_artifact_matches_rust_reference() {
        let Some((rt, m)) = runtime_and_manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let fam = m.family("mnist").unwrap();
        let entry = fam.phi_agg.get(&2).unwrap();
        let zspec = &entry.inputs[0];
        let (c, b, q) = (zspec.shape[0], zspec.shape[1], zspec.shape[2]);
        let mut rng = crate::util::rng::Rng::new(5);
        let z: Vec<f32> =
            (0..c * b * q).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let lam: Vec<f32> = vec![1.0 / c as f32; c];
        let m_agg = b / 2;
        let mask: Vec<f32> = (0..b)
            .map(|j| if j < m_agg { 1.0 } else { 0.0 })
            .collect();
        let out = rt
            .call(
                entry,
                &[
                    literal_f32(&[c, b, q], &z).unwrap(),
                    literal_f32(&[c], &lam).unwrap(),
                    literal_f32(&[b], &mask).unwrap(),
                ],
            )
            .unwrap();
        let got = to_f32_vec(&out[0]).unwrap();
        // Rust-side oracle of eq. (5)-(6).
        for i in 0..c {
            for j in 0..b {
                for x in 0..q.min(7) {
                    let idx = (i * b + j) * q + x;
                    let expect = if j < m_agg {
                        (0..c)
                            .map(|k| lam[k] * z[(k * b + j) * q + x])
                            .sum::<f32>()
                    } else {
                        z[idx]
                    };
                    assert!(
                        (got[idx] - expect).abs() < 1e-4,
                        "mismatch at ({i},{j},{x}): {} vs {expect}",
                        got[idx]
                    );
                }
            }
        }
    }

    #[test]
    fn executable_cache_hits() {
        let Some((rt, m)) = runtime_and_manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let fam = m.family("mnist").unwrap();
        let seed = literal_u32(&[2], &[1, 2]).unwrap();
        rt.call(&fam.init, &[seed]).unwrap();
        let before = rt.stats().compiles;
        let seed = literal_u32(&[2], &[1, 3]).unwrap();
        rt.call(&fam.init, &[seed]).unwrap();
        assert_eq!(rt.stats().compiles, before, "second call recompiled");
        assert_eq!(rt.stats().executions, 2);
    }

    #[test]
    fn input_arity_and_shape_validated() {
        let Some((rt, m)) = runtime_and_manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let fam = m.family("mnist").unwrap();
        // wrong arity
        assert!(rt.call(&fam.init, &[]).is_err());
        // wrong element count
        let bad = literal_u32(&[3], &[1, 2, 3]).unwrap();
        assert!(rt.call(&fam.init, &[bad]).is_err());
    }
}
