//! The execution-backend seam: everything above it (driver, fedavg,
//! experiments) speaks `Manifest` + `Literal` entry points; everything
//! below is either the PJRT artifact path or the pure-Rust native
//! backend.
//!
//! Selection (`--backend native|pjrt|auto` on the CLI, `[backend]` in
//! TOML): `pjrt` requires built artifacts and fails otherwise, `native`
//! always works, `auto` prefers PJRT artifacts when present and falls
//! back to native — so the training stack runs on any machine, in CI,
//! and on a fresh checkout.

use xla::Literal;

use crate::error::{Error, Result};

use super::artifact::{ArtifactEntry, Manifest};
use super::native::{self, MathTier, NativeBackend};
use super::Runtime;

/// An execution backend for the manifest entry points.
///
/// Implementations must be deterministic: identical inputs produce
/// bit-identical outputs (the driver's reproducibility contract rests on
/// this).
pub trait Backend {
    /// Human-readable platform string ("Host CPU" / "native-f32 …").
    fn platform(&self) -> String;

    /// Execute one entry with the given inputs; returns the outputs in
    /// manifest order.
    fn call(&self, entry: &ArtifactEntry, inputs: &[Literal])
        -> Result<Vec<Literal>>;

    /// Execute one entry over many independent input sets (one per
    /// client). The default runs serially; backends that are `Sync` (the
    /// native one) fan the sets across cores with order-preserving
    /// results, so callers may rely on `out[i] == call(entry, &sets[i])`
    /// bit for bit.
    fn call_many(&self, entry: &ArtifactEntry, batches: &[Vec<Literal>])
        -> Result<Vec<Vec<Literal>>> {
        batches.iter().map(|b| self.call(entry, b)).collect()
    }

    /// One-line execution-stats summary for logs and benches.
    fn stats_summary(&self) -> String;
}

/// Which backend the user asked for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendChoice {
    /// PJRT when artifacts are present, native otherwise.
    #[default]
    Auto,
    Native,
    Pjrt,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Result<BackendChoice> {
        match s {
            "auto" => Ok(BackendChoice::Auto),
            "native" => Ok(BackendChoice::Native),
            "pjrt" => Ok(BackendChoice::Pjrt),
            other => Err(Error::Config(format!(
                "unknown backend '{other}' (auto|native|pjrt)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendChoice::Auto => "auto",
            BackendChoice::Native => "native",
            BackendChoice::Pjrt => "pjrt",
        }
    }
}

/// A selected backend plus the manifest it executes.
pub struct SelectedBackend {
    pub backend: Box<dyn Backend>,
    pub manifest: Manifest,
    /// Which implementation was picked: "pjrt" or "native".
    pub kind: &'static str,
}

impl SelectedBackend {
    pub fn describe(&self) -> String {
        format!("{} ({})", self.kind, self.backend.platform())
    }
}

/// Resolve a [`BackendChoice`] against the artifacts directory (bitwise
/// math tier).
pub fn select_backend(artifacts_dir: &str, choice: BackendChoice)
    -> Result<SelectedBackend> {
    select_backend_with(artifacts_dir, choice, MathTier::default())
}

/// Resolve a [`BackendChoice`] with an explicit native [`MathTier`]. The
/// PJRT path ignores the tier (its numerics come from the compiled
/// artifacts); only the native backend dispatches on it.
pub fn select_backend_with(artifacts_dir: &str, choice: BackendChoice,
                           tier: MathTier)
    -> Result<SelectedBackend> {
    let pjrt = || -> Result<SelectedBackend> {
        let manifest = Manifest::load(artifacts_dir)?;
        let rt = Runtime::new(artifacts_dir)?;
        Ok(SelectedBackend {
            backend: Box::new(rt),
            manifest,
            kind: "pjrt",
        })
    };
    let native_sel = || SelectedBackend {
        backend: Box::new(NativeBackend::with_options(
            crate::util::par::max_threads(), tier)),
        manifest: native::manifest(),
        kind: "native",
    };
    match choice {
        BackendChoice::Pjrt => pjrt(),
        BackendChoice::Native => Ok(native_sel()),
        BackendChoice::Auto => match pjrt() {
            Ok(sel) => Ok(sel),
            Err(e) => {
                // A missing manifest is the expected offline state and
                // falls back silently; artifacts that exist but fail to
                // load mean the measured PJRT system is being replaced —
                // surface why instead of degrading silently.
                if std::path::Path::new(artifacts_dir)
                    .join("manifest.json")
                    .exists()
                {
                    eprintln!(
                        "backend auto: PJRT path unavailable ({e}); \
                         falling back to the native backend"
                    );
                }
                Ok(native_sel())
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parses() {
        assert_eq!(BackendChoice::parse("auto").unwrap(),
                   BackendChoice::Auto);
        assert_eq!(BackendChoice::parse("native").unwrap(),
                   BackendChoice::Native);
        assert_eq!(BackendChoice::parse("pjrt").unwrap(),
                   BackendChoice::Pjrt);
        assert!(BackendChoice::parse("tpu").is_err());
    }

    #[test]
    fn native_always_selectable() {
        let sel =
            select_backend("artifacts", BackendChoice::Native).unwrap();
        assert_eq!(sel.kind, "native");
        assert!(sel.manifest.family("mnist").is_ok());
        assert!(sel.describe().contains("native"));
    }

    #[test]
    fn fast_tier_selectable_and_reported() {
        let sel = select_backend_with("artifacts", BackendChoice::Native,
                                      MathTier::Fast)
            .unwrap();
        assert_eq!(sel.kind, "native");
        let platform = sel.backend.platform();
        assert!(platform.contains("fast"), "{platform}");
        // And the default entry point stays on the bitwise tier.
        let def =
            select_backend("artifacts", BackendChoice::Native).unwrap();
        assert!(def.backend.platform().contains("bitwise"),
                "{}", def.backend.platform());
    }

    #[test]
    fn auto_never_fails() {
        // With or without artifacts on disk, auto yields a usable backend.
        let sel = select_backend("artifacts", BackendChoice::Auto).unwrap();
        assert!(sel.manifest.family("ham").is_ok());
    }

    #[test]
    fn pjrt_requires_artifacts() {
        // In an offline checkout (no artifacts, stub PJRT) the explicit
        // pjrt choice must fail loudly rather than fall back.
        if Manifest::load("artifacts").is_err()
            || Runtime::new("artifacts").is_err()
        {
            assert!(
                select_backend("artifacts", BackendChoice::Pjrt).is_err()
            );
        }
    }
}
