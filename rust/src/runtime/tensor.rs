//! Host tensors and Literal conversion helpers.
//!
//! The coordinator works with flat `f32`/`i32`/`u32` buffers; this module
//! is the single crossing point between host memory and XLA literals, with
//! shape/dtype checking against the manifest specs.

use xla::{ElementType, Literal};

use crate::error::{Error, Result};

use super::artifact::{DType, TensorSpec};

/// A host-side tensor (always f32 — labels/seeds use dedicated builders).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        HostTensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

fn bytes_of<T: Copy>(v: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(
            v.as_ptr() as *const u8,
            std::mem::size_of_val(v),
        )
    }
}

/// f32 tensor → literal.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        shape,
        bytes_of(data),
    )?)
}

/// i32 tensor → literal.
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        shape,
        bytes_of(data),
    )?)
}

/// u32 tensor → literal.
pub fn literal_u32(shape: &[usize], data: &[u32]) -> Result<Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::U32,
        shape,
        bytes_of(data),
    )?)
}

/// Build a literal matching `spec` from f32 data (spec must be f32).
pub fn literal_for_spec(spec: &TensorSpec, data: &[f32]) -> Result<Literal> {
    if spec.dtype != DType::F32 {
        return Err(Error::Runtime(format!(
            "spec {} is {:?}, not f32",
            spec.name, spec.dtype
        )));
    }
    if spec.numel() != data.len() {
        return Err(Error::Runtime(format!(
            "spec {} wants {} elements, got {}",
            spec.name,
            spec.numel(),
            data.len()
        )));
    }
    literal_f32(&spec.shape, data)
}

/// Literal → f32 vec (with count check).
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a scalar f32 from a literal.
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Elementwise λ-weighted average of equally-shaped f32 buffers
/// (the SFL FedAvg and the evaluation-model average).
pub fn weighted_average(buffers: &[Vec<f32>], weights: &[f32]) -> Vec<f32> {
    assert_eq!(buffers.len(), weights.len());
    assert!(!buffers.is_empty());
    let n = buffers[0].len();
    let mut out = vec![0.0f32; n];
    for (buf, &w) in buffers.iter().zip(weights) {
        assert_eq!(buf.len(), n);
        for (o, &v) in out.iter_mut().zip(buf) {
            *o += w * v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checked() {
        let t = HostTensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        let z = HostTensor::zeros(vec![4, 4]);
        assert_eq!(z.data.len(), 16);
    }

    #[test]
    #[should_panic]
    fn host_tensor_bad_shape_panics() {
        let _ = HostTensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let data = vec![1.0f32, -2.5, 3.25, 0.0, 9.0, 7.5];
        let lit = literal_f32(&[2, 3], &data).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(to_f32_vec(&lit).unwrap(), data);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let data = vec![1i32, -7, 42];
        let lit = literal_i32(&[3], &data).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn literal_roundtrip_u32() {
        let data = vec![0u32, 4_000_000_000];
        let lit = literal_u32(&[2], &data).unwrap();
        assert_eq!(lit.to_vec::<u32>().unwrap(), data);
    }

    #[test]
    fn spec_mismatch_rejected() {
        let spec = TensorSpec {
            name: "x".into(),
            dtype: DType::F32,
            shape: vec![2, 2],
        };
        assert!(literal_for_spec(&spec, &[1.0; 3]).is_err());
        assert!(literal_for_spec(&spec, &[1.0; 4]).is_ok());
        let ispec = TensorSpec {
            name: "y".into(),
            dtype: DType::I32,
            shape: vec![1],
        };
        assert!(literal_for_spec(&ispec, &[1.0]).is_err());
    }

    #[test]
    fn weighted_average_math() {
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 6.0];
        let avg = weighted_average(&[a, b], &[0.25, 0.75]);
        assert_eq!(avg, vec![2.5, 5.0]);
    }
}
