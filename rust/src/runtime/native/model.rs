//! The SplitNet family on host buffers: parameter specs, He-normal init,
//! and the five exported graph semantics (`client_fwd`, `server_train`,
//! `client_step`, `eval`, `phi_agg`) exactly as `python/compile/model.py`
//! defines them — including the λ-weighted softmax-CE loss (eq. 1), the
//! ⌈φb⌉ last-layer gradient aggregation (eq. 5–6) over a virtual
//! aggregated batch, and the per-row 1/b weighting of eq. 9.
//!
//! Parallelism: heavy per-sample work (server FP/BP, eval FP) fans across
//! cores with [`par::parallel_map`], whose output is ordered; every
//! cross-sample reduction then runs serially in sample order, so results
//! are bit-identical for any `EPSL_THREADS`.
//!
//! ## Fast path vs reference
//!
//! The public entry points (`client_fwd`, `server_train`, `eval`,
//! `client_step`) run on the im2col + blocked-GEMM kernels of
//! [`super::kernels`]: the forward pass is **batched** — one im2col +
//! one GEMM per layer over the whole virtual batch `C·b` (the paper's
//! server-side parallelism), with GEMM row-blocks fanned across cores —
//! and the backward pass runs per sample on the same kernels with a
//! pooled [`kernels::Scratch`] arena, eliminating the per-call
//! `vec![0.0; ..]` churn of every kernel work buffer (im2col patches,
//! backward cols, intermediate cotangents); only the gradient tensors a
//! sample *returns* into the serial reduction are still owned
//! allocations. Every kernel preserves the reference
//! summation order, so the fast path is **bit-identical** to the
//! retained `*_reference` implementations (property-tested in
//! `tests/property_kernels.rs`) and all PR 3 determinism guarantees
//! (seed-reproducible, `EPSL_THREADS`-invariant) carry over unchanged.
//!
//! ## Math tiers
//!
//! Every public entry point takes a [`MathTier`]: `Bitwise` (the
//! default) runs the kernels above under the bit-identity contract;
//! `Fast` swaps the two GEMM seams — the batched forward's macro-loop
//! and the per-sample conv backward — for the SIMD/FMA kernels of
//! [`super::kernels_fast`], which are tolerance-tested against the
//! bitwise tier instead (PERF.md §10, `tests/property_tier.rs`).
//! Everything around those seams (im2col, elementwise ops, reduction
//! orders, masking) is shared, so the tiers differ only in kernel
//! arithmetic, never in semantics.

use crate::error::Result;
use crate::profile::splitnet::SplitNetConfig;
use crate::util::par;
use crate::util::rng::Rng;

use super::kernels::{self, Buf, Scratch, ScratchPool};
use super::kernels_fast::{self, MathTier};
use super::ops::{self, Dims};

/// Parameter tensors per stage (s1, s2, s3, s4) + head — the canonical
/// prefix bookkeeping shared with `python/compile/model.py`.
pub const STAGE_PARAM_COUNTS: [usize; 4] = [2, 4, 6, 6];

/// Number of client-side tensors for a cut (canonical prefix).
pub fn client_param_count(cut: usize) -> usize {
    STAGE_PARAM_COUNTS[..cut].iter().sum()
}

/// Canonical ordered `(name, shape)` list, mirroring `param_specs` in
/// `model.py` (HWIO conv weights, `(in, out)` FC weight).
pub fn param_specs(cfg: &SplitNetConfig) -> Vec<(String, Vec<usize>)> {
    let w = cfg.width;
    let (w1, w2, w3, w4) = (w, w, 2 * w, 4 * w);
    let mut s: Vec<(String, Vec<usize>)> = Vec::with_capacity(20);
    let mut push = |n: &str, shape: Vec<usize>| s.push((n.into(), shape));
    push("s1.w", vec![3, 3, cfg.channels, w1]);
    push("s1.b", vec![w1]);
    push("s2.wa", vec![3, 3, w1, w2]);
    push("s2.ba", vec![w2]);
    push("s2.wb", vec![3, 3, w2, w2]);
    push("s2.bb", vec![w2]);
    push("s3.wa", vec![3, 3, w2, w3]);
    push("s3.ba", vec![w3]);
    push("s3.wb", vec![3, 3, w3, w3]);
    push("s3.bb", vec![w3]);
    push("s3.wp", vec![1, 1, w2, w3]);
    push("s3.bp", vec![w3]);
    push("s4.wa", vec![3, 3, w3, w4]);
    push("s4.ba", vec![w4]);
    push("s4.wb", vec![3, 3, w4, w4]);
    push("s4.bb", vec![w4]);
    push("s4.wp", vec![1, 1, w3, w4]);
    push("s4.bp", vec![w4]);
    push("fc.w", vec![w4, cfg.num_classes]);
    push("fc.b", vec![cfg.num_classes]);
    s
}

/// He-normal init (biases zero), deterministic in `seed`. The native
/// backend's init need not match JAX's PRNG bit for bit — only the
/// *contract* (shape list, determinism from the run seed) is shared.
pub fn init_params(cfg: &SplitNetConfig, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed ^ 0x5EED_1417);
    param_specs(cfg)
        .iter()
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            let leaf = name.rsplit('.').next().unwrap_or("");
            if leaf.starts_with('b') {
                vec![0.0f32; n]
            } else {
                let fan_in: usize =
                    shape[..shape.len() - 1].iter().product();
                let std = (2.0 / fan_in as f64).sqrt();
                (0..n).map(|_| rng.normal(0.0, std) as f32).collect()
            }
        })
        .collect()
}

/// Input feature-map dims of stage `s` (1..=4).
fn stage_in_dims(cfg: &SplitNetConfig, s: usize) -> Dims {
    let img = cfg.img;
    let w = cfg.width;
    match s {
        1 => (img, img, cfg.channels),
        2 => (img, img, w),
        3 => (img, img, w),
        4 => (img / 2, img / 2, 2 * w),
        // audit:allow(R1, "internal contract: stage indices come from the fixed 1..=4 stage loop, never from input")
        _ => panic!("stage {s} out of 1..=4"),
    }
}

/// Output dims of stage `s` — also the smashed shape at cut `s`.
pub fn stage_out_dims(cfg: &SplitNetConfig, s: usize) -> Dims {
    let (h, w, c) = cfg.smashed_shape(s);
    (h, w, c)
}

/// Backward cache for one executed stage.
enum StageCache {
    /// stage 1: conv + relu. Caches input and post-relu output.
    Conv { x: Vec<f32>, y: Vec<f32> },
    /// stages 2–4: residual block. Caches input, post-relu `a`, output.
    Res { x: Vec<f32>, a: Vec<f32>, out: Vec<f32> },
}

/// Per-sample activation cache for stages `[first..=last]` (+ head).
pub struct Cache {
    stages: Vec<StageCache>,
    /// `(pooled, head input dims)` when the head ran.
    head: Option<(Vec<f32>, Dims)>,
}

/// Per-sample forward through stages `[first..=last]`, then the head if
/// `with_head`. `params` is the canonical subset for exactly that range.
/// Returns `(output, cache)`.
pub fn forward(cfg: &SplitNetConfig, params: &[Vec<f32>], first: usize,
               last: usize, with_head: bool, x: &[f32])
    -> (Vec<f32>, Cache) {
    let mut cache = Cache { stages: Vec::with_capacity(last + 1 - first),
                            head: None };
    let mut h = x.to_vec();
    let mut off = 0;
    for s in first..=last {
        let xd = stage_in_dims(cfg, s);
        let (_, _, cout) = stage_out_dims(cfg, s);
        if s == 1 {
            let (w, b) = (&params[off], &params[off + 1]);
            let mut y = ops::conv2d(&h, xd, w, 3, cout, b, 1);
            ops::relu(&mut y);
            cache.stages.push(StageCache::Conv { x: h, y: y.clone() });
            h = y;
        } else {
            let stride = if s >= 3 { 2 } else { 1 };
            let project = s >= 3;
            let (wa, ba) = (&params[off], &params[off + 1]);
            let (wb, bb) = (&params[off + 2], &params[off + 3]);
            let mut a = ops::conv2d(&h, xd, wa, 3, cout, ba, stride);
            ops::relu(&mut a);
            let ad = (ops::out_size(xd.0, stride),
                      ops::out_size(xd.1, stride), cout);
            let mut out = ops::conv2d(&a, ad, wb, 3, cout, bb, 1);
            if project {
                let (wp, bp) = (&params[off + 4], &params[off + 5]);
                let skip = ops::conv2d(&h, xd, wp, 1, cout, bp, stride);
                ops::add_assign(&mut out, &skip);
            } else {
                ops::add_assign(&mut out, &h);
            }
            ops::relu(&mut out);
            cache.stages.push(StageCache::Res { x: h, a, out: out.clone() });
            h = out;
        }
        off += STAGE_PARAM_COUNTS[s - 1];
    }
    if with_head {
        debug_assert_eq!(last, 4, "the head always follows stage 4");
        let xd = stage_out_dims(cfg, 4);
        let (fc_w, fc_b) = (&params[off], &params[off + 1]);
        let (logits, pooled) =
            ops::gap_fc(&h, xd, fc_w, fc_b, cfg.num_classes);
        cache.head = Some((pooled, xd));
        h = logits;
    }
    (h, cache)
}

/// Per-sample backward for the same range: given the output cotangent,
/// returns `(param gradients aligned with `params`, input cotangent)`.
pub fn backward(cfg: &SplitNetConfig, params: &[Vec<f32>], first: usize,
                last: usize, with_head: bool, cache: &Cache, cot: &[f32])
    -> (Vec<Vec<f32>>, Vec<f32>) {
    let mut grads: Vec<Vec<f32>> = Vec::with_capacity(params.len());
    let mut g = cot.to_vec();
    let mut off = params.len();
    if with_head {
        // audit:allow(R1, "with_head callers always ran the head forward that fills cache.head")
        let (pooled, xd) = cache.head.as_ref().expect("head cache");
        let fc_w = &params[off - 2];
        let (gw, gb, gx) =
            ops::gap_fc_bwd(pooled, *xd, fc_w, cfg.num_classes, &g);
        grads.push(gb);
        grads.push(gw);
        g = gx;
        off -= 2;
    }
    for s in (first..=last).rev() {
        let xd = stage_in_dims(cfg, s);
        let (_, _, cout) = stage_out_dims(cfg, s);
        let sc = &cache.stages[s - first];
        off -= STAGE_PARAM_COUNTS[s - 1];
        match sc {
            StageCache::Conv { x, y } => {
                ops::relu_bwd(&mut g, y);
                let w = &params[off];
                let (gw, gb, gx) =
                    ops::conv2d_bwd(x, xd, w, 3, cout, 1, &g);
                grads.push(gb);
                grads.push(gw);
                g = gx;
            }
            StageCache::Res { x, a, out } => {
                let stride = if s >= 3 { 2 } else { 1 };
                let project = s >= 3;
                ops::relu_bwd(&mut g, out); // g_sum = g ⊙ (out > 0)
                let ad = (ops::out_size(xd.0, stride),
                          ops::out_size(xd.1, stride), cout);
                let wb = &params[off + 2];
                let (gwb, gbb, mut ga) =
                    ops::conv2d_bwd(a, ad, wb, 3, cout, 1, &g);
                ops::relu_bwd(&mut ga, a);
                let wa = &params[off];
                let (gwa, gba, mut gx) =
                    ops::conv2d_bwd(x, xd, wa, 3, cout, stride, &ga);
                if project {
                    let wp = &params[off + 4];
                    let (gwp, gbp, gxp) =
                        ops::conv2d_bwd(x, xd, wp, 1, cout, stride, &g);
                    ops::add_assign(&mut gx, &gxp);
                    grads.push(gbp);
                    grads.push(gwp);
                } else {
                    ops::add_assign(&mut gx, &g);
                }
                grads.push(gbb);
                grads.push(gwb);
                grads.push(gba);
                grads.push(gwa);
                g = gx;
            }
        }
    }
    grads.reverse();
    (grads, g)
}

/// Reference client-side FP (stages 1..cut) over a batch — the retained
/// naive per-sample oracle of [`client_fwd`].
pub fn client_fwd_reference(cfg: &SplitNetConfig, cut: usize,
                            params: &[Vec<f32>], x: &[f32], b: usize)
    -> Vec<f32> {
    let in_len = cfg.img * cfg.img * cfg.channels;
    let (sh, sw, sc) = stage_out_dims(cfg, cut);
    let smash_len = sh * sw * sc;
    let mut out = Vec::with_capacity(b * smash_len);
    for j in 0..b {
        let (s, _) = forward(cfg, params, 1, cut, false,
                             &x[j * in_len..][..in_len]);
        out.extend_from_slice(&s);
    }
    out
}

/// Reference client-side BP + SGD (eq. 8–12) — the retained naive
/// per-sample oracle of [`client_step`]: cotangent `g_cut/b` per row,
/// then `w ← w − η_c · gw` with gradients accumulated in sample order.
pub fn client_step_reference(cfg: &SplitNetConfig, cut: usize,
                             params: &[Vec<f32>], x: &[f32],
                             g_cut: &[f32], lr: f32, b: usize)
    -> Vec<Vec<f32>> {
    let in_len = cfg.img * cfg.img * cfg.channels;
    let (sh, sw, sc) = stage_out_dims(cfg, cut);
    let smash_len = sh * sw * sc;
    let mut acc: Vec<Vec<f32>> =
        params.iter().map(|p| vec![0.0f32; p.len()]).collect();
    let inv_b = 1.0 / b as f32;
    for j in 0..b {
        let xs = &x[j * in_len..][..in_len];
        let (_, cache) = forward(cfg, params, 1, cut, false, xs);
        let cot: Vec<f32> = g_cut[j * smash_len..][..smash_len]
            .iter()
            .map(|&v| v * inv_b)
            .collect();
        let (grads, _) = backward(cfg, params, 1, cut, false, &cache, &cot);
        for (a, gr) in acc.iter_mut().zip(&grads) {
            ops::add_assign(a, gr);
        }
    }
    params
        .iter()
        .zip(&acc)
        .map(|(p, g)| {
            p.iter().zip(g).map(|(&w, &gv)| w - lr * gv).collect()
        })
        .collect()
}

/// Output bundle of [`server_train`], in manifest output order.
pub struct ServerTrainOut {
    pub new_params: Vec<Vec<f32>>,
    /// `(b, *smash)` broadcast cut-layer gradient (masked rows; others 0).
    pub cut_agg: Vec<f32>,
    /// `(C, b, *smash)` unicast gradients (masked slots zero).
    pub cut_unagg: Vec<f32>,
    pub loss: f32,
    pub ncorrect: f32,
}

/// Per-sample result of the real-batch FP/BP pass.
struct RealSample {
    ce: f32,
    correct: bool,
    dlogits: Vec<f32>,
    /// `(gw, gs)` when the unicast cotangent was nonzero.
    bp: Option<(Vec<Vec<f32>>, Vec<f32>)>,
}

/// Reference EPSL server step (paper §IV stages 3–6, eq. 5–7) — the
/// retained naive per-sample oracle of [`server_train`]. Labels must be
/// pre-validated (the fast public path does this and returns
/// `Error::Data`; the oracle asserts).
#[allow(clippy::too_many_arguments)]
pub fn server_train_reference(cfg: &SplitNetConfig, cut: usize, c: usize,
                              b: usize, threads: usize,
                              params: &[Vec<f32>], smashed: &[f32],
                              labels: &[i32], lam: &[f32], mask: &[f32],
                              lr: f32)
    -> ServerTrainOut {
    let (sh, sw, sc) = stage_out_dims(cfg, cut);
    let smash_len = sh * sw * sc;
    let nc = cfg.num_classes;
    let inv_b = 1.0 / b as f32;

    // --- real pass: FP over all C·b samples; BP of the unaggregated
    // slots with row weight λ_i/b (eq. 5 remaining blocks) ---
    let idx: Vec<usize> = (0..c * b).collect();
    let real: Vec<RealSample> = par::parallel_map(&idx, threads, |_, &k| {
        let (i, j) = (k / b, k % b);
        let row = &smashed[k * smash_len..][..smash_len];
        let (logits, cache) =
            forward(cfg, params, cut + 1, 4, true, row);
        let (ce, dlogits, correct) = ops::softmax_xent(&logits, labels[k]);
        let unmask = 1.0 - mask[j];
        let weight = unmask * lam[i] * inv_b;
        let bp = if weight != 0.0 {
            let cot: Vec<f32> =
                dlogits.iter().map(|&z| weight * z).collect();
            let (gw, gs) =
                backward(cfg, params, cut + 1, 4, true, &cache, &cot);
            Some((gw, gs))
        } else {
            None
        };
        RealSample { ce, correct, dlogits, bp }
    });

    // Loss / accuracy reductions in flat sample order (eq. 1 weighting).
    let mut loss = 0.0f32;
    let mut ncorrect = 0.0f32;
    for (k, r) in real.iter().enumerate() {
        loss += lam[k / b] * r.ce;
        ncorrect += r.correct as u32 as f32;
    }
    loss *= inv_b;

    // --- virtual aggregated batch (eq. 6): λ-aggregate the smashed rows
    // and last-layer gradients for the ⌈φb⌉ masked slots, one BP row each
    // (eq. 5 first block, row weight 1/b) ---
    let masked: Vec<usize> =
        (0..b).filter(|&j| mask[j] != 0.0).collect();
    let virt = par::parallel_map(&masked, threads, |_, &j| {
        let mut sbar = vec![0.0f32; smash_len];
        let mut zbar = vec![0.0f32; nc];
        for i in 0..c {
            ops::axpy(&mut sbar, lam[i],
                      &smashed[(i * b + j) * smash_len..][..smash_len]);
            ops::axpy(&mut zbar, lam[i], &real[i * b + j].dlogits);
        }
        let (_, cache) = forward(cfg, params, cut + 1, 4, true, &sbar);
        let cot: Vec<f32> =
            zbar.iter().map(|&z| mask[j] * z * inv_b).collect();
        backward(cfg, params, cut + 1, 4, true, &cache, &cot)
    });

    // --- outputs ---
    let bf = b as f32;
    let mut cut_agg = vec![0.0f32; b * smash_len];
    for (&j, (_, gs)) in masked.iter().zip(&virt) {
        for (dst, &g) in
            cut_agg[j * smash_len..][..smash_len].iter_mut().zip(gs)
        {
            *dst = g * bf; // raw activations' gradients for the broadcast
        }
    }
    let mut cut_unagg = vec![0.0f32; c * b * smash_len];
    for (k, r) in real.iter().enumerate() {
        if let Some((_, gs)) = &r.bp {
            let (i, j) = (k / b, k % b);
            // Divide the λ_i/b row weight back out (unicast payload);
            // masked slots stay zero.
            let scale = (1.0 - mask[j]) * bf / lam[i].max(1e-12);
            for (dst, &g) in cut_unagg[k * smash_len..][..smash_len]
                .iter_mut()
                .zip(gs)
            {
                *dst = g * scale;
            }
        }
    }

    // --- parameter update (eq. 7): g = Σ virtual rows + Σ real samples,
    // both in ascending order ---
    let mut acc: Vec<Vec<f32>> =
        params.iter().map(|p| vec![0.0f32; p.len()]).collect();
    for (gw, _) in &virt {
        for (a, g) in acc.iter_mut().zip(gw) {
            ops::add_assign(a, g);
        }
    }
    for r in &real {
        if let Some((gw, _)) = &r.bp {
            for (a, g) in acc.iter_mut().zip(gw) {
                ops::add_assign(a, g);
            }
        }
    }
    let new_params = params
        .iter()
        .zip(&acc)
        .map(|(p, g)| {
            p.iter().zip(g).map(|(&w, &gv)| w - lr * gv).collect()
        })
        .collect();

    ServerTrainOut { new_params, cut_agg, cut_unagg, loss, ncorrect }
}

/// Reference full-model eval on a fixed-size batch — the retained naive
/// per-sample oracle of [`eval`]: `(mean CE, ncorrect)`.
pub fn eval_reference(cfg: &SplitNetConfig, params: &[Vec<f32>],
                      x: &[f32], labels: &[i32], threads: usize)
    -> (f32, f32) {
    let in_len = cfg.img * cfg.img * cfg.channels;
    let n = labels.len();
    let idx: Vec<usize> = (0..n).collect();
    let per: Vec<(f32, bool)> = par::parallel_map(&idx, threads, |_, &j| {
        let (logits, _) = forward(cfg, params, 1, 4, true,
                                  &x[j * in_len..][..in_len]);
        let (ce, _, correct) = ops::softmax_xent(&logits, labels[j]);
        (ce, correct)
    });
    let mut loss = 0.0f32;
    let mut ncorr = 0.0f32;
    for (ce, correct) in per {
        loss += ce;
        ncorr += correct as u32 as f32;
    }
    (loss / n as f32, ncorr)
}

// ---------------------------------------------------------------------
// Fast path: batched im2col + blocked-GEMM forward, per-sample GEMM
// backward on pooled scratch arenas. Bit-identical to the reference
// implementations above (property-tested in tests/property_kernels.rs).
// ---------------------------------------------------------------------

/// Patch-buffer budget of one batched conv (f32 elements, 8 MiB): the
/// sample group is sized so the im2col buffer stays bounded even for the
/// C=32 virtual batch.
const MAX_PATCH_F32: usize = 2 << 20;
/// Output rows per blocked-GEMM work item in the batched forward.
const GEMM_BLOCK_ROWS: usize = 128;
/// Elementwise-op chunk (relu / residual-add fan-out).
const ELEM_CHUNK: usize = 1 << 16;

/// One batched conv layer: im2col across a group of samples (fanned per
/// sample), then one blocked GEMM over the group's rows (fanned per
/// row-block). Groups run in ascending order and every output element
/// keeps the reference summation order, so the result is bit-identical
/// to per-sample `ops::conv2d` for any thread count.
#[allow(clippy::too_many_arguments)]
fn conv_batch(n: usize, x_all: &[f32], xd: Dims, w: &[f32], k: usize,
              cout: usize, bias: &[f32], stride: usize, threads: usize,
              tier: MathTier, patch: &mut Buf, y_all: &mut [f32]) {
    let (h, ww, cin) = xd;
    let in_len = h * ww * cin;
    let rows = ops::out_size(h, stride) * ops::out_size(ww, stride);
    let kc = kernels::patch_cols(k, cin);
    let per = rows * kc;
    debug_assert_eq!(x_all.len(), n * in_len);
    debug_assert_eq!(y_all.len(), n * rows * cout);
    if n == 0 || per == 0 {
        return;
    }
    let group = (MAX_PATCH_F32 / per).clamp(1, n);
    let p = patch.get(group * per);
    let mut s0 = 0;
    while s0 < n {
        let gn = group.min(n - s0);
        let pg = &mut p[..gn * per];
        par::parallel_chunks_mut(pg, per, threads, |si, chunk| {
            kernels::im2col(&x_all[(s0 + si) * in_len..][..in_len], xd,
                            k, stride, chunk);
        });
        let pg: &[f32] = pg;
        let out_g = &mut y_all[s0 * rows * cout..][..gn * rows * cout];
        match tier {
            MathTier::Bitwise => par::parallel_chunks_mut(
                out_g, GEMM_BLOCK_ROWS * cout, threads, |bi, chunk| {
                    let r0 = bi * GEMM_BLOCK_ROWS;
                    let m = chunk.len() / cout;
                    kernels::gemm_bias(m, kc, cout,
                                       &pg[r0 * kc..][..m * kc], w, bias,
                                       chunk);
                },
            ),
            // The fast tier's threaded SIMD macro-loop over the same
            // group rows (tolerance contract, PERF.md §10).
            MathTier::Fast => kernels_fast::gemm_bias_mt(
                gn * rows, kc, cout, pg, w, bias, out_g, threads),
        }
        s0 += gn;
    }
}

fn relu_batch(x: &mut [f32], threads: usize) {
    par::parallel_chunks_mut(x, ELEM_CHUNK, threads, |_, c| ops::relu(c));
}

fn add_batch(a: &mut [f32], b: &[f32], threads: usize) {
    debug_assert_eq!(a.len(), b.len());
    par::parallel_chunks_mut(a, ELEM_CHUNK, threads, |i, c| {
        ops::add_assign(c, &b[i * ELEM_CHUNK..][..c.len()]);
    });
}

/// Batched activation cache of [`forward_batch`]: per executed stage the
/// post-relu activations of all `n` samples — exactly what the reference
/// per-sample [`Cache`] retains — plus the pooled head inputs.
pub struct BatchCache {
    n: usize,
    /// Per-sample element count of each stage's output.
    out_lens: Vec<usize>,
    stages: Vec<BatchStage>,
    /// Pooled GAP outputs (`n · c4`) when the head ran.
    pooled: Option<Vec<f32>>,
}

enum BatchStage {
    /// stage 1: post-relu output.
    Conv { y: Vec<f32> },
    /// stages 2–4: post-relu `a` and block output.
    Res { a: Vec<f32>, out: Vec<f32> },
}

impl BatchStage {
    fn out(&self) -> &[f32] {
        match self {
            BatchStage::Conv { y } => y,
            BatchStage::Res { out, .. } => out,
        }
    }
}

impl BatchCache {
    /// Move the final stage's batched output out of the cache — the
    /// smashed activations for [`client_fwd`].
    fn into_last_out(mut self) -> Vec<f32> {
        // audit:allow(R1, "into_last_out is only called after the forward loop pushed >= 1 stage")
        match self.stages.pop().expect("at least one stage ran") {
            BatchStage::Conv { y } => y,
            BatchStage::Res { out, .. } => out,
        }
    }
}

/// Batched forward through stages `[first..=last]` (+ head) over `n`
/// samples: one im2col + blocked GEMM per conv layer across the whole
/// batch — the server-side parallelism of the paper, generalized to
/// every forward. Bit-identical per sample to the reference
/// [`forward`]. Returns `(logits (n·nc; empty unless with_head), cache)`.
///
/// `keep` retains the full activation cache for a following
/// [`backward_sample`] pass; inference callers (`eval`, `client_fwd`)
/// pass `false`, which keeps only the rolling last stage output (the
/// next layer's input), so the live footprint stays at two stage
/// buffers instead of the whole batch's intermediates.
#[allow(clippy::too_many_arguments)]
fn forward_batch(cfg: &SplitNetConfig, params: &[Vec<f32>], first: usize,
                 last: usize, with_head: bool, keep: bool, xs: &[f32],
                 n: usize, threads: usize, tier: MathTier,
                 ws: &mut Scratch)
    -> (Vec<f32>, BatchCache) {
    let mut cache = BatchCache {
        n,
        out_lens: Vec::new(),
        stages: Vec::new(),
        pooled: None,
    };
    let mut off = 0;
    for s in first..=last {
        let xd = stage_in_dims(cfg, s);
        let (oh, ow, cout) = stage_out_dims(cfg, s);
        let out_len = oh * ow * cout;
        let x_all: &[f32] = match cache.stages.last() {
            Some(st) => st.out(),
            None => xs,
        };
        if s == 1 {
            let (w, b) = (&params[off], &params[off + 1]);
            let mut y = vec![0.0f32; n * out_len];
            conv_batch(n, x_all, xd, w, 3, cout, b, 1, threads, tier,
                       &mut ws.patch, &mut y);
            relu_batch(&mut y, threads);
            cache.stages.push(BatchStage::Conv { y });
        } else {
            let stride = if s >= 3 { 2 } else { 1 };
            let project = s >= 3;
            let (wa, ba) = (&params[off], &params[off + 1]);
            let (wb, bb) = (&params[off + 2], &params[off + 3]);
            let mut a = vec![0.0f32; n * out_len];
            conv_batch(n, x_all, xd, wa, 3, cout, ba, stride, threads,
                       tier, &mut ws.patch, &mut a);
            relu_batch(&mut a, threads);
            let ad = (oh, ow, cout);
            let mut out = vec![0.0f32; n * out_len];
            conv_batch(n, &a, ad, wb, 3, cout, bb, 1, threads, tier,
                       &mut ws.patch, &mut out);
            if project {
                let (wp, bp) = (&params[off + 4], &params[off + 5]);
                let skip = ws.skip.get(n * out_len);
                conv_batch(n, x_all, xd, wp, 1, cout, bp, stride,
                           threads, tier, &mut ws.patch, skip);
                add_batch(&mut out, skip, threads);
            } else {
                add_batch(&mut out, x_all, threads);
            }
            relu_batch(&mut out, threads);
            // Inference never revisits `a`; drop it immediately.
            let a = if keep { a } else { Vec::new() };
            cache.stages.push(BatchStage::Res { a, out });
        }
        if !keep && cache.stages.len() >= 2 {
            // The stage before the one just pushed has served its turn
            // as layer input; release it.
            let idx = cache.stages.len() - 2;
            cache.stages.remove(idx);
        }
        cache.out_lens.push(out_len);
        off += STAGE_PARAM_COUNTS[s - 1];
    }
    let mut logits_all = Vec::new();
    if with_head {
        let xd = stage_out_dims(cfg, 4);
        let hlen = xd.0 * xd.1 * xd.2;
        let nc = cfg.num_classes;
        let (fc_w, fc_b) = (&params[off], &params[off + 1]);
        let h_all: &[f32] = match cache.stages.last() {
            Some(st) => st.out(),
            None => xs,
        };
        let mut pooled_all = vec![0.0f32; n * xd.2];
        logits_all = vec![0.0f32; n * nc];
        for j in 0..n {
            let (lg, pl) = ops::gap_fc(&h_all[j * hlen..][..hlen], xd,
                                       fc_w, fc_b, nc);
            logits_all[j * nc..][..nc].copy_from_slice(&lg);
            pooled_all[j * xd.2..][..xd.2].copy_from_slice(&pl);
        }
        cache.pooled = Some(pooled_all);
    }
    (logits_all, cache)
}

/// Per-sample backward on the fast kernels, reading activations from the
/// batch cache and running every conv gradient as im2col + GEMM with the
/// pooled scratch arena — bit-identical to the reference [`backward`]
/// (same gradient layout and summation orders). `xs_sample` is this
/// sample's stage-`first` input.
#[allow(clippy::too_many_arguments)]
fn backward_sample(cfg: &SplitNetConfig, params: &[Vec<f32>],
                   first: usize, last: usize, with_head: bool,
                   xs_sample: &[f32], cache: &BatchCache, j: usize,
                   cot: &[f32], tier: MathTier, scratch: &mut Scratch)
    -> (Vec<Vec<f32>>, Vec<f32>) {
    debug_assert!(j < cache.n);
    // The tier's conv-backward kernel: identical signatures, so the
    // stage loop below is tier-oblivious.
    let conv_bwd = match tier {
        MathTier::Bitwise => kernels::conv2d_bwd_fast,
        MathTier::Fast => kernels_fast::conv2d_bwd_fast,
    };
    let Scratch {
        ref mut patch, ref mut dpatch, ref mut ga, ref mut gproj, ..
    } = *scratch;
    let (ga_buf, gproj_buf) = (ga, gproj);
    let mut grads: Vec<Vec<f32>> = Vec::with_capacity(params.len());
    let mut g = cot.to_vec();
    let mut off = params.len();
    if with_head {
        let xd = stage_out_dims(cfg, 4);
        // audit:allow(R1, "with_head callers always ran the batched head forward that fills cache.pooled")
        let pooled_all = cache.pooled.as_ref().expect("head cache");
        let pooled = &pooled_all[j * xd.2..][..xd.2];
        let fc_w = &params[off - 2];
        let (gw, gb, gx) =
            ops::gap_fc_bwd(pooled, xd, fc_w, cfg.num_classes, &g);
        grads.push(gb);
        grads.push(gw);
        g = gx;
        off -= 2;
    }
    for s in (first..=last).rev() {
        let xd = stage_in_dims(cfg, s);
        let (_, _, cout) = stage_out_dims(cfg, s);
        let si = s - first;
        let out_len = cache.out_lens[si];
        let in_len = xd.0 * xd.1 * xd.2;
        let x: &[f32] = if si == 0 {
            xs_sample
        } else {
            &cache.stages[si - 1].out()[j * in_len..][..in_len]
        };
        off -= STAGE_PARAM_COUNTS[s - 1];
        match &cache.stages[si] {
            BatchStage::Conv { y } => {
                ops::relu_bwd(&mut g, &y[j * out_len..][..out_len]);
                let w = &params[off];
                let mut gw = vec![0.0f32; w.len()];
                let mut gb = vec![0.0f32; cout];
                let mut gx = vec![0.0f32; in_len];
                conv_bwd(x, xd, w, 3, cout, 1, &g, patch, dpatch,
                         &mut gw, &mut gb, &mut gx);
                grads.push(gb);
                grads.push(gw);
                g = gx;
            }
            BatchStage::Res { a, out } => {
                let stride = if s >= 3 { 2 } else { 1 };
                let project = s >= 3;
                ops::relu_bwd(&mut g, &out[j * out_len..][..out_len]);
                let ad = (ops::out_size(xd.0, stride),
                          ops::out_size(xd.1, stride), cout);
                let a_s = &a[j * out_len..][..out_len];
                let wb = &params[off + 2];
                let mut gwb = vec![0.0f32; wb.len()];
                let mut gbb = vec![0.0f32; cout];
                let ga = ga_buf.get(out_len);
                conv_bwd(a_s, ad, wb, 3, cout, 1, &g, patch, dpatch,
                         &mut gwb, &mut gbb, ga);
                ops::relu_bwd(ga, a_s);
                let wa = &params[off];
                let mut gwa = vec![0.0f32; wa.len()];
                let mut gba = vec![0.0f32; cout];
                let mut gx = vec![0.0f32; in_len];
                conv_bwd(x, xd, wa, 3, cout, stride, ga, patch, dpatch,
                         &mut gwa, &mut gba, &mut gx);
                if project {
                    let wp = &params[off + 4];
                    let mut gwp = vec![0.0f32; wp.len()];
                    let mut gbp = vec![0.0f32; cout];
                    let gxp = gproj_buf.get(in_len);
                    conv_bwd(x, xd, wp, 1, cout, stride, &g, patch,
                             dpatch, &mut gwp, &mut gbp, gxp);
                    ops::add_assign(&mut gx, gxp);
                    grads.push(gbp);
                    grads.push(gwp);
                } else {
                    ops::add_assign(&mut gx, &g);
                }
                grads.push(gbb);
                grads.push(gwb);
                grads.push(gba);
                grads.push(gwa);
                g = gx;
            }
        }
    }
    grads.reverse();
    (grads, g)
}

/// Client-side FP (stages 1..cut) over a batch on the fast batched
/// kernels: `x (b,img,img,ch)` → smashed `(b,*smash)`. Bit-identical to
/// [`client_fwd_reference`]. Runs single-threaded internally — the
/// driver already fans whole clients across cores via `call_many`.
pub fn client_fwd(cfg: &SplitNetConfig, cut: usize, params: &[Vec<f32>],
                  x: &[f32], b: usize, tier: MathTier,
                  pool: &ScratchPool) -> Vec<f32> {
    pool.with(|ws| {
        let (_, cache) = forward_batch(cfg, params, 1, cut, false, false,
                                       x, b, 1, tier, ws);
        cache.into_last_out()
    })
}

/// Client-side BP + SGD (eq. 8–12) on the fast kernels — bit-identical
/// to [`client_step_reference`]: batched FP, per-sample GEMM BP with
/// gradients accumulated in sample order, then `w ← w − η_c · gw`.
#[allow(clippy::too_many_arguments)]
pub fn client_step(cfg: &SplitNetConfig, cut: usize, params: &[Vec<f32>],
                   x: &[f32], g_cut: &[f32], lr: f32, b: usize,
                   tier: MathTier, pool: &ScratchPool) -> Vec<Vec<f32>> {
    let in_len = cfg.img * cfg.img * cfg.channels;
    let (sh, sw, sc) = stage_out_dims(cfg, cut);
    let smash_len = sh * sw * sc;
    let inv_b = 1.0 / b as f32;
    pool.with(|ws| {
        let (_, cache) = forward_batch(cfg, params, 1, cut, false, true,
                                       x, b, 1, tier, ws);
        let mut acc: Vec<Vec<f32>> =
            params.iter().map(|p| vec![0.0f32; p.len()]).collect();
        for j in 0..b {
            let xs = &x[j * in_len..][..in_len];
            let cot: Vec<f32> = g_cut[j * smash_len..][..smash_len]
                .iter()
                .map(|&v| v * inv_b)
                .collect();
            let (grads, _) =
                backward_sample(cfg, params, 1, cut, false, xs, &cache,
                                j, &cot, tier, ws);
            for (a, gr) in acc.iter_mut().zip(&grads) {
                ops::add_assign(a, gr);
            }
        }
        params
            .iter()
            .zip(&acc)
            .map(|(p, g)| {
                p.iter().zip(g).map(|(&w, &gv)| w - lr * gv).collect()
            })
            .collect()
    })
}

/// EPSL server step (paper §IV stages 3–6, eq. 5–7) on the fast batched
/// kernels — the semantics of the `server_train_cut{k}_c{C}` graph and
/// the drop-in replacement for [`server_train_reference`], bit-identical
/// to it (property-tested). The FP over the `C·b` virtual batch runs as
/// one im2col + blocked GEMM per layer; the per-sample BP fans across
/// cores with pooled scratch arenas; all reductions stay serial in
/// sample order, so results are `EPSL_THREADS`-invariant. Labels are
/// validated up front and surface as `Error::Data` instead of panicking
/// a worker mid-round.
#[allow(clippy::too_many_arguments)]
pub fn server_train(cfg: &SplitNetConfig, cut: usize, c: usize, b: usize,
                    threads: usize, tier: MathTier, params: &[Vec<f32>],
                    smashed: &[f32], labels: &[i32], lam: &[f32],
                    mask: &[f32], lr: f32, pool: &ScratchPool)
    -> Result<ServerTrainOut> {
    ops::check_labels(labels, cfg.num_classes)?;
    let (sh, sw, sc) = stage_out_dims(cfg, cut);
    let smash_len = sh * sw * sc;
    let nc = cfg.num_classes;
    let inv_b = 1.0 / b as f32;

    // --- real pass: batched FP over all C·b rows, then BP of the
    // unaggregated slots with row weight λ_i/b, fanned per sample ---
    let (real, bps) = pool.with(|ws| {
        let (logits_all, cache) = forward_batch(cfg, params, cut + 1, 4,
                                                true, true, smashed,
                                                c * b, threads, tier,
                                                ws);
        let real: Vec<(f32, bool, Vec<f32>)> = (0..c * b)
            .map(|k| {
                let (ce, d, correct) = ops::softmax_xent(
                    &logits_all[k * nc..][..nc], labels[k]);
                (ce, correct, d)
            })
            .collect();
        let todo: Vec<usize> = (0..c * b)
            .filter(|&k| {
                (1.0 - mask[k % b]) * lam[k / b] * inv_b != 0.0
            })
            .collect();
        let bps = par::parallel_map(&todo, threads, |_, &k| {
                let (i, j) = (k / b, k % b);
                let weight = (1.0 - mask[j]) * lam[i] * inv_b;
                let cot: Vec<f32> =
                    real[k].2.iter().map(|&z| weight * z).collect();
                let xs = &smashed[k * smash_len..][..smash_len];
                let out = pool.with(|scratch| {
                    backward_sample(cfg, params, cut + 1, 4, true, xs,
                                    &cache, k, &cot, tier, scratch)
                });
                (k, out)
            });
        (real, bps)
    });

    // Loss / accuracy reductions in flat sample order (eq. 1 weighting).
    let mut loss = 0.0f32;
    let mut ncorrect = 0.0f32;
    for (k, r) in real.iter().enumerate() {
        loss += lam[k / b] * r.0;
        ncorrect += r.1 as u32 as f32;
    }
    loss *= inv_b;

    // --- virtual aggregated batch (eq. 6): λ-aggregate the smashed rows
    // and last-layer gradients per masked slot, batched FP over the
    // virtual rows, one BP row each (row weight 1/b) ---
    let masked: Vec<usize> =
        (0..b).filter(|&j| mask[j] != 0.0).collect();
    let nm = masked.len();
    let mut sbar_all = vec![0.0f32; nm * smash_len];
    let mut zbar_all = vec![0.0f32; nm * nc];
    for (mi, &j) in masked.iter().enumerate() {
        let sbar = &mut sbar_all[mi * smash_len..][..smash_len];
        let zbar = &mut zbar_all[mi * nc..][..nc];
        for i in 0..c {
            ops::axpy(sbar, lam[i],
                      &smashed[(i * b + j) * smash_len..][..smash_len]);
            ops::axpy(zbar, lam[i], &real[i * b + j].2);
        }
    }
    let virt = pool.with(|ws| {
        let (_, vcache) = forward_batch(cfg, params, cut + 1, 4, true,
                                        true, &sbar_all, nm, threads,
                                        tier, ws);
        par::parallel_map(&masked, threads, |mi, &j| {
            let cot: Vec<f32> = zbar_all[mi * nc..][..nc]
                .iter()
                .map(|&z| mask[j] * z * inv_b)
                .collect();
            let xs = &sbar_all[mi * smash_len..][..smash_len];
            pool.with(|scratch| {
                backward_sample(cfg, params, cut + 1, 4, true, xs,
                                &vcache, mi, &cot, tier, scratch)
            })
        })
    });

    // --- outputs (identical reduction orders to the reference) ---
    let bf = b as f32;
    let mut cut_agg = vec![0.0f32; b * smash_len];
    for (&j, (_, gs)) in masked.iter().zip(&virt) {
        for (dst, &g) in
            cut_agg[j * smash_len..][..smash_len].iter_mut().zip(gs)
        {
            *dst = g * bf;
        }
    }
    let mut cut_unagg = vec![0.0f32; c * b * smash_len];
    for (k, (_, gs)) in bps.iter().map(|(k, o)| (*k, o)) {
        let (i, j) = (k / b, k % b);
        let scale = (1.0 - mask[j]) * bf / lam[i].max(1e-12);
        for (dst, &g) in cut_unagg[k * smash_len..][..smash_len]
            .iter_mut()
            .zip(gs)
        {
            *dst = g * scale;
        }
    }

    // --- parameter update (eq. 7): virtual rows then real samples, both
    // ascending ---
    let mut acc: Vec<Vec<f32>> =
        params.iter().map(|p| vec![0.0f32; p.len()]).collect();
    for (gw, _) in &virt {
        for (a, g) in acc.iter_mut().zip(gw) {
            ops::add_assign(a, g);
        }
    }
    for (_, (gw, _)) in &bps {
        for (a, g) in acc.iter_mut().zip(gw) {
            ops::add_assign(a, g);
        }
    }
    let new_params = params
        .iter()
        .zip(&acc)
        .map(|(p, g)| {
            p.iter().zip(g).map(|(&w, &gv)| w - lr * gv).collect()
        })
        .collect();

    Ok(ServerTrainOut { new_params, cut_agg, cut_unagg, loss, ncorrect })
}

/// Full-model eval on a fixed-size batch, batched on the fast kernels —
/// bit-identical to [`eval_reference`]: `(mean CE, ncorrect)`. Labels
/// are validated up front and surface as `Error::Data`.
pub fn eval(cfg: &SplitNetConfig, params: &[Vec<f32>], x: &[f32],
            labels: &[i32], threads: usize, tier: MathTier,
            pool: &ScratchPool)
    -> Result<(f32, f32)> {
    ops::check_labels(labels, cfg.num_classes)?;
    let n = labels.len();
    let nc = cfg.num_classes;
    let logits_all = pool.with(|ws| {
        forward_batch(cfg, params, 1, 4, true, false, x, n, threads,
                      tier, ws)
            .0
    });
    let mut loss = 0.0f32;
    let mut ncorr = 0.0f32;
    for (j, &y) in labels.iter().enumerate() {
        let (ce, _, correct) =
            ops::softmax_xent(&logits_all[j * nc..][..nc], y);
        loss += ce;
        ncorr += correct as u32 as f32;
    }
    Ok((loss / n as f32, ncorr))
}

/// The φ-aggregation kernel semantics (`phi_aggregate_nd`): masked rows of
/// every client hold the λ-aggregate, unmasked rows pass through.
pub fn phi_agg(c: usize, b: usize, q: usize, z: &[f32], lam: &[f32],
               mask: &[f32]) -> Vec<f32> {
    let mut out = z.to_vec();
    for j in 0..b {
        if mask[j] == 0.0 {
            continue;
        }
        let mut zbar = vec![0.0f32; q];
        for i in 0..c {
            ops::axpy(&mut zbar, lam[i], &z[(i * b + j) * q..][..q]);
        }
        for i in 0..c {
            out[(i * b + j) * q..][..q].copy_from_slice(&zbar);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SplitNetConfig {
        SplitNetConfig::mnist_like()
    }

    #[test]
    fn param_specs_match_the_python_contract() {
        let specs = param_specs(&cfg());
        assert_eq!(specs.len(), 20);
        let total: usize =
            specs.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        // Cross-language constant (profile::splitnet::param_count).
        assert_eq!(total, 19_642);
        assert_eq!(client_param_count(1), 2);
        assert_eq!(client_param_count(2), 6);
        assert_eq!(client_param_count(3), 12);
        assert_eq!(client_param_count(4), 18);
        assert_eq!(specs[0].0, "s1.w");
        assert_eq!(specs[19].0, "fc.b");
    }

    #[test]
    fn init_is_deterministic_and_he_scaled() {
        let a = init_params(&cfg(), 42);
        let b = init_params(&cfg(), 42);
        assert_eq!(a, b);
        let c = init_params(&cfg(), 43);
        assert_ne!(a[0], c[0]);
        // biases zero
        assert!(a[1].iter().all(|&v| v == 0.0));
        // He std for s1.w: sqrt(2 / (3*3*1)) ≈ 0.471
        let std = (a[0].iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / a[0].len() as f64)
            .sqrt();
        assert!((std - 0.471).abs() < 0.15, "std={std}");
    }

    #[test]
    fn full_forward_shapes() {
        let p = init_params(&cfg(), 1);
        let x = vec![0.1f32; 16 * 16];
        let (logits, _) = forward(&cfg(), &p, 1, 4, true, &x);
        assert_eq!(logits.len(), 10);
        // split at cut 2: client stages 1-2 then server 3-4+head compose
        // to the same logits
        let n = client_param_count(2);
        let (smash, _) = forward(&cfg(), &p[..n], 1, 2, false, &x);
        assert_eq!(smash.len(), 16 * 16 * 8);
        let (logits2, _) = forward(&cfg(), &p[n..], 3, 4, true, &smash);
        assert_eq!(logits, logits2, "split forward must compose exactly");
    }

    #[test]
    fn client_backward_matches_finite_difference() {
        let cfg = cfg();
        let p = init_params(&cfg, 5);
        let n = client_param_count(1); // stage 1 only: cheap FD
        let x: Vec<f32> =
            (0..256).map(|i| ((i % 13) as f32 - 6.0) / 7.0).collect();
        let cot: Vec<f32> = (0..16 * 16 * 8)
            .map(|i| ((i % 7) as f32 - 3.0) / 50.0)
            .collect();
        let loss = |params: &[Vec<f32>]| -> f64 {
            let (y, _) = forward(&cfg, params, 1, 1, false, &x);
            y.iter().zip(&cot).map(|(&a, &b)| (a * b) as f64).sum()
        };
        let (_, cache) = forward(&cfg, &p[..n], 1, 1, false, &x);
        let (grads, _) =
            backward(&cfg, &p[..n], 1, 1, false, &cache, &cot);
        assert_eq!(grads.len(), 2);
        let eps = 1e-3;
        let base = loss(&p[..n]);
        for t in 0..2 {
            for i in [0usize, 3] {
                let mut pp: Vec<Vec<f32>> = p[..n].to_vec();
                pp[t][i] += eps;
                let num = (loss(&pp) - base) / eps as f64;
                assert!(
                    (num - grads[t][i] as f64).abs() < 2e-2,
                    "grad[{t}][{i}]: num {num} vs {}",
                    grads[t][i]
                );
            }
        }
    }

    #[test]
    fn server_train_is_thread_count_invariant() {
        let cfg = cfg();
        let (cut, c, b) = (2, 3, 8);
        let p = init_params(&cfg, 9);
        let n = client_param_count(cut);
        let smash_len = 16 * 16 * 8;
        let mut rng = Rng::new(4);
        let smashed: Vec<f32> = (0..c * b * smash_len)
            .map(|_| rng.normal(0.0, 1.0) as f32)
            .collect();
        let labels: Vec<i32> =
            (0..c * b).map(|k| (k % 10) as i32).collect();
        let lam = vec![1.0 / c as f32; c];
        let mask: Vec<f32> =
            (0..b).map(|j| if j < b / 2 { 1.0 } else { 0.0 }).collect();
        let pool = ScratchPool::new();
        let a = server_train(&cfg, cut, c, b, 1, MathTier::Bitwise,
                             &p[n..], &smashed, &labels, &lam, &mask,
                             0.05, &pool)
            .unwrap();
        let z = server_train(&cfg, cut, c, b, 7, MathTier::Bitwise,
                             &p[n..], &smashed, &labels, &lam, &mask,
                             0.05, &pool)
            .unwrap();
        assert_eq!(a.loss.to_bits(), z.loss.to_bits());
        assert_eq!(a.cut_agg, z.cut_agg);
        assert_eq!(a.cut_unagg, z.cut_unagg);
        assert_eq!(a.new_params, z.new_params);
        // ... and bit-identical to the retained naive reference.
        let r = server_train_reference(&cfg, cut, c, b, 3, &p[n..],
                                       &smashed, &labels, &lam, &mask,
                                       0.05);
        assert_eq!(a.loss.to_bits(), r.loss.to_bits());
        assert_eq!(a.cut_agg, r.cut_agg);
        assert_eq!(a.cut_unagg, r.cut_unagg);
        assert_eq!(a.new_params, r.new_params);
    }

    #[test]
    fn server_train_rejects_corrupt_labels() {
        let cfg = cfg();
        let (cut, c, b) = (2, 2, 4);
        let p = init_params(&cfg, 9);
        let n = client_param_count(cut);
        let smash_len = 16 * 16 * 8;
        let smashed = vec![0.1f32; c * b * smash_len];
        let lam = vec![0.5f32; c];
        let mask = vec![1.0f32; b];
        let pool = ScratchPool::new();
        for bad in [-1i32, 10, i32::MIN] {
            let mut labels: Vec<i32> = vec![0; c * b];
            labels[3] = bad;
            let e = server_train(&cfg, cut, c, b, 1, MathTier::Bitwise,
                                 &p[n..], &smashed, &labels, &lam,
                                 &mask, 0.05, &pool)
                .unwrap_err();
            assert!(matches!(e, crate::error::Error::Data(_)),
                    "label {bad}: {e}");
        }
        let ex = vec![0.0f32; 2 * 256];
        let e = eval(&cfg, &p, &ex, &[0, 12], 1, MathTier::Bitwise,
                     &pool)
            .unwrap_err();
        assert!(matches!(e, crate::error::Error::Data(_)), "{e}");
    }

    #[test]
    fn phi1_broadcast_rows_bit_identical_to_mask_path_aggregated_rows() {
        // Acceptance criterion: the φ=1.0 all-broadcast gradients must be
        // bit-identical to the aggregated rows the masked path produces —
        // each virtual row depends only on that row's data, not the mask
        // of other rows.
        let cfg = cfg();
        let (cut, c, b) = (2, 2, 8);
        let p = init_params(&cfg, 11);
        let n = client_param_count(cut);
        let smash_len = 16 * 16 * 8;
        let mut rng = Rng::new(6);
        let smashed: Vec<f32> = (0..c * b * smash_len)
            .map(|_| rng.normal(0.0, 1.0) as f32)
            .collect();
        let labels: Vec<i32> =
            (0..c * b).map(|k| ((k * 3) % 10) as i32).collect();
        let lam = vec![0.25f32, 0.75];
        let m = b / 2;
        let half: Vec<f32> =
            (0..b).map(|j| if j < m { 1.0 } else { 0.0 }).collect();
        let full = vec![1.0f32; b];
        let pool = ScratchPool::new();
        let a = server_train(&cfg, cut, c, b, 2, MathTier::Bitwise,
                             &p[n..], &smashed, &labels, &lam, &half,
                             0.05, &pool)
            .unwrap();
        let f = server_train(&cfg, cut, c, b, 2, MathTier::Bitwise,
                             &p[n..], &smashed, &labels, &lam, &full,
                             0.05, &pool)
            .unwrap();
        for j in 0..m {
            assert_eq!(
                a.cut_agg[j * smash_len..(j + 1) * smash_len],
                f.cut_agg[j * smash_len..(j + 1) * smash_len],
                "aggregated row {j} diverged between φ=0.5 and φ=1.0"
            );
        }
        // φ=1.0 has no unicast payload at all.
        assert!(f.cut_unagg.iter().all(|&v| v == 0.0));
        // masked slots of the half-mask unicast payload are zero.
        for i in 0..c {
            for j in 0..m {
                let row = &a.cut_unagg
                    [(i * b + j) * smash_len..(i * b + j + 1) * smash_len];
                assert!(row.iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn phi_agg_semantics() {
        let (c, b, q) = (2, 4, 3);
        let z: Vec<f32> = (0..c * b * q).map(|i| i as f32).collect();
        let lam = [0.5f32, 0.5];
        let mask = [1.0f32, 1.0, 0.0, 0.0];
        let out = phi_agg(c, b, q, &z, &lam, &mask);
        for i in 0..c {
            for j in 0..b {
                for x in 0..q {
                    let idx = (i * b + j) * q + x;
                    let expect = if mask[j] > 0.0 {
                        0.5 * z[(j) * q + x] + 0.5 * z[(b + j) * q + x]
                    } else {
                        z[idx]
                    };
                    assert_eq!(out[idx], expect);
                }
            }
        }
    }
}
