//! The SplitNet family on host buffers: parameter specs, He-normal init,
//! and the five exported graph semantics (`client_fwd`, `server_train`,
//! `client_step`, `eval`, `phi_agg`) exactly as `python/compile/model.py`
//! defines them — including the λ-weighted softmax-CE loss (eq. 1), the
//! ⌈φb⌉ last-layer gradient aggregation (eq. 5–6) over a virtual
//! aggregated batch, and the per-row 1/b weighting of eq. 9.
//!
//! Parallelism: heavy per-sample work (server FP/BP, eval FP) fans across
//! cores with [`par::parallel_map`], whose output is ordered; every
//! cross-sample reduction then runs serially in sample order, so results
//! are bit-identical for any `EPSL_THREADS`.

use crate::profile::splitnet::SplitNetConfig;
use crate::util::par;
use crate::util::rng::Rng;

use super::ops::{self, Dims};

/// Parameter tensors per stage (s1, s2, s3, s4) + head — the canonical
/// prefix bookkeeping shared with `python/compile/model.py`.
pub const STAGE_PARAM_COUNTS: [usize; 4] = [2, 4, 6, 6];

/// Number of client-side tensors for a cut (canonical prefix).
pub fn client_param_count(cut: usize) -> usize {
    STAGE_PARAM_COUNTS[..cut].iter().sum()
}

/// Canonical ordered `(name, shape)` list, mirroring `param_specs` in
/// `model.py` (HWIO conv weights, `(in, out)` FC weight).
pub fn param_specs(cfg: &SplitNetConfig) -> Vec<(String, Vec<usize>)> {
    let w = cfg.width;
    let (w1, w2, w3, w4) = (w, w, 2 * w, 4 * w);
    let mut s: Vec<(String, Vec<usize>)> = Vec::with_capacity(20);
    let mut push = |n: &str, shape: Vec<usize>| s.push((n.into(), shape));
    push("s1.w", vec![3, 3, cfg.channels, w1]);
    push("s1.b", vec![w1]);
    push("s2.wa", vec![3, 3, w1, w2]);
    push("s2.ba", vec![w2]);
    push("s2.wb", vec![3, 3, w2, w2]);
    push("s2.bb", vec![w2]);
    push("s3.wa", vec![3, 3, w2, w3]);
    push("s3.ba", vec![w3]);
    push("s3.wb", vec![3, 3, w3, w3]);
    push("s3.bb", vec![w3]);
    push("s3.wp", vec![1, 1, w2, w3]);
    push("s3.bp", vec![w3]);
    push("s4.wa", vec![3, 3, w3, w4]);
    push("s4.ba", vec![w4]);
    push("s4.wb", vec![3, 3, w4, w4]);
    push("s4.bb", vec![w4]);
    push("s4.wp", vec![1, 1, w3, w4]);
    push("s4.bp", vec![w4]);
    push("fc.w", vec![w4, cfg.num_classes]);
    push("fc.b", vec![cfg.num_classes]);
    s
}

/// He-normal init (biases zero), deterministic in `seed`. The native
/// backend's init need not match JAX's PRNG bit for bit — only the
/// *contract* (shape list, determinism from the run seed) is shared.
pub fn init_params(cfg: &SplitNetConfig, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed ^ 0x5EED_1417);
    param_specs(cfg)
        .iter()
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            let leaf = name.rsplit('.').next().unwrap_or("");
            if leaf.starts_with('b') {
                vec![0.0f32; n]
            } else {
                let fan_in: usize =
                    shape[..shape.len() - 1].iter().product();
                let std = (2.0 / fan_in as f64).sqrt();
                (0..n).map(|_| rng.normal(0.0, std) as f32).collect()
            }
        })
        .collect()
}

/// Input feature-map dims of stage `s` (1..=4).
fn stage_in_dims(cfg: &SplitNetConfig, s: usize) -> Dims {
    let img = cfg.img;
    let w = cfg.width;
    match s {
        1 => (img, img, cfg.channels),
        2 => (img, img, w),
        3 => (img, img, w),
        4 => (img / 2, img / 2, 2 * w),
        _ => panic!("stage {s} out of 1..=4"),
    }
}

/// Output dims of stage `s` — also the smashed shape at cut `s`.
pub fn stage_out_dims(cfg: &SplitNetConfig, s: usize) -> Dims {
    let (h, w, c) = cfg.smashed_shape(s);
    (h, w, c)
}

/// Backward cache for one executed stage.
enum StageCache {
    /// stage 1: conv + relu. Caches input and post-relu output.
    Conv { x: Vec<f32>, y: Vec<f32> },
    /// stages 2–4: residual block. Caches input, post-relu `a`, output.
    Res { x: Vec<f32>, a: Vec<f32>, out: Vec<f32> },
}

/// Per-sample activation cache for stages `[first..=last]` (+ head).
pub struct Cache {
    stages: Vec<StageCache>,
    /// `(pooled, head input dims)` when the head ran.
    head: Option<(Vec<f32>, Dims)>,
}

/// Per-sample forward through stages `[first..=last]`, then the head if
/// `with_head`. `params` is the canonical subset for exactly that range.
/// Returns `(output, cache)`.
pub fn forward(cfg: &SplitNetConfig, params: &[Vec<f32>], first: usize,
               last: usize, with_head: bool, x: &[f32])
    -> (Vec<f32>, Cache) {
    let mut cache = Cache { stages: Vec::with_capacity(last + 1 - first),
                            head: None };
    let mut h = x.to_vec();
    let mut off = 0;
    for s in first..=last {
        let xd = stage_in_dims(cfg, s);
        let (_, _, cout) = stage_out_dims(cfg, s);
        if s == 1 {
            let (w, b) = (&params[off], &params[off + 1]);
            let mut y = ops::conv2d(&h, xd, w, 3, cout, b, 1);
            ops::relu(&mut y);
            cache.stages.push(StageCache::Conv { x: h, y: y.clone() });
            h = y;
        } else {
            let stride = if s >= 3 { 2 } else { 1 };
            let project = s >= 3;
            let (wa, ba) = (&params[off], &params[off + 1]);
            let (wb, bb) = (&params[off + 2], &params[off + 3]);
            let mut a = ops::conv2d(&h, xd, wa, 3, cout, ba, stride);
            ops::relu(&mut a);
            let ad = (ops::out_size(xd.0, stride),
                      ops::out_size(xd.1, stride), cout);
            let mut out = ops::conv2d(&a, ad, wb, 3, cout, bb, 1);
            if project {
                let (wp, bp) = (&params[off + 4], &params[off + 5]);
                let skip = ops::conv2d(&h, xd, wp, 1, cout, bp, stride);
                ops::add_assign(&mut out, &skip);
            } else {
                ops::add_assign(&mut out, &h);
            }
            ops::relu(&mut out);
            cache.stages.push(StageCache::Res { x: h, a, out: out.clone() });
            h = out;
        }
        off += STAGE_PARAM_COUNTS[s - 1];
    }
    if with_head {
        debug_assert_eq!(last, 4, "the head always follows stage 4");
        let xd = stage_out_dims(cfg, 4);
        let (fc_w, fc_b) = (&params[off], &params[off + 1]);
        let (logits, pooled) =
            ops::gap_fc(&h, xd, fc_w, fc_b, cfg.num_classes);
        cache.head = Some((pooled, xd));
        h = logits;
    }
    (h, cache)
}

/// Per-sample backward for the same range: given the output cotangent,
/// returns `(param gradients aligned with `params`, input cotangent)`.
pub fn backward(cfg: &SplitNetConfig, params: &[Vec<f32>], first: usize,
                last: usize, with_head: bool, cache: &Cache, cot: &[f32])
    -> (Vec<Vec<f32>>, Vec<f32>) {
    let mut grads: Vec<Vec<f32>> = Vec::with_capacity(params.len());
    let mut g = cot.to_vec();
    let mut off = params.len();
    if with_head {
        let (pooled, xd) = cache.head.as_ref().expect("head cache");
        let fc_w = &params[off - 2];
        let (gw, gb, gx) =
            ops::gap_fc_bwd(pooled, *xd, fc_w, cfg.num_classes, &g);
        grads.push(gb);
        grads.push(gw);
        g = gx;
        off -= 2;
    }
    for s in (first..=last).rev() {
        let xd = stage_in_dims(cfg, s);
        let (_, _, cout) = stage_out_dims(cfg, s);
        let sc = &cache.stages[s - first];
        off -= STAGE_PARAM_COUNTS[s - 1];
        match sc {
            StageCache::Conv { x, y } => {
                ops::relu_bwd(&mut g, y);
                let w = &params[off];
                let (gw, gb, gx) =
                    ops::conv2d_bwd(x, xd, w, 3, cout, 1, &g);
                grads.push(gb);
                grads.push(gw);
                g = gx;
            }
            StageCache::Res { x, a, out } => {
                let stride = if s >= 3 { 2 } else { 1 };
                let project = s >= 3;
                ops::relu_bwd(&mut g, out); // g_sum = g ⊙ (out > 0)
                let ad = (ops::out_size(xd.0, stride),
                          ops::out_size(xd.1, stride), cout);
                let wb = &params[off + 2];
                let (gwb, gbb, mut ga) =
                    ops::conv2d_bwd(a, ad, wb, 3, cout, 1, &g);
                ops::relu_bwd(&mut ga, a);
                let wa = &params[off];
                let (gwa, gba, mut gx) =
                    ops::conv2d_bwd(x, xd, wa, 3, cout, stride, &ga);
                if project {
                    let wp = &params[off + 4];
                    let (gwp, gbp, gxp) =
                        ops::conv2d_bwd(x, xd, wp, 1, cout, stride, &g);
                    ops::add_assign(&mut gx, &gxp);
                    grads.push(gbp);
                    grads.push(gwp);
                } else {
                    ops::add_assign(&mut gx, &g);
                }
                grads.push(gbb);
                grads.push(gwb);
                grads.push(gba);
                grads.push(gwa);
                g = gx;
            }
        }
    }
    grads.reverse();
    (grads, g)
}

/// Client-side FP (stages 1..cut) over a batch: `x (b,img,img,ch)` →
/// smashed `(b,*smash)`.
pub fn client_fwd(cfg: &SplitNetConfig, cut: usize, params: &[Vec<f32>],
                  x: &[f32], b: usize) -> Vec<f32> {
    let in_len = cfg.img * cfg.img * cfg.channels;
    let (sh, sw, sc) = stage_out_dims(cfg, cut);
    let smash_len = sh * sw * sc;
    let mut out = Vec::with_capacity(b * smash_len);
    for j in 0..b {
        let (s, _) = forward(cfg, params, 1, cut, false,
                             &x[j * in_len..][..in_len]);
        out.extend_from_slice(&s);
    }
    out
}

/// Client-side BP + SGD (eq. 8–12): cotangent `g_cut/b` per row, then
/// `w ← w − η_c · gw` with gradients accumulated in sample order.
pub fn client_step(cfg: &SplitNetConfig, cut: usize, params: &[Vec<f32>],
                   x: &[f32], g_cut: &[f32], lr: f32, b: usize)
    -> Vec<Vec<f32>> {
    let in_len = cfg.img * cfg.img * cfg.channels;
    let (sh, sw, sc) = stage_out_dims(cfg, cut);
    let smash_len = sh * sw * sc;
    let mut acc: Vec<Vec<f32>> =
        params.iter().map(|p| vec![0.0f32; p.len()]).collect();
    let inv_b = 1.0 / b as f32;
    for j in 0..b {
        let xs = &x[j * in_len..][..in_len];
        let (_, cache) = forward(cfg, params, 1, cut, false, xs);
        let cot: Vec<f32> = g_cut[j * smash_len..][..smash_len]
            .iter()
            .map(|&v| v * inv_b)
            .collect();
        let (grads, _) = backward(cfg, params, 1, cut, false, &cache, &cot);
        for (a, gr) in acc.iter_mut().zip(&grads) {
            ops::add_assign(a, gr);
        }
    }
    params
        .iter()
        .zip(&acc)
        .map(|(p, g)| {
            p.iter().zip(g).map(|(&w, &gv)| w - lr * gv).collect()
        })
        .collect()
}

/// Output bundle of [`server_train`], in manifest output order.
pub struct ServerTrainOut {
    pub new_params: Vec<Vec<f32>>,
    /// `(b, *smash)` broadcast cut-layer gradient (masked rows; others 0).
    pub cut_agg: Vec<f32>,
    /// `(C, b, *smash)` unicast gradients (masked slots zero).
    pub cut_unagg: Vec<f32>,
    pub loss: f32,
    pub ncorrect: f32,
}

/// Per-sample result of the real-batch FP/BP pass.
struct RealSample {
    ce: f32,
    correct: bool,
    dlogits: Vec<f32>,
    /// `(gw, gs)` when the unicast cotangent was nonzero.
    bp: Option<(Vec<Vec<f32>>, Vec<f32>)>,
}

/// EPSL server step (paper §IV stages 3–6, eq. 5–7) — the semantics of
/// the `server_train_cut{k}_c{C}` graph.
#[allow(clippy::too_many_arguments)]
pub fn server_train(cfg: &SplitNetConfig, cut: usize, c: usize, b: usize,
                    threads: usize, params: &[Vec<f32>], smashed: &[f32],
                    labels: &[i32], lam: &[f32], mask: &[f32], lr: f32)
    -> ServerTrainOut {
    let (sh, sw, sc) = stage_out_dims(cfg, cut);
    let smash_len = sh * sw * sc;
    let nc = cfg.num_classes;
    let inv_b = 1.0 / b as f32;

    // --- real pass: FP over all C·b samples; BP of the unaggregated
    // slots with row weight λ_i/b (eq. 5 remaining blocks) ---
    let idx: Vec<usize> = (0..c * b).collect();
    let real: Vec<RealSample> = par::parallel_map(&idx, threads, |_, &k| {
        let (i, j) = (k / b, k % b);
        let row = &smashed[k * smash_len..][..smash_len];
        let (logits, cache) =
            forward(cfg, params, cut + 1, 4, true, row);
        let (ce, dlogits, correct) = ops::softmax_xent(&logits, labels[k]);
        let unmask = 1.0 - mask[j];
        let weight = unmask * lam[i] * inv_b;
        let bp = if weight != 0.0 {
            let cot: Vec<f32> =
                dlogits.iter().map(|&z| weight * z).collect();
            let (gw, gs) =
                backward(cfg, params, cut + 1, 4, true, &cache, &cot);
            Some((gw, gs))
        } else {
            None
        };
        RealSample { ce, correct, dlogits, bp }
    });

    // Loss / accuracy reductions in flat sample order (eq. 1 weighting).
    let mut loss = 0.0f32;
    let mut ncorrect = 0.0f32;
    for (k, r) in real.iter().enumerate() {
        loss += lam[k / b] * r.ce;
        ncorrect += r.correct as u32 as f32;
    }
    loss *= inv_b;

    // --- virtual aggregated batch (eq. 6): λ-aggregate the smashed rows
    // and last-layer gradients for the ⌈φb⌉ masked slots, one BP row each
    // (eq. 5 first block, row weight 1/b) ---
    let masked: Vec<usize> =
        (0..b).filter(|&j| mask[j] != 0.0).collect();
    let virt = par::parallel_map(&masked, threads, |_, &j| {
        let mut sbar = vec![0.0f32; smash_len];
        let mut zbar = vec![0.0f32; nc];
        for i in 0..c {
            ops::axpy(&mut sbar, lam[i],
                      &smashed[(i * b + j) * smash_len..][..smash_len]);
            ops::axpy(&mut zbar, lam[i], &real[i * b + j].dlogits);
        }
        let (_, cache) = forward(cfg, params, cut + 1, 4, true, &sbar);
        let cot: Vec<f32> =
            zbar.iter().map(|&z| mask[j] * z * inv_b).collect();
        backward(cfg, params, cut + 1, 4, true, &cache, &cot)
    });

    // --- outputs ---
    let bf = b as f32;
    let mut cut_agg = vec![0.0f32; b * smash_len];
    for (&j, (_, gs)) in masked.iter().zip(&virt) {
        for (dst, &g) in
            cut_agg[j * smash_len..][..smash_len].iter_mut().zip(gs)
        {
            *dst = g * bf; // raw activations' gradients for the broadcast
        }
    }
    let mut cut_unagg = vec![0.0f32; c * b * smash_len];
    for (k, r) in real.iter().enumerate() {
        if let Some((_, gs)) = &r.bp {
            let (i, j) = (k / b, k % b);
            // Divide the λ_i/b row weight back out (unicast payload);
            // masked slots stay zero.
            let scale = (1.0 - mask[j]) * bf / lam[i].max(1e-12);
            for (dst, &g) in cut_unagg[k * smash_len..][..smash_len]
                .iter_mut()
                .zip(gs)
            {
                *dst = g * scale;
            }
        }
    }

    // --- parameter update (eq. 7): g = Σ virtual rows + Σ real samples,
    // both in ascending order ---
    let mut acc: Vec<Vec<f32>> =
        params.iter().map(|p| vec![0.0f32; p.len()]).collect();
    for (gw, _) in &virt {
        for (a, g) in acc.iter_mut().zip(gw) {
            ops::add_assign(a, g);
        }
    }
    for r in &real {
        if let Some((gw, _)) = &r.bp {
            for (a, g) in acc.iter_mut().zip(gw) {
                ops::add_assign(a, g);
            }
        }
    }
    let new_params = params
        .iter()
        .zip(&acc)
        .map(|(p, g)| {
            p.iter().zip(g).map(|(&w, &gv)| w - lr * gv).collect()
        })
        .collect();

    ServerTrainOut { new_params, cut_agg, cut_unagg, loss, ncorrect }
}

/// Full-model eval on a fixed-size batch: `(mean CE, ncorrect)`.
pub fn eval(cfg: &SplitNetConfig, params: &[Vec<f32>], x: &[f32],
            labels: &[i32], threads: usize) -> (f32, f32) {
    let in_len = cfg.img * cfg.img * cfg.channels;
    let n = labels.len();
    let idx: Vec<usize> = (0..n).collect();
    let per: Vec<(f32, bool)> = par::parallel_map(&idx, threads, |_, &j| {
        let (logits, _) = forward(cfg, params, 1, 4, true,
                                  &x[j * in_len..][..in_len]);
        let (ce, _, correct) = ops::softmax_xent(&logits, labels[j]);
        (ce, correct)
    });
    let mut loss = 0.0f32;
    let mut ncorr = 0.0f32;
    for (ce, correct) in per {
        loss += ce;
        ncorr += correct as u32 as f32;
    }
    (loss / n as f32, ncorr)
}

/// The φ-aggregation kernel semantics (`phi_aggregate_nd`): masked rows of
/// every client hold the λ-aggregate, unmasked rows pass through.
pub fn phi_agg(c: usize, b: usize, q: usize, z: &[f32], lam: &[f32],
               mask: &[f32]) -> Vec<f32> {
    let mut out = z.to_vec();
    for j in 0..b {
        if mask[j] == 0.0 {
            continue;
        }
        let mut zbar = vec![0.0f32; q];
        for i in 0..c {
            ops::axpy(&mut zbar, lam[i], &z[(i * b + j) * q..][..q]);
        }
        for i in 0..c {
            out[(i * b + j) * q..][..q].copy_from_slice(&zbar);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SplitNetConfig {
        SplitNetConfig::mnist_like()
    }

    #[test]
    fn param_specs_match_the_python_contract() {
        let specs = param_specs(&cfg());
        assert_eq!(specs.len(), 20);
        let total: usize =
            specs.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        // Cross-language constant (profile::splitnet::param_count).
        assert_eq!(total, 19_642);
        assert_eq!(client_param_count(1), 2);
        assert_eq!(client_param_count(2), 6);
        assert_eq!(client_param_count(3), 12);
        assert_eq!(client_param_count(4), 18);
        assert_eq!(specs[0].0, "s1.w");
        assert_eq!(specs[19].0, "fc.b");
    }

    #[test]
    fn init_is_deterministic_and_he_scaled() {
        let a = init_params(&cfg(), 42);
        let b = init_params(&cfg(), 42);
        assert_eq!(a, b);
        let c = init_params(&cfg(), 43);
        assert_ne!(a[0], c[0]);
        // biases zero
        assert!(a[1].iter().all(|&v| v == 0.0));
        // He std for s1.w: sqrt(2 / (3*3*1)) ≈ 0.471
        let std = (a[0].iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / a[0].len() as f64)
            .sqrt();
        assert!((std - 0.471).abs() < 0.15, "std={std}");
    }

    #[test]
    fn full_forward_shapes() {
        let p = init_params(&cfg(), 1);
        let x = vec![0.1f32; 16 * 16];
        let (logits, _) = forward(&cfg(), &p, 1, 4, true, &x);
        assert_eq!(logits.len(), 10);
        // split at cut 2: client stages 1-2 then server 3-4+head compose
        // to the same logits
        let n = client_param_count(2);
        let (smash, _) = forward(&cfg(), &p[..n], 1, 2, false, &x);
        assert_eq!(smash.len(), 16 * 16 * 8);
        let (logits2, _) = forward(&cfg(), &p[n..], 3, 4, true, &smash);
        assert_eq!(logits, logits2, "split forward must compose exactly");
    }

    #[test]
    fn client_backward_matches_finite_difference() {
        let cfg = cfg();
        let p = init_params(&cfg, 5);
        let n = client_param_count(1); // stage 1 only: cheap FD
        let x: Vec<f32> =
            (0..256).map(|i| ((i % 13) as f32 - 6.0) / 7.0).collect();
        let cot: Vec<f32> = (0..16 * 16 * 8)
            .map(|i| ((i % 7) as f32 - 3.0) / 50.0)
            .collect();
        let loss = |params: &[Vec<f32>]| -> f64 {
            let (y, _) = forward(&cfg, params, 1, 1, false, &x);
            y.iter().zip(&cot).map(|(&a, &b)| (a * b) as f64).sum()
        };
        let (_, cache) = forward(&cfg, &p[..n], 1, 1, false, &x);
        let (grads, _) =
            backward(&cfg, &p[..n], 1, 1, false, &cache, &cot);
        assert_eq!(grads.len(), 2);
        let eps = 1e-3;
        let base = loss(&p[..n]);
        for t in 0..2 {
            for i in [0usize, 3] {
                let mut pp: Vec<Vec<f32>> = p[..n].to_vec();
                pp[t][i] += eps;
                let num = (loss(&pp) - base) / eps as f64;
                assert!(
                    (num - grads[t][i] as f64).abs() < 2e-2,
                    "grad[{t}][{i}]: num {num} vs {}",
                    grads[t][i]
                );
            }
        }
    }

    #[test]
    fn server_train_is_thread_count_invariant() {
        let cfg = cfg();
        let (cut, c, b) = (2, 3, 8);
        let p = init_params(&cfg, 9);
        let n = client_param_count(cut);
        let smash_len = 16 * 16 * 8;
        let mut rng = Rng::new(4);
        let smashed: Vec<f32> = (0..c * b * smash_len)
            .map(|_| rng.normal(0.0, 1.0) as f32)
            .collect();
        let labels: Vec<i32> =
            (0..c * b).map(|k| (k % 10) as i32).collect();
        let lam = vec![1.0 / c as f32; c];
        let mask: Vec<f32> =
            (0..b).map(|j| if j < b / 2 { 1.0 } else { 0.0 }).collect();
        let a = server_train(&cfg, cut, c, b, 1, &p[n..], &smashed,
                             &labels, &lam, &mask, 0.05);
        let z = server_train(&cfg, cut, c, b, 7, &p[n..], &smashed,
                             &labels, &lam, &mask, 0.05);
        assert_eq!(a.loss.to_bits(), z.loss.to_bits());
        assert_eq!(a.cut_agg, z.cut_agg);
        assert_eq!(a.cut_unagg, z.cut_unagg);
        assert_eq!(a.new_params, z.new_params);
    }

    #[test]
    fn phi1_broadcast_rows_bit_identical_to_mask_path_aggregated_rows() {
        // Acceptance criterion: the φ=1.0 all-broadcast gradients must be
        // bit-identical to the aggregated rows the masked path produces —
        // each virtual row depends only on that row's data, not the mask
        // of other rows.
        let cfg = cfg();
        let (cut, c, b) = (2, 2, 8);
        let p = init_params(&cfg, 11);
        let n = client_param_count(cut);
        let smash_len = 16 * 16 * 8;
        let mut rng = Rng::new(6);
        let smashed: Vec<f32> = (0..c * b * smash_len)
            .map(|_| rng.normal(0.0, 1.0) as f32)
            .collect();
        let labels: Vec<i32> =
            (0..c * b).map(|k| ((k * 3) % 10) as i32).collect();
        let lam = vec![0.25f32, 0.75];
        let m = b / 2;
        let half: Vec<f32> =
            (0..b).map(|j| if j < m { 1.0 } else { 0.0 }).collect();
        let full = vec![1.0f32; b];
        let a = server_train(&cfg, cut, c, b, 2, &p[n..], &smashed,
                             &labels, &lam, &half, 0.05);
        let f = server_train(&cfg, cut, c, b, 2, &p[n..], &smashed,
                             &labels, &lam, &full, 0.05);
        for j in 0..m {
            assert_eq!(
                a.cut_agg[j * smash_len..(j + 1) * smash_len],
                f.cut_agg[j * smash_len..(j + 1) * smash_len],
                "aggregated row {j} diverged between φ=0.5 and φ=1.0"
            );
        }
        // φ=1.0 has no unicast payload at all.
        assert!(f.cut_unagg.iter().all(|&v| v == 0.0));
        // masked slots of the half-mask unicast payload are zero.
        for i in 0..c {
            for j in 0..m {
                let row = &a.cut_unagg
                    [(i * b + j) * smash_len..(i * b + j + 1) * smash_len];
                assert!(row.iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn phi_agg_semantics() {
        let (c, b, q) = (2, 4, 3);
        let z: Vec<f32> = (0..c * b * q).map(|i| i as f32).collect();
        let lam = [0.5f32, 0.5];
        let mask = [1.0f32, 1.0, 0.0, 0.0];
        let out = phi_agg(c, b, q, &z, &lam, &mask);
        for i in 0..c {
            for j in 0..b {
                for x in 0..q {
                    let idx = (i * b + j) * q + x;
                    let expect = if mask[j] > 0.0 {
                        0.5 * z[(j) * q + x] + 0.5 * z[(b + j) * q + x]
                    } else {
                        z[idx]
                    };
                    assert_eq!(out[idx], expect);
                }
            }
        }
    }
}
