//! The pure-Rust native training backend.
//!
//! Implements the splitnet family end-to-end on host f32 buffers against
//! the same `Manifest`/`Literal` entry-point contract the PJRT path
//! speaks, so `coordinator::driver`, `fedavg`, and
//! `experiments::accuracy` run unmodified above the [`Backend`] seam —
//! with no artifacts on disk. [`manifest`] synthesizes the full manifest
//! (both families, cuts 1..=4, server_train for C = 1..=32) with entry
//! files in a `native://{family}/{op}` grammar that [`NativeBackend`]
//! dispatches on.
//!
//! Determinism: everything is a pure function of the inputs (init of the
//! seed literal), per-sample fan-out goes through the order-preserving
//! [`crate::util::par::parallel_map`], and all cross-sample reductions run
//! serially in sample order — results are bit-identical for any
//! `EPSL_THREADS`. Unlike the PJRT client the backend is `Send + Sync`,
//! so the driver's `call_many` fans client FP/BP across cores.

pub mod kernels;
pub mod kernels_fast;
pub mod model;
pub mod ops;

use std::sync::Mutex;

use xla::Literal;

use crate::error::{Error, Result};
use crate::profile::splitnet::SplitNetConfig;
use crate::runtime::artifact::{ArtifactEntry, DType, FamilyManifest,
                               Manifest, TensorSpec};
use crate::runtime::backend::Backend;
use crate::runtime::tensor::{literal_f32, to_f32_vec};
use crate::runtime::{validate_inputs, RuntimeStats};
use crate::util::bench::WallTimer;
use crate::util::par;

pub use kernels_fast::MathTier;

/// Training mini-batch b baked into the graph contract (matches the AOT
/// export in `python/compile/aot.py`).
pub const BATCH: usize = 32;
/// Fixed eval chunk size.
pub const EVAL_BATCH: usize = 256;
/// server_train graphs are synthesized for C = 1..=MAX_CLIENTS.
pub const MAX_CLIENTS: usize = 32;
/// Client count baked into the standalone `phi_agg` entries.
const PHI_AGG_CLIENTS: usize = 5;

/// The native backend: stateless apart from perf counters and the
/// reusable kernel scratch arenas.
pub struct NativeBackend {
    threads: usize,
    /// Compute tier: [`MathTier::Bitwise`] (default, bit-identical to the
    /// reference oracles) or [`MathTier::Fast`] (SIMD + threaded GEMM,
    /// tolerance contract — see `kernels_fast`).
    tier: MathTier,
    stats: Mutex<RuntimeStats>,
    /// Pooled [`kernels::Scratch`] arenas: im2col/GEMM buffers allocated
    /// once per concurrent worker and reused across samples and rounds.
    pool: kernels::ScratchPool,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    /// Thread budget from `EPSL_THREADS` / available parallelism.
    pub fn new() -> Self {
        Self::with_threads(par::max_threads())
    }

    /// Explicit thread budget (determinism tests pin this). Bitwise tier.
    pub fn with_threads(threads: usize) -> Self {
        Self::with_options(threads, MathTier::Bitwise)
    }

    /// Explicit thread budget and compute tier.
    pub fn with_options(threads: usize, tier: MathTier) -> Self {
        NativeBackend {
            threads: threads.max(1),
            tier,
            stats: Mutex::new(RuntimeStats::default()),
            pool: kernels::ScratchPool::new(),
        }
    }

    pub fn stats(&self) -> RuntimeStats {
        // A panicked worker must not cascade into poison panics on
        // unrelated stats reads — recover the guard.
        self.stats
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    fn dispatch(&self, entry: &ArtifactEntry, inputs: &[Literal])
        -> Result<Vec<Literal>> {
        let op = NativeOp::parse(&entry.file)?;
        let cfg = op.cfg();
        match op.kind {
            OpKind::Init => {
                let seed = inputs[0].to_vec::<u32>()?;
                let seed = ((seed[0] as u64) << 32) | seed[1] as u64;
                let params = model::init_params(&cfg, seed);
                model::param_specs(&cfg)
                    .iter()
                    .zip(&params)
                    .map(|((_, shape), data)| literal_f32(shape, data))
                    .collect()
            }
            OpKind::ClientFwd { cut } => {
                let n = model::client_param_count(cut);
                let params = to_host(&inputs[..n])?;
                let x = to_f32_vec(&inputs[n])?;
                let smashed = model::client_fwd(&cfg, cut, &params, &x,
                                                BATCH, self.tier,
                                                &self.pool);
                Ok(vec![literal_f32(&entry.outputs[0].shape, &smashed)?])
            }
            OpKind::ClientStep { cut } => {
                let n = model::client_param_count(cut);
                let params = to_host(&inputs[..n])?;
                let x = to_f32_vec(&inputs[n])?;
                let g_cut = to_f32_vec(&inputs[n + 1])?;
                let lr = inputs[n + 2].get_first_element::<f32>()?;
                let new =
                    model::client_step(&cfg, cut, &params, &x, &g_cut, lr,
                                       BATCH, self.tier, &self.pool);
                entry
                    .outputs
                    .iter()
                    .zip(&new)
                    .map(|(spec, data)| literal_f32(&spec.shape, data))
                    .collect()
            }
            OpKind::ServerTrain { cut, c } => {
                let n_sp = model::param_specs(&cfg).len()
                    - model::client_param_count(cut);
                let params = to_host(&inputs[..n_sp])?;
                let smashed = to_f32_vec(&inputs[n_sp])?;
                let labels = inputs[n_sp + 1].to_vec::<i32>()?;
                let lam = to_f32_vec(&inputs[n_sp + 2])?;
                let mask = to_f32_vec(&inputs[n_sp + 3])?;
                let lr = inputs[n_sp + 4].get_first_element::<f32>()?;
                let out = model::server_train(&cfg, cut, c, BATCH,
                                              self.threads, self.tier,
                                              &params, &smashed, &labels,
                                              &lam, &mask, lr,
                                              &self.pool)?;
                let mut lits: Vec<Literal> = entry.outputs[..n_sp]
                    .iter()
                    .zip(&out.new_params)
                    .map(|(spec, data)| literal_f32(&spec.shape, data))
                    .collect::<Result<_>>()?;
                lits.push(literal_f32(&entry.outputs[n_sp].shape,
                                      &out.cut_agg)?);
                lits.push(literal_f32(&entry.outputs[n_sp + 1].shape,
                                      &out.cut_unagg)?);
                lits.push(literal_f32(&[], &[out.loss])?);
                lits.push(literal_f32(&[], &[out.ncorrect])?);
                Ok(lits)
            }
            OpKind::Eval => {
                let np = model::param_specs(&cfg).len();
                let params = to_host(&inputs[..np])?;
                let x = to_f32_vec(&inputs[np])?;
                let labels = inputs[np + 1].to_vec::<i32>()?;
                let (loss, ncorr) = model::eval(&cfg, &params, &x,
                                                &labels, self.threads,
                                                self.tier, &self.pool)?;
                Ok(vec![
                    literal_f32(&[], &[loss])?,
                    literal_f32(&[], &[ncorr])?,
                ])
            }
            OpKind::PhiAgg { cut } => {
                let z = to_f32_vec(&inputs[0])?;
                let lam = to_f32_vec(&inputs[1])?;
                let mask = to_f32_vec(&inputs[2])?;
                let (sh, sw, sc) = model::stage_out_dims(&cfg, cut);
                let out = model::phi_agg(lam.len(), mask.len(),
                                         sh * sw * sc, &z, &lam, &mask);
                Ok(vec![literal_f32(&entry.outputs[0].shape, &out)?])
            }
        }
    }
}

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        format!("native-f32 ({} threads, {} tier)", self.threads,
                self.tier.name())
    }

    fn call(&self, entry: &ArtifactEntry, inputs: &[Literal])
        -> Result<Vec<Literal>> {
        validate_inputs(entry, inputs)?;
        let t0 = WallTimer::start();
        let outs = self.dispatch(entry, inputs)?;
        // into_inner on poison: one panicked worker must not turn every
        // later stats update into a cascade of unrelated panics.
        let mut stats =
            self.stats.lock().unwrap_or_else(|e| e.into_inner());
        stats.executions += 1;
        stats.execute_seconds += t0.elapsed_seconds();
        Ok(outs)
    }

    fn call_many(&self, entry: &ArtifactEntry, batches: &[Vec<Literal>])
        -> Result<Vec<Vec<Literal>>> {
        // Per-batch work is pure and parallel_map is order-preserving, so
        // the fan-out is bit-identical to the serial loop.
        par::parallel_map(batches, self.threads, |_, inputs| {
            self.call(entry, inputs)
        })
        .into_iter()
        .collect()
    }

    fn stats_summary(&self) -> String {
        let s = self.stats();
        format!(
            "native backend: {} executions ({:.2}s)",
            s.executions, s.execute_seconds
        )
    }
}

/// Convert a parameter-literal prefix to host buffers.
fn to_host(lits: &[Literal]) -> Result<Vec<Vec<f32>>> {
    lits.iter().map(to_f32_vec).collect()
}

/// Which graph a `native://` entry file names.
struct NativeOp {
    family: String,
    kind: OpKind,
}

enum OpKind {
    Init,
    Eval,
    ClientFwd { cut: usize },
    ClientStep { cut: usize },
    ServerTrain { cut: usize, c: usize },
    PhiAgg { cut: usize },
}

impl NativeOp {
    fn parse(file: &str) -> Result<NativeOp> {
        let bad = || {
            Error::Artifact(format!(
                "'{file}' is not a native:// entry — this manifest was \
                 built for the PJRT backend (run with --backend pjrt or \
                 rebuild artifacts)"
            ))
        };
        let rest = file.strip_prefix("native://").ok_or_else(bad)?;
        let (family, op) = rest.split_once('/').ok_or_else(bad)?;
        let cut_of = |s: &str| -> Result<usize> {
            let cut: usize = s.parse().map_err(|_| bad())?;
            if (1..=4).contains(&cut) {
                Ok(cut)
            } else {
                Err(bad())
            }
        };
        let kind = if op == "init" {
            OpKind::Init
        } else if op == "eval" {
            OpKind::Eval
        } else if let Some(s) = op.strip_prefix("client_fwd_cut") {
            OpKind::ClientFwd { cut: cut_of(s)? }
        } else if let Some(s) = op.strip_prefix("client_step_cut") {
            OpKind::ClientStep { cut: cut_of(s)? }
        } else if let Some(s) = op.strip_prefix("phi_agg_cut") {
            OpKind::PhiAgg { cut: cut_of(s)? }
        } else if let Some(s) = op.strip_prefix("server_train_cut") {
            let (cut_s, c_s) = s.split_once("_c").ok_or_else(bad)?;
            let c: usize = c_s.parse().map_err(|_| bad())?;
            if c == 0 {
                return Err(bad());
            }
            OpKind::ServerTrain { cut: cut_of(cut_s)?, c }
        } else {
            return Err(bad());
        };
        Ok(NativeOp { family: family.to_string(), kind })
    }

    fn cfg(&self) -> SplitNetConfig {
        SplitNetConfig::for_family(&self.family)
    }
}

// ---------------------------------------------------------------------------
// Manifest synthesis
// ---------------------------------------------------------------------------

fn f32_spec(name: &str, shape: &[usize]) -> TensorSpec {
    TensorSpec { name: name.into(), dtype: DType::F32, shape: shape.to_vec() }
}

fn i32_spec(name: &str, shape: &[usize]) -> TensorSpec {
    TensorSpec { name: name.into(), dtype: DType::I32, shape: shape.to_vec() }
}

fn param_input_specs(specs: &[(String, Vec<usize>)], range: std::ops::Range<usize>)
    -> Vec<TensorSpec> {
    specs[range].iter().map(|(n, s)| f32_spec(n, s)).collect()
}

fn family_manifest(cfg: &SplitNetConfig, name: &str) -> FamilyManifest {
    let specs = model::param_specs(cfg);
    let file = |op: &str| format!("native://{name}/{op}");
    let entry = |op: &str, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>| {
        ArtifactEntry { file: file(op), inputs, outputs }
    };
    let x_spec = |b: usize| {
        f32_spec("x", &[b, cfg.img, cfg.img, cfg.channels])
    };
    let smash_of = |cut: usize| -> Vec<usize> {
        let (h, w, c) = cfg.smashed_shape(cut);
        vec![h, w, c]
    };
    let all_params: Vec<TensorSpec> =
        param_input_specs(&specs, 0..specs.len());

    let init = entry(
        "init",
        vec![TensorSpec { name: "seed".into(), dtype: DType::U32,
                          shape: vec![2] }],
        all_params.clone(),
    );
    let eval = entry(
        "eval",
        {
            let mut v = all_params.clone();
            v.push(x_spec(EVAL_BATCH));
            v.push(i32_spec("y", &[EVAL_BATCH]));
            v
        },
        vec![f32_spec("loss", &[]), f32_spec("ncorrect", &[])],
    );

    let mut client_fwd = std::collections::BTreeMap::new();
    let mut client_step = std::collections::BTreeMap::new();
    let mut phi_agg = std::collections::BTreeMap::new();
    let mut server_train = std::collections::BTreeMap::new();
    let mut client_param_count = std::collections::BTreeMap::new();
    let mut smashed_shape = std::collections::BTreeMap::new();
    for cut in 1..=4usize {
        let n_c = model::client_param_count(cut);
        let smash = smash_of(cut);
        let smash_len: usize = smash.iter().product();
        client_param_count.insert(cut, n_c);
        smashed_shape.insert(cut, smash.clone());

        let mut cf_in = param_input_specs(&specs, 0..n_c);
        cf_in.push(x_spec(BATCH));
        let mut smash_b = vec![BATCH];
        smash_b.extend(&smash);
        client_fwd.insert(
            cut,
            entry(&format!("client_fwd_cut{cut}"), cf_in,
                  vec![f32_spec("smashed", &smash_b)]),
        );

        let mut cs_in = param_input_specs(&specs, 0..n_c);
        cs_in.push(x_spec(BATCH));
        cs_in.push(f32_spec("g_cut", &smash_b));
        cs_in.push(f32_spec("lr", &[]));
        client_step.insert(
            cut,
            entry(&format!("client_step_cut{cut}"), cs_in,
                  param_input_specs(&specs, 0..n_c)),
        );

        phi_agg.insert(
            cut,
            entry(
                &format!("phi_agg_cut{cut}"),
                vec![
                    f32_spec("z", &[PHI_AGG_CLIENTS, BATCH, smash_len]),
                    f32_spec("lam", &[PHI_AGG_CLIENTS]),
                    f32_spec("mask", &[BATCH]),
                ],
                vec![f32_spec("z_mixed",
                              &[PHI_AGG_CLIENTS, BATCH, smash_len])],
            ),
        );

        let mut by_c = std::collections::BTreeMap::new();
        for c in 1..=MAX_CLIENTS {
            let mut st_in = param_input_specs(&specs, n_c..specs.len());
            let mut smash_cb = vec![c, BATCH];
            smash_cb.extend(&smash);
            st_in.push(f32_spec("smashed", &smash_cb));
            st_in.push(i32_spec("y", &[c, BATCH]));
            st_in.push(f32_spec("lam", &[c]));
            st_in.push(f32_spec("mask", &[BATCH]));
            st_in.push(f32_spec("lr", &[]));
            let mut st_out = param_input_specs(&specs, n_c..specs.len());
            st_out.push(f32_spec("cut_agg", &smash_b));
            st_out.push(f32_spec("cut_unagg", &smash_cb));
            st_out.push(f32_spec("loss", &[]));
            st_out.push(f32_spec("ncorrect", &[]));
            by_c.insert(
                c,
                entry(&format!("server_train_cut{cut}_c{c}"), st_in,
                      st_out),
            );
        }
        server_train.insert(cut, by_c);
    }

    FamilyManifest {
        name: name.into(),
        channels: cfg.channels,
        num_classes: cfg.num_classes,
        img: cfg.img,
        batch: BATCH,
        eval_batch: EVAL_BATCH,
        params: specs,
        client_param_count,
        smashed_shape,
        init,
        eval,
        client_fwd,
        client_step,
        phi_agg,
        server_train,
    }
}

/// Synthesize the native backend's manifest: both families, cuts 1..=4,
/// server_train for every C in 1..=[`MAX_CLIENTS`]. Same shape contract
/// as `artifacts/manifest.json`, no files on disk.
pub fn manifest() -> Manifest {
    let mut families = std::collections::BTreeMap::new();
    for name in ["mnist", "ham"] {
        families.insert(
            name.to_string(),
            family_manifest(&SplitNetConfig::for_family(name), name),
        );
    }
    Manifest {
        client_counts: (1..=MAX_CLIENTS).collect(),
        cuts: vec![1, 2, 3, 4],
        families,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::{literal_i32, literal_u32, scalar_f32};

    fn init_full(fam: &FamilyManifest, be: &NativeBackend, seed: u32)
        -> Vec<Literal> {
        let seed = literal_u32(&[2], &[0, seed]).unwrap();
        be.call(&fam.init, &[seed]).unwrap()
    }

    #[test]
    fn manifest_mirrors_the_aot_contract() {
        let m = manifest();
        let fam = m.family("mnist").unwrap();
        assert_eq!(fam.params.len(), 20);
        assert_eq!(fam.cuts(), vec![1, 2, 3, 4]);
        assert_eq!(fam.client_param_count[&2], 6);
        assert_eq!(fam.smashed_shape[&2], vec![16, 16, 8]);
        assert_eq!(fam.param_elements(), 19_642);
        assert!(fam.server_train_entry(2, 5).is_ok());
        assert!(fam.server_train_entry(2, MAX_CLIENTS + 1).is_err());
        let names: Vec<&str> = fam
            .server_train_entry(2, 5)
            .unwrap()
            .inputs
            .iter()
            .map(|s| s.name.as_str())
            .collect();
        assert!(names.ends_with(&["smashed", "y", "lam", "mask", "lr"]));
        let ham = m.family("ham").unwrap();
        assert_eq!(ham.num_classes, 7);
        assert_eq!(ham.channels, 3);
    }

    #[test]
    fn init_executes_and_shapes_match() {
        let m = manifest();
        let fam = m.family("mnist").unwrap();
        let be = NativeBackend::new();
        let params = init_full(fam, &be, 42);
        assert_eq!(params.len(), fam.params.len());
        for (lit, (name, shape)) in params.iter().zip(&fam.params) {
            assert_eq!(
                lit.element_count(),
                shape.iter().product::<usize>(),
                "param {name}"
            );
        }
        let params2 = init_full(fam, &be, 42);
        assert_eq!(
            to_f32_vec(&params[0]).unwrap(),
            to_f32_vec(&params2[0]).unwrap()
        );
        assert!(be.stats().executions >= 2);
    }

    #[test]
    fn input_arity_and_shape_validated() {
        let m = manifest();
        let fam = m.family("mnist").unwrap();
        let be = NativeBackend::new();
        assert!(be.call(&fam.init, &[]).is_err());
        let bad = literal_u32(&[3], &[1, 2, 3]).unwrap();
        assert!(be.call(&fam.init, &[bad]).is_err());
    }

    #[test]
    fn non_native_entry_rejected_with_hint() {
        let be = NativeBackend::new();
        let entry = ArtifactEntry {
            file: "init.hlo.txt".into(),
            inputs: vec![],
            outputs: vec![],
        };
        let e = be.call(&entry, &[]).unwrap_err();
        assert!(e.to_string().contains("native://"), "{e}");
    }

    #[test]
    fn full_training_cycle_through_entries() {
        // init → client_fwd → server_train → client_step → eval, all via
        // the manifest entry points (what the driver does per round).
        let m = manifest();
        let fam = m.family("mnist").unwrap();
        let be = NativeBackend::new();
        let cut = 2;
        let c = 2;
        let params = init_full(fam, &be, 7);
        let n_c = fam.client_param_count[&cut];
        let (client_p, server_p) =
            (params[..n_c].to_vec(), params[n_c..].to_vec());

        let mut rng = crate::util::rng::Rng::new(3);
        let x: Vec<f32> = (0..BATCH * 16 * 16)
            .map(|_| rng.normal(0.0, 1.0) as f32)
            .collect();
        let x_lit = literal_f32(&[BATCH, 16, 16, 1], &x).unwrap();
        let cf = fam.client_fwd.get(&cut).unwrap();
        let mut inputs = client_p.clone();
        inputs.push(x_lit.clone());
        let smashed = be.call(cf, &inputs).unwrap();
        let one = to_f32_vec(&smashed[0]).unwrap();

        let mut all = one.clone();
        all.extend_from_slice(&one);
        let smash = &fam.smashed_shape[&cut];
        let smash_len: usize = smash.iter().product();
        let mut st_shape = vec![c, BATCH];
        st_shape.extend(smash.iter());
        let labels: Vec<i32> =
            (0..c * BATCH).map(|i| (i % 10) as i32).collect();
        let st = fam.server_train_entry(cut, c).unwrap();
        let mut st_in = server_p.clone();
        st_in.push(literal_f32(&st_shape, &all).unwrap());
        st_in.push(literal_i32(&[c, BATCH], &labels).unwrap());
        st_in.push(literal_f32(&[c], &[0.5, 0.5]).unwrap());
        let mask: Vec<f32> = (0..BATCH)
            .map(|j| if j < BATCH / 2 { 1.0 } else { 0.0 })
            .collect();
        st_in.push(literal_f32(&[BATCH], &mask).unwrap());
        st_in.push(literal_f32(&[], &[0.05]).unwrap());
        let out = be.call(st, &st_in).unwrap();
        let n_sp = server_p.len();
        assert_eq!(out.len(), n_sp + 4);
        let loss = scalar_f32(&out[n_sp + 2]).unwrap();
        let ncorr = scalar_f32(&out[n_sp + 3]).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=(c * BATCH) as f32).contains(&ncorr));
        let cut_agg = to_f32_vec(&out[n_sp]).unwrap();
        assert_eq!(cut_agg.len(), BATCH * smash_len);

        let cs = fam.client_step.get(&cut).unwrap();
        let mut g_shape = vec![BATCH];
        g_shape.extend(smash.iter());
        let mut cs_in = client_p.clone();
        cs_in.push(x_lit);
        cs_in.push(
            literal_f32(&g_shape, &cut_agg).unwrap(),
        );
        cs_in.push(literal_f32(&[], &[0.05]).unwrap());
        let new_client = be.call(cs, &cs_in).unwrap();
        assert_eq!(new_client.len(), n_c);
        // Parameters moved.
        assert_ne!(
            to_f32_vec(&new_client[0]).unwrap(),
            to_f32_vec(&client_p[0]).unwrap()
        );

        let ex: Vec<f32> = (0..EVAL_BATCH * 256)
            .map(|_| rng.normal(0.0, 1.0) as f32)
            .collect();
        let ey: Vec<i32> =
            (0..EVAL_BATCH).map(|i| (i % 10) as i32).collect();
        let mut ev_in = params.clone();
        ev_in.push(literal_f32(&[EVAL_BATCH, 16, 16, 1], &ex).unwrap());
        ev_in.push(literal_i32(&[EVAL_BATCH], &ey).unwrap());
        let ev = be.call(&fam.eval, &ev_in).unwrap();
        assert!(scalar_f32(&ev[0]).unwrap().is_finite());
    }

    #[test]
    fn call_many_is_bit_identical_to_serial_calls() {
        let m = manifest();
        let fam = m.family("mnist").unwrap();
        let be1 = NativeBackend::with_threads(1);
        let be8 = NativeBackend::with_threads(8);
        let cut = 2;
        let params = init_full(fam, &be1, 5);
        let n_c = fam.client_param_count[&cut];
        let cf = fam.client_fwd.get(&cut).unwrap();
        let mut rng = crate::util::rng::Rng::new(9);
        let batches: Vec<Vec<Literal>> = (0..5)
            .map(|_| {
                let x: Vec<f32> = (0..BATCH * 16 * 16)
                    .map(|_| rng.normal(0.0, 1.0) as f32)
                    .collect();
                let mut v = params[..n_c].to_vec();
                v.push(literal_f32(&[BATCH, 16, 16, 1], &x).unwrap());
                v
            })
            .collect();
        let serial: Vec<Vec<Literal>> = batches
            .iter()
            .map(|b| be1.call(cf, b).unwrap())
            .collect();
        let fanned = be8.call_many(cf, &batches).unwrap();
        for (a, b) in serial.iter().zip(&fanned) {
            assert_eq!(
                to_f32_vec(&a[0]).unwrap(),
                to_f32_vec(&b[0]).unwrap()
            );
        }
    }

    #[test]
    fn phi_agg_entry_matches_rust_reference() {
        // The native twin of the PJRT `phi_agg_artifact_matches_rust_
        // reference` test (eq. 5–6 oracle).
        let m = manifest();
        let fam = m.family("mnist").unwrap();
        let be = NativeBackend::new();
        let entry = fam.phi_agg.get(&2).unwrap();
        let zspec = &entry.inputs[0];
        let (c, b, q) = (zspec.shape[0], zspec.shape[1], zspec.shape[2]);
        let mut rng = crate::util::rng::Rng::new(5);
        let z: Vec<f32> =
            (0..c * b * q).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let lam: Vec<f32> = vec![1.0 / c as f32; c];
        let m_agg = b / 2;
        let mask: Vec<f32> = (0..b)
            .map(|j| if j < m_agg { 1.0 } else { 0.0 })
            .collect();
        let out = be
            .call(
                entry,
                &[
                    literal_f32(&[c, b, q], &z).unwrap(),
                    literal_f32(&[c], &lam).unwrap(),
                    literal_f32(&[b], &mask).unwrap(),
                ],
            )
            .unwrap();
        let got = to_f32_vec(&out[0]).unwrap();
        for i in 0..c {
            for j in 0..b {
                for x in 0..q.min(7) {
                    let idx = (i * b + j) * q + x;
                    let expect = if j < m_agg {
                        (0..c)
                            .map(|k| lam[k] * z[(k * b + j) * q + x])
                            .sum::<f32>()
                    } else {
                        z[idx]
                    };
                    assert!(
                        (got[idx] - expect).abs() < 1e-4,
                        "mismatch at ({i},{j},{x}): {} vs {expect}",
                        got[idx]
                    );
                }
            }
        }
    }
}
