//! The opt-in **fast-math compute tier** (PERF.md §10): explicit-SIMD
//! GEMM microkernels plus a multi-threaded macro-loop, selected by
//! [`MathTier::Fast`] and dispatched at runtime from
//! [`super::model`]'s batched paths.
//!
//! ## Contract: tolerance, not bit-identity
//!
//! The bitwise tier ([`super::kernels`]) promises exact reproduction of
//! the reference summation order — no FMA, no reassociation, invariant
//! to `EPSL_THREADS`. This module deliberately trades that for speed:
//!
//! - The AVX2/FMA microkernels contract `a·b + c` into fused
//!   multiply-adds (one rounding instead of two) and, in the
//!   input-gradient dot ([`gemm_b_bt`]), reassociate the reduction into
//!   8 SIMD partial sums. Outputs therefore differ from the bitwise
//!   tier in the low mantissa bits: per-kernel relative error is
//!   bounded by O(K·ε) for a K-long reduction (K ≤ 1152 for every
//!   SplitNet layer, ε = 2⁻²⁴ ⇒ ~7·10⁻⁵), tested against the bitwise
//!   tier at 1e-3 here and in `tests/property_tier.rs`.
//! - [`gemm_bias_mt`] fans M-panels across threads via [`par`]. The
//!   partition is output-row-disjoint and each element's reduction
//!   order is fixed *within* a panel, so the current implementation is
//!   still thread-count-invariant and run-to-run deterministic — but
//!   only the weaker guarantee (deterministic at a *fixed*
//!   `EPSL_THREADS`) is contractual, leaving room for K-split
//!   reductions later. `tests/property_tier.rs` pins the documented
//!   guarantee, PERF.md §10 spells out the difference.
//!
//! On non-x86_64 targets, or when the CPU lacks AVX2/FMA at runtime,
//! every dispatcher falls back to the bitwise kernels — `Fast` then
//! degenerates to `Bitwise` semantics (never the other way around).
//!
//! This file is the R5-sanctioned home for fast-math/SIMD code (next to
//! `util/par.rs` for threading); `mul_add`/FMA stays banned everywhere
//! else in the tree (see ANALYSIS.md).

use crate::error::{Error, Result};
use crate::util::par;

use super::kernels::{self, Buf};
use super::ops::{out_size, Dims};

/// Which arithmetic the native backend runs.
///
/// `Bitwise` (the default) keeps every PR-4 guarantee: bit-identical to
/// the naive reference oracles and invariant to `EPSL_THREADS`. `Fast`
/// opts into the SIMD + threaded-GEMM kernels in this module under the
/// tolerance contract above. Selected via `[backend] math_tier` in TOML
/// or `--math-tier` on the CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MathTier {
    /// Bit-identical to the reference oracles; `EPSL_THREADS`-invariant.
    #[default]
    Bitwise,
    /// SIMD microkernel + threaded GEMM macro-loop; tolerance-tested.
    Fast,
}

impl MathTier {
    /// Parse `"bitwise"` / `"fast"` (the `--math-tier` / TOML values).
    pub fn parse(s: &str) -> Result<MathTier> {
        match s {
            "bitwise" => Ok(MathTier::Bitwise),
            "fast" => Ok(MathTier::Fast),
            other => Err(Error::Config(format!(
                "math tier '{other}' unknown (bitwise|fast)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MathTier::Bitwise => "bitwise",
            MathTier::Fast => "fast",
        }
    }
}

/// Output rows per threaded macro-loop panel (`gemm_bias_mt`). Matches
/// the bitwise path's `GEMM_BLOCK_ROWS` so the two tiers fan comparable
/// work items.
const PANEL_ROWS: usize = 128;

#[cfg(target_arch = "x86_64")]
fn simd_available() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// `out[m][n] = bias[n] + Σ_t a[m][t]·b[t][n]` — the fast-tier
/// counterpart of [`kernels::gemm_bias`]: AVX2/FMA when the CPU has it,
/// the bitwise kernel otherwise.
pub fn gemm_bias(m: usize, kdim: usize, n: usize, a: &[f32], b: &[f32],
                 bias: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * kdim);
    debug_assert_eq!(b.len(), kdim * n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(out.len(), m * n);
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: gated on runtime detection of AVX2 + FMA; slice
        // lengths asserted above match the kernel's access pattern.
        unsafe { x86::gemm_bias(m, kdim, n, a, b, bias, out) };
        return;
    }
    kernels::gemm_bias(m, kdim, n, a, b, bias, out);
}

/// `gw[t][n] += Σ_r patch[r][t]·gy[r][n]` — fast-tier counterpart of
/// [`kernels::gemm_at_b_acc`] (weight-gradient GEMM).
pub fn gemm_at_b_acc(rows: usize, kdim: usize, n: usize, patch: &[f32],
                     gy: &[f32], gw: &mut [f32]) {
    debug_assert_eq!(patch.len(), rows * kdim);
    debug_assert_eq!(gy.len(), rows * n);
    debug_assert_eq!(gw.len(), kdim * n);
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: gated on runtime detection of AVX2 + FMA; lengths
        // asserted above.
        unsafe { x86::gemm_at_b_acc(rows, kdim, n, patch, gy, gw) };
        return;
    }
    kernels::gemm_at_b_acc(rows, kdim, n, patch, gy, gw);
}

/// `dpatch[r][t] = Σ_c gy[r][c]·w[t][c]` — fast-tier counterpart of
/// [`kernels::gemm_b_bt`] (input-gradient cols). The SIMD dot keeps 8
/// partial sums, so this is the one kernel that *reassociates* the
/// reduction rather than merely contracting it.
pub fn gemm_b_bt(rows: usize, kdim: usize, n: usize, gy: &[f32],
                 w: &[f32], dpatch: &mut [f32]) {
    debug_assert_eq!(gy.len(), rows * n);
    debug_assert_eq!(w.len(), kdim * n);
    debug_assert_eq!(dpatch.len(), rows * kdim);
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: gated on runtime detection of AVX2 + FMA; lengths
        // asserted above.
        unsafe { x86::gemm_b_bt(rows, kdim, n, gy, w, dpatch) };
        return;
    }
    kernels::gemm_b_bt(rows, kdim, n, gy, w, dpatch);
}

/// The threaded GEMM macro-loop: fan `PANEL_ROWS`-row M-panels of
/// `out` across `threads` workers, each panel running the SIMD (or
/// fallback) [`gemm_bias`] microkernel. Panels partition output rows
/// disjointly and every element's reduction stays within its panel, so
/// the result is identical for any thread count; `threads <= 1` runs
/// the plain serial kernel.
#[allow(clippy::too_many_arguments)]
pub fn gemm_bias_mt(m: usize, kdim: usize, n: usize, a: &[f32],
                    b: &[f32], bias: &[f32], out: &mut [f32],
                    threads: usize) {
    debug_assert_eq!(a.len(), m * kdim);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if threads <= 1 || m <= PANEL_ROWS {
        gemm_bias(m, kdim, n, a, b, bias, out);
        return;
    }
    par::parallel_chunks_mut(out, PANEL_ROWS * n, threads, |pi, chunk| {
        let r0 = pi * PANEL_ROWS;
        let rows = chunk.len() / n;
        gemm_bias(rows, kdim, n, &a[r0 * kdim..][..rows * kdim], b, bias,
                  chunk);
    });
}

/// Fast-tier conv2d backward for one sample — the same decomposition as
/// [`kernels::conv2d_bwd_fast`] (zeroed `gw`/`gb`/`gx`, row-sum `gb`,
/// im2col → weight-gradient GEMM → input-gradient cols → col2im) with
/// the GEMMs dispatched to the SIMD kernels above. Within the
/// documented tolerance of the bitwise version, never bit-asserted.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bwd_fast(x: &[f32], xd: Dims, w: &[f32], k: usize,
                       cout: usize, stride: usize, gy: &[f32],
                       patch: &mut Buf, dpatch: &mut Buf, gw: &mut [f32],
                       gb: &mut [f32], gx: &mut [f32]) {
    let (h, ww, cin) = xd;
    let (oh, ow) = (out_size(h, stride), out_size(ww, stride));
    let rows = oh * ow;
    let kc = kernels::patch_cols(k, cin);
    gw.fill(0.0);
    gb.fill(0.0);
    gx.fill(0.0);
    for r in 0..rows {
        for (b, &g) in gb.iter_mut().zip(&gy[r * cout..][..cout]) {
            *b += g;
        }
    }
    let patch = patch.get(rows * kc);
    kernels::im2col(x, xd, k, stride, patch);
    gemm_at_b_acc(rows, kc, cout, patch, gy, gw);
    let dpatch = dpatch.get(rows * kc);
    gemm_b_bt(rows, kc, cout, gy, w, dpatch);
    kernels::col2im_acc(dpatch, xd, k, stride, gx);
}

/// The AVX2 + FMA microkernels. All functions here are `unsafe` solely
/// because of `#[target_feature]`; callers gate on
/// [`simd_available`]. Kept private to this module.
#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// Register tile: 4 output rows × 16 columns (two 8-lane vectors),
    /// the SIMD realization of the bitwise kernel's MR=4×NR=16 tile.
    const MR: usize = 4;

    /// SAFETY: requires AVX2 + FMA; `a` is m×kdim, `b` is kdim×n,
    /// `bias` is n, `out` is m×n, all row-major.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemm_bias(m: usize, kdim: usize, n: usize, a: &[f32],
                            b: &[f32], bias: &[f32], out: &mut [f32]) {
        let mut i0 = 0;
        while i0 < m {
            let mr = MR.min(m - i0);
            let mut j0 = 0;
            // 16-wide column tiles: 2 vectors × MR row accumulators.
            while j0 + 16 <= n {
                let b0 = _mm256_loadu_ps(bias.as_ptr().add(j0));
                let b1 = _mm256_loadu_ps(bias.as_ptr().add(j0 + 8));
                let mut acc = [[_mm256_setzero_ps(); 2]; MR];
                for accr in acc.iter_mut().take(mr) {
                    accr[0] = b0;
                    accr[1] = b1;
                }
                for t in 0..kdim {
                    let v0 = _mm256_loadu_ps(b.as_ptr().add(t * n + j0));
                    let v1 =
                        _mm256_loadu_ps(b.as_ptr().add(t * n + j0 + 8));
                    for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                        let av = _mm256_set1_ps(a[(i0 + r) * kdim + t]);
                        accr[0] = _mm256_fmadd_ps(av, v0, accr[0]);
                        accr[1] = _mm256_fmadd_ps(av, v1, accr[1]);
                    }
                }
                for (r, accr) in acc.iter().enumerate().take(mr) {
                    let dst = out.as_mut_ptr().add((i0 + r) * n + j0);
                    _mm256_storeu_ps(dst, accr[0]);
                    _mm256_storeu_ps(dst.add(8), accr[1]);
                }
                j0 += 16;
            }
            // 8-wide tail tiles.
            while j0 + 8 <= n {
                let bv = _mm256_loadu_ps(bias.as_ptr().add(j0));
                let mut acc = [_mm256_setzero_ps(); MR];
                for accr in acc.iter_mut().take(mr) {
                    *accr = bv;
                }
                for t in 0..kdim {
                    let v = _mm256_loadu_ps(b.as_ptr().add(t * n + j0));
                    for (r, accr) in acc.iter_mut().enumerate().take(mr) {
                        let av = _mm256_set1_ps(a[(i0 + r) * kdim + t]);
                        *accr = _mm256_fmadd_ps(av, v, *accr);
                    }
                }
                for (r, accr) in acc.iter().enumerate().take(mr) {
                    _mm256_storeu_ps(
                        out.as_mut_ptr().add((i0 + r) * n + j0), *accr);
                }
                j0 += 8;
            }
            // Scalar tail columns (FMA-contracted, like the vector body).
            while j0 < n {
                for r in 0..mr {
                    let mut c = bias[j0];
                    for t in 0..kdim {
                        c = a[(i0 + r) * kdim + t].mul_add(b[t * n + j0],
                                                           c);
                    }
                    out[(i0 + r) * n + j0] = c;
                }
                j0 += 1;
            }
            i0 += mr;
        }
    }

    /// SAFETY: requires AVX2 + FMA; `patch` is rows×kdim, `gy` is
    /// rows×n, `gw` is kdim×n (accumulated in place), all row-major.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemm_at_b_acc(rows: usize, kdim: usize, n: usize,
                                patch: &[f32], gy: &[f32],
                                gw: &mut [f32]) {
        let mut t0 = 0;
        while t0 < kdim {
            let tr = MR.min(kdim - t0);
            let mut j0 = 0;
            while j0 + 8 <= n {
                let mut acc = [_mm256_setzero_ps(); MR];
                for (ti, accr) in acc.iter_mut().enumerate().take(tr) {
                    *accr = _mm256_loadu_ps(
                        gw.as_ptr().add((t0 + ti) * n + j0));
                }
                for r in 0..rows {
                    let gv = _mm256_loadu_ps(gy.as_ptr().add(r * n + j0));
                    for (ti, accr) in acc.iter_mut().enumerate().take(tr)
                    {
                        let pv = _mm256_set1_ps(
                            patch[r * kdim + t0 + ti]);
                        *accr = _mm256_fmadd_ps(pv, gv, *accr);
                    }
                }
                for (ti, accr) in acc.iter().enumerate().take(tr) {
                    _mm256_storeu_ps(
                        gw.as_mut_ptr().add((t0 + ti) * n + j0), *accr);
                }
                j0 += 8;
            }
            while j0 < n {
                for ti in 0..tr {
                    let mut c = gw[(t0 + ti) * n + j0];
                    for r in 0..rows {
                        c = patch[r * kdim + t0 + ti]
                            .mul_add(gy[r * n + j0], c);
                    }
                    gw[(t0 + ti) * n + j0] = c;
                }
                j0 += 1;
            }
            t0 += tr;
        }
    }

    /// Horizontal sum of one 8-lane accumulator (reassociates).
    #[target_feature(enable = "avx2")]
    unsafe fn hsum(v: __m256) -> f32 {
        let hi = _mm256_extractf128_ps(v, 1);
        let lo = _mm256_castps256_ps128(v);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// SAFETY: requires AVX2 + FMA; `gy` is rows×n, `w` is kdim×n,
    /// `dpatch` is rows×kdim, all row-major.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemm_b_bt(rows: usize, kdim: usize, n: usize,
                            gy: &[f32], w: &[f32], dpatch: &mut [f32]) {
        for r in 0..rows {
            let gp = gy.as_ptr().add(r * n);
            for t in 0..kdim {
                let wp = w.as_ptr().add(t * n);
                let mut acc = _mm256_setzero_ps();
                let mut j = 0;
                while j + 8 <= n {
                    acc = _mm256_fmadd_ps(_mm256_loadu_ps(wp.add(j)),
                                          _mm256_loadu_ps(gp.add(j)),
                                          acc);
                    j += 8;
                }
                let mut s = hsum(acc);
                while j < n {
                    s = w[t * n + j].mul_add(gy[r * n + j], s);
                    j += 1;
                }
                dpatch[r * kdim + t] = s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    }

    /// The documented per-kernel tolerance (PERF.md §10).
    const TOL: f32 = 1e-3;

    fn rel_close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * a.abs().max(b.abs()).max(1.0)
    }

    #[test]
    fn tier_parse_roundtrip_and_default() {
        assert_eq!(MathTier::default(), MathTier::Bitwise);
        for t in [MathTier::Bitwise, MathTier::Fast] {
            assert_eq!(MathTier::parse(t.name()).unwrap(), t);
        }
        assert!(MathTier::parse("turbo").is_err());
        assert!(MathTier::parse("Fast").is_err());
    }

    #[test]
    fn gemm_bias_within_tolerance_of_bitwise_on_odd_shapes() {
        let mut rng = Rng::new(301);
        for &(m, k, n) in &[
            (7usize, 23usize, 19usize),
            (1, 1, 1),
            (33, 144, 16),
            (5, 1152, 37),
            (128, 9, 8),
        ] {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let bias = rand_vec(&mut rng, n);
            let mut bitwise = vec![0.0f32; m * n];
            kernels::gemm_bias(m, k, n, &a, &b, &bias, &mut bitwise);
            let mut fast = vec![0.0f32; m * n];
            gemm_bias(m, k, n, &a, &b, &bias, &mut fast);
            for (i, (&r, &f)) in bitwise.iter().zip(&fast).enumerate() {
                assert!(rel_close(r, f, TOL),
                        "gemm_bias m={m} k={k} n={n} [{i}]: {r} vs {f}");
            }
        }
    }

    #[test]
    fn gemm_at_b_acc_within_tolerance_and_accumulates() {
        let mut rng = Rng::new(302);
        let (rows, k, n) = (29, 37, 11);
        let patch = rand_vec(&mut rng, rows * k);
        let gy = rand_vec(&mut rng, rows * n);
        let init = rand_vec(&mut rng, k * n);
        let mut bitwise = init.clone();
        kernels::gemm_at_b_acc(rows, k, n, &patch, &gy, &mut bitwise);
        let mut fast = init;
        gemm_at_b_acc(rows, k, n, &patch, &gy, &mut fast);
        for (i, (&r, &f)) in bitwise.iter().zip(&fast).enumerate() {
            assert!(rel_close(r, f, TOL), "gw[{i}]: {r} vs {f}");
        }
    }

    #[test]
    fn gemm_b_bt_within_tolerance() {
        let mut rng = Rng::new(303);
        let (rows, k, n) = (13, 27, 21);
        let gy = rand_vec(&mut rng, rows * n);
        let w = rand_vec(&mut rng, k * n);
        let mut bitwise = vec![0.0f32; rows * k];
        kernels::gemm_b_bt(rows, k, n, &gy, &w, &mut bitwise);
        let mut fast = vec![0.0f32; rows * k];
        gemm_b_bt(rows, k, n, &gy, &w, &mut fast);
        for (i, (&r, &f)) in bitwise.iter().zip(&fast).enumerate() {
            assert!(rel_close(r, f, TOL), "dpatch[{i}]: {r} vs {f}");
        }
    }

    #[test]
    fn gemm_bias_mt_is_thread_count_invariant() {
        let mut rng = Rng::new(304);
        // m spans several panels plus a short tail.
        let (m, k, n) = (PANEL_ROWS * 3 + 17, 45, 24);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bias = rand_vec(&mut rng, n);
        let mut serial = vec![0.0f32; m * n];
        gemm_bias_mt(m, k, n, &a, &b, &bias, &mut serial, 1);
        let mut fanned = vec![0.0f32; m * n];
        gemm_bias_mt(m, k, n, &a, &b, &bias, &mut fanned, 4);
        assert_eq!(
            serial.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            fanned.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // ... and bit-identical to the single-call dispatch (panels
        // partition output rows without touching any reduction order).
        let mut single = vec![0.0f32; m * n];
        gemm_bias(m, k, n, &a, &b, &bias, &mut single);
        assert_eq!(
            serial.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            single.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn conv2d_bwd_fast_within_tolerance_of_bitwise() {
        let mut rng = Rng::new(305);
        let mut patch = Buf::default();
        let mut dpatch = Buf::default();
        for &(h, w, cin, cout, k, stride) in &[
            (5usize, 7usize, 3usize, 16usize, 3usize, 1usize),
            (9, 9, 8, 8, 3, 2),
            (4, 4, 2, 32, 1, 2),
        ] {
            let x = rand_vec(&mut rng, h * w * cin);
            let wt = rand_vec(&mut rng, k * k * cin * cout);
            let (oh, ow) = (out_size(h, stride), out_size(w, stride));
            let gy = rand_vec(&mut rng, oh * ow * cout);
            let mut rgw = vec![0.0f32; wt.len()];
            let mut rgb = vec![0.0f32; cout];
            let mut rgx = vec![0.0f32; h * w * cin];
            kernels::conv2d_bwd_fast(&x, (h, w, cin), &wt, k, cout,
                                     stride, &gy, &mut patch,
                                     &mut dpatch, &mut rgw, &mut rgb,
                                     &mut rgx);
            let mut fgw = vec![1.0f32; wt.len()]; // nonzero: fill check
            let mut fgb = vec![1.0f32; cout];
            let mut fgx = vec![1.0f32; h * w * cin];
            conv2d_bwd_fast(&x, (h, w, cin), &wt, k, cout, stride, &gy,
                            &mut patch, &mut dpatch, &mut fgw, &mut fgb,
                            &mut fgx);
            for (name, r, f) in [("gw", &rgw, &fgw), ("gb", &rgb, &fgb),
                                 ("gx", &rgx, &fgx)]
            {
                for (i, (&rv, &fv)) in r.iter().zip(f.iter()).enumerate()
                {
                    assert!(rel_close(rv, fv, TOL),
                            "{name}[{i}] h={h} w={w} cin={cin} \
                             cout={cout} k={k} stride={stride}: \
                             {rv} vs {fv}");
                }
            }
        }
    }
}
