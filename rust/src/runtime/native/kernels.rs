//! im2col + cache-blocked f32 GEMM kernels for the native backend, plus
//! the reusable [`Scratch`] arena that eliminates per-sample allocation
//! churn in the training hot path.
//!
//! ## Bit-identity contract
//!
//! Every kernel here reproduces the *exact* floating-point summation
//! order of the naive reference ops in [`super::ops`], so the fast path
//! is bitwise equal to the reference per sample (property-tested in
//! `tests/property_kernels.rs`):
//!
//! - [`gemm_bias`] initializes each output element from the bias and
//!   accumulates `a[m][t] * b[t][n]` with `t` strictly ascending — the
//!   same `bias; += x·w` order as `ops::conv2d` when the im2col patch is
//!   laid out `(ky, kx, ci)` (the HWIO tap order).
//! - [`gemm_at_b_acc`] accumulates `gw[t][n] += patch[r][t] * gy[r][n]`
//!   with `r` (output position) strictly ascending, matching the
//!   `oy, ox` loop of `ops::conv2d_bwd`.
//! - [`gemm_b_bt`] computes each `dpatch[r][t]` as a sequential dot over
//!   `cout` — the reference's scalar `acc += wv * g` loop.
//! - [`col2im_acc`] scatters `dpatch` into `gx` in `(row, tap)` order and
//!   *skips* out-of-bounds taps, exactly like the reference's bounds
//!   `continue`s.
//!
//! Padding taps are materialized as `0.0` in the patch buffer; the
//! reference skips them instead. `acc + 0.0·w` is bitwise `acc` for every
//! value reachable from the model's init/update rules (biases are never
//! `-0.0`), and a `gw` row of a padding tap sums `±0.0` terms from a
//! `+0.0` start, which is `+0.0` — the reference's untouched zero.
//! **Contract limit:** this argument assumes finite weights. If training
//! diverges to `±inf`/NaN, `0.0 · inf = NaN` makes the fast path go NaN
//! one step before the tap-skipping reference would — both paths are
//! garbage at that point, but no longer the *same* garbage.
//!
//! Blocking: the microkernel tiles M×N into `MR`×`NR` register tiles and
//! runs the full K loop per tile, so each output element owns one
//! accumulator for its entire reduction — blocking never reassociates the
//! sum. There is deliberately no FMA contraction (separate mul/add, like
//! the reference); the speedup comes from register/L1 reuse, not from
//! changing the arithmetic.

use std::sync::Mutex;

use super::ops::{out_size, pad_lo, Dims};

/// Number of im2col columns for a `k`×`k` conv over `cin` channels.
pub fn patch_cols(k: usize, cin: usize) -> usize {
    k * k * cin
}

/// Lower one sample's HWC input into an im2col patch matrix:
/// `patch[(oy·ow + ox) · K + (ky·k + kx)·cin + ci] = x[iy, ix, ci]`
/// (or `0.0` when the tap is out of bounds). `patch` must hold
/// `oh·ow·k·k·cin` elements.
pub fn im2col(x: &[f32], xd: Dims, k: usize, stride: usize,
              patch: &mut [f32]) {
    let (h, w, cin) = xd;
    let (oh, ow) = (out_size(h, stride), out_size(w, stride));
    let (py, px) = (pad_lo(h, k, stride), pad_lo(w, k, stride));
    let kc = patch_cols(k, cin);
    debug_assert_eq!(patch.len(), oh * ow * kc);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = &mut patch[(oy * ow + ox) * kc..][..kc];
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - py;
                let dst = &mut row[ky * k * cin..][..k * cin];
                if iy < 0 || iy >= h as isize {
                    dst.fill(0.0);
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - px;
                    let cell = &mut dst[kx * cin..][..cin];
                    if ix < 0 || ix >= w as isize {
                        cell.fill(0.0);
                    } else {
                        let src = ((iy as usize) * w + ix as usize) * cin;
                        cell.copy_from_slice(&x[src..][..cin]);
                    }
                }
            }
        }
    }
}

/// Register-tile rows (M) and columns (N) of the microkernel. `NR` covers
/// a full SIMD-friendly span of `cout`; both divide nothing — edge tiles
/// are handled by the same code with shorter bounds.
const MR: usize = 4;
const NR: usize = 16;

/// `out[m][n] = bias[n] + Σ_t a[m][t]·b[t][n]`, `t` ascending per output
/// element. `a` is M×K row-major, `b` is K×N row-major (an HWIO conv
/// weight reshaped to `(k·k·cin, cout)` is already in this layout), `out`
/// is M×N row-major and fully overwritten.
pub fn gemm_bias(m: usize, kdim: usize, n: usize, a: &[f32], b: &[f32],
                 bias: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * kdim);
    debug_assert_eq!(b.len(), kdim * n);
    debug_assert_eq!(bias.len(), n);
    debug_assert_eq!(out.len(), m * n);
    let mut acc = [[0.0f32; NR]; MR];
    let mut i0 = 0;
    while i0 < m {
        let mr = MR.min(m - i0);
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            // init tile from bias
            for row in acc.iter_mut().take(mr) {
                row[..nr].copy_from_slice(&bias[j0..j0 + nr]);
            }
            // full-K accumulation: one accumulator per element, t ascending
            for t in 0..kdim {
                let brow = &b[t * n + j0..][..nr];
                for (i, row) in acc.iter_mut().enumerate().take(mr) {
                    let av = a[(i0 + i) * kdim + t];
                    for (c, &bv) in row[..nr].iter_mut().zip(brow) {
                        *c += av * bv;
                    }
                }
            }
            for (i, row) in acc.iter().enumerate().take(mr) {
                out[(i0 + i) * n + j0..][..nr].copy_from_slice(&row[..nr]);
            }
            j0 += NR;
        }
        i0 += MR;
    }
}

/// `gw[t][n] += Σ_r patch[r][t]·gy[r][n]`, `r` ascending per output
/// element — the weight-gradient GEMM (`patchᵀ · gy`). `gw` accumulates
/// in place (callers zero it per sample, matching the reference's fresh
/// buffer).
pub fn gemm_at_b_acc(rows: usize, kdim: usize, n: usize, patch: &[f32],
                     gy: &[f32], gw: &mut [f32]) {
    debug_assert_eq!(patch.len(), rows * kdim);
    debug_assert_eq!(gy.len(), rows * n);
    debug_assert_eq!(gw.len(), kdim * n);
    // Tile over the (t, n) output; full row loop per tile keeps each
    // element's reduction sequential in r.
    let mut t0 = 0;
    while t0 < kdim {
        let tr = MR.min(kdim - t0);
        let mut j0 = 0;
        while j0 < n {
            let nr = NR.min(n - j0);
            let mut acc = [[0.0f32; NR]; MR];
            for (ti, row) in acc.iter_mut().enumerate().take(tr) {
                row[..nr]
                    .copy_from_slice(&gw[(t0 + ti) * n + j0..][..nr]);
            }
            for r in 0..rows {
                let gyr = &gy[r * n + j0..][..nr];
                for (ti, row) in acc.iter_mut().enumerate().take(tr) {
                    let pv = patch[r * kdim + t0 + ti];
                    for (c, &g) in row[..nr].iter_mut().zip(gyr) {
                        *c += pv * g;
                    }
                }
            }
            for (ti, row) in acc.iter().enumerate().take(tr) {
                gw[(t0 + ti) * n + j0..][..nr]
                    .copy_from_slice(&row[..nr]);
            }
            j0 += NR;
        }
        t0 += MR;
    }
}

/// `dpatch[r][t] = Σ_c gy[r][c]·w[t][c]`, `c` ascending sequentially per
/// element (`gy · wᵀ` with both operands row-major) — the input-gradient
/// cols. Matches the reference's scalar `acc += wv · g` dot.
pub fn gemm_b_bt(rows: usize, kdim: usize, n: usize, gy: &[f32],
                 w: &[f32], dpatch: &mut [f32]) {
    debug_assert_eq!(gy.len(), rows * n);
    debug_assert_eq!(w.len(), kdim * n);
    debug_assert_eq!(dpatch.len(), rows * kdim);
    for r in 0..rows {
        let gyr = &gy[r * n..][..n];
        let drow = &mut dpatch[r * kdim..][..kdim];
        for (t, d) in drow.iter_mut().enumerate() {
            let wrow = &w[t * n..][..n];
            let mut acc = 0.0f32;
            for (&wv, &g) in wrow.iter().zip(gyr) {
                acc += wv * g;
            }
            *d = acc;
        }
    }
}

/// Scatter-accumulate `dpatch` (rows × k·k·cin) back into `gx` (h·w·cin),
/// skipping out-of-bounds taps — `(row, tap)` ascending, the reference's
/// `oy, ox, ky, kx` order. `gx` accumulates in place.
pub fn col2im_acc(dpatch: &[f32], xd: Dims, k: usize, stride: usize,
                  gx: &mut [f32]) {
    let (h, w, cin) = xd;
    let (oh, ow) = (out_size(h, stride), out_size(w, stride));
    let (py, px) = (pad_lo(h, k, stride), pad_lo(w, k, stride));
    let kc = patch_cols(k, cin);
    debug_assert_eq!(dpatch.len(), oh * ow * kc);
    debug_assert_eq!(gx.len(), h * w * cin);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = &dpatch[(oy * ow + ox) * kc..][..kc];
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - py;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - px;
                    if ix < 0 || ix >= w as isize {
                        continue;
                    }
                    let dst = ((iy as usize) * w + ix as usize) * cin;
                    let src = &row[(ky * k + kx) * cin..][..cin];
                    for (g, &d) in
                        gx[dst..][..cin].iter_mut().zip(src)
                    {
                        *g += d;
                    }
                }
            }
        }
    }
}

/// Fast conv2d for one sample via im2col + [`gemm_bias`], bit-identical
/// to `ops::conv2d`. Writes into `out` (`oh·ow·cout`), using the pooled
/// `patch` buffer.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_fast(x: &[f32], xd: Dims, w: &[f32], k: usize, cout: usize,
                   bias: &[f32], stride: usize, patch: &mut Buf,
                   out: &mut [f32]) {
    let (h, ww, cin) = xd;
    let (oh, ow) = (out_size(h, stride), out_size(ww, stride));
    let rows = oh * ow;
    let kc = patch_cols(k, cin);
    let patch = patch.get(rows * kc);
    im2col(x, xd, k, stride, patch);
    gemm_bias(rows, kc, cout, patch, w, bias, out);
}

/// Fast conv2d backward for one sample, bit-identical to
/// `ops::conv2d_bwd`: `gw`/`gb` are freshly zeroed here (reference
/// allocates fresh buffers), `gx` accumulates into a zeroed buffer.
/// Returns nothing; results land in the provided slices.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_bwd_fast(x: &[f32], xd: Dims, w: &[f32], k: usize,
                       cout: usize, stride: usize, gy: &[f32],
                       patch: &mut Buf, dpatch: &mut Buf, gw: &mut [f32],
                       gb: &mut [f32], gx: &mut [f32]) {
    let (h, ww, cin) = xd;
    let (oh, ow) = (out_size(h, stride), out_size(ww, stride));
    let rows = oh * ow;
    let kc = patch_cols(k, cin);
    gw.fill(0.0);
    gb.fill(0.0);
    gx.fill(0.0);
    // gb: row sums, rows ascending (reference interleaves this with the
    // tap loops but per-element order is identical).
    for r in 0..rows {
        for (b, &g) in gb.iter_mut().zip(&gy[r * cout..][..cout]) {
            *b += g;
        }
    }
    let patch = patch.get(rows * kc);
    im2col(x, xd, k, stride, patch);
    gemm_at_b_acc(rows, kc, cout, patch, gy, gw);
    let dpatch = dpatch.get(rows * kc);
    gemm_b_bt(rows, kc, cout, gy, w, dpatch);
    col2im_acc(dpatch, xd, k, stride, gx);
}

/// One growable, reusable f32 buffer of the arena.
#[derive(Default)]
pub struct Buf(Vec<f32>);

impl Buf {
    /// Borrow `len` elements, growing (never shrinking) the backing
    /// storage. Contents are unspecified — callers fully overwrite or
    /// explicitly zero.
    pub fn get(&mut self, len: usize) -> &mut [f32] {
        if self.0.len() < len {
            self.0.resize(len, 0.0);
        }
        &mut self.0[..len]
    }
}

/// Per-worker scratch arena: every kernel buffer the fast paths need,
/// allocated once and grown to the high-water mark, reused across
/// samples and rounds (via [`ScratchPool`]). Distinct fields exist for
/// buffers that must be live simultaneously (disjoint `&mut` borrows).
#[derive(Default)]
pub struct Scratch {
    /// im2col patch matrix (forward and `gw` backward).
    pub patch: Buf,
    /// Backward cols (`gy · wᵀ`) before the col2im scatter.
    pub dpatch: Buf,
    /// Skip-branch output during batched forward.
    pub skip: Buf,
    /// Residual-block intermediate cotangent `ga` (backward).
    pub ga: Buf,
    /// Projection-branch input cotangent `gxp` (backward).
    pub gproj: Buf,
}

/// A checkout/checkin pool of [`Scratch`] arenas shared by all workers of
/// a backend. Pop order is irrelevant to results (arenas carry no state
/// that reaches outputs), so the pool is determinism-neutral; what it
/// buys is that once every worker's arena has hit its high-water mark,
/// the kernels' *work* buffers (patches, cols, intermediate cotangents)
/// are never allocated again — only the per-sample gradient tensors the
/// callers return (and later reduce serially) remain owned allocations.
#[derive(Default)]
pub struct ScratchPool {
    pool: Mutex<Vec<Scratch>>,
}

impl ScratchPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f` with a pooled arena (created on first use per concurrent
    /// worker), returning the arena afterwards.
    pub fn with<R>(&self, f: impl FnOnce(&mut Scratch) -> R) -> R {
        let mut s = self
            .pool
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop()
            .unwrap_or_default();
        let r = f(&mut s);
        self.pool.lock().unwrap_or_else(|e| e.into_inner()).push(s);
        r
    }

    /// Number of idle arenas (test/debug visibility).
    pub fn idle(&self) -> usize {
        self.pool.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

#[cfg(test)]
mod tests {
    use super::super::ops;
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    }

    #[test]
    fn conv2d_fast_bit_identical_to_reference() {
        let mut rng = Rng::new(71);
        let mut patch = Buf::default();
        for &(h, w, cin, cout, k, stride) in &[
            (5usize, 7usize, 3usize, 16usize, 3usize, 1usize),
            (9, 9, 8, 8, 3, 2),
            (4, 4, 2, 32, 1, 2),
            (1, 1, 1, 4, 3, 1),
        ] {
            let x = rand_vec(&mut rng, h * w * cin);
            let wt = rand_vec(&mut rng, k * k * cin * cout);
            let bias = rand_vec(&mut rng, cout);
            let reference = ops::conv2d(&x, (h, w, cin), &wt, k, cout,
                                        &bias, stride);
            let mut fast = vec![0.0f32; reference.len()];
            conv2d_fast(&x, (h, w, cin), &wt, k, cout, &bias, stride,
                        &mut patch, &mut fast);
            assert_eq!(
                reference.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                fast.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "conv mismatch at h={h} w={w} cin={cin} cout={cout} \
                 k={k} stride={stride}"
            );
        }
    }

    #[test]
    fn conv2d_bwd_fast_bit_identical_to_reference() {
        let mut rng = Rng::new(72);
        let mut patch = Buf::default();
        let mut dpatch = Buf::default();
        for &(h, w, cin, cout, k, stride) in &[
            (5usize, 7usize, 3usize, 16usize, 3usize, 1usize),
            (9, 9, 8, 8, 3, 2),
            (4, 4, 2, 32, 1, 2),
        ] {
            let x = rand_vec(&mut rng, h * w * cin);
            let wt = rand_vec(&mut rng, k * k * cin * cout);
            let (oh, ow) =
                (out_size(h, stride), out_size(w, stride));
            let gy = rand_vec(&mut rng, oh * ow * cout);
            let (rgw, rgb, rgx) = ops::conv2d_bwd(&x, (h, w, cin), &wt, k,
                                                  cout, stride, &gy);
            let mut gw = vec![1.0f32; rgw.len()]; // nonzero: fill check
            let mut gb = vec![1.0f32; rgb.len()];
            let mut gx = vec![1.0f32; rgx.len()];
            conv2d_bwd_fast(&x, (h, w, cin), &wt, k, cout, stride, &gy,
                            &mut patch, &mut dpatch, &mut gw, &mut gb,
                            &mut gx);
            for (name, r, f) in
                [("gw", &rgw, &gw), ("gb", &rgb, &gb), ("gx", &rgx, &gx)]
            {
                assert_eq!(
                    r.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    f.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{name} mismatch at h={h} w={w} cin={cin} \
                     cout={cout} k={k} stride={stride}"
                );
            }
        }
    }

    #[test]
    fn gemm_edge_tiles_cover_all_shapes() {
        // M, N not multiples of MR/NR; K = 1.
        let mut rng = Rng::new(73);
        let (m, k, n) = (7, 1, 5);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let bias = rand_vec(&mut rng, n);
        let mut out = vec![0.0f32; m * n];
        gemm_bias(m, k, n, &a, &b, &bias, &mut out);
        for i in 0..m {
            for j in 0..n {
                let want = bias[j] + a[i] * b[j];
                assert_eq!(out[i * n + j].to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn scratch_pool_reuses_arenas() {
        let pool = ScratchPool::new();
        pool.with(|s| {
            s.patch.get(1024);
        });
        assert_eq!(pool.idle(), 1);
        pool.with(|s| {
            // Arena returns with capacity intact.
            assert!(s.patch.0.capacity() >= 1024);
        });
        assert_eq!(pool.idle(), 1);
    }
}
