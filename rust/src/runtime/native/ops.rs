//! Host f32 NN primitives for the native backend: NHWC conv2d (SAME
//! padding, HWIO weights), global-average-pool + FC head, and the stable
//! softmax cross-entropy — forward and backward, mirroring the JAX graphs
//! in `python/compile/model.py` operation for operation.
//!
//! All loops run in a fixed order over one sample, so every function is a
//! pure deterministic map: the backend parallelizes *across* samples /
//! clients (via `util::par`, order-preserving), never inside a reduction,
//! which is what makes results bit-identical for any `EPSL_THREADS`.

/// (height, width, channels) of one NHWC feature map.
pub type Dims = (usize, usize, usize);

/// SAME-padding low offset for one spatial axis (JAX convention:
/// `pad_total = max((out-1)*stride + k - in, 0)`, low = total/2).
///
/// Guarded against the degenerate `input == 0` case, where `out == 0`
/// and `(out - 1) * stride` would wrap (debug overflow panic / garbage
/// padding in release). Zero-sized spatial dims are rejected up front by
/// manifest shape validation; the guard here is defense in depth so the
/// kernels can never be driven into the underflow.
///
/// `pub(super)` so the im2col/col2im kernels in [`super::kernels`] share
/// this exact computation — the bitwise fast==reference contract hinges
/// on the two paths padding identically.
pub(super) fn pad_lo(input: usize, k: usize, stride: usize) -> isize {
    let out = input.div_ceil(stride);
    if out == 0 {
        return 0;
    }
    let total = ((out - 1) * stride + k).saturating_sub(input);
    (total / 2) as isize
}

/// Output spatial size under SAME padding.
pub fn out_size(input: usize, stride: usize) -> usize {
    input.div_ceil(stride)
}

/// conv2d + bias, one sample. `x` is HWC `(h,w,cin)`, `w` is HWIO
/// `(k,k,cin,cout)`, returns `(oh,ow,cout)`.
pub fn conv2d(x: &[f32], xd: Dims, w: &[f32], k: usize, cout: usize,
              bias: &[f32], stride: usize) -> Vec<f32> {
    let (h, ww, cin) = xd;
    let (oh, ow) = (out_size(h, stride), out_size(ww, stride));
    let (py, px) = (pad_lo(h, k, stride), pad_lo(ww, k, stride));
    let mut out = vec![0.0f32; oh * ow * cout];
    for oy in 0..oh {
        for ox in 0..ow {
            let o = &mut out[(oy * ow + ox) * cout..][..cout];
            o.copy_from_slice(bias);
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - py;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - px;
                    if ix < 0 || ix >= ww as isize {
                        continue;
                    }
                    let xrow =
                        &x[((iy as usize) * ww + ix as usize) * cin..][..cin];
                    let wbase = (ky * k + kx) * cin * cout;
                    for (ci, &xv) in xrow.iter().enumerate() {
                        let wrow = &w[wbase + ci * cout..][..cout];
                        for (ov, &wv) in o.iter_mut().zip(wrow) {
                            *ov += xv * wv;
                        }
                    }
                }
            }
        }
    }
    out
}

/// conv2d backward, one sample: given `gy` `(oh,ow,cout)` returns
/// `(gw (k,k,cin,cout), gb (cout), gx (h,w,cin))`.
pub fn conv2d_bwd(x: &[f32], xd: Dims, w: &[f32], k: usize, cout: usize,
                  stride: usize, gy: &[f32])
    -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (h, ww, cin) = xd;
    let (oh, ow) = (out_size(h, stride), out_size(ww, stride));
    let (py, px) = (pad_lo(h, k, stride), pad_lo(ww, k, stride));
    let mut gw = vec![0.0f32; k * k * cin * cout];
    let mut gb = vec![0.0f32; cout];
    let mut gx = vec![0.0f32; h * ww * cin];
    for oy in 0..oh {
        for ox in 0..ow {
            let gyr = &gy[(oy * ow + ox) * cout..][..cout];
            for (b, &g) in gb.iter_mut().zip(gyr) {
                *b += g;
            }
            for ky in 0..k {
                let iy = (oy * stride + ky) as isize - py;
                if iy < 0 || iy >= h as isize {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * stride + kx) as isize - px;
                    if ix < 0 || ix >= ww as isize {
                        continue;
                    }
                    let xi = ((iy as usize) * ww + ix as usize) * cin;
                    let wbase = (ky * k + kx) * cin * cout;
                    for ci in 0..cin {
                        let xv = x[xi + ci];
                        let wrow = &w[wbase + ci * cout..][..cout];
                        let gwrow = &mut gw[wbase + ci * cout..][..cout];
                        let mut acc = 0.0f32;
                        for ((gwv, &wv), &g) in
                            gwrow.iter_mut().zip(wrow).zip(gyr)
                        {
                            *gwv += xv * g;
                            acc += wv * g;
                        }
                        gx[xi + ci] += acc;
                    }
                }
            }
        }
    }
    (gw, gb, gx)
}

/// In-place ReLU.
pub fn relu(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Gate a cotangent by a forward ReLU output (`relu'(0) = 0`, matching
/// `jax.nn.relu`'s VJP).
pub fn relu_bwd(cot: &mut [f32], fwd_out: &[f32]) {
    for (g, &y) in cot.iter_mut().zip(fwd_out) {
        if y <= 0.0 {
            *g = 0.0;
        }
    }
}

/// Global average pool `(h,w,c) → (c)`, then FC `(c × nc)` + bias.
/// Returns `(logits, pooled)` — `pooled` is the head's backward cache.
pub fn gap_fc(x: &[f32], xd: Dims, fc_w: &[f32], fc_b: &[f32], nc: usize)
    -> (Vec<f32>, Vec<f32>) {
    let (h, w, c) = xd;
    let hw = (h * w) as f32;
    let mut pooled = vec![0.0f32; c];
    for p in 0..h * w {
        let row = &x[p * c..][..c];
        for (s, &v) in pooled.iter_mut().zip(row) {
            *s += v;
        }
    }
    for s in pooled.iter_mut() {
        *s /= hw;
    }
    let mut logits = fc_b.to_vec();
    for (ci, &p) in pooled.iter().enumerate() {
        let wrow = &fc_w[ci * nc..][..nc];
        for (l, &wv) in logits.iter_mut().zip(wrow) {
            *l += p * wv;
        }
    }
    (logits, pooled)
}

/// Backward of [`gap_fc`]: `(g_fc_w, g_fc_b, g_x)`.
pub fn gap_fc_bwd(pooled: &[f32], xd: Dims, fc_w: &[f32], nc: usize,
                  dlogits: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (h, w, c) = xd;
    let hw = (h * w) as f32;
    let mut gw = vec![0.0f32; c * nc];
    let mut dpooled = vec![0.0f32; c];
    for ci in 0..c {
        let wrow = &fc_w[ci * nc..][..nc];
        let gwrow = &mut gw[ci * nc..][..nc];
        let mut acc = 0.0f32;
        for ((gwv, &wv), &g) in gwrow.iter_mut().zip(wrow).zip(dlogits) {
            *gwv += pooled[ci] * g;
            acc += wv * g;
        }
        dpooled[ci] = acc / hw;
    }
    let mut gx = vec![0.0f32; h * w * c];
    for p in 0..h * w {
        gx[p * c..][..c].copy_from_slice(&dpooled);
    }
    (gw, dlogits.to_vec(), gx)
}

/// Validate a label batch against the class count: every label must lie
/// in `[0, nc)`. A corrupt shard or bad literal used to panic the worker
/// thread mid-round on the `logits[label as usize]` index in
/// [`softmax_xent`]; callers (`server_train`, `eval`) surface this as
/// `Error::Data` instead.
pub fn check_labels(labels: &[i32], nc: usize) -> crate::error::Result<()> {
    for (i, &y) in labels.iter().enumerate() {
        if y < 0 || y as usize >= nc {
            return Err(crate::error::Error::Data(format!(
                "label {y} at flat index {i} is outside [0, {nc}) — \
                 corrupt shard or bad label literal"
            )));
        }
    }
    Ok(())
}

/// Stable softmax cross-entropy for one sample:
/// `(ce, dlogits = softmax − onehot, correct)`. Argmax ties resolve to the
/// first maximum (`jnp.argmax` convention). The label must be
/// pre-validated (see [`check_labels`]); an out-of-range label is a
/// caller bug here.
pub fn softmax_xent(logits: &[f32], label: i32) -> (f32, Vec<f32>, bool) {
    debug_assert!(
        label >= 0 && (label as usize) < logits.len(),
        "softmax_xent: unvalidated label {label} for {} classes",
        logits.len()
    );
    let m = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut d: Vec<f32> = logits.iter().map(|&l| (l - m).exp()).collect();
    let sum: f32 = d.iter().sum();
    let logsum = sum.ln();
    let y = label as usize;
    let ce = -(logits[y] - m - logsum);
    let mut argmax = 0;
    for (i, &l) in logits.iter().enumerate() {
        if l > logits[argmax] {
            argmax = i;
        }
    }
    for v in d.iter_mut() {
        *v /= sum;
    }
    d[y] -= 1.0;
    (ce, d, argmax == y)
}

/// `a += b` elementwise.
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// `a += s * b` elementwise.
pub fn axpy(a: &mut [f32], s: f32, b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x += s * y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_padding_shapes() {
        assert_eq!(out_size(16, 1), 16);
        assert_eq!(out_size(16, 2), 8);
        assert_eq!(pad_lo(16, 3, 1), 1);
        assert_eq!(pad_lo(16, 3, 2), 0); // total 1 → low 0, high 1
        assert_eq!(pad_lo(16, 1, 2), 0); // 1x1 stride-2 needs no padding
    }

    #[test]
    fn degenerate_zero_dim_does_not_underflow() {
        // input 0 → out 0: `(out - 1) * stride` used to wrap.
        assert_eq!(pad_lo(0, 3, 1), 0);
        assert_eq!(pad_lo(0, 3, 2), 0);
        assert_eq!(out_size(0, 2), 0);
        // A zero-sized conv is a no-op, not a panic.
        let y = conv2d(&[], (0, 0, 1), &[0.0; 9], 3, 1, &[0.0], 1);
        assert!(y.is_empty());
    }

    #[test]
    fn check_labels_catches_corrupt_shards() {
        assert!(check_labels(&[0, 3, 9], 10).is_ok());
        assert!(check_labels(&[], 10).is_ok());
        let e = check_labels(&[0, -1], 10).unwrap_err();
        assert!(matches!(e, crate::error::Error::Data(_)), "{e}");
        assert!(e.to_string().contains("-1"), "{e}");
        let e = check_labels(&[10], 10).unwrap_err();
        assert!(e.to_string().contains("10"), "{e}");
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weights reproduces the input.
        let x: Vec<f32> = (0..2 * 2 * 3).map(|i| i as f32).collect();
        let mut w = vec![0.0f32; 3 * 3]; // (1,1,3,3) HWIO
        for c in 0..3 {
            w[c * 3 + c] = 1.0;
        }
        let y = conv2d(&x, (2, 2, 3), &w, 1, 3, &[0.0; 3], 1);
        assert_eq!(y, x);
    }

    #[test]
    fn conv_bwd_matches_finite_difference() {
        let xd = (4, 4, 2);
        let (k, cout, stride) = (3, 3, 2);
        let x: Vec<f32> = (0..4 * 4 * 2)
            .map(|i| ((i * 37 % 11) as f32 - 5.0) / 7.0)
            .collect();
        let w: Vec<f32> = (0..k * k * 2 * cout)
            .map(|i| ((i * 13 % 17) as f32 - 8.0) / 23.0)
            .collect();
        let b = vec![0.05f32, -0.1, 0.2];
        let gy: Vec<f32> = (0..2 * 2 * cout)
            .map(|i| ((i * 7 % 5) as f32 - 2.0) / 3.0)
            .collect();
        let loss = |x: &[f32], w: &[f32], b: &[f32]| -> f64 {
            conv2d(x, xd, w, k, cout, b, stride)
                .iter()
                .zip(&gy)
                .map(|(&y, &g)| (y * g) as f64)
                .sum()
        };
        let (gw, gb, gx) = conv2d_bwd(&x, xd, &w, k, cout, stride, &gy);
        let eps = 1e-3;
        // spot-check a few coordinates of each gradient
        for i in [0usize, 5, 17, 31] {
            let mut xp = x.clone();
            xp[i] += eps;
            let num = (loss(&xp, &w, &b) - loss(&x, &w, &b)) / eps as f64;
            assert!(
                (num - gx[i] as f64).abs() < 1e-2,
                "gx[{i}]: num {num} vs {}",
                gx[i]
            );
        }
        for i in [0usize, 10, 25] {
            let mut wp = w.clone();
            wp[i] += eps;
            let num = (loss(&x, &wp, &b) - loss(&x, &w, &b)) / eps as f64;
            assert!(
                (num - gw[i] as f64).abs() < 1e-2,
                "gw[{i}]: num {num} vs {}",
                gw[i]
            );
        }
        let mut bp = b.clone();
        bp[1] += eps;
        let num = (loss(&x, &w, &bp) - loss(&x, &w, &b)) / eps as f64;
        assert!((num - gb[1] as f64).abs() < 1e-2);
    }

    #[test]
    fn softmax_xent_gradient_sums_to_zero() {
        let (ce, d, correct) = softmax_xent(&[1.0, 3.0, -2.0, 0.5], 1);
        assert!(ce > 0.0);
        assert!(correct);
        assert!(d.iter().sum::<f32>().abs() < 1e-6);
        assert!(d[1] < 0.0, "true-class gradient must be negative");
        let (_, _, c2) = softmax_xent(&[5.0, 1.0], 1);
        assert!(!c2);
    }

    #[test]
    fn gap_fc_bwd_matches_finite_difference() {
        let xd = (2, 2, 3);
        let nc = 4;
        let x: Vec<f32> =
            (0..12).map(|i| (i as f32 - 6.0) / 5.0).collect();
        let w: Vec<f32> =
            (0..12).map(|i| ((i * 5 % 7) as f32 - 3.0) / 4.0).collect();
        let b = vec![0.1f32; nc];
        let dlog = vec![0.3f32, -0.2, 0.5, -0.6];
        let loss = |x: &[f32], w: &[f32]| -> f64 {
            let (l, _) = gap_fc(x, xd, w, &b, nc);
            l.iter().zip(&dlog).map(|(&y, &g)| (y * g) as f64).sum()
        };
        let (logits, pooled) = gap_fc(&x, xd, &w, &b, nc);
        assert_eq!(logits.len(), nc);
        let (gw, gb, gx) = gap_fc_bwd(&pooled, xd, &w, nc, &dlog);
        assert_eq!(gb, dlog);
        let eps = 1e-3;
        for i in [0usize, 7, 11] {
            let mut xp = x.clone();
            xp[i] += eps;
            let num = (loss(&xp, &w) - loss(&x, &w)) / eps as f64;
            assert!((num - gx[i] as f64).abs() < 1e-2, "gx[{i}]");
            let mut wp = w.clone();
            wp[i] += eps;
            let num = (loss(&x, &wp) - loss(&x, &w)) / eps as f64;
            assert!((num - gw[i] as f64).abs() < 1e-2, "gw[{i}]");
        }
    }
}
