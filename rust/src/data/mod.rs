//! Datasets and sharding.
//!
//! The paper trains on MNIST and HAM10000; this environment has no network
//! access, so [`synth`] generates procedural class-structured image
//! datasets with the same interface (documented substitution — DESIGN.md
//! §3): `synth-mnist` (10 classes, 1 channel) and `synth-ham` (7 classes,
//! 3 channels). [`partition`] implements the paper's IID and non-IID
//! (2 classes per client) shardings.

pub mod partition;
pub mod synth;

use crate::util::rng::Rng;

/// A flat NHWC f32 image-classification dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Row-major `[n, h, w, c]`.
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub n_classes: usize,
}

impl Dataset {
    /// Floats per image.
    pub fn image_len(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Borrow image `i` as a slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let l = self.image_len();
        &self.images[i * l..(i + 1) * l]
    }

    /// Gather the given indices into contiguous (images, labels) buffers —
    /// the mini-batch layout the AOT artifacts expect.
    pub fn gather(&self, idx: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let l = self.image_len();
        let mut imgs = Vec::with_capacity(idx.len() * l);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            imgs.extend_from_slice(self.image(i));
            labels.push(self.labels[i]);
        }
        (imgs, labels)
    }

    /// Per-class sample counts.
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.n_classes];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }
}

/// A client's shard: indices into the parent dataset.
#[derive(Debug, Clone)]
pub struct Shard {
    pub indices: Vec<usize>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Sample a mini-batch of `b` indices (with replacement if the shard is
    /// smaller than `b` — mirrors random mini-batch draws in Alg. 1).
    ///
    /// Empty shards are rejected at partition time ([`partition::iid`] /
    /// [`partition::non_iid_two_class`] return `Error::Data`); the assert
    /// here is a named backstop instead of the old modulo-by-zero panic.
    pub fn sample_batch(&self, b: usize, rng: &mut Rng) -> Vec<usize> {
        assert!(
            !self.is_empty(),
            "sample_batch on an empty shard — partitioning should have \
             rejected this client count (Error::Data)"
        );
        if self.len() >= b {
            rng.sample_indices(self.len(), b)
                .into_iter()
                .map(|j| self.indices[j])
                .collect()
        } else {
            (0..b).map(|_| self.indices[rng.below(self.len())]).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::synth::{generate, SynthSpec};
    use super::*;

    #[test]
    fn gather_layout() {
        let ds = generate(&SynthSpec::mnist_like(64), 999);
        let (imgs, labels) = ds.gather(&[3, 7]);
        assert_eq!(imgs.len(), 2 * ds.image_len());
        assert_eq!(labels, vec![ds.labels[3], ds.labels[7]]);
        assert_eq!(&imgs[..ds.image_len()], ds.image(3));
    }

    #[test]
    fn shard_sampling_in_range() {
        let shard = Shard { indices: vec![5, 9, 11] };
        let mut rng = Rng::new(1);
        let b = shard.sample_batch(8, &mut rng); // larger than shard
        assert_eq!(b.len(), 8);
        assert!(b.iter().all(|i| [5, 9, 11].contains(i)));
        let b2 = shard.sample_batch(2, &mut rng);
        assert_eq!(b2.len(), 2);
        assert_ne!(b2[0], b2[1]); // without replacement when possible
    }
}
