//! IID / non-IID client sharding (paper §VII-A).
//!
//! - **IID**: shuffle and split evenly — every client sees all classes.
//! - **non-IID**: each client holds samples from exactly **2 classes**
//!   (the paper's pathological setting from [27, 45]): class shards are
//!   built per class, split into half-shards, and each client receives two
//!   half-shards of distinct classes.

use crate::error::{Error, Result};
use crate::util::rng::Rng;

use super::{Dataset, Shard};

/// IID partition into `c` near-equal shards.
///
/// Fails fast when `c > ds.n`: some shard would be empty, and an empty
/// shard panics later inside `Shard::sample_batch` (modulo-by-zero /
/// out-of-bounds) — far from the configuration mistake that caused it.
pub fn iid(ds: &Dataset, c: usize, rng: &mut Rng) -> Result<Vec<Shard>> {
    if c == 0 || ds.n < c {
        return Err(Error::Data(format!(
            "iid partition: {} samples cannot fill {c} client shards \
             (every client needs at least one sample)",
            ds.n
        )));
    }
    let mut idx: Vec<usize> = (0..ds.n).collect();
    rng.shuffle(&mut idx);
    let base = ds.n / c;
    let extra = ds.n % c;
    let mut shards = Vec::with_capacity(c);
    let mut cursor = 0;
    for i in 0..c {
        let take = base + usize::from(i < extra);
        shards.push(Shard { indices: idx[cursor..cursor + take].to_vec() });
        cursor += take;
    }
    Ok(shards)
}

/// Non-IID partition: exactly 2 classes per client.
///
/// Builds `2·C` class-chunks (each class contributes `ceil(2C / n_classes)`
/// or fewer chunks) and deals every client two chunks with distinct
/// classes. Requires `n_classes ≥ 2`.
pub fn non_iid_two_class(ds: &Dataset, c: usize, rng: &mut Rng)
    -> Result<Vec<Shard>> {
    if ds.n_classes < 2 {
        return Err(Error::Data("need ≥ 2 classes for non-IID".into()));
    }
    // Per-class index pools (shuffled).
    let mut pools: Vec<Vec<usize>> = vec![Vec::new(); ds.n_classes];
    for (i, &l) in ds.labels.iter().enumerate() {
        pools[l as usize].push(i);
    }
    for p in pools.iter_mut() {
        rng.shuffle(p);
    }
    // Assign class pairs round-robin over a shuffled class list so chunks
    // per class stay balanced and the two classes always differ.
    let mut class_order: Vec<usize> = (0..ds.n_classes).collect();
    rng.shuffle(&mut class_order);
    let pairs: Vec<(usize, usize)> = (0..c)
        .map(|i| {
            let a = class_order[(2 * i) % ds.n_classes];
            let mut b = class_order[(2 * i + 1) % ds.n_classes];
            if a == b {
                b = class_order[(2 * i + 2) % ds.n_classes];
            }
            (a, b)
        })
        .collect();
    // How many clients draw from each class → split pools evenly.
    let mut demand = vec![0usize; ds.n_classes];
    for &(a, b) in &pairs {
        demand[a] += 1;
        demand[b] += 1;
    }
    let mut cursors = vec![0usize; ds.n_classes];
    let mut shards = Vec::with_capacity(c);
    for &(a, b) in &pairs {
        let mut indices = Vec::new();
        for &cls in &[a, b] {
            let pool = &pools[cls];
            let share = pool.len() / demand[cls].max(1);
            let start = cursors[cls];
            let end = (start + share).min(pool.len());
            indices.extend_from_slice(&pool[start..end]);
            cursors[cls] = end;
        }
        if indices.is_empty() {
            // An empty shard would panic much later in sample_batch
            // (modulo-by-zero); name the cause here instead.
            return Err(Error::Data(format!(
                "empty non-IID shard (classes {a},{b}): {c} clients over \
                 {} samples in {} classes leave this client no data — \
                 lower the client count or enlarge the dataset",
                ds.n, ds.n_classes
            )));
        }
        shards.push(Shard { indices });
    }
    Ok(shards)
}

/// λ_i = D_i / D dataset weights for a sharding.
pub fn lambda_weights(shards: &[Shard]) -> Vec<f32> {
    let total: usize = shards.iter().map(Shard::len).sum();
    shards
        .iter()
        .map(|s| s.len() as f32 / total.max(1) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthSpec};

    fn ds() -> Dataset {
        generate(&SynthSpec::mnist_like(1000), 5)
    }

    #[test]
    fn iid_covers_everything_once() {
        let d = ds();
        let mut rng = Rng::new(1);
        let shards = iid(&d, 7, &mut rng).unwrap();
        assert_eq!(shards.len(), 7);
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
        // near-equal sizes
        let sizes: Vec<usize> = shards.iter().map(Shard::len).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn iid_shards_see_all_classes() {
        let d = ds();
        let mut rng = Rng::new(2);
        let shards = iid(&d, 5, &mut rng).unwrap();
        for s in &shards {
            let mut classes: Vec<i32> =
                s.indices.iter().map(|&i| d.labels[i]).collect();
            classes.sort_unstable();
            classes.dedup();
            assert_eq!(classes.len(), 10, "IID shard missing classes");
        }
    }

    #[test]
    fn non_iid_exactly_two_classes() {
        let d = ds();
        let mut rng = Rng::new(3);
        let shards = non_iid_two_class(&d, 5, &mut rng).unwrap();
        assert_eq!(shards.len(), 5);
        for s in &shards {
            let mut classes: Vec<i32> =
                s.indices.iter().map(|&i| d.labels[i]).collect();
            classes.sort_unstable();
            classes.dedup();
            assert_eq!(classes.len(), 2, "shard has {classes:?}");
        }
    }

    #[test]
    fn non_iid_no_index_reuse() {
        let d = ds();
        let mut rng = Rng::new(4);
        let shards = non_iid_two_class(&d, 10, &mut rng).unwrap();
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.indices.clone()).collect();
        let before = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), before, "an index was assigned twice");
    }

    #[test]
    fn empty_shards_fail_fast_at_partition_time() {
        // Pre-fix these configurations produced empty shards that blew up
        // rounds later inside Shard::sample_batch (rng.below(0) → panic);
        // now partitioning reports a descriptive Error::Data up front.
        let small = generate(&SynthSpec::mnist_like(3), 8);
        let mut rng = Rng::new(7);
        let e = iid(&small, 5, &mut rng).unwrap_err();
        assert!(matches!(e, crate::error::Error::Data(_)), "{e}");
        assert!(e.to_string().contains("5 client shards"), "{e}");

        // non-IID at an awkward client count: 40 clients each demand two
        // class half-shards of a 20-sample/2-class corpus — some client
        // ends up with no data.
        let mut spec = SynthSpec::mnist_like(20);
        spec.n_classes = 2;
        let tiny = generate(&spec, 9);
        let mut rng = Rng::new(8);
        let e = non_iid_two_class(&tiny, 40, &mut rng).unwrap_err();
        assert!(matches!(e, crate::error::Error::Data(_)), "{e}");
        assert!(e.to_string().contains("empty non-IID shard"), "{e}");
    }

    #[test]
    fn non_iid_handles_more_clients_than_class_pairs() {
        let d = ds();
        let mut rng = Rng::new(5);
        // 15 clients over 10 classes: pairs wrap around.
        let shards = non_iid_two_class(&d, 15, &mut rng).unwrap();
        assert_eq!(shards.len(), 15);
        for s in &shards {
            assert!(!s.is_empty());
        }
    }

    #[test]
    fn lambda_sums_to_one() {
        let d = ds();
        let mut rng = Rng::new(6);
        let shards = iid(&d, 5, &mut rng).unwrap();
        let lam = lambda_weights(&shards);
        let sum: f32 = lam.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(lam.iter().all(|&l| l > 0.0));
    }
}
