//! Procedural class-structured image synthesis.
//!
//! Each class has a deterministic *prototype*: smoothed Gaussian noise at a
//! class-specific seed. A sample is its class prototype under a small random
//! translation, a per-sample amplitude jitter, and additive pixel noise —
//! enough intra-class variation that a CNN must learn translation-tolerant
//! class features (what the accuracy experiments exercise), while staying
//! fully reproducible from a single seed.

use crate::util::rng::Rng;

use super::Dataset;

/// Generation parameters.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub n_classes: usize,
    /// Additive noise std (signal std is ~1).
    pub noise: f64,
    /// Max |shift| in pixels for the random translation.
    pub max_shift: isize,
}

impl SynthSpec {
    /// 10-class single-channel digits analogue.
    pub fn mnist_like(n: usize) -> Self {
        SynthSpec {
            n,
            h: 16,
            w: 16,
            c: 1,
            n_classes: 10,
            noise: 0.35,
            max_shift: 2,
        }
    }

    /// 7-class RGB skin-lesion analogue (harder: more noise).
    pub fn ham_like(n: usize) -> Self {
        SynthSpec {
            n,
            h: 16,
            w: 16,
            c: 3,
            n_classes: 7,
            noise: 0.5,
            max_shift: 2,
        }
    }

    pub fn for_family(family: &str, n: usize) -> Self {
        match family {
            "mnist" => Self::mnist_like(n),
            _ => Self::ham_like(n),
        }
    }
}

/// 3x3 box blur with edge clamping (smooths prototypes so translations
/// produce correlated, learnable features rather than white noise).
fn box_blur(img: &[f64], h: usize, w: usize) -> Vec<f64> {
    let mut out = vec![0.0; h * w];
    for y in 0..h {
        for x in 0..w {
            let mut acc = 0.0;
            let mut cnt = 0.0;
            for dy in -1i64..=1 {
                for dx in -1i64..=1 {
                    let yy = y as i64 + dy;
                    let xx = x as i64 + dx;
                    if yy >= 0 && yy < h as i64 && xx >= 0 && xx < w as i64 {
                        acc += img[(yy as usize) * w + xx as usize];
                        cnt += 1.0;
                    }
                }
            }
            out[y * w + x] = acc / cnt;
        }
    }
    out
}

/// Class prototype: smoothed unit-variance noise, one plane per channel.
fn prototype(spec: &SynthSpec, class: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ (0xC1A5_5000 + class as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let plane = spec.h * spec.w;
    let mut proto = vec![0.0; plane * spec.c];
    for ch in 0..spec.c {
        let raw: Vec<f64> = (0..plane).map(|_| rng.gaussian()).collect();
        let mut sm = box_blur(&raw, spec.h, spec.w);
        sm = box_blur(&sm, spec.h, spec.w);
        // Renormalize to unit std.
        let mean: f64 = sm.iter().sum::<f64>() / plane as f64;
        let var: f64 =
            sm.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
                / plane as f64;
        let std = var.sqrt().max(1e-9);
        for (dst, src) in proto[ch * plane..(ch + 1) * plane]
            .iter_mut()
            .zip(sm.iter())
        {
            *dst = (src - mean) / std;
        }
    }
    proto
}

/// Generate a dataset. Deterministic in `(spec, seed)`.
pub fn generate(spec: &SynthSpec, seed: u64) -> Dataset {
    let protos: Vec<Vec<f64>> =
        (0..spec.n_classes).map(|c| prototype(spec, c, seed)).collect();
    let mut rng = Rng::new(seed);
    let plane = spec.h * spec.w;
    let img_len = plane * spec.c;
    let mut images = Vec::with_capacity(spec.n * img_len);
    let mut labels = Vec::with_capacity(spec.n);
    for i in 0..spec.n {
        let class = i % spec.n_classes; // balanced
        let proto = &protos[class];
        let dy = rng.range(0, (2 * spec.max_shift + 1) as usize) as isize
            - spec.max_shift;
        let dx = rng.range(0, (2 * spec.max_shift + 1) as usize) as isize
            - spec.max_shift;
        let amp = rng.uniform(0.8, 1.2);
        for ch in 0..spec.c {
            for y in 0..spec.h {
                for x in 0..spec.w {
                    let sy = (y as isize + dy)
                        .clamp(0, spec.h as isize - 1)
                        as usize;
                    let sx = (x as isize + dx)
                        .clamp(0, spec.w as isize - 1)
                        as usize;
                    let v = amp * proto[ch * plane + sy * spec.w + sx]
                        + spec.noise * rng.gaussian();
                    // NHWC layout.
                    images.push(v as f32);
                }
            }
        }
        // interleave channels into NHWC: we pushed HW per channel (CHW);
        // fix ordering below if multi-channel.
        labels.push(class as i32);
    }
    // Convert CHW blocks to HWC per image when c > 1.
    if spec.c > 1 {
        let mut fixed = vec![0.0f32; images.len()];
        for i in 0..spec.n {
            let base = i * img_len;
            for ch in 0..spec.c {
                for p in 0..plane {
                    fixed[base + p * spec.c + ch] =
                        images[base + ch * plane + p];
                }
            }
        }
        images = fixed;
    }
    // Shuffle sample order (labels were round-robin).
    let mut order: Vec<usize> = (0..spec.n).collect();
    rng.shuffle(&mut order);
    let mut s_images = Vec::with_capacity(images.len());
    let mut s_labels = Vec::with_capacity(spec.n);
    for &i in &order {
        s_images.extend_from_slice(&images[i * img_len..(i + 1) * img_len]);
        s_labels.push(labels[i]);
    }
    Dataset {
        images: s_images,
        labels: s_labels,
        n: spec.n,
        h: spec.h,
        w: spec.w,
        c: spec.c,
        n_classes: spec.n_classes,
    }
}

/// Standard train/test pair (disjoint seeds ⇒ same prototypes, fresh
/// translations/noise — prototypes must share the seed so the test set
/// tests generalization over nuisance factors, not new classes).
pub fn train_test(spec_train: &SynthSpec, n_test: usize, seed: u64)
    -> (Dataset, Dataset) {
    let train = generate(spec_train, seed);
    let mut test_spec = spec_train.clone();
    test_spec.n = n_test;
    // Same prototype seed; different sample stream.
    let protos_seed = seed;
    let test = generate_with_proto_seed(&test_spec, protos_seed, seed + 1);
    (train, test)
}

fn generate_with_proto_seed(spec: &SynthSpec, proto_seed: u64,
                            sample_seed: u64) -> Dataset {
    // Same as `generate` but decoupling prototype and sample randomness.
    let protos: Vec<Vec<f64>> =
        (0..spec.n_classes).map(|c| prototype(spec, c, proto_seed)).collect();
    let mut rng = Rng::new(sample_seed);
    let plane = spec.h * spec.w;
    let img_len = plane * spec.c;
    let mut images = Vec::with_capacity(spec.n * img_len);
    let mut labels = Vec::with_capacity(spec.n);
    for i in 0..spec.n {
        let class = i % spec.n_classes;
        let proto = &protos[class];
        let dy = rng.range(0, (2 * spec.max_shift + 1) as usize) as isize
            - spec.max_shift;
        let dx = rng.range(0, (2 * spec.max_shift + 1) as usize) as isize
            - spec.max_shift;
        let amp = rng.uniform(0.8, 1.2);
        let mut chw = vec![0.0f32; img_len];
        for ch in 0..spec.c {
            for y in 0..spec.h {
                for x in 0..spec.w {
                    let sy = (y as isize + dy)
                        .clamp(0, spec.h as isize - 1)
                        as usize;
                    let sx = (x as isize + dx)
                        .clamp(0, spec.w as isize - 1)
                        as usize;
                    let v = amp * proto[ch * plane + sy * spec.w + sx]
                        + spec.noise * rng.gaussian();
                    chw[ch * plane + y * spec.w + x] = v as f32;
                }
            }
        }
        for p in 0..plane {
            for ch in 0..spec.c {
                images.push(chw[ch * plane + p]);
            }
        }
        labels.push(class as i32);
    }
    let mut order: Vec<usize> = (0..spec.n).collect();
    rng.shuffle(&mut order);
    let mut s_images = Vec::with_capacity(images.len());
    let mut s_labels = Vec::with_capacity(spec.n);
    for &i in &order {
        s_images.extend_from_slice(&images[i * img_len..(i + 1) * img_len]);
        s_labels.push(labels[i]);
    }
    Dataset {
        images: s_images,
        labels: s_labels,
        n: spec.n,
        h: spec.h,
        w: spec.w,
        c: spec.c,
        n_classes: spec.n_classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn deterministic_and_balanced() {
        let spec = SynthSpec::mnist_like(200);
        let a = generate(&spec, 42);
        let b = generate(&spec, 42);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let hist = a.class_histogram();
        assert_eq!(hist.iter().sum::<usize>(), 200);
        assert!(hist.iter().all(|&h| h == 20));
    }

    #[test]
    fn different_seed_different_data() {
        let spec = SynthSpec::mnist_like(50);
        let a = generate(&spec, 1);
        let b = generate(&spec, 2);
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn classes_are_separable() {
        // Same-class samples must correlate more than cross-class — the
        // learnability precondition for every accuracy experiment.
        let spec = SynthSpec::mnist_like(400);
        let ds = generate(&spec, 7);
        let corr = |a: &[f32], b: &[f32]| -> f64 {
            let ma = mean(&a.iter().map(|x| *x as f64).collect::<Vec<_>>());
            let mb = mean(&b.iter().map(|x| *x as f64).collect::<Vec<_>>());
            let mut num = 0.0;
            let mut da = 0.0;
            let mut db = 0.0;
            for (x, y) in a.iter().zip(b) {
                let xa = *x as f64 - ma;
                let yb = *y as f64 - mb;
                num += xa * yb;
                da += xa * xa;
                db += yb * yb;
            }
            num / (da.sqrt() * db.sqrt() + 1e-12)
        };
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..60 {
            for j in (i + 1)..60 {
                let c = corr(ds.image(i), ds.image(j));
                if ds.labels[i] == ds.labels[j] {
                    same.push(c);
                } else {
                    diff.push(c);
                }
            }
        }
        let ms = mean(&same);
        let md = mean(&diff);
        assert!(
            ms > md + 0.2,
            "same-class corr {ms:.3} not >> cross-class {md:.3}"
        );
    }

    #[test]
    fn ham_is_three_channel_seven_class() {
        let ds = generate(&SynthSpec::ham_like(70), 3);
        assert_eq!(ds.c, 3);
        assert_eq!(ds.n_classes, 7);
        assert_eq!(ds.image_len(), 16 * 16 * 3);
        assert_eq!(ds.images.len(), 70 * 768);
    }

    #[test]
    fn train_test_share_prototypes() {
        let spec = SynthSpec::mnist_like(300);
        let (train, test) = train_test(&spec, 100, 11);
        assert_eq!(test.n, 100);
        // Cross-set same-class correlation must exceed cross-class — the
        // test set is recognizable from training prototypes.
        let ci = |ds: &Dataset, class: i32| {
            ds.labels.iter().position(|&l| l == class).unwrap()
        };
        let dot = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum()
        };
        let tr0 = train.image(ci(&train, 0));
        let te0 = test.image(ci(&test, 0));
        let te1 = test.image(ci(&test, 1));
        assert!(dot(tr0, te0) > dot(tr0, te1));
    }
}
