//! In-tree static-analysis pass (`epsl-audit`) enforcing the source
//! invariants the repo's bit-exactness guarantees rest on.
//!
//! Every guarantee this reproduction makes — bit-exact checkpoint /
//! resume, hetero-cut ≤ uniform dominance, `EPSL_THREADS`-invariance,
//! the eq. 23 fp-association parity between the closed forms and the
//! event timeline — depends on source-level discipline: seed-pure RNG
//! streams, deterministic iteration order, no wall-clock reads in
//! simulated paths, no panics in the training loop. This module turns
//! those rules into a machine-checked, CI-gated audit.
//!
//! The engine is dependency-free and works at two levels. Token level:
//! [`lexer`] strips comments and literals, [`rules`] matches forbidden
//! tokens (rules R1–R6). Item level: [`items`] parses `crate::…`
//! module references, `rng.fork(TAG)` call sites, and the
//! `util::rng::streams` tag registry, feeding the semantic rules —
//! R7 (module-layering DAG), R8 (RNG-stream lineage), and R9
//! (stale-suppression ratchet). [`engine`] scopes rules by path,
//! tracks `#[cfg(test)]` regions, honors
//! `// audit:allow(R<n>, "reason")` suppressions (and reports the
//! stale ones), and walks the tree in sorted order. [`report`] adds
//! the `--baseline` ratchet (frozen findings demote to advisory;
//! fresh ones deny) and SARIF 2.1.0 output. The `epsl-audit` binary
//! (`cargo run --bin epsl-audit`) reports findings as
//! `path:line: rule [token] snippet` (or `--json` / `--sarif`) and
//! exits non-zero on denied findings. See `ANALYSIS.md` at the repo
//! root for the full rule catalogue, rationale, and suppression
//! policy.

pub mod engine;
pub mod items;
pub mod lexer;
pub mod report;
pub mod rules;

pub use engine::{
    audit_source, audit_source_with, audit_tree, module_of, severity,
    AuditReport, FileAudit, Finding, Severity, LAYER_MAP, WALK_ROOTS,
};
pub use items::{scan_items, ForkArg, StreamRegistry};
pub use report::{to_sarif, Baseline};
pub use rules::{scan_allows, scan_rule, RuleId};
