//! In-tree static-analysis pass (`epsl-audit`) enforcing the source
//! invariants the repo's bit-exactness guarantees rest on.
//!
//! Every guarantee this reproduction makes — bit-exact checkpoint /
//! resume, hetero-cut ≤ uniform dominance, `EPSL_THREADS`-invariance,
//! the eq. 23 fp-association parity between the closed forms and the
//! event timeline — depends on source-level discipline: seed-pure RNG
//! streams, deterministic iteration order, no wall-clock reads in
//! simulated paths, no panics in the training loop. This module turns
//! those rules into a machine-checked, CI-gated audit.
//!
//! The engine is dependency-free and line/token-level: [`lexer`] strips
//! comments and literals, [`rules`] matches forbidden tokens (rules
//! R1–R6), [`engine`] scopes rules by path, tracks `#[cfg(test)]`
//! regions, honors `// audit:allow(R<n>, "reason")` suppressions, and
//! walks the tree in sorted order. The `epsl-audit` binary
//! (`cargo run --bin epsl-audit`) reports findings as
//! `path:line: rule [token] snippet` (or `--json`) and exits non-zero
//! on denied findings. See `ANALYSIS.md` at the repo root for the full
//! rule catalogue, rationale, and suppression policy.

pub mod engine;
pub mod lexer;
pub mod rules;

pub use engine::{
    audit_source, audit_tree, severity, AuditReport, FileAudit, Finding,
    Severity, WALK_ROOTS,
};
pub use rules::{scan_allows, scan_rule, RuleId};
