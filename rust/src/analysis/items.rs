//! Item-level scans on top of the [`super::lexer`] channel split: the
//! semantic inputs for rules R7 (module layering) and R8 (RNG-stream
//! lineage).
//!
//! Three scans run over the *code* channel of a lexed file:
//!
//! * **module references** — every `crate::<module>` / `epsl::<module>`
//!   path head, from `use` declarations and inline qualified paths
//!   alike (an inline `crate::experiments::f()` is the same layering
//!   edge as `use crate::experiments`), including one-line
//!   `crate::{a, b}` groups;
//! * **fork call sites** — every `.fork(ARG)` occurrence, with `ARG`
//!   classified as an integer literal, a SCREAMING_CASE constant path,
//!   or a threaded expression (a lowercase binding such as the `tag`
//!   parameter of a sub-stream closure — checked at the call site that
//!   names the constant, not here);
//! * **integer literals** — every standalone integer literal ≥ 0x1000,
//!   so a registered stream tag value re-introduced as a raw number is
//!   caught anywhere, not just inside a `.fork(...)` argument.
//!
//! [`StreamRegistry::parse`] additionally reads the central tag
//! registry (`pub mod streams` in `util/rng.rs`): its `pub const NAME:
//! u64 = <value>;` declarations and the `ALL` mirror array that feeds
//! the compile-time uniqueness assert.

use super::lexer::{lex, LineView};
use super::rules::is_word_char;

/// One `crate::…` / `epsl::…` reference: the top-level module named
/// right after the crate-root segment.
#[derive(Debug, Clone)]
pub struct ModuleRef {
    /// 1-based line number.
    pub line: usize,
    /// The referenced top-level module (`"experiments"` for
    /// `crate::experiments::sweep`).
    pub module: String,
}

/// Classification of the argument of one `.fork(...)` call.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ForkArg {
    /// A raw integer literal (`.fork(0xFEA7)`).
    Literal { value: u64, text: String },
    /// A constant path whose final segment is SCREAMING_CASE
    /// (`.fork(streams::SCENARIO_DYNAMICS)` → `SCENARIO_DYNAMICS`).
    Named { name: String, text: String },
    /// Anything else — a lowercase binding or expression that threads a
    /// tag chosen (and checked) at an upstream call site.
    Threaded { text: String },
}

/// One `.fork(...)` call site.
#[derive(Debug, Clone)]
pub struct ForkSite {
    /// 1-based line number.
    pub line: usize,
    pub arg: ForkArg,
}

/// One standalone integer literal (value ≥ 0x1000 only — small
/// literals are ubiquitous and stream tags are required to clear the
/// same floor, so nothing below it can be a tag collision).
#[derive(Debug, Clone)]
pub struct IntLit {
    /// 1-based line number.
    pub line: usize,
    pub value: u64,
}

/// Smallest value a registered stream tag may take; also the floor
/// below which [`IntLit`]s are not collected.
pub const MIN_TAG_VALUE: u64 = 0x1000;

/// Everything the item pass extracted from one file.
#[derive(Debug, Default)]
pub struct FileItems {
    pub module_refs: Vec<ModuleRef>,
    pub forks: Vec<ForkSite>,
    pub int_lits: Vec<IntLit>,
}

/// Parse a complete Rust integer literal (hex or decimal, `_`
/// separators, optional integer-type suffix). Returns `None` for
/// anything else — floats, invalid suffixes, overflow.
pub fn parse_int_literal(s: &str) -> Option<u64> {
    let s = s.trim();
    let (digits, radix) = match s.strip_prefix("0x").or_else(|| {
        s.strip_prefix("0X")
    }) {
        Some(hex) => (hex, 16),
        None => (s, 10),
    };
    if digits.is_empty() {
        return None;
    }
    // Split the digit run from a trailing type suffix.
    let mut split = digits.len();
    for (i, c) in digits.char_indices() {
        let is_digit = c == '_'
            || (radix == 16 && c.is_ascii_hexdigit())
            || (radix == 10 && c.is_ascii_digit());
        if !is_digit {
            split = i;
            break;
        }
    }
    let (body, suffix) = digits.split_at(split);
    let suffix = suffix.strip_prefix('_').unwrap_or(suffix);
    const SUFFIXES: [&str; 12] = [
        "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32",
        "i64", "i128", "isize",
    ];
    if !suffix.is_empty() && !SUFFIXES.contains(&suffix) {
        return None;
    }
    let clean: String = body.chars().filter(|c| *c != '_').collect();
    if clean.is_empty() {
        return None;
    }
    u64::from_str_radix(&clean, radix).ok()
}

fn leading_ident(s: &str) -> Option<&str> {
    let s = s.trim_start();
    let mut end = 0;
    for (i, c) in s.char_indices() {
        if i == 0 {
            if !(c.is_ascii_alphabetic() || c == '_') {
                return None;
            }
        } else if !is_word_char(c) {
            break;
        }
        end = i + c.len_utf8();
    }
    if end == 0 {
        None
    } else {
        Some(&s[..end])
    }
}

fn is_screaming_case(s: &str) -> bool {
    s.starts_with(|c: char| c.is_ascii_uppercase())
        && s.chars().all(|c| {
            c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_'
        })
}

/// Scan one code line for `crate::` / `epsl::` module references.
fn scan_module_refs(code: &str, ln: usize, out: &mut Vec<ModuleRef>) {
    for head in ["crate::", "epsl::"] {
        for (idx, _) in code.match_indices(head) {
            if let Some(c) = code[..idx].chars().next_back() {
                // Word boundary: skips `xcrate::`; a preceding `:`
                // means a deeper path segment, which is invalid Rust
                // for `crate`/root anyway.
                if is_word_char(c) || c == ':' {
                    continue;
                }
            }
            let tail = &code[idx + head.len()..];
            if let Some(rest) = tail.strip_prefix('{') {
                // One-line `crate::{a, b::c}` group: the leading ident
                // of each comma-separated entry is a module head.
                let body = match rest.find('}') {
                    Some(close) => &rest[..close],
                    None => rest,
                };
                for entry in body.split(',') {
                    if let Some(id) = leading_ident(entry) {
                        out.push(ModuleRef {
                            line: ln,
                            module: id.to_string(),
                        });
                    }
                }
            } else if let Some(id) = leading_ident(tail) {
                out.push(ModuleRef { line: ln, module: id.to_string() });
            }
        }
    }
}

/// Scan one code line for `.fork(...)` call sites.
fn scan_forks(code: &str, ln: usize, out: &mut Vec<ForkSite>) {
    const NEEDLE: &str = ".fork(";
    for (idx, _) in code.match_indices(NEEDLE) {
        let tail = &code[idx + NEEDLE.len()..];
        // Argument text up to the matching close paren (same line; a
        // multi-line argument is classified as threaded from what is
        // visible, which errs toward reporting at the upstream site).
        let mut depth = 0usize;
        let mut end = tail.len();
        for (i, c) in tail.char_indices() {
            match c {
                '(' => depth += 1,
                ')' => {
                    if depth == 0 {
                        end = i;
                        break;
                    }
                    depth -= 1;
                }
                _ => {}
            }
        }
        let text = tail[..end].trim().to_string();
        let arg = if let Some(value) = parse_int_literal(&text) {
            ForkArg::Literal { value, text }
        } else {
            let last = text.rsplit("::").next().unwrap_or("").trim();
            let path_like = text
                .split("::")
                .all(|seg| leading_ident(seg).map(|id| id.len())
                    == Some(seg.trim().len()) && !seg.trim().is_empty());
            if path_like && is_screaming_case(last) {
                ForkArg::Named { name: last.to_string(), text }
            } else {
                ForkArg::Threaded { text }
            }
        };
        out.push(ForkSite { line: ln, arg });
    }
}

/// Scan one code line for standalone integer literals ≥
/// [`MIN_TAG_VALUE`].
fn scan_int_lits(code: &str, ln: usize, out: &mut Vec<IntLit>) {
    let bytes = code.as_bytes();
    let mut i = 0usize;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if !c.is_ascii_digit() {
            i += 1;
            continue;
        }
        if i > 0 && is_word_char(bytes[i - 1] as char) {
            // Digit inside an identifier (`cut2`, `0xFEA7`'s tail once
            // the head was consumed below).
            i += 1;
            while i < bytes.len() && is_word_char(bytes[i] as char) {
                i += 1;
            }
            continue;
        }
        // Take the whole word-character run as the literal candidate.
        let start = i;
        while i < bytes.len() && is_word_char(bytes[i] as char) {
            i += 1;
        }
        // A following `.` or exponent marks a float, not an int.
        if i < bytes.len() && bytes[i] == b'.' {
            // Skip the fractional part too.
            i += 1;
            while i < bytes.len() && is_word_char(bytes[i] as char) {
                i += 1;
            }
            continue;
        }
        if let Some(value) = parse_int_literal(&code[start..i]) {
            if value >= MIN_TAG_VALUE {
                out.push(IntLit { line: ln, value });
            }
        }
    }
}

/// Run all item scans over a lexed file.
pub fn scan_items(lines: &[LineView]) -> FileItems {
    let mut items = FileItems::default();
    for (ix, line) in lines.iter().enumerate() {
        let ln = ix + 1;
        scan_module_refs(&line.code, ln, &mut items.module_refs);
        scan_forks(&line.code, ln, &mut items.forks);
        scan_int_lits(&line.code, ln, &mut items.int_lits);
    }
    items
}

/// One `pub const NAME: u64 = <value>;` declaration inside the
/// registry module.
#[derive(Debug, Clone)]
pub struct StreamDef {
    pub name: String,
    pub value: u64,
    /// 1-based line number in the registry source file.
    pub line: usize,
}

/// The parsed central tag registry (`pub mod streams` in
/// `util/rng.rs`).
#[derive(Debug, Clone, Default)]
pub struct StreamRegistry {
    pub defs: Vec<StreamDef>,
    /// Constant names listed in the `ALL` mirror array (the operand of
    /// the compile-time uniqueness assert).
    pub all_names: Vec<String>,
    /// Line of the `mod streams` declaration, if found.
    pub mod_line: Option<usize>,
}

impl StreamRegistry {
    /// Parse the registry out of `util/rng.rs` source text. Absent or
    /// empty `mod streams` yields an empty registry (R8's
    /// name-resolution checks then report every named tag as
    /// unregistered, the safe direction).
    pub fn parse(text: &str) -> StreamRegistry {
        let lines = lex(text);
        let mut reg = StreamRegistry::default();
        let mut depth: i64 = 0;
        let mut region: Option<i64> = None;
        let mut pending = false;
        let mut in_all = false;
        for (ix, line) in lines.iter().enumerate() {
            let ln = ix + 1;
            let code = &line.code;
            if region.is_none()
                && code.contains("mod streams")
                && reg.mod_line.is_none()
            {
                pending = true;
                reg.mod_line = Some(ln);
            }
            if region.is_some() {
                if in_all {
                    in_all = !Self::collect_all_names(code, &mut reg);
                } else if code.contains("const ALL") {
                    if let Some(eq) = code.find('=') {
                        in_all =
                            !Self::collect_all_names(&code[eq + 1..], &mut reg);
                    }
                } else {
                    Self::collect_const(code, ln, &mut reg);
                }
            }
            for c in code.chars() {
                if c == '{' {
                    if pending {
                        region = Some(depth);
                        pending = false;
                    }
                    depth += 1;
                } else if c == '}' {
                    depth -= 1;
                    if region == Some(depth) {
                        region = None;
                        in_all = false;
                    }
                }
            }
        }
        reg
    }

    /// Pull SCREAMING_CASE idents out of (part of) an `ALL` initializer
    /// line. Returns `true` when the closing `]` was seen.
    fn collect_all_names(code: &str, reg: &mut StreamRegistry) -> bool {
        let body = match code.find(']') {
            Some(close) => &code[..close],
            None => code,
        };
        let mut word = String::new();
        for c in body.chars().chain(std::iter::once(' ')) {
            if is_word_char(c) {
                word.push(c);
            } else {
                if is_screaming_case(&word) && word != "ALL" {
                    reg.all_names.push(std::mem::take(&mut word));
                }
                word.clear();
            }
        }
        code.contains(']')
    }

    /// Parse one `pub const NAME: u64 = <int>;` declaration, if the
    /// line holds one.
    fn collect_const(code: &str, ln: usize, reg: &mut StreamRegistry) {
        const KEY: &str = "const ";
        let idx = match code.find(KEY) {
            Some(i) => i,
            None => return,
        };
        let name = match leading_ident(&code[idx + KEY.len()..]) {
            Some(id) => id.to_string(),
            None => return,
        };
        let eq = match code.find('=') {
            Some(e) => e,
            None => return,
        };
        let rhs = code[eq + 1..].trim().trim_end_matches(';').trim();
        if let Some(value) = parse_int_literal(rhs) {
            reg.defs.push(StreamDef { name, value, line: ln });
        }
    }

    /// Is `name` a registered stream constant?
    pub fn contains(&self, name: &str) -> bool {
        self.defs.iter().any(|d| d.name == name)
    }

    /// Names registered for `value` (normally zero or one).
    pub fn names_of(&self, value: u64) -> Vec<&str> {
        self.defs
            .iter()
            .filter(|d| d.value == value)
            .map(|d| d.name.as_str())
            .collect()
    }

    /// Pairs of constants sharing one tag value — the duplicate-fork
    /// bug class R8 exists to deny.
    pub fn duplicate_values(&self) -> Vec<(StreamDef, StreamDef)> {
        let mut out = Vec::new();
        for (i, a) in self.defs.iter().enumerate() {
            for b in &self.defs[i + 1..] {
                if a.value == b.value {
                    out.push((a.clone(), b.clone()));
                }
            }
        }
        out
    }

    /// Constants below the [`MIN_TAG_VALUE`] floor (raw-value collision
    /// detection needs tags out of the small-literal range).
    pub fn low_values(&self) -> Vec<StreamDef> {
        self.defs
            .iter()
            .filter(|d| d.value < MIN_TAG_VALUE)
            .cloned()
            .collect()
    }

    /// Registered constants missing from the `ALL` mirror, and `ALL`
    /// entries naming no registered constant — either desynchronizes
    /// the compile-time uniqueness assert from the real registry.
    pub fn mirror_mismatch(&self) -> Vec<String> {
        let mut out = Vec::new();
        for d in &self.defs {
            if !self.all_names.iter().any(|n| *n == d.name) {
                out.push(format!("{} missing from streams::ALL", d.name));
            }
        }
        for n in &self.all_names {
            if !self.contains(n) {
                out.push(format!(
                    "streams::ALL entry {n} names no registered constant"
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn items(src: &str) -> FileItems {
        scan_items(&lex(src))
    }

    #[test]
    fn int_literal_forms() {
        assert_eq!(parse_int_literal("0xFEA7"), Some(0xFEA7));
        assert_eq!(parse_int_literal("0xFE_A7"), Some(0xFEA7));
        assert_eq!(parse_int_literal("65191"), Some(65191));
        assert_eq!(parse_int_literal("0xFEA7u64"), Some(0xFEA7));
        assert_eq!(parse_int_literal("4096_usize"), Some(4096));
        assert_eq!(parse_int_literal("1e6"), None);
        assert_eq!(parse_int_literal("3.14"), None);
        assert_eq!(parse_int_literal("0xZZ"), None);
        assert_eq!(parse_int_literal("seed"), None);
        assert_eq!(parse_int_literal(""), None);
    }

    #[test]
    fn module_refs_use_and_inline_and_group() {
        let it = items(
            "use crate::experiments::sweep;\n\
             fn f() { crate::timeline::Mode::parse(s); }\n\
             use crate::{util, optim};\n\
             use epsl::coordinator::train;\n",
        );
        let mods: Vec<&str> =
            it.module_refs.iter().map(|m| m.module.as_str()).collect();
        assert_eq!(
            mods,
            vec!["experiments", "timeline", "util", "optim", "coordinator"]
        );
        assert_eq!(it.module_refs[1].line, 2);
    }

    #[test]
    fn module_refs_skip_strings_and_words() {
        let it = items(
            "let s = \"crate::experiments\";\n\
             let xcrate__ = 1; // crate::optim in a comment\n",
        );
        assert!(it.module_refs.is_empty());
    }

    #[test]
    fn fork_sites_classified() {
        let it = items(
            "let a = rng.fork(0xFEA7);\n\
             let b = rng.fork(streams::SCENARIO_DYNAMICS);\n\
             let c = b.fork(tag);\n\
             let d = rng.fork(seed ^ 3);\n",
        );
        assert_eq!(it.forks.len(), 4);
        assert_eq!(
            it.forks[0].arg,
            ForkArg::Literal { value: 0xFEA7, text: "0xFEA7".into() }
        );
        assert_eq!(
            it.forks[1].arg,
            ForkArg::Named {
                name: "SCENARIO_DYNAMICS".into(),
                text: "streams::SCENARIO_DYNAMICS".into()
            }
        );
        assert!(matches!(it.forks[2].arg, ForkArg::Threaded { .. }));
        assert!(matches!(it.forks[3].arg, ForkArg::Threaded { .. }));
    }

    #[test]
    fn int_lits_collect_large_only() {
        let it = items(
            "let a = 0xFEA7; let b = 7; let c = 4096; let d = 65191.0;\n\
             let e = x2_5000; let f = arr[50219];\n",
        );
        let vals: Vec<u64> = it.int_lits.iter().map(|l| l.value).collect();
        assert_eq!(vals, vec![0xFEA7, 4096, 50219]);
    }

    #[test]
    fn registry_parse_roundtrip() {
        let src = "\
pub mod streams {\n\
    /// Scenario base stream.\n\
    pub const SCENARIO_DYNAMICS: u64 = 0xFEA7;\n\
    pub const FAULT_PLAN: u64 = 0xFA17;\n\
    pub const ALL: [u64; 2] = [SCENARIO_DYNAMICS, FAULT_PLAN];\n\
}\n\
pub const OUTSIDE: u64 = 0xBEEF;\n";
        let reg = StreamRegistry::parse(src);
        assert_eq!(reg.defs.len(), 2);
        assert!(reg.contains("SCENARIO_DYNAMICS"));
        assert!(reg.contains("FAULT_PLAN"));
        assert!(!reg.contains("OUTSIDE"));
        assert_eq!(reg.names_of(0xFA17), vec!["FAULT_PLAN"]);
        assert_eq!(reg.all_names, vec!["SCENARIO_DYNAMICS", "FAULT_PLAN"]);
        assert!(reg.duplicate_values().is_empty());
        assert!(reg.low_values().is_empty());
        assert!(reg.mirror_mismatch().is_empty());
    }

    #[test]
    fn registry_detects_duplicates_low_values_and_mirror_drift() {
        let src = "\
pub mod streams {\n\
    pub const A_STREAM: u64 = 0xFEA7;\n\
    pub const B_STREAM: u64 = 0xFEA7;\n\
    pub const C_LOW: u64 = 0x7;\n\
    pub const ALL: [u64; 2] = [A_STREAM, B_STREAM];\n\
}\n";
        let reg = StreamRegistry::parse(src);
        assert_eq!(reg.defs.len(), 3);
        let dups = reg.duplicate_values();
        assert_eq!(dups.len(), 1);
        assert_eq!(dups[0].0.name, "A_STREAM");
        assert_eq!(dups[0].1.name, "B_STREAM");
        assert_eq!(reg.low_values().len(), 1);
        // C_LOW is registered but missing from ALL.
        assert_eq!(reg.mirror_mismatch().len(), 1);
    }

    #[test]
    fn registry_multi_line_all_array() {
        let src = "\
pub mod streams {\n\
    pub const A_STREAM: u64 = 0x1001;\n\
    pub const B_STREAM: u64 = 0x1002;\n\
    pub const ALL: [u64; 2] = [\n\
        A_STREAM,\n\
        B_STREAM,\n\
    ];\n\
}\n";
        let reg = StreamRegistry::parse(src);
        assert_eq!(reg.all_names, vec!["A_STREAM", "B_STREAM"]);
        assert!(reg.mirror_mismatch().is_empty());
    }

    #[test]
    fn registry_absent_mod_is_empty() {
        let reg = StreamRegistry::parse("pub const X: u64 = 0xFEA7;\n");
        assert!(reg.defs.is_empty());
        assert!(reg.mod_line.is_none());
    }
}
