//! The audit rule catalogue (R1–R9) and the token matchers for the
//! line-level rules.
//!
//! R1–R6 are token rules: each is a small set of patterns matched
//! against the comment-and-literal-stripped *code* channel of a line
//! (see [`super::lexer`]). Where a rule applies is decided by the
//! engine ([`super::engine`]) from the file's repo-relative path; this
//! module only answers "does this code line contain the forbidden
//! token".
//!
//! R7–R9 are *item-level* rules — module layering, RNG-stream lineage,
//! and stale-suppression detection. Their matching lives in
//! [`super::items`] (the item scanner) and [`super::engine`] (the
//! checks); they share this catalogue for ids, names, severities, and
//! `audit:allow` suppression.

use std::fmt;

/// Identifier of one audit rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RuleId {
    /// No `unwrap()` / `expect()` / `panic!` / `todo!` /
    /// `unimplemented!` in non-test library code.
    R1,
    /// No `HashMap` / `HashSet` state in deterministic modules —
    /// iteration order must come from `BTreeMap` or an explicit sort.
    R2,
    /// No `std::time::{Instant, SystemTime}` outside the benchmarking
    /// harness and the driver's wall-clock stats.
    R3,
    /// No ambient entropy (`thread_rng`, `from_entropy`,
    /// `RandomState`, …) — all randomness forks from `util::rng`
    /// named streams.
    R4,
    /// No `mul_add` / fast-math contractions and no ad-hoc threading
    /// (`std::thread`, `.par_*`, rayon) outside `util::par`.
    R5,
    /// Flag narrowing `as` casts in config / checkpoint parsing.
    R6,
    /// Module layering: `crate::`/`epsl::` references must point
    /// strictly *down* the module DAG (no back- or sideways edges).
    R7,
    /// RNG-stream lineage: every `Rng::fork` tag is a named constant
    /// registered in `util::rng::streams`, with unique values.
    R8,
    /// Stale suppression: an `audit:allow` directive whose rule no
    /// longer fires on its target line is itself a finding.
    R9,
}

impl RuleId {
    /// Every rule, in report order.
    pub const ALL: [RuleId; 9] = [
        RuleId::R1,
        RuleId::R2,
        RuleId::R3,
        RuleId::R4,
        RuleId::R5,
        RuleId::R6,
        RuleId::R7,
        RuleId::R8,
        RuleId::R9,
    ];

    /// Short mnemonic used in reports next to the id.
    pub fn name(self) -> &'static str {
        match self {
            RuleId::R1 => "no-panic",
            RuleId::R2 => "hash-order",
            RuleId::R3 => "wall-clock",
            RuleId::R4 => "ambient-entropy",
            RuleId::R5 => "fast-math-threading",
            RuleId::R6 => "trunc-cast",
            RuleId::R7 => "layering",
            RuleId::R8 => "rng-lineage",
            RuleId::R9 => "stale-allow",
        }
    }

    /// One-line rationale, shown by `epsl-audit --help`.
    pub fn summary(self) -> &'static str {
        match self {
            RuleId::R1 => {
                "library code returns typed Errors instead of panicking"
            }
            RuleId::R2 => {
                "deterministic modules must not iterate hash-ordered maps"
            }
            RuleId::R3 => {
                "simulated-latency paths must never read the host clock"
            }
            RuleId::R4 => {
                "all randomness forks from seed-pure util::rng streams"
            }
            RuleId::R5 => {
                "no FP contraction, and threading only via util::par"
            }
            RuleId::R6 => {
                "narrowing casts in config/checkpoint parsing need review"
            }
            RuleId::R7 => {
                "module references must follow the layering DAG downward"
            }
            RuleId::R8 => {
                "fork tags are unique named util::rng::streams constants"
            }
            RuleId::R9 => {
                "a suppression whose rule no longer fires must be deleted"
            }
        }
    }

    /// Parse `"R1"`..`"R9"`.
    pub fn parse(s: &str) -> Option<RuleId> {
        match s {
            "R1" => Some(RuleId::R1),
            "R2" => Some(RuleId::R2),
            "R3" => Some(RuleId::R3),
            "R4" => Some(RuleId::R4),
            "R5" => Some(RuleId::R5),
            "R6" => Some(RuleId::R6),
            "R7" => Some(RuleId::R7),
            "R8" => Some(RuleId::R8),
            "R9" => Some(RuleId::R9),
            _ => None,
        }
    }
}

impl fmt::Display for RuleId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            RuleId::R1 => "R1",
            RuleId::R2 => "R2",
            RuleId::R3 => "R3",
            RuleId::R4 => "R4",
            RuleId::R5 => "R5",
            RuleId::R6 => "R6",
            RuleId::R7 => "R7",
            RuleId::R8 => "R8",
            RuleId::R9 => "R9",
        };
        f.write_str(s)
    }
}

pub(crate) fn is_word_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Collect occurrences of `needle` in `code`, optionally requiring a
/// non-identifier character (or line edge) before / after the match.
fn hits(
    code: &str,
    needle: &str,
    bound_start: bool,
    bound_end: bool,
    out: &mut Vec<String>,
) {
    for (idx, _) in code.match_indices(needle) {
        if bound_start {
            if let Some(c) = code[..idx].chars().next_back() {
                if is_word_char(c) {
                    continue;
                }
            }
        }
        if bound_end {
            if let Some(c) = code[idx + needle.len()..].chars().next() {
                if is_word_char(c) {
                    continue;
                }
            }
        }
        out.push(needle.to_string());
    }
}

/// `.par_` followed by a lowercase identifier character — a rayon-style
/// parallel-iterator call.
fn par_hits(code: &str, out: &mut Vec<String>) {
    for (idx, _) in code.match_indices(".par_") {
        let tail = &code[idx + ".par_".len()..];
        if tail
            .chars()
            .next()
            .map(|c| c.is_ascii_lowercase() || c == '_')
            .unwrap_or(false)
        {
            let word: String = tail
                .chars()
                .take_while(|c| is_word_char(*c))
                .collect();
            out.push(format!(".par_{word}"));
        }
    }
}

/// Word-bounded `as` followed by a narrowing integer type.
fn cast_hits(code: &str, out: &mut Vec<String>) {
    const NARROW: [&str; 7] = ["u8", "u16", "u32", "i8", "i16", "i32", "usize"];
    for (idx, _) in code.match_indices("as") {
        if let Some(c) = code[..idx].chars().next_back() {
            if is_word_char(c) {
                continue;
            }
        }
        let tail = &code[idx + 2..];
        if !tail.starts_with(|c: char| c.is_ascii_whitespace()) {
            continue;
        }
        let word: String = tail
            .trim_start()
            .chars()
            .take_while(|c| is_word_char(*c))
            .collect();
        if NARROW.contains(&word.as_str()) {
            out.push(format!("as {word}"));
        }
    }
}

/// All pattern matches of `rule` on one stripped code line. Returns the
/// matched token text, one entry per occurrence.
pub fn scan_rule(rule: RuleId, code: &str) -> Vec<String> {
    let mut out = Vec::new();
    match rule {
        RuleId::R1 => {
            hits(code, ".unwrap()", false, false, &mut out);
            hits(code, ".expect(", false, false, &mut out);
            hits(code, "panic!", true, false, &mut out);
            hits(code, "todo!", true, false, &mut out);
            hits(code, "unimplemented!", true, false, &mut out);
        }
        RuleId::R2 => {
            hits(code, "HashMap", true, true, &mut out);
            hits(code, "HashSet", true, true, &mut out);
            hits(code, "hash_map", true, true, &mut out);
            hits(code, "hash_set", true, true, &mut out);
        }
        RuleId::R3 => {
            hits(code, "Instant", true, true, &mut out);
            hits(code, "SystemTime", true, true, &mut out);
        }
        RuleId::R4 => {
            hits(code, "thread_rng", true, true, &mut out);
            hits(code, "from_entropy", true, true, &mut out);
            hits(code, "RandomState", true, true, &mut out);
            hits(code, "OsRng", true, true, &mut out);
            hits(code, "getrandom", true, true, &mut out);
        }
        RuleId::R5 => {
            hits(code, "mul_add", true, true, &mut out);
            par_hits(code, &mut out);
            hits(code, "rayon", true, true, &mut out);
            hits(code, "std::thread", true, true, &mut out);
            hits(code, "thread::spawn", true, true, &mut out);
            hits(code, "thread::scope", true, true, &mut out);
        }
        RuleId::R6 => {
            cast_hits(code, &mut out);
        }
        // Item-level rules: matched by the engine over `items` scans,
        // never by per-line token patterns.
        RuleId::R7 | RuleId::R8 | RuleId::R9 => {}
    }
    out
}

/// Parse every well-formed `audit:allow(R<n>, "reason")` directive in a
/// comment channel. Malformed directives (unknown rule, missing or
/// empty reason) are ignored, which means the underlying finding still
/// surfaces — the safe failure mode.
pub fn scan_allows(comment: &str) -> Vec<(RuleId, String)> {
    const KEY: &str = "audit:allow(";
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find(KEY) {
        let body = &rest[pos + KEY.len()..];
        rest = body;
        let comma = match body.find(',') {
            Some(c) => c,
            None => continue,
        };
        let rule = match RuleId::parse(body[..comma].trim()) {
            Some(r) => r,
            None => continue,
        };
        let after = body[comma + 1..].trim_start();
        let quoted = match after.strip_prefix('"') {
            Some(q) => q,
            None => continue,
        };
        let endq = match quoted.find('"') {
            Some(e) => e,
            None => continue,
        };
        let reason = quoted[..endq].trim();
        if reason.is_empty() {
            continue;
        }
        if !quoted[endq + 1..].trim_start().starts_with(')') {
            continue;
        }
        out.push((rule, reason.to_string()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r1_matches_exact_calls_only() {
        assert_eq!(scan_rule(RuleId::R1, "x.unwrap();").len(), 1);
        assert_eq!(scan_rule(RuleId::R1, "x.unwrap_or(0);").len(), 0);
        assert_eq!(scan_rule(RuleId::R1, "x.unwrap_or_else(f);").len(), 0);
        assert_eq!(scan_rule(RuleId::R1, "x.expect(m);").len(), 1);
        assert_eq!(scan_rule(RuleId::R1, "x.expect_err(m);").len(), 0);
        assert_eq!(scan_rule(RuleId::R1, "panic!(m);").len(), 1);
        assert_eq!(scan_rule(RuleId::R1, "no_panic!(m);").len(), 0);
        assert_eq!(scan_rule(RuleId::R1, "todo!()").len(), 1);
        assert_eq!(scan_rule(RuleId::R1, "unimplemented!()").len(), 1);
    }

    #[test]
    fn r2_word_bounded() {
        assert_eq!(scan_rule(RuleId::R2, "let m: HashMap<K, V>;").len(), 1);
        assert_eq!(scan_rule(RuleId::R2, "let m = MyHashMapish;").len(), 0);
        assert_eq!(scan_rule(RuleId::R2, "use x::hash_map::Entry;").len(), 1);
    }

    #[test]
    fn r5_patterns() {
        assert_eq!(scan_rule(RuleId::R5, "a.mul_add(b, c)").len(), 1);
        assert_eq!(scan_rule(RuleId::R5, "v.par_iter().sum()").len(), 1);
        assert_eq!(scan_rule(RuleId::R5, "v.particle()").len(), 0);
        assert_eq!(scan_rule(RuleId::R5, "std::thread::spawn(f)").len(), 2);
        assert_eq!(scan_rule(RuleId::R5, "my_thread::spawnish()").len(), 0);
    }

    #[test]
    fn r6_narrowing_casts() {
        assert_eq!(scan_rule(RuleId::R6, "x as u32"), vec!["as u32"]);
        assert_eq!(scan_rule(RuleId::R6, "x as usize"), vec!["as usize"]);
        assert!(scan_rule(RuleId::R6, "x as u64").is_empty());
        assert!(scan_rule(RuleId::R6, "x as f64").is_empty());
        assert!(scan_rule(RuleId::R6, "alias u32").is_empty());
    }

    #[test]
    fn allow_directives() {
        let got = scan_allows(r#" audit:allow(R1, "checked above") "#);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0, RuleId::R1);
        assert_eq!(got[0].1, "checked above");
        // Malformed: unknown rule, empty reason, missing quote.
        assert!(scan_allows(r#" audit:allow(R12, "x") "#).is_empty());
        assert!(scan_allows(r#" audit:allow(R1, "") "#).is_empty());
        assert!(scan_allows(" audit:allow(R1, reason) ").is_empty());
    }
}
