//! Reporting layers above the audit engine: the baseline ratchet and
//! SARIF-style machine-readable output.
//!
//! **Baseline ratchet.** A baseline file freezes today's findings as a
//! multiset of [`Finding::baseline_key`]s (`path|rule|token`) with
//! counts. Findings covered by the baseline are demoted to advisory;
//! anything *fresh* keeps its normal severity. The key deliberately
//! omits the line number, so unrelated edits that shift a baselined
//! finding do not break the build — but an additional violation of the
//! same rule/token in the same file exceeds the baselined count and is
//! fresh. Fixing a finding and regenerating (`--write-baseline`)
//! shrinks the file monotonically: that is the ratchet.
//!
//! **SARIF.** `--sarif` emits a minimal SARIF 2.1.0 log (single run,
//! one `rule` per [`RuleId`], one `result` per finding) for CI
//! annotation tooling; it is output-only, nothing here parses SARIF.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::util::json::Json;

use super::engine::{severity, Finding, Severity};
use super::rules::RuleId;

/// A frozen finding multiset: `path|rule|token` → occurrence count.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct Baseline {
    pub entries: BTreeMap<String, usize>,
}

impl Baseline {
    /// Freeze a set of findings.
    pub fn from_findings(findings: &[Finding]) -> Baseline {
        let mut entries: BTreeMap<String, usize> = BTreeMap::new();
        for f in findings {
            *entries.entry(f.baseline_key()).or_insert(0) += 1;
        }
        Baseline { entries }
    }

    /// Parse a baseline file (the JSON written by [`Baseline::to_json`]).
    pub fn parse(text: &str) -> Result<Baseline> {
        let json = Json::parse(text)?;
        let obj = json
            .req("findings")?
            .as_obj()
            .ok_or_else(|| Error::Io("baseline: findings not an object".into()))?;
        let mut entries = BTreeMap::new();
        for (key, v) in obj {
            let n = v.as_usize().ok_or_else(|| {
                Error::Io(format!("baseline: count for {key} not an integer"))
            })?;
            entries.insert(key.clone(), n);
        }
        Ok(Baseline { entries })
    }

    /// Serialize for `--write-baseline`.
    pub fn to_json(&self) -> Json {
        let mut counts = BTreeMap::new();
        for (k, n) in &self.entries {
            counts.insert(k.clone(), Json::Num(*n as f64));
        }
        let mut root = BTreeMap::new();
        root.insert("version".to_string(), Json::Num(1.0));
        root.insert("findings".to_string(), Json::Obj(counts));
        Json::Obj(root)
    }

    /// Split `findings` into (baselined, fresh). Each baseline entry
    /// absorbs at most its recorded count, in finding order; the
    /// overflow — and every unlisted key — is fresh.
    pub fn partition(
        &self,
        findings: &[Finding],
    ) -> (Vec<Finding>, Vec<Finding>) {
        let mut budget = self.entries.clone();
        let mut baselined = Vec::new();
        let mut fresh = Vec::new();
        for f in findings {
            match budget.get_mut(&f.baseline_key()) {
                Some(n) if *n > 0 => {
                    *n -= 1;
                    baselined.push(f.clone());
                }
                _ => fresh.push(f.clone()),
            }
        }
        (baselined, fresh)
    }
}

fn sarif_level(sev: Severity) -> &'static str {
    match sev {
        Severity::Deny => "error",
        Severity::Warn => "warning",
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
    )
}

/// Render findings as a SARIF 2.1.0 log. `baselined` findings get
/// `level: "warning"` regardless of rule severity; the rest follow
/// [`severity`] under `deny_all`.
pub fn to_sarif(
    fresh: &[Finding],
    baselined: &[Finding],
    deny_all: bool,
) -> Json {
    let rules: Vec<Json> = RuleId::ALL
        .iter()
        .map(|r| {
            obj(vec![
                ("id", Json::Str(format!("{r}"))),
                ("name", Json::Str(r.name().to_string())),
                (
                    "shortDescription",
                    obj(vec![("text", Json::Str(r.summary().to_string()))]),
                ),
            ])
        })
        .collect();
    let mut results: Vec<Json> = Vec::new();
    for (set, demoted) in [(fresh, false), (baselined, true)] {
        for f in set {
            let level = if demoted {
                "warning"
            } else {
                sarif_level(severity(f.rule, deny_all))
            };
            let mut props = vec![("baselined", Json::Bool(demoted))];
            props.push(("token", Json::Str(f.token.clone())));
            results.push(obj(vec![
                ("ruleId", Json::Str(format!("{}", f.rule))),
                ("level", Json::Str(level.to_string())),
                ("message", obj(vec![(
                    "text",
                    Json::Str(format!(
                        "{} [{}] {}",
                        f.rule.name(),
                        f.token,
                        f.snippet
                    )),
                )])),
                ("locations", Json::Arr(vec![obj(vec![(
                    "physicalLocation",
                    obj(vec![
                        (
                            "artifactLocation",
                            obj(vec![("uri", Json::Str(f.path.clone()))]),
                        ),
                        (
                            "region",
                            obj(vec![(
                                "startLine",
                                Json::Num(f.line as f64),
                            )]),
                        ),
                    ]),
                )])])),
                ("properties", obj(props)),
            ]));
        }
    }
    let tool = obj(vec![("driver", obj(vec![
        ("name", Json::Str("epsl-audit".to_string())),
        ("rules", Json::Arr(rules)),
    ]))]);
    obj(vec![
        ("version", Json::Str("2.1.0".to_string())),
        (
            "$schema",
            Json::Str(
                "https://json.schemastore.org/sarif-2.1.0.json".to_string(),
            ),
        ),
        ("runs", Json::Arr(vec![obj(vec![
            ("tool", tool),
            ("results", Json::Arr(results)),
        ])])),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(path: &str, line: usize, rule: RuleId, token: &str) -> Finding {
        Finding {
            path: path.to_string(),
            line,
            rule,
            token: token.to_string(),
            snippet: "let x = 1;".to_string(),
        }
    }

    #[test]
    fn baseline_roundtrip() {
        let fs = vec![
            finding("a.rs", 3, RuleId::R1, ".unwrap()"),
            finding("a.rs", 9, RuleId::R1, ".unwrap()"),
            finding("b.rs", 1, RuleId::R7, "crate::coordinator"),
        ];
        let base = Baseline::from_findings(&fs);
        assert_eq!(base.entries.len(), 2);
        assert_eq!(base.entries["a.rs|R1|.unwrap()"], 2);
        let text = base.to_json().to_string_pretty();
        let back = Baseline::parse(&text).unwrap();
        assert_eq!(back, base);
    }

    #[test]
    fn partition_absorbs_counts_and_flags_overflow() {
        let old = vec![finding("a.rs", 3, RuleId::R1, ".unwrap()")];
        let base = Baseline::from_findings(&old);
        // Same key twice: one absorbed, one fresh. New key: fresh.
        let now = vec![
            finding("a.rs", 3, RuleId::R1, ".unwrap()"),
            finding("a.rs", 40, RuleId::R1, ".unwrap()"),
            finding("b.rs", 1, RuleId::R8, ".fork(0xFEA7)"),
        ];
        let (baselined, fresh) = base.partition(&now);
        assert_eq!(baselined.len(), 1);
        assert_eq!(fresh.len(), 2);
        assert_eq!(fresh[1].rule, RuleId::R8);
    }

    #[test]
    fn partition_line_drift_still_baselined() {
        let base = Baseline::from_findings(&[finding(
            "a.rs",
            3,
            RuleId::R6,
            "as u32",
        )]);
        let (baselined, fresh) =
            base.partition(&[finding("a.rs", 117, RuleId::R6, "as u32")]);
        assert_eq!(baselined.len(), 1);
        assert!(fresh.is_empty());
    }

    #[test]
    fn sarif_shape_and_levels() {
        let fresh = vec![finding("a.rs", 3, RuleId::R7, "crate::experiments")];
        let baselined = vec![finding("b.rs", 5, RuleId::R6, "as u32")];
        let sarif = to_sarif(&fresh, &baselined, false);
        let text = sarif.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.req("version").unwrap().as_str(), Some("2.1.0"));
        let runs = parsed.req("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 1);
        let results = runs[0].req("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[0].req("level").unwrap().as_str(),
            Some("error")
        );
        assert_eq!(
            results[1].req("level").unwrap().as_str(),
            Some("warning")
        );
        let rules = runs[0]
            .req("tool")
            .unwrap()
            .req("driver")
            .unwrap()
            .req("rules")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(rules.len(), RuleId::ALL.len());
    }
}
