//! The audit engine: rule scoping by path, test-region tracking,
//! suppression handling, and the deterministic tree walk.
//!
//! Scope model (all paths repo-root-relative, `/`-separated):
//!
//! * **R1** applies to `rust/src/**` outside `#[cfg(test)]` / `#[test]`
//!   regions — benches, integration tests, and examples may panic.
//! * **R2** applies to the deterministic modules: `optim`, `timeline`,
//!   `coordinator`, `scenario`, and `runtime/native`.
//! * **R3** applies to `rust/src/**` except `util/bench.rs` (the
//!   measurement harness) and `coordinator/driver.rs` (wall-clock
//!   stats reported next to, never mixed into, simulated latency).
//! * **R4** applies everywhere.
//! * **R5** applies everywhere except `util/par.rs`, the one sanctioned
//!   threading home.
//! * **R6** applies to `rust/src/config/**` and
//!   `rust/src/coordinator/checkpoint.rs` — the parsing layers where a
//!   silent narrowing cast corrupts a run instead of crashing it.
//!
//! Test regions are tracked by brace depth: a line containing
//! `cfg(test)` or `#[test]` marks the next opened brace as a test
//! scope; R1 is waived until that brace closes. The test decision for
//! a line is made at its *start*, so a violation on the same line as
//! the opening `{` of a test module is still reported.

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

use super::lexer::lex;
use super::rules::{scan_allows, scan_rule, RuleId};

/// Directories walked by [`audit_tree`], relative to the repo root.
pub const WALK_ROOTS: [&str; 4] =
    ["rust/src", "rust/benches", "rust/tests", "examples"];

/// Modules whose iteration order feeds bit-exact guarantees (R2).
const DET_DIRS: [&str; 5] = [
    "rust/src/optim/",
    "rust/src/timeline/",
    "rust/src/coordinator/",
    "rust/src/scenario/",
    "rust/src/runtime/native/",
];

/// Files allowed to read the host clock (R3).
const R3_EXEMPT: [&str; 2] =
    ["rust/src/util/bench.rs", "rust/src/coordinator/driver.rs"];

/// The sanctioned threading home (R5).
const R5_EXEMPT: [&str; 1] = ["rust/src/util/par.rs"];

/// Parsing layers where narrowing casts need review (R6).
const R6_SCOPE: [&str; 2] =
    ["rust/src/config/", "rust/src/coordinator/checkpoint.rs"];

/// How a finding is treated by the reporting layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Fails the audit.
    Deny,
    /// Reported, but only fails under `--deny-all`.
    Warn,
}

/// Severity of `rule` under the given strictness. R6 findings are
/// advisory by default (a reviewed narrowing cast is sometimes the
/// right tool); `--deny-all` promotes them, and CI runs that way.
pub fn severity(rule: RuleId, deny_all: bool) -> Severity {
    if deny_all {
        return Severity::Deny;
    }
    match rule {
        RuleId::R6 => Severity::Warn,
        _ => Severity::Deny,
    }
}

/// One rule violation at one source location.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Repo-root-relative path, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: RuleId,
    /// The matched token text.
    pub token: String,
    /// The offending code line, trimmed and truncated.
    pub snippet: String,
}

/// Result of auditing one source file.
#[derive(Debug, Default)]
pub struct FileAudit {
    pub findings: Vec<Finding>,
    /// Findings silenced by a well-formed `audit:allow` directive.
    pub suppressed: usize,
}

/// Aggregate over a tree walk.
#[derive(Debug, Default)]
pub struct AuditReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    pub suppressed: usize,
}

fn applicable_rules(rel: &str, in_test: bool) -> Vec<RuleId> {
    let is_src = rel.starts_with("rust/src/");
    let mut rules = Vec::new();
    if is_src && !in_test {
        rules.push(RuleId::R1);
    }
    if DET_DIRS.iter().any(|d| rel.starts_with(d)) {
        rules.push(RuleId::R2);
    }
    if is_src && !R3_EXEMPT.contains(&rel) {
        rules.push(RuleId::R3);
    }
    rules.push(RuleId::R4);
    if !R5_EXEMPT.contains(&rel) {
        rules.push(RuleId::R5);
    }
    if R6_SCOPE.iter().any(|s| rel.starts_with(s)) {
        rules.push(RuleId::R6);
    }
    rules
}

fn snippet_of(code: &str) -> String {
    code.trim().chars().take(90).collect()
}

/// Audit one file's source text. `rel` is the repo-root-relative path
/// used for rule scoping and reporting; the text does not have to come
/// from disk, which is what the fixture tests rely on.
pub fn audit_source(rel: &str, text: &str) -> FileAudit {
    let lines = lex(text);
    let mut out = FileAudit::default();
    let mut depth: i64 = 0;
    let mut test_stack: Vec<i64> = Vec::new();
    let mut pending_test = false;
    for (ix, line) in lines.iter().enumerate() {
        let ln = ix + 1;
        let in_test = !test_stack.is_empty();
        if line.code.contains("cfg(test)") || line.code.contains("#[test]") {
            pending_test = true;
        }
        // Directives on the same line, or on an immediately preceding
        // comment-only line, suppress this line's findings.
        let mut allows: Vec<RuleId> =
            scan_allows(&line.comment).into_iter().map(|(r, _)| r).collect();
        if ix > 0 {
            let prev = &lines[ix - 1];
            if prev.code.trim().is_empty() {
                allows.extend(
                    scan_allows(&prev.comment).into_iter().map(|(r, _)| r),
                );
            }
        }
        for rule in applicable_rules(rel, in_test) {
            for token in scan_rule(rule, &line.code) {
                if allows.contains(&rule) {
                    out.suppressed += 1;
                    continue;
                }
                out.findings.push(Finding {
                    path: rel.to_string(),
                    line: ln,
                    rule,
                    token,
                    snippet: snippet_of(&line.code),
                });
            }
        }
        for c in line.code.chars() {
            if c == '{' {
                if pending_test {
                    test_stack.push(depth);
                    pending_test = false;
                }
                depth += 1;
            } else if c == '}' {
                depth -= 1;
                if test_stack.last() == Some(&depth) {
                    test_stack.pop();
                }
            }
        }
    }
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in fs::read_dir(dir)
        .map_err(|e| Error::Io(format!("read_dir {}: {e}", dir.display())))?
    {
        let entry = entry
            .map_err(|e| Error::Io(format!("read_dir {}: {e}", dir.display())))?;
        entries.push(entry.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

/// Walk the audited roots under `root` (deterministically: sorted
/// directory entries) and audit every `.rs` file.
pub fn audit_tree(root: &Path) -> Result<AuditReport> {
    let mut files: Vec<PathBuf> = Vec::new();
    for wr in WALK_ROOTS {
        let dir = root.join(wr);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    files.sort();
    let mut report = AuditReport::default();
    for path in &files {
        let text = fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("read {}: {e}", path.display())))?;
        let rel_path = path.strip_prefix(root).unwrap_or(path);
        let rel = rel_path.to_string_lossy().replace('\\', "/");
        let fa = audit_source(&rel, &text);
        report.findings.extend(fa.findings);
        report.suppressed += fa.suppressed;
        report.files_scanned += 1;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r1_waived_inside_test_modules() {
        let src = "pub fn f(x: Option<u8>) -> u8 { x.unwrap() }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn g(x: Option<u8>) -> u8 { x.unwrap() }\n\
                   }\n\
                   pub fn h(x: Option<u8>) -> u8 { x.unwrap() }\n";
        let fa = audit_source("rust/src/lib.rs", src);
        let lines: Vec<usize> = fa
            .findings
            .iter()
            .filter(|f| f.rule == RuleId::R1)
            .map(|f| f.line)
            .collect();
        assert_eq!(lines, vec![1, 6]);
    }

    #[test]
    fn r1_not_applied_outside_src() {
        let fa = audit_source("rust/tests/x.rs", "fn f() { o.unwrap(); }\n");
        assert!(fa.findings.iter().all(|f| f.rule != RuleId::R1));
    }

    #[test]
    fn same_line_allow_suppresses_and_counts() {
        let src =
            "let v = o.unwrap(); // audit:allow(R1, \"checked two lines up\")\n";
        let fa = audit_source("rust/src/lib.rs", src);
        assert!(fa.findings.is_empty());
        assert_eq!(fa.suppressed, 1);
    }

    #[test]
    fn preceding_comment_line_allow_suppresses() {
        let src = "// audit:allow(R1, \"guarded by the loop condition\")\n\
                   let v = o.unwrap();\n";
        let fa = audit_source("rust/src/lib.rs", src);
        assert!(fa.findings.is_empty());
        assert_eq!(fa.suppressed, 1);
    }

    #[test]
    fn allow_does_not_leak_past_code_lines() {
        let src = "// audit:allow(R1, \"only for the next line\")\n\
                   let a = 1;\n\
                   let v = o.unwrap();\n";
        let fa = audit_source("rust/src/lib.rs", src);
        assert_eq!(fa.findings.len(), 1);
        assert_eq!(fa.findings[0].line, 3);
    }

    #[test]
    fn wrong_rule_allow_does_not_suppress() {
        let src = "let v = o.unwrap(); // audit:allow(R2, \"wrong rule\")\n";
        let fa = audit_source("rust/src/lib.rs", src);
        assert_eq!(fa.findings.len(), 1);
        assert_eq!(fa.suppressed, 0);
    }

    #[test]
    fn scoping_r2_r3_r5_r6() {
        let hm = "use std::collections::HashMap;\n";
        assert_eq!(audit_source("rust/src/optim/x.rs", hm).findings.len(), 1);
        assert!(audit_source("rust/src/util/x.rs", hm).findings.is_empty());

        let inst = "use std::time::Instant;\n";
        assert_eq!(audit_source("rust/src/latency/x.rs", inst).findings.len(), 1);
        assert!(audit_source("rust/src/util/bench.rs", inst)
            .findings
            .is_empty());
        assert!(audit_source("rust/src/coordinator/driver.rs", inst)
            .findings
            .is_empty());

        let thr = "std::thread::spawn(f);\n";
        assert!(!audit_source("rust/src/optim/x.rs", thr).findings.is_empty());
        assert!(audit_source("rust/src/util/par.rs", thr)
            .findings
            .is_empty());

        let cast = "let n = x as u32;\n";
        assert_eq!(audit_source("rust/src/config/toml.rs", cast).findings.len(), 1);
        assert_eq!(
            audit_source("rust/src/coordinator/checkpoint.rs", cast)
                .findings
                .len(),
            1
        );
        assert!(audit_source("rust/src/optim/x.rs", cast).findings.is_empty());
    }

    #[test]
    fn r4_applies_everywhere() {
        let src = "let r = thread_rng();\n";
        for rel in [
            "rust/src/util/rng.rs",
            "rust/tests/t.rs",
            "rust/benches/b.rs",
            "examples/e.rs",
        ] {
            let fa = audit_source(rel, src);
            assert!(
                fa.findings.iter().any(|f| f.rule == RuleId::R4),
                "R4 should fire in {rel}"
            );
        }
    }

    #[test]
    fn severity_default_and_deny_all() {
        assert_eq!(severity(RuleId::R1, false), Severity::Deny);
        assert_eq!(severity(RuleId::R6, false), Severity::Warn);
        assert_eq!(severity(RuleId::R6, true), Severity::Deny);
    }

    #[test]
    fn tokens_in_strings_and_comments_do_not_fire() {
        let src = "let s = \"call .unwrap() on a HashMap\"; // Instant\n";
        let fa = audit_source("rust/src/optim/x.rs", src);
        assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    }
}
