//! The audit engine: rule scoping by path, test-region tracking,
//! suppression handling, and the deterministic tree walk.
//!
//! Scope model (all paths repo-root-relative, `/`-separated):
//!
//! * **R1** applies to `rust/src/**` outside `#[cfg(test)]` / `#[test]`
//!   regions — benches, integration tests, and examples may panic.
//! * **R2** applies to the deterministic modules: `optim`, `timeline`,
//!   `coordinator`, `scenario`, and `runtime/native`.
//! * **R3** applies to `rust/src/**` except `util/bench.rs` (the
//!   measurement harness) and `coordinator/driver.rs` (wall-clock
//!   stats reported next to, never mixed into, simulated latency).
//! * **R4** applies everywhere.
//! * **R5** applies everywhere except `util/par.rs` (the sanctioned
//!   threading home) and `runtime/native/kernels_fast.rs` (the opt-in
//!   fast math tier, whose contract is tolerance — not bit-identity —
//!   so fused `mul_add` and the threaded macro-loop are its point).
//! * **R6** applies to `rust/src/config/**` and
//!   `rust/src/coordinator/checkpoint.rs` — the parsing layers where a
//!   silent narrowing cast corrupts a run instead of crashing it.
//! * **R7** applies to `rust/src/**` *including* test regions (a
//!   test-only back-edge still couples the layers at build time);
//!   `lib.rs` is exempt, being the module root that declares every
//!   layer.
//! * **R8** applies to `rust/src/**` outside test regions. Raw
//!   integer fork tags are denied everywhere; named tags must resolve
//!   in the `util::rng::streams` registry; a registered tag *value*
//!   reappearing as a raw literal anywhere outside `util/rng.rs` is a
//!   collision-in-waiting and also denied. Auditing `util/rng.rs`
//!   itself re-parses the registry from the audited text and checks it
//!   for duplicate values, sub-`0x1000` tags, and `ALL`-mirror drift.
//! * **R9** applies everywhere a directive can appear: after the file
//!   pass, any well-formed `audit:allow` that suppressed nothing is
//!   itself a finding (stale suppression). An `allow(R9)` aimed at the
//!   directive's own line silences it; a stale `allow(R9)` is always
//!   reported — the ratchet needs a fixed point.
//!
//! Test regions are tracked by brace depth: a line containing
//! `cfg(test)` or `#[test]` marks the next opened brace as a test
//! scope; R1/R8 are waived until that brace closes. The test decision
//! for a line is made at its *start*, so a violation on the same line
//! as the opening `{` of a test module is still reported.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

use super::items::{scan_items, ForkArg, StreamRegistry};
use super::lexer::lex;
use super::rules::{scan_allows, scan_rule, RuleId};

/// Directories walked by [`audit_tree`], relative to the repo root.
pub const WALK_ROOTS: [&str; 4] =
    ["rust/src", "rust/benches", "rust/tests", "examples"];

/// Modules whose iteration order feeds bit-exact guarantees (R2).
const DET_DIRS: [&str; 5] = [
    "rust/src/optim/",
    "rust/src/timeline/",
    "rust/src/coordinator/",
    "rust/src/scenario/",
    "rust/src/runtime/native/",
];

/// Files allowed to read the host clock (R3).
const R3_EXEMPT: [&str; 2] =
    ["rust/src/util/bench.rs", "rust/src/coordinator/driver.rs"];

/// The sanctioned homes for threading / fused arithmetic (R5): the
/// thread-pool module itself, and the opt-in fast math tier whose
/// guarantee is documented tolerance rather than bit-identity.
const R5_EXEMPT: [&str; 2] = [
    "rust/src/util/par.rs",
    "rust/src/runtime/native/kernels_fast.rs",
];

/// Parsing layers where narrowing casts need review (R6).
const R6_SCOPE: [&str; 2] =
    ["rust/src/config/", "rust/src/coordinator/checkpoint.rs"];

/// Home of the `streams` tag registry; exempt from the R8
/// raw-value-collision scan (its constants *are* the values).
const RNG_PATH: &str = "rust/src/util/rng.rs";

/// The module layering DAG as a strict rank map: a reference from
/// module A to module B is legal iff `rank(B) < rank(A)` or `A == B`.
/// This refines the coarse layer diagram in `ANALYSIS.md` — modules
/// sharing a layer there get distinct ranks here reflecting their real
/// (acyclic) intra-layer order, e.g. `channel` reads `config` but never
/// the reverse.
pub const LAYER_MAP: [(&str, u32); 16] = [
    ("error", 0),
    ("util", 1),
    ("analysis", 2),
    ("config", 2),
    ("channel", 3),
    ("profile", 3),
    ("data", 3),
    ("latency", 4),
    ("optim", 5),
    ("timeline", 6),
    ("metrics", 7),
    ("scenario", 8),
    ("runtime", 9),
    ("coordinator", 10),
    ("experiments", 11),
    ("bin", 12),
];

fn rank_of(module: &str) -> Option<u32> {
    LAYER_MAP.iter().find(|(m, _)| *m == module).map(|(_, r)| *r)
}

/// The layering module a `rust/src` file belongs to, or `None` when
/// the file is out of R7 scope (`lib.rs`, or not under `rust/src`).
pub fn module_of(rel: &str) -> Option<&'static str> {
    let rest = rel.strip_prefix("rust/src/")?;
    if rest == "lib.rs" {
        return None;
    }
    if rest == "main.rs" || rest.starts_with("bin/") {
        return Some("bin");
    }
    let head = rest.split('/').next().unwrap_or(rest);
    let head = head.strip_suffix(".rs").unwrap_or(head);
    LAYER_MAP.iter().find(|(m, _)| *m == head).map(|(m, _)| *m)
}

/// Map a `crate::X` reference head to its layering module. The only
/// crate-root re-exports are `Error`/`Result` from `error`.
fn ref_module(head: &str) -> Option<&'static str> {
    if head == "Error" || head == "Result" {
        return Some("error");
    }
    LAYER_MAP.iter().find(|(m, _)| *m == head).map(|(m, _)| *m)
}

/// How a finding is treated by the reporting layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Fails the audit.
    Deny,
    /// Reported, but only fails under `--deny-all`.
    Warn,
}

/// Severity of `rule` under the given strictness. R6 findings are
/// advisory by default (a reviewed narrowing cast is sometimes the
/// right tool); `--deny-all` promotes them, and CI runs that way. The
/// semantic rules R7–R9 deny by default: a layering back-edge, an
/// unregistered fork tag, or a stale suppression is never "advisory".
pub fn severity(rule: RuleId, deny_all: bool) -> Severity {
    if deny_all {
        return Severity::Deny;
    }
    match rule {
        RuleId::R6 => Severity::Warn,
        _ => Severity::Deny,
    }
}

/// One rule violation at one source location.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Repo-root-relative path, `/`-separated.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: RuleId,
    /// The matched token text.
    pub token: String,
    /// The offending code line, trimmed and truncated.
    pub snippet: String,
}

impl Finding {
    /// The baseline identity of this finding: stable under unrelated
    /// edits (line drift), specific enough that a *new* violation of
    /// the same rule in the same file does not ride an old entry.
    pub fn baseline_key(&self) -> String {
        format!("{}|{}|{}", self.path, self.rule, self.token)
    }
}

/// Result of auditing one source file.
#[derive(Debug, Default)]
pub struct FileAudit {
    pub findings: Vec<Finding>,
    /// Findings silenced by a well-formed `audit:allow` directive.
    pub suppressed: usize,
}

/// Aggregate over a tree walk.
#[derive(Debug, Default)]
pub struct AuditReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    pub suppressed: usize,
}

impl AuditReport {
    /// Count of stale-suppression (R9) findings — the number CI pins
    /// to zero.
    pub fn stale_suppressions(&self) -> usize {
        self.findings.iter().filter(|f| f.rule == RuleId::R9).count()
    }
}

fn applicable_rules(rel: &str, in_test: bool) -> Vec<RuleId> {
    let is_src = rel.starts_with("rust/src/");
    let mut rules = Vec::new();
    if is_src && !in_test {
        rules.push(RuleId::R1);
    }
    if DET_DIRS.iter().any(|d| rel.starts_with(d)) {
        rules.push(RuleId::R2);
    }
    if is_src && !R3_EXEMPT.contains(&rel) {
        rules.push(RuleId::R3);
    }
    rules.push(RuleId::R4);
    if !R5_EXEMPT.contains(&rel) {
        rules.push(RuleId::R5);
    }
    if R6_SCOPE.iter().any(|s| rel.starts_with(s)) {
        rules.push(RuleId::R6);
    }
    rules
}

fn snippet_of(code: &str) -> String {
    code.trim().chars().take(90).collect()
}

/// One well-formed `audit:allow` directive found in the file, with the
/// line its suppression applies to and whether it ever fired.
struct Directive {
    rule: RuleId,
    /// Line the directive is written on.
    source_line: usize,
    /// Line whose findings it suppresses (own line, or the next line
    /// when the directive sits on a comment-only line).
    target_line: usize,
    used: bool,
}

/// An item-level (R7/R8) violation candidate waiting for the main
/// pass's test-region and suppression decisions.
struct ItemHit {
    rule: RuleId,
    token: String,
    /// Waived inside `#[cfg(test)]` regions (R8 is; R7 is not).
    test_waived: bool,
}

/// Audit one file's source text. `rel` is the repo-root-relative path
/// used for rule scoping and reporting; the text does not have to come
/// from disk, which is what the fixture tests rely on.
///
/// Runs with no stream registry: R8 still denies raw-literal fork
/// tags, but named-tag resolution and raw-value collision checks are
/// skipped. [`audit_tree`] and the fixture tests that exercise those
/// checks use [`audit_source_with`].
pub fn audit_source(rel: &str, text: &str) -> FileAudit {
    audit_source_with(rel, text, None)
}

/// [`audit_source`] with an explicit `util::rng::streams` registry for
/// the R8 named-tag and raw-value-collision checks.
pub fn audit_source_with(
    rel: &str,
    text: &str,
    registry: Option<&StreamRegistry>,
) -> FileAudit {
    let lines = lex(text);
    let mut out = FileAudit::default();
    let is_src = rel.starts_with("rust/src/");

    // Auditing the registry file itself re-parses the registry from
    // the audited text, so fixtures exercise the self-checks and the
    // live pass can never check rng.rs against a stale copy.
    let own_registry =
        if rel == RNG_PATH { Some(StreamRegistry::parse(text)) } else { None };
    let effective = own_registry.as_ref().or(registry);

    // Collect directives with target lines and used-flags (R9 input).
    let mut directives: Vec<Directive> = Vec::new();
    for (ix, line) in lines.iter().enumerate() {
        let ln = ix + 1;
        let target = if line.code.trim().is_empty() { ln + 1 } else { ln };
        for (rule, _) in scan_allows(&line.comment) {
            directives.push(Directive {
                rule,
                source_line: ln,
                target_line: target,
                used: false,
            });
        }
    }
    let mut by_target: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (di, d) in directives.iter().enumerate() {
        by_target.entry(d.target_line).or_default().push(di);
    }

    // Item-level (R7/R8) violation candidates, keyed by line.
    let mut item_hits: BTreeMap<usize, Vec<ItemHit>> = BTreeMap::new();
    if is_src {
        let items = scan_items(&lines);
        if let Some(own) = module_of(rel) {
            let own_rank = rank_of(own).unwrap_or(u32::MAX);
            for mr in &items.module_refs {
                let target = match ref_module(&mr.module) {
                    Some(t) => t,
                    None => continue,
                };
                if target != own && rank_of(target).unwrap_or(0) >= own_rank {
                    item_hits.entry(mr.line).or_default().push(ItemHit {
                        rule: RuleId::R7,
                        token: format!("crate::{}", mr.module),
                        test_waived: false,
                    });
                }
            }
        }
        for fork in &items.forks {
            match &fork.arg {
                ForkArg::Literal { text, .. } => {
                    item_hits.entry(fork.line).or_default().push(ItemHit {
                        rule: RuleId::R8,
                        token: format!(".fork({text})"),
                        test_waived: true,
                    });
                }
                ForkArg::Named { name, text } => {
                    if let Some(reg) = effective {
                        if !reg.contains(name) {
                            item_hits.entry(fork.line).or_default().push(
                                ItemHit {
                                    rule: RuleId::R8,
                                    token: format!(".fork({text})"),
                                    test_waived: true,
                                },
                            );
                        }
                    }
                }
                ForkArg::Threaded { .. } => {}
            }
        }
        if rel != RNG_PATH {
            if let Some(reg) = effective {
                for lit in &items.int_lits {
                    let names = reg.names_of(lit.value);
                    if let Some(name) = names.first() {
                        item_hits.entry(lit.line).or_default().push(ItemHit {
                            rule: RuleId::R8,
                            token: format!(
                                "{:#x} (= streams::{name})",
                                lit.value
                            ),
                            test_waived: true,
                        });
                    }
                }
            }
        }
        if let Some(own) = &own_registry {
            for (a, b) in own.duplicate_values() {
                item_hits.entry(b.line).or_default().push(ItemHit {
                    rule: RuleId::R8,
                    token: format!(
                        "{} duplicates {} (= {:#x})",
                        b.name, a.name, b.value
                    ),
                    test_waived: false,
                });
            }
            for d in own.low_values() {
                item_hits.entry(d.line).or_default().push(ItemHit {
                    rule: RuleId::R8,
                    token: format!("{} = {:#x} below 0x1000", d.name, d.value),
                    test_waived: false,
                });
            }
            let mod_line = own.mod_line.unwrap_or(1);
            for msg in own.mirror_mismatch() {
                item_hits.entry(mod_line).or_default().push(ItemHit {
                    rule: RuleId::R8,
                    token: msg,
                    test_waived: false,
                });
            }
        }
    }

    // Main pass: token rules + item hits, with test-region tracking
    // and suppression accounting.
    let mut depth: i64 = 0;
    let mut test_stack: Vec<i64> = Vec::new();
    let mut pending_test = false;
    for (ix, line) in lines.iter().enumerate() {
        let ln = ix + 1;
        let in_test = !test_stack.is_empty();
        if line.code.contains("cfg(test)") || line.code.contains("#[test]") {
            pending_test = true;
        }
        let dirs_here: &[usize] =
            by_target.get(&ln).map(|v| v.as_slice()).unwrap_or(&[]);
        let suppress = |rule: RuleId,
                            token: String,
                            directives: &mut Vec<Directive>,
                            out: &mut FileAudit| {
            let mut hit = false;
            for &di in dirs_here {
                if directives[di].rule == rule {
                    directives[di].used = true;
                    hit = true;
                }
            }
            if hit {
                out.suppressed += 1;
            } else {
                out.findings.push(Finding {
                    path: rel.to_string(),
                    line: ln,
                    rule,
                    token,
                    snippet: snippet_of(&line.code),
                });
            }
        };
        for rule in applicable_rules(rel, in_test) {
            for token in scan_rule(rule, &line.code) {
                suppress(rule, token, &mut directives, &mut out);
            }
        }
        if let Some(hits) = item_hits.get(&ln) {
            for hit in hits {
                if hit.test_waived && in_test {
                    continue;
                }
                suppress(hit.rule, hit.token.clone(), &mut directives, &mut out);
            }
        }
        for c in line.code.chars() {
            if c == '{' {
                if pending_test {
                    test_stack.push(depth);
                    pending_test = false;
                }
                depth += 1;
            } else if c == '}' {
                depth -= 1;
                if test_stack.last() == Some(&depth) {
                    test_stack.pop();
                }
            }
        }
    }

    // R9 pass: an unused directive is a stale suppression. Non-R9
    // directives first — each may be silenced by an `allow(R9)` whose
    // target is the stale directive's own line, which marks that R9
    // directive used. Whatever `allow(R9)`s remain unused after that
    // are themselves stale, reported unconditionally.
    let stale: Vec<usize> = directives
        .iter()
        .enumerate()
        .filter(|(_, d)| !d.used && d.rule != RuleId::R9)
        .map(|(i, _)| i)
        .collect();
    for si in stale {
        let (src_line, rule) =
            (directives[si].source_line, directives[si].rule);
        let mut silenced = false;
        if let Some(dis) = by_target.get(&src_line) {
            for &di in dis {
                if directives[di].rule == RuleId::R9 {
                    directives[di].used = true;
                    silenced = true;
                }
            }
        }
        if silenced {
            out.suppressed += 1;
        } else {
            out.findings.push(Finding {
                path: rel.to_string(),
                line: src_line,
                rule: RuleId::R9,
                token: format!("audit:allow({rule})"),
                snippet: snippet_of(
                    &lines
                        .get(src_line - 1)
                        .map(|l| {
                            format!("{}{}", l.code.trim_end(), l.comment)
                        })
                        .unwrap_or_default(),
                ),
            });
        }
    }
    for d in &directives {
        if !d.used && d.rule == RuleId::R9 {
            out.findings.push(Finding {
                path: rel.to_string(),
                line: d.source_line,
                rule: RuleId::R9,
                token: "audit:allow(R9)".to_string(),
                snippet: snippet_of(
                    &lines
                        .get(d.source_line - 1)
                        .map(|l| {
                            format!("{}{}", l.code.trim_end(), l.comment)
                        })
                        .unwrap_or_default(),
                ),
            });
        }
    }
    out.findings.sort_by(|a, b| {
        (a.line, a.rule as u32).cmp(&(b.line, b.rule as u32))
    });
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in fs::read_dir(dir)
        .map_err(|e| Error::Io(format!("read_dir {}: {e}", dir.display())))?
    {
        let entry = entry
            .map_err(|e| Error::Io(format!("read_dir {}: {e}", dir.display())))?;
        entries.push(entry.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

/// Walk the audited roots under `root` (deterministically: sorted
/// directory entries) and audit every `.rs` file. The stream registry
/// is parsed from `rust/src/util/rng.rs` first so every file's R8
/// checks see the same tag table.
pub fn audit_tree(root: &Path) -> Result<AuditReport> {
    let registry = match fs::read_to_string(root.join(RNG_PATH)) {
        Ok(text) => Some(StreamRegistry::parse(&text)),
        Err(_) => None,
    };
    let mut files: Vec<PathBuf> = Vec::new();
    for wr in WALK_ROOTS {
        let dir = root.join(wr);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    files.sort();
    let mut report = AuditReport::default();
    for path in &files {
        let text = fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("read {}: {e}", path.display())))?;
        let rel_path = path.strip_prefix(root).unwrap_or(path);
        let rel = rel_path.to_string_lossy().replace('\\', "/");
        let fa = audit_source_with(&rel, &text, registry.as_ref());
        report.findings.extend(fa.findings);
        report.suppressed += fa.suppressed;
        report.files_scanned += 1;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r1_waived_inside_test_modules() {
        let src = "pub fn f(x: Option<u8>) -> u8 { x.unwrap() }\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn g(x: Option<u8>) -> u8 { x.unwrap() }\n\
                   }\n\
                   pub fn h(x: Option<u8>) -> u8 { x.unwrap() }\n";
        let fa = audit_source("rust/src/lib.rs", src);
        let lines: Vec<usize> = fa
            .findings
            .iter()
            .filter(|f| f.rule == RuleId::R1)
            .map(|f| f.line)
            .collect();
        assert_eq!(lines, vec![1, 6]);
    }

    #[test]
    fn r1_not_applied_outside_src() {
        let fa = audit_source("rust/tests/x.rs", "fn f() { o.unwrap(); }\n");
        assert!(fa.findings.iter().all(|f| f.rule != RuleId::R1));
    }

    #[test]
    fn same_line_allow_suppresses_and_counts() {
        let src =
            "let v = o.unwrap(); // audit:allow(R1, \"checked two lines up\")\n";
        let fa = audit_source("rust/src/lib.rs", src);
        assert!(fa.findings.is_empty());
        assert_eq!(fa.suppressed, 1);
    }

    #[test]
    fn preceding_comment_line_allow_suppresses() {
        let src = "// audit:allow(R1, \"guarded by the loop condition\")\n\
                   let v = o.unwrap();\n";
        let fa = audit_source("rust/src/lib.rs", src);
        assert!(fa.findings.is_empty());
        assert_eq!(fa.suppressed, 1);
    }

    #[test]
    fn allow_does_not_leak_past_code_lines() {
        let src = "// audit:allow(R1, \"only for the next line\")\n\
                   let a = 1;\n\
                   let v = o.unwrap();\n";
        let fa = audit_source("rust/src/lib.rs", src);
        // The unwrap on line 3 fires, and the directive — which
        // suppressed nothing — is now itself a stale-allow finding.
        assert_eq!(fa.findings.len(), 2);
        assert_eq!(fa.findings[0].line, 1);
        assert_eq!(fa.findings[0].rule, RuleId::R9);
        assert_eq!(fa.findings[1].line, 3);
        assert_eq!(fa.findings[1].rule, RuleId::R1);
    }

    #[test]
    fn wrong_rule_allow_does_not_suppress() {
        let src = "let v = o.unwrap(); // audit:allow(R2, \"wrong rule\")\n";
        let fa = audit_source("rust/src/lib.rs", src);
        assert_eq!(fa.findings.len(), 2, "{:?}", fa.findings);
        assert!(fa.findings.iter().any(|f| f.rule == RuleId::R1));
        assert!(fa.findings.iter().any(|f| f.rule == RuleId::R9));
        assert_eq!(fa.suppressed, 0);
    }

    #[test]
    fn scoping_r2_r3_r5_r6() {
        let hm = "use std::collections::HashMap;\n";
        assert_eq!(audit_source("rust/src/optim/x.rs", hm).findings.len(), 1);
        assert!(audit_source("rust/src/util/x.rs", hm).findings.is_empty());

        let inst = "use std::time::Instant;\n";
        assert_eq!(audit_source("rust/src/latency/x.rs", inst).findings.len(), 1);
        assert!(audit_source("rust/src/util/bench.rs", inst)
            .findings
            .is_empty());
        assert!(audit_source("rust/src/coordinator/driver.rs", inst)
            .findings
            .is_empty());

        let thr = "std::thread::spawn(f);\n";
        assert!(!audit_source("rust/src/optim/x.rs", thr).findings.is_empty());
        assert!(audit_source("rust/src/util/par.rs", thr)
            .findings
            .is_empty());
        let fused = "let y = a.mul_add(b, c);\n";
        assert!(!audit_source("rust/src/runtime/native/kernels.rs", fused)
            .findings
            .is_empty());
        assert!(
            audit_source("rust/src/runtime/native/kernels_fast.rs", fused)
                .findings
                .is_empty()
        );
        assert!(
            audit_source("rust/src/runtime/native/kernels_fast.rs", thr)
                .findings
                .is_empty()
        );

        let cast = "let n = x as u32;\n";
        assert_eq!(audit_source("rust/src/config/toml.rs", cast).findings.len(), 1);
        assert_eq!(
            audit_source("rust/src/coordinator/checkpoint.rs", cast)
                .findings
                .len(),
            1
        );
        assert!(audit_source("rust/src/optim/x.rs", cast).findings.is_empty());
    }

    #[test]
    fn r4_applies_everywhere() {
        let src = "let r = thread_rng();\n";
        for rel in [
            "rust/src/util/rng.rs",
            "rust/tests/t.rs",
            "rust/benches/b.rs",
            "examples/e.rs",
        ] {
            let fa = audit_source(rel, src);
            assert!(
                fa.findings.iter().any(|f| f.rule == RuleId::R4),
                "R4 should fire in {rel}"
            );
        }
    }

    #[test]
    fn severity_default_and_deny_all() {
        assert_eq!(severity(RuleId::R1, false), Severity::Deny);
        assert_eq!(severity(RuleId::R6, false), Severity::Warn);
        assert_eq!(severity(RuleId::R6, true), Severity::Deny);
        assert_eq!(severity(RuleId::R7, false), Severity::Deny);
        assert_eq!(severity(RuleId::R8, false), Severity::Deny);
        assert_eq!(severity(RuleId::R9, false), Severity::Deny);
    }

    #[test]
    fn tokens_in_strings_and_comments_do_not_fire() {
        let src = "let s = \"call .unwrap() on a HashMap\"; // Instant\n";
        let fa = audit_source("rust/src/optim/x.rs", src);
        assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    }

    #[test]
    fn module_of_paths() {
        assert_eq!(module_of("rust/src/optim/bcd.rs"), Some("optim"));
        assert_eq!(module_of("rust/src/error.rs"), Some("error"));
        assert_eq!(module_of("rust/src/main.rs"), Some("bin"));
        assert_eq!(module_of("rust/src/bin/epsl_audit.rs"), Some("bin"));
        assert_eq!(module_of("rust/src/lib.rs"), None);
        assert_eq!(module_of("rust/tests/t.rs"), None);
    }

    #[test]
    fn r7_back_edge_fires_and_downward_edge_does_not() {
        let back = "use crate::coordinator::train;\n";
        let fa = audit_source("rust/src/optim/bcd.rs", back);
        assert_eq!(fa.findings.len(), 1, "{:?}", fa.findings);
        assert_eq!(fa.findings[0].rule, RuleId::R7);
        assert_eq!(fa.findings[0].token, "crate::coordinator");

        let down = "use crate::util::rng::Rng;\nuse crate::channel::Deployment;\n";
        let fa = audit_source("rust/src/optim/bcd.rs", down);
        assert!(fa.findings.is_empty(), "{:?}", fa.findings);
    }

    #[test]
    fn r7_same_rank_cross_edge_fires_self_edge_does_not() {
        // config and analysis share rank 2: neither may read the other.
        let fa = audit_source(
            "rust/src/config/mod.rs",
            "use crate::analysis::engine;\n",
        );
        assert!(fa.findings.iter().any(|f| f.rule == RuleId::R7));
        let fa = audit_source(
            "rust/src/config/toml.rs",
            "use crate::config::NetworkConfig;\n",
        );
        assert!(fa.findings.iter().all(|f| f.rule != RuleId::R7));
    }

    #[test]
    fn r7_applies_inside_test_regions() {
        let src = "#[cfg(test)]\n\
                   mod tests {\n\
                   use crate::experiments::sweep;\n\
                   }\n";
        let fa = audit_source("rust/src/scenario/run.rs", src);
        assert!(
            fa.findings.iter().any(|f| f.rule == RuleId::R7),
            "{:?}",
            fa.findings
        );
    }

    #[test]
    fn r7_error_result_reexports_map_to_error() {
        let src = "use crate::Result;\nfn f() -> crate::Error { todo!() }\n";
        let fa = audit_source("rust/src/util/x.rs", src);
        assert!(
            fa.findings.iter().all(|f| f.rule != RuleId::R7),
            "{:?}",
            fa.findings
        );
    }

    #[test]
    fn r8_literal_fork_fires_outside_tests_only() {
        let src = "let a = rng.fork(0xFEA7);\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn g() { let b = rng.fork(0x2222); }\n\
                   }\n";
        let fa = audit_source("rust/src/scenario/x.rs", src);
        let r8: Vec<usize> = fa
            .findings
            .iter()
            .filter(|f| f.rule == RuleId::R8)
            .map(|f| f.line)
            .collect();
        assert_eq!(r8, vec![1]);
    }

    #[test]
    fn r8_named_fork_checked_against_registry() {
        let reg = StreamRegistry::parse(
            "pub mod streams {\n\
             pub const GOOD_TAG: u64 = 0x1234;\n\
             pub const ALL: [u64; 1] = [GOOD_TAG];\n\
             }\n",
        );
        let good = "let a = rng.fork(streams::GOOD_TAG);\n";
        let fa = audit_source_with("rust/src/scenario/x.rs", good, Some(&reg));
        assert!(fa.findings.is_empty(), "{:?}", fa.findings);

        let bad = "let a = rng.fork(streams::MYSTERY_TAG);\n";
        let fa = audit_source_with("rust/src/scenario/x.rs", bad, Some(&reg));
        assert_eq!(fa.findings.len(), 1);
        assert_eq!(fa.findings[0].rule, RuleId::R8);

        // Without a registry the named check is skipped (fixture mode).
        let fa = audit_source("rust/src/scenario/x.rs", bad);
        assert!(fa.findings.is_empty());
    }

    #[test]
    fn r8_registered_value_as_raw_literal_fires() {
        let reg = StreamRegistry::parse(
            "pub mod streams {\n\
             pub const CHURN_TAG: u64 = 0xC42B;\n\
             pub const ALL: [u64; 1] = [CHURN_TAG];\n\
             }\n",
        );
        let src = "let x = sub(0xC42B);\n";
        let fa = audit_source_with("rust/src/scenario/x.rs", src, Some(&reg));
        assert_eq!(fa.findings.len(), 1, "{:?}", fa.findings);
        assert_eq!(fa.findings[0].rule, RuleId::R8);
        assert!(fa.findings[0].token.contains("CHURN_TAG"));

        // Unregistered large literals are fine.
        let fa = audit_source_with(
            "rust/src/scenario/x.rs",
            "let batch = 4096;\n",
            Some(&reg),
        );
        assert!(fa.findings.is_empty());
    }

    #[test]
    fn r8_registry_self_checks_fire_on_rng_path() {
        let dup = "pub mod streams {\n\
                   pub const A_TAG: u64 = 0x1234;\n\
                   pub const B_TAG: u64 = 0x1234;\n\
                   pub const ALL: [u64; 2] = [A_TAG, B_TAG];\n\
                   }\n";
        let fa = audit_source("rust/src/util/rng.rs", dup);
        assert!(
            fa.findings
                .iter()
                .any(|f| f.rule == RuleId::R8 && f.token.contains("duplicates")),
            "{:?}",
            fa.findings
        );

        let low = "pub mod streams {\n\
                   pub const TINY_TAG: u64 = 0x7;\n\
                   pub const ALL: [u64; 1] = [TINY_TAG];\n\
                   }\n";
        let fa = audit_source("rust/src/util/rng.rs", low);
        assert!(fa
            .findings
            .iter()
            .any(|f| f.rule == RuleId::R8 && f.token.contains("below 0x1000")));

        let drift = "pub mod streams {\n\
                     pub const A_TAG: u64 = 0x1234;\n\
                     pub const B_TAG: u64 = 0x2345;\n\
                     pub const ALL: [u64; 1] = [A_TAG];\n\
                     }\n";
        let fa = audit_source("rust/src/util/rng.rs", drift);
        assert!(fa
            .findings
            .iter()
            .any(|f| f.rule == RuleId::R8 && f.token.contains("ALL")));
    }

    #[test]
    fn r9_stale_allow_fires_and_live_allow_does_not() {
        // Live: the directive suppresses a real finding — no R9.
        let live =
            "let v = o.unwrap(); // audit:allow(R1, \"bounded by caller\")\n";
        let fa = audit_source("rust/src/util/x.rs", live);
        assert!(fa.findings.is_empty());
        assert_eq!(fa.suppressed, 1);

        // Stale: nothing to suppress — the directive itself fires.
        let stale = "let v = 1; // audit:allow(R1, \"obsolete\")\n";
        let fa = audit_source("rust/src/util/x.rs", stale);
        assert_eq!(fa.findings.len(), 1);
        assert_eq!(fa.findings[0].rule, RuleId::R9);
        assert_eq!(fa.findings[0].line, 1);
        assert!(fa.findings[0].token.contains("R1"));
    }

    #[test]
    fn r9_allow_r9_silences_a_kept_stale_directive_once() {
        // A deliberately kept directive: allow(R9) on the same line
        // silences the staleness finding.
        let src = "let v = 1; // audit:allow(R1, \"kept\") audit:allow(R9, \"transition\")\n";
        let fa = audit_source("rust/src/util/x.rs", src);
        assert!(fa.findings.is_empty(), "{:?}", fa.findings);
        assert_eq!(fa.suppressed, 1);

        // But a stale allow(R9) with nothing to silence is reported.
        let src = "let v = o.unwrap(); // audit:allow(R1, \"live\") audit:allow(R9, \"useless\")\n";
        let fa = audit_source("rust/src/util/x.rs", src);
        assert_eq!(fa.findings.len(), 1, "{:?}", fa.findings);
        assert_eq!(fa.findings[0].rule, RuleId::R9);
        assert_eq!(fa.findings[0].token, "audit:allow(R9)");
    }
}
